"""Build-time compile package: L2 JAX model + L1 Pallas kernels + AOT.

Python runs ONCE (`make artifacts`) and never on the request path. The
physics/control constants here are the single source of truth shared with
the Rust mirror in `rust/src/apps/power.rs` (pinned by tests on both
sides).
"""
import jax

# 64-bit mode: the plant model is f64 and the checksum kernel is uint64.
jax.config.update("jax_enable_x64", True)
