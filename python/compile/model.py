"""L2 JAX model: the power-controller compute graph (paper Appendix B).

Three jittable functions, all calling the L1 Pallas kernels where the
hot math lives:

* ``converter_step(state, duty)`` — one plant step (Pallas kernel).
* ``controller_step(v_meas, integ, dt_ctrl)`` — vectorized anti-windup
  PI update for all converters.
* ``closed_loop(period_steps, n_steps)`` — the full closed loop under
  ``lax.scan`` with a one-period measurement delay: the *pure-compute
  reference* for the Fig. 7 stability boundary, used by the tests and
  to cross-check the distributed run.

plus ``checksum_batch`` for the kvstore prefill path.

Constants live in ``kernels/ref.py`` and are mirrored bit-for-bit by
``rust/src/apps/power.rs``.
"""
import functools

import jax
import jax.numpy as jnp

from .kernels import checksum as checksum_kernel
from .kernels import converter as converter_kernel
from .kernels import ref


def converter_step(state, duty):
    """One plant step for a batch of converters (L1 Pallas kernel)."""
    return converter_kernel.converter_step(state, duty)


def controller_step(v_meas, integ, dt_ctrl):
    """PI update; dt_ctrl is a length-1 array so one artifact serves all
    loop periods."""
    return ref.controller_step_ref(v_meas, integ, dt_ctrl)


def checksum_batch(vals):
    """Bulk FNV-1a checksums (L1 Pallas kernel)."""
    return checksum_kernel.checksum(vals)


@functools.partial(jax.jit, static_argnums=(0, 1, 2))
def closed_loop(period_steps: int, n_steps: int, batch: int):
    """Simulate the closed loop: the controller samples every
    ``period_steps`` plant steps and sees voltages one period late.

    Returns v_c trace of shape [n_steps, batch].
    """
    dt_ctrl = jnp.full((1,), period_steps * ref.DT_PLANT)

    def plant_block(carry, _):
        state, integ, duty = carry
        # Controller tick: sample-and-hold on the current voltage (the
        # converters' push from the end of the previous tick, App. B).
        duty, integ = controller_step(state[1], integ, dt_ctrl)

        def step(st, _):
            st2, v = converter_step(st, duty)
            return st2, v

        state, vs = jax.lax.scan(step, state, None, length=period_steps)
        return (state, integ, duty), vs

    state0 = jnp.zeros((2, batch))
    integ0 = jnp.zeros((batch,))
    duty0 = jnp.zeros((batch,))
    blocks = n_steps // period_steps
    _, vs = jax.lax.scan(plant_block, (state0, integ0, duty0), None, length=blocks)
    return vs.reshape(blocks * period_steps, batch)


def tail_ripple(trace):
    """Peak-to-peak ripple over the last quarter of a [T, B] trace."""
    tail = trace[trace.shape[0] * 3 // 4 :]
    return (tail.max(axis=0) - tail.min(axis=0)).max()


def tail_mean(trace):
    tail = trace[trace.shape[0] * 3 // 4 :]
    return tail.mean()
