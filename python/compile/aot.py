"""AOT lowering: JAX/Pallas → HLO **text** artifacts for the Rust runtime.

Interchange is HLO text, NOT a serialized HloModuleProto: jax ≥ 0.5
emits protos with 64-bit instruction ids which the image's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Artifacts:
  converter1.hlo.txt      plant step, batch 1   (one per converter node)
  converter128.hlo.txt    plant step, batch 128 (bulk/bench variant)
  controller<N>.hlo.txt   PI update for N converters (N = 4, 8, 20)
  checksum1.hlo.txt       FNV-1a, 4096 rows × 1 word (kvstore prefill)
  checksum4.hlo.txt       FNV-1a, 1024 rows × 4 words

Usage: python -m compile.aot --out-dir ../artifacts
"""
import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

CONTROLLER_SIZES = (4, 8, 20)


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_converter(batch: int) -> str:
    state = jax.ShapeDtypeStruct((2, batch), jnp.float64)
    duty = jax.ShapeDtypeStruct((batch,), jnp.float64)
    return to_hlo_text(jax.jit(model.converter_step).lower(state, duty))


def lower_controller(n: int) -> str:
    v = jax.ShapeDtypeStruct((n,), jnp.float64)
    integ = jax.ShapeDtypeStruct((n,), jnp.float64)
    dt = jax.ShapeDtypeStruct((1,), jnp.float64)
    return to_hlo_text(jax.jit(model.controller_step).lower(v, integ, dt))


def lower_checksum(rows: int, words: int) -> str:
    vals = jax.ShapeDtypeStruct((rows, words), jnp.uint64)
    return to_hlo_text(jax.jit(model.checksum_batch).lower(vals))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    artifacts = {
        "converter1.hlo.txt": lambda: lower_converter(1),
        "converter128.hlo.txt": lambda: lower_converter(128),
        "checksum1.hlo.txt": lambda: lower_checksum(4096, 1),
        "checksum4.hlo.txt": lambda: lower_checksum(1024, 4),
    }
    for n in CONTROLLER_SIZES:
        artifacts[f"controller{n}.hlo.txt"] = lambda n=n: lower_controller(n)

    for name, build in artifacts.items():
        path = os.path.join(args.out_dir, name)
        text = build()
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {path} ({len(text)} chars)")


if __name__ == "__main__":
    main()
