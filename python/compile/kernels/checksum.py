"""L1 Pallas kernel: batched FNV-1a checksum over 64-bit words.

The kvstore's value-atomicity checksum (paper §5.1.1/§6), computed in
bulk for prefill/verify batches:

    h = OFFSET;  for each word w:  h = (h ^ w) * PRIME   (mod 2^64)

Rows are independent, so the batch axis rides the lanes; the word axis
(W, small and static) is unrolled inside the kernel. The same function
is implemented in Rust (`util::fnv64`) for the per-op hot path — the
python tests and the Rust runtime test pin all three implementations to
identical outputs.
"""
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

LANE = 128


def _kernel(vals_ref, out_ref):
    b, w = vals_ref.shape
    h = jnp.full((b,), ref.FNV_OFFSET, dtype=jnp.uint64)
    for k in range(w):  # static unroll over the word axis
        h = (h ^ vals_ref[:, k]) * jnp.uint64(ref.FNV_PRIME)
    out_ref[:] = h


def checksum(vals):
    """vals: u64[B, W] -> u64[B]."""
    b, w = vals.shape
    if b % LANE == 0 and b > LANE:
        return pl.pallas_call(
            _kernel,
            grid=(b // LANE,),
            in_specs=[pl.BlockSpec((LANE, w), lambda j: (j, 0))],
            out_specs=pl.BlockSpec((LANE,), lambda j: (j,)),
            out_shape=jax.ShapeDtypeStruct((b,), jnp.uint64),
            interpret=True,
        )(vals)
    return pl.pallas_call(
        _kernel,
        out_shape=jax.ShapeDtypeStruct((b,), jnp.uint64),
        interpret=True,
    )(vals)
