"""Pure-jnp oracles for the Pallas kernels.

These are the correctness references: pytest (and hypothesis sweeps)
assert the Pallas kernels match them exactly, and the FNV constants are
additionally pinned against the Rust implementation's test vectors
(`rust/src/util/mod.rs::fnv64`).
"""
import jax.numpy as jnp

# ---- power-converter plant constants (mirror of rust/src/apps/power.rs) --
VIN = 48.0
IND_L = 200e-6
CAP_C = 470e-6
LOAD_R = 2.0
VREF = 24.0
DT_PLANT = 10e-6
KP = 0.015
KI = 32.0
D0 = 0.5
WINDUP = 0.5

# ---- FNV-1a over 64-bit words (mirror of rust/src/util/mod.rs) ----------
# Plain ints: Pallas kernels may not capture array constants, and weak
# typing keeps uint64 arithmetic exact.
FNV_OFFSET = 0xCBF29CE484222325
FNV_PRIME = 0x100000001B3


def converter_step_ref(state, duty):
    """Semi-implicit Euler buck-converter step.

    state: f64[2, B] rows (i_L, v_C); duty: f64[B].
    Returns (state', v_out[B]).
    """
    i_l, v_c = state[0], state[1]
    i2 = i_l + DT_PLANT * (duty * VIN - v_c) / IND_L
    v2 = v_c + DT_PLANT * (i2 - v_c / LOAD_R) / CAP_C
    return jnp.stack([i2, v2]), v2


def checksum_ref(vals):
    """Row-wise FNV-1a over uint64 words. vals: u64[B, W] -> u64[B]."""
    h = jnp.full(vals.shape[0], FNV_OFFSET, dtype=jnp.uint64)
    for w in range(vals.shape[1]):
        h = (h ^ vals[:, w]) * FNV_PRIME
    return h


def controller_step_ref(v_meas, integ, dt_ctrl):
    """Vectorized anti-windup PI update. v_meas/integ f64[B], dt_ctrl f64[1].

    Returns (duty', integ').
    """
    e = VREF - v_meas
    lim = WINDUP / KI
    integ2 = jnp.clip(integ + e * dt_ctrl[0], -lim, lim)
    duty = jnp.clip(D0 + KP * e + KI * integ2, 0.0, 1.0)
    return duty, integ2
