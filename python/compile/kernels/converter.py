"""L1 Pallas kernel: batched buck-converter plant step.

One semi-implicit Euler step of the averaged buck-converter dynamics
(paper Appendix B), vectorized over the converter axis:

    i' = i + dt * (d * Vin - v) / L
    v' = v + dt * (i' - v / R) / C

TPU mapping (DESIGN.md §Hardware-Adaptation): the physics is purely
elementwise, so the natural layout is converters-along-lanes. The
BlockSpec tiles the converter axis in lane-width (128) blocks so the
HBM↔VMEM schedule matches what a real Mosaic lowering would want; VMEM
footprint per block is 5 × 128 × 8 B ≈ 5 KiB — far under budget, so the
kernel is bandwidth-trivial and roofline analysis lives in DESIGN.md.

`interpret=True` always: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret mode lowers to plain HLO that both pytest and
the Rust runtime execute. Correctness is pinned against `ref.py`.
"""
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

LANE = 128


def _kernel(state_ref, duty_ref, out_state_ref, v_ref):
    i_l = state_ref[0, :]
    v_c = state_ref[1, :]
    d = duty_ref[:]
    i2 = i_l + ref.DT_PLANT * (d * ref.VIN - v_c) / ref.IND_L
    v2 = v_c + ref.DT_PLANT * (i2 - v_c / ref.LOAD_R) / ref.CAP_C
    out_state_ref[0, :] = i2
    out_state_ref[1, :] = v2
    v_ref[:] = v2


def converter_step(state, duty):
    """state: f64[2, B], duty: f64[B] -> (state' f64[2, B], v f64[B])."""
    b = state.shape[1]
    if b % LANE == 0 and b > LANE:
        # Tile the converter axis in lane-width blocks.
        grid = (b // LANE,)
        return pl.pallas_call(
            _kernel,
            grid=grid,
            in_specs=[
                pl.BlockSpec((2, LANE), lambda j: (0, j)),
                pl.BlockSpec((LANE,), lambda j: (j,)),
            ],
            out_specs=[
                pl.BlockSpec((2, LANE), lambda j: (0, j)),
                pl.BlockSpec((LANE,), lambda j: (j,)),
            ],
            out_shape=[
                jax.ShapeDtypeStruct((2, b), state.dtype),
                jax.ShapeDtypeStruct((b,), state.dtype),
            ],
            interpret=True,
        )(state, duty)
    # Small batch: single block.
    return pl.pallas_call(
        _kernel,
        out_shape=[
            jax.ShapeDtypeStruct((2, b), state.dtype),
            jax.ShapeDtypeStruct((b,), state.dtype),
        ],
        interpret=True,
    )(state, duty)
