"""L2 correctness: the closed-loop model reproduces the paper's Fig. 7
stability boundary (stable ≤ 40 µs controller period, unstable beyond),
and the constants match the Rust mirror."""
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels import ref


def run(period_us, t=0.3, batch=1):
    period_steps = int(round(period_us / 10))
    n_steps = int(t / ref.DT_PLANT) // period_steps * period_steps
    trace = model.closed_loop(period_steps, n_steps, batch)
    return np.asarray(trace)


def test_stable_at_20us_and_40us():
    for period in (20, 40):
        trace = run(period)
        ripple = float(model.tail_ripple(jnp.asarray(trace)))
        mean = float(model.tail_mean(jnp.asarray(trace)))
        assert ripple < 0.5, f"{period}µs ripple {ripple}"
        assert abs(mean - ref.VREF) < 0.5, f"{period}µs mean {mean}"


def test_unstable_beyond_40us():
    for period in (60, 80):
        trace = run(period)
        ripple = float(model.tail_ripple(jnp.asarray(trace)))
        assert ripple > 10.0, f"{period}µs should oscillate, ripple {ripple}"


def test_batch_converters_independent():
    # All converters share parameters → identical columns.
    trace = run(40, t=0.1, batch=8)
    for b in range(1, 8):
        np.testing.assert_allclose(trace[:, b], trace[:, 0], rtol=1e-12)


def test_constants_match_rust_mirror():
    # Pin the shared constants so neither side drifts (values also
    # hard-coded in rust/src/apps/power.rs).
    assert ref.VIN == 48.0
    assert ref.IND_L == 200e-6
    assert ref.CAP_C == 470e-6
    assert ref.LOAD_R == 2.0
    assert ref.VREF == 24.0
    assert ref.DT_PLANT == 10e-6
    assert ref.KP == 0.015
    assert ref.KI == 32.0
    assert ref.D0 == 0.5
    assert ref.WINDUP == 0.5


def test_open_loop_settles_to_d_vin():
    # Fixed duty 0.5 → v settles to 24 V (plant sanity).
    import jax

    def step(st, _):
        s2, v = model.converter_step(st, jnp.full((1,), 0.5))
        return s2, v

    _, vs = jax.lax.scan(step, jnp.zeros((2, 1)), None, length=30000)
    tail = np.asarray(vs)[-3000:]
    assert abs(tail.mean() - 24.0) < 0.01
    assert tail.max() - tail.min() < 0.01
