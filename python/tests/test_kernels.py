"""L1 correctness: Pallas kernels vs the pure-jnp oracles, swept with
hypothesis across shapes and values. This is the CORE correctness signal
of the compute layer."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import checksum, converter, ref


# ---- FNV checksum kernel ------------------------------------------------

def rust_fnv64(words):
    """Independent python mirror of rust/src/util/mod.rs::fnv64."""
    h = 0xCBF29CE484222325
    for w in words:
        h ^= int(w)
        h = (h * 0x100000001B3) % (1 << 64)
    return h


def test_checksum_matches_rust_vectors():
    # The same vectors rust's runtime test uses (golden ridge between
    # the layers): rows r of (i * golden) for i in 0..32, W=4.
    rows = np.array(
        [[(i * 0x9E3779B97F4A7C15) % (1 << 64) for i in range(r * 4, r * 4 + 4)] for r in range(8)],
        dtype=np.uint64,
    )
    got = np.asarray(checksum.checksum(jnp.asarray(rows)))
    for r in range(8):
        assert got[r] == rust_fnv64(rows[r]), f"row {r}"


def test_checksum_empty_offset():
    # W=1 with word 0: h = (OFFSET ^ 0) * PRIME.
    got = np.asarray(checksum.checksum(jnp.zeros((4, 1), dtype=jnp.uint64)))
    expect = (0xCBF29CE484222325 * 0x100000001B3) % (1 << 64)
    assert (got == expect).all()


@settings(max_examples=30, deadline=None)
@given(
    b=st.sampled_from([1, 3, 16, 128, 256]),
    w=st.integers(min_value=1, max_value=8),
    seed=st.integers(min_value=0, max_value=2**32 - 1),
)
def test_checksum_kernel_vs_ref(b, w, seed):
    rng = np.random.default_rng(seed)
    vals = jnp.asarray(rng.integers(0, 1 << 63, size=(b, w), dtype=np.uint64))
    got = checksum.checksum(vals)
    want = ref.checksum_ref(vals)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # Spot-check one row against the independent python mirror.
    assert int(got[0]) == rust_fnv64(np.asarray(vals)[0])


# ---- converter kernel ---------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(
    b=st.sampled_from([1, 2, 20, 128, 384]),
    seed=st.integers(min_value=0, max_value=2**32 - 1),
)
def test_converter_kernel_vs_ref(b, seed):
    rng = np.random.default_rng(seed)
    state = jnp.asarray(rng.uniform(-5.0, 30.0, size=(2, b)))
    duty = jnp.asarray(rng.uniform(0.0, 1.0, size=(b,)))
    s2, v = converter.converter_step(state, duty)
    s2r, vr = ref.converter_step_ref(state, duty)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(s2r), rtol=1e-12)
    np.testing.assert_allclose(np.asarray(v), np.asarray(vr), rtol=1e-12)


def test_converter_fixed_point():
    # At i = V/R, v = d*Vin the plant is at equilibrium.
    d = 0.5
    v = d * ref.VIN
    i = v / ref.LOAD_R
    state = jnp.asarray([[i], [v]])
    s2, vout = converter.converter_step(state, jnp.asarray([d]))
    np.testing.assert_allclose(np.asarray(s2), np.asarray(state), rtol=1e-12)
    np.testing.assert_allclose(float(vout[0]), v, rtol=1e-12)


def test_converter_dtype_f64():
    s2, v = converter.converter_step(jnp.zeros((2, 4)), jnp.full((4,), 0.5))
    assert s2.dtype == jnp.float64
    assert v.dtype == jnp.float64
    # First step from rest: i rises, v barely moves.
    assert (np.asarray(s2)[0] > 0).all()


# ---- controller ----------------------------------------------------------

def test_controller_at_setpoint_holds_duty():
    v = jnp.full((4,), ref.VREF)
    d, integ = ref.controller_step_ref(v, jnp.zeros((4,)), jnp.asarray([40e-6]))
    np.testing.assert_allclose(np.asarray(d), ref.D0)
    np.testing.assert_allclose(np.asarray(integ), 0.0)


def test_controller_clamps():
    v = jnp.asarray([-1000.0, 1000.0])
    d, integ = ref.controller_step_ref(v, jnp.zeros((2,)), jnp.asarray([1.0]))
    assert float(d[0]) == 1.0 and float(d[1]) == 0.0
    lim = ref.WINDUP / ref.KI
    assert abs(float(integ[0])) <= lim + 1e-15
    assert abs(float(integ[1])) <= lim + 1e-15


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**32 - 1))
def test_controller_duty_always_in_range(seed):
    rng = np.random.default_rng(seed)
    v = jnp.asarray(rng.uniform(-100, 100, size=(16,)))
    integ = jnp.asarray(rng.uniform(-1, 1, size=(16,)))
    d, integ2 = ref.controller_step_ref(v, integ, jnp.asarray([40e-6]))
    assert ((np.asarray(d) >= 0) & (np.asarray(d) <= 1)).all()
    assert (np.abs(np.asarray(integ2)) <= ref.WINDUP / ref.KI + 1e-15).all()
