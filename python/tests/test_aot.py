"""AOT path: every artifact lowers to parseable HLO text with the
expected entry shapes, and executes correctly through jax itself
(the Rust runtime re-validates execution on the PJRT CPU client)."""
import re

import jax
import jax.numpy as jnp
import numpy as np

from compile import aot, model
from compile.kernels import ref


def test_converter_hlo_text_shapes():
    text = aot.lower_converter(1)
    assert "HloModule" in text
    assert "f64[2,1]" in text
    # Tuple return of (state', v).
    assert re.search(r"\(f64\[2,1\].*, .*f64\[1\]", text) or "tuple" in text


def test_controller_hlo_text_shapes():
    text = aot.lower_controller(20)
    assert "HloModule" in text
    assert "f64[20]" in text
    assert "f64[1]" in text  # dt input


def test_checksum_hlo_text_shapes():
    text = aot.lower_checksum(1024, 4)
    assert "HloModule" in text
    assert "u64[1024,4]" in text
    assert "u64[1024]" in text


def test_lowered_converter_executes():
    # Compile the same lowering jax-side and compare against ref.
    state = jnp.asarray([[1.0], [10.0]])
    duty = jnp.asarray([0.7])
    got_s, got_v = jax.jit(model.converter_step)(state, duty)
    want_s, want_v = ref.converter_step_ref(state, duty)
    np.testing.assert_allclose(np.asarray(got_s), np.asarray(want_s), rtol=1e-12)
    np.testing.assert_allclose(np.asarray(got_v), np.asarray(want_v), rtol=1e-12)


def test_all_artifact_builders_produce_text():
    for n in aot.CONTROLLER_SIZES:
        assert "HloModule" in aot.lower_controller(n)
    assert "HloModule" in aot.lower_checksum(4096, 1)
    assert "HloModule" in aot.lower_converter(128)
