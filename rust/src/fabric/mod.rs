//! Simulated RDMA fabric — the substrate LOCO runs on.
//!
//! The paper evaluates on ConnectX-5 RoCE NICs; this module replaces the
//! hardware with a faithful software model of the RDMA contract LOCO
//! depends on (paper §2.2 / RFC 5040):
//!
//! * **One-sided verbs**: READ, WRITE, FETCH_ADD, COMPARE_SWAP, plus the
//!   zero-length READ used as a fence primitive, and two-sided SEND/RECV
//!   (used only for channel setup, as in the paper).
//! * **Per-QP ordering**: writes on the same queue pair are placed in
//!   submission order.
//! * **Completion ≠ placement**: a WRITE's completion is delivered to the
//!   issuer when the data has *arrived* at the remote NIC; the *placement*
//!   of the data into remote memory may lag completion. This is the
//!   weak-consistency hazard the paper's fences exist to tame.
//! * **Read/atomic flushes prior writes**: a remote READ or atomic on a QP
//!   forces full placement of all earlier WRITEs on that QP before it
//!   completes — the mechanism LOCO's fences are built from.
//! * **Word atomicity**: aligned accesses of at most 8 bytes are untorn;
//!   larger payloads are placed word-by-word and may be observed torn
//!   (hence owned_var's checksum protocol).
//!
//! All offsets and lengths are in 8-byte **words**; network memory is an
//! array of `AtomicU64`. This matches the paper's "CPU-atomic word size"
//! reasoning exactly and keeps the simulation free of UB.

pub mod cq;
pub mod faults;
pub mod memory;
pub mod network;
pub mod nic;
pub mod qp;
pub mod verbs;

pub use cq::{CompletionQueue, Cqe, CqeStatus};
pub use faults::FaultPlan;
pub use memory::{Arena, MrTable, Region, DEVICE_BASE};
pub use network::{Cluster, NodeFabric};
pub use qp::{Qp, QpId, Submission};
pub use verbs::{Payload, PostList, Verb, Wqe};

use std::time::Instant;

/// Node identifier within a cluster (dense, 0-based).
pub type NodeId = u32;

/// How verbs are executed.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DeliveryMode {
    /// Execute verbs synchronously at post time in the caller thread.
    /// Placement is immediate (but still ordered). No background threads.
    /// Deterministic-ish; used by unit tests of channel logic.
    Inline,
    /// One NIC-engine thread per node processes that node's outgoing
    /// verbs: latency-stamped arrival events, decoupled placement events,
    /// real data races between placement and application reads. Used by
    /// consistency tests and all benchmarks.
    Threaded,
    /// Single-threaded discrete-event simulation: the same engine cores
    /// as `Threaded`, but stepped cooperatively by a
    /// [`SimExecutor`](crate::sim::SimExecutor) over **virtual time**.
    /// No engine threads are spawned; submissions queue until the sim
    /// scheduler pumps them. Every nondeterministic choice is drawn from
    /// one seeded RNG stream, so the same seed replays bit-identically.
    Sim,
}

/// Latency/bandwidth model. All values in nanoseconds.
#[derive(Clone, Debug)]
pub struct LatencyModel {
    /// Base one-sided READ latency (post → completion), small payload.
    pub read_ns: u64,
    /// Base one-sided WRITE latency (post → completion).
    pub write_ns: u64,
    /// Base remote-atomic latency (FAA / CAS).
    pub atomic_ns: u64,
    /// Two-sided SEND delivery latency.
    pub send_ns: u64,
    /// Additional per-word transfer cost (bandwidth term). 25 Gbps is
    /// ~2.56 ns per 8-byte word on the wire.
    pub per_word_ns: f64,
    /// Per-WQE NIC processing overhead; bounds per-QP op rate when the
    /// application pipelines many outstanding requests (window > 1).
    pub op_overhead_ns: u64,
    /// Per-**doorbell** cost (the MMIO write that tells the NIC new WQEs
    /// are ready). Charged once per `post` and once per `post_list`
    /// regardless of batch size — the reason posting N work requests per
    /// doorbell beats N scalar posts (paper §2.2's cheap asynchrony;
    /// cf. Brock et al.'s op-aggregation results).
    pub doorbell_ns: u64,
    /// Per-**signaled** WQE cost of generating its CQE (the NIC's DMA
    /// write into the completion queue). Unsignaled WQEs skip it
    /// entirely — the selective-signaling economy: a chain of N writes
    /// with only the last signaled pays this once, not N times. Charged
    /// into both the op's latency and the QP's serialization term (CQE
    /// generation occupies the NIC per WQE, like `op_overhead_ns`).
    pub completion_ns: u64,
    /// Per-WRITE cost of the NIC's DMA read fetching a non-inline
    /// payload from registered host memory (the PCIe round every
    /// scatter-gather WRITE pays before its data can hit the wire).
    /// WRITEs posted **inline** replace this with `inline_ns`.
    pub wqe_fetch_ns: u64,
    /// Per-WRITE cost of an inline payload (the CPU copied the data into
    /// the WQE at post time, so the NIC has it immediately). Replaces
    /// `wqe_fetch_ns` for writes of ≤ `max_inline_words`.
    pub inline_ns: u64,
    /// Largest WRITE payload (words) the device accepts inline
    /// (ConnectX-class NICs: 220 B ≈ 27 words; we round to 28).
    /// `ThreadCtx::write`/`write_many` inline automatically at or below
    /// this; 0 disables inlining (the ablation baseline).
    pub max_inline_words: usize,
    /// Placement lag after completion, uniform in `[0, placement_lag_ns]`.
    /// This is the §2.2 "placement may happen during and after completion"
    /// window.
    pub placement_lag_ns: u64,
    /// Per-op penalty applied when the *target* node has more registered
    /// memory regions than the NIC's MR cache can hold (`mr_cache_entries`).
    /// Models the NIC caching-structure effect the paper cites ([33]) to
    /// explain OpenMPI's transactional-locking loss in Fig. 4.
    pub mr_miss_ns: u64,
    /// Number of MR translations the simulated NIC caches.
    pub mr_cache_entries: usize,
    /// Extra latency for regions allocated in NIC device memory is
    /// *subtracted* (device memory avoids the PCIe hop): `device_mem_save_ns`.
    pub device_mem_save_ns: u64,
    /// Per-**engine** execution occupancy: each engine lane retires at
    /// most one WQE per this many nanoseconds, round-robin across the
    /// QPs it owns. This is the processing-unit serialization that makes
    /// `engines_per_node` a *modeled* throughput axis (Brock et al.'s
    /// injection-rate parallelism) rather than a host-core artifact —
    /// E lanes retire E WQEs per quantum. 0 (the default everywhere)
    /// disables the term entirely: execution happens the instant an
    /// arrival is due, byte-for-byte the pre-occupancy behavior. The
    /// `fig4_engine_scaling` cell is the intended consumer.
    pub engine_occupancy_ns: u64,
}

impl LatencyModel {
    /// Zero-latency model: completions and placement are immediate.
    pub fn ideal() -> Self {
        LatencyModel {
            read_ns: 0,
            write_ns: 0,
            atomic_ns: 0,
            send_ns: 0,
            per_word_ns: 0.0,
            op_overhead_ns: 0,
            doorbell_ns: 0,
            completion_ns: 0,
            wqe_fetch_ns: 0,
            inline_ns: 0,
            max_inline_words: 28,
            placement_lag_ns: 0,
            mr_miss_ns: 0,
            mr_cache_entries: usize::MAX,
            device_mem_save_ns: 0,
            engine_occupancy_ns: 0,
        }
    }

    /// Calibrated to published ConnectX-5 RoCE (25 Gbps) microbenchmarks:
    /// ~2.7–3 µs small READ, ~2.5 µs WRITE completion, ~3.6 µs atomics.
    pub fn roce25() -> Self {
        LatencyModel {
            read_ns: 2900,
            write_ns: 2500,
            atomic_ns: 3600,
            send_ns: 4000,
            per_word_ns: 2.56,
            op_overhead_ns: 120,
            doorbell_ns: 450,
            completion_ns: 300,
            wqe_fetch_ns: 500,
            inline_ns: 50,
            max_inline_words: 28,
            placement_lag_ns: 1200,
            mr_miss_ns: 900,
            mr_cache_entries: 64,
            device_mem_save_ns: 600,
            engine_occupancy_ns: 0,
        }
    }

    /// `roce25` scaled down 20× so benchmark sweeps finish quickly while
    /// preserving every latency *ratio* (shapes of all figures hold).
    pub fn fast_sim() -> Self {
        let r = Self::roce25();
        LatencyModel {
            read_ns: r.read_ns / 20,
            write_ns: r.write_ns / 20,
            atomic_ns: r.atomic_ns / 20,
            send_ns: r.send_ns / 20,
            per_word_ns: r.per_word_ns / 20.0,
            op_overhead_ns: r.op_overhead_ns / 20,
            doorbell_ns: r.doorbell_ns / 20,
            completion_ns: r.completion_ns / 20,
            wqe_fetch_ns: r.wqe_fetch_ns / 20,
            inline_ns: r.inline_ns / 20,
            max_inline_words: r.max_inline_words,
            placement_lag_ns: r.placement_lag_ns / 20,
            mr_miss_ns: r.mr_miss_ns / 20,
            mr_cache_entries: r.mr_cache_entries,
            device_mem_save_ns: r.device_mem_save_ns / 20,
            engine_occupancy_ns: r.engine_occupancy_ns,
        }
    }

    /// Override the inline threshold (builder style, for ablations).
    pub fn with_max_inline_words(mut self, words: usize) -> Self {
        self.max_inline_words = words;
        self
    }

    /// Enable per-engine execution occupancy (builder style; see
    /// [`LatencyModel::engine_occupancy_ns`]). The engine-scaling bench
    /// uses this so E engines ⇒ E× structural WQE throughput is a
    /// property of the model, independent of host core count.
    pub fn with_engine_occupancy(mut self, ns: u64) -> Self {
        self.engine_occupancy_ns = ns;
        self
    }
}

/// Fabric configuration.
#[derive(Clone, Debug)]
pub struct FabricConfig {
    pub delivery: DeliveryMode,
    pub latency: LatencyModel,
    /// Words of host network memory per node (8 bytes each).
    pub node_mem_words: usize,
    /// Words of NIC device memory per node.
    pub device_mem_words: usize,
    /// Validate remote accesses against the target's registered regions.
    pub validate_access: bool,
    /// Insert thread yields between word stores during placement, widening
    /// the torn-write window (chaos testing of checksum/fence machinery).
    pub chaotic_placement: bool,
    /// RNG seed for latency jitter / placement lag sampling.
    pub seed: u64,
    /// Seeded fault injection (delay / reorder / duplicate / QP flap /
    /// crash-stop). `None` — the default — costs the hot paths only an
    /// `Option` branch; see [`faults::FaultPlan`].
    pub faults: Option<FaultPlan>,
    /// Selective-signaling chain length for the batched write paths:
    /// `ThreadCtx::write_many`/`write_covered` signal only every Nth
    /// WQE (and the last of a batch); the one CQE retires the whole
    /// covered prefix. `0` or `1` signals everything (the pre-PR-5
    /// behavior; the ablation baseline). Overridable per process via
    /// `LOCO_SIGNAL_EVERY`.
    pub signal_every: u32,
    /// Happens-before race/consistency checking ([`crate::analysis`]):
    /// `Auto` — the default — runs the full checker under
    /// `DeliveryMode::Sim` and nothing elsewhere, so threaded
    /// benchmarks pay only a dead `OnceLock` branch per arena access
    /// (`bench::micro::check_hook_overhead` pins it). Overridable per
    /// process via `LOCO_CHECK` (`off`, `structural`, `full`).
    pub check_races: crate::analysis::CheckMode,
    /// NIC engines per node. QPs are striped across engines by stable
    /// `qp_id % engines_per_node` assignment, so per-QP WQE/CQE FIFO —
    /// and with it covered-chain retirement and fence semantics — is
    /// untouched; only *cross-QP* parallelism grows. Threaded mode runs
    /// this many engine threads per node; sim mode registers this many
    /// steppable engine actors per node from the same seeded scheduler
    /// stream. `1` (the default) is byte-for-byte the single-engine
    /// behavior. Overridable per process via `LOCO_ENGINES`.
    pub engines_per_node: u32,
}

/// Default selective-signaling chain length (overridable with
/// `LOCO_SIGNAL_EVERY`; `1` disables).
///
/// The override is validated at config construction: an unparseable
/// value or `0` aborts with a diagnosis instead of being silently
/// swallowed (the seed behavior fell back to 16 on typos, and `0`
/// would wedge the covered-chain retire cadence).
fn default_signal_every() -> u32 {
    match parse_signal_every(std::env::var("LOCO_SIGNAL_EVERY").ok().as_deref()) {
        Ok(n) => n,
        Err(e) => panic!("invalid LOCO_SIGNAL_EVERY: {e}"),
    }
}

/// Default checker mode (overridable with `LOCO_CHECK`). Validated the
/// same way as `LOCO_SIGNAL_EVERY`: garbage aborts with a diagnosis
/// instead of silently running unchecked.
fn default_check_mode() -> crate::analysis::CheckMode {
    match crate::analysis::parse_check_mode(std::env::var("LOCO_CHECK").ok().as_deref()) {
        Ok(m) => m,
        Err(e) => panic!("invalid LOCO_CHECK: {e}"),
    }
}

/// Default NIC-engine count per node (overridable with `LOCO_ENGINES`).
/// Validated like `LOCO_SIGNAL_EVERY`: garbage aborts with a diagnosis
/// instead of silently running single-engined.
fn default_engines() -> u32 {
    match parse_engines(std::env::var("LOCO_ENGINES").ok().as_deref()) {
        Ok(n) => n,
        Err(e) => panic!("invalid LOCO_ENGINES: {e}"),
    }
}

/// Parse an optional `LOCO_ENGINES` override. `None` (unset) means one
/// engine per node; anything set must parse to an integer ≥ 1.
fn parse_engines(raw: Option<&str>) -> Result<u32, String> {
    match raw {
        None => Ok(1),
        Some(v) => match v.trim().parse::<u32>() {
            Ok(0) => Err(format!(
                "{v:?} — a node needs at least one NIC engine to execute its QPs; \
                 use 1 for the serial (default) configuration"
            )),
            Ok(n) => Ok(n),
            Err(_) => Err(format!("{v:?} is not a positive integer (expected 1, 2, 4, ...)")),
        },
    }
}

/// Parse an optional `LOCO_SIGNAL_EVERY` override. `None` (unset) means
/// the default of 16; anything set must parse to an integer ≥ 1.
fn parse_signal_every(raw: Option<&str>) -> Result<u32, String> {
    match raw {
        None => Ok(16),
        Some(v) => match v.trim().parse::<u32>() {
            Ok(0) => Err(format!(
                "{v:?} — a chain length of 0 has no signaled WQE to retire the covered \
                 prefix; use 1 to signal every WQE"
            )),
            Ok(n) => Ok(n),
            Err(_) => Err(format!("{v:?} is not a positive integer (expected 1, 4, 16, ...)")),
        },
    }
}

impl FabricConfig {
    pub fn inline_ideal() -> Self {
        FabricConfig {
            delivery: DeliveryMode::Inline,
            latency: LatencyModel::ideal(),
            node_mem_words: 1 << 22,
            device_mem_words: 1 << 12,
            validate_access: true,
            chaotic_placement: false,
            seed: 0x10c0,
            faults: None,
            signal_every: default_signal_every(),
            check_races: default_check_mode(),
            engines_per_node: default_engines(),
        }
    }

    pub fn threaded(latency: LatencyModel) -> Self {
        FabricConfig {
            delivery: DeliveryMode::Threaded,
            latency,
            node_mem_words: 1 << 22,
            device_mem_words: 1 << 12,
            validate_access: true,
            chaotic_placement: false,
            seed: 0x10c0,
            faults: None,
            signal_every: default_signal_every(),
            check_races: default_check_mode(),
            engines_per_node: default_engines(),
        }
    }

    /// Deterministic-simulation config: the `Threaded` semantics (arrival
    /// stamping, placement lag, faults) stepped over virtual time by a
    /// [`SimExecutor`](crate::sim::SimExecutor). `seed` drives both the
    /// fabric jitter and the sim scheduler.
    pub fn sim(latency: LatencyModel, seed: u64) -> Self {
        let mut cfg = Self::threaded(latency);
        cfg.delivery = DeliveryMode::Sim;
        cfg.seed = seed.max(1);
        cfg
    }

    pub fn with_mem_words(mut self, words: usize) -> Self {
        self.node_mem_words = words;
        self
    }

    /// Override the selective-signaling chain length (`1` = signal every
    /// WQE, the pre-selective behavior).
    pub fn with_signal_every(mut self, n: u32) -> Self {
        self.signal_every = n;
        self
    }

    /// Override the NIC-engine count per node (`1` = the serial
    /// single-engine configuration); wins over the `LOCO_ENGINES`
    /// default. QPs stripe across engines by `qp_id % n`.
    pub fn with_engines(mut self, n: u32) -> Self {
        assert!(n >= 1, "a node needs at least one NIC engine");
        self.engines_per_node = n;
        self
    }

    pub fn chaotic(mut self) -> Self {
        self.chaotic_placement = true;
        self
    }

    /// Install a seeded [`FaultPlan`] (threaded delivery recommended:
    /// inline mode honors crash-stop but has no in-flight window for
    /// delay / reorder / duplication to act on).
    pub fn with_faults(mut self, plan: FaultPlan) -> Self {
        self.faults = Some(plan);
        self
    }

    /// Override the race-checker mode (see [`crate::analysis`]); wins
    /// over the `LOCO_CHECK` default.
    pub fn with_check(mut self, mode: crate::analysis::CheckMode) -> Self {
        self.check_races = mode;
        self
    }
}

/// Monotonic clock shared by a cluster, in nanoseconds since creation.
///
/// `Wall` (the default) reads the host's monotonic clock. `Virtual` is
/// a shared counter advanced **only** by the sim scheduler
/// ([`crate::sim`]): time jumps straight to the next due event, so a
/// 64-node schedule covering minutes of simulated traffic runs in
/// wall-clock seconds, and two runs with the same seed read identical
/// timestamps.
#[derive(Clone, Debug)]
pub enum Clock {
    Wall { base: Instant },
    Virtual { now: std::sync::Arc<std::sync::atomic::AtomicU64> },
}

impl Clock {
    pub fn new() -> Self {
        Clock::Wall { base: Instant::now() }
    }

    /// A virtual clock starting at 0 (advanced via [`Clock::advance_to`]).
    pub fn new_virtual() -> Self {
        Clock::Virtual { now: std::sync::Arc::new(std::sync::atomic::AtomicU64::new(0)) }
    }

    #[inline]
    pub fn now_ns(&self) -> u64 {
        match self {
            Clock::Wall { base } => base.elapsed().as_nanos() as u64,
            Clock::Virtual { now } => now.load(std::sync::atomic::Ordering::Relaxed),
        }
    }

    /// Is this a virtual (sim-driven) clock?
    #[inline]
    pub fn is_virtual(&self) -> bool {
        matches!(self, Clock::Virtual { .. })
    }

    /// Advance a virtual clock to `ns` (monotonic: earlier targets are
    /// ignored). Panics on a wall clock — only the sim scheduler owns
    /// time here.
    pub fn advance_to(&self, ns: u64) {
        match self {
            Clock::Virtual { now } => {
                now.fetch_max(ns, std::sync::atomic::Ordering::Relaxed);
            }
            Clock::Wall { .. } => panic!("advance_to on a wall clock"),
        }
    }
}

impl Default for Clock {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::{parse_engines, parse_signal_every};

    #[test]
    fn engines_override_is_validated() {
        // Unset: one engine per node, the serial seed behavior.
        assert_eq!(parse_engines(None), Ok(1));
        // Any integer ≥ 1 is accepted (whitespace tolerated).
        assert_eq!(parse_engines(Some("2")), Ok(2));
        assert_eq!(parse_engines(Some(" 4 ")), Ok(4));
        // 0 engines would leave every QP unowned — rejected with a
        // diagnosis, not silently defaulted.
        let err = parse_engines(Some("0")).unwrap_err();
        assert!(err.contains("at least one"), "diagnosis should explain the 0 hazard: {err}");
        // Typos must not silently fall back to 1.
        assert!(parse_engines(Some("two")).is_err());
        assert!(parse_engines(Some("-2")).is_err());
        assert!(parse_engines(Some("")).is_err());
    }

    #[test]
    fn signal_every_override_is_validated() {
        // Unset: the default chain length.
        assert_eq!(parse_signal_every(None), Ok(16));
        // Any integer ≥ 1 is accepted (whitespace tolerated).
        assert_eq!(parse_signal_every(Some("1")), Ok(1));
        assert_eq!(parse_signal_every(Some(" 64 ")), Ok(64));
        // 0 would leave covered chains with no signaled WQE to retire
        // them — rejected with a diagnosis, not silently defaulted.
        let err = parse_signal_every(Some("0")).unwrap_err();
        assert!(err.contains("covered"), "diagnosis should explain the 0 hazard: {err}");
        // Typos must not silently fall back to 16 (the seed bug).
        assert!(parse_signal_every(Some("sixteen")).is_err());
        assert!(parse_signal_every(Some("-4")).is_err());
        assert!(parse_signal_every(Some("")).is_err());
    }
}
