//! Queue pairs.
//!
//! A QP is a unidirectional submission endpoint from one node toward one
//! peer. LOCO gives every application thread a private QP per peer
//! (paper Appendix A.1), so submission is single-producer in practice;
//! the queue is MPMC-safe regardless.
//!
//! Ordering guarantees (paper §2.2) are enforced by the NIC engine, which
//! consumes each QP's submissions strictly in FIFO order and keeps
//! per-QP arrival times monotonic.

use std::sync::Arc;

use crate::util::queue::Queue;

use super::verbs::Wqe;
use super::NodeId;

/// Identifies a QP: owned by `node`, at `index` in that node's QP table.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct QpId {
    pub node: NodeId,
    pub index: u32,
}

pub struct Qp {
    pub id: QpId,
    /// Target node of all verbs posted on this QP.
    pub peer: NodeId,
    subq: Arc<Queue<Wqe>>,
}

impl Qp {
    pub fn new(id: QpId, peer: NodeId) -> Self {
        Qp { id, peer, subq: Arc::new(Queue::new()) }
    }

    /// Enqueue a work request (threaded mode; the NIC engine drains it).
    #[inline]
    pub fn submit(&self, wqe: Wqe) {
        self.subq.push(wqe);
    }

    /// Engine-side drain handle.
    pub fn submission_queue(&self) -> Arc<Queue<Wqe>> {
        self.subq.clone()
    }

    pub fn pending(&self) -> usize {
        self.subq.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::verbs::{Payload, Verb};

    #[test]
    fn fifo_submission() {
        let qp = Qp::new(QpId { node: 0, index: 0 }, 1);
        for i in 0..4 {
            qp.submit(Wqe {
                wr_id: i,
                verb: Verb::Write { remote: 0, data: Payload::one(i) },
                signaled: true,
            });
        }
        assert_eq!(qp.pending(), 4);
        let q = qp.submission_queue();
        for i in 0..4 {
            assert_eq!(q.try_pop().unwrap().wr_id, i);
        }
    }
}
