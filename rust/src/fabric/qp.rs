//! Queue pairs.
//!
//! A QP is a unidirectional submission endpoint from one node toward one
//! peer. LOCO gives every application thread a private QP per peer
//! (paper Appendix A.1), so submission is single-producer in practice;
//! the queue is MPMC-safe regardless.
//!
//! Ordering guarantees (paper §2.2) are enforced by the NIC engine, which
//! consumes each QP's submissions strictly in FIFO order and keeps
//! per-QP arrival times monotonic.
//!
//! Submissions arrive either one WQE per doorbell ([`Qp::submit`]) or as
//! a **doorbell-batched list** ([`Qp::submit_list`]): only the head of a
//! list rings the doorbell, the tail rides along for free. The engine
//! charges `LatencyModel::doorbell_ns` once per doorbell, which is what
//! makes batching measurable (see `bench::micro`'s ablation).

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use crate::util::queue::Queue;

use super::verbs::Wqe;
use super::NodeId;

/// Identifies a QP: owned by `node`, at `index` in that node's QP table.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct QpId {
    pub node: NodeId,
    pub index: u32,
}

/// A work request as it sits in a submission queue: the WQE plus whether
/// it paid for a doorbell ring (head of a post) or rode a predecessor's
/// doorbell (tail of a batched [`PostList`](super::verbs::PostList)).
#[derive(Clone, Debug)]
pub struct Submission {
    pub wqe: Wqe,
    pub rings_doorbell: bool,
}

pub struct Qp {
    pub id: QpId,
    /// Target node of all verbs posted on this QP.
    pub peer: NodeId,
    subq: Arc<Queue<Submission>>,
    /// Transient error state (fault injection: a "flapped" QP). While
    /// set, the NIC engine executes nothing on this QP; on recovery it
    /// retransmits everything in flight, in order, with an extra
    /// penalty. Mirrors the IBV_QPS_ERR → reset → RTS cycle without the
    /// state machine.
    error: AtomicBool,
    /// Selective-signaling chain error: set when an **unsignaled** WQE
    /// on this QP fails (its target crash-stopped), consumed by the next
    /// signaled completion, which is then delivered as `PeerFailed` even
    /// if its own verb would have succeeded. This is the software
    /// analogue of a real QP transitioning to the error state: an
    /// unsignaled WR can never report its own failure, so the covering
    /// signaled WR of its chain must.
    chain_error: AtomicBool,
}

impl Qp {
    pub fn new(id: QpId, peer: NodeId) -> Self {
        Qp {
            id,
            peer,
            subq: Arc::new(Queue::new()),
            error: AtomicBool::new(false),
            chain_error: AtomicBool::new(false),
        }
    }

    /// Is this QP currently in the (transient) error state?
    #[inline]
    pub fn is_error(&self) -> bool {
        self.error.load(Ordering::Relaxed)
    }

    /// Engine-side: move the QP into or out of the error state.
    pub(super) fn set_error(&self, err: bool) {
        self.error.store(err, Ordering::Relaxed);
    }

    /// An unsignaled WQE on this QP failed: remember it so the next
    /// signaled completion reports the chain's failure.
    pub(super) fn raise_chain_error(&self) {
        self.chain_error.store(true, Ordering::Release);
    }

    /// Consume the chain-error flag (called when generating a CQE for a
    /// signaled WQE on this QP).
    pub(super) fn take_chain_error(&self) -> bool {
        self.chain_error.swap(false, Ordering::AcqRel)
    }

    /// Is a failed-unsignaled-WQE chain error pending? (Introspection
    /// for tests; the flag is consumed by the next signaled CQE.)
    pub fn chain_error_pending(&self) -> bool {
        self.chain_error.load(Ordering::Acquire)
    }

    /// Enqueue a single work request (threaded mode; the NIC engine
    /// drains it). One doorbell per call.
    #[inline]
    pub fn submit(&self, wqe: Wqe) {
        self.subq.push(Submission { wqe, rings_doorbell: true });
    }

    /// Enqueue an ordered batch of work requests under a single
    /// doorbell: one lock round, one wakeup, one `doorbell_ns` charge
    /// for the whole list.
    pub fn submit_list(&self, wqes: Vec<Wqe>) {
        let mut first = true;
        self.subq.push_batch(wqes.into_iter().map(|wqe| {
            let sub = Submission { wqe, rings_doorbell: first };
            first = false;
            sub
        }));
    }

    /// Engine-side drain handle.
    pub fn submission_queue(&self) -> Arc<Queue<Submission>> {
        self.subq.clone()
    }

    pub fn pending(&self) -> usize {
        self.subq.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::verbs::{Payload, Verb};

    #[test]
    fn fifo_submission() {
        let qp = Qp::new(QpId { node: 0, index: 0 }, 1);
        for i in 0..4 {
            qp.submit(Wqe::new(i, Verb::Write { remote: 0, data: Payload::one(i) }));
        }
        assert_eq!(qp.pending(), 4);
        let q = qp.submission_queue();
        for i in 0..4 {
            let sub = q.try_pop().unwrap();
            assert_eq!(sub.wqe.wr_id, i);
            assert!(sub.rings_doorbell, "scalar submits each ring the doorbell");
        }
    }

    #[test]
    fn batched_submission_single_doorbell() {
        let qp = Qp::new(QpId { node: 0, index: 0 }, 1);
        let wqes: Vec<Wqe> = (0..5)
            .map(|i| Wqe::new(i, Verb::Write { remote: 0, data: Payload::one(i) }))
            .collect();
        qp.submit_list(wqes);
        assert_eq!(qp.pending(), 5);
        let q = qp.submission_queue();
        for i in 0..5 {
            let sub = q.try_pop().unwrap();
            assert_eq!(sub.wqe.wr_id, i, "batch preserves FIFO order");
            assert_eq!(sub.rings_doorbell, i == 0, "only the batch head rings");
        }
    }
}
