//! Completion queues.
//!
//! As in LOCO's backend (paper Appendix A.1), each node funnels all
//! completions into a single shared CQ which a dedicated polling thread
//! drains.

use crate::util::queue::Queue;

use super::qp::QpId;

/// Completion status. Real verbs carry a rich status enum
/// (`IBV_WC_SUCCESS`, retry-exceeded, …); the simulation needs only the
/// distinction LOCO's error propagation acts on: did the op take effect,
/// or did the peer (or the local port) fail?
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CqeStatus {
    /// The op executed at the target.
    Ok,
    /// The target node crash-stopped (or the issuing node is itself
    /// dead): the op had **no remote effect** and any local result
    /// buffer is unchanged.
    PeerFailed,
}

/// Completion queue entry.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Cqe {
    pub wr_id: u64,
    pub qp: QpId,
    pub status: CqeStatus,
}

impl Cqe {
    /// A successful completion.
    #[inline]
    pub fn ok(wr_id: u64, qp: QpId) -> Cqe {
        Cqe { wr_id, qp, status: CqeStatus::Ok }
    }

    /// An error completion (peer crash-stopped).
    #[inline]
    pub fn failed(wr_id: u64, qp: QpId) -> Cqe {
        Cqe { wr_id, qp, status: CqeStatus::PeerFailed }
    }

    #[inline]
    pub fn is_ok(&self) -> bool {
        self.status == CqeStatus::Ok
    }
}

pub struct CompletionQueue {
    q: Queue<Cqe>,
    /// CQEs ever posted (monotonic). The selective-signaling tests and
    /// benches diff this to show completions *avoided*, the same way
    /// `Cluster::ops_posted` shows remote ops avoided by the cache.
    posted: std::sync::atomic::AtomicU64,
}

impl CompletionQueue {
    pub fn new() -> Self {
        CompletionQueue { q: Queue::new(), posted: std::sync::atomic::AtomicU64::new(0) }
    }

    #[inline]
    pub fn post(&self, cqe: Cqe) {
        self.posted.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        self.q.push(cqe);
    }

    /// CQEs ever posted to this queue (monotonic).
    pub fn posted(&self) -> u64 {
        self.posted.load(std::sync::atomic::Ordering::Relaxed)
    }

    /// Drain up to `max` completions into `out`; returns the count.
    pub fn poll(&self, max: usize, out: &mut Vec<Cqe>) -> usize {
        self.q.drain_into(max, out)
    }

    /// Blocking poll of a single completion (test helper). Spins through
    /// `Backoff::snooze` rather than the condvar so it also works under
    /// the deterministic simulator (where the snooze pumps the
    /// scheduler).
    pub fn poll_one_blocking(&self) -> Cqe {
        let mut backoff = crate::util::Backoff::new();
        let mut budget = crate::util::WaitBudget::wedge(std::time::Duration::from_secs(30));
        loop {
            if let Some(cqe) = self.q.try_pop() {
                return cqe;
            }
            backoff.snooze();
            assert!(!budget.expired(), "cq poll timed out");
        }
    }

    /// Blocking poll with timeout (the polling thread's backstop path).
    pub fn poll_timeout(&self, timeout: std::time::Duration) -> Option<Cqe> {
        self.q.pop_timeout(timeout)
    }

    pub fn is_empty(&self) -> bool {
        self.q.is_empty()
    }
}

impl Default for CompletionQueue {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn post_and_poll() {
        let cq = CompletionQueue::new();
        assert!(cq.is_empty());
        for i in 0..5 {
            cq.post(Cqe::ok(i, QpId { node: 0, index: 0 }));
        }
        let mut out = Vec::new();
        assert_eq!(cq.poll(3, &mut out), 3);
        assert_eq!(out.len(), 3);
        assert_eq!(out[0].wr_id, 0);
        assert_eq!(cq.poll(10, &mut out), 2);
        assert_eq!(cq.poll(10, &mut out), 0);
    }
}
