//! Verb and work-request types for the simulated fabric.

use super::NodeId;

/// Payload for WRITE verbs. Small payloads (≤ 4 words, the common case for
/// LOCO channel metadata) are stored inline to keep the hot path
/// allocation-free; larger payloads are boxed.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Payload {
    Inline { len: u8, words: [u64; 4] },
    Heap(Box<[u64]>),
}

impl Payload {
    pub fn from_words(words: &[u64]) -> Payload {
        if words.len() <= 4 {
            let mut buf = [0u64; 4];
            buf[..words.len()].copy_from_slice(words);
            Payload::Inline { len: words.len() as u8, words: buf }
        } else {
            Payload::Heap(words.to_vec().into_boxed_slice())
        }
    }

    pub fn one(word: u64) -> Payload {
        Payload::Inline { len: 1, words: [word, 0, 0, 0] }
    }

    #[inline]
    pub fn as_slice(&self) -> &[u64] {
        match self {
            Payload::Inline { len, words } => &words[..*len as usize],
            Payload::Heap(b) => b,
        }
    }

    #[inline]
    pub fn len(&self) -> usize {
        match self {
            Payload::Inline { len, .. } => *len as usize,
            Payload::Heap(b) => b.len(),
        }
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// One-sided and two-sided verbs. All addresses are word offsets in the
/// *target* node's address space; `local` addresses are word offsets in
/// the *issuing* node's address space (results of READs and atomics are
/// placed into local registered memory, as on real hardware).
#[derive(Clone, Debug)]
pub enum Verb {
    /// RDMA WRITE: place `data` at `remote` on the target.
    Write { remote: u64, data: Payload },
    /// RDMA READ: fetch `len` words from `remote` into `local`.
    Read { remote: u64, local: u64, len: u32 },
    /// Zero-length READ: no data transfer, but (like any READ) forces full
    /// placement of all prior WRITEs on this QP before completing. This is
    /// the fence primitive of paper §5.3.
    ZeroLenRead,
    /// Remote fetch-and-add on one word; original value lands at `local`.
    FetchAdd { remote: u64, add: u64, local: u64 },
    /// Remote compare-and-swap on one word; original value lands at `local`.
    CompareSwap { remote: u64, expect: u64, swap: u64, local: u64 },
    /// Two-sided SEND; delivered to the target node's receive queue.
    /// Used only on the setup path (join/connect), as in the paper.
    Send { bytes: Box<[u8]> },
}

impl Verb {
    /// Payload size in words (for the bandwidth term of the latency model).
    pub fn wire_words(&self) -> usize {
        match self {
            Verb::Write { data, .. } => data.len(),
            Verb::Read { len, .. } => *len as usize,
            Verb::ZeroLenRead => 0,
            Verb::FetchAdd { .. } | Verb::CompareSwap { .. } => 1,
            Verb::Send { bytes } => bytes.len().div_ceil(8),
        }
    }

    /// Does this verb flush prior placements on its QP before executing?
    pub fn is_flushing(&self) -> bool {
        matches!(
            self,
            Verb::Read { .. } | Verb::ZeroLenRead | Verb::FetchAdd { .. } | Verb::CompareSwap { .. }
        )
    }
}

/// A work request as submitted to a QP.
#[derive(Clone, Debug)]
pub struct Wqe {
    /// Caller-chosen id, routed back on the completion. LOCO's ack_key
    /// machinery packs (slot, bit) into this.
    pub wr_id: u64,
    pub verb: Verb,
    /// If false, no CQE is generated on completion (unsignaled work
    /// request — used for fire-and-forget writes that a later fence
    /// covers, and by the selective-signaling write chains where the
    /// chain's last *signaled* WQE's CQE retires the whole prefix; a
    /// failed unsignaled WQE raises its QP's chain-error so the covering
    /// completion reports the failure). The NIC engine charges no
    /// `completion_ns` for unsignaled WQEs.
    pub signaled: bool,
    /// Inline payload (WRITEs only): the payload was copied into the
    /// WQE at post time, so the NIC skips the DMA read that fetches a
    /// scatter-gather payload from registered memory — the engine
    /// charges `LatencyModel::inline_ns` instead of `wqe_fetch_ns`.
    /// Only legal for writes of at most `LatencyModel::max_inline_words`
    /// (callers decide; `ThreadCtx::write`/`write_many` pick it
    /// automatically).
    pub inline: bool,
    /// The target MR this request was issued against (`None` for raw
    /// posts, which fall back to the target's whole-table `covers`
    /// check). Carrying the rkey moves MR validation to DMA-execution
    /// time: a WQE whose region was invalidated/re-registered while in
    /// flight is caught as a `StaleMr` checker diagnostic instead of
    /// silently writing through the new registration.
    pub rkey: Option<u32>,
    /// Happens-before token stamped at post time by the race checker
    /// (`0` = none): index+1 of the poster's clock snapshot, joined
    /// into the engine clock at execution. See [`crate::analysis`].
    pub hb: u32,
}

impl Wqe {
    /// A signaled, non-inline work request (the default shape).
    pub fn new(wr_id: u64, verb: Verb) -> Wqe {
        Wqe { wr_id, verb, signaled: true, inline: false, rkey: None, hb: 0 }
    }

    /// Stamp the target MR the request was issued against (enables the
    /// DMA-execution-time stale-MR check).
    pub fn with_rkey(mut self, mr: u32) -> Wqe {
        self.rkey = Some(mr);
        self
    }

    /// Mark unsignaled: no CQE on completion.
    pub fn unsignaled(mut self) -> Wqe {
        self.signaled = false;
        self
    }

    /// Mark the payload inline (WRITEs of ≤ `max_inline_words` only).
    pub fn inlined(mut self) -> Wqe {
        debug_assert!(matches!(self.verb, Verb::Write { .. }), "only WRITEs can be inline");
        self.inline = true;
        self
    }
}

/// An ordered batch of work requests destined for one QP under a
/// **single doorbell** — the software analogue of `ibv_post_send` with a
/// linked WR list. Real NICs charge the MMIO doorbell write once per
/// post call regardless of how many WRs it covers; LOCO's hot paths
/// (SST row scans, kvstore `multi_get`/`multi_put`) exploit exactly this
/// to amortize per-op submission cost (paper §2.2's "cheap asynchrony").
///
/// Entries execute in list order with the usual per-QP guarantees;
/// completion ordering across the batch follows submission order.
#[derive(Clone, Debug, Default)]
pub struct PostList {
    wqes: Vec<Wqe>,
}

impl PostList {
    pub fn new() -> PostList {
        PostList { wqes: Vec::new() }
    }

    pub fn with_capacity(n: usize) -> PostList {
        PostList { wqes: Vec::with_capacity(n) }
    }

    /// Append a work request to the batch (executes after all earlier
    /// entries).
    pub fn push(&mut self, wqe: Wqe) {
        self.wqes.push(wqe);
    }

    pub fn len(&self) -> usize {
        self.wqes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.wqes.is_empty()
    }

    /// Consume the list in submission order.
    pub fn into_wqes(self) -> Vec<Wqe> {
        self.wqes
    }

    /// Borrow the entries in submission order.
    pub fn wqes(&self) -> &[Wqe] {
        &self.wqes
    }
}

impl FromIterator<Wqe> for PostList {
    fn from_iter<I: IntoIterator<Item = Wqe>>(iter: I) -> PostList {
        PostList { wqes: iter.into_iter().collect() }
    }
}

/// A message delivered over SEND/RECV.
#[derive(Clone, Debug)]
pub struct RecvMsg {
    pub from: NodeId,
    pub bytes: Box<[u8]>,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn payload_inline_vs_heap() {
        let p = Payload::from_words(&[1, 2, 3]);
        assert!(matches!(p, Payload::Inline { .. }));
        assert_eq!(p.as_slice(), &[1, 2, 3]);
        let p = Payload::from_words(&[0; 9]);
        assert!(matches!(p, Payload::Heap(_)));
        assert_eq!(p.len(), 9);
        assert_eq!(Payload::one(7).as_slice(), &[7]);
    }

    #[test]
    fn verb_flush_classification() {
        assert!(Verb::ZeroLenRead.is_flushing());
        assert!(Verb::Read { remote: 0, local: 0, len: 1 }.is_flushing());
        assert!(Verb::FetchAdd { remote: 0, add: 1, local: 0 }.is_flushing());
        assert!(!Verb::Write { remote: 0, data: Payload::one(1) }.is_flushing());
        assert!(!Verb::Send { bytes: Box::new([]) }.is_flushing());
    }

    #[test]
    fn post_list_builds_in_order() {
        let mut list = PostList::with_capacity(3);
        assert!(list.is_empty());
        for i in 0..3 {
            list.push(Wqe::new(i, Verb::ZeroLenRead));
        }
        assert_eq!(list.len(), 3);
        let ids: Vec<u64> = list.into_wqes().into_iter().map(|w| w.wr_id).collect();
        assert_eq!(ids, vec![0, 1, 2]);
        let collected: PostList = (0..4)
            .map(|i| Wqe::new(i, Verb::ZeroLenRead).unsignaled())
            .collect();
        assert_eq!(collected.len(), 4);
    }

    #[test]
    fn wire_words() {
        assert_eq!(Verb::Write { remote: 0, data: Payload::from_words(&[1, 2]) }.wire_words(), 2);
        assert_eq!(Verb::ZeroLenRead.wire_words(), 0);
        assert_eq!(Verb::Send { bytes: vec![0u8; 17].into_boxed_slice() }.wire_words(), 3);
    }
}
