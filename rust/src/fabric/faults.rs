//! Deterministic, seeded fault injection for the simulated fabric.
//!
//! The fabric is, by default, perfectly reliable and perfectly ordered —
//! which means every consistency claim the channel layer makes is only
//! ever exercised on the happy path. A [`FaultPlan`] installs seeded
//! per-operation hooks in the NIC engine (`fabric::nic`) and the post
//! path (`fabric::network`) that recreate the failure modes a real RoCE
//! deployment exhibits, while staying **reproducible**: the same seed
//! always yields the same schedule, so a failing chaos run can be
//! replayed from its printed seed.
//!
//! Injected faults, and what each is allowed to break:
//!
//! * **Delay** — extra per-WQE network latency, sampled per op. Per-QP
//!   arrival order is still monotonic (RC QPs never reorder), so delays
//!   reorder operations only *across* QPs — exactly the reordering RDMA
//!   permits.
//! * **Completion reorder** — adjacent CQEs from *different* QPs may
//!   swap in the shared CQ. Same-QP completion order is never violated
//!   (the RFC 5040 guarantee LOCO's ack batching relies on).
//! * **Duplicate completions** — a CQE may be delivered twice. The ack
//!   bitset must be idempotent against this.
//! * **QP flap** — a QP transiently enters the error state
//!   ([`Qp::is_error`](super::qp::Qp::is_error)); everything in flight
//!   is retransmitted after recovery with an extra penalty, preserving
//!   submission order.
//! * **Crash-stop** — a node stops serving entirely (see
//!   [`Cluster::crash`](super::network::Cluster::crash)): verbs
//!   targeting it complete with
//!   [`CqeStatus::PeerFailed`](super::cq::CqeStatus::PeerFailed), its
//!   own posts fail, and it never comes back. Can be scheduled by op
//!   count here or triggered explicitly by a test.
//!
//! All hooks live behind `FabricConfig::faults: Option<FaultPlan>`; the
//! fault-free path pays only an `Option` branch (see
//! `bench::micro::fault_hook_overhead`).
//!
//! # Examples
//!
//! ```
//! use loco::fabric::FaultPlan;
//!
//! // A reproducible chaos schedule: 20 % of ops delayed up to 20 µs,
//! // 10 % duplicated completions, 10 % reordered, occasional QP flaps.
//! let plan = FaultPlan::seeded(42)
//!     .delays(0.2, 20_000)
//!     .dup_completions(0.1)
//!     .reorders(0.1)
//!     .qp_flaps(0.02, 30_000, 5_000);
//! assert_eq!(plan.seed, 42);
//! assert!(plan.any_active());
//! ```

use super::NodeId;

/// A seeded fault-injection schedule (see the module docs). Construct
/// with [`FaultPlan::seeded`] and chain the builder methods.
#[derive(Clone, Debug, Default)]
pub struct FaultPlan {
    /// RNG seed for every sampled decision. The engine mixes in the node
    /// id, so per-node streams are independent but reproducible.
    pub seed: u64,
    /// Probability that a WQE is charged extra latency.
    pub delay_prob: f64,
    /// Maximum extra latency, ns (sampled uniformly in `[0, max]`).
    pub delay_max_ns: u64,
    /// Probability that a CQE is delivered twice.
    pub dup_prob: f64,
    /// Probability that a CQE is held back and swapped with the next
    /// CQE from a different QP.
    pub reorder_prob: f64,
    /// Per-submission probability that the QP flaps into the error
    /// state.
    pub flap_prob: f64,
    /// How long a flapped QP stays in the error state, ns.
    pub flap_ns: u64,
    /// Retransmission penalty added to everything in flight on a
    /// flapped QP once it recovers, ns.
    pub retransmit_ns: u64,
    /// Crash-stop `node` after its NIC engine has executed `ops` work
    /// requests: `(node, ops)`. Tests can instead call
    /// [`Cluster::crash`](super::network::Cluster::crash) directly.
    pub crash_after: Option<(NodeId, u64)>,
}

impl FaultPlan {
    /// An inert plan (all probabilities zero) carrying `seed`. Useful on
    /// its own to measure the cost of having the hooks installed.
    pub fn seeded(seed: u64) -> FaultPlan {
        FaultPlan { seed, ..FaultPlan::default() }
    }

    /// Delay each op with probability `prob` by up to `max_ns`.
    pub fn delays(mut self, prob: f64, max_ns: u64) -> FaultPlan {
        self.delay_prob = prob;
        self.delay_max_ns = max_ns;
        self
    }

    /// Duplicate each completion with probability `prob`.
    pub fn dup_completions(mut self, prob: f64) -> FaultPlan {
        self.dup_prob = prob;
        self
    }

    /// Swap adjacent completions of different QPs with probability
    /// `prob`.
    pub fn reorders(mut self, prob: f64) -> FaultPlan {
        self.reorder_prob = prob;
        self
    }

    /// Flap a QP into the error state with per-submission probability
    /// `prob`; it recovers after `flap_ns` and retransmits everything in
    /// flight with an extra `retransmit_ns`.
    pub fn qp_flaps(mut self, prob: f64, flap_ns: u64, retransmit_ns: u64) -> FaultPlan {
        self.flap_prob = prob;
        self.flap_ns = flap_ns;
        self.retransmit_ns = retransmit_ns;
        self
    }

    /// Crash-stop `node` after its engine has executed `ops` WQEs.
    pub fn crash_after(mut self, node: NodeId, ops: u64) -> FaultPlan {
        self.crash_after = Some((node, ops));
        self
    }

    /// Does this plan inject anything at all?
    pub fn any_active(&self) -> bool {
        self.delay_prob > 0.0
            || self.dup_prob > 0.0
            || self.reorder_prob > 0.0
            || self.flap_prob > 0.0
            || self.crash_after.is_some()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_chains_and_defaults() {
        let p = FaultPlan::seeded(7);
        assert_eq!(p.seed, 7);
        assert!(!p.any_active(), "seeded() alone must be inert");

        let p = p
            .delays(0.5, 1000)
            .dup_completions(0.25)
            .reorders(0.125)
            .qp_flaps(0.1, 2000, 300)
            .crash_after(2, 64);
        assert!(p.any_active());
        assert_eq!(p.delay_max_ns, 1000);
        assert_eq!(p.crash_after, Some((2, 64)));
    }
}
