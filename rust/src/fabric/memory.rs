//! Network memory: per-node word-addressed arenas and the MR table.
//!
//! Each node owns two fixed slabs of `AtomicU64` words: host memory and
//! (much smaller) NIC *device memory* (paper Appendix A.2). Offsets are in
//! words; device-memory offsets live above [`DEVICE_BASE`] so a single
//! `u64` address space covers both slabs.
//!
//! The paper's backend aggregates all registered memory into a few 1 GB
//! huge pages, each one libibverbs MR, to avoid NIC MR-cache thrashing.
//! We model that with an explicit [`MrTable`]: every registered region
//! maps to an MR id, and the NIC model charges a penalty when a node's
//! MR count exceeds the simulated NIC cache (see `LatencyModel::mr_miss_ns`).

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};

use std::sync::{OnceLock, RwLock};

use crate::analysis::{AccessKind, CheckerHandle};

use super::NodeId;

/// Word offsets at or above this value address NIC device memory.
pub const DEVICE_BASE: u64 = 1 << 40;

/// A registered region of network memory on some node.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Region {
    pub node: NodeId,
    /// First word of the region (may be in device space).
    pub base: u64,
    /// Length in words.
    pub len: u64,
    /// MR this region belongs to (index into the owner's `MrTable`).
    pub mr: u32,
    pub device: bool,
}

impl Region {
    /// Word address of `idx` words into the region, bounds-checked.
    #[inline]
    pub fn at(&self, idx: u64) -> u64 {
        debug_assert!(idx < self.len, "region index {idx} out of {}", self.len);
        self.base + idx
    }

    /// Sub-region `[off, off+len)`, sharing the parent's MR.
    pub fn slice(&self, off: u64, len: u64) -> Region {
        assert!(
            off + len <= self.len,
            "slice [{off}, {off}+{len}) out of region of {} words",
            self.len
        );
        Region { base: self.base + off, len, ..*self }
    }
}

/// One node's memory: host slab + device slab, bump-allocated.
pub struct Arena {
    host: Box<[AtomicU64]>,
    device: Box<[AtomicU64]>,
    host_next: AtomicUsize,
    device_next: AtomicUsize,
    /// Race-checker hook ([`crate::analysis`]), installed once by
    /// `Cluster::new` when checking is enabled. Never set — the default
    /// — every access pays exactly one `OnceLock` load and a dead
    /// branch (pinned by `bench::micro::check_hook_overhead`).
    check: OnceLock<CheckerHandle>,
}

impl Arena {
    pub fn new(host_words: usize, device_words: usize) -> Self {
        let mk = |n: usize| -> Box<[AtomicU64]> {
            (0..n).map(|_| AtomicU64::new(0)).collect::<Vec<_>>().into_boxed_slice()
        };
        Arena {
            host: mk(host_words),
            device: mk(device_words),
            host_next: AtomicUsize::new(0),
            device_next: AtomicUsize::new(0),
            check: OnceLock::new(),
        }
    }

    /// Install the race checker (at cluster construction; `node` is the
    /// arena's owner, the default attribution for unguarded accesses).
    pub fn set_checker(&self, node: NodeId, checker: std::sync::Arc<crate::analysis::Checker>) {
        let _ = self.check.set(CheckerHandle { node, checker });
    }

    /// The installed checker handle, if any.
    #[inline]
    pub fn checker(&self) -> Option<&CheckerHandle> {
        self.check.get()
    }

    #[inline]
    fn hook(&self, addr: u64, len: u64, kind: AccessKind, site: &'static str) {
        if let Some(h) = self.check.get() {
            h.checker.on_access(h.node, addr, len, kind, site);
        }
    }

    /// Bump-allocate `words` from the host (or device) slab. Returns the
    /// base word address. Panics on exhaustion: the simulation sizes slabs
    /// up front (the "huge page pool"), mirroring the paper's static
    /// registration strategy.
    pub fn alloc(&self, words: usize, device: bool) -> u64 {
        let (slab_len, next, base) = if device {
            (self.device.len(), &self.device_next, DEVICE_BASE)
        } else {
            (self.host.len(), &self.host_next, 0)
        };
        let off = next.fetch_add(words, Ordering::Relaxed);
        assert!(
            off + words <= slab_len,
            "network memory exhausted: asked {} words at {} of {} ({})",
            words,
            off,
            slab_len,
            if device { "device" } else { "host" }
        );
        base + off as u64
    }

    #[inline]
    fn word(&self, addr: u64) -> &AtomicU64 {
        if addr >= DEVICE_BASE {
            &self.device[(addr - DEVICE_BASE) as usize]
        } else {
            &self.host[addr as usize]
        }
    }

    /// Atomic word load. Relaxed: network memory is data, not
    /// synchronization; happens-before edges come from completion queues.
    #[inline]
    pub fn load(&self, addr: u64) -> u64 {
        self.hook(addr, 1, AccessKind::Read, "arena::load");
        self.word(addr).load(Ordering::Relaxed)
    }

    #[inline]
    pub fn store(&self, addr: u64, val: u64) {
        self.hook(addr, 1, AccessKind::Write, "arena::store");
        self.word(addr).store(val, Ordering::Relaxed);
    }

    #[inline]
    pub fn fetch_add(&self, addr: u64, add: u64) -> u64 {
        self.hook(addr, 1, AccessKind::Atomic, "arena::fetch_add");
        self.word(addr).fetch_add(add, Ordering::AcqRel)
    }

    #[inline]
    pub fn compare_swap(&self, addr: u64, expect: u64, swap: u64) -> u64 {
        self.hook(addr, 1, AccessKind::Atomic, "arena::compare_swap");
        match self.word(addr).compare_exchange(expect, swap, Ordering::AcqRel, Ordering::Acquire) {
            Ok(v) => v,
            Err(v) => v,
        }
    }

    /// Copy `vals` into consecutive words starting at `addr`, one atomic
    /// store per word. Concurrent readers may observe a torn prefix —
    /// exactly the RDMA >8 B atomicity hazard.
    pub fn store_words(&self, addr: u64, vals: &[u64], yield_between: bool) {
        for (i, v) in vals.iter().enumerate() {
            self.store(addr + i as u64, *v);
            if yield_between && i + 1 != vals.len() {
                std::thread::yield_now();
            }
        }
    }

    pub fn load_words(&self, addr: u64, out: &mut [u64]) {
        for (i, o) in out.iter_mut().enumerate() {
            *o = self.load(addr + i as u64);
        }
    }

    pub fn host_words_used(&self) -> usize {
        self.host_next.load(Ordering::Relaxed)
    }
}

/// Descriptor of a registered MR ("huge page" in LOCO's backend).
#[derive(Clone, Copy, Debug)]
pub struct MrInfo {
    pub base: u64,
    pub len: u64,
    pub device: bool,
    /// Cleared by [`MrTable::invalidate`]: a deregistered MR's id stays
    /// allocated (so in-flight WQEs carrying it are detectably stale —
    /// see the NIC engine's execution-time check) but covers nothing.
    pub valid: bool,
}

/// Per-node table of registered memory regions.
///
/// LOCO registers a handful of huge MRs; the MPI baseline registers one MR
/// per window. The table's size drives the NIC MR-cache penalty.
pub struct MrTable {
    mrs: RwLock<Vec<MrInfo>>,
}

impl MrTable {
    pub fn new() -> Self {
        MrTable { mrs: RwLock::new(Vec::new()) }
    }

    pub fn register(&self, base: u64, len: u64, device: bool) -> u32 {
        let mut mrs = self.mrs.write().unwrap();
        mrs.push(MrInfo { base, len, device, valid: true });
        (mrs.len() - 1) as u32
    }

    /// Invalidate (deregister) MR `mr`: its id stays allocated but no
    /// longer covers anything, so a stale in-flight WQE stamped with it
    /// is caught at DMA-execution time even if the same words were
    /// since re-registered under a fresh id.
    pub fn invalidate(&self, mr: u32) {
        if let Some(m) = self.mrs.write().unwrap().get_mut(mr as usize) {
            m.valid = false;
        }
    }

    pub fn count(&self) -> usize {
        self.mrs.read().unwrap().len()
    }

    /// Check that `[addr, addr+len)` lies within MR `mr` (and `mr` is
    /// still valid).
    pub fn contains(&self, mr: u32, addr: u64, len: u64) -> bool {
        let mrs = self.mrs.read().unwrap();
        match mrs.get(mr as usize) {
            Some(m) => m.valid && addr >= m.base && addr + len <= m.base + m.len,
            None => false,
        }
    }

    /// Check that `[addr, addr+len)` lies within *some* registered MR
    /// (used when the issuer did not carry an rkey).
    pub fn covers(&self, addr: u64, len: u64) -> bool {
        let mrs = self.mrs.read().unwrap();
        mrs.iter().any(|m| m.valid && addr >= m.base && addr + len <= m.base + m.len)
    }
}

impl Default for MrTable {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bump_alloc_host_and_device() {
        let a = Arena::new(128, 16);
        let r0 = a.alloc(10, false);
        let r1 = a.alloc(10, false);
        assert_eq!(r0, 0);
        assert_eq!(r1, 10);
        let d0 = a.alloc(4, true);
        assert_eq!(d0, DEVICE_BASE);
        a.store(d0, 7);
        assert_eq!(a.load(d0), 7);
    }

    #[test]
    #[should_panic(expected = "exhausted")]
    fn alloc_exhaustion_panics() {
        let a = Arena::new(8, 0);
        a.alloc(9, false);
    }

    #[test]
    fn word_ops_roundtrip() {
        let a = Arena::new(64, 0);
        let base = a.alloc(8, false);
        a.store_words(base, &[1, 2, 3, 4], false);
        let mut out = [0u64; 4];
        a.load_words(base, &mut out);
        assert_eq!(out, [1, 2, 3, 4]);
        assert_eq!(a.fetch_add(base, 41), 1);
        assert_eq!(a.load(base), 42);
        assert_eq!(a.compare_swap(base, 42, 100), 42);
        assert_eq!(a.compare_swap(base, 42, 0), 100);
        assert_eq!(a.load(base), 100);
    }

    #[test]
    fn mr_table_containment() {
        let t = MrTable::new();
        let mr = t.register(100, 50, false);
        assert!(t.contains(mr, 100, 50));
        assert!(t.contains(mr, 120, 10));
        assert!(!t.contains(mr, 120, 50));
        assert!(t.covers(149, 1));
        assert!(!t.covers(150, 1));
        assert_eq!(t.count(), 1);
    }

    #[test]
    fn invalidated_mr_covers_nothing() {
        let t = MrTable::new();
        let a = t.register(100, 50, false);
        let b = t.register(200, 10, false);
        t.invalidate(a);
        assert!(!t.contains(a, 100, 50));
        assert!(!t.covers(120, 1), "no fallback coverage through a dead MR");
        assert!(t.contains(b, 200, 10));
        assert_eq!(t.count(), 2, "the id stays allocated");
    }

    /// The re-register window (PR-9 satellite): invalidating an MR and
    /// registering the same range again must NOT revive the stale rkey
    /// — a WQE still carrying the old id stays dead even though the
    /// range itself is covered again (the StaleMr diagnostic's exact
    /// precondition). Only the fresh id reaches the range.
    #[test]
    fn reregistered_range_does_not_revive_the_stale_rkey() {
        let t = MrTable::new();
        let old = t.register(100, 50, false);
        t.invalidate(old);
        let fresh = t.register(100, 50, false);
        assert_ne!(old, fresh, "re-registration must mint a new id");
        assert!(!t.contains(old, 100, 50), "the stale rkey stays dead");
        assert!(!t.contains(old, 120, 1), "even for sub-ranges of the reborn range");
        assert!(t.contains(fresh, 100, 50));
        assert!(t.covers(120, 1), "keyless coverage returns with the fresh MR");
        assert_eq!(t.count(), 2, "the dead id stays allocated; no id reuse");
    }

    #[test]
    fn region_slice() {
        let r = Region { node: 0, base: 10, len: 20, mr: 0, device: false };
        let s = r.slice(5, 5);
        assert_eq!(s.base, 15);
        assert_eq!(s.at(0), 15);
    }
}
