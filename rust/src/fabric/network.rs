//! Cluster construction and the per-node fabric endpoint.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;

use std::sync::{Condvar, Mutex, RwLock};

use crate::util::queue::Queue;

use super::cq::{CompletionQueue, Cqe};
use super::memory::{Arena, MrTable, Region};
use super::nic;
use super::qp::{Qp, QpId};
use super::verbs::{PostList, RecvMsg, Wqe};
use super::{Clock, DeliveryMode, FabricConfig, NodeId};

/// One node's fabric endpoint: its network memory, MR table, shared
/// completion queue, QPs, and two-sided receive queue.
pub struct NodeFabric {
    id: NodeId,
    arena: Arena,
    mrs: MrTable,
    cq: CompletionQueue,
    qps: RwLock<Vec<Arc<Qp>>>,
    recvq: Queue<RecvMsg>,
    /// Doorbell for the NIC engine: bumped on every submission / QP
    /// creation so the engine can sleep when idle instead of spinning
    /// (important on oversubscribed hosts; see EXPERIMENTS.md §Perf).
    doorbell: (Mutex<u64>, Condvar),
    /// Work requests posted from this node (one per verb). Kept per node
    /// so the hot post path never bounces a cluster-global cache line;
    /// `Cluster::ops_posted` sums on the rare read.
    ops_posted: AtomicU64,
    /// Doorbells rung from this node (one per `post` / `post_list`).
    doorbells_rung: AtomicU64,
    /// WRITEs posted with an inline payload (one per inline WQE).
    wqes_inlined: AtomicU64,
    /// Kvstore mutations this node routed down the op-shipping channel
    /// (bumped by the router, not the fabric — lives here so the hot
    /// path touches the same per-node line as the verb counters).
    ops_shipped: AtomicU64,
    /// Route decisions that flipped a key between one-sided and shipped
    /// (adaptive-routing hysteresis crossings).
    route_flips: AtomicU64,
    /// Shipped updates whose server died between enqueue and reply,
    /// completed through the one-sided ambiguous fallback.
    ship_fallbacks: AtomicU64,
    /// Ambiguous fallbacks whose probe found the shipped value already
    /// in place — the server applied (and replicated) before crashing,
    /// so the fallback skipped the re-apply. Chaos schedules pin this
    /// to prove the applied-then-crashed window is exercised.
    ship_fallbacks_confirmed: AtomicU64,
    /// Crash-stop flag (fault injection): once cleared the node never
    /// serves or transmits again. See [`Cluster::crash`].
    alive: AtomicBool,
    /// Engine-executed op counts, one slot per engine lane
    /// (`FabricConfig::engines_per_node`), published by each NIC engine
    /// every step so [`Cluster::crash_after_ops`] can arm a crash
    /// relative to "now" (calibrated past bring-up, unlike the
    /// construction-time
    /// [`FaultPlan::crash_after`](super::FaultPlan::crash_after)) and
    /// so tests can prove the QP stripes actually share load.
    engine_ops: Vec<AtomicU64>,
    /// Engine-loop iterations (threaded mode; all lanes summed). The
    /// idle-cluster regression diffs this: parked engines must execute
    /// ~zero steps per second, where the seed's 200 µs shutdown-poll
    /// cap burned thousands.
    engine_steps: AtomicU64,
    /// Engine-op count at which this node crash-stops (runtime-armed
    /// fault injection; `u64::MAX` = disarmed).
    crash_at_ops: AtomicU64,
}

impl NodeFabric {
    fn new(id: NodeId, cfg: &FabricConfig) -> Self {
        NodeFabric {
            id,
            arena: Arena::new(cfg.node_mem_words, cfg.device_mem_words),
            mrs: MrTable::new(),
            cq: CompletionQueue::new(),
            qps: RwLock::new(Vec::new()),
            recvq: Queue::new(),
            doorbell: (Mutex::new(0), Condvar::new()),
            ops_posted: AtomicU64::new(0),
            doorbells_rung: AtomicU64::new(0),
            wqes_inlined: AtomicU64::new(0),
            ops_shipped: AtomicU64::new(0),
            route_flips: AtomicU64::new(0),
            ship_fallbacks: AtomicU64::new(0),
            ship_fallbacks_confirmed: AtomicU64::new(0),
            alive: AtomicBool::new(true),
            engine_ops: (0..cfg.engines_per_node.max(1)).map(|_| AtomicU64::new(0)).collect(),
            engine_steps: AtomicU64::new(0),
            crash_at_ops: AtomicU64::new(u64::MAX),
        }
    }

    /// Has this node crash-stopped? (Fault injection; always true on a
    /// fault-free fabric.)
    #[inline]
    pub fn is_alive(&self) -> bool {
        self.alive.load(Ordering::Relaxed)
    }

    /// Crash-stop this node: it stops serving remote verbs and stops
    /// transmitting. Rings the doorbell so the NIC engine notices and
    /// drains everything in flight with error completions.
    pub(super) fn crash(&self) {
        self.alive.store(false, Ordering::SeqCst);
        self.ring();
    }

    /// Undo a crash-stop: the node serves and transmits again. Chain
    /// errors raised on a QP during the outage still surface on its
    /// next signaled completion (the selective-signaling contract);
    /// after that the QP is usable again.
    pub(super) fn revive(&self) {
        self.alive.store(true, Ordering::SeqCst);
        self.ring();
    }

    /// Engine-side: publish lane `lane`'s executed-op count so
    /// [`Cluster::crash_after_ops`] can arm thresholds relative to the
    /// node total and tests can read the per-stripe split.
    pub(super) fn publish_engine_ops(&self, lane: u32, ops: u64) {
        self.engine_ops[lane as usize].store(ops, Ordering::Relaxed);
    }

    /// Executed-op count summed across this node's engine lanes (the
    /// quantity crash thresholds are armed against).
    pub(super) fn engine_ops_total(&self) -> u64 {
        self.engine_ops.iter().map(|c| c.load(Ordering::Relaxed)).sum()
    }

    /// Engine-side: one engine-loop iteration ran (threaded mode).
    pub(super) fn note_engine_step(&self) {
        self.engine_steps.fetch_add(1, Ordering::Relaxed);
    }

    /// Engine-side: is a runtime-armed crash due at `ops` executed ops?
    pub(super) fn crash_due(&self, ops: u64) -> bool {
        ops >= self.crash_at_ops.load(Ordering::Relaxed)
    }

    /// Ring the engine doorbell (submission or new QP). All of the
    /// node's engine lanes wait on the one condvar, so wake them all —
    /// a QP's work belongs to exactly one lane, and `notify_one` could
    /// rouse the wrong one and leave the owner parked.
    pub(super) fn ring(&self) {
        let (lock, cv) = &self.doorbell;
        *lock.lock().unwrap() += 1;
        cv.notify_all();
    }

    /// Engine-side: current doorbell value.
    pub(super) fn doorbell_value(&self) -> u64 {
        *self.doorbell.0.lock().unwrap()
    }

    /// Engine-side: sleep until the doorbell moves past `seen` or
    /// `timeout_ns` elapses.
    pub(super) fn doorbell_wait(&self, seen: u64, timeout_ns: u64) {
        let (lock, cv) = &self.doorbell;
        let count = lock.lock().unwrap();
        if *count != seen {
            return;
        }
        let _ = cv
            .wait_timeout(count, std::time::Duration::from_nanos(timeout_ns))
            .unwrap();
    }

    pub fn id(&self) -> NodeId {
        self.id
    }

    pub fn arena(&self) -> &Arena {
        &self.arena
    }

    pub fn cq(&self) -> &CompletionQueue {
        &self.cq
    }

    /// Allocate `words` of network memory and register them as **one new
    /// MR**. LOCO's pool calls this for large huge pages and carves
    /// sub-regions out of them; the MPI baseline calls it once per window
    /// (which is exactly what costs it in Fig. 4).
    pub fn register_mr(self: &Arc<Self>, words: usize, device: bool) -> Region {
        let base = self.arena.alloc(words, device);
        let mr = self.mrs.register(base, words as u64, device);
        Region { node: self.id, base, len: words as u64, mr, device }
    }

    pub fn mr_count(&self) -> usize {
        self.mrs.count()
    }

    /// Deregister MR `mr`: its id stays allocated but covers nothing, so
    /// an in-flight WQE stamped with it is caught at DMA-execution time
    /// as a `StaleMr` checker diagnostic (see [`crate::analysis`]).
    pub fn invalidate_mr(&self, mr: u32) {
        self.mrs.invalidate(mr);
    }

    /// Engine-side: does MR `mr` still cover `[addr, addr+len)`?
    pub(super) fn mr_contains(&self, mr: u32, addr: u64, len: u64) -> bool {
        self.mrs.contains(mr, addr, len)
    }

    /// Protection check (simulated NIC fault on violation).
    pub fn check_covered(&self, addr: u64, len: u64) {
        if len == 0 {
            return;
        }
        assert!(
            self.mrs.covers(addr, len),
            "protection fault: node {} access [{addr}, +{len}) not in any registered MR",
            self.id
        );
    }

    pub(super) fn deliver(&self, msg: RecvMsg) {
        self.recvq.push(msg);
    }

    /// Non-blocking receive of a two-sided message.
    pub fn try_recv(&self) -> Option<RecvMsg> {
        self.recvq.try_pop()
    }

    /// Blocking receive with timeout.
    pub fn recv_timeout(&self, timeout: std::time::Duration) -> Option<RecvMsg> {
        self.recvq.pop_timeout(timeout)
    }

    pub(super) fn qp_count(&self) -> usize {
        self.qps.read().unwrap().len()
    }

    pub(super) fn qp_engine_handle(&self, index: u32) -> Arc<Qp> {
        self.qps.read().unwrap()[index as usize].clone()
    }

    fn add_qp(&self, peer: NodeId) -> QpId {
        let id = {
            let mut qps = self.qps.write().unwrap();
            let id = QpId { node: self.id, index: qps.len() as u32 };
            qps.push(Arc::new(Qp::new(id, peer)));
            id
        };
        self.ring();
        id
    }

    fn qp(&self, id: QpId) -> Arc<Qp> {
        self.qps.read().unwrap()[id.index as usize].clone()
    }
}

/// A simulated cluster: `n` nodes plus (in threaded mode) one NIC engine
/// thread per node.
pub struct Cluster {
    cfg: FabricConfig,
    clock: Clock,
    nodes: Vec<Arc<NodeFabric>>,
    shutdown: Arc<AtomicBool>,
    engines: Mutex<Vec<JoinHandle<()>>>,
    /// Happens-before race checker ([`crate::analysis`]); `Some` when
    /// `cfg.check_races` resolves to a level for this delivery mode
    /// (default: full checking under `Sim`, off otherwise). The same
    /// instance is installed into every node's arena.
    checker: Option<Arc<crate::analysis::Checker>>,
}

impl Cluster {
    pub fn new(n: usize, cfg: FabricConfig) -> Arc<Cluster> {
        // Sim mode runs on virtual time: it only moves when the sim
        // scheduler advances it, so the cluster is frozen until a
        // `SimExecutor` adopts it.
        let clock = if cfg.delivery == DeliveryMode::Sim {
            Clock::new_virtual()
        } else {
            Clock::new()
        };
        let nodes: Vec<Arc<NodeFabric>> =
            (0..n).map(|i| Arc::new(NodeFabric::new(i as NodeId, &cfg))).collect();
        let checker = cfg.check_races.resolve(cfg.delivery == DeliveryMode::Sim).map(|level| {
            Arc::new(crate::analysis::Checker::new_striped(
                n,
                cfg.engines_per_node.max(1) as usize,
                level,
                cfg.seed,
            ))
        });
        if let Some(chk) = &checker {
            for node in &nodes {
                node.arena.set_checker(node.id, chk.clone());
            }
        }
        let shutdown = Arc::new(AtomicBool::new(false));
        let cluster = Arc::new(Cluster {
            cfg: cfg.clone(),
            clock: clock.clone(),
            nodes: nodes.clone(),
            shutdown: shutdown.clone(),
            engines: Mutex::new(Vec::new()),
            checker,
        });
        if cfg.delivery == DeliveryMode::Threaded {
            let epn = cfg.engines_per_node.max(1);
            let mut engines = cluster.engines.lock().unwrap();
            for i in 0..n {
                for lane in 0..epn {
                    let nodes = nodes.clone();
                    let cfg = cfg.clone();
                    let clock = clock.clone();
                    let shutdown = shutdown.clone();
                    let name = if epn == 1 {
                        format!("nic-engine-{i}")
                    } else {
                        format!("nic-engine-{i}.{lane}")
                    };
                    engines.push(
                        std::thread::Builder::new()
                            .name(name)
                            .spawn(move || {
                                nic::engine_loop(nodes, i as NodeId, lane, cfg, clock, shutdown)
                            })
                            .expect("spawn nic engine"),
                    );
                }
            }
        }
        cluster
    }

    pub fn config(&self) -> &FabricConfig {
        &self.cfg
    }

    /// The installed race checker, if checking resolved on for this
    /// cluster (see [`FabricConfig::check_races`]).
    pub fn checker(&self) -> Option<&Arc<crate::analysis::Checker>> {
        self.checker.as_ref()
    }

    /// Diagnostics the race checker has accumulated (empty when checking
    /// is off). Green runs assert this is empty at teardown.
    pub fn diagnostics(&self) -> Vec<crate::analysis::Diagnostic> {
        self.checker.as_ref().map(|c| c.diagnostics()).unwrap_or_default()
    }

    /// Drain accumulated checker diagnostics (for tests that expect a
    /// specific diagnostic and then want a clean slate).
    pub fn take_diagnostics(&self) -> Vec<crate::analysis::Diagnostic> {
        self.checker.as_ref().map(|c| c.take_diagnostics()).unwrap_or_default()
    }

    /// Build the steppable engine cores (sim mode): `engines_per_node`
    /// per node, node-major, so `engines_per_node = 1` yields exactly
    /// the seed's one-core-per-node vector. The `SimExecutor` owns and
    /// steps these; in `Threaded` mode the same cores live inside the
    /// per-lane engine threads instead.
    pub(crate) fn engine_cores(&self) -> Vec<nic::EngineCore> {
        assert_eq!(
            self.cfg.delivery,
            DeliveryMode::Sim,
            "engine_cores is only meaningful for DeliveryMode::Sim"
        );
        let epn = self.cfg.engines_per_node.max(1);
        let mut cores = Vec::with_capacity(self.nodes.len() * epn as usize);
        for i in 0..self.nodes.len() {
            for lane in 0..epn {
                cores.push(nic::EngineCore::new(
                    self.nodes.clone(),
                    i as NodeId,
                    lane,
                    self.cfg.clone(),
                ));
            }
        }
        cores
    }

    pub fn clock(&self) -> &Clock {
        &self.clock
    }

    pub fn num_nodes(&self) -> usize {
        self.nodes.len()
    }

    pub fn node(&self, id: NodeId) -> &Arc<NodeFabric> {
        &self.nodes[id as usize]
    }

    /// Create a QP on `from` targeting `to`.
    pub fn create_qp(&self, from: NodeId, to: NodeId) -> QpId {
        assert!((to as usize) < self.nodes.len(), "unknown peer {to}");
        self.nodes[from as usize].add_qp(to)
    }

    /// Post a work request on a QP. In threaded mode this enqueues for the
    /// NIC engine; in inline mode the verb executes synchronously.
    pub fn post(&self, qpid: QpId, mut wqe: Wqe) {
        let node = &self.nodes[qpid.node as usize];
        if let Some(chk) = &self.checker {
            wqe.hb = chk.on_post(qpid.node);
        }
        node.ops_posted.fetch_add(1, Ordering::Relaxed);
        node.doorbells_rung.fetch_add(1, Ordering::Relaxed);
        if wqe.inline {
            node.wqes_inlined.fetch_add(1, Ordering::Relaxed);
        }
        let qp = node.qp(qpid);
        if !node.is_alive() {
            // Crash-stop: nothing transmits. Signaled WRs still flush an
            // error completion so the dead node's own (simulated) threads
            // waiting on an ack_key unblock instead of hanging; failed
            // unsignaled WRs raise the chain error for their covering
            // signaled successor.
            if wqe.signaled {
                qp.take_chain_error();
                node.cq().post(Cqe::failed(wqe.wr_id, qpid));
            } else {
                qp.raise_chain_error();
            }
            return;
        }
        match self.cfg.delivery {
            // Sim mode shares the Threaded submission path: the WQE sits
            // in the QP's submission queue until a `SimExecutor` steps
            // this node's engine core.
            DeliveryMode::Threaded | DeliveryMode::Sim => {
                qp.submit(wqe);
                node.ring();
            }
            DeliveryMode::Inline => nic::execute_inline(&self.nodes, &self.cfg, qpid.node, &qp, wqe),
        }
    }

    /// Post an ordered batch of work requests on a QP under a **single
    /// doorbell** (the `ibv_post_send` WR-list analogue). In threaded
    /// mode the whole list is enqueued with one lock round and one
    /// engine wakeup, and only the head WQE pays `doorbell_ns`; in
    /// inline mode the verbs execute synchronously in list order.
    pub fn post_list(&self, qpid: QpId, list: PostList) {
        if list.is_empty() {
            return;
        }
        let node = &self.nodes[qpid.node as usize];
        node.ops_posted.fetch_add(list.len() as u64, Ordering::Relaxed);
        node.doorbells_rung.fetch_add(1, Ordering::Relaxed);
        let qp = node.qp(qpid);
        let mut wqes = list.into_wqes();
        if !node.is_alive() {
            for wqe in wqes {
                if wqe.signaled {
                    qp.take_chain_error();
                    node.cq().post(Cqe::failed(wqe.wr_id, qpid));
                } else {
                    qp.raise_chain_error();
                }
            }
            return;
        }
        for wqe in &wqes {
            if wqe.inline {
                node.wqes_inlined.fetch_add(1, Ordering::Relaxed);
            }
        }
        if let Some(chk) = &self.checker {
            // One clock snapshot covers the whole batch: list entries
            // share the doorbell and the poster's program order.
            let hb = chk.on_post(qpid.node);
            for wqe in &mut wqes {
                wqe.hb = hb;
            }
        }
        match self.cfg.delivery {
            DeliveryMode::Threaded | DeliveryMode::Sim => {
                qp.submit_list(wqes);
                node.ring();
            }
            DeliveryMode::Inline => {
                for wqe in wqes {
                    nic::execute_inline(&self.nodes, &self.cfg, qpid.node, &qp, wqe);
                }
            }
        }
    }

    /// Peer a QP targets (for bookkeeping layers above).
    pub fn qp_peer(&self, qpid: QpId) -> NodeId {
        self.nodes[qpid.node as usize].qp(qpid).peer
    }

    /// Is a failed-unsignaled-WQE chain error pending on `qpid`?
    /// (Introspection; the flag is consumed by the QP's next signaled
    /// completion — see [`Qp::chain_error_pending`].)
    pub fn chain_error_pending(&self, qpid: QpId) -> bool {
        self.nodes[qpid.node as usize].qp(qpid).chain_error_pending()
    }

    /// Total work requests posted cluster-wide since construction
    /// (monotonic; summed over per-node counters). The locality tier's
    /// benches diff this across runs to show remote ops *avoided* by
    /// cache hits, not just wall-clock gains.
    pub fn ops_posted(&self) -> u64 {
        self.nodes.iter().map(|n| n.ops_posted.load(Ordering::Relaxed)).sum()
    }

    /// Total doorbells rung cluster-wide since construction (monotonic).
    pub fn doorbells_rung(&self) -> u64 {
        self.nodes.iter().map(|n| n.doorbells_rung.load(Ordering::Relaxed)).sum()
    }

    /// Total WRITEs posted with inline payloads (monotonic). Benches and
    /// tests diff this to prove the automatic inline pick is firing.
    pub fn wqes_inlined(&self) -> u64 {
        self.nodes.iter().map(|n| n.wqes_inlined.load(Ordering::Relaxed)).sum()
    }

    /// Total CQEs generated cluster-wide (monotonic). The selective-
    /// signaling tests diff this against `ops_posted` to show the
    /// completions a covered write chain *avoided*.
    pub fn cqes_posted(&self) -> u64 {
        self.nodes.iter().map(|n| n.cq().posted()).sum()
    }

    /// Total kvstore mutations routed down the op-shipping channel
    /// (monotonic; see `apps::kvstore` routing). Routing tests pin that
    /// adaptive mode actually ships hot keys / leaves uniform ones alone.
    pub fn ops_shipped(&self) -> u64 {
        self.nodes.iter().map(|n| n.ops_shipped.load(Ordering::Relaxed)).sum()
    }

    /// Total adaptive-routing hysteresis crossings (monotonic).
    pub fn route_flips(&self) -> u64 {
        self.nodes.iter().map(|n| n.route_flips.load(Ordering::Relaxed)).sum()
    }

    /// Router-side accounting: `node` shipped one mutation.
    pub fn note_op_shipped(&self, node: NodeId) {
        self.nodes[node as usize].ops_shipped.fetch_add(1, Ordering::Relaxed);
    }

    /// Router-side accounting: `node` flipped a key's route.
    pub fn note_route_flip(&self, node: NodeId) {
        self.nodes[node as usize].route_flips.fetch_add(1, Ordering::Relaxed);
    }

    /// Total shipped updates completed through the ambiguous one-sided
    /// fallback (server died between enqueue and reply; monotonic).
    pub fn ship_fallbacks(&self) -> u64 {
        self.nodes.iter().map(|n| n.ship_fallbacks.load(Ordering::Relaxed)).sum()
    }

    /// Of [`Cluster::ship_fallbacks`], those whose under-lock probe
    /// found the shipped value already applied — the server crashed
    /// AFTER its apply replicated but before replying (monotonic).
    pub fn ship_fallbacks_confirmed(&self) -> u64 {
        self.nodes.iter().map(|n| n.ship_fallbacks_confirmed.load(Ordering::Relaxed)).sum()
    }

    /// Router-side accounting: `node` entered the ambiguous fallback.
    pub fn note_ship_fallback(&self, node: NodeId) {
        self.nodes[node as usize].ship_fallbacks.fetch_add(1, Ordering::Relaxed);
    }

    /// Router-side accounting: `node`'s fallback probe confirmed the
    /// dead server's apply.
    pub fn note_ship_fallback_confirmed(&self, node: NodeId) {
        self.nodes[node as usize].ship_fallbacks_confirmed.fetch_add(1, Ordering::Relaxed);
    }

    // ---- fault injection: crash-stop ---------------------------------

    /// Crash-stop `node`: it stops serving remote verbs, stops
    /// transmitting, and never recovers. In-flight verbs targeting it
    /// complete with [`super::CqeStatus::PeerFailed`]; its own in-flight
    /// verbs are drained with error completions so nothing hangs.
    /// Idempotent. (Tests drive this directly; a
    /// [`FaultPlan::crash_after`](super::FaultPlan::crash_after)
    /// schedule triggers it from the NIC engine.)
    pub fn crash(&self, node: NodeId) {
        self.nodes[node as usize].crash();
        // Wake every engine: peers must fail their in-flight verbs to
        // the dead node even if their own submission queues are idle.
        for n in &self.nodes {
            n.ring();
        }
    }

    /// Engine-executed op count of `node` so far (monotonic; summed
    /// over the node's engine lanes). Pair with
    /// [`Cluster::crash_after_ops`] to calibrate a crash cut relative
    /// to a known point of the run rather than time zero.
    pub fn engine_ops(&self, node: NodeId) -> u64 {
        self.nodes[node as usize].engine_ops_total()
    }

    /// Per-engine executed-op counts of `node` (one entry per lane of
    /// `FabricConfig::engines_per_node`). The engine-scaling acceptance
    /// test asserts every lane is non-zero — striping that funnels all
    /// work through one lane is a silent return to the serial engine.
    pub fn engine_ops_by_engine(&self, node: NodeId) -> Vec<u64> {
        self.nodes[node as usize]
            .engine_ops
            .iter()
            .map(|c| c.load(Ordering::Relaxed))
            .collect()
    }

    /// Engine-loop iterations `node`'s engine threads have run
    /// (threaded mode; all lanes summed, monotonic). An idle cluster's
    /// delta over a sleep should be ~zero — parked engines wake only on
    /// doorbells or due events.
    pub fn engine_steps(&self, node: NodeId) -> u64 {
        self.nodes[node as usize].engine_steps.load(Ordering::Relaxed)
    }

    /// Arm a crash-stop of `node` after it executes `delta` MORE engine
    /// ops (relative to now). Unlike
    /// [`FaultPlan::crash_after`](super::FaultPlan::crash_after), which
    /// counts from time zero and must be fixed before the cluster is
    /// built, this can be armed mid-run — chaos schedules let bring-up
    /// finish, then sweep `delta` to land the crash at a precise point
    /// of a serve window (e.g. between a shipped op's replicated apply
    /// and its reply). Re-arming overwrites any earlier threshold.
    pub fn crash_after_ops(&self, node: NodeId, delta: u64) {
        let n = &self.nodes[node as usize];
        let due = n.engine_ops_total().saturating_add(delta);
        n.crash_at_ops.store(due, Ordering::Relaxed);
        // Wake the engines so an idle victim still observes the arm.
        for nf in &self.nodes {
            nf.ring();
        }
    }

    /// Revive a crash-stopped `node` (elastic membership: the physical
    /// slot is being reused by a joiner). The fabric serves its memory
    /// again and its engines resume; chain errors raised during the
    /// outage still surface on the owning QP's next signaled CQE, and
    /// [`Cluster::down_mask`] drops the bit — managers latch only
    /// *newly* down nodes, so the revived slot stays dead in every
    /// membership view until its join is broadcast. Idempotent.
    pub fn revive(&self, node: NodeId) {
        self.nodes[node as usize].revive();
        for n in &self.nodes {
            n.ring();
        }
    }

    /// Has `node` crash-stopped?
    #[inline]
    pub fn is_down(&self, node: NodeId) -> bool {
        !self.nodes[node as usize].is_alive()
    }

    /// Bitmask of crash-stopped nodes (bit *i* set ⇔ node *i* is down).
    /// Clusters are far smaller than 64 nodes in every configuration
    /// this repo builds.
    pub fn down_mask(&self) -> u64 {
        let mut mask = 0u64;
        for (i, n) in self.nodes.iter().enumerate() {
            if !n.is_alive() {
                mask |= 1u64 << i;
            }
        }
        mask
    }
}

impl Drop for Cluster {
    fn drop(&mut self) {
        // Release pairs with the engine loop's Acquire load: the engine
        // must observe every pre-shutdown submission before it exits
        // (the Relaxed/Relaxed pair here was a genuine lint finding —
        // see scripts/loco_lint.py, rule `relaxed-publish`).
        self.shutdown.store(true, Ordering::Release);
        // Idle engines park on their doorbells with no timeout (the
        // 200 µs shutdown-poll cap is gone): wake them so they observe
        // the flag and exit.
        for n in &self.nodes {
            n.ring();
        }
        for h in self.engines.lock().unwrap().drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::verbs::{Payload, Verb};
    use crate::fabric::LatencyModel;

    fn wqe(wr_id: u64, verb: Verb) -> Wqe {
        Wqe::new(wr_id, verb)
    }

    #[test]
    fn inline_write_read_roundtrip() {
        let c = Cluster::new(2, FabricConfig::inline_ideal());
        let dst = c.node(1).register_mr(16, false);
        let src_buf = c.node(0).register_mr(16, false);
        let qp = c.create_qp(0, 1);

        c.post(qp, wqe(1, Verb::Write { remote: dst.at(0), data: Payload::from_words(&[7, 8, 9]) }));
        assert_eq!(c.node(0).cq().poll_one_blocking().wr_id, 1);
        assert_eq!(c.node(1).arena().load(dst.at(1)), 8);

        c.post(qp, wqe(2, Verb::Read { remote: dst.at(0), local: src_buf.at(0), len: 3 }));
        assert_eq!(c.node(0).cq().poll_one_blocking().wr_id, 2);
        let mut out = [0u64; 3];
        c.node(0).arena().load_words(src_buf.at(0), &mut out);
        assert_eq!(out, [7, 8, 9]);
    }

    #[test]
    fn inline_atomics() {
        let c = Cluster::new(2, FabricConfig::inline_ideal());
        let dst = c.node(1).register_mr(4, false);
        let loc = c.node(0).register_mr(4, false);
        let qp = c.create_qp(0, 1);
        c.post(qp, wqe(1, Verb::FetchAdd { remote: dst.at(0), add: 5, local: loc.at(0) }));
        c.node(0).cq().poll_one_blocking();
        assert_eq!(c.node(0).arena().load(loc.at(0)), 0);
        assert_eq!(c.node(1).arena().load(dst.at(0)), 5);
        c.post(qp, wqe(2, Verb::CompareSwap { remote: dst.at(0), expect: 5, swap: 11, local: loc.at(0) }));
        c.node(0).cq().poll_one_blocking();
        assert_eq!(c.node(0).arena().load(loc.at(0)), 5);
        assert_eq!(c.node(1).arena().load(dst.at(0)), 11);
    }

    #[test]
    fn send_recv_delivery() {
        let c = Cluster::new(2, FabricConfig::inline_ideal());
        let qp = c.create_qp(0, 1);
        c.post(qp, wqe(9, Verb::Send { bytes: b"hello".to_vec().into_boxed_slice() }));
        let msg = c.node(1).recv_timeout(std::time::Duration::from_secs(1)).unwrap();
        assert_eq!(msg.from, 0);
        assert_eq!(&*msg.bytes, b"hello");
    }

    #[test]
    #[should_panic(expected = "protection fault")]
    fn unregistered_access_faults() {
        let c = Cluster::new(2, FabricConfig::inline_ideal());
        let qp = c.create_qp(0, 1);
        c.post(qp, wqe(1, Verb::Write { remote: 12345, data: Payload::one(1) }));
    }

    /// Completion ≠ placement: with a huge placement lag, a completed
    /// write must not be visible remotely, until a flushing verb on the
    /// same QP forces placement.
    #[test]
    fn threaded_completion_before_placement_and_flush() {
        let mut lat = LatencyModel::ideal();
        lat.placement_lag_ns = 5_000_000_000; // 5 s: never retires on its own
        let c = Cluster::new(2, FabricConfig::threaded(lat));
        let dst = c.node(1).register_mr(4, false);
        let qp = c.create_qp(0, 1);

        c.post(qp, wqe(1, Verb::Write { remote: dst.at(0), data: Payload::one(42) }));
        assert_eq!(c.node(0).cq().poll_one_blocking().wr_id, 1);
        // Completed but almost surely not placed.
        assert_eq!(c.node(1).arena().load(dst.at(0)), 0, "placement should lag completion");

        // Zero-length read on the same QP flushes placement before completing.
        c.post(qp, wqe(2, Verb::ZeroLenRead));
        assert_eq!(c.node(0).cq().poll_one_blocking().wr_id, 2);
        assert_eq!(c.node(1).arena().load(dst.at(0)), 42);
    }

    /// Same-QP writes are placed in order even with random lag.
    #[test]
    fn threaded_same_qp_write_ordering() {
        let mut lat = LatencyModel::ideal();
        lat.placement_lag_ns = 10_000; // random per-write lag
        let c = Cluster::new(2, FabricConfig::threaded(lat));
        let dst = c.node(1).register_mr(4, false);
        let qp = c.create_qp(0, 1);

        for round in 0..200u64 {
            c.post(qp, wqe(1, Verb::Write { remote: dst.at(0), data: Payload::one(round * 2 + 1) }));
            c.post(qp, wqe(2, Verb::Write { remote: dst.at(0), data: Payload::one(round * 2 + 2) }));
            c.post(qp, wqe(3, Verb::ZeroLenRead));
            for _ in 0..3 {
                c.node(0).cq().poll_one_blocking();
            }
            // After the flush, the *second* write must have won.
            assert_eq!(c.node(1).arena().load(dst.at(0)), round * 2 + 2);
        }
    }

    /// A failed unsignaled WQE raises its QP's chain error, and the
    /// next signaled completion on that QP is delivered as `PeerFailed`
    /// (consuming the flag) — the selective-signaling failure contract:
    /// a covered chain's one CQE reports the whole prefix's fate.
    #[test]
    fn unsignaled_failure_fails_covering_completion() {
        use crate::fabric::cq::CqeStatus;
        let c = Cluster::new(2, FabricConfig::inline_ideal());
        let dst = c.node(1).register_mr(8, false);
        let qp = c.create_qp(0, 1);

        // Healthy chain first: unsignaled + covering signaled → Ok.
        c.post(qp, wqe(1, Verb::Write { remote: dst.at(0), data: Payload::one(5) }).unsignaled());
        assert!(!c.chain_error_pending(qp));
        c.post(qp, wqe(2, Verb::Write { remote: dst.at(1), data: Payload::one(6) }));
        assert!(c.node(0).cq().poll_one_blocking().is_ok());

        c.crash(1);
        // Failed unsignaled WQE: no CQE, chain error raised.
        c.post(qp, wqe(3, Verb::Write { remote: dst.at(0), data: Payload::one(9) }).unsignaled());
        assert!(c.node(0).cq().is_empty(), "unsignaled WQEs never generate CQEs");
        assert!(c.chain_error_pending(qp), "failed unsignaled WQE must raise the chain error");
        // The covering signaled completion reports the chain's failure
        // and consumes the flag.
        c.post(qp, wqe(4, Verb::Write { remote: dst.at(1), data: Payload::one(10) }));
        let cqe = c.node(0).cq().poll_one_blocking();
        assert_eq!((cqe.wr_id, cqe.status), (4, CqeStatus::PeerFailed));
        assert!(!c.chain_error_pending(qp), "covering completion consumes the chain error");
    }

    /// CQE accounting: signaled WQEs are counted, unsignaled are not —
    /// the counter the selective-signaling benches diff.
    #[test]
    fn cqe_counter_tracks_signaled_only() {
        let c = Cluster::new(2, FabricConfig::inline_ideal());
        let dst = c.node(1).register_mr(8, false);
        let qp = c.create_qp(0, 1);
        assert_eq!(c.cqes_posted(), 0);
        for i in 0..4u64 {
            c.post(
                qp,
                wqe(i, Verb::Write { remote: dst.at(i), data: Payload::one(i) }).unsignaled(),
            );
        }
        assert_eq!(c.cqes_posted(), 0, "unsignaled writes generate no CQEs");
        c.post(qp, wqe(9, Verb::ZeroLenRead));
        c.node(0).cq().poll_one_blocking();
        assert_eq!(c.cqes_posted(), 1);
        // Inline accounting: single-word payloads under the default cap.
        assert_eq!(c.wqes_inlined(), 0, "raw posts don't mark inline");
        c.post(
            qp,
            Wqe::new(10, Verb::Write { remote: dst.at(0), data: Payload::one(3) }).inlined(),
        );
        assert_eq!(c.wqes_inlined(), 1);
    }

    /// Unsignaled writes generate no CQE but still execute.
    #[test]
    fn unsignaled_write() {
        let c = Cluster::new(2, FabricConfig::inline_ideal());
        let dst = c.node(1).register_mr(4, false);
        let qp = c.create_qp(0, 1);
        c.post(qp, Wqe::new(0, Verb::Write { remote: dst.at(0), data: Payload::one(3) }).unsignaled());
        assert!(c.node(0).cq().is_empty());
        assert_eq!(c.node(1).arena().load(dst.at(0)), 3);
    }

    /// A post list executes in order on both delivery modes, and a
    /// flushing verb inside the batch still forces earlier placement.
    #[test]
    fn post_list_in_order_inline_and_threaded() {
        for threaded in [false, true] {
            let cfg = if threaded {
                FabricConfig::threaded(LatencyModel::fast_sim())
            } else {
                FabricConfig::inline_ideal()
            };
            let c = Cluster::new(2, cfg);
            let dst = c.node(1).register_mr(16, false);
            let src_buf = c.node(0).register_mr(16, false);
            let qp = c.create_qp(0, 1);

            let mut list = PostList::with_capacity(4);
            list.push(wqe(1, Verb::Write { remote: dst.at(0), data: Payload::one(5) }));
            list.push(wqe(2, Verb::Write { remote: dst.at(0), data: Payload::one(9) }));
            // The READ flushes both writes, then observes the second.
            list.push(wqe(3, Verb::Read { remote: dst.at(0), local: src_buf.at(0), len: 1 }));
            c.post_list(qp, list);
            for want in 1..=3u64 {
                assert_eq!(c.node(0).cq().poll_one_blocking().wr_id, want, "per-QP order");
            }
            assert_eq!(c.node(0).arena().load(src_buf.at(0)), 9, "read after both writes");
            // Empty lists are a no-op.
            c.post_list(qp, PostList::new());
            assert!(c.node(0).cq().is_empty());
        }
    }

    /// Doorbell amortization: N writes in one post list reach their last
    /// completion sooner than N scalar posts, because only the head pays
    /// `doorbell_ns` (simulated-arrival argument, not wall clock).
    #[test]
    fn post_list_amortizes_doorbell() {
        let mut lat = LatencyModel::ideal();
        lat.doorbell_ns = 200_000; // exaggerate so wall-clock noise can't mask it
        let n = 16u64;

        let elapsed = |batched: bool| {
            let c = Cluster::new(2, FabricConfig::threaded(lat.clone()));
            let dst = c.node(1).register_mr(64, false);
            let qp = c.create_qp(0, 1);
            let t0 = std::time::Instant::now();
            if batched {
                let list: PostList = (0..n)
                    .map(|i| wqe(i, Verb::Write { remote: dst.at(i), data: Payload::one(i) }))
                    .collect();
                c.post_list(qp, list);
            } else {
                for i in 0..n {
                    c.post(qp, wqe(i, Verb::Write { remote: dst.at(i), data: Payload::one(i) }));
                }
            }
            let mut seen = 0;
            let mut out = Vec::new();
            while seen < n as usize {
                seen += c.node(0).cq().poll(64, &mut out);
            }
            t0.elapsed()
        };
        let scalar = elapsed(false);
        let batched = elapsed(true);
        // Scalar pays 16 × 200 µs of doorbells (≥ 3.2 ms); batched pays
        // one. Require a conservative 2× separation.
        assert!(
            batched.as_secs_f64() * 2.0 < scalar.as_secs_f64(),
            "batched {batched:?} not ≥2× faster than scalar {scalar:?}"
        );
    }

    /// Crash-stop semantics (inline): verbs targeting a dead node
    /// complete with `PeerFailed` and have no effect; verbs posted *by*
    /// a dead node fail the same way; nothing hangs.
    #[test]
    fn crash_stop_error_completions_inline() {
        use crate::fabric::cq::CqeStatus;
        let c = Cluster::new(3, FabricConfig::inline_ideal());
        let dst = c.node(1).register_mr(8, false);
        let qp01 = c.create_qp(0, 1);
        let qp10 = c.create_qp(1, 0);

        c.post(qp01, wqe(1, Verb::Write { remote: dst.at(0), data: Payload::one(5) }));
        assert!(c.node(0).cq().poll_one_blocking().is_ok());
        assert!(!c.is_down(1));
        c.crash(1);
        assert!(c.is_down(1));
        assert_eq!(c.down_mask(), 0b010);

        // Write to the dead node: error completion, memory untouched.
        c.post(qp01, wqe(2, Verb::Write { remote: dst.at(0), data: Payload::one(9) }));
        let cqe = c.node(0).cq().poll_one_blocking();
        assert_eq!((cqe.wr_id, cqe.status), (2, CqeStatus::PeerFailed));
        assert_eq!(c.node(1).arena().load(dst.at(0)), 5, "dead node must not serve");

        // Posts from the dead node fail too (no transmission).
        let src = c.node(0).register_mr(4, false);
        c.post(qp10, wqe(3, Verb::Write { remote: src.at(0), data: Payload::one(7) }));
        let cqe = c.node(1).cq().poll_one_blocking();
        assert_eq!((cqe.wr_id, cqe.status), (3, CqeStatus::PeerFailed));
        assert_eq!(c.node(0).arena().load(src.at(0)), 0);

        // crash is idempotent.
        c.crash(1);
        assert_eq!(c.down_mask(), 0b010);
    }

    /// Revive undoes a crash-stop at the fabric layer: the node serves
    /// remote verbs again, the down mask drops the bit, and a chain
    /// error raised during the outage still surfaces once on the QP's
    /// next signaled completion before service resumes.
    #[test]
    fn revive_restores_service_and_surfaces_outage_chain_errors() {
        use crate::fabric::cq::CqeStatus;
        let c = Cluster::new(2, FabricConfig::inline_ideal());
        let dst = c.node(1).register_mr(8, false);
        let qp = c.create_qp(0, 1);

        c.crash(1);
        // An unsignaled write lost to the outage raises the chain error.
        c.post(qp, wqe(0, Verb::Write { remote: dst.at(0), data: Payload::one(9) }).unsignaled());

        c.revive(1);
        assert!(!c.is_down(1));
        assert_eq!(c.down_mask(), 0);
        // The first signaled completion after revive reports the outage…
        c.post(qp, wqe(1, Verb::Write { remote: dst.at(1), data: Payload::one(5) }));
        assert_eq!(c.node(0).cq().poll_one_blocking().status, CqeStatus::PeerFailed);
        // …and after that the QP serves normally again.
        c.post(qp, wqe(2, Verb::Write { remote: dst.at(0), data: Payload::one(7) }));
        let cqe = c.node(0).cq().poll_one_blocking();
        assert_eq!((cqe.wr_id, cqe.status), (2, CqeStatus::Ok));
        assert_eq!(c.node(1).arena().load(dst.at(0)), 7, "revived node must serve");
        // revive is idempotent.
        c.revive(1);
        assert!(!c.is_down(1));
    }

    /// Crash-stop under threaded delivery: in-flight verbs to the dead
    /// node drain with error completions (no hang), and a batched post
    /// list sees per-entry errors.
    #[test]
    fn crash_stop_drains_in_flight_threaded() {
        use crate::fabric::cq::CqeStatus;
        let mut lat = LatencyModel::ideal();
        lat.write_ns = 300_000; // 300 µs: ops are in flight when we crash
        let c = Cluster::new(2, FabricConfig::threaded(lat));
        let dst = c.node(1).register_mr(64, false);
        let qp = c.create_qp(0, 1);
        let list: PostList = (0..8u64)
            .map(|i| wqe(i, Verb::Write { remote: dst.at(i), data: Payload::one(i + 1) }))
            .collect();
        c.post_list(qp, list);
        c.crash(1);
        let mut got = Vec::new();
        let mut out = Vec::new();
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(10);
        while got.len() < 8 {
            c.node(0).cq().poll(64, &mut out);
            got.append(&mut out);
            assert!(std::time::Instant::now() < deadline, "completions never drained");
        }
        // Every op completed (ok before the crash landed, or failed
        // after); nothing was placed after the crash either way.
        assert!(got.iter().any(|e| e.status == CqeStatus::PeerFailed), "crash unseen");
    }

    /// Satellite regression for the engine-loop park fix: an idle
    /// cluster's engines must execute ~zero steps per second. The seed
    /// capped every doorbell wait at 200 µs as a shutdown poll, so each
    /// engine woke ≥ ~5000 times/s doing nothing; now an idle engine
    /// parks until a doorbell or its next due event, and shutdown rings
    /// the doorbells itself.
    #[test]
    fn idle_engines_park_instead_of_polling() {
        let c = Cluster::new(2, FabricConfig::threaded(LatencyModel::fast_sim()));
        let dst = c.node(1).register_mr(4, false);
        let qp = c.create_qp(0, 1);
        c.post(qp, wqe(1, Verb::Write { remote: dst.at(0), data: Payload::one(7) }));
        assert_eq!(c.node(0).cq().poll_one_blocking().wr_id, 1);
        // Let placement retire and both engines reach their parked state.
        std::thread::sleep(std::time::Duration::from_millis(100));
        let before: u64 = (0..2).map(|i| c.engine_steps(i)).sum();
        std::thread::sleep(std::time::Duration::from_millis(300));
        let woke = (0..2).map(|i| c.engine_steps(i)).sum::<u64>() - before;
        // The old poll cap would show ≥ ~3000 iterations here (2 engines
        // × 300 ms / 200 µs); allow a generous slack for stray wakeups.
        assert!(woke < 100, "idle engines ran {woke} loop iterations in 300 ms");
    }

    /// Striped engines: QPs spread across lanes by `qp_id % E`, every
    /// lane executes work, per-QP completion order is preserved, and
    /// the per-lane counters sum to the node total.
    #[test]
    fn striped_engines_share_qps_and_preserve_per_qp_order() {
        let c = Cluster::new(2, FabricConfig::threaded(LatencyModel::fast_sim()).with_engines(2));
        let dst = c.node(1).register_mr(256, false);
        let qps: Vec<QpId> = (0..4).map(|_| c.create_qp(0, 1)).collect();
        for i in 0..64u64 {
            let qp = qps[(i % 4) as usize];
            c.post(qp, wqe(i, Verb::Write { remote: dst.at(i), data: Payload::one(i + 1) }));
        }
        let mut got = Vec::new();
        let mut out = Vec::new();
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(10);
        while got.len() < 64 {
            c.node(0).cq().poll(64, &mut out);
            got.append(&mut out);
            assert!(std::time::Instant::now() < deadline, "completions never drained");
        }
        // Per-QP FIFO: each QP's wr_ids complete in posting order.
        for (q, qp) in qps.iter().enumerate() {
            let ids: Vec<u64> = got.iter().filter(|e| e.qp == *qp).map(|e| e.wr_id).collect();
            let want: Vec<u64> = (0..64).filter(|i| (i % 4) as usize == q).collect();
            assert_eq!(ids, want, "QP {q} completions out of order");
        }
        let by_lane = c.engine_ops_by_engine(0);
        assert_eq!(by_lane.len(), 2);
        assert!(
            by_lane.iter().all(|&ops| ops > 0),
            "degenerate striping: per-lane ops {by_lane:?}"
        );
        assert_eq!(by_lane.iter().sum::<u64>(), c.engine_ops(0));
    }

    /// Threaded mode actually delivers pipelined ops and all complete.
    #[test]
    fn threaded_pipeline_completes() {
        let c = Cluster::new(3, FabricConfig::threaded(LatencyModel::fast_sim()));
        let dst = c.node(1).register_mr(64, false);
        let qp = c.create_qp(0, 1);
        for i in 0..32u64 {
            c.post(qp, wqe(i, Verb::Write { remote: dst.at(i % 64), data: Payload::one(i) }));
        }
        let mut seen = 0;
        let mut out = Vec::new();
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(5);
        while seen < 32 {
            seen += c.node(0).cq().poll(64, &mut out);
            assert!(std::time::Instant::now() < deadline, "timed out waiting for completions");
        }
        // Completions arrive in per-QP order.
        let ids: Vec<u64> = out.iter().map(|c| c.wr_id).collect();
        assert_eq!(ids, (0..32).collect::<Vec<_>>());
    }
}
