//! The NIC engine: executes verbs with the paper's §2.2 semantics.
//!
//! In `Threaded` mode each node runs one engine thread that processes the
//! node's *outgoing* work requests:
//!
//! 1. drain each QP's submission queue, stamping an **arrival time**
//!    (base latency + bandwidth term + MR-cache penalty + a per-doorbell
//!    charge — only the head of a batched post list pays it), kept
//!    monotonic per QP so same-QP ordering holds;
//! 2. when an arrival is due, execute the verb's remote effect:
//!    * WRITE → post the completion *now*, but only enqueue the memory
//!      stores as a **placement** event with an extra sampled lag
//!      (completion ≠ placement);
//!    * READ / atomic / zero-length READ → first force full placement of
//!      every earlier WRITE on the same QP (the RFC 5040 flushing rule
//!      LOCO's fences rely on), then execute, then complete;
//!    * SEND → deliver to the target's receive queue, then complete;
//! 3. retire placement events whose lag has elapsed.
//!
//! Placement writes words one at a time, so application threads racing
//! with placement observe genuinely torn large values — the hazard
//! owned_var's checksums and the kvstore's retry protocol must tolerate.
//!
//! # Fault injection
//!
//! When `FabricConfig::faults` carries a [`FaultPlan`], the engine
//! additionally (all decisions drawn from a seeded per-node RNG stream,
//! so schedules replay exactly):
//!
//! * charges sampled **extra delay** per WQE (reordering ops *across*
//!   QPs — per-QP arrival stays monotonic, as RC QPs guarantee);
//! * **duplicates** and **reorders** completions in the shared CQ
//!   (never two CQEs of the same QP — that order is contractual);
//! * **flaps** a QP into the error state: execution pauses, and on
//!   recovery everything in flight is retransmitted in order with an
//!   extra penalty (`Qp::is_error` is observable above);
//! * **crash-stops** a node after a scheduled op count: from then on the
//!   node serves nothing, transmits nothing, and every verb touching it
//!   completes with [`CqeStatus::PeerFailed`](super::cq::CqeStatus) —
//!   including its own queued work, which is drained with error
//!   completions so no local waiter hangs.
//!
//! With `faults: None` every hook is a dead `Option` branch
//! (`bench::micro::fault_hook_overhead` pins the cost).
//!
//! In `Inline` mode the same effect functions run synchronously at post
//! time with zero lag (ordering preserved, no races from delay); unit
//! tests of channel logic use this. Inline mode honors crash-stop but
//! has no in-flight window for the other faults to act on.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use crate::analysis::ActorGuard;
use crate::util::queue::Queue;
use crate::util::rng::Rng;

use super::cq::Cqe;
use super::faults::FaultPlan;
use super::network::NodeFabric;
use super::qp::{Qp, QpId, Submission};
use super::verbs::{RecvMsg, Verb, Wqe};
use super::{Clock, FabricConfig, NodeId, DEVICE_BASE};

/// A WQE that has been stamped with its network arrival time.
struct InFlight {
    due_ns: u64,
    wqe: Wqe,
}

/// Stores that have "completed" but not yet been placed in remote memory.
struct Placement {
    due_ns: u64,
    target: NodeId,
    remote: u64,
    data: Box<[u64]>,
    /// Race-checker provenance: wr_id of the WRITE this placement
    /// belongs to (the posting node is the owning QP's node).
    wr_id: u64,
}

/// Per-QP engine state (owned exclusively by the engine thread).
struct QpState {
    qp: Arc<Qp>,
    rx: Arc<Queue<Submission>>,
    peer: NodeId,
    /// The QP's node-wide index (`QpId::index`). With striped engines a
    /// lane owns a subsequence of the node's QPs, so the position in
    /// `EngineCore::qps` is not the QP id — this is.
    global_idx: u32,
    inflight: VecDeque<InFlight>,
    placements: VecDeque<Placement>,
    last_arrival_ns: u64,
    /// Fault injection: while the wall clock is before this, the QP sits
    /// in the error state and executes nothing.
    flapped_until_ns: u64,
}

/// Per-engine completion-delivery state for the duplicate/reorder
/// faults: at most one CQE is held back, to be swapped with the next
/// CQE from a *different* QP.
struct CqeFx {
    hold: Option<Cqe>,
}

/// Deliver a CQE to `src`'s shared CQ, applying the duplicate/reorder
/// faults. Same-QP completion order is never violated: a held CQE only
/// swaps with a successor from another QP.
fn deliver_cqe(
    src: &Arc<NodeFabric>,
    fx: &mut CqeFx,
    faults: Option<&FaultPlan>,
    rng: &mut Rng,
    cqe: Cqe,
) {
    if let Some(f) = faults {
        if let Some(held) = fx.hold.take() {
            if held.qp != cqe.qp {
                // Cross-QP reorder: the newer completion overtakes.
                src.cq().post(cqe);
                src.cq().post(held);
            } else {
                src.cq().post(held);
                src.cq().post(cqe);
            }
            return;
        }
        if f.dup_prob > 0.0 && rng.gen_bool(f.dup_prob) {
            src.cq().post(cqe);
        }
        if f.reorder_prob > 0.0 && rng.gen_bool(f.reorder_prob) {
            fx.hold = Some(cqe);
            return;
        }
    }
    src.cq().post(cqe);
}

/// Execute the remote effect of a non-WRITE verb (WRITEs go through the
/// placement queue instead). Callers have already checked the target is
/// alive.
fn execute_effect(nodes: &[Arc<NodeFabric>], from: NodeId, wqe: &Wqe, target: NodeId, validate: bool) {
    let tgt = &nodes[target as usize];
    let src = &nodes[from as usize];
    match &wqe.verb {
        Verb::Write { remote, data } => {
            if validate {
                tgt.check_covered(*remote, data.len() as u64);
            }
            tgt.arena().store_words(*remote, data.as_slice(), false);
        }
        Verb::Read { remote, local, len } => {
            if validate {
                tgt.check_covered(*remote, *len as u64);
                src.check_covered(*local, *len as u64);
            }
            // Word-by-word copy: reads concurrent with remote writers may
            // observe torn large values, as on hardware.
            for i in 0..*len as u64 {
                let w = tgt.arena().load(*remote + i);
                src.arena().store(*local + i, w);
            }
        }
        Verb::ZeroLenRead => {}
        Verb::FetchAdd { remote, add, local } => {
            if validate {
                tgt.check_covered(*remote, 1);
            }
            let old = tgt.arena().fetch_add(*remote, *add);
            src.arena().store(*local, old);
        }
        Verb::CompareSwap { remote, expect, swap, local } => {
            if validate {
                tgt.check_covered(*remote, 1);
            }
            let old = tgt.arena().compare_swap(*remote, *expect, *swap);
            src.arena().store(*local, old);
        }
        Verb::Send { bytes } => {
            tgt.deliver(RecvMsg { from, bytes: bytes.clone() });
        }
    }
}

/// Compute the post→completion latency for a verb.
fn verb_latency(cfg: &FabricConfig, nodes: &[Arc<NodeFabric>], wqe: &Wqe, target: NodeId) -> u64 {
    let lat = &cfg.latency;
    let device_adj = |base: u64, remote: u64| {
        if remote >= DEVICE_BASE {
            base.saturating_sub(lat.device_mem_save_ns)
        } else {
            base
        }
    };
    let base = match &wqe.verb {
        Verb::Write { remote, .. } => device_adj(lat.write_ns, *remote),
        Verb::Read { remote, .. } => device_adj(lat.read_ns, *remote),
        Verb::ZeroLenRead => lat.read_ns,
        Verb::FetchAdd { remote, .. } | Verb::CompareSwap { remote, .. } => {
            device_adj(lat.atomic_ns, *remote)
        }
        Verb::Send { .. } => lat.send_ns,
    };
    let bw = (wqe.verb.wire_words() as f64 * lat.per_word_ns) as u64;
    // NIC MR-cache penalty: charged when the target node's registered-MR
    // count exceeds the simulated cache (paper [33]; explains Fig. 4).
    let mr_penalty = if nodes[target as usize].mr_count() > lat.mr_cache_entries {
        lat.mr_miss_ns
    } else {
        0
    };
    base + bw + mr_penalty
}

/// Per-WQE NIC occupancy beyond `op_overhead_ns`: the CQE DMA write
/// (signaled WQEs only — the selective-signaling economy) plus, for
/// WRITEs, the payload fetch (the PCIe DMA read for scatter-gather
/// payloads, or the much cheaper `inline_ns` when the payload was copied
/// into the WQE at post time). Charged into both the op's latency and
/// the per-QP serialization term: these steps occupy the NIC for every
/// WQE, so they bound pipelined throughput exactly like `op_overhead_ns`.
fn wqe_nic_extra(lat: &super::LatencyModel, wqe: &Wqe) -> u64 {
    let completion = if wqe.signaled { lat.completion_ns } else { 0 };
    let fetch = match &wqe.verb {
        Verb::Write { .. } => {
            if wqe.inline {
                lat.inline_ns
            } else {
                lat.wqe_fetch_ns
            }
        }
        _ => 0,
    };
    completion + fetch
}

/// Flush all pending placements of one QP (in order), regardless of lag.
/// Placements whose target crash-stopped are dropped — the data never
/// reached the remote memory.
fn flush_placements(nodes: &[Arc<NodeFabric>], from: NodeId, q: &mut QpState, chaotic: bool) {
    while let Some(p) = q.placements.pop_front() {
        let tgt = &nodes[p.target as usize];
        if tgt.is_alive() {
            let _dma = tgt.arena().checker().map(|_| ActorGuard::dma(from, from, p.wr_id));
            tgt.arena().store_words(p.remote, &p.data, chaotic);
        }
    }
}

/// Retire placements whose lag has elapsed (in order; stop at the first
/// not-yet-due entry so same-QP placement order is preserved).
fn retire_due_placements(
    nodes: &[Arc<NodeFabric>],
    from: NodeId,
    q: &mut QpState,
    now: u64,
    chaotic: bool,
) {
    while q.placements.front().map(|p| p.due_ns <= now).unwrap_or(false) {
        let p = q.placements.pop_front().unwrap();
        let tgt = &nodes[p.target as usize];
        if tgt.is_alive() {
            let _dma = tgt.arena().checker().map(|_| ActorGuard::dma(from, from, p.wr_id));
            tgt.arena().store_words(p.remote, &p.data, chaotic);
        }
    }
}

/// Execute one arrived WQE against per-QP engine state.
#[allow(clippy::too_many_arguments)]
fn execute_arrival(
    nodes: &[Arc<NodeFabric>],
    cfg: &FabricConfig,
    faults: Option<&FaultPlan>,
    rng: &mut Rng,
    fx: &mut CqeFx,
    from: NodeId,
    qpid: QpId,
    q: &mut QpState,
    fl: InFlight,
    now: u64,
) {
    let target = q.peer;
    let src = &nodes[from as usize];
    if !nodes[target as usize].is_alive() {
        // Crash-stopped peer: the verb has no effect; pending placements
        // on this QP can never land either. A failed **unsignaled** WQE
        // has no CQE of its own — raise the chain error so the covering
        // signaled completion of its chain reports the failure.
        q.placements.clear();
        if fl.wqe.signaled {
            q.qp.take_chain_error();
            deliver_cqe(src, fx, faults, rng, Cqe::failed(fl.wqe.wr_id, qpid));
        } else {
            q.qp.raise_chain_error();
        }
        return;
    }
    // A pending chain error (an earlier unsignaled WQE on this QP died)
    // fails the next signaled completion even though this verb itself
    // executed — the waiter must learn its covered chain broke.
    let chain_failed = fl.wqe.signaled && q.qp.take_chain_error();
    let completion = || {
        if chain_failed {
            Cqe::failed(fl.wqe.wr_id, qpid)
        } else {
            Cqe::ok(fl.wqe.wr_id, qpid)
        }
    };
    let chk = src.arena().checker();
    if let Some(h) = chk {
        h.checker.on_execute(from, fl.wqe.hb, fl.wqe.signaled);
        // DMA-execution-time MR check: a WQE stamped with an rkey whose
        // MR was invalidated while it sat in flight must not write
        // through whatever registration now covers those words. The
        // effect is skipped and the completion still delivered — the
        // diagnostic is the observable outcome. (Raw posts carry no
        // rkey and keep the legacy whole-table `check_covered` panic.)
        if let Some(mr) = fl.wqe.rkey {
            let span = match &fl.wqe.verb {
                Verb::Write { remote, data } => Some((*remote, data.len() as u64)),
                Verb::Read { remote, len, .. } => Some((*remote, *len as u64)),
                Verb::FetchAdd { remote, .. } | Verb::CompareSwap { remote, .. } => {
                    Some((*remote, 1))
                }
                Verb::ZeroLenRead | Verb::Send { .. } => None,
            };
            if let Some((addr, len)) = span {
                if !nodes[target as usize].mr_contains(mr, addr, len) {
                    h.checker.on_stale_mr(
                        target,
                        addr,
                        len,
                        from,
                        fl.wqe.wr_id,
                        mr,
                        "nic::execute_arrival",
                    );
                    if fl.wqe.signaled {
                        deliver_cqe(src, fx, faults, rng, completion());
                    }
                    return;
                }
            }
        }
    }
    match &fl.wqe.verb {
        Verb::Write { remote, data } => {
            if cfg.validate_access {
                nodes[target as usize].check_covered(*remote, data.len() as u64);
            }
            // Completion is posted now; placement lags behind (§2.2).
            let lag = if cfg.latency.placement_lag_ns == 0 {
                0
            } else {
                rng.gen_range_incl(0, cfg.latency.placement_lag_ns)
            };
            q.placements.push_back(Placement {
                due_ns: now + lag,
                target,
                remote: *remote,
                data: data.as_slice().to_vec().into_boxed_slice(),
                wr_id: fl.wqe.wr_id,
            });
            if lag == 0 {
                retire_due_placements(nodes, from, q, now, cfg.chaotic_placement);
            }
            if fl.wqe.signaled {
                deliver_cqe(src, fx, faults, rng, completion());
            }
        }
        _ => {
            if fl.wqe.verb.is_flushing() {
                flush_placements(nodes, from, q, cfg.chaotic_placement);
            }
            {
                let _dma = chk.map(|_| ActorGuard::dma(from, from, fl.wqe.wr_id));
                execute_effect(nodes, from, &fl.wqe, target, cfg.validate_access);
            }
            if fl.wqe.signaled {
                deliver_cqe(src, fx, faults, rng, completion());
            }
        }
    }
}

/// One node's engine state, factored out of the threaded loop so the
/// deterministic simulator ([`crate::sim`]) can *step* it over virtual
/// time. The threaded [`engine_loop`] is a thin driver around
/// [`EngineCore::step`]; both modes run byte-for-byte the same
/// stamping / execution / placement code.
pub(crate) struct EngineCore {
    nodes: Vec<Arc<NodeFabric>>,
    node: NodeId,
    /// Which of the node's `engines_per_node` stripes this core is. A
    /// QP with node-wide index `g` belongs to lane `g % engines_per_node`
    /// — a stable assignment, so a QP's whole life (stamping, FIFO
    /// execution, placement retirement) stays on one engine and per-QP
    /// ordering is untouched by striping.
    lane: u32,
    /// `cfg.engines_per_node`, cached (the stripe modulus).
    engines: u32,
    /// Claim cursor over the node's QP table: node-wide indices
    /// `< seen_global` have been examined (and claimed when ours).
    seen_global: u32,
    cfg: FabricConfig,
    faults: Option<FaultPlan>,
    rng: Rng,
    fx: CqeFx,
    executed_ops: u64,
    qps: Vec<QpState>,
    /// Occupancy model (`latency.engine_occupancy_ns > 0` only): no WQE
    /// on this lane executes before this instant. Stays 0 when the term
    /// is disabled, so the byte-compat fast paths below are untouched.
    busy_until_ns: u64,
    /// Occupancy model: round-robin cursor over `qps` so a saturating
    /// QP cannot starve its lane-mates of execution quanta.
    rr_exec: usize,
    /// Event-trace hash: folded over every executed arrival
    /// (node, qp, wr_id, verb tag, virtual timestamp). Two sim runs with
    /// the same seed must produce identical hashes on every engine — the
    /// determinism regression tests assert exactly this.
    trace: u64,
}

impl EngineCore {
    pub(crate) fn new(nodes: Vec<Arc<NodeFabric>>, node: NodeId, lane: u32, cfg: FabricConfig) -> Self {
        let engines = cfg.engines_per_node.max(1);
        debug_assert!(lane < engines);
        let fault_seed = cfg.faults.as_ref().map(|f| f.seed).unwrap_or(0);
        // Lane 0 keeps the exact single-engine stream (the XOR term is 0)
        // so engines_per_node = 1 replays seed-era traces bit-for-bit;
        // other lanes get independent streams.
        let rng = Rng::seeded(
            cfg.seed
                ^ ((node as u64) << 17)
                ^ fault_seed.rotate_left(31)
                ^ (lane as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15),
        );
        let faults = cfg.faults.clone();
        EngineCore {
            nodes,
            node,
            lane,
            engines,
            seen_global: 0,
            cfg,
            faults,
            rng,
            fx: CqeFx { hold: None },
            executed_ops: 0,
            qps: Vec::new(),
            busy_until_ns: 0,
            rr_exec: 0,
            trace: 0,
        }
    }

    #[inline]
    fn me(&self) -> &Arc<NodeFabric> {
        &self.nodes[self.node as usize]
    }

    /// Pick up newly created QPs (submission queues appear after the
    /// engine starts), claiming only this lane's stripe:
    /// `qp_id % engines_per_node == lane`.
    pub(crate) fn pickup_qps(&mut self) {
        let qp_count = self.me().qp_count() as u32;
        while self.seen_global < qp_count {
            let g = self.seen_global;
            self.seen_global += 1;
            if g % self.engines != self.lane {
                continue;
            }
            let qp = self.me().qp_engine_handle(g);
            self.qps.push(QpState {
                rx: qp.submission_queue(),
                peer: qp.peer,
                qp,
                global_idx: g,
                inflight: VecDeque::new(),
                placements: VecDeque::new(),
                last_arrival_ns: 0,
                flapped_until_ns: 0,
            });
        }
    }

    /// The event-trace hash accumulated so far.
    pub(crate) fn trace(&self) -> u64 {
        self.trace
    }

    /// One engine pass at the clock's current time: stamp submissions,
    /// recover flaps, execute due arrivals, retire due placements, apply
    /// the scheduled crash-stop. Returns whether anything ran.
    pub(crate) fn step(&mut self, clock: &Clock) -> bool {
        self.pickup_qps();
        let EngineCore {
            nodes,
            node,
            lane,
            cfg,
            faults,
            rng,
            fx,
            executed_ops,
            qps,
            busy_until_ns,
            rr_exec,
            trace,
            ..
        } = self;
        let node = *node;
        let lane = *lane;
        let me = &nodes[node as usize];
        let mut did_work = false;

        if !me.is_alive() {
            // Crash-stop: drain everything with error completions so the
            // dead node's local waiters (its service threads in the
            // simulation) unblock; execute nothing, transmit nothing.
            for q in qps.iter_mut() {
                let qpid = QpId { node, index: q.global_idx };
                while let Some(sub) = q.rx.try_pop() {
                    if sub.wqe.signaled {
                        q.qp.take_chain_error();
                        me.cq().post(Cqe::failed(sub.wqe.wr_id, qpid));
                    } else {
                        q.qp.raise_chain_error();
                    }
                    did_work = true;
                }
                while let Some(fl) = q.inflight.pop_front() {
                    if fl.wqe.signaled {
                        q.qp.take_chain_error();
                        me.cq().post(Cqe::failed(fl.wqe.wr_id, qpid));
                    } else {
                        q.qp.raise_chain_error();
                    }
                    did_work = true;
                }
                if !q.placements.is_empty() {
                    q.placements.clear();
                    did_work = true;
                }
                if q.qp.is_error() {
                    q.qp.set_error(false);
                }
            }
        } else {
            // Mark this thread as the node's NIC engine (this stripe's
            // lane) for the checker — per-WQE DMA guards nest inside and
            // restore this on drop.
            let _engine = me.arena().checker().map(|_| ActorGuard::engine_lane(node, lane));
            for q in qps.iter_mut() {
                // 1. stamp new submissions
                let now = clock.now_ns();
                while let Some(sub) = q.rx.try_pop() {
                    let wqe = sub.wqe;
                    let mut lat = verb_latency(cfg, nodes, &wqe, q.peer);
                    if let Some(f) = &faults {
                        // Sampled extra delay: reorders ops across QPs
                        // while the max() below keeps per-QP order.
                        if f.delay_prob > 0.0 && rng.gen_bool(f.delay_prob) {
                            lat += rng.gen_range_incl(0, f.delay_max_ns);
                        }
                        // QP flap: transient error state, sampled per
                        // submission so the rate tracks offered load.
                        if f.flap_prob > 0.0 && rng.gen_bool(f.flap_prob) {
                            q.flapped_until_ns = now + f.flap_ns;
                            q.qp.set_error(true);
                        }
                    }
                    // Doorbell charge: only the head of a post list pays the
                    // MMIO cost; batch tails ride the same doorbell. This is
                    // the term that makes PostList batching measurable.
                    let db = if sub.rings_doorbell { cfg.latency.doorbell_ns } else { 0 };
                    // Per-WQE occupancy beyond op_overhead: CQE generation
                    // (signaled only) + payload fetch (non-inline WRITEs).
                    // These are what selective signaling and inline
                    // payloads buy back on the write hot path.
                    let extra = wqe_nic_extra(&cfg.latency, &wqe);
                    // Per-QP serialization: the NIC cannot accept WQEs faster
                    // than op_overhead_ns (+ per-WQE occupancy) apart →
                    // arrival monotone per QP.
                    let arr = (now + lat + db + extra)
                        .max(q.last_arrival_ns + cfg.latency.op_overhead_ns + extra + db);
                    q.last_arrival_ns = arr;
                    q.inflight.push_back(InFlight { due_ns: arr, wqe });
                    did_work = true;
                }
                let now2 = clock.now_ns();
                // 1b. flap recovery: leave the error state and retransmit
                // everything in flight, in order, with the penalty.
                if q.qp.is_error() && now2 >= q.flapped_until_ns {
                    let penalty = faults.as_ref().map(|f| f.retransmit_ns).unwrap_or(0);
                    let resume = q.flapped_until_ns + penalty;
                    for fl in q.inflight.iter_mut() {
                        fl.due_ns = fl.due_ns.max(resume);
                    }
                    q.last_arrival_ns = q.last_arrival_ns.max(resume);
                    q.qp.set_error(false);
                    did_work = true;
                }
                // 2. execute due arrivals (FIFO per QP; a flapped QP
                // executes nothing until it recovers). With the occupancy
                // model on, execution instead happens in pass 2b below —
                // this in-place loop is the zero-occupancy fast path,
                // byte-for-byte the pre-occupancy behavior.
                if !q.qp.is_error() && cfg.latency.engine_occupancy_ns == 0 {
                    while q.inflight.front().map(|f| f.due_ns <= now2).unwrap_or(false) {
                        let fl = q.inflight.pop_front().unwrap();
                        let qpid = QpId { node, index: q.global_idx };
                        let tag = match &fl.wqe.verb {
                            Verb::Write { .. } => 1u64,
                            Verb::Read { .. } => 2,
                            Verb::ZeroLenRead => 3,
                            Verb::FetchAdd { .. } => 4,
                            Verb::CompareSwap { .. } => 5,
                            Verb::Send { .. } => 6,
                        };
                        *trace = crate::util::mix64(
                            *trace
                                ^ ((node as u64) << 48)
                                ^ ((q.global_idx as u64) << 32)
                                ^ fl.wqe.wr_id.rotate_left(13)
                                ^ (tag << 56)
                                ^ now2,
                        );
                        execute_arrival(
                            nodes,
                            cfg,
                            faults.as_ref(),
                            rng,
                            fx,
                            node,
                            qpid,
                            q,
                            fl,
                            now2,
                        );
                        *executed_ops += 1;
                        did_work = true;
                    }
                }
                // 3. retire due placements
                retire_due_placements(nodes, node, q, clock.now_ns(), cfg.chaotic_placement);
            }
            // 2b. occupancy-modeled execution: the lane retires at most
            // one due WQE per `engine_occupancy_ns`, round-robin across
            // its QPs (per-QP FIFO still holds — only the front of each
            // inflight queue is eligible). This makes engine count a
            // modeled throughput axis: E lanes retire E WQEs per
            // quantum, regardless of how many host cores back them.
            let occ = cfg.latency.engine_occupancy_ns;
            if occ > 0 && !qps.is_empty() {
                loop {
                    let now2 = clock.now_ns();
                    if *busy_until_ns > now2 {
                        break;
                    }
                    let k = qps.len();
                    let mut ran = false;
                    for i in 0..k {
                        let qi = (*rr_exec + i) % k;
                        let q = &mut qps[qi];
                        if q.qp.is_error() {
                            continue;
                        }
                        if q.inflight.front().map(|f| f.due_ns <= now2).unwrap_or(false) {
                            let fl = q.inflight.pop_front().unwrap();
                            let qpid = QpId { node, index: q.global_idx };
                            let tag = match &fl.wqe.verb {
                                Verb::Write { .. } => 1u64,
                                Verb::Read { .. } => 2,
                                Verb::ZeroLenRead => 3,
                                Verb::FetchAdd { .. } => 4,
                                Verb::CompareSwap { .. } => 5,
                                Verb::Send { .. } => 6,
                            };
                            *trace = crate::util::mix64(
                                *trace
                                    ^ ((node as u64) << 48)
                                    ^ ((q.global_idx as u64) << 32)
                                    ^ fl.wqe.wr_id.rotate_left(13)
                                    ^ (tag << 56)
                                    ^ now2,
                            );
                            execute_arrival(
                                nodes,
                                cfg,
                                faults.as_ref(),
                                rng,
                                fx,
                                node,
                                qpid,
                                q,
                                fl,
                                now2,
                            );
                            *executed_ops += 1;
                            *busy_until_ns = now2 + occ;
                            *rr_exec = qi + 1;
                            ran = true;
                            break;
                        }
                    }
                    if !ran {
                        break;
                    }
                    did_work = true;
                }
            }
            // Scheduled crash-stop (fault injection): this node dies once
            // its engines have executed the planned op count — either from
            // the construction-time plan or a runtime-armed threshold
            // (`Cluster::crash_after_ops`). With striped engines the
            // threshold is against the node *total* across lanes (equal
            // to this lane's own count when engines_per_node = 1).
            nodes[node as usize].publish_engine_ops(lane, *executed_ops);
            let total = nodes[node as usize].engine_ops_total();
            let planned = faults
                .as_ref()
                .and_then(|f| f.crash_after)
                .is_some_and(|(victim, after)| victim == node && total >= after);
            if planned || nodes[node as usize].crash_due(total) {
                nodes[node as usize].crash();
                for n in nodes.iter() {
                    n.ring();
                }
                did_work = true;
            }
        }
        did_work
    }

    /// Flush a held-back (reorder-fault) completion, if any. A held CQE
    /// must not outlive the burst that produced it — the threaded loop
    /// flushes before idling, the sim before declaring quiescence.
    pub(crate) fn flush_hold(&mut self) -> bool {
        if let Some(held) = self.fx.hold.take() {
            self.me().cq().post(held);
            return true;
        }
        false
    }

    /// Nothing queued, in flight, or pending anywhere (shutdown gate).
    pub(crate) fn fully_idle(&self) -> bool {
        self.qps
            .iter()
            .all(|q| q.inflight.is_empty() && q.placements.is_empty() && q.rx.is_empty())
            && self.me().qp_count() == self.seen_global as usize
            && self.fx.hold.is_none()
    }

    /// Would a step at time `now` do anything? (The sim scheduler's
    /// runnability test. `pickup_qps` must run first so fresh
    /// submission queues are visible.)
    pub(crate) fn has_immediate_work(&self, now: u64) -> bool {
        let me = self.me();
        if !me.is_alive() {
            return self.qps.iter().any(|q| {
                !q.rx.is_empty()
                    || !q.inflight.is_empty()
                    || !q.placements.is_empty()
                    || q.qp.is_error()
            });
        }
        // Crash thresholds are against the node total across lanes (see
        // `step`) — published counts, so every lane of the victim node
        // sees the due crash and any one of them can apply it.
        let total = me.engine_ops_total();
        if let Some((victim, after)) = self.faults.as_ref().and_then(|f| f.crash_after) {
            if victim == self.node && total >= after {
                return true;
            }
        }
        if me.crash_due(total) {
            return true;
        }
        self.qps.iter().any(|q| {
            if !q.rx.is_empty() {
                return true;
            }
            // Placements retire on the wall even while the QP is flapped
            // (the threaded loop runs step 3 unconditionally).
            if q.placements.front().map(|p| p.due_ns <= now).unwrap_or(false) {
                return true;
            }
            if q.qp.is_error() {
                return now >= q.flapped_until_ns;
            }
            // Execution also waits out the lane's occupancy window
            // (`busy_until_ns` is pinned to 0 when the term is off).
            q.inflight.front().map(|f| f.due_ns <= now).unwrap_or(false)
                && now >= self.busy_until_ns
        })
    }

    /// Earliest future event on this engine (arrival, placement, or flap
    /// recovery) — the sim scheduler advances the virtual clock to the
    /// minimum over all engines when nothing is immediately runnable.
    pub(crate) fn next_due(&self) -> Option<u64> {
        if !self.me().is_alive() {
            return None;
        }
        let mut next: Option<u64> = None;
        let mut fold = |t: u64| next = Some(next.map_or(t, |n: u64| n.min(t)));
        for q in &self.qps {
            if let Some(p) = q.placements.front() {
                fold(p.due_ns);
            }
            if q.qp.is_error() {
                fold(q.flapped_until_ns);
                continue;
            }
            if let Some(f) = q.inflight.front() {
                // An arrival cannot execute inside the lane's occupancy
                // window (0 when the term is off).
                fold(f.due_ns.max(q.flapped_until_ns).max(self.busy_until_ns));
            }
        }
        next
    }
}

/// The per-node engine loop (threaded mode): drive one lane's
/// [`EngineCore`] against the wall clock, sleeping on the doorbell when
/// idle.
pub(super) fn engine_loop(
    nodes: Vec<Arc<NodeFabric>>,
    node: NodeId,
    lane: u32,
    cfg: FabricConfig,
    clock: Clock,
    shutdown: Arc<AtomicBool>,
) {
    let me = nodes[node as usize].clone();
    let mut core = EngineCore::new(nodes, node, lane, cfg);
    let mut idle_iters: u32 = 0;
    loop {
        let doorbell = me.doorbell_value();
        me.note_engine_step();
        let did_work = core.step(&clock);
        if !did_work {
            // A held-back completion must not outlive the burst that
            // produced it: flush before idling or shutting down.
            if core.flush_hold() {
                idle_iters = 0;
                continue;
            }
            idle_iters += 1;
            if shutdown.load(Ordering::Acquire) && core.fully_idle() {
                break;
            }
            // Nothing ran this pass: park until the next deadline (due
            // arrival, placement, or flap recovery) or until a doorbell
            // rings. An idle engine must not wake on its own: every
            // state change that could give it work rings the doorbell
            // (post, crash, revive, shutdown), so there is no polling
            // cap here — the seed's 200 µs shutdown-poll cap burned
            // ~5k wakeups/s per engine on an idle cluster. Burning a
            // core spinning here starves application threads on small
            // hosts (EXPERIMENTS.md §Perf).
            let wait = core
                .next_due()
                .map(|t| t.saturating_sub(clock.now_ns()))
                .unwrap_or(u64::MAX);
            if wait > 3_000 && idle_iters > 8 {
                me.doorbell_wait(doorbell, wait);
            } else if idle_iters > 16 {
                std::thread::yield_now();
            } else {
                std::hint::spin_loop();
            }
        } else {
            idle_iters = 0;
        }
    }
}

/// Inline-mode execution: run the verb synchronously at post time.
/// Placement is immediate; ordering trivially preserved. Crash-stop is
/// honored (error completion, no effect); the in-flight faults have no
/// window to act on.
pub(super) fn execute_inline(
    nodes: &[Arc<NodeFabric>],
    cfg: &FabricConfig,
    from: NodeId,
    qp: &super::qp::Qp,
    wqe: Wqe,
) {
    let qpid = qp.id;
    let peer = qp.peer;
    let src = &nodes[from as usize];
    if !nodes[peer as usize].is_alive() {
        if wqe.signaled {
            qp.take_chain_error();
            src.cq().post(Cqe::failed(wqe.wr_id, qpid));
        } else {
            qp.raise_chain_error();
        }
        return;
    }
    let chk = src.arena().checker();
    if let Some(h) = chk {
        h.checker.on_execute(from, wqe.hb, wqe.signaled);
        if let Some(mr) = wqe.rkey {
            let span = match &wqe.verb {
                Verb::Write { remote, data } => Some((*remote, data.len() as u64)),
                Verb::Read { remote, len, .. } => Some((*remote, *len as u64)),
                Verb::FetchAdd { remote, .. } | Verb::CompareSwap { remote, .. } => {
                    Some((*remote, 1))
                }
                Verb::ZeroLenRead | Verb::Send { .. } => None,
            };
            if let Some((addr, len)) = span {
                if !nodes[peer as usize].mr_contains(mr, addr, len) {
                    h.checker.on_stale_mr(peer, addr, len, from, wqe.wr_id, mr, "nic::execute_inline");
                    if wqe.signaled {
                        if qp.take_chain_error() {
                            src.cq().post(Cqe::failed(wqe.wr_id, qpid));
                        } else {
                            src.cq().post(Cqe::ok(wqe.wr_id, qpid));
                        }
                    }
                    return;
                }
            }
        }
    }
    {
        // Inline mode: the posting application thread performs the
        // remote effect itself (synchronous, program-ordered).
        let _g = chk.map(|_| ActorGuard::app(from, wqe.wr_id));
        match &wqe.verb {
            Verb::Write { remote, data } => {
                if cfg.validate_access {
                    nodes[peer as usize].check_covered(*remote, data.len() as u64);
                }
                nodes[peer as usize]
                    .arena()
                    .store_words(*remote, data.as_slice(), cfg.chaotic_placement);
            }
            _ => execute_effect(nodes, from, &wqe, peer, cfg.validate_access),
        }
    }
    if wqe.signaled {
        // An earlier unsignaled WQE of this chain failed: the covering
        // completion carries the failure even though this verb executed.
        if qp.take_chain_error() {
            src.cq().post(Cqe::failed(wqe.wr_id, qpid));
        } else {
            src.cq().post(Cqe::ok(wqe.wr_id, qpid));
        }
    }
}
