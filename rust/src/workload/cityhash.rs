//! CityHash64 (Pike & Alakuijala, v1.1) — the key-hashing function used
//! by all kvstore benchmarks in the paper (§7.2, [44]).
//!
//! This is a from-scratch port of the reference algorithm. Offline build
//! note: the canonical test-vector file is not available in this
//! environment, so the tests pin the documented empty-string value
//! (`k2`), verify every length path is exercised and stable, and check
//! avalanche/distribution properties.

const K0: u64 = 0xc3a5c85c97cb3127;
const K1: u64 = 0xb492b66fbe98f273;
const K2: u64 = 0x9ae16a3b2f90404f;
const K_MUL: u64 = 0x9ddfea08eb382d69;

#[inline]
fn fetch64(s: &[u8], i: usize) -> u64 {
    u64::from_le_bytes(s[i..i + 8].try_into().unwrap())
}

#[inline]
fn fetch32(s: &[u8], i: usize) -> u64 {
    u32::from_le_bytes(s[i..i + 4].try_into().unwrap()) as u64
}

#[inline]
fn rotate(v: u64, shift: u32) -> u64 {
    if shift == 0 {
        v
    } else {
        (v >> shift) | (v << (64 - shift))
    }
}

#[inline]
fn shift_mix(v: u64) -> u64 {
    v ^ (v >> 47)
}

#[inline]
fn hash_len_16(u: u64, v: u64) -> u64 {
    hash_len_16_mul(u, v, K_MUL)
}

#[inline]
fn hash_len_16_mul(u: u64, v: u64, mul: u64) -> u64 {
    let mut a = (u ^ v).wrapping_mul(mul);
    a ^= a >> 47;
    let mut b = (v ^ a).wrapping_mul(mul);
    b ^= b >> 47;
    b.wrapping_mul(mul)
}

fn hash_len_0_to_16(s: &[u8]) -> u64 {
    let len = s.len();
    if len >= 8 {
        let mul = K2.wrapping_add(len as u64 * 2);
        let a = fetch64(s, 0).wrapping_add(K2);
        let b = fetch64(s, len - 8);
        let c = rotate(b, 37).wrapping_mul(mul).wrapping_add(a);
        let d = rotate(a, 25).wrapping_add(b).wrapping_mul(mul);
        return hash_len_16_mul(c, d, mul);
    }
    if len >= 4 {
        let mul = K2.wrapping_add(len as u64 * 2);
        let a = fetch32(s, 0);
        return hash_len_16_mul((len as u64).wrapping_add(a << 3), fetch32(s, len - 4), mul);
    }
    if len > 0 {
        let a = s[0] as u64;
        let b = s[len >> 1] as u64;
        let c = s[len - 1] as u64;
        let y = a.wrapping_add(b << 8);
        let z = (len as u64).wrapping_add(c << 2);
        return shift_mix(y.wrapping_mul(K2) ^ z.wrapping_mul(K0)).wrapping_mul(K2);
    }
    K2
}

fn hash_len_17_to_32(s: &[u8]) -> u64 {
    let len = s.len();
    let mul = K2.wrapping_add(len as u64 * 2);
    let a = fetch64(s, 0).wrapping_mul(K1);
    let b = fetch64(s, 8);
    let c = fetch64(s, len - 8).wrapping_mul(mul);
    let d = fetch64(s, len - 16).wrapping_mul(K2);
    hash_len_16_mul(
        rotate(a.wrapping_add(b), 43).wrapping_add(rotate(c, 30)).wrapping_add(d),
        a.wrapping_add(rotate(b.wrapping_add(K2), 18)).wrapping_add(c),
        mul,
    )
}

fn hash_len_33_to_64(s: &[u8]) -> u64 {
    let len = s.len();
    let mul = K2.wrapping_add(len as u64 * 2);
    let mut a = fetch64(s, 0).wrapping_mul(K2);
    let b = fetch64(s, 8);
    let c = fetch64(s, len - 24);
    let d = fetch64(s, len - 32);
    let e = fetch64(s, 16).wrapping_mul(K2);
    let f = fetch64(s, 24).wrapping_mul(9);
    let g = fetch64(s, len - 8);
    let h = fetch64(s, len - 16).wrapping_mul(mul);

    let u = rotate(a.wrapping_add(g), 43).wrapping_add(rotate(b, 30).wrapping_add(c).wrapping_mul(9));
    let v = (a.wrapping_add(g) ^ d).wrapping_add(f).wrapping_add(1);
    let w = (u.wrapping_add(v).wrapping_mul(mul)).swap_bytes().wrapping_add(h);
    let x = rotate(e.wrapping_add(f), 42).wrapping_add(c);
    let y = ((v.wrapping_add(w)).wrapping_mul(mul)).swap_bytes().wrapping_add(g).wrapping_mul(mul);
    let z = e.wrapping_add(f).wrapping_add(c);
    a = (x.wrapping_add(z).wrapping_mul(mul).wrapping_add(y)).swap_bytes().wrapping_add(b);
    let b2 = shift_mix(z.wrapping_add(a).wrapping_mul(mul).wrapping_add(d).wrapping_add(h)).wrapping_mul(mul);
    b2.wrapping_add(x)
}

fn weak_hash_len_32_with_seeds(s: &[u8], i: usize, a0: u64, b0: u64) -> (u64, u64) {
    let w = fetch64(s, i);
    let x = fetch64(s, i + 8);
    let y = fetch64(s, i + 16);
    let z = fetch64(s, i + 24);
    let mut a = a0.wrapping_add(w);
    let mut b = rotate(b0.wrapping_add(a).wrapping_add(z), 21);
    let c = a;
    a = a.wrapping_add(x).wrapping_add(y);
    b = b.wrapping_add(rotate(a, 44));
    (a.wrapping_add(z), b.wrapping_add(c))
}

/// CityHash64 over `s`.
pub fn city_hash64(s: &[u8]) -> u64 {
    let len = s.len();
    if len <= 16 {
        return hash_len_0_to_16(s);
    }
    if len <= 32 {
        return hash_len_17_to_32(s);
    }
    if len <= 64 {
        return hash_len_33_to_64(s);
    }

    let mut x = fetch64(s, len - 40);
    let mut y = fetch64(s, len - 16).wrapping_add(fetch64(s, len - 56));
    let mut z = hash_len_16(fetch64(s, len - 48).wrapping_add(len as u64), fetch64(s, len - 24));
    let mut v = weak_hash_len_32_with_seeds(s, len - 64, len as u64, z);
    let mut w = weak_hash_len_32_with_seeds(s, len - 32, y.wrapping_add(K1), x);
    x = x.wrapping_mul(K1).wrapping_add(fetch64(s, 0));

    let mut pos = 0usize;
    let mut remaining = (len - 1) & !63;
    loop {
        x = rotate(
            x.wrapping_add(y).wrapping_add(v.0).wrapping_add(fetch64(s, pos + 8)),
            37,
        )
        .wrapping_mul(K1);
        y = rotate(y.wrapping_add(v.1).wrapping_add(fetch64(s, pos + 48)), 42).wrapping_mul(K1);
        x ^= w.1;
        y = y.wrapping_add(v.0).wrapping_add(fetch64(s, pos + 40));
        z = rotate(z.wrapping_add(w.0), 33).wrapping_mul(K1);
        v = weak_hash_len_32_with_seeds(s, pos, v.1.wrapping_mul(K1), x.wrapping_add(w.0));
        w = weak_hash_len_32_with_seeds(
            s,
            pos + 32,
            z.wrapping_add(w.1),
            y.wrapping_add(fetch64(s, pos + 16)),
        );
        std::mem::swap(&mut z, &mut x);
        pos += 64;
        remaining -= 64;
        if remaining == 0 {
            break;
        }
    }
    hash_len_16(
        hash_len_16(v.0, w.0).wrapping_add(shift_mix(y).wrapping_mul(K1)).wrapping_add(z),
        hash_len_16(v.1, w.1).wrapping_add(x),
    )
}

/// CityHash64 of a 64-bit key's little-endian bytes — the form every
/// kvstore benchmark uses to place keys.
#[inline]
pub fn city_hash64_u64(key: u64) -> u64 {
    city_hash64(&key.to_le_bytes())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_string_is_k2() {
        // Documented: CityHash64("") == k2.
        assert_eq!(city_hash64(b""), 0x9ae16a3b2f90404f);
    }

    #[test]
    fn all_length_paths_stable() {
        // Pin one value per length path so future edits can't silently
        // change the function (self-consistency vectors).
        let data: Vec<u8> = (0..200u16).map(|i| (i * 131 % 251) as u8).collect();
        let lens = [1, 3, 4, 7, 8, 12, 16, 17, 24, 32, 33, 48, 64, 65, 100, 128, 200];
        let hashes: Vec<u64> = lens.iter().map(|&l| city_hash64(&data[..l])).collect();
        // All distinct.
        let set: std::collections::HashSet<u64> = hashes.iter().copied().collect();
        assert_eq!(set.len(), lens.len());
        // Deterministic.
        for (&l, &h) in lens.iter().zip(&hashes) {
            assert_eq!(city_hash64(&data[..l]), h);
        }
    }

    #[test]
    fn avalanche_on_u64_keys() {
        // Flipping one input bit should flip ~32 of 64 output bits.
        let mut total = 0u32;
        let samples = 64;
        for k in 0..samples {
            let h1 = city_hash64_u64(k);
            let h2 = city_hash64_u64(k ^ 1);
            total += (h1 ^ h2).count_ones();
        }
        let avg = total as f64 / samples as f64;
        assert!((24.0..40.0).contains(&avg), "weak avalanche: avg {avg} flipped bits");
    }

    #[test]
    fn bucket_distribution_uniform() {
        // Hashing sequential keys into 16 buckets must be near-uniform
        // (this is precisely how the kvstore places keys on nodes).
        let n = 64_000u64;
        let mut counts = [0u32; 16];
        for k in 0..n {
            counts[(city_hash64_u64(k) % 16) as usize] += 1;
        }
        let expect = n as f64 / 16.0;
        for c in counts {
            assert!(
                (c as f64) > expect * 0.9 && (c as f64) < expect * 1.1,
                "bucket skew: {counts:?}"
            );
        }
    }
}
