//! Workload generation: key distributions and operation mixes matching
//! the paper's evaluation setup (§7.2): CityHash64 key hashing [44],
//! the YCSB-C Zipfian implementation [5] with θ = 0.99, and
//! read/update operation mixes over a 10 MB keyspace at 80 % fill.

pub mod cityhash;
pub mod ycsb;
pub mod zipfian;

pub use cityhash::city_hash64;
pub use ycsb::{KeyDist, Op, OpMix, ValueDist, WorkloadGen};
pub use zipfian::Zipfian;
