//! Zipfian key distribution — a port of the YCSB-C generator [5] with
//! the paper's θ = 0.99 (§7.2).
//!
//! The YCSB algorithm (Gray et al.'s "quickly generating billion-record
//! synthetic databases" rejection-free method): draw u ∈ [0,1) and map
//! through the zeta-function-based inverse CDF approximation.

use crate::util::rng::Rng;

use super::cityhash::city_hash64_u64;

pub struct Zipfian {
    items: u64,
    theta: f64,
    zeta_n: f64,
    alpha: f64,
    eta: f64,
    /// Scramble outputs with CityHash so hot keys are spread across the
    /// keyspace (YCSB's "scrambled zipfian"), as benchmark keys.
    scramble: bool,
}

fn zeta(n: u64, theta: f64) -> f64 {
    let mut sum = 0.0;
    for i in 1..=n {
        sum += 1.0 / (i as f64).powf(theta);
    }
    sum
}

impl Zipfian {
    /// `items` ranks, skew `theta` (the paper uses 0.99). O(items) setup.
    pub fn new(items: u64, theta: f64) -> Self {
        assert!(items >= 2);
        let zeta_n = zeta(items, theta);
        let zeta_2 = zeta(2, theta);
        let alpha = 1.0 / (1.0 - theta);
        let eta = (1.0 - (2.0 / items as f64).powf(1.0 - theta)) / (1.0 - zeta_2 / zeta_n);
        Zipfian { items, theta, zeta_n, alpha, eta, scramble: false }
    }

    pub fn scrambled(items: u64, theta: f64) -> Self {
        let mut z = Self::new(items, theta);
        z.scramble = true;
        z
    }

    /// Draw the next rank (0 = most popular) or, if scrambled, a key
    /// in `[0, items)` with zipf-distributed popularity.
    pub fn next(&self, rng: &mut Rng) -> u64 {
        let u = rng.gen_f64();
        let uz = u * self.zeta_n;
        let rank = if uz < 1.0 {
            0
        } else if uz < 1.0 + 0.5f64.powf(self.theta) {
            1
        } else {
            ((self.items as f64) * (self.eta * u - self.eta + 1.0).powf(self.alpha)) as u64
        };
        let rank = rank.min(self.items - 1);
        if self.scramble {
            city_hash64_u64(rank) % self.items
        } else {
            rank
        }
    }

    pub fn items(&self) -> u64 {
        self.items
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Empirical frequencies must follow the analytic zipf pmf:
    /// p(rank k) = (1/k^θ) / ζ(n).
    #[test]
    fn matches_analytic_pmf() {
        let n = 1000;
        let theta = 0.99;
        let z = Zipfian::new(n, theta);
        let mut rng = Rng::seeded(42);
        let draws = 200_000;
        let mut counts = vec![0u32; n as usize];
        for _ in 0..draws {
            counts[z.next(&mut rng) as usize] += 1;
        }
        let zeta_n = zeta(n, theta);
        for rank in [0u64, 1, 2, 9, 99] {
            let expect = (1.0 / ((rank + 1) as f64).powf(theta)) / zeta_n;
            let got = counts[rank as usize] as f64 / draws as f64;
            assert!(
                (got - expect).abs() < expect * 0.15 + 0.001,
                "rank {rank}: got {got:.4}, expect {expect:.4}"
            );
        }
    }

    #[test]
    fn skew_head_dominates() {
        let z = Zipfian::new(1_000_000, 0.99);
        let mut rng = Rng::seeded(7);
        let draws = 100_000;
        let head = (0..draws)
            .filter(|_| z.next(&mut rng) < 100)
            .count();
        // With θ=0.99 and 1M items, the top-100 ranks get ~30%+ of draws.
        assert!(
            head as f64 / draws as f64 > 0.25,
            "zipf head too light: {head}/{draws}"
        );
    }

    #[test]
    fn scrambled_spreads_hot_keys() {
        let z = Zipfian::scrambled(1000, 0.99);
        let mut rng = Rng::seeded(9);
        let mut seen = std::collections::HashSet::new();
        for _ in 0..1000 {
            let k = z.next(&mut rng);
            assert!(k < 1000);
            seen.insert(k);
        }
        // Hot ranks map to scattered keys, not a dense prefix.
        let max = *seen.iter().max().unwrap();
        assert!(max > 500, "scramble failed to spread keys: max {max}");
    }

    #[test]
    fn all_in_range() {
        let z = Zipfian::new(64, 0.5);
        let mut rng = Rng::seeded(3);
        for _ in 0..10_000 {
            assert!(z.next(&mut rng) < 64);
        }
    }
}
