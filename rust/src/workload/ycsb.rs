//! YCSB-style operation mixes over the paper's keyspace (§7.2):
//! 10 MB of 64-bit keys (1.25 M + slots) filled to 80 % capacity, with
//! read-only / mixed / write-only distributions over uniform or Zipfian
//! key popularity.

use crate::util::rng::Rng;

use super::zipfian::Zipfian;

/// Key popularity distribution.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KeyDist {
    Uniform,
    /// YCSB-C Zipfian with θ = 0.99.
    Zipfian,
}

impl KeyDist {
    pub fn label(&self) -> &'static str {
        match self {
            KeyDist::Uniform => "uniform",
            KeyDist::Zipfian => "zipfian",
        }
    }
}

/// Operation mix (read fraction).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct OpMix {
    pub read_fraction: f64,
}

impl OpMix {
    pub const READ_ONLY: OpMix = OpMix { read_fraction: 1.0 };
    pub const MIXED_50_50: OpMix = OpMix { read_fraction: 0.5 };
    pub const WRITE_ONLY: OpMix = OpMix { read_fraction: 0.0 };

    pub fn label(&self) -> String {
        if self.read_fraction >= 1.0 {
            "read-only".into()
        } else if self.read_fraction <= 0.0 {
            "write-only".into()
        } else {
            format!("{:.0}/{:.0} r/w", self.read_fraction * 100.0, (1.0 - self.read_fraction) * 100.0)
        }
    }
}

/// Value-size distribution, in words (8 B each). The kvstore's slab
/// allocator serves any length up to the configured class ceiling, so
/// benches can sweep the paper's value-size regimes: `Fixed(1)` is the
/// original single-word workload, `Fixed(128)` the 1 KB point, and
/// `Uniform` the mixed 8 B–1 KB stream.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ValueDist {
    /// Every value exactly `words` long.
    Fixed(usize),
    /// Uniform in `[min_words, max_words]` (inclusive).
    Uniform { min_words: usize, max_words: usize },
}

impl ValueDist {
    /// The 8 B–1 KB mixed stream from the evaluation setup.
    pub const MIXED_8B_1KB: ValueDist = ValueDist::Uniform { min_words: 1, max_words: 128 };

    /// Largest length this distribution can emit (what
    /// `KvConfig::value_words` must be configured to).
    pub fn max_words(&self) -> usize {
        match *self {
            ValueDist::Fixed(w) => w,
            ValueDist::Uniform { max_words, .. } => max_words,
        }
    }

    pub fn sample(&self, rng: &mut Rng) -> usize {
        match *self {
            ValueDist::Fixed(w) => w,
            ValueDist::Uniform { min_words, max_words } => {
                debug_assert!(min_words >= 1 && min_words <= max_words);
                rng.gen_range_incl(min_words as u64, max_words as u64) as usize
            }
        }
    }

    pub fn label(&self) -> String {
        match *self {
            ValueDist::Fixed(w) => format!("{}B", w * 8),
            ValueDist::Uniform { min_words, max_words } => {
                format!("{}B-{}B", min_words * 8, max_words * 8)
            }
        }
    }
}

/// One generated operation. `len` is the update's value length in words
/// (drawn from the generator's [`ValueDist`]); consumers of a
/// fixed-single-word store may ignore it.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Op {
    Read { key: u64 },
    Update { key: u64, value: u64, len: usize },
}

/// Per-thread workload stream. Key universe is `[0, keys)`; the prefill
/// loads `keys * fill` of them, and every generated key stays inside
/// the loaded prefix (the paper measures successful-op throughput).
pub struct WorkloadGen {
    loaded: u64,
    dist: KeyDist,
    mix: OpMix,
    values: ValueDist,
    zipf: Option<Zipfian>,
    rng: Rng,
}

/// The paper's keyspace: 10 MB of 64-bit keys.
pub const PAPER_KEYSPACE: u64 = 10 * 1024 * 1024 / 8;
/// The paper's fill factor.
pub const PAPER_FILL: f64 = 0.8;

impl WorkloadGen {
    pub fn new(keys: u64, dist: KeyDist, mix: OpMix, seed: u64) -> Self {
        Self::with_value_dist(keys, dist, mix, ValueDist::Fixed(1), seed)
    }

    pub fn with_value_dist(
        keys: u64,
        dist: KeyDist,
        mix: OpMix,
        values: ValueDist,
        seed: u64,
    ) -> Self {
        // The generator draws over the LOADED prefix directly. The seed
        // implementation built the Zipfian over the full `keys` space
        // and folded with `% loaded`: that aliased the unloaded tail's
        // probability mass onto arbitrary loaded keys — hot ranks gained
        // phantom weight from tail ranks that happened to collide mod
        // `loaded` — distorting both the skew and the hit-rate of every
        // fig5 number.
        let loaded = (keys as f64 * PAPER_FILL) as u64;
        let zipf = match dist {
            KeyDist::Zipfian => Some(Zipfian::scrambled(loaded, 0.99)),
            KeyDist::Uniform => None,
        };
        WorkloadGen { loaded, dist, mix, values, zipf, rng: Rng::seeded(seed) }
    }

    /// Keys that should be present after prefill (dense prefix keeps the
    /// load factor exact; placement is hashed anyway).
    pub fn prefill_keys(keys: u64, fill: f64) -> impl Iterator<Item = u64> {
        let n = (keys as f64 * fill) as u64;
        0..n
    }

    #[inline]
    pub fn next_key(&mut self) -> u64 {
        match self.dist {
            KeyDist::Uniform => self.rng.gen_range(self.loaded),
            KeyDist::Zipfian => self.zipf.as_ref().unwrap().next(&mut self.rng),
        }
    }

    #[inline]
    pub fn next_op(&mut self) -> Op {
        let key = self.next_key();
        if self.rng.gen_bool(self.mix.read_fraction) {
            Op::Read { key }
        } else {
            let len = self.values.sample(&mut self.rng);
            Op::Update { key, value: self.rng.next_u64(), len }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mix_fractions_respected() {
        let mut g = WorkloadGen::new(1000, KeyDist::Uniform, OpMix { read_fraction: 0.7 }, 1);
        let n = 20_000;
        let reads = (0..n).filter(|_| matches!(g.next_op(), Op::Read { .. })).count();
        let frac = reads as f64 / n as f64;
        assert!((frac - 0.7).abs() < 0.02, "read fraction {frac}");
    }

    #[test]
    fn read_only_and_write_only() {
        let mut r = WorkloadGen::new(100, KeyDist::Uniform, OpMix::READ_ONLY, 2);
        let mut w = WorkloadGen::new(100, KeyDist::Zipfian, OpMix::WRITE_ONLY, 3);
        for _ in 0..100 {
            assert!(matches!(r.next_op(), Op::Read { .. }));
            assert!(matches!(w.next_op(), Op::Update { .. }));
        }
    }

    #[test]
    fn keys_stay_in_loaded_range() {
        let keys = 1000;
        let loaded = (keys as f64 * PAPER_FILL) as u64;
        for dist in [KeyDist::Uniform, KeyDist::Zipfian] {
            let mut g = WorkloadGen::new(keys, dist, OpMix::MIXED_50_50, 4);
            for _ in 0..5000 {
                assert!(g.next_key() < loaded);
            }
        }
    }

    #[test]
    fn prefill_count() {
        let n = WorkloadGen::prefill_keys(1000, 0.8).count();
        assert_eq!(n, 800);
    }

    /// Regression for the fold bug: the Zipfian must be built over the
    /// loaded prefix directly, not over the full keyspace folded with
    /// `% loaded`. Same-seed draws must be bit-identical to a reference
    /// generator over `loaded` ranks.
    #[test]
    fn zipfian_built_over_loaded_not_folded() {
        let keys = 1000u64;
        let loaded = (keys as f64 * PAPER_FILL) as u64; // 800
        let mut g = WorkloadGen::new(keys, KeyDist::Zipfian, OpMix::READ_ONLY, 7);
        let reference = Zipfian::scrambled(loaded, 0.99);
        let mut rng = Rng::seeded(7);
        for i in 0..10_000 {
            assert_eq!(g.next_key(), reference.next(&mut rng), "draw {i} diverged");
        }
    }

    /// Seeded frequency-histogram regression (the satellite test): the
    /// generator's empirical key distribution must match a reference
    /// scrambled Zipfian over the loaded prefix. The fold bug aliased
    /// the unloaded tail's probability mass (ranks ≥ loaded of a
    /// full-keyspace generator) onto arbitrary hot keys — a structural
    /// transplant that total-variation distance catches immediately,
    /// while two correct same-size samples differ only by sampling
    /// noise.
    #[test]
    fn zipfian_frequency_histogram_matches_reference() {
        let keys = 1000u64;
        let loaded = (keys as f64 * PAPER_FILL) as u64;
        let draws = 400_000u64;
        let mut counts = vec![0i64; loaded as usize];
        let mut g = WorkloadGen::new(keys, KeyDist::Zipfian, OpMix::READ_ONLY, 42);
        for _ in 0..draws {
            let k = g.next_key();
            assert!(k < loaded, "key {k} outside the loaded prefix");
            counts[k as usize] += 1;
        }
        // Reference histogram from an independent seed: identical
        // distribution, independent noise.
        let reference = Zipfian::scrambled(loaded, 0.99);
        let mut rng = Rng::seeded(4242);
        let mut ref_counts = vec![0i64; loaded as usize];
        for _ in 0..draws {
            ref_counts[reference.next(&mut rng) as usize] += 1;
        }
        let tv: f64 = counts
            .iter()
            .zip(&ref_counts)
            .map(|(&a, &b)| (a - b).unsigned_abs() as f64)
            .sum::<f64>()
            / (2.0 * draws as f64);
        assert!(tv < 0.08, "key histogram diverged from the zipfian reference: TV {tv:.4}");
    }

    #[test]
    fn value_dist_samples_in_bounds() {
        let mut rng = Rng::seeded(3);
        let d = ValueDist::MIXED_8B_1KB;
        assert_eq!(d.max_words(), 128);
        for _ in 0..10_000 {
            let len = d.sample(&mut rng);
            assert!((1..=128).contains(&len));
        }
        assert_eq!(ValueDist::Fixed(16).sample(&mut rng), 16);
        assert_eq!(ValueDist::Fixed(128).label(), "1024B");

        // next_op threads the sampled length through Op::Update.
        let mut g = WorkloadGen::with_value_dist(
            1000,
            KeyDist::Uniform,
            OpMix::WRITE_ONLY,
            ValueDist::Uniform { min_words: 2, max_words: 9 },
            11,
        );
        for _ in 0..1000 {
            let Op::Update { len, .. } = g.next_op() else { panic!("write-only mix") };
            assert!((2..=9).contains(&len));
        }
    }
}
