//! YCSB-style operation mixes over the paper's keyspace (§7.2):
//! 10 MB of 64-bit keys (1.25 M + slots) filled to 80 % capacity, with
//! read-only / mixed / write-only distributions over uniform or Zipfian
//! key popularity.

use crate::util::rng::Rng;

use super::zipfian::Zipfian;

/// Key popularity distribution.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KeyDist {
    Uniform,
    /// YCSB-C Zipfian with θ = 0.99.
    Zipfian,
}

impl KeyDist {
    pub fn label(&self) -> &'static str {
        match self {
            KeyDist::Uniform => "uniform",
            KeyDist::Zipfian => "zipfian",
        }
    }
}

/// Operation mix (read fraction).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct OpMix {
    pub read_fraction: f64,
}

impl OpMix {
    pub const READ_ONLY: OpMix = OpMix { read_fraction: 1.0 };
    pub const MIXED_50_50: OpMix = OpMix { read_fraction: 0.5 };
    pub const WRITE_ONLY: OpMix = OpMix { read_fraction: 0.0 };

    pub fn label(&self) -> String {
        if self.read_fraction >= 1.0 {
            "read-only".into()
        } else if self.read_fraction <= 0.0 {
            "write-only".into()
        } else {
            format!("{:.0}/{:.0} r/w", self.read_fraction * 100.0, (1.0 - self.read_fraction) * 100.0)
        }
    }
}

/// One generated operation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Op {
    Read { key: u64 },
    Update { key: u64, value: u64 },
}

/// Per-thread workload stream. Key universe is `[0, keys)`; the prefill
/// loads `keys * fill` of them.
pub struct WorkloadGen {
    keys: u64,
    dist: KeyDist,
    mix: OpMix,
    zipf: Option<Zipfian>,
    rng: Rng,
}

/// The paper's keyspace: 10 MB of 64-bit keys.
pub const PAPER_KEYSPACE: u64 = 10 * 1024 * 1024 / 8;
/// The paper's fill factor.
pub const PAPER_FILL: f64 = 0.8;

impl WorkloadGen {
    pub fn new(keys: u64, dist: KeyDist, mix: OpMix, seed: u64) -> Self {
        let zipf = match dist {
            KeyDist::Zipfian => Some(Zipfian::scrambled(keys, 0.99)),
            KeyDist::Uniform => None,
        };
        WorkloadGen { keys, dist, mix, zipf, rng: Rng::seeded(seed) }
    }

    /// Keys that should be present after prefill (dense prefix keeps the
    /// load factor exact; placement is hashed anyway).
    pub fn prefill_keys(keys: u64, fill: f64) -> impl Iterator<Item = u64> {
        let n = (keys as f64 * fill) as u64;
        0..n
    }

    #[inline]
    pub fn next_key(&mut self) -> u64 {
        let loaded = (self.keys as f64 * PAPER_FILL) as u64;
        match self.dist {
            // Restrict to loaded keys so reads hit (the paper measures
            // successful-op throughput).
            KeyDist::Uniform => self.rng.gen_range(loaded),
            KeyDist::Zipfian => {
                let z = self.zipf.as_ref().unwrap();
                z.next(&mut self.rng) % loaded
            }
        }
    }

    #[inline]
    pub fn next_op(&mut self) -> Op {
        let key = self.next_key();
        if self.rng.gen_bool(self.mix.read_fraction) {
            Op::Read { key }
        } else {
            Op::Update { key, value: self.rng.next_u64() }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mix_fractions_respected() {
        let mut g = WorkloadGen::new(1000, KeyDist::Uniform, OpMix { read_fraction: 0.7 }, 1);
        let n = 20_000;
        let reads = (0..n).filter(|_| matches!(g.next_op(), Op::Read { .. })).count();
        let frac = reads as f64 / n as f64;
        assert!((frac - 0.7).abs() < 0.02, "read fraction {frac}");
    }

    #[test]
    fn read_only_and_write_only() {
        let mut r = WorkloadGen::new(100, KeyDist::Uniform, OpMix::READ_ONLY, 2);
        let mut w = WorkloadGen::new(100, KeyDist::Zipfian, OpMix::WRITE_ONLY, 3);
        for _ in 0..100 {
            assert!(matches!(r.next_op(), Op::Read { .. }));
            assert!(matches!(w.next_op(), Op::Update { .. }));
        }
    }

    #[test]
    fn keys_stay_in_loaded_range() {
        let keys = 1000;
        let loaded = (keys as f64 * PAPER_FILL) as u64;
        for dist in [KeyDist::Uniform, KeyDist::Zipfian] {
            let mut g = WorkloadGen::new(keys, dist, OpMix::MIXED_50_50, 4);
            for _ in 0..5000 {
                assert!(g.next_key() < loaded);
            }
        }
    }

    #[test]
    fn prefill_count() {
        let n = WorkloadGen::prefill_keys(1000, 0.8).count();
        assert_eq!(n, 800);
    }
}
