//! Measurement utilities for the benchmark harness: latency histograms,
//! throughput accounting, and the table printer used by every `fig*`
//! bench to emit the paper's rows.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Log-linear latency histogram (HdrHistogram-style): 2^k major buckets,
/// 16 linear sub-buckets each. Records nanoseconds.
pub struct Histogram {
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum: AtomicU64,
    max: AtomicU64,
}

const SUB: usize = 16;
const MAJORS: usize = 40;

impl Histogram {
    pub fn new() -> Self {
        Histogram {
            buckets: (0..MAJORS * SUB).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            max: AtomicU64::new(0),
        }
    }

    fn index(ns: u64) -> usize {
        if ns < SUB as u64 {
            return ns as usize;
        }
        let major = 63 - ns.leading_zeros() as usize; // floor(log2)
        let shift = major.saturating_sub(4);
        let sub = ((ns >> shift) & (SUB as u64 - 1)) as usize;
        let idx = (major - 3) * SUB + sub;
        idx.min(MAJORS * SUB - 1)
    }

    /// Lower bound of bucket `idx` in ns (inverse of `index`).
    fn bucket_floor(idx: usize) -> u64 {
        let major = idx / SUB + 3;
        let sub = (idx % SUB) as u64;
        if major == 3 {
            return sub;
        }
        let shift = major - 4;
        ((SUB as u64) << shift) + (sub << shift)
    }

    pub fn record(&self, ns: u64) {
        self.buckets[Self::index(ns)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(ns, Ordering::Relaxed);
        self.max.fetch_max(ns, Ordering::Relaxed);
    }

    pub fn record_duration(&self, d: Duration) {
        self.record(d.as_nanos() as u64);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn mean_ns(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            0.0
        } else {
            self.sum.load(Ordering::Relaxed) as f64 / c as f64
        }
    }

    pub fn max_ns(&self) -> u64 {
        self.max.load(Ordering::Relaxed)
    }

    /// Approximate percentile (bucket floor), p in [0, 100].
    pub fn percentile_ns(&self, p: f64) -> u64 {
        let total = self.count();
        if total == 0 {
            return 0;
        }
        let target = ((p / 100.0) * total as f64).ceil() as u64;
        let mut seen = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                return Self::bucket_floor(i);
            }
        }
        self.max_ns()
    }

    pub fn merge_from(&self, other: &Histogram) {
        for (a, b) in self.buckets.iter().zip(&other.buckets) {
            a.fetch_add(b.load(Ordering::Relaxed), Ordering::Relaxed);
        }
        self.count.fetch_add(other.count(), Ordering::Relaxed);
        self.sum.fetch_add(other.sum.load(Ordering::Relaxed), Ordering::Relaxed);
        self.max.fetch_max(other.max_ns(), Ordering::Relaxed);
    }
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

/// Geometric mean — the paper reports geomeans of 5 runs.
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let s: f64 = xs.iter().map(|x| x.max(1e-12).ln()).sum();
    (s / xs.len() as f64).exp()
}

/// Throughput helper: ops and wall time → Mops/s.
pub fn mops(ops: u64, elapsed: Duration) -> f64 {
    ops as f64 / elapsed.as_secs_f64() / 1e6
}

/// Fixed-width table printer for bench output (the repo's replacement
/// for criterion's reports; every fig* bench prints paper-shaped rows).
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells.to_vec());
    }

    pub fn print(&self) {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (w, c) in widths.iter_mut().zip(row) {
                *w = (*w).max(c.len());
            }
        }
        let line = |cells: &[String]| {
            let cols: Vec<String> = cells
                .iter()
                .zip(&widths)
                .map(|(c, w)| format!("{c:>w$}", w = w))
                .collect();
            println!("  {}", cols.join("  "));
        };
        line(&self.headers);
        let total: usize = widths.iter().sum::<usize>() + 2 * widths.len();
        println!("  {}", "-".repeat(total));
        for row in &self.rows {
            line(row);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_percentiles_ordered() {
        let h = Histogram::new();
        for i in 1..=10_000u64 {
            h.record(i * 100);
        }
        assert_eq!(h.count(), 10_000);
        let p50 = h.percentile_ns(50.0);
        let p99 = h.percentile_ns(99.0);
        assert!(p50 < p99, "p50 {p50} >= p99 {p99}");
        // p50 of uniform 100..=1_000_000 is ~500_000 (bucket resolution ~6%).
        assert!((400_000..600_000).contains(&p50), "p50 {p50}");
        let mean = h.mean_ns();
        assert!((450_000.0..550_000.0).contains(&mean), "mean {mean}");
        assert_eq!(h.max_ns(), 1_000_000);
    }

    #[test]
    fn histogram_small_values_exact() {
        let h = Histogram::new();
        for v in [0u64, 1, 5, 15] {
            h.record(v);
        }
        assert_eq!(h.count(), 4);
        assert!(h.percentile_ns(1.0) <= 1);
    }

    #[test]
    fn merge() {
        let a = Histogram::new();
        let b = Histogram::new();
        a.record(100);
        b.record(1000);
        a.merge_from(&b);
        assert_eq!(a.count(), 2);
        assert_eq!(a.max_ns(), 1000);
    }

    #[test]
    fn geomean_basics() {
        assert!((geomean(&[2.0, 8.0]) - 4.0).abs() < 1e-9);
        assert_eq!(geomean(&[]), 0.0);
    }

    #[test]
    fn mops_math() {
        assert!((mops(2_000_000, Duration::from_secs(2)) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn table_prints() {
        let mut t = Table::new(&["nodes", "mops"]);
        t.row(&["2".into(), "1.5".into()]);
        t.print(); // smoke: no panic
    }
}
