//! `owned_var`: single-writer multi-reader register (paper §5.1.1).
//!
//! One participant (the *owner*) holds the authoritative copy; every
//! participant holds a cached copy. Updates propagate either by the owner
//! **push**ing to all caches (remote writes) or by readers **pull**ing
//! from the authoritative copy (remote read) — higher-level channels pick
//! the strategy.
//!
//! Atomicity follows the paper exactly:
//! * values of one word: aligned access is inherently atomic;
//! * larger values: a trailing FNV-1a checksum is stored with the value
//!   and readers retry on mismatch (torn placement is routine on the
//!   simulated fabric, see `fabric::nic`).

use std::sync::Arc;
use std::time::Duration;

use crate::core::ack::AckKey;
use crate::core::ctx::ThreadCtx;
use crate::core::endpoint::{region_name, Endpoint, Expect};
use crate::core::manager::Manager;
use crate::fabric::{NodeId, Region};
use crate::util::{fnv64, Backoff};

/// Single-writer multi-reader register (paper §5.1.1).
///
/// # Examples
///
/// ```
/// use std::time::Duration;
/// use loco::channels::OwnedVar;
/// use loco::core::manager::Manager;
/// use loco::fabric::{Cluster, FabricConfig};
///
/// let cluster = Cluster::new(2, FabricConfig::inline_ideal());
/// let m0 = Manager::new(cluster.clone(), 0);
/// let m1 = Manager::new(cluster.clone(), 1);
/// // Same name/owner/width on every participating node.
/// let v0 = OwnedVar::new(&m0, "ov", 0, 1, false);
/// let v1 = OwnedVar::new(&m1, "ov", 0, 1, false);
/// v0.wait_ready(Duration::from_secs(10));
/// v1.wait_ready(Duration::from_secs(10));
///
/// let ctx0 = m0.ctx();
/// v0.publish(&ctx0, &[42]).wait(); // owner stores + pushes to all caches
/// let ctx1 = m1.ctx();
/// assert_eq!(v1.read_cached(&ctx1), vec![42]); // reader hits its cache
/// assert_eq!(v1.pull(&ctx1), vec![42]); // or pulls the owner's copy
/// ```
pub struct OwnedVar {
    ep: Arc<Endpoint>,
    me: NodeId,
    owner: NodeId,
    /// Value width in words (excluding the checksum slot).
    words: usize,
    /// Slot width: words (+1 checksum when words > 1).
    slot: usize,
    /// Authoritative copy (owner only).
    own: Option<Region>,
    /// Local cached copy (all participants).
    cache: Region,
    num_nodes: usize,
}

impl OwnedVar {
    /// Construct the local endpoint. Every participating node calls this
    /// with the same `name`, `owner`, and `words`.
    ///
    /// Regions: the owner allocates `"<name>.own"`; everyone allocates
    /// `"<name>.cache"`. `device` places the owner copy in NIC device
    /// memory (useful for synchronization-only state, App. A.2).
    pub fn new(mgr: &Arc<Manager>, name: &str, owner: NodeId, words: usize, device: bool) -> Self {
        assert!(words >= 1);
        let me = mgr.me();
        let slot = if words > 1 { words + 1 } else { 1 };
        let ep = Endpoint::new(name, me, mgr.num_nodes(), Expect::AllPeers);
        let own = if me == owner {
            let r = mgr.pool().alloc_named(&region_name(name, "own"), slot, device);
            if words > 1 {
                // Seed the checksum of the all-zero initial value: a
                // never-pushed row must still validate (readers
                // checksum-retry forever on a slot whose stored checksum
                // can never match its contents).
                mgr.cluster().node(me).arena().store(r.at(words as u64), fnv64(&vec![0u64; words]));
            }
            ep.add_local_region("own", r);
            Some(r)
        } else {
            None
        };
        let cache = mgr.pool().alloc_named(&region_name(name, "cache"), slot, false);
        if words > 1 {
            mgr.cluster().node(me).arena().store(cache.at(words as u64), fnv64(&vec![0u64; words]));
        }
        ep.add_local_region("cache", cache);
        mgr.register_channel(ep.clone());
        OwnedVar { ep, me, owner, words, slot, own, cache, num_nodes: mgr.num_nodes() }
    }

    pub fn wait_ready(&self, timeout: Duration) {
        self.ep.wait_ready(timeout);
    }

    /// Non-blocking readiness probe (simulator services).
    pub fn is_ready(&self) -> bool {
        self.ep.is_ready()
    }

    pub fn owner(&self) -> NodeId {
        self.owner
    }

    pub fn words(&self) -> usize {
        self.words
    }

    pub fn endpoint(&self) -> &Arc<Endpoint> {
        &self.ep
    }

    fn encode(&self, value: &[u64]) -> Vec<u64> {
        assert_eq!(value.len(), self.words, "owned_var value width mismatch");
        let mut buf = Vec::with_capacity(self.slot);
        buf.extend_from_slice(value);
        if self.words > 1 {
            buf.push(fnv64(value));
        }
        buf
    }

    /// Owner: store a new value into the authoritative copy (local).
    pub fn store_local(&self, ctx: &ThreadCtx, value: &[u64]) {
        let own = self.own.expect("store_local called on non-owner endpoint");
        let buf = self.encode(value);
        // Checksum first, then data? No: the authoritative copy is only
        // read remotely (pull), and remote READs can tear too — readers
        // validate. Write data then checksum in one local pass.
        for (i, w) in buf.iter().enumerate() {
            ctx.local_store(own, i as u64, *w);
        }
    }

    /// Owner: push the authoritative value to one peer's cache.
    pub fn push_to(&self, ctx: &ThreadCtx, peer: NodeId) -> AckKey {
        assert_eq!(self.me, self.owner, "push from non-owner");
        let own = self.own.unwrap();
        let mut buf = vec![0u64; self.slot];
        for (i, b) in buf.iter_mut().enumerate() {
            *b = ctx.local_load(own, i as u64);
        }
        let cache = self.ep.remote_region(peer, "cache");
        ctx.write(cache, 0, &buf)
    }

    /// Owner: push to all peers; returns the unioned ack_key (§5.2).
    /// Rides the batched write pipeline: the authoritative copy is read
    /// once, ack allocation is amortized across all peers, and each
    /// peer's write goes out under its own (single) doorbell.
    pub fn push_broadcast(&self, ctx: &ThreadCtx) -> AckKey {
        assert_eq!(self.me, self.owner, "push from non-owner");
        let own = self.own.unwrap();
        let mut buf = vec![0u64; self.slot];
        for (i, b) in buf.iter_mut().enumerate() {
            *b = ctx.local_load(own, i as u64);
        }
        let caches: Vec<Region> = (0..self.num_nodes as NodeId)
            .filter(|&peer| peer != self.me)
            .map(|peer| self.ep.remote_region(peer, "cache"))
            .collect();
        let writes: Vec<(Region, u64, &[u64])> =
            caches.iter().map(|&cache| (cache, 0, buf.as_slice())).collect();
        ctx.write_many(&writes)
    }

    /// Convenience: store + broadcast in one call.
    pub fn publish(&self, ctx: &ThreadCtx, value: &[u64]) -> AckKey {
        self.store_local(ctx, value);
        self.push_broadcast(ctx)
    }

    /// Any participant: read the locally cached copy (checksum-validated
    /// with retry for >1-word values).
    pub fn read_cached(&self, ctx: &ThreadCtx) -> Vec<u64> {
        let mut bo = Backoff::new();
        loop {
            let mut buf = vec![0u64; self.slot];
            for (i, b) in buf.iter_mut().enumerate() {
                *b = ctx.local_load(self.cache, i as u64);
            }
            if self.words == 1 {
                buf.truncate(1);
                return buf;
            }
            let (value, ck) = buf.split_at(self.words);
            if fnv64(value) == ck[0] {
                return value.to_vec();
            }
            bo.snooze();
        }
    }

    /// Single-word cached read.
    pub fn read_cached1(&self, ctx: &ThreadCtx) -> u64 {
        debug_assert_eq!(self.words, 1);
        ctx.local_load(self.cache, 0)
    }

    /// Any participant: pull the authoritative copy from the owner
    /// (remote read + checksum retry), refreshing the local cache.
    pub fn pull(&self, ctx: &ThreadCtx) -> Vec<u64> {
        if self.me == self.owner {
            return self.read_own(ctx);
        }
        let own = self.ep.remote_region(self.owner, "own");
        let mut bo = Backoff::new();
        loop {
            let buf = ctx.read(own, 0, self.slot);
            if self.words == 1 {
                ctx.local_store(self.cache, 0, buf[0]);
                return buf.to_vec();
            }
            let (value, ck) = buf.split_at(self.words);
            if fnv64(value) == ck[0] {
                for (i, w) in buf.iter().enumerate() {
                    ctx.local_store(self.cache, i as u64, *w);
                }
                return value.to_vec();
            }
            bo.snooze();
        }
    }

    fn read_own(&self, ctx: &ThreadCtx) -> Vec<u64> {
        let own = self.own.unwrap();
        let mut out = vec![0u64; self.words];
        for (i, o) in out.iter_mut().enumerate() {
            *o = ctx.local_load(own, i as u64);
        }
        out
    }

    /// The owner-side region (for channels that need raw access).
    pub fn own_region(&self) -> Option<Region> {
        self.own
    }

    pub fn cache_region(&self) -> Region {
        self.cache
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::{Cluster, FabricConfig, LatencyModel};

    fn setup(n: usize, cfg: FabricConfig) -> (Arc<Cluster>, Vec<Arc<Manager>>) {
        let cluster = Cluster::new(n, cfg);
        let mgrs = (0..n as NodeId).map(|i| Manager::new(cluster.clone(), i)).collect();
        (cluster, mgrs)
    }

    #[test]
    fn push_and_cached_read_word() {
        let (_c, mgrs) = setup(3, FabricConfig::inline_ideal());
        let vars: Vec<OwnedVar> =
            mgrs.iter().map(|m| OwnedVar::new(m, "ov", 0, 1, false)).collect();
        for v in &vars {
            v.wait_ready(Duration::from_secs(5));
        }
        let ctx0 = mgrs[0].ctx();
        vars[0].publish(&ctx0, &[42]).wait();
        let ctx1 = mgrs[1].ctx();
        let ctx2 = mgrs[2].ctx();
        assert_eq!(vars[1].read_cached(&ctx1), vec![42]);
        assert_eq!(vars[2].read_cached1(&ctx2), 42);
    }

    #[test]
    fn pull_from_owner_multiword() {
        let (_c, mgrs) = setup(2, FabricConfig::inline_ideal());
        let vars: Vec<OwnedVar> =
            mgrs.iter().map(|m| OwnedVar::new(m, "big", 1, 4, false)).collect();
        for v in &vars {
            v.wait_ready(Duration::from_secs(5));
        }
        let ctx1 = mgrs[1].ctx();
        vars[1].store_local(&ctx1, &[10, 20, 30, 40]);
        let ctx0 = mgrs[0].ctx();
        assert_eq!(vars[0].pull(&ctx0), vec![10, 20, 30, 40]);
        // Pull refreshed the cache.
        assert_eq!(vars[0].read_cached(&ctx0), vec![10, 20, 30, 40]);
    }

    /// Under chaotic placement, cached reads of multi-word values must
    /// never observe a torn value (checksum catches and retries).
    #[test]
    fn no_torn_reads_under_chaos() {
        let mut lat = LatencyModel::ideal();
        lat.placement_lag_ns = 2_000;
        let (_c, mgrs) = setup(2, FabricConfig::threaded(lat).chaotic());
        let vars: Vec<Arc<OwnedVar>> = mgrs
            .iter()
            .map(|m| Arc::new(OwnedVar::new(m, "chaos", 0, 8, false)))
            .collect();
        for v in &vars {
            v.wait_ready(Duration::from_secs(5));
        }

        let writer_mgr = mgrs[0].clone();
        let writer_var = vars[0].clone();
        let w = std::thread::spawn(move || {
            let ctx = writer_mgr.ctx();
            for round in 1..=300u64 {
                let val = [round; 8];
                writer_var.publish(&ctx, &val).wait();
            }
        });
        let reader_mgr = mgrs[1].clone();
        let reader_var = vars[1].clone();
        let r = std::thread::spawn(move || {
            let ctx = reader_mgr.ctx();
            for _ in 0..2000 {
                let v = reader_var.read_cached(&ctx);
                // All 8 words must agree — torn values are retried away.
                assert!(v.iter().all(|&x| x == v[0]), "torn read escaped checksum: {v:?}");
            }
        });
        w.join().unwrap();
        r.join().unwrap();
    }

    #[test]
    #[should_panic(expected = "non-owner")]
    fn non_owner_push_panics() {
        let (_c, mgrs) = setup(2, FabricConfig::inline_ideal());
        let _v0 = OwnedVar::new(&mgrs[0], "ov", 0, 1, false);
        let v1 = OwnedVar::new(&mgrs[1], "ov", 0, 1, false);
        v1.wait_ready(Duration::from_secs(5));
        let ctx1 = mgrs[1].ctx();
        v1.push_to(&ctx1, 0);
    }
}
