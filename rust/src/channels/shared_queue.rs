//! The shared queue: a globally consistent MPMC FIFO (paper §5.4).
//!
//! All participants can push and pop; each pop corresponds to exactly
//! one push. Head and tail indices are [`AtomicVar`]s; the entry array is
//! **striped across participants** (slot *s* lives on node *s mod N*).
//! The algorithm adapts the shared-memory cyclic ring queue [43] to
//! RDMA: a pusher claims a slot with a remote FAA on `tail`, writes the
//! payload, then publishes a per-slot sequence word — the payload write
//! and the sequence write share a QP, so same-QP placement ordering
//! guarantees the payload is visible before the sequence says so.
//!
//! Slot lifecycle (bounded queue of `Q` slots, sequence word per slot):
//! * initially `seq[s] = s`;
//! * push with ticket `t` waits for `seq == t`, fills, sets `seq = t+1`;
//! * pop  with ticket `h` waits for `seq == h+1`, drains, sets `seq = h+Q`.

use std::sync::Arc;
use std::time::Duration;

use crate::core::ctx::ThreadCtx;
use crate::core::endpoint::{region_name, sub_name, Endpoint, Expect};
use crate::core::manager::Manager;
use crate::fabric::{NodeId, Region};
use crate::util::Backoff;

use super::atomic_var::AtomicVar;

/// Globally consistent MPMC FIFO queue, striped across participants
/// (paper §5.4).
///
/// # Examples
///
/// ```
/// use std::time::Duration;
/// use loco::channels::SharedQueue;
/// use loco::core::manager::Manager;
/// use loco::fabric::{Cluster, FabricConfig};
///
/// let cluster = Cluster::new(2, FabricConfig::inline_ideal());
/// let m0 = Manager::new(cluster.clone(), 0);
/// let m1 = Manager::new(cluster.clone(), 1);
/// let q0 = SharedQueue::new(&m0, "q", 8, 2); // 8 slots, 2-word entries
/// let q1 = SharedQueue::new(&m1, "q", 8, 2);
/// q0.wait_ready(Duration::from_secs(10));
/// q1.wait_ready(Duration::from_secs(10));
///
/// let ctx0 = m0.ctx();
/// q0.push(&ctx0, &[7, 8]);
/// let ctx1 = m1.ctx();
/// assert_eq!(q1.pop(&ctx1), vec![7, 8]); // global FIFO, exactly-once
/// ```
pub struct SharedQueue {
    ep: Arc<Endpoint>,
    head: AtomicVar,
    tail: AtomicVar,
    me: NodeId,
    num_nodes: usize,
    /// Total slots (multiple of num_nodes).
    slots: u64,
    /// Payload words per entry.
    entry_words: usize,
    /// This node's stripe of slots.
    local: Region,
}

impl SharedQueue {
    /// `slots` is rounded up to a multiple of the node count; every node
    /// must construct the endpoint with identical parameters.
    pub fn new(mgr: &Arc<Manager>, name: &str, slots: u64, entry_words: usize) -> Self {
        let n = mgr.num_nodes() as u64;
        let slots = slots.div_ceil(n) * n;
        let per_node = slots / n;
        let slot_words = entry_words as u64 + 1; // [seq][payload]
        let me = mgr.me();

        let ep = Endpoint::new(name, me, mgr.num_nodes(), Expect::AllPeers);
        let local = mgr
            .pool()
            .alloc_named(&region_name(name, "slots"), (per_node * slot_words) as usize, false);
        // Initialize our stripe's sequence words BEFORE announcing the
        // region (peers can only access after our connect metadata).
        let arena = mgr.cluster().node(me).arena();
        for k in 0..per_node {
            let s = k * n + me as u64; // global slot index of local slot k
            arena.store(local.at(k * slot_words), s);
        }
        ep.add_local_region("slots", local);
        ep.expect_regions(&["slots"]);
        mgr.register_channel(ep.clone());

        let head = AtomicVar::with_initial(mgr, &sub_name(name, "head"), 0, false, 0);
        let tail = AtomicVar::with_initial(mgr, &sub_name(name, "tail"), 0, false, 0);
        SharedQueue {
            ep,
            head,
            tail,
            me,
            num_nodes: mgr.num_nodes(),
            slots,
            entry_words,
            local,
        }
    }

    pub fn wait_ready(&self, timeout: Duration) {
        self.ep.wait_ready(timeout);
        self.head.wait_ready(timeout);
        self.tail.wait_ready(timeout);
    }

    fn slot_words(&self) -> u64 {
        self.entry_words as u64 + 1
    }

    /// (region, word offset) of global slot `s`.
    fn slot_region(&self, s: u64) -> (Region, u64) {
        let node = (s % self.num_nodes as u64) as NodeId;
        let k = s / self.num_nodes as u64;
        let region = if node == self.me {
            self.local
        } else {
            self.ep.remote_region(node, "slots")
        };
        (region, k * self.slot_words())
    }

    /// Push an entry (blocking while the queue is full).
    pub fn push(&self, ctx: &ThreadCtx, payload: &[u64]) {
        self.try_push(ctx, payload).expect("shared_queue push failed");
    }

    /// Crash-stop-aware push with a bounded wait: a crashed index host
    /// or slot home surfaces as `Err(Error::PeerFailed)` (the queue has
    /// permanently lost a stripe — FIFO cannot be preserved by skipping
    /// it), and a slot that never frees within 30 s returns
    /// `Err(Error::Timeout)` instead of spinning forever.
    pub fn try_push(&self, ctx: &ThreadCtx, payload: &[u64]) -> crate::Result<()> {
        assert_eq!(payload.len(), self.entry_words, "entry width mismatch");
        let t = self.tail.try_fetch_add(ctx, 1)?;
        let slot = t % self.slots;
        let (region, off) = self.slot_region(slot);
        // Wait for the slot to be free for round t.
        let mut budget = crate::util::WaitBudget::wedge(Duration::from_secs(30));
        let mut bo = Backoff::new();
        loop {
            if ctx.try_read(region, off, 1)?[0] == t {
                break;
            }
            if budget.expired() {
                return Err(crate::Error::Timeout(format!(
                    "shared_queue push: slot {slot} never freed"
                )));
            }
            bo.snooze();
        }
        // Payload first, then sequence word: same QP → placed in order.
        ctx.write_unsignaled(region, off + 1, payload);
        ctx.write1(region, off, t + 1).wait_result()
    }

    /// Pop the next entry (blocking while the queue is empty).
    pub fn pop(&self, ctx: &ThreadCtx) -> Vec<u64> {
        self.try_pop(ctx).expect("shared_queue pop failed")
    }

    /// Crash-stop-aware pop with a bounded (30 s) wait; see
    /// [`SharedQueue::try_push`] for the failure contract.
    pub fn try_pop(&self, ctx: &ThreadCtx) -> crate::Result<Vec<u64>> {
        let h = self.head.try_fetch_add(ctx, 1)?;
        let slot = h % self.slots;
        let (region, off) = self.slot_region(slot);
        let mut budget = crate::util::WaitBudget::wedge(Duration::from_secs(30));
        let mut bo = Backoff::new();
        loop {
            // One read covers [seq][payload]; the payload was placed
            // before seq became h+1 (same-QP ordering on the pusher).
            let words = ctx.try_read(region, off, self.slot_words() as usize)?;
            if words[0] == h + 1 {
                // Free the slot for round h+Q.
                ctx.write1(region, off, h + self.slots).wait_result()?;
                return Ok(words[1..].to_vec());
            }
            if budget.expired() {
                return Err(crate::Error::Timeout(format!(
                    "shared_queue pop: slot {slot} never published"
                )));
            }
            bo.snooze();
        }
    }

    /// Approximate occupancy (racy; for monitoring).
    pub fn len_approx(&self, ctx: &ThreadCtx) -> u64 {
        let t = self.tail.load(ctx);
        let h = self.head.load(ctx);
        t.saturating_sub(h)
    }

    pub fn capacity(&self) -> u64 {
        self.slots
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::{Cluster, FabricConfig, LatencyModel};
    use std::collections::HashSet;

    #[test]
    fn fifo_single_node() {
        let cluster = Cluster::new(2, FabricConfig::inline_ideal());
        let mgrs: Vec<Arc<Manager>> =
            (0..2).map(|i| Manager::new(cluster.clone(), i)).collect();
        let qs: Vec<SharedQueue> =
            mgrs.iter().map(|m| SharedQueue::new(m, "q", 8, 2)).collect();
        for q in &qs {
            q.wait_ready(Duration::from_secs(10));
        }
        let ctx = mgrs[0].ctx();
        for i in 0..20u64 {
            qs[0].push(&ctx, &[i, i * i]);
            // Wraps the 8-slot ring repeatedly.
            let v = qs[0].pop(&ctx);
            assert_eq!(v, vec![i, i * i]);
        }
    }

    #[test]
    fn cross_node_push_pop() {
        let cluster = Cluster::new(3, FabricConfig::inline_ideal());
        let mgrs: Vec<Arc<Manager>> =
            (0..3).map(|i| Manager::new(cluster.clone(), i)).collect();
        let qs: Vec<SharedQueue> =
            mgrs.iter().map(|m| SharedQueue::new(m, "q", 9, 1)).collect();
        for q in &qs {
            q.wait_ready(Duration::from_secs(10));
        }
        let ctx0 = mgrs[0].ctx();
        let ctx1 = mgrs[1].ctx();
        let ctx2 = mgrs[2].ctx();
        qs[0].push(&ctx0, &[111]);
        qs[1].push(&ctx1, &[222]);
        assert_eq!(qs[2].pop(&ctx2), vec![111], "global FIFO order");
        assert_eq!(qs[2].pop(&ctx2), vec![222]);
    }

    /// A crashed stripe host bounds the wait: try_push/try_pop return
    /// PeerFailed once they touch a slot homed on the dead node, instead
    /// of spinning forever.
    #[test]
    fn crash_bounds_queue_waits() {
        let cluster = Cluster::new(2, FabricConfig::inline_ideal());
        let mgrs: Vec<Arc<Manager>> =
            (0..2).map(|i| Manager::new(cluster.clone(), i)).collect();
        let qs: Vec<SharedQueue> =
            mgrs.iter().map(|m| SharedQueue::new(m, "q", 8, 1)).collect();
        for q in &qs {
            q.wait_ready(Duration::from_secs(10));
        }
        let ctx0 = mgrs[0].ctx();
        qs[0].try_push(&ctx0, &[1]).unwrap();
        assert_eq!(qs[0].try_pop(&ctx0).unwrap(), vec![1]);

        cluster.crash(1);
        // Slots are striped (slot s lives on node s mod 2), so within two
        // pushes one must land on the dead node and fail fast.
        let mut failed = false;
        for i in 0..4u64 {
            if matches!(qs[0].try_push(&ctx0, &[i]), Err(crate::Error::PeerFailed(_))) {
                failed = true;
                break;
            }
        }
        assert!(failed, "push never observed the dead stripe");
    }

    /// Each pop corresponds to exactly one push (paper's invariant),
    /// under concurrent producers/consumers on a racy threaded fabric.
    #[test]
    fn exactly_once_concurrent() {
        let nodes = 3;
        let per_node = 60u64;
        let cluster =
            Cluster::new(nodes, FabricConfig::threaded(LatencyModel::fast_sim()));
        let mgrs: Vec<Arc<Manager>> =
            (0..nodes as NodeId).map(|i| Manager::new(cluster.clone(), i)).collect();
        let qs: Vec<Arc<SharedQueue>> = mgrs
            .iter()
            .map(|m| Arc::new(SharedQueue::new(m, "q", 12, 1)))
            .collect();
        for q in &qs {
            q.wait_ready(Duration::from_secs(10));
        }
        let mut handles = Vec::new();
        // Producers: node i pushes values i*10_000 + j.
        for (i, (m, q)) in mgrs.iter().zip(&qs).enumerate() {
            let m = m.clone();
            let q = q.clone();
            handles.push(std::thread::spawn(move || {
                let ctx = m.ctx();
                for j in 0..per_node {
                    q.push(&ctx, &[i as u64 * 10_000 + j]);
                }
                Vec::new()
            }));
        }
        // Consumers: each node pops per_node entries.
        for (m, q) in mgrs.iter().zip(&qs) {
            let m = m.clone();
            let q = q.clone();
            handles.push(std::thread::spawn(move || {
                let ctx = m.ctx();
                (0..per_node).map(|_| q.pop(&ctx)[0]).collect::<Vec<u64>>()
            }));
        }
        let mut popped = Vec::new();
        for h in handles {
            popped.extend(h.join().unwrap());
        }
        assert_eq!(popped.len() as u64, nodes as u64 * per_node);
        let set: HashSet<u64> = popped.iter().copied().collect();
        assert_eq!(set.len(), popped.len(), "duplicate pop detected");
        for i in 0..nodes as u64 {
            for j in 0..per_node {
                assert!(set.contains(&(i * 10_000 + j)), "lost push {i}:{j}");
            }
        }
    }
}
