//! The ringbuffer: an asynchronous one-to-many broadcast channel
//! (paper §5.4; similar to the buffer in FaRM [22]).
//!
//! One participant (the *sender*) owns the channel; every other
//! participant allocates a ring of network memory that the sender writes
//! messages into. Messages are **mixed-size**; atomicity uses a custom
//! mechanism: each message is framed as
//!
//! ```text
//!   [ hdr = seq<<32 | len ][ payload … len words ][ tail = fnv64(hdr‖payload) ]
//! ```
//!
//! The receiver knows the `seq` it expects next, so stale ring contents
//! never validate; a partially placed message fails the tail checksum and
//! is simply retried. Buffer reuse is governed by receiver
//! acknowledgements carried on an SST sub-channel (`"<name>/ack"`): each
//! receiver publishes its cumulative consumed-words counter, and the
//! sender blocks while any receiver's ring lacks space.

use std::cell::Cell;
use std::sync::Arc;
use std::time::Duration;

use crate::core::ack::AckKey;
use crate::core::ctx::ThreadCtx;
use crate::core::endpoint::{region_name, sub_name, Endpoint, Expect};
use crate::core::manager::{Manager, Membership};
use crate::fabric::{NodeId, Region};
use crate::util::{fnv64, Backoff};

use super::sst::Sst;

/// `len` value marking a wrap-to-start filler record.
const WRAP: u64 = 0xFFFF_FFFF;

fn hdr(seq: u64, len: u64) -> u64 {
    ((seq & 0xFFFF_FFFF) << 32) | (len & 0xFFFF_FFFF)
}

fn hdr_seq(h: u64) -> u64 {
    h >> 32
}

fn hdr_len(h: u64) -> u64 {
    h & 0xFFFF_FFFF
}

/// Sender endpoint.
///
/// # Examples
///
/// ```
/// use std::time::Duration;
/// use loco::channels::{RingReceiver, RingSender};
/// use loco::core::manager::Manager;
/// use loco::fabric::{Cluster, FabricConfig};
///
/// let cluster = Cluster::new(2, FabricConfig::inline_ideal());
/// let m0 = Manager::new(cluster.clone(), 0);
/// let m1 = Manager::new(cluster.clone(), 1);
/// let tx = RingSender::new(&m0, "rb", 64); // node 0 broadcasts
/// let rx = RingReceiver::new(&m1, "rb", 64); // node 1 receives
/// tx.wait_ready(Duration::from_secs(10));
/// rx.wait_ready(Duration::from_secs(10));
///
/// let ctx0 = m0.ctx();
/// tx.send(&ctx0, &[1, 2, 3]); // mixed sizes are fine
/// tx.send(&ctx0, &[4]);
/// let ctx1 = m1.ctx();
/// assert_eq!(rx.recv(&ctx1), vec![1, 2, 3]); // in-order delivery
/// assert_eq!(rx.recv(&ctx1), vec![4]);
/// ```
pub struct RingSender {
    ep: Arc<Endpoint>,
    ack: Sst,
    me: NodeId,
    capacity: u64,
    /// Cumulative words written.
    head: Cell<u64>,
    seq: Cell<u64>,
    num_nodes: usize,
    /// Membership view for the skip-dead-peer ack paths: a crashed
    /// receiver stops publishing consumed-words acks forever, and
    /// without this the sender would block on it indefinitely.
    membership: Arc<Membership>,
}

impl RingSender {
    pub fn new(mgr: &Arc<Manager>, name: &str, capacity_words: u64) -> Self {
        let me = mgr.me();
        let ep = Endpoint::new(name, me, mgr.num_nodes(), Expect::AllPeers);
        ep.expect_regions(&["ring"]);
        mgr.register_channel(ep.clone());
        let ack = Sst::new(mgr, &sub_name(name, "ack"), 1);
        RingSender {
            ep,
            ack,
            me,
            capacity: capacity_words,
            head: Cell::new(0),
            seq: Cell::new(0),
            num_nodes: mgr.num_nodes(),
            membership: mgr.membership().clone(),
        }
    }

    pub fn wait_ready(&self, timeout: Duration) {
        self.ep.wait_ready(timeout);
        self.ack.wait_ready(timeout);
    }

    /// Non-blocking readiness probe (used by simulator services, which
    /// must never block the single scheduler thread).
    pub fn is_ready(&self) -> bool {
        self.ep.is_ready() && self.ack.is_ready()
    }

    fn receivers(&self) -> impl Iterator<Item = NodeId> + '_ {
        (0..self.num_nodes as NodeId).filter(move |&p| p != self.me)
    }

    /// Words consumed by the slowest **live** receiver (from the ack
    /// SST). Crash-stopped receivers are skipped — they will never ack
    /// again, and their rings no longer exist to overflow. `None` when
    /// no live receiver remains.
    fn min_consumed(&self, ctx: &ThreadCtx) -> Option<u64> {
        self.receivers()
            .filter(|r| !self.membership.is_dead(*r))
            .map(|r| self.ack.read_row1(ctx, r))
            .min()
    }

    /// Broadcast `payload` to every receiver. Blocks while any ring is
    /// full. Returns the unioned completion key of the remote writes.
    ///
    /// Rides the batched write pipeline: one `write_many` covers every
    /// receiver (ack allocation amortized, one doorbell per peer), and
    /// the frame write is **inline** whenever it fits the device's
    /// inline cap (tracker-ring broadcasts are a few words — the common
    /// case skips the NIC's payload-fetch round). A wrap filler is
    /// still posted immediately (unsignaled): the second space wait may
    /// depend on receivers consuming it, so it cannot be deferred into
    /// the frame's batch.
    pub fn send(&self, ctx: &ThreadCtx, payload: &[u64]) -> AckKey {
        let len = payload.len() as u64;
        assert!(len + 2 <= self.capacity, "message of {len} words exceeds ring capacity");
        assert!(len < WRAP, "message too long for framing");

        // Wrap if the frame doesn't fit in the remaining ring tail.
        let off = self.head.get() % self.capacity;
        if off + len + 2 > self.capacity {
            let fill = self.capacity - off;
            self.wait_space(ctx, fill);
            let w = hdr(self.seq.get(), WRAP);
            for r in self.receivers() {
                let ring = self.ep.remote_region(r, "ring");
                ctx.write_unsignaled(ring, off, &[w]);
            }
            self.head.set(self.head.get() + fill);
            self.seq.set(self.seq.get() + 1);
        }

        self.wait_space(ctx, len + 2);
        let off = self.head.get() % self.capacity;
        let h = hdr(self.seq.get(), len);
        let mut frame = Vec::with_capacity(payload.len() + 2);
        frame.push(h);
        frame.extend_from_slice(payload);
        frame.push(fnv64(&frame));
        let rings: Vec<Region> =
            self.receivers().map(|r| self.ep.remote_region(r, "ring")).collect();
        let writes: Vec<(Region, u64, &[u64])> =
            rings.iter().map(|&ring| (ring, off, frame.as_slice())).collect();
        let key = ctx.write_many(&writes);
        self.head.set(self.head.get() + len + 2);
        self.seq.set(self.seq.get() + 1);
        key
    }

    fn wait_space(&self, ctx: &ThreadCtx, need: u64) {
        let mut bo = Backoff::new();
        let mut budget = crate::util::WaitBudget::wedge(Duration::from_secs(30));
        loop {
            let consumed = match self.min_consumed(ctx) {
                Some(c) => c,
                None => return, // no live receivers left to throttle us
            };
            let in_flight = self.head.get() - consumed;
            if in_flight + need <= self.capacity {
                return;
            }
            if self.membership.is_dead(self.me) {
                return; // we crash-stopped: sends are no-ops anyway
            }
            assert!(
                !budget.expired(),
                "ring sender wedged (30 s) waiting for {need} words of space"
            );
            bo.snooze();
        }
    }

    /// Messages sent so far.
    pub fn sent(&self) -> u64 {
        self.seq.get()
    }

    /// Cumulative words written (a "position"; compare with
    /// [`RingSender::wait_all_acked`]).
    pub fn position(&self) -> u64 {
        self.head.get()
    }

    /// Block until every **live** receiver has acknowledged consumption
    /// up to `upto` (a position returned by [`RingSender::position`]).
    /// The kvstore inserter uses this: all surviving indices hold the
    /// new location once this returns (§6). Receivers that crash-stop
    /// mid-wait drop out of the minimum on the next poll — a dead peer
    /// cannot wedge a broadcast — and a sender that itself crash-stopped
    /// gives up (its writes were never transmitted).
    pub fn wait_all_acked(&self, ctx: &ThreadCtx, upto: u64) {
        let mut bo = Backoff::new();
        let mut budget = crate::util::WaitBudget::wedge(Duration::from_secs(30));
        loop {
            match self.min_consumed(ctx) {
                None => return,
                Some(c) if c >= upto => return,
                _ => {}
            }
            if self.membership.is_dead(self.me) {
                return;
            }
            assert!(
                !budget.expired(),
                "ring broadcast wedged (30 s) waiting for acks up to position {upto}"
            );
            bo.snooze();
        }
    }
}

/// Receiver endpoint.
pub struct RingReceiver {
    ep: Arc<Endpoint>,
    ack: Sst,
    ring: Region,
    capacity: u64,
    /// Cumulative words consumed.
    tail: Cell<u64>,
    seq: Cell<u64>,
    /// Batch acks: publish every `ack_interval` messages.
    ack_interval: u64,
    unacked: Cell<u64>,
}

impl RingReceiver {
    pub fn new(mgr: &Arc<Manager>, name: &str, capacity_words: u64) -> Self {
        let me = mgr.me();
        let ep = Endpoint::new(name, me, mgr.num_nodes(), Expect::AllPeers);
        let ring = mgr.pool().alloc_named(&region_name(name, "ring"), capacity_words as usize, false);
        ep.add_local_region("ring", ring);
        mgr.register_channel(ep.clone());
        let ack = Sst::new(mgr, &sub_name(name, "ack"), 1);
        RingReceiver {
            ep,
            ack,
            ring,
            capacity: capacity_words,
            tail: Cell::new(0),
            seq: Cell::new(0),
            ack_interval: 1,
            unacked: Cell::new(0),
        }
    }

    /// Publish consumed-words acks only every `n` messages (batching
    /// ablation; default 1).
    pub fn set_ack_interval(&mut self, n: u64) {
        self.ack_interval = n.max(1);
    }

    /// Manual-ack mode: `try_recv`/`recv` no longer publish acks; the
    /// caller must invoke [`RingReceiver::ack_now`] after it has *applied*
    /// the message. The kvstore tracker uses this — the paper requires
    /// "applies requested updates to the local index and THEN
    /// acknowledges" (§6).
    pub fn set_manual_ack(&mut self) {
        self.ack_interval = u64::MAX;
    }

    /// Publish the consumed-words counter now (manual-ack mode).
    pub fn ack_now(&self, ctx: &ThreadCtx) {
        self.ack.store_mine(ctx, &[self.tail.get()]);
        self.ack.push_broadcast(ctx);
        self.unacked.set(0);
    }

    pub fn wait_ready(&self, timeout: Duration) {
        self.ep.wait_ready(timeout);
        self.ack.wait_ready(timeout);
    }

    /// Non-blocking readiness probe (simulator services).
    pub fn is_ready(&self) -> bool {
        self.ep.is_ready() && self.ack.is_ready()
    }

    /// Non-blocking receive of the next broadcast message.
    pub fn try_recv(&self, ctx: &ThreadCtx) -> Option<Vec<u64>> {
        loop {
            let off = self.tail.get() % self.capacity;
            let h = ctx.local_load(self.ring, off);
            if hdr_seq(h) != self.seq.get() & 0xFFFF_FFFF {
                return None; // not yet written (or partially placed hdr)
            }
            let len = hdr_len(h);
            if len == WRAP {
                // Filler: skip to the start of the ring.
                self.tail.set(self.tail.get() + (self.capacity - off));
                self.seq.set(self.seq.get() + 1);
                self.publish_ack(ctx, true);
                continue;
            }
            // Read payload + tail checksum; retry if torn.
            let mut frame = vec![0u64; len as usize + 2];
            for (i, f) in frame.iter_mut().enumerate() {
                *f = ctx.local_load(self.ring, off + i as u64);
            }
            let tail_ck = frame[len as usize + 1];
            if fnv64(&frame[..len as usize + 1]) != tail_ck {
                return None; // placement in progress; try again later
            }
            let payload = frame[1..=len as usize].to_vec();
            self.tail.set(self.tail.get() + len + 2);
            self.seq.set(self.seq.get() + 1);
            self.publish_ack(ctx, false);
            return Some(payload);
        }
    }

    /// Blocking receive.
    pub fn recv(&self, ctx: &ThreadCtx) -> Vec<u64> {
        let mut bo = Backoff::new();
        loop {
            if let Some(m) = self.try_recv(ctx) {
                return m;
            }
            bo.snooze();
        }
    }

    fn publish_ack(&self, ctx: &ThreadCtx, force: bool) {
        if self.ack_interval == u64::MAX {
            return; // manual-ack mode
        }
        let n = self.unacked.get() + 1;
        if force || n >= self.ack_interval {
            self.ack.store_mine(ctx, &[self.tail.get()]);
            self.ack.push_broadcast(ctx); // fire-and-forget
            self.unacked.set(0);
        } else {
            self.unacked.set(n);
        }
    }

    pub fn received(&self) -> u64 {
        self.seq.get()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::{Cluster, FabricConfig, LatencyModel};

    #[test]
    fn broadcast_in_order_mixed_sizes() {
        let cluster = Cluster::new(3, FabricConfig::inline_ideal());
        let mgrs: Vec<Arc<Manager>> =
            (0..3).map(|i| Manager::new(cluster.clone(), i)).collect();
        let tx = RingSender::new(&mgrs[0], "rb", 64);
        let rx1 = RingReceiver::new(&mgrs[1], "rb", 64);
        let rx2 = RingReceiver::new(&mgrs[2], "rb", 64);
        tx.wait_ready(Duration::from_secs(10));
        rx1.wait_ready(Duration::from_secs(10));
        rx2.wait_ready(Duration::from_secs(10));

        let ctx0 = mgrs[0].ctx();
        let ctx1 = mgrs[1].ctx();
        let ctx2 = mgrs[2].ctx();
        let msgs: Vec<Vec<u64>> = (0..40u64)
            .map(|i| (0..=(i % 7)).map(|j| i * 100 + j).collect())
            .collect();
        // Interleave sends and receives so the ring wraps several times.
        let mut got1 = Vec::new();
        let mut got2 = Vec::new();
        for m in &msgs {
            tx.send(&ctx0, m);
            while let Some(x) = rx1.try_recv(&ctx1) {
                got1.push(x);
            }
            while let Some(x) = rx2.try_recv(&ctx2) {
                got2.push(x);
            }
        }
        while got1.len() < msgs.len() {
            got1.push(rx1.recv(&ctx1));
        }
        while got2.len() < msgs.len() {
            got2.push(rx2.recv(&ctx2));
        }
        assert_eq!(got1, msgs, "receiver 1 in-order delivery");
        assert_eq!(got2, msgs, "receiver 2 in-order delivery");
    }

    /// Sender blocks on a slow receiver, then drains once acks arrive —
    /// and nothing is lost under threaded placement lag.
    #[test]
    fn flow_control_and_threaded_delivery() {
        let mut lat = LatencyModel::fast_sim();
        lat.placement_lag_ns = 2000;
        let cluster = Cluster::new(2, FabricConfig::threaded(lat).chaotic());
        let m0 = Manager::new(cluster.clone(), 0);
        let m1 = Manager::new(cluster.clone(), 1);
        let tx = RingSender::new(&m0, "rb", 32);
        let rx = RingReceiver::new(&m1, "rb", 32);
        tx.wait_ready(Duration::from_secs(10));
        rx.wait_ready(Duration::from_secs(10));

        let h = std::thread::spawn(move || {
            let ctx = m0.ctx();
            for i in 0..200u64 {
                tx.send(&ctx, &[i, i * 2, i * 3]);
            }
        });
        let ctx1 = m1.ctx();
        for i in 0..200u64 {
            let m = rx.recv(&ctx1);
            assert_eq!(m, vec![i, i * 2, i * 3]);
        }
        h.join().unwrap();
    }
}
