//! `atomic_var`: multi-writer multi-reader word-size register
//! (paper §5.1.1).
//!
//! A single "official" copy lives on the `host` participant; all
//! participants operate on it with remote atomics (FAA/CAS) and
//! word-atomic reads/writes. The primary purpose is exposing atomic
//! operations on remote memory — the building block of the ticket lock
//! and the shared queue.
//!
//! The official copy can live in NIC **device memory** (App. A.2):
//! state that is only ever accessed through the network (like mutex
//! words) avoids the PCIe hop.

use std::sync::Arc;
use std::time::Duration;

use crate::core::ctx::ThreadCtx;
use crate::core::endpoint::{region_name, Endpoint, Expect};
use crate::core::manager::Manager;
use crate::fabric::{NodeId, Region};

/// Multi-writer word-size register with one "official" copy (paper
/// §5.1.1).
///
/// # Examples
///
/// ```
/// use std::time::Duration;
/// use loco::channels::AtomicVar;
/// use loco::core::manager::Manager;
/// use loco::fabric::{Cluster, FabricConfig};
///
/// let cluster = Cluster::new(2, FabricConfig::inline_ideal());
/// let m0 = Manager::new(cluster.clone(), 0);
/// let m1 = Manager::new(cluster.clone(), 1);
/// // Official copy hosted on node 0; all nodes use remote atomics.
/// let a0 = AtomicVar::with_initial(&m0, "ctr", 0, false, 100);
/// let a1 = AtomicVar::with_initial(&m1, "ctr", 0, false, 100);
/// a0.wait_ready(Duration::from_secs(10));
/// a1.wait_ready(Duration::from_secs(10));
///
/// let ctx1 = m1.ctx();
/// assert_eq!(a1.fetch_add(&ctx1, 5), 100); // remote FAA
/// assert_eq!(a1.compare_swap(&ctx1, 105, 7), 105); // remote CAS
/// let ctx0 = m0.ctx();
/// assert_eq!(a0.load(&ctx0), 7); // host sees the official copy
/// ```
pub struct AtomicVar {
    ep: Arc<Endpoint>,
    host: NodeId,
    /// Official copy (host only).
    cell: Option<Region>,
    num_nodes: usize,
}

impl AtomicVar {
    pub fn new(mgr: &Arc<Manager>, name: &str, host: NodeId, device: bool) -> Self {
        let me = mgr.me();
        let ep = Endpoint::new(name, me, mgr.num_nodes(), Expect::AllPeers);
        let _ = me;
        let cell = if me == host {
            let r = mgr.pool().alloc_named(&region_name(name, "cell"), 1, device);
            ep.add_local_region("cell", r);
            Some(r)
        } else {
            None
        };
        mgr.register_channel(ep.clone());
        AtomicVar { ep, host, cell, num_nodes: mgr.num_nodes() }
    }

    /// Construct with an initial value (host side sets it before peers
    /// can possibly access: they need our connect metadata first).
    pub fn with_initial(mgr: &Arc<Manager>, name: &str, host: NodeId, device: bool, init: u64) -> Self {
        let v = Self::new(mgr, name, host, device);
        if let Some(cell) = v.cell {
            mgr.cluster().node(mgr.me()).arena().store(cell.at(0), init);
        }
        v
    }

    pub fn wait_ready(&self, timeout: Duration) {
        self.ep.wait_ready(timeout);
    }

    /// Non-blocking readiness probe (simulator services).
    pub fn is_ready(&self) -> bool {
        self.ep.is_ready()
    }

    pub fn host(&self) -> NodeId {
        self.host
    }

    fn cell_region(&self) -> Region {
        match self.cell {
            Some(r) => r,
            None => self.ep.remote_region(self.host, "cell"),
        }
    }

    /// Global word address of the official copy, in the host's address
    /// space — the race checker keys lock-HB edges by `(host, addr)`.
    /// Requires the endpoint to be ready on non-host nodes.
    pub(crate) fn cell_addr(&self) -> u64 {
        self.cell_region().at(0)
    }

    /// Word-atomic load of the official copy.
    pub fn load(&self, ctx: &ThreadCtx) -> u64 {
        ctx.read1(self.cell_region(), 0)
    }

    /// Word-atomic store to the official copy. Remote stores are
    /// completion-tracked but, like any RDMA write, not placed until a
    /// flushing op or fence (use `fetch_add`/`compare_swap` for
    /// read-modify-write semantics).
    pub fn store(&self, ctx: &ThreadCtx, v: u64) {
        ctx.write1(self.cell_region(), 0, v).wait();
    }

    /// Atomic fetch-and-add on the official copy; returns the old value.
    pub fn fetch_add(&self, ctx: &ThreadCtx, add: u64) -> u64 {
        ctx.fetch_add(self.cell_region(), 0, add)
    }

    /// Atomic compare-and-swap; returns the old value.
    pub fn compare_swap(&self, ctx: &ThreadCtx, expect: u64, swap: u64) -> u64 {
        ctx.compare_swap(self.cell_region(), 0, expect, swap)
    }

    // ---- fallible variants (crash-stop aware) ------------------------
    //
    // The official copy lives on one host; if that host crash-stops the
    // register is gone. The try_ variants surface that as
    // `Err(Error::PeerFailed)` so spin loops built on this channel (the
    // ticket lock, the shared queue) can bound their waits instead of
    // spinning on a corpse.

    /// Like [`AtomicVar::load`], but a crashed host returns
    /// `Err(Error::PeerFailed)` instead of a meaningless word.
    pub fn try_load(&self, ctx: &ThreadCtx) -> crate::Result<u64> {
        if ctx.node_down(self.host) {
            return Err(crate::Error::PeerFailed(format!(
                "atomic_var host {} crash-stopped",
                self.host
            )));
        }
        Ok(ctx.try_read(self.cell_region(), 0, 1)?[0])
    }

    /// Like [`AtomicVar::fetch_add`], crash-stop aware.
    pub fn try_fetch_add(&self, ctx: &ThreadCtx, add: u64) -> crate::Result<u64> {
        ctx.try_fetch_add(self.cell_region(), 0, add)
    }

    /// Like [`AtomicVar::compare_swap`], crash-stop aware.
    pub fn try_compare_swap(&self, ctx: &ThreadCtx, expect: u64, swap: u64) -> crate::Result<u64> {
        ctx.try_compare_swap(self.cell_region(), 0, expect, swap)
    }

    pub fn num_nodes(&self) -> usize {
        self.num_nodes
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::{Cluster, FabricConfig};

    fn setup(n: usize) -> Vec<Arc<Manager>> {
        let cluster = Cluster::new(n, FabricConfig::inline_ideal());
        (0..n as NodeId).map(|i| Manager::new(cluster.clone(), i)).collect()
    }

    #[test]
    fn remote_atomics_from_all_nodes() {
        let mgrs = setup(3);
        let vars: Vec<AtomicVar> =
            mgrs.iter().map(|m| AtomicVar::with_initial(m, "ctr", 1, false, 100)).collect();
        for v in &vars {
            v.wait_ready(Duration::from_secs(5));
        }
        let ctxs: Vec<_> = mgrs.iter().map(|m| m.ctx()).collect();
        assert_eq!(vars[0].load(&ctxs[0]), 100);
        assert_eq!(vars[0].fetch_add(&ctxs[0], 1), 100);
        assert_eq!(vars[1].fetch_add(&ctxs[1], 1), 101); // host-local fast path
        assert_eq!(vars[2].fetch_add(&ctxs[2], 1), 102);
        assert_eq!(vars[1].load(&ctxs[1]), 103);
        assert_eq!(vars[2].compare_swap(&ctxs[2], 103, 7), 103);
        assert_eq!(vars[0].load(&ctxs[0]), 7);
    }

    #[test]
    fn device_memory_cell() {
        let mgrs = setup(2);
        let vars: Vec<AtomicVar> =
            mgrs.iter().map(|m| AtomicVar::new(m, "dev", 0, true)).collect();
        for v in &vars {
            v.wait_ready(Duration::from_secs(5));
        }
        let ctx1 = mgrs[1].ctx();
        assert_eq!(vars[1].fetch_add(&ctx1, 5), 0);
        assert_eq!(vars[1].load(&ctx1), 5);
        // The official copy really is in device space.
        assert!(vars[1].ep.remote_region(0, "cell").base >= crate::fabric::DEVICE_BASE);
    }

    /// FAA from many nodes concurrently: no lost updates.
    #[test]
    fn concurrent_faa_no_lost_updates() {
        let cluster = Cluster::new(4, FabricConfig::threaded(crate::fabric::LatencyModel::fast_sim()));
        let mgrs: Vec<Arc<Manager>> =
            (0..4).map(|i| Manager::new(cluster.clone(), i)).collect();
        let vars: Vec<Arc<AtomicVar>> = mgrs
            .iter()
            .map(|m| Arc::new(AtomicVar::new(m, "race", 0, false)))
            .collect();
        for v in &vars {
            v.wait_ready(Duration::from_secs(5));
        }
        let handles: Vec<_> = mgrs
            .iter()
            .zip(&vars)
            .map(|(m, v)| {
                let m = m.clone();
                let v = v.clone();
                std::thread::spawn(move || {
                    let ctx = m.ctx();
                    for _ in 0..250 {
                        v.fetch_add(&ctx, 1);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let ctx0 = mgrs[0].ctx();
        assert_eq!(vars[0].load(&ctx0), 1000);
    }
}
