//! `request_ring`: a served op-shipping (RPC) channel.
//!
//! Where every other channel moves *memory*, this one moves
//! *operations*: a client writes a checksummed `OpReq` frame (op code,
//! key, epoch, inline value) into a slot of the server's request
//! region with **one** RDMA WRITE, then spins on a completion word in
//! its own local reply region. The server's service loop sweeps its
//! request slots, validates checksums (a frame still being placed
//! word-by-word simply fails validation and is retried next sweep —
//! the §2.2 torn-write hazard needs no fence here), hands complete
//! requests to the application, and answers with a one-sided WRITE of
//! a 3-word checksummed reply. Total cost per shipped op: one WRITE
//! each way — the Brock-et-al. crossover regime where this beats a
//! one-sided lock/write/fence/unlock conversation on hot keys.
//!
//! The ring is application-agnostic: [`RequestRing::call`] ships, and
//! the owner of the serving loop pairs [`RequestRing::drain`] /
//! [`RequestRing::reply`] with its own apply logic (the kvstore's
//! shipped-update handler, fig4's delegated lock server). Op and
//! status codes are caller-defined bytes.
//!
//! ## Frame layout
//!
//! Request slot (`4 + max_value_words` words, per client × slot):
//!
//! ```text
//! [ seq(32) | op(8) | pad(8) | len(16) ][ key ][ epoch ][ value… ][ fnv64 ]
//! ```
//!
//! Reply slot (3 words, per server × slot, in the *client's* memory):
//!
//! ```text
//! [ seq(32) | status(8) ][ retval ][ fnv64 ]
//! ```
//!
//! `seq` is per (client, server, slot), starts at 1, and makes slot
//! reuse unambiguous: a reply is only accepted when its `seq` matches
//! the outstanding request, and the server only accepts a slot whose
//! `seq` moved past the last one it served.
//!
//! ## Failure contract
//!
//! Crash-stop of the server surfaces as `Err(Error::PeerFailed)` from
//! `call` in bounded time (the reply spin watches the cluster's down
//! mask; it never wedges on a corpse). The op may or may not have been
//! applied before the crash — a blind re-execution down another path
//! is NOT transparent (the apply may have replicated and another
//! writer may land at the re-home first, so re-applying can resurrect
//! a superseded value). Callers must resolve the ambiguity themselves:
//! the kvstore probes the current frame under the key lock, skips the
//! re-apply when its value already landed, and reports any performed
//! re-apply as ambiguous so history recorders don't treat the op's
//! interval as definite (see `apps::kvstore::UpdateOutcome`).
//! Transient completion errors (QP flaps) are retried on the same
//! slot/`seq` while the peer is alive, so a frame is never abandoned
//! where a live server could still apply it late.

use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::core::ctx::ThreadCtx;
use crate::core::endpoint::{region_name, Endpoint, Expect};
use crate::core::manager::Manager;
use crate::fabric::{NodeId, Region};
use crate::util::{fnv64, Backoff, WaitBudget};

/// Concurrent shipped ops per (client, server) pair; calls beyond this
/// briefly wait for a slot.
pub const SLOTS_PER_CLIENT: usize = 4;
/// Reply frame words: header, retval, checksum.
const REP_WORDS: u64 = 3;
/// Request frame overhead words: header, key, epoch, checksum.
const REQ_META_WORDS: u64 = 4;

/// One complete request drained by the server.
#[derive(Clone, Debug)]
pub struct OpReq {
    /// Requesting node.
    pub from: NodeId,
    /// Caller-defined op code.
    pub op: u8,
    /// Key operand.
    pub key: u64,
    /// Caller-defined auxiliary word (the kvstore ships its membership
    /// epoch here).
    pub aux: u64,
    /// Inline value payload.
    pub val: Vec<u64>,
    slot: usize,
    seq: u32,
}

/// A served reply.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Reply {
    /// Caller-defined status byte.
    pub status: u8,
    /// Caller-defined return word.
    pub retval: u64,
}

struct ClientSlots {
    /// Last sequence number used per slot (next call uses `+1`).
    seq: [u32; SLOTS_PER_CLIENT],
    busy: [bool; SLOTS_PER_CLIENT],
}

/// Per-node served request ring (see module docs).
pub struct RequestRing {
    ep: Arc<Endpoint>,
    me: NodeId,
    num_nodes: usize,
    /// Requests addressed to me: `num_nodes × SLOTS × slot_words`.
    req: Region,
    /// Replies addressed to me: `num_nodes × SLOTS × REP_WORDS`.
    rep: Region,
    slot_words: u64,
    max_value: usize,
    /// Client side: slot allocation per server node.
    clients: Vec<Mutex<ClientSlots>>,
    /// Server side: highest request `seq` served per (client, slot).
    served: Mutex<Vec<[u32; SLOTS_PER_CLIENT]>>,
}

impl RequestRing {
    /// Build this node's ring under `name`. `max_value_words` bounds the
    /// inline payload (callers cap it at the fabric's inline budget so a
    /// shipped frame stays a single inline WRITE).
    pub fn new(mgr: &Arc<Manager>, name: &str, max_value_words: usize) -> Self {
        assert!(max_value_words >= 1, "request ring needs at least one value word");
        let me = mgr.me();
        let n = mgr.num_nodes();
        let slot_words = REQ_META_WORDS + max_value_words as u64;
        let ep = Endpoint::new(name, me, n, Expect::AllPeers);
        let req_len = n as u64 * SLOTS_PER_CLIENT as u64 * slot_words;
        let rep_len = n as u64 * SLOTS_PER_CLIENT as u64 * REP_WORDS;
        let req = mgr.pool().alloc_named(&region_name(name, "req"), req_len, false);
        let rep = mgr.pool().alloc_named(&region_name(name, "rep"), rep_len, false);
        ep.add_local_region("req", req);
        ep.add_local_region("rep", rep);
        ep.expect_regions(&["req", "rep"]);
        mgr.register_channel(ep.clone());
        RequestRing {
            ep,
            me,
            num_nodes: n,
            req,
            rep,
            slot_words,
            max_value: max_value_words,
            clients: (0..n)
                .map(|_| {
                    Mutex::new(ClientSlots {
                        seq: [0; SLOTS_PER_CLIENT],
                        busy: [false; SLOTS_PER_CLIENT],
                    })
                })
                .collect(),
            served: Mutex::new(vec![[0; SLOTS_PER_CLIENT]; n]),
        }
    }

    pub fn wait_ready(&self, timeout: Duration) {
        self.ep.wait_ready(timeout);
    }

    /// Non-blocking readiness probe (simulator services).
    pub fn is_ready(&self) -> bool {
        self.ep.is_ready()
    }

    /// Largest inline value `call` accepts.
    pub fn max_value_words(&self) -> usize {
        self.max_value
    }

    /// Offset of (client, slot) in a request region.
    fn req_off(&self, client: NodeId, slot: usize) -> u64 {
        (client as u64 * SLOTS_PER_CLIENT as u64 + slot as u64) * self.slot_words
    }

    /// Offset of (server, slot) in a reply region.
    fn rep_off(server: NodeId, slot: usize) -> u64 {
        (server as u64 * SLOTS_PER_CLIENT as u64 + slot as u64) * REP_WORDS
    }

    fn pack_req_hdr(seq: u32, op: u8, len: usize) -> u64 {
        ((seq as u64) << 32) | ((op as u64) << 24) | (len as u64 & 0xFFFF)
    }

    fn pack_rep_hdr(seq: u32, status: u8) -> u64 {
        ((seq as u64) << 32) | status as u64
    }

    /// Ship `(op, key, aux, val)` to `server` and wait for its reply.
    ///
    /// `Err(Error::PeerFailed)` if the server (or this node) crash-stops
    /// before the reply lands; whether the op was applied is then
    /// unknown (see module docs). Never called with `server == me` —
    /// local ops have no reason to ship.
    pub fn call(
        &self,
        ctx: &ThreadCtx,
        server: NodeId,
        op: u8,
        key: u64,
        aux: u64,
        val: &[u64],
    ) -> crate::Result<Reply> {
        assert_ne!(server, self.me, "shipping to self");
        assert!(val.len() <= self.max_value, "shipped value exceeds the ring's inline budget");
        if ctx.node_down(server) {
            return Err(crate::Error::PeerFailed(format!("ship target {server} crash-stopped")));
        }

        // Claim a slot (briefly wait if all are in flight).
        let (slot, seq) = {
            let mut bo = Backoff::new();
            let mut budget = WaitBudget::wedge(Duration::from_secs(30));
            loop {
                {
                    let mut st = self.clients[server as usize].lock().unwrap();
                    if let Some(s) = st.busy.iter().position(|b| !b) {
                        st.busy[s] = true;
                        st.seq[s] = st.seq[s].wrapping_add(1).max(1);
                        break (s, st.seq[s]);
                    }
                }
                if ctx.node_down(server) {
                    return Err(crate::Error::PeerFailed(format!(
                        "ship target {server} crash-stopped"
                    )));
                }
                bo.snooze();
                assert!(!budget.expired(), "request ring slot wait wedged (30 s)");
            }
        };
        let free_slot = || self.clients[server as usize].lock().unwrap().busy[slot] = false;

        // Build and post the request frame: one WRITE, checksummed so a
        // mid-placement sweep on the server just skips it.
        let mut frame = Vec::with_capacity(self.slot_words as usize);
        frame.push(Self::pack_req_hdr(seq, op, val.len()));
        frame.push(key);
        frame.push(aux);
        frame.extend_from_slice(val);
        frame.push(fnv64(&frame));
        let target = self.ep.remote_region(server, "req");
        let off = self.req_off(self.me, slot);
        let mut bo = Backoff::new();
        let mut budget = WaitBudget::wedge(Duration::from_secs(30));
        loop {
            let k = ctx.write(target, off, &frame);
            match ctx.wait_checked(&k) {
                Ok(()) => break,
                // Transient (flap) errors retry the same slot/seq: a
                // live server must never be left holding a frame we
                // abandoned (it could apply it arbitrarily late).
                Err(_) if !ctx.node_down(server) && !ctx.node_down(self.me) => {
                    bo.snooze();
                    assert!(!budget.expired(), "request ring post wedged (30 s)");
                }
                Err(e) => {
                    free_slot();
                    return Err(e);
                }
            }
        }

        // Spin on the local reply word. Bounded: a crash of either end
        // surfaces via the down mask, anything else is a wedge.
        let mut bo = Backoff::new();
        let mut budget = WaitBudget::wedge(Duration::from_secs(30));
        let roff = Self::rep_off(server, slot);
        loop {
            let hdr = ctx.local_load(self.rep, roff);
            if (hdr >> 32) as u32 == seq {
                let retval = ctx.local_load(self.rep, roff + 1);
                let ck = ctx.local_load(self.rep, roff + 2);
                if ck == fnv64(&[hdr, retval]) {
                    free_slot();
                    return Ok(Reply { status: (hdr & 0xFF) as u8, retval });
                }
            }
            if ctx.node_down(server) {
                free_slot();
                return Err(crate::Error::PeerFailed(format!(
                    "ship target {server} crash-stopped before replying"
                )));
            }
            if ctx.node_down(self.me) {
                free_slot();
                return Err(crate::Error::PeerFailed("local node crash-stopped".into()));
            }
            bo.snooze();
            assert!(!budget.expired(), "request ring reply wait wedged (30 s): seq {seq}");
        }
    }

    /// Server side: sweep my request slots and return every complete,
    /// not-yet-served request (placement-torn frames are skipped and
    /// picked up by a later sweep). Non-blocking; safe to call from a
    /// simulator service.
    pub fn drain(&self, ctx: &ThreadCtx) -> Vec<OpReq> {
        let mut served = self.served.lock().unwrap();
        let mut out = Vec::new();
        for client in 0..self.num_nodes as NodeId {
            if client == self.me {
                continue;
            }
            for slot in 0..SLOTS_PER_CLIENT {
                let off = self.req_off(client, slot);
                let hdr = ctx.local_load(self.req, off);
                let seq = (hdr >> 32) as u32;
                if seq == 0 || seq == served[client as usize][slot] {
                    continue;
                }
                let len = (hdr & 0xFFFF) as usize;
                if len > self.max_value {
                    continue; // torn header half; retry next sweep
                }
                let mut words = Vec::with_capacity(3 + len);
                words.push(hdr);
                for i in 1..(3 + len) as u64 {
                    words.push(ctx.local_load(self.req, off + i));
                }
                let ck = ctx.local_load(self.req, off + 3 + len as u64);
                if ck != fnv64(&words) {
                    continue; // placement in flight; retry next sweep
                }
                served[client as usize][slot] = seq;
                out.push(OpReq {
                    from: client,
                    op: (hdr >> 24) as u8,
                    key: words[1],
                    aux: words[2],
                    val: words[3..].to_vec(),
                    slot,
                    seq,
                });
            }
        }
        out
    }

    /// Server side: answer a drained request. Retries transient
    /// completion errors while the client is alive (a lost reply would
    /// wedge the client's spin); a dead client's reply is dropped.
    pub fn reply(&self, ctx: &ThreadCtx, req: &OpReq, status: u8, retval: u64) {
        let hdr = Self::pack_rep_hdr(req.seq, status);
        let frame = [hdr, retval, fnv64(&[hdr, retval])];
        let target = self.ep.remote_region(req.from, "rep");
        let off = Self::rep_off(self.me, req.slot);
        let mut bo = Backoff::new();
        let mut budget = WaitBudget::wedge(Duration::from_secs(30));
        loop {
            let k = ctx.write(target, off, &frame);
            match ctx.wait_checked(&k) {
                Ok(()) => return,
                Err(_) if ctx.node_down(req.from) || ctx.node_down(self.me) => return,
                Err(_) => {
                    bo.snooze();
                    assert!(!budget.expired(), "request ring reply post wedged (30 s)");
                }
            }
        }
    }

    /// Fast-forward the server cursor past everything currently in the
    /// ring without serving it. Called when this node (re)joins the
    /// serving role: frames shipped before the membership change belong
    /// to clients that have already timed out on our death and must not
    /// be applied late.
    pub fn quiesce(&self, ctx: &ThreadCtx) {
        let mut served = self.served.lock().unwrap();
        for client in 0..self.num_nodes as NodeId {
            for slot in 0..SLOTS_PER_CLIENT {
                let hdr = ctx.local_load(self.req, self.req_off(client, slot));
                served[client as usize][slot] = (hdr >> 32) as u32;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::{Cluster, FabricConfig};
    use std::sync::atomic::{AtomicBool, Ordering};

    fn pair() -> (Arc<Cluster>, Arc<Manager>, Arc<Manager>) {
        let cluster = Cluster::new(2, FabricConfig::inline_ideal());
        let m0 = Manager::new(cluster.clone(), 0);
        let m1 = Manager::new(cluster.clone(), 1);
        (cluster, m0, m1)
    }

    #[test]
    fn call_roundtrips_through_a_serving_peer() {
        let (_cluster, m0, m1) = pair();
        let r0 = Arc::new(RequestRing::new(&m0, "rr", 8));
        let r1 = Arc::new(RequestRing::new(&m1, "rr", 8));
        r0.wait_ready(Duration::from_secs(10));
        r1.wait_ready(Duration::from_secs(10));

        // Node 0 serves: echo the op, sum the value words.
        let stop = Arc::new(AtomicBool::new(false));
        let server = {
            let (r0, m0, stop) = (r0.clone(), m0.clone(), stop.clone());
            std::thread::spawn(move || {
                let ctx = m0.ctx();
                let mut bo = Backoff::new();
                while !stop.load(Ordering::Relaxed) {
                    let reqs = r0.drain(&ctx);
                    if reqs.is_empty() {
                        bo.snooze();
                        continue;
                    }
                    bo.reset();
                    for req in reqs {
                        let sum: u64 = req.val.iter().sum();
                        r0.reply(&ctx, &req, req.op, sum.wrapping_add(req.key + req.aux));
                    }
                }
            })
        };

        let ctx1 = m1.ctx();
        for i in 0..64u64 {
            let val = vec![i, i + 1, i + 2];
            let rep = r1.call(&ctx1, 0, 7, 100 + i, i, &val).unwrap();
            assert_eq!(rep.status, 7);
            assert_eq!(rep.retval, (3 * i + 3) + (100 + i) + i);
        }
        stop.store(true, Ordering::Relaxed);
        server.join().unwrap();
    }

    #[test]
    fn call_to_a_corpse_fails_bounded() {
        let (cluster, m0, m1) = pair();
        let r0 = RequestRing::new(&m0, "rr2", 4);
        let r1 = RequestRing::new(&m1, "rr2", 4);
        r0.wait_ready(Duration::from_secs(10));
        r1.wait_ready(Duration::from_secs(10));
        cluster.crash(0);
        let ctx1 = m1.ctx();
        let err = r1.call(&ctx1, 0, 1, 42, 0, &[1]).unwrap_err();
        assert!(matches!(err, crate::Error::PeerFailed(_)), "got {err:?}");
    }

    #[test]
    fn quiesce_skips_preexisting_frames() {
        let (_cluster, m0, m1) = pair();
        let r0 = Arc::new(RequestRing::new(&m0, "rr3", 4));
        let r1 = Arc::new(RequestRing::new(&m1, "rr3", 4));
        r0.wait_ready(Duration::from_secs(10));
        r1.wait_ready(Duration::from_secs(10));

        // Ship one op with nobody serving, then quiesce the server: the
        // frame must be skipped, and a fresh call must still serve.
        let r1c = r1.clone();
        let m1c = m1.clone();
        let orphan = std::thread::spawn(move || {
            // The reply never comes; the call errors out when the server
            // "dies" below.
            let _ = r1c.call(&m1c.ctx(), 0, 9, 1, 0, &[5]);
        });
        let ctx0 = m0.ctx();
        // Wait until the orphan frame is visible, then quiesce.
        let mut bo = Backoff::new();
        while ctx0.local_load(r0.req, r0.req_off(1, 0)) == 0 {
            bo.snooze();
        }
        r0.quiesce(&ctx0);
        assert!(r0.drain(&ctx0).is_empty(), "quiesced frame must not be served");

        // Un-wedge the orphan caller by serving its slot manually after
        // a fresh request shows up on another slot.
        let t = std::thread::spawn(move || {
            let ctx = m1.ctx();
            r1.call(&ctx, 0, 2, 3, 0, &[4]).unwrap()
        });
        let mut bo = Backoff::new();
        loop {
            let reqs = r0.drain(&ctx0);
            if !reqs.is_empty() {
                for req in &reqs {
                    assert_eq!(req.op, 2, "only the post-quiesce frame is served");
                    r0.reply(&ctx0, req, 0, req.val[0]);
                }
                break;
            }
            bo.snooze();
        }
        assert_eq!(t.join().unwrap(), Reply { status: 0, retval: 4 });
        // Release the orphan: serve whatever is still pending (its slot
        // got a *new* seq only if retried; otherwise it stays quiesced —
        // emulate server death so the call returns).
        _cluster.crash(0);
        orphan.join().unwrap();
    }
}
