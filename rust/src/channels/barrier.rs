//! The network barrier (paper §4.1, Fig. 1a; algorithm after [27]).
//!
//! Each use: complete all outstanding RDMA (a **global fence**),
//! increment a private count, publish it through the SST, and spin until
//! every participant's SST row reaches our count.

use std::cell::Cell;
use std::sync::Arc;
use std::time::Duration;

use crate::core::ctx::ThreadCtx;
use crate::core::endpoint::sub_name;
use crate::core::manager::Manager;
use crate::fabric::NodeId;
use crate::util::Backoff;

use super::sst::Sst;

/// SST counting barrier (paper Fig. 1a).
///
/// # Examples
///
/// ```
/// use std::time::Duration;
/// use loco::channels::Barrier;
/// use loco::core::manager::Manager;
/// use loco::fabric::{Cluster, FabricConfig};
///
/// let cluster = Cluster::new(2, FabricConfig::inline_ideal());
/// let m0 = Manager::new(cluster.clone(), 0);
/// let m1 = Manager::new(cluster.clone(), 1);
/// // Node 1 runs in its own thread, as every node would on hardware.
/// let m1b = m1.clone();
/// let peer = std::thread::spawn(move || {
///     let bar = Barrier::new(&m1b, "bar", 2);
///     bar.wait_ready(Duration::from_secs(10));
///     let ctx = m1b.ctx();
///     bar.wait(&ctx);
///     bar.episodes()
/// });
/// let bar = Barrier::new(&m0, "bar", 2);
/// bar.wait_ready(Duration::from_secs(10));
/// let ctx = m0.ctx();
/// bar.wait(&ctx); // returns once BOTH nodes arrive
/// assert_eq!(bar.episodes(), 1);
/// assert_eq!(peer.join().unwrap(), 1);
/// ```
pub struct Barrier {
    mgr: Arc<Manager>,
    sst: Sst,
    count: Cell<u64>,
    num_nodes: usize,
}

impl Barrier {
    /// Construct the barrier endpoint (all `num` nodes participate).
    /// The SST sub-channel is namespaced `"<name>/sst"` as in the paper.
    pub fn new(mgr: &Arc<Manager>, name: &str, num: usize) -> Self {
        assert_eq!(num, mgr.num_nodes(), "partial-participation barriers: use expect_num");
        let sst = Sst::new(mgr, &sub_name(name, "sst"), 1);
        Barrier { mgr: mgr.clone(), sst, count: Cell::new(0), num_nodes: num }
    }

    pub fn wait_ready(&self, timeout: Duration) {
        self.sst.wait_ready(timeout);
    }

    /// The paper's `waiting()`: returns when all participants have
    /// arrived at this barrier use.
    pub fn wait(&self, ctx: &ThreadCtx) {
        // Complete all outstanding RDMA operations (§5.3).
        self.mgr.global_fence(ctx);
        let count = self.count.get() + 1;
        self.count.set(count);
        self.sst.store_mine(ctx, &[count]);
        self.sst.push_broadcast(ctx); // fire and forget; peers spin on rows
        let mut bo = Backoff::new();
        loop {
            let mut waiting = false;
            for row in 0..self.num_nodes as NodeId {
                if self.sst.read_row1(ctx, row) < count {
                    waiting = true;
                    break;
                }
            }
            if !waiting {
                return;
            }
            bo.snooze();
        }
    }

    /// Number of completed barrier episodes on this node.
    pub fn episodes(&self) -> u64 {
        self.count.get()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::{Cluster, FabricConfig, LatencyModel};
    use std::sync::atomic::{AtomicU64, Ordering};

    /// No node may leave barrier k before all nodes have entered it.
    fn barrier_stress(n: usize, cfg: FabricConfig, rounds: u64) {
        let cluster = Cluster::new(n, cfg);
        let mgrs: Vec<Arc<Manager>> =
            (0..n as NodeId).map(|i| Manager::new(cluster.clone(), i)).collect();
        let arrived = Arc::new(AtomicU64::new(0));
        let handles: Vec<_> = mgrs
            .iter()
            .map(|m| {
                let m = m.clone();
                let arrived = arrived.clone();
                let n = n as u64;
                std::thread::spawn(move || {
                    let bar = Barrier::new(&m, "bar", n as usize);
                    bar.wait_ready(Duration::from_secs(10));
                    let ctx = m.ctx();
                    for round in 0..rounds {
                        arrived.fetch_add(1, Ordering::SeqCst);
                        bar.wait(&ctx);
                        // Everyone must have arrived at this round.
                        let a = arrived.load(Ordering::SeqCst);
                        assert!(
                            a >= (round + 1) * n,
                            "left barrier round {round} after only {a} arrivals"
                        );
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(arrived.load(Ordering::SeqCst), rounds * n as u64);
    }

    #[test]
    fn inline_3_nodes() {
        barrier_stress(3, FabricConfig::inline_ideal(), 25);
    }

    #[test]
    fn threaded_4_nodes_with_lag() {
        let mut lat = LatencyModel::fast_sim();
        lat.placement_lag_ns = 3000;
        barrier_stress(4, FabricConfig::threaded(lat), 10);
    }
}
