//! Cross-node ticket lock (paper §5.4; algorithm after [41]).
//!
//! `next_ticket` and `now_serving` are [`AtomicVar`]s hosted on the
//! lock's home node (in NIC device memory by default — lock words are
//! only ever touched through the network, App. A.2). Acquire performs a
//! remote fetch-and-add on `next_ticket` and spins on `now_serving`;
//! release runs the caller-specified fence (§5.3), then increments
//! `now_serving`.
//!
//! The lock also provides mutual exclusion between *local* threads with a
//! fast **local handover** path: when a local thread releases while
//! another local thread is waiting, ownership passes node-locally without
//! touching the network, and the node keeps its global ticket. (This
//! trades global FIFO fairness for latency, as in the paper; the
//! `micro_channels` bench ablates it.)

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use crate::core::ctx::{FenceScope, ThreadCtx};
use crate::core::endpoint::sub_name;
use crate::core::manager::Manager;
use crate::fabric::NodeId;
use crate::util::Backoff;

use super::atomic_var::AtomicVar;

struct LocalState {
    /// This node currently owns the global ticket.
    node_holds: bool,
    /// A local thread is inside the critical section.
    local_active: bool,
    /// Local threads blocked waiting for handover.
    waiters: usize,
}

/// Cross-node ticket lock (paper §5.4).
///
/// # Examples
///
/// ```
/// use std::time::Duration;
/// use loco::channels::TicketLock;
/// use loco::core::manager::Manager;
/// use loco::fabric::{Cluster, FabricConfig};
///
/// let cluster = Cluster::new(2, FabricConfig::inline_ideal());
/// let m0 = Manager::new(cluster.clone(), 0);
/// let m1 = Manager::new(cluster.clone(), 1);
/// // Lock words hosted on node 0 (NIC device memory by default).
/// let l0 = TicketLock::new(&m0, "L", 0);
/// let l1 = TicketLock::new(&m1, "L", 0);
/// l0.wait_ready(Duration::from_secs(10));
/// l1.wait_ready(Duration::from_secs(10));
///
/// let ctx1 = m1.ctx();
/// l1.lock(&ctx1); // remote FAA on next_ticket, spin on now_serving
/// l1.unlock(&ctx1); // release fence, then advance now_serving
/// let ctx0 = m0.ctx();
/// assert_eq!(l0.with(&ctx0, || 21 * 2), 42); // closure under the lock
/// ```
pub struct TicketLock {
    mgr: Arc<Manager>,
    next_ticket: AtomicVar,
    now_serving: AtomicVar,
    local: Mutex<LocalState>,
    cv: Condvar,
    /// Fence scope run on release, before the lock becomes available.
    release_fence: FenceScope,
    /// Local-handover fast path enabled (ablation knob).
    handover: bool,
    /// Sticky "an acquire found this lock busy" flag, consumed by the
    /// kvstore's heat tracker ([`TicketLock::take_contended`]): a
    /// contended lock is the signal that its keys should cross to the
    /// op-shipping path sooner than their raw touch rate implies.
    contended: AtomicBool,
}

impl TicketLock {
    pub fn new(mgr: &Arc<Manager>, name: &str, host: NodeId) -> Self {
        Self::with_options(mgr, name, host, FenceScope::Thread, true, true)
    }

    /// `release_fence`: scope of the fence issued on release (paper:
    /// "LOCO fences used on release and specified by caller").
    /// `device`: host the lock words in NIC device memory.
    /// `handover`: enable the local-handover fast path.
    pub fn with_options(
        mgr: &Arc<Manager>,
        name: &str,
        host: NodeId,
        release_fence: FenceScope,
        device: bool,
        handover: bool,
    ) -> Self {
        let next_ticket =
            AtomicVar::with_initial(mgr, &sub_name(name, "next"), host, device, 0);
        let now_serving =
            AtomicVar::with_initial(mgr, &sub_name(name, "serving"), host, device, 0);
        TicketLock {
            mgr: mgr.clone(),
            next_ticket,
            now_serving,
            local: Mutex::new(LocalState { node_holds: false, local_active: false, waiters: 0 }),
            cv: Condvar::new(),
            release_fence,
            handover,
            contended: AtomicBool::new(false),
        }
    }

    /// Consume the contention flag: true iff some acquire since the
    /// last call found the lock held (a local thread inside, or a
    /// remote ticket ahead of ours). Relaxed — a lost race under-counts
    /// one observation, which the heat EWMA absorbs.
    pub fn take_contended(&self) -> bool {
        self.contended.swap(false, Ordering::Relaxed)
    }

    pub fn wait_ready(&self, timeout: Duration) {
        self.next_ticket.wait_ready(timeout);
        self.now_serving.wait_ready(timeout);
    }

    /// Non-blocking readiness probe (simulator services).
    pub fn is_ready(&self) -> bool {
        self.next_ticket.is_ready() && self.now_serving.is_ready()
    }

    /// Acquire the lock (blocking). Returns true if acquisition used the
    /// local-handover fast path (for tests/metrics).
    pub fn lock(&self, ctx: &ThreadCtx) -> bool {
        self.lock_inner(ctx, false).expect("unchecked lock path never errors")
    }

    /// Crash-stop-aware acquire: if the node hosting the lock words has
    /// crash-stopped (before or during acquisition), local state is
    /// unwound and `Err(Error::PeerFailed)` is returned instead of
    /// spinning on a corpse. A crashed *holder* is also bounded: once
    /// any node in the cluster is observably dead, the ticket ahead of
    /// us may belong to the corpse (its `now_serving` advance was never
    /// transmitted), so the spin gives up after a short grace period —
    /// a live holder's critical section is orders of magnitude shorter.
    /// Either way the lock is unrecoverable — callers treat the
    /// protected resource as read-only until the membership epoch
    /// re-homes it (see `docs/ARCHITECTURE.md § Failure model`). On
    /// success, returns whether the local-handover fast path was used,
    /// like [`TicketLock::lock`].
    pub fn try_lock(&self, ctx: &ThreadCtx) -> crate::Result<bool> {
        self.lock_inner(ctx, true)
    }

    /// Grace the checked spin allows a (possibly dead) ticket holder
    /// once a crash has been observed anywhere in the cluster.
    const DEAD_HOLDER_GRACE: Duration = Duration::from_millis(300);

    /// Roll back the local-state claim after a failed remote
    /// acquisition, waking one waiter so it can observe the failure too.
    fn unwind_local(&self) {
        let mut st = self.local.lock().unwrap();
        st.local_active = false;
        st.node_holds = false;
        drop(st);
        self.cv.notify_one();
    }

    fn lock_inner(&self, ctx: &ThreadCtx, checked: bool) -> crate::Result<bool> {
        if checked && ctx.node_down(self.next_ticket.host()) {
            return Err(crate::Error::PeerFailed(format!(
                "ticket lock host {} crash-stopped",
                self.next_ticket.host()
            )));
        }
        if self.handover {
            let mut st = self.local.lock().unwrap();
            loop {
                if st.local_active {
                    self.contended.store(true, Ordering::Relaxed);
                    st.waiters += 1;
                    st = self.cv.wait(st).unwrap();
                    st.waiters -= 1;
                    continue;
                }
                if st.node_holds {
                    // Handover: the node still owns the global ticket.
                    st.local_active = true;
                    drop(st);
                    // Same HB edge as a remote acquire — the previous
                    // holder's release hook ran before the condvar wake
                    // that let us in.
                    ctx.note_lock_acquire(self.now_serving.host(), self.now_serving.cell_addr());
                    return Ok(true);
                }
                // We are the node's representative: go remote.
                st.local_active = true;
                st.node_holds = true;
                break;
            }
        } else {
            // Without handover, still serialize local threads so each
            // holds its own global ticket in turn.
            let mut st = self.local.lock().unwrap();
            while st.local_active {
                self.contended.store(true, Ordering::Relaxed);
                st.waiters += 1;
                st = self.cv.wait(st).unwrap();
                st.waiters -= 1;
            }
            st.local_active = true;
            st.node_holds = true;
        }

        // Remote acquisition: classic ticket protocol. The checked path
        // bounds the wait: a crash of the host surfaces as an error CQE
        // on the very read we are spinning on.
        let my_ticket = if checked {
            match self.next_ticket.try_fetch_add(ctx, 1) {
                Ok(t) => t,
                Err(e) => {
                    self.unwind_local();
                    return Err(e);
                }
            }
        } else {
            self.next_ticket.fetch_add(ctx, 1)
        };
        let mut bo = Backoff::new();
        // Grace budget for a presumed-dead ticket holder: wall-clock in
        // threaded mode, a fixed pump count under the simulator (where
        // wall time never advances and elapsed() would never expire).
        let mut death_grace: Option<crate::util::WaitBudget> = None;
        // Even the unchecked spin is bounded (spin-loop-hinted backoff
        // plus a hard deadline): a wedged lock panics with a diagnosis
        // instead of silently pinning a core forever.
        let mut deadline = crate::util::WaitBudget::wedge(Duration::from_secs(30));
        loop {
            let serving = if checked {
                match self.now_serving.try_load(ctx) {
                    Ok(v) => v,
                    Err(e) => {
                        self.unwind_local();
                        return Err(e);
                    }
                }
            } else {
                self.now_serving.load(ctx)
            };
            if serving == my_ticket {
                break;
            }
            self.contended.store(true, Ordering::Relaxed);
            if checked && ctx.cluster_has_failures() {
                // The ticket being served may belong to a crash-stopped
                // holder whose unlock never transmitted; the host being
                // alive keeps the spin "healthy" forever. Give a live
                // holder a grace period, then declare the lock wedged.
                let grace = death_grace.get_or_insert_with(|| {
                    crate::util::WaitBudget::grace(Self::DEAD_HOLDER_GRACE, 256)
                });
                if grace.expired() {
                    self.unwind_local();
                    return Err(crate::Error::PeerFailed(format!(
                        "ticket {my_ticket} not served within the post-crash grace \
                         (holder of ticket {serving} presumed crashed)"
                    )));
                }
            }
            assert!(
                !deadline.expired(),
                "ticket lock wait wedged (30 s): ticket {my_ticket}, serving {serving}"
            );
            bo.snooze();
        }
        // Acquire edge for the race checker: join the last releaser's
        // history (the `now_serving` observation above is the physical
        // carrier of this edge).
        ctx.note_lock_acquire(self.now_serving.host(), self.now_serving.cell_addr());
        Ok(false)
    }

    /// Release the lock: run the release fence so protected writes are
    /// placed, then either hand over locally or advance `now_serving`.
    pub fn unlock(&self, ctx: &ThreadCtx) {
        match self.release_fence {
            FenceScope::Global => self.mgr.global_fence(ctx),
            scope => ctx.fence(scope),
        }
        // Release edge for the race checker: snapshot this critical
        // section's history under the lock key BEFORE the next holder
        // can possibly acquire (handover wake or `now_serving` advance,
        // both below).
        ctx.note_lock_release(self.now_serving.host(), self.now_serving.cell_addr());
        let mut st = self.local.lock().unwrap();
        debug_assert!(st.local_active, "unlock without lock");
        st.local_active = false;
        if self.handover && st.waiters > 0 {
            // Local handover: keep the global ticket.
            self.cv.notify_one();
            return;
        }
        st.node_holds = false;
        drop(st);
        self.cv.notify_one();
        self.now_serving.fetch_add(ctx, 1);
    }

    /// Run `f` under the lock.
    pub fn with<R>(&self, ctx: &ThreadCtx, f: impl FnOnce() -> R) -> R {
        self.lock(ctx);
        let r = f();
        self.unlock(ctx);
        r
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::{Cluster, FabricConfig, LatencyModel};
    use std::sync::atomic::{AtomicU64, Ordering};

    /// Mutual exclusion across nodes and threads: a shared (non-atomic
    /// increment) counter must not lose updates.
    fn mutex_stress(nodes: usize, threads_per_node: usize, iters: u64, handover: bool) {
        let cluster = Cluster::new(nodes, FabricConfig::threaded(LatencyModel::fast_sim()));
        let mgrs: Vec<Arc<Manager>> =
            (0..nodes as NodeId).map(|i| Manager::new(cluster.clone(), i)).collect();
        // The protected "resource": a plain pair of counters that would
        // race visibly without mutual exclusion.
        let shared = Arc::new((AtomicU64::new(0), AtomicU64::new(0)));
        let handles: Vec<_> = mgrs
            .iter()
            .map(|m| {
                let m = m.clone();
                let shared = shared.clone();
                std::thread::spawn(move || {
                    let lock = Arc::new(TicketLock::with_options(
                        &m,
                        "L",
                        0,
                        FenceScope::Thread,
                        true,
                        handover,
                    ));
                    lock.wait_ready(Duration::from_secs(10));
                    let ths: Vec<_> = (0..threads_per_node)
                        .map(|_| {
                            let m = m.clone();
                            let lock = lock.clone();
                            let shared = shared.clone();
                            std::thread::spawn(move || {
                                let ctx = m.ctx();
                                for _ in 0..iters {
                                    lock.lock(&ctx);
                                    // Non-atomic read-modify-write under the lock.
                                    let a = shared.0.load(Ordering::Relaxed);
                                    let b = shared.1.load(Ordering::Relaxed);
                                    assert_eq!(a, b, "lock violated: observed torn invariant");
                                    shared.0.store(a + 1, Ordering::Relaxed);
                                    shared.1.store(b + 1, Ordering::Relaxed);
                                    lock.unlock(&ctx);
                                }
                            })
                        })
                        .collect();
                    for t in ths {
                        t.join().unwrap();
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let total = (nodes * threads_per_node) as u64 * iters;
        assert_eq!(shared.0.load(Ordering::SeqCst), total, "lost updates");
    }

    #[test]
    fn cross_node_mutual_exclusion() {
        mutex_stress(3, 1, 60, true);
    }

    #[test]
    fn multi_thread_with_handover() {
        mutex_stress(2, 3, 40, true);
    }

    #[test]
    fn multi_thread_without_handover() {
        mutex_stress(2, 2, 40, false);
    }

    /// A crashed lock host must bound the wait: try_lock returns
    /// PeerFailed instead of spinning forever, and repeated attempts
    /// keep failing fast (local claim state is unwound each time).
    #[test]
    fn try_lock_bounded_on_crashed_host() {
        let cluster = Cluster::new(2, FabricConfig::inline_ideal());
        let m0 = Manager::new(cluster.clone(), 0);
        let m1 = Manager::new(cluster.clone(), 1);
        let l0 = TicketLock::new(&m0, "cl", 0);
        let l1 = TicketLock::new(&m1, "cl", 0);
        l0.wait_ready(Duration::from_secs(10));
        l1.wait_ready(Duration::from_secs(10));
        let ctx1 = m1.ctx();
        assert!(!l1.try_lock(&ctx1).expect("host alive"), "first acquire goes remote");
        l1.unlock(&ctx1);

        cluster.crash(0);
        for _ in 0..3 {
            assert!(
                matches!(l1.try_lock(&ctx1), Err(crate::Error::PeerFailed(_))),
                "try_lock must fail fast on a crashed host"
            );
        }
    }

    #[test]
    fn handover_fast_path_used() {
        let cluster = Cluster::new(2, FabricConfig::inline_ideal());
        let m0 = Manager::new(cluster.clone(), 0);
        let _m1 = Manager::new(cluster.clone(), 1);
        let lock = Arc::new(TicketLock::new(&m0, "h", 0));
        // Need both endpoints for readiness.
        let lock1 = TicketLock::new(&_m1, "h", 0);
        lock.wait_ready(Duration::from_secs(5));
        lock1.wait_ready(Duration::from_secs(5));

        let ctx = m0.ctx();
        assert!(!lock.lock(&ctx), "first acquire goes remote");
        // A second local thread queues up, then gets handover.
        let lock2 = lock.clone();
        let m0b = m0.clone();
        let h = std::thread::spawn(move || {
            let ctx2 = m0b.ctx();
            let handover = lock2.lock(&ctx2);
            lock2.unlock(&ctx2);
            handover
        });
        // Give the thread time to block.
        std::thread::sleep(Duration::from_millis(50));
        lock.unlock(&ctx);
        assert!(h.join().unwrap(), "second local acquire should be a handover");
    }
}
