//! The SST (Shared State Table), paper §5.1.2, after Derecho [30, 31].
//!
//! An array of single-writer multiple-reader registers, one per
//! participant (Fig. 2): node *i* is the owner of row *i*. Owners write
//! their row locally and push it to all peers; everyone reads all rows
//! locally. Composed directly from [`OwnedVar`] sub-channels — the
//! paper's showcase of channel composability.

use std::sync::Arc;
use std::time::Duration;

use crate::core::ack::AckKey;
use crate::core::ctx::ThreadCtx;
use crate::core::endpoint::sub_name;
use crate::core::manager::Manager;
use crate::fabric::NodeId;

use super::owned_var::OwnedVar;

pub struct Sst {
    /// Row i is the owned_var whose owner is node i.
    rows: Vec<OwnedVar>,
    me: NodeId,
    words: usize,
}

impl Sst {
    /// Construct the SST endpoint: one owned_var sub-channel per
    /// participant, namespaced `"<name>/ov<i>"`.
    pub fn new(mgr: &Arc<Manager>, name: &str, words: usize) -> Self {
        let n = mgr.num_nodes();
        let rows = (0..n as NodeId)
            .map(|owner| OwnedVar::new(mgr, &sub_name(name, &format!("ov{owner}")), owner, words, false))
            .collect();
        Sst { rows, me: mgr.me(), words }
    }

    pub fn wait_ready(&self, timeout: Duration) {
        for row in &self.rows {
            row.wait_ready(timeout);
        }
    }

    pub fn num_rows(&self) -> usize {
        self.rows.len()
    }

    pub fn words(&self) -> usize {
        self.words
    }

    /// Write this node's row (local store; not yet visible to peers).
    pub fn store_mine(&self, ctx: &ThreadCtx, value: &[u64]) {
        self.rows[self.me as usize].store_local(ctx, value);
    }

    /// Push this node's row to all peers; returns the unioned ack_key
    /// (one remote write per peer — §5.2's composite-operation example).
    pub fn push_broadcast(&self, ctx: &ThreadCtx) -> AckKey {
        self.rows[self.me as usize].push_broadcast(ctx)
    }

    /// Store + broadcast.
    pub fn publish_mine(&self, ctx: &ThreadCtx, value: &[u64]) -> AckKey {
        self.store_mine(ctx, value);
        self.push_broadcast(ctx)
    }

    /// Read node `i`'s row from the local cache (checksum-retried for
    /// multi-word rows).
    pub fn read_row(&self, ctx: &ThreadCtx, i: NodeId) -> Vec<u64> {
        if i == self.me {
            let mut v = vec![0u64; self.words];
            let own = self.rows[i as usize].own_region().unwrap();
            for (k, o) in v.iter_mut().enumerate() {
                *o = ctx.local_load(own, k as u64);
            }
            v
        } else {
            self.rows[i as usize].read_cached(ctx)
        }
    }

    /// Single-word row read (the common case, e.g. the barrier).
    pub fn read_row1(&self, ctx: &ThreadCtx, i: NodeId) -> u64 {
        self.read_row(ctx, i)[0]
    }

    /// Iterate all rows (paper Fig. 1a's `for (auto& row : sst)`).
    pub fn rows1(&self, ctx: &ThreadCtx) -> Vec<u64> {
        (0..self.rows.len() as NodeId).map(|i| self.read_row1(ctx, i)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::{Cluster, FabricConfig};

    #[test]
    fn all_rows_visible_everywhere() {
        let n = 3;
        let cluster = Cluster::new(n, FabricConfig::inline_ideal());
        let mgrs: Vec<Arc<Manager>> =
            (0..n as NodeId).map(|i| Manager::new(cluster.clone(), i)).collect();
        let ssts: Vec<Sst> = mgrs.iter().map(|m| Sst::new(m, "sst", 1)).collect();
        for s in &ssts {
            s.wait_ready(Duration::from_secs(10));
        }
        let ctxs: Vec<_> = mgrs.iter().map(|m| m.ctx()).collect();
        for i in 0..n {
            ssts[i].publish_mine(&ctxs[i], &[(i as u64 + 1) * 11]).wait();
        }
        for i in 0..n {
            assert_eq!(ssts[i].rows1(&ctxs[i]), vec![11, 22, 33], "node {i} view");
        }
    }

    #[test]
    fn multiword_rows() {
        let n = 2;
        let cluster = Cluster::new(n, FabricConfig::inline_ideal());
        let mgrs: Vec<Arc<Manager>> =
            (0..n as NodeId).map(|i| Manager::new(cluster.clone(), i)).collect();
        let ssts: Vec<Sst> = mgrs.iter().map(|m| Sst::new(m, "wide", 3)).collect();
        for s in &ssts {
            s.wait_ready(Duration::from_secs(10));
        }
        let ctx0 = mgrs[0].ctx();
        let ctx1 = mgrs[1].ctx();
        ssts[0].publish_mine(&ctx0, &[1, 2, 3]).wait();
        ssts[1].publish_mine(&ctx1, &[4, 5, 6]).wait();
        assert_eq!(ssts[1].read_row(&ctx1, 0), vec![1, 2, 3]);
        assert_eq!(ssts[0].read_row(&ctx0, 1), vec![4, 5, 6]);
        assert_eq!(ssts[0].read_row(&ctx0, 0), vec![1, 2, 3], "own row readback");
    }
}
