//! The SST (Shared State Table), paper §5.1.2, after Derecho [30, 31].
//!
//! An array of single-writer multiple-reader registers, one per
//! participant (Fig. 2): node *i* is the owner of row *i*. Owners write
//! their row locally and push it to all peers; everyone reads all rows
//! locally. Composed directly from [`OwnedVar`] sub-channels — the
//! paper's showcase of channel composability.

use std::sync::Arc;
use std::time::Duration;

use crate::core::ack::AckKey;
use crate::core::ctx::ThreadCtx;
use crate::core::endpoint::sub_name;
use crate::core::manager::Manager;
use crate::fabric::{NodeId, Region};
use crate::util::fnv64;

use super::owned_var::OwnedVar;

/// The Shared State Table: one single-writer row per participant.
///
/// # Examples
///
/// ```
/// use std::time::Duration;
/// use loco::channels::Sst;
/// use loco::core::manager::Manager;
/// use loco::fabric::{Cluster, FabricConfig};
///
/// let cluster = Cluster::new(2, FabricConfig::inline_ideal());
/// let m0 = Manager::new(cluster.clone(), 0);
/// let m1 = Manager::new(cluster.clone(), 1);
/// let s0 = Sst::new(&m0, "sst", 1);
/// let s1 = Sst::new(&m1, "sst", 1);
/// s0.wait_ready(Duration::from_secs(10));
/// s1.wait_ready(Duration::from_secs(10));
///
/// let ctx0 = m0.ctx();
/// let ctx1 = m1.ctx();
/// s0.publish_mine(&ctx0, &[7]).wait();
/// s1.publish_mine(&ctx1, &[9]).wait();
/// // Every node reads all rows from its local caches…
/// assert_eq!(s0.rows1(&ctx0), vec![7, 9]);
/// // …or pulls the authoritative copies in one batched scan.
/// assert_eq!(s1.pull_all(&ctx1), vec![vec![7], vec![9]]);
/// ```
pub struct Sst {
    /// Row i is the owned_var whose owner is node i.
    rows: Vec<OwnedVar>,
    me: NodeId,
    words: usize,
}

impl Sst {
    /// Construct the SST endpoint: one owned_var sub-channel per
    /// participant, namespaced `"<name>/ov<i>"`.
    pub fn new(mgr: &Arc<Manager>, name: &str, words: usize) -> Self {
        let n = mgr.num_nodes();
        let rows = (0..n as NodeId)
            .map(|owner| OwnedVar::new(mgr, &sub_name(name, &format!("ov{owner}")), owner, words, false))
            .collect();
        Sst { rows, me: mgr.me(), words }
    }

    pub fn wait_ready(&self, timeout: Duration) {
        for row in &self.rows {
            row.wait_ready(timeout);
        }
    }

    /// Non-blocking readiness probe (simulator services).
    pub fn is_ready(&self) -> bool {
        self.rows.iter().all(|r| r.is_ready())
    }

    pub fn num_rows(&self) -> usize {
        self.rows.len()
    }

    pub fn words(&self) -> usize {
        self.words
    }

    /// Write this node's row (local store; not yet visible to peers).
    pub fn store_mine(&self, ctx: &ThreadCtx, value: &[u64]) {
        self.rows[self.me as usize].store_local(ctx, value);
    }

    /// Push this node's row to all peers; returns the unioned ack_key
    /// (one remote write per peer — §5.2's composite-operation example).
    pub fn push_broadcast(&self, ctx: &ThreadCtx) -> AckKey {
        self.rows[self.me as usize].push_broadcast(ctx)
    }

    /// Store + broadcast.
    pub fn publish_mine(&self, ctx: &ThreadCtx, value: &[u64]) -> AckKey {
        self.store_mine(ctx, value);
        self.push_broadcast(ctx)
    }

    /// Read node `i`'s row from the local cache (checksum-retried for
    /// multi-word rows).
    pub fn read_row(&self, ctx: &ThreadCtx, i: NodeId) -> Vec<u64> {
        if i == self.me {
            let mut v = vec![0u64; self.words];
            let own = self.rows[i as usize].own_region().unwrap();
            for (k, o) in v.iter_mut().enumerate() {
                *o = ctx.local_load(own, k as u64);
            }
            v
        } else {
            self.rows[i as usize].read_cached(ctx)
        }
    }

    /// Single-word row read (the common case, e.g. the barrier).
    pub fn read_row1(&self, ctx: &ThreadCtx, i: NodeId) -> u64 {
        self.read_row(ctx, i)[0]
    }

    /// Iterate all rows (paper Fig. 1a's `for (auto& row : sst)`).
    pub fn rows1(&self, ctx: &ThreadCtx) -> Vec<u64> {
        (0..self.rows.len() as NodeId).map(|i| self.read_row1(ctx, i)).collect()
    }

    /// Pull the **authoritative** copy of every row in one batched scan:
    /// all remote row reads are issued asynchronously through the
    /// batched pipeline (ack tracking allocated once for the whole scan)
    /// and awaited together — one overlapped round trip instead of
    /// n − 1 sequential blocking pulls. (Rows and owners are 1:1, so
    /// each owner still gets its own doorbell; the win is the overlap
    /// and the single wait.) Rows that validate are returned; a row
    /// caught mid-placement (checksum mismatch, multi-word rows only)
    /// falls back to the scalar retry of [`OwnedVar::pull`].
    ///
    /// Unlike [`OwnedVar::pull`] this does not refresh the local caches;
    /// it is the snapshot-scan primitive for schedulers and monitors.
    pub fn pull_all(&self, ctx: &ThreadCtx) -> Vec<Vec<u64>> {
        let slot = if self.words > 1 { self.words + 1 } else { 1 };
        let reqs: Vec<(Region, u64, usize)> = (0..self.rows.len())
            .map(|i| {
                let region = if i == self.me as usize {
                    self.rows[i].own_region().expect("own row has an authoritative copy")
                } else {
                    self.rows[i].endpoint().remote_region(i as NodeId, "own")
                };
                (region, 0, slot)
            })
            .collect();
        let raw = ctx.read_many(&reqs);
        raw.iter()
            .enumerate()
            .map(|(i, buf)| {
                if self.words == 1 {
                    return vec![buf[0]];
                }
                let (value, ck) = buf.split_at(self.words);
                if fnv64(value) == ck[0] {
                    value.to_vec()
                } else {
                    // Torn read raced a placement: scalar checksum-retry.
                    self.rows[i].pull(ctx)
                }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::{Cluster, FabricConfig};

    #[test]
    fn all_rows_visible_everywhere() {
        let n = 3;
        let cluster = Cluster::new(n, FabricConfig::inline_ideal());
        let mgrs: Vec<Arc<Manager>> =
            (0..n as NodeId).map(|i| Manager::new(cluster.clone(), i)).collect();
        let ssts: Vec<Sst> = mgrs.iter().map(|m| Sst::new(m, "sst", 1)).collect();
        for s in &ssts {
            s.wait_ready(Duration::from_secs(10));
        }
        let ctxs: Vec<_> = mgrs.iter().map(|m| m.ctx()).collect();
        for i in 0..n {
            ssts[i].publish_mine(&ctxs[i], &[(i as u64 + 1) * 11]).wait();
        }
        for i in 0..n {
            assert_eq!(ssts[i].rows1(&ctxs[i]), vec![11, 22, 33], "node {i} view");
        }
    }

    #[test]
    fn multiword_rows() {
        let n = 2;
        let cluster = Cluster::new(n, FabricConfig::inline_ideal());
        let mgrs: Vec<Arc<Manager>> =
            (0..n as NodeId).map(|i| Manager::new(cluster.clone(), i)).collect();
        let ssts: Vec<Sst> = mgrs.iter().map(|m| Sst::new(m, "wide", 3)).collect();
        for s in &ssts {
            s.wait_ready(Duration::from_secs(10));
        }
        let ctx0 = mgrs[0].ctx();
        let ctx1 = mgrs[1].ctx();
        ssts[0].publish_mine(&ctx0, &[1, 2, 3]).wait();
        ssts[1].publish_mine(&ctx1, &[4, 5, 6]).wait();
        assert_eq!(ssts[1].read_row(&ctx1, 0), vec![1, 2, 3]);
        assert_eq!(ssts[0].read_row(&ctx0, 1), vec![4, 5, 6]);
        assert_eq!(ssts[0].read_row(&ctx0, 0), vec![1, 2, 3], "own row readback");
    }

    /// pull_all returns every authoritative row (multi-word, checksum
    /// validated) in one batched scan, on a racy threaded fabric.
    #[test]
    fn pull_all_batched_scan() {
        let n = 3;
        let mut lat = crate::fabric::LatencyModel::fast_sim();
        lat.placement_lag_ns = 2000;
        let cluster = Cluster::new(n, FabricConfig::threaded(lat));
        let mgrs: Vec<Arc<Manager>> =
            (0..n as NodeId).map(|i| Manager::new(cluster.clone(), i)).collect();
        let ssts: Vec<Sst> = mgrs.iter().map(|m| Sst::new(m, "scan", 2)).collect();
        for s in &ssts {
            s.wait_ready(Duration::from_secs(10));
        }
        let ctxs: Vec<_> = mgrs.iter().map(|m| m.ctx()).collect();
        for i in 0..n {
            // store_local only: pull_all must fetch authoritative copies,
            // not rely on pushes having happened.
            ssts[i].store_mine(&ctxs[i], &[i as u64 + 1, (i as u64 + 1) * 100]);
        }
        for i in 0..n {
            let rows = ssts[i].pull_all(&ctxs[i]);
            assert_eq!(
                rows,
                vec![vec![1, 100], vec![2, 200], vec![3, 300]],
                "node {i} batched scan"
            );
        }
    }
}
