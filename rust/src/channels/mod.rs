//! The LOCO channel catalogue (paper §5).
//!
//! Core memory-access channels:
//! * [`owned_var`] — single-writer multi-reader register with push/pull
//!   update strategies and checksum atomicity for >word values (§5.1.1).
//! * [`atomic_var`] — multi-writer word-size register with an "official"
//!   copy on one host, exposing remote atomics (§5.1.1).
//! * [`sst`] — the Shared State Table: one owned_var row per participant
//!   (§5.1.2, after Derecho).
//!
//! Complex channels (§5.4):
//! * [`ticket_lock`] — cross-node ticket lock with local-handover fast
//!   path and caller-specified release fence.
//! * [`barrier`] — SST counting barrier (Fig. 1a).
//! * [`ringbuffer`] — one-to-many broadcast ring with mixed-size
//!   messages and SST-based receiver acknowledgements.
//! * [`request_ring`] — served op-shipping (RPC) ring: one WRITE ships
//!   a whole operation to its home node, one WRITE carries the reply
//!   (the kvstore's hot-key routing target).
//! * [`shared_queue`] — globally consistent MPMC FIFO queue, striped
//!   across participants (cyclic ring queue adapted for RDMA).
//! * [`read_cache`] — bounded per-node hot-key value cache with
//!   epoch-validated fills and broadcast invalidation (the kvstore's
//!   locality tier).

pub mod atomic_var;
pub mod barrier;
pub mod owned_var;
pub mod read_cache;
pub mod request_ring;
pub mod ringbuffer;
pub mod shared_queue;
pub mod sst;
pub mod ticket_lock;

pub use atomic_var::AtomicVar;
pub use barrier::Barrier;
pub use owned_var::OwnedVar;
pub use read_cache::ReadCache;
pub use request_ring::{OpReq, Reply, RequestRing};
pub use ringbuffer::{RingReceiver, RingSender};
pub use shared_queue::SharedQueue;
pub use sst::Sst;
pub use ticket_lock::TicketLock;
