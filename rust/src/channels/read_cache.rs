//! `read_cache` — a bounded per-node hot-key value cache, the second leg
//! of the kvstore's **locality tier** (paper §1/§7: channel objects
//! should let the programmer *exploit* locality rather than hide it).
//!
//! Skewed workloads (Zipfian θ=0.99, the paper's §7.2 distribution) read
//! the same handful of keys over and over; without a cache every repeat
//! `get` pays a full remote READ for bytes fetched microseconds ago. The
//! read cache serves those repeats from local memory while preserving
//! the kvstore's consistency story:
//!
//! * **Hit rule.** An entry is stored as `(key → value, counter)` where
//!   `counter` is the slot-reuse generation from the location index. A
//!   hit is served only when the caller's *current* index entry carries
//!   the same counter — a key that was deleted (index entry gone) or
//!   re-inserted (new counter) can never be served stale.
//! * **Invalidation.** In-place updates don't bump the counter, so the
//!   kvstore broadcasts invalidations on its (already running) tracker
//!   ring; the tracker applies them here before acknowledging. A
//!   mutation therefore cannot return until every node's cache has
//!   dropped the key.
//! * **Fill/invalidate race.** A reader may fetch an old value remotely,
//!   get descheduled, and try to insert it *after* the invalidation was
//!   applied — re-poisoning the cache forever. Each cache shard keeps a
//!   **fill epoch**: readers snapshot it (via [`ReadCache::begin_fill`])
//!   before issuing the remote READ, and [`ReadCache::fill`] rejects the
//!   insert if the shard's epoch moved since. Invalidation bumps the
//!   epoch under the shard lock, closing the race.
//!
//! Capacity is bounded; eviction is CLOCK-style second chance (hits set
//! a reference bit, the evictor clears bits until it finds a cold
//! entry), which under Zipfian skew keeps the hot head pinned.
//!
//! # Examples
//!
//! ```
//! use loco::channels::read_cache::ReadCache;
//!
//! let cache = ReadCache::new(256);
//! // Miss: nothing cached for (key=7, counter=1).
//! assert_eq!(cache.lookup(7, 1), None);
//! // Fill under an epoch token, as the kvstore read path does.
//! let token = cache.begin_fill(7);
//! assert!(cache.fill(token, 7, 1, &[42]));
//! assert_eq!(cache.lookup(7, 1), Some(vec![42]));
//! // A new slot generation (counter 2) never hits the stale entry.
//! assert_eq!(cache.lookup(7, 2), None);
//! // An invalidation between begin_fill and fill rejects the fill.
//! let token = cache.begin_fill(8);
//! cache.invalidate(8);
//! assert!(!cache.fill(token, 8, 1, &[9]));
//! assert_eq!(cache.lookup(8, 1), None);
//! ```

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Minimum shard count; scales up with capacity to keep the per-shard
/// mutex uncontended (the cache shards are disjoint from the location
/// index's shards — a cache lock never delays an index reader).
const MIN_SHARDS: usize = 8;
const MAX_SHARDS: usize = 64;

struct CacheEntry {
    value: Box<[u64]>,
    counter: u64,
    /// CLOCK reference bit.
    hot: bool,
}

struct CacheShard {
    /// Fill epoch: bumped by every invalidation of a key in this shard.
    epoch: AtomicU64,
    map: Mutex<HashMap<u64, CacheEntry>>,
}

/// Epoch snapshot taken before a remote READ; consumed by
/// [`ReadCache::fill`].
#[derive(Clone, Copy, Debug)]
pub struct FillToken {
    shard: usize,
    epoch: u64,
}

/// Cumulative counters (monotonic; sampled by benches and tests).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub fills: u64,
    pub rejected_fills: u64,
    pub invalidations: u64,
    pub evictions: u64,
}

impl CacheStats {
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

/// The bounded hot-key value cache. See the module docs for the
/// validation protocol.
pub struct ReadCache {
    shards: Box<[CacheShard]>,
    shard_mask: u64,
    per_shard_cap: usize,
    hits: AtomicU64,
    misses: AtomicU64,
    fills: AtomicU64,
    rejected_fills: AtomicU64,
    invalidations: AtomicU64,
    evictions: AtomicU64,
}

impl ReadCache {
    /// A cache holding at most ~`capacity` entries.
    pub fn new(capacity: usize) -> ReadCache {
        let shards = (capacity / 32).next_power_of_two().clamp(MIN_SHARDS, MAX_SHARDS);
        ReadCache {
            shards: (0..shards)
                .map(|_| CacheShard { epoch: AtomicU64::new(0), map: Mutex::new(HashMap::new()) })
                .collect(),
            shard_mask: shards as u64 - 1,
            per_shard_cap: capacity.div_ceil(shards).max(1),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            fills: AtomicU64::new(0),
            rejected_fills: AtomicU64::new(0),
            invalidations: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
        }
    }

    /// Zipfian-aware sizing (§7.2's θ=0.99 skew): under YCSB-C Zipfian
    /// the most popular `c` of `n` keys draw roughly `ln c / ln n` of
    /// all accesses, so a cache holding a quarter of the keyspace
    /// already absorbs the large majority of reads; beyond 64 Ki entries
    /// the marginal hit rate no longer pays for the memory.
    pub fn zipfian_capacity(keyspace: u64) -> usize {
        (keyspace as usize / 4).clamp(256, 1 << 16)
    }

    #[inline]
    fn shard_index(&self, key: u64) -> usize {
        (crate::util::mix64(key) & self.shard_mask) as usize
    }

    /// Serve `key` if the cached generation matches the caller's current
    /// index `counter`. A stale generation is dropped on sight.
    pub fn lookup(&self, key: u64, counter: u64) -> Option<Vec<u64>> {
        let shard = &self.shards[self.shard_index(key)];
        let mut map = shard.map.lock().unwrap();
        let stale = match map.get_mut(&key) {
            Some(e) if e.counter == counter => {
                e.hot = true;
                self.hits.fetch_add(1, Ordering::Relaxed);
                return Some(e.value.to_vec());
            }
            Some(_) => true, // stale generation: drop it below
            None => false,
        };
        if stale {
            map.remove(&key);
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        None
    }

    /// Snapshot the fill epoch of `key`'s shard. Must be taken **before**
    /// the remote READ whose result may be filled.
    pub fn begin_fill(&self, key: u64) -> FillToken {
        let shard = self.shard_index(key);
        FillToken { shard, epoch: self.shards[shard].epoch.load(Ordering::Acquire) }
    }

    /// Insert a validated read result. Rejected (returns `false`) if any
    /// invalidation touched the shard since `token` was taken — the value
    /// may predate a concurrent mutation.
    pub fn fill(&self, token: FillToken, key: u64, counter: u64, value: &[u64]) -> bool {
        let shard = &self.shards[token.shard];
        debug_assert_eq!(token.shard, self.shard_index(key), "token/key shard mismatch");
        let mut map = shard.map.lock().unwrap();
        // Epoch check under the shard lock: invalidations bump the epoch
        // under the same lock, so this is race-free.
        if shard.epoch.load(Ordering::Acquire) != token.epoch {
            self.rejected_fills.fetch_add(1, Ordering::Relaxed);
            return false;
        }
        if map.len() >= self.per_shard_cap && !map.contains_key(&key) {
            self.evict_one(&mut map);
        }
        map.insert(key, CacheEntry { value: value.into(), counter, hot: false });
        self.fills.fetch_add(1, Ordering::Relaxed);
        true
    }

    /// CLOCK second chance over the shard's (arbitrary) iteration order:
    /// clear reference bits until a cold entry turns up, then evict it.
    fn evict_one(&self, map: &mut HashMap<u64, CacheEntry>) {
        let mut victim = None;
        for (k, e) in map.iter_mut() {
            if e.hot {
                e.hot = false; // second chance
            } else {
                victim = Some(*k);
                break;
            }
        }
        // Every entry was hot: take the first (now-cold) one.
        let victim = victim.or_else(|| map.keys().next().copied());
        if let Some(k) = victim {
            map.remove(&k);
            self.evictions.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Drop `key` and bump its shard's fill epoch (killing in-flight
    /// fills that may carry the pre-mutation value).
    pub fn invalidate(&self, key: u64) {
        let shard = &self.shards[self.shard_index(key)];
        let mut map = shard.map.lock().unwrap();
        shard.epoch.fetch_add(1, Ordering::AcqRel);
        map.remove(&key);
        self.invalidations.fetch_add(1, Ordering::Relaxed);
    }

    /// Invalidate a batch of keys (one lock round per distinct shard
    /// would be nicer; at tracker-application rates per-key is fine).
    pub fn invalidate_many(&self, keys: impl IntoIterator<Item = u64>) {
        for k in keys {
            self.invalidate(k);
        }
    }

    /// Drop **everything** and bump every shard's fill epoch, so fills
    /// begun under the old membership epoch can never land. The kvstore
    /// calls this when a node crash-stops: entries cached from the dead
    /// epoch — including values homed on the dead node that are about to
    /// be re-homed under fresh generation counters — must not survive
    /// into the new one.
    pub fn clear(&self) {
        for shard in self.shards.iter() {
            let mut map = shard.map.lock().unwrap();
            shard.epoch.fetch_add(1, Ordering::AcqRel);
            self.invalidations.fetch_add(map.len() as u64, Ordering::Relaxed);
            map.clear();
        }
    }

    /// Total cached entries (racy; for tests and monitoring).
    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.map.lock().unwrap().len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn stats(&self) -> CacheStats {
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            fills: self.fills.load(Ordering::Relaxed),
            rejected_fills: self.rejected_fills.load(Ordering::Relaxed),
            invalidations: self.invalidations.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hit_miss_and_generation_check() {
        let c = ReadCache::new(64);
        assert_eq!(c.lookup(1, 5), None);
        let t = c.begin_fill(1);
        assert!(c.fill(t, 1, 5, &[10, 11]));
        assert_eq!(c.lookup(1, 5), Some(vec![10, 11]));
        // Different generation: miss, and the stale entry is dropped.
        assert_eq!(c.lookup(1, 6), None);
        assert_eq!(c.lookup(1, 5), None);
        let s = c.stats();
        assert_eq!((s.hits, s.fills), (1, 1));
        assert!(s.misses >= 3);
    }

    #[test]
    fn invalidation_rejects_in_flight_fill() {
        let c = ReadCache::new(64);
        let t = c.begin_fill(9);
        c.invalidate(9);
        assert!(!c.fill(t, 9, 1, &[7]), "fill must lose the race");
        assert_eq!(c.lookup(9, 1), None);
        // A fresh token after the invalidation fills fine.
        let t = c.begin_fill(9);
        assert!(c.fill(t, 9, 1, &[7]));
        assert_eq!(c.lookup(9, 1), Some(vec![7]));
        assert_eq!(c.stats().rejected_fills, 1);
    }

    #[test]
    fn bounded_with_clock_eviction_keeps_hot_keys() {
        let c = ReadCache::new(32);
        // Fill beyond capacity; key 0 is kept hot by lookups.
        for k in 0..256u64 {
            let t = c.begin_fill(k);
            c.fill(t, k, 1, &[k]);
            c.lookup(0, 1);
        }
        assert!(c.len() <= 32 + MAX_SHARDS, "cache unbounded: {}", c.len());
        assert!(c.stats().evictions > 0);
        assert_eq!(c.lookup(0, 1), Some(vec![0]), "hot key evicted");
    }

    #[test]
    fn invalidate_many_clears_keys() {
        let c = ReadCache::new(64);
        for k in 0..8u64 {
            let t = c.begin_fill(k);
            c.fill(t, k, 1, &[k]);
        }
        c.invalidate_many(0..8u64);
        assert!(c.is_empty());
        assert_eq!(c.stats().invalidations, 8);
    }

    #[test]
    fn clear_drops_all_and_poisons_in_flight_fills() {
        let c = ReadCache::new(64);
        let stale_token = c.begin_fill(3);
        for k in 0..8u64 {
            let t = c.begin_fill(k);
            assert!(c.fill(t, k, 1, &[k]));
        }
        c.clear();
        assert!(c.is_empty(), "clear must drop every shard");
        // A fill begun before the clear (dead membership epoch) loses.
        assert!(!c.fill(stale_token, 3, 1, &[9]), "pre-clear token must be rejected");
        assert_eq!(c.lookup(3, 1), None);
    }

    #[test]
    fn zipfian_sizing_clamped() {
        assert_eq!(ReadCache::zipfian_capacity(100), 256);
        assert_eq!(ReadCache::zipfian_capacity(1 << 14), 1 << 12);
        assert_eq!(ReadCache::zipfian_capacity(1 << 30), 1 << 16);
    }
}
