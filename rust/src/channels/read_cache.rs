//! `read_cache` — a bounded per-node hot-key value cache, the second leg
//! of the kvstore's **locality tier** (paper §1/§7: channel objects
//! should let the programmer *exploit* locality rather than hide it).
//!
//! Skewed workloads (Zipfian θ=0.99, the paper's §7.2 distribution) read
//! the same handful of keys over and over; without a cache every repeat
//! `get` pays a full remote READ for bytes fetched microseconds ago. The
//! read cache serves those repeats from local memory while preserving
//! the kvstore's consistency story:
//!
//! * **Hit rule.** An entry is stored as `(key → value, counter)` where
//!   `counter` is the slot-reuse generation from the location index. A
//!   hit is served only when the caller's *current* index entry carries
//!   the same counter — a key that was deleted (index entry gone) or
//!   re-inserted (new counter) can never be served stale.
//! * **Invalidation.** In-place updates don't bump the counter, so the
//!   kvstore broadcasts invalidations on its (already running) tracker
//!   ring; the tracker applies them here before acknowledging. A
//!   mutation therefore cannot return until every node's cache has
//!   dropped the key.
//! * **Fill/invalidate race.** A reader may fetch an old value remotely,
//!   get descheduled, and try to insert it *after* the invalidation was
//!   applied — re-poisoning the cache forever. Each cache shard keeps a
//!   **fill epoch**: readers snapshot it (via [`ReadCache::begin_fill`])
//!   before issuing the remote READ, and [`ReadCache::fill`] rejects the
//!   insert if the shard's epoch moved since. Invalidation bumps the
//!   epoch under the shard lock, closing the race.
//!
//! Capacity is a **byte budget**, not an entry count — values are
//! variable-size (the kvstore's slab-allocated frames run from one word
//! to kilobytes), and an entry-count bound would let a handful of 1 KB
//! values occupy unbounded memory while starving nothing. Each entry is
//! charged its value bytes plus a fixed overhead
//! ([`ReadCache::entry_bytes`]); fills evict until the budget holds.
//! Eviction is CLOCK-style second chance (hits set a reference bit, the
//! evictor clears bits until it finds a cold entry), which under
//! Zipfian skew keeps the hot head pinned.
//!
//! # Examples
//!
//! ```
//! use loco::channels::read_cache::ReadCache;
//!
//! let cache = ReadCache::new(64 * 1024); // 64 KiB budget
//! // Miss: nothing cached for (key=7, counter=1).
//! assert_eq!(cache.lookup(7, 1), None);
//! // Fill under an epoch token, as the kvstore read path does.
//! let token = cache.begin_fill(7);
//! assert!(cache.fill(token, 7, 1, &[42]));
//! assert_eq!(cache.lookup(7, 1), Some(vec![42]));
//! // A new slot generation (counter 2) never hits the stale entry.
//! assert_eq!(cache.lookup(7, 2), None);
//! // An invalidation between begin_fill and fill rejects the fill.
//! let token = cache.begin_fill(8);
//! cache.invalidate(8);
//! assert!(!cache.fill(token, 8, 1, &[9]));
//! assert_eq!(cache.lookup(8, 1), None);
//! ```

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Minimum shard count; scales up with capacity to keep the per-shard
/// mutex uncontended (the cache shards are disjoint from the location
/// index's shards — a cache lock never delays an index reader).
const MIN_SHARDS: usize = 8;
const MAX_SHARDS: usize = 64;

struct CacheEntry {
    value: Box<[u64]>,
    counter: u64,
    /// CLOCK reference bit.
    hot: bool,
}

struct ShardMap {
    map: HashMap<u64, CacheEntry>,
    /// Bytes charged against this shard's budget (values + overhead).
    used: usize,
}

struct CacheShard {
    /// Fill epoch: bumped by every invalidation of a key in this shard.
    epoch: AtomicU64,
    map: Mutex<ShardMap>,
}

/// Epoch snapshot taken before a remote READ; consumed by
/// [`ReadCache::fill`].
#[derive(Clone, Copy, Debug)]
pub struct FillToken {
    shard: usize,
    epoch: u64,
}

/// Cumulative counters (monotonic; sampled by benches and tests).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub fills: u64,
    pub rejected_fills: u64,
    pub invalidations: u64,
    pub evictions: u64,
}

impl CacheStats {
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

/// The bounded hot-key value cache. See the module docs for the
/// validation protocol.
pub struct ReadCache {
    shards: Box<[CacheShard]>,
    shard_mask: u64,
    per_shard_budget: usize,
    hits: AtomicU64,
    misses: AtomicU64,
    fills: AtomicU64,
    rejected_fills: AtomicU64,
    invalidations: AtomicU64,
    evictions: AtomicU64,
}

impl ReadCache {
    /// Fixed per-entry overhead charged against the byte budget (key,
    /// generation, flags, map slot — a deliberate round number so
    /// budgets are easy to reason about).
    const ENTRY_OVERHEAD_BYTES: usize = 32;

    /// Bytes an entry holding a `value_words`-word value is charged.
    pub fn entry_bytes(value_words: usize) -> usize {
        Self::ENTRY_OVERHEAD_BYTES + value_words * 8
    }

    /// A cache bounded by ~`budget_bytes` of cached state (values plus
    /// per-entry overhead, split evenly across the shards).
    pub fn new(budget_bytes: usize) -> ReadCache {
        let shards = (budget_bytes / 1024).next_power_of_two().clamp(MIN_SHARDS, MAX_SHARDS);
        ReadCache {
            shards: (0..shards)
                .map(|_| CacheShard {
                    epoch: AtomicU64::new(0),
                    map: Mutex::new(ShardMap { map: HashMap::new(), used: 0 }),
                })
                .collect(),
            shard_mask: shards as u64 - 1,
            per_shard_budget: budget_bytes.div_ceil(shards).max(Self::entry_bytes(1)),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            fills: AtomicU64::new(0),
            rejected_fills: AtomicU64::new(0),
            invalidations: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
        }
    }

    /// Zipfian-aware sizing (§7.2's θ=0.99 skew), in **entries**: under
    /// YCSB-C Zipfian the most popular `c` of `n` keys draw roughly
    /// `ln c / ln n` of all accesses, so a cache holding a quarter of
    /// the keyspace already absorbs the large majority of reads; beyond
    /// 64 Ki entries the marginal hit rate no longer pays for the
    /// memory. Multiply by [`ReadCache::entry_bytes`] for the byte
    /// budget (as [`crate::apps::kvstore::KvConfig::with_zipfian_cache`]
    /// does).
    pub fn zipfian_capacity(keyspace: u64) -> usize {
        (keyspace as usize / 4).clamp(256, 1 << 16)
    }

    #[inline]
    fn shard_index(&self, key: u64) -> usize {
        (crate::util::mix64(key) & self.shard_mask) as usize
    }

    /// Serve `key` if the cached generation matches the caller's current
    /// index `counter`. A stale generation is dropped on sight.
    pub fn lookup(&self, key: u64, counter: u64) -> Option<Vec<u64>> {
        let shard = &self.shards[self.shard_index(key)];
        let mut sm = shard.map.lock().unwrap();
        let stale = match sm.map.get_mut(&key) {
            Some(e) if e.counter == counter => {
                e.hot = true;
                self.hits.fetch_add(1, Ordering::Relaxed);
                return Some(e.value.to_vec());
            }
            Some(_) => true, // stale generation: drop it below
            None => false,
        };
        if stale {
            Self::remove_entry(&mut sm, key);
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        None
    }

    /// Remove `key` from a locked shard, refunding its budget charge.
    fn remove_entry(sm: &mut ShardMap, key: u64) -> bool {
        match sm.map.remove(&key) {
            Some(e) => {
                sm.used -= Self::entry_bytes(e.value.len());
                true
            }
            None => false,
        }
    }

    /// Snapshot the fill epoch of `key`'s shard. Must be taken **before**
    /// the remote READ whose result may be filled.
    pub fn begin_fill(&self, key: u64) -> FillToken {
        let shard = self.shard_index(key);
        FillToken { shard, epoch: self.shards[shard].epoch.load(Ordering::Acquire) }
    }

    /// Insert a validated read result. Rejected (returns `false`) if any
    /// invalidation touched the shard since `token` was taken — the value
    /// may predate a concurrent mutation — or if the value alone exceeds
    /// the shard's whole byte budget (caching it would evict everything
    /// for one key).
    pub fn fill(&self, token: FillToken, key: u64, counter: u64, value: &[u64]) -> bool {
        let shard = &self.shards[token.shard];
        debug_assert_eq!(token.shard, self.shard_index(key), "token/key shard mismatch");
        let cost = Self::entry_bytes(value.len());
        if cost > self.per_shard_budget {
            self.rejected_fills.fetch_add(1, Ordering::Relaxed);
            return false;
        }
        let mut sm = shard.map.lock().unwrap();
        // Epoch check under the shard lock: invalidations bump the epoch
        // under the same lock, so this is race-free.
        if shard.epoch.load(Ordering::Acquire) != token.epoch {
            self.rejected_fills.fetch_add(1, Ordering::Relaxed);
            return false;
        }
        Self::remove_entry(&mut sm, key); // replacing refunds the old charge
        while sm.used + cost > self.per_shard_budget && !sm.map.is_empty() {
            self.evict_one(&mut sm);
        }
        sm.map.insert(key, CacheEntry { value: value.into(), counter, hot: false });
        sm.used += cost;
        self.fills.fetch_add(1, Ordering::Relaxed);
        true
    }

    /// CLOCK second chance over the shard's (arbitrary) iteration order:
    /// clear reference bits until a cold entry turns up, then evict it.
    fn evict_one(&self, sm: &mut ShardMap) {
        let mut victim = None;
        for (k, e) in sm.map.iter_mut() {
            if e.hot {
                e.hot = false; // second chance
            } else {
                victim = Some(*k);
                break;
            }
        }
        // Every entry was hot: take the first (now-cold) one.
        let victim = victim.or_else(|| sm.map.keys().next().copied());
        if let Some(k) = victim {
            Self::remove_entry(sm, k);
            self.evictions.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Drop `key` and bump its shard's fill epoch (killing in-flight
    /// fills that may carry the pre-mutation value).
    pub fn invalidate(&self, key: u64) {
        let shard = &self.shards[self.shard_index(key)];
        let mut sm = shard.map.lock().unwrap();
        shard.epoch.fetch_add(1, Ordering::AcqRel);
        Self::remove_entry(&mut sm, key);
        self.invalidations.fetch_add(1, Ordering::Relaxed);
    }

    /// Invalidate a batch of keys (one lock round per distinct shard
    /// would be nicer; at tracker-application rates per-key is fine).
    pub fn invalidate_many(&self, keys: impl IntoIterator<Item = u64>) {
        for k in keys {
            self.invalidate(k);
        }
    }

    /// Drop **everything** and bump every shard's fill epoch, so fills
    /// begun under the old membership epoch can never land. The kvstore
    /// calls this when a node crash-stops: entries cached from the dead
    /// epoch — including values homed on the dead node that are about to
    /// be re-homed under fresh generation counters — must not survive
    /// into the new one.
    pub fn clear(&self) {
        for shard in self.shards.iter() {
            let mut sm = shard.map.lock().unwrap();
            shard.epoch.fetch_add(1, Ordering::AcqRel);
            self.invalidations.fetch_add(sm.map.len() as u64, Ordering::Relaxed);
            sm.map.clear();
            sm.used = 0;
        }
    }

    /// Total cached entries (racy; for tests and monitoring).
    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.map.lock().unwrap().map.len()).sum()
    }

    /// Total bytes charged against the budget (racy; for tests and
    /// monitoring).
    pub fn bytes(&self) -> usize {
        self.shards.iter().map(|s| s.map.lock().unwrap().used).sum()
    }

    /// The configured total byte budget.
    pub fn budget_bytes(&self) -> usize {
        self.per_shard_budget * self.shards.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn stats(&self) -> CacheStats {
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            fills: self.fills.load(Ordering::Relaxed),
            rejected_fills: self.rejected_fills.load(Ordering::Relaxed),
            invalidations: self.invalidations.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
        }
    }
}

/// Membership-epoch gate for caches that must drop wholesale on a
/// reshard: the kvstore records the membership epoch its cache was last
/// valid under and, on every read, [`EpochGate::advance`] reports —
/// exactly once per transition, even with concurrent readers — whether
/// the epoch moved past the recorded one (death, join, join-complete),
/// in which case the caller clears the cache before serving. This keys
/// the locality tier's *fills* to membership epochs: an entry cached
/// under a superseded ownership table can never serve into the new one,
/// even when the per-key invalidation traffic for a migrated range has
/// not reached this node yet.
pub struct EpochGate(AtomicU64);

impl EpochGate {
    #[allow(clippy::new_without_default)]
    pub fn new() -> EpochGate {
        EpochGate(AtomicU64::new(0))
    }

    /// True exactly once per epoch change: the caller that wins the CAS
    /// performs the (idempotent) clear, racers serve under the already
    /// recorded new epoch.
    pub fn advance(&self, epoch: u64) -> bool {
        let seen = self.0.load(Ordering::Acquire);
        if seen == epoch {
            return false;
        }
        self.0.compare_exchange(seen, epoch, Ordering::AcqRel, Ordering::Acquire).is_ok()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hit_miss_and_generation_check() {
        let c = ReadCache::new(64 * 1024);
        assert_eq!(c.lookup(1, 5), None);
        let t = c.begin_fill(1);
        assert!(c.fill(t, 1, 5, &[10, 11]));
        assert_eq!(c.lookup(1, 5), Some(vec![10, 11]));
        // Different generation: miss, and the stale entry is dropped.
        assert_eq!(c.lookup(1, 6), None);
        assert_eq!(c.lookup(1, 5), None);
        let s = c.stats();
        assert_eq!((s.hits, s.fills), (1, 1));
        assert!(s.misses >= 3);
    }

    #[test]
    fn invalidation_rejects_in_flight_fill() {
        let c = ReadCache::new(64 * 1024);
        let t = c.begin_fill(9);
        c.invalidate(9);
        assert!(!c.fill(t, 9, 1, &[7]), "fill must lose the race");
        assert_eq!(c.lookup(9, 1), None);
        // A fresh token after the invalidation fills fine.
        let t = c.begin_fill(9);
        assert!(c.fill(t, 9, 1, &[7]));
        assert_eq!(c.lookup(9, 1), Some(vec![7]));
        assert_eq!(c.stats().rejected_fills, 1);
    }

    #[test]
    fn bounded_with_clock_eviction_keeps_hot_keys() {
        let budget = 32 * ReadCache::entry_bytes(1);
        let c = ReadCache::new(budget);
        // Fill far beyond the budget; key 0 is kept hot by lookups.
        for k in 0..256u64 {
            let t = c.begin_fill(k);
            c.fill(t, k, 1, &[k]);
            c.lookup(0, 1);
        }
        assert!(c.bytes() <= c.budget_bytes(), "cache over budget: {} B", c.bytes());
        assert!(c.stats().evictions > 0);
        assert_eq!(c.lookup(0, 1), Some(vec![0]), "hot key evicted");
    }

    /// The byte-budget satellite: a stream of 128-word (1 KB) values
    /// cannot blow the cache — the charged bytes stay under the budget
    /// and each fill evicts enough cold entries to fit. A value larger
    /// than a whole shard's budget is refused outright.
    #[test]
    fn large_values_respect_byte_budget() {
        let big = vec![7u64; 128]; // 1 KB + overhead per entry
        let c = ReadCache::new(16 * 1024);
        for k in 0..200u64 {
            let t = c.begin_fill(k);
            assert!(c.fill(t, k, 1, &big), "fill {k} refused under ample budget");
        }
        assert!(c.bytes() <= c.budget_bytes(), "over budget: {} B", c.bytes());
        assert!(c.len() < 200, "nothing was evicted");
        assert!(c.stats().evictions > 0);
        // Mixed sizes: small entries refund their exact charge.
        for k in 0..50u64 {
            let t = c.begin_fill(1000 + k);
            assert!(c.fill(t, 1000 + k, 1, &[k]));
        }
        assert!(c.bytes() <= c.budget_bytes());
        // One value bigger than any shard's slice of the budget: refused,
        // cache untouched.
        let before = c.stats().rejected_fills;
        let huge = vec![1u64; 16 * 1024];
        let t = c.begin_fill(9999);
        assert!(!c.fill(t, 9999, 1, &huge));
        assert_eq!(c.stats().rejected_fills, before + 1);
        assert_eq!(c.lookup(9999, 1), None);
    }

    #[test]
    fn invalidate_many_clears_keys() {
        let c = ReadCache::new(64 * 1024);
        for k in 0..8u64 {
            let t = c.begin_fill(k);
            c.fill(t, k, 1, &[k]);
        }
        c.invalidate_many(0..8u64);
        assert!(c.is_empty());
        assert_eq!(c.stats().invalidations, 8);
    }

    #[test]
    fn clear_drops_all_and_poisons_in_flight_fills() {
        let c = ReadCache::new(64 * 1024);
        let stale_token = c.begin_fill(3);
        for k in 0..8u64 {
            let t = c.begin_fill(k);
            assert!(c.fill(t, k, 1, &[k]));
        }
        c.clear();
        assert!(c.is_empty(), "clear must drop every shard");
        // A fill begun before the clear (dead membership epoch) loses.
        assert!(!c.fill(stale_token, 3, 1, &[9]), "pre-clear token must be rejected");
        assert_eq!(c.lookup(3, 1), None);
    }

    #[test]
    fn zipfian_sizing_clamped() {
        assert_eq!(ReadCache::zipfian_capacity(100), 256);
        assert_eq!(ReadCache::zipfian_capacity(1 << 14), 1 << 12);
        assert_eq!(ReadCache::zipfian_capacity(1 << 30), 1 << 16);
    }

    /// The gate fires exactly once per membership-epoch change, however
    /// many readers observe it.
    #[test]
    fn epoch_gate_fires_once_per_transition() {
        let g = EpochGate::new();
        assert!(!g.advance(0), "no transition yet");
        assert!(g.advance(1), "first observer clears");
        assert!(!g.advance(1), "second observer must not re-clear");
        assert!(g.advance(3), "epochs may skip (batched transitions)");
        assert!(!g.advance(3));
    }
}
