//! `testkit` — shared scaffolding for the integration / property /
//! chaos test tiers (and for anyone scripting the simulator).
//!
//! Before this module existed, every test file re-implemented the same
//! three helpers (`managers`, cluster construction, kvstore setup) and
//! the linearizability checker lived inline in one of them. They are
//! centralized here, together with the **seeded chaos schedule DSL**:
//! [`chaos_plan`] derives a complete [`FaultPlan`] (delay / completion
//! reorder / duplication / QP flap mix) from a single seed, so a chaos
//! run's entire behavior — fabric jitter, fault schedule, workload — is
//! reproducible from the one number a failing test prints. The scripted
//! membership scenarios ([`join_leave_rebalance`], [`MembershipStep`])
//! and their [`check_convergence`] invariant checker live here too, so
//! the model, chaos, and membership tiers drive elasticity through one
//! vocabulary.
//!
//! The linearizability machinery ([`Event`], [`check_key`],
//! [`check_history`]) implements the paper's Appendix C argument: all
//! mutations of one key hold that key's lock, so their linearization
//! points are totally ordered; each read must be legal at *some* point
//! of its own interval against that order. Only **definite** precedence
//! (`a.resp < b.inv`) is used, which keeps the checker sound for
//! mutation intervals that include lock-wait time — and for mutations
//! cut short by a crash, whose response edge is reported as
//! [`CRASHED`] so they are never "definitely before" anything.

use std::sync::Arc;
use std::time::Duration;

use crate::apps::kvstore::{KvConfig, KvStore};
use crate::core::heat::RouteMode;
use crate::core::manager::Manager;
use crate::fabric::{Cluster, FabricConfig, FaultPlan, LatencyModel, NodeId};
use crate::util::rng::Rng;

/// Response timestamp for an operation that never responded (its issuer
/// crash-stopped mid-call). An interval ending here is never definitely
/// before anything, so the checker treats the op as "may or may not
/// have happened" — exactly the truth after a crash.
pub const CRASHED: u64 = u64::MAX;

// ---- cluster builders -------------------------------------------------

/// `n` managers over a fresh cluster (the helper formerly copy-pasted
/// across the test files).
pub fn managers(n: usize, cfg: FabricConfig) -> Vec<Arc<Manager>> {
    cluster_with_managers(n, cfg).1
}

/// A fresh cluster plus one manager per node.
pub fn cluster_with_managers(n: usize, cfg: FabricConfig) -> (Arc<Cluster>, Vec<Arc<Manager>>) {
    let cluster = Cluster::new(n, cfg);
    let mgrs = (0..n as NodeId).map(|i| Manager::new(cluster.clone(), i)).collect();
    (cluster, mgrs)
}

/// A ready kvstore on every node of a fresh cluster: returns the
/// cluster (for crash injection), the managers, and the stores, all
/// `wait_ready`.
pub fn kv_cluster(
    n: usize,
    mut fabric: FabricConfig,
    cfg: KvConfig,
) -> (Arc<Cluster>, Vec<Arc<Manager>>, Vec<Arc<KvStore>>) {
    if let Some(mode) = cfg.check_races {
        fabric = fabric.with_check(mode);
    }
    let (cluster, mgrs) = cluster_with_managers(n, fabric);
    let kvs: Vec<Arc<KvStore>> = mgrs.iter().map(|m| KvStore::new(m, "kv", cfg.clone())).collect();
    for kv in &kvs {
        kv.wait_ready(Duration::from_secs(30));
    }
    (cluster, mgrs, kvs)
}

// ---- seeded chaos schedules -------------------------------------------

/// Derive a full fault schedule from one seed: moderate probabilities
/// whose exact values are themselves seed-sampled, so a sweep over
/// seeds explores delay-heavy, duplication-heavy, flap-heavy, … mixes.
/// Delay magnitudes scale with `fast_sim` latencies (µs-scale).
pub fn chaos_plan(seed: u64) -> FaultPlan {
    let mut rng = Rng::seeded(seed ^ 0xFA_17);
    FaultPlan::seeded(seed)
        .delays(0.05 + rng.gen_f64() * 0.25, 2_000 + rng.gen_range(30_000))
        .dup_completions(rng.gen_f64() * 0.15)
        .reorders(rng.gen_f64() * 0.15)
        .qp_flaps(rng.gen_f64() * 0.02, 5_000 + rng.gen_range(40_000), 1_000)
}

/// The standard chaos fabric: threaded `fast_sim` with placement lag,
/// chaotic word-by-word placement, and the [`chaos_plan`] for `seed`.
///
/// The seed also picks the **selective-signaling chain length** (PR-5):
/// three quarters of the matrix runs with covered write chains on
/// (lengths 4 / 16 / 64), so duplicated, reordered, and error CQEs are
/// exercised *as covering completions of unsignaled prefixes* — the
/// remaining quarter keeps the signal-everything legacy shape.
pub fn chaos_fabric(seed: u64) -> FabricConfig {
    let mut lat = LatencyModel::fast_sim();
    lat.placement_lag_ns = 3000;
    let mut cfg = FabricConfig::threaded(lat)
        .chaotic()
        .with_faults(chaos_plan(seed))
        // The chaos tier runs the checker's structural level: the
        // free/alloc and publication rules stay armed (they are cheap
        // and phase-accurate under real threads) while the vector-clock
        // machinery — meaningless without deterministic delivery — is
        // off. `LOCO_CHECK` still wins for one-off investigations via
        // KvConfig::check_races = None paths.
        .with_check(crate::analysis::CheckMode::Structural);
    cfg.seed = seed.wrapping_mul(0x9E37_79B9_7F4A_7C15).max(1);
    cfg.signal_every = match seed % 4 {
        0 => 1, // legacy: every WQE signaled
        1 => 4,
        2 => 16,
        _ => 64,
    };
    cfg
}

// ---- deterministic simulation builders --------------------------------

/// The standard **simulated** chaos fabric: same latency model, chaotic
/// placement, fault plan, and signal-chain sweep as [`chaos_fabric`],
/// but stepped over virtual time by a
/// [`SimExecutor`](crate::sim::SimExecutor). Everything nondeterministic
/// derives from `seed`: same seed ⇒ bit-identical event trace.
pub fn sim_fabric(seed: u64) -> FabricConfig {
    let mut lat = LatencyModel::fast_sim();
    lat.placement_lag_ns = 3000;
    let mut cfg = FabricConfig::sim(lat, seed).chaotic().with_faults(chaos_plan(seed));
    cfg.signal_every = match seed % 4 {
        0 => 1,
        1 => 4,
        2 => 16,
        _ => 64,
    };
    cfg
}

/// A ready kvstore on every node of a fresh **simulated** cluster. The
/// executor must be installed before any manager or store is built (they
/// register their polling loops as scheduler services), so this builder
/// owns the whole sequence. Keep the returned executor alive for the
/// duration of the test — dropping it uninstalls the scheduler.
pub fn sim_kv_cluster(
    n: usize,
    seed: u64,
    cfg: KvConfig,
) -> (crate::sim::SimExecutor, Arc<Cluster>, Vec<Arc<Manager>>, Vec<Arc<KvStore>>) {
    let mut fabric = sim_fabric(seed);
    if let Some(mode) = cfg.check_races {
        fabric = fabric.with_check(mode);
    }
    let cluster = Cluster::new(n, fabric);
    let sim = crate::sim::SimExecutor::install(&cluster);
    let mgrs: Vec<Arc<Manager>> =
        (0..n as NodeId).map(|i| Manager::new(cluster.clone(), i)).collect();
    let kvs: Vec<Arc<KvStore>> = mgrs.iter().map(|m| KvStore::new(m, "kv", cfg.clone())).collect();
    for kv in &kvs {
        kv.wait_ready(Duration::from_secs(30));
    }
    (sim, cluster, mgrs, kvs)
}

// ---- model-based testing (reference model + shrinking) ----------------

/// One step of a model-based schedule. All randomness is pre-drawn into
/// this plain data — a schedule is a value, which is what makes delta
/// debugging sound (removing an op cannot shift any other op's
/// randomness).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ModelOp {
    Insert { node: NodeId, key: u64, val: u64 },
    Update { node: NodeId, key: u64, val: u64 },
    Remove { node: NodeId, key: u64 },
    Get { node: NodeId, key: u64 },
    /// Crash-stop `node` and run the cluster to quiescence (the re-home
    /// pass completes before the next op issues).
    Crash { node: NodeId },
    /// The designated spare `node` joins: broadcast the membership
    /// transition, pull every range the new ownership table assigns it
    /// ([`KvStore::rebalance`] until a sweep moves nothing), announce
    /// itself alive, and run to quiescence. A no-op for a node that is
    /// already a full member or crash-stopped (a shrunk schedule may
    /// have dropped the context that made it a spare).
    Join { node: NodeId },
}

/// Encode a model value as a kv value (2 words, so the checksummed
/// multi-word frame path is exercised). Injective: every stale read is
/// distinguishable.
fn enc(val: u64) -> Vec<u64> {
    vec![val, val.wrapping_mul(0x9E37_79B9_7F4A_7C15)]
}

/// The kvstore configuration the model tier runs: replication + fenced
/// updates + the hot-key cache + coalesced invalidations — every
/// consistency mechanism on at once, sized small so schedules run in
/// milliseconds of virtual time.
pub fn model_kv_config() -> KvConfig {
    KvConfig {
        slots_per_node: 64,
        value_words: 2,
        num_locks: 12,
        tracker_words: 1 << 12,
        fence_updates: true,
        lock_handover: true,
        read_cache_bytes: 16 * 1024,
        replicas: 2,
        coalesce_invals: true,
        // Pinned (not from env): the model tier's must-find guarantees
        // for the mutation cfgs are calibrated on the one-sided path;
        // the routing tier exercises Ship/Adaptive explicitly.
        routing: RouteMode::OneSided,
        // Sim delivery resolves `Auto` to full happens-before checking:
        // every model schedule runs under the race checker.
        check_races: None,
        // Pinned unsharded: the mutation must-find calibrations assume
        // one tracker ring; the kvstore's own shard tests and the
        // multi-engine model schedule cover `tracker_shards > 1`.
        tracker_shards: 1,
    }
}

/// Result of replaying one schedule.
pub struct ModelRun {
    /// First divergence between the store and the reference model
    /// (`None`: the schedule passed).
    pub failure: Option<String>,
    /// Deterministic event-trace hash of the whole run.
    pub trace: u64,
    /// Every scheduler choice drawn during the run (replayable via the
    /// `plan` argument of [`run_model_schedule`]).
    pub choices: Vec<u32>,
    /// Everything the race checker reported during the run. On a
    /// non-mutant build a non-empty list is itself folded into
    /// `failure`; the mutation smoke-checks instead assert the expected
    /// diagnostics are HERE (detected and localized).
    pub diagnostics: Vec<crate::analysis::Diagnostic>,
}

/// Cluster shape of the model tier: [`MODEL_NODES`] nodes total, of
/// which the last ([`MODEL_SPARE`]) starts as a designated spare that a
/// [`ModelOp::Join`] can bring into the ownership table mid-schedule.
pub const MODEL_NODES: usize = 4;
/// The model tier's designated spare node.
pub const MODEL_SPARE: NodeId = (MODEL_NODES - 1) as NodeId;

/// Replay `ops` on a fresh simulated cluster of [`MODEL_NODES`] nodes
/// (three active plus the designated spare) against a `BTreeMap`
/// reference model. Ops are sequential and fully acked, so under ≤ 1
/// crash-stop and ≤ 1 join (both injected *between* ops and run to
/// quiescence) the store must agree with the model exactly:
///
/// * a mutation that returns `Ok` is applied to the model; an `Err`
///   (dead lock host / crashed issuer) means the mutation did not
///   happen — the model is left unchanged;
/// * ops issued from a crashed node are skipped (a corpse issues
///   nothing);
/// * every `Get` must return exactly the model's value.
///
/// `plan` forces the scheduler's choice stream (shrinking/replay);
/// `None` draws from the seeded RNG. The failure outcome is a pure
/// function of `(ops, seed, plan)`.
pub fn run_model_schedule(ops: &[ModelOp], seed: u64, plan: Option<Vec<u32>>) -> ModelRun {
    run_model_schedule_striped(ops, seed, plan, 1, model_kv_config())
}

/// [`run_model_schedule`] over `engines` striped NIC engines per node
/// and an explicit kv config. The multi-engine determinism tier replays
/// schedules at `engines = 2` (often with `tracker_shards > 1`): the
/// reference-model agreement, the bit-identical trace, and checker
/// silence must all survive striping.
pub fn run_model_schedule_striped(
    ops: &[ModelOp],
    seed: u64,
    plan: Option<Vec<u32>>,
    engines: u32,
    cfg: KvConfig,
) -> ModelRun {
    let n = MODEL_NODES;
    let cluster = Cluster::new(n, sim_fabric(seed).with_engines(engines));
    let sim = crate::sim::SimExecutor::install(&cluster);
    if let Some(p) = plan {
        sim.force_plan(p);
    }
    let mgrs: Vec<Arc<Manager>> =
        (0..n as NodeId).map(|i| Manager::new(cluster.clone(), i)).collect();
    for m in &mgrs {
        m.membership().set_spares(1 << MODEL_SPARE);
    }
    let kvs: Vec<Arc<KvStore>> =
        mgrs.iter().map(|m| KvStore::new(m, "kv", cfg.clone())).collect();
    for kv in &kvs {
        kv.wait_ready(Duration::from_secs(30));
    }
    let ctxs: Vec<_> = mgrs.iter().map(|m| m.ctx()).collect();

    let mut model: std::collections::BTreeMap<u64, u64> = std::collections::BTreeMap::new();
    let mut failure = None;
    for (i, op) in ops.iter().enumerate() {
        match *op {
            ModelOp::Crash { node } => {
                if !cluster.is_down(node) {
                    cluster.crash(node);
                    sim.settle(); // drain + membership + re-home, to quiescence
                }
            }
            ModelOp::Join { node } => {
                let nu = node as usize;
                if cluster.is_down(node) || !mgrs[nu].membership().is_spare(node) {
                    continue; // corpses don't join; full members need no join
                }
                kvs[nu].join(&ctxs[nu]);
                while kvs[nu].rebalance(&ctxs[nu]) > 0 {}
                kvs[nu].activate(&ctxs[nu]);
                sim.settle();
            }
            ModelOp::Insert { node, key, val } => {
                if cluster.is_down(node) {
                    continue;
                }
                if let Ok(fresh) = kvs[node as usize].insert(&ctxs[node as usize], key, &enc(val))
                {
                    let had = model.insert(key, val).is_some();
                    if fresh == had {
                        failure = Some(format!(
                            "op {i} {op:?}: insert reported fresh={fresh} but the model {}",
                            if had { "already had the key" } else { "did not have the key" }
                        ));
                    }
                }
            }
            ModelOp::Update { node, key, val } => {
                if cluster.is_down(node) {
                    continue;
                }
                if let Ok(applied) =
                    kvs[node as usize].try_update(&ctxs[node as usize], key, &enc(val))
                {
                    let present = model.contains_key(&key);
                    if applied != present {
                        failure = Some(format!(
                            "op {i} {op:?}: update applied={applied}, model present={present}"
                        ));
                    } else if applied {
                        model.insert(key, val);
                    }
                }
            }
            ModelOp::Remove { node, key } => {
                if cluster.is_down(node) {
                    continue;
                }
                if let Ok(removed) = kvs[node as usize].try_remove(&ctxs[node as usize], key) {
                    let present = model.remove(&key).is_some();
                    if removed != present {
                        failure = Some(format!(
                            "op {i} {op:?}: remove returned {removed}, model present={present}"
                        ));
                    }
                }
            }
            ModelOp::Get { node, key } => {
                if cluster.is_down(node) {
                    continue;
                }
                let got = kvs[node as usize].get(&ctxs[node as usize], key);
                let want = model.get(&key).map(|&v| enc(v));
                if got != want {
                    failure =
                        Some(format!("op {i} {op:?}: store returned {got:?}, model has {want:?}"));
                }
            }
        }
        if failure.is_some() {
            break;
        }
    }
    sim.settle();
    let diagnostics = cluster.take_diagnostics();
    // The checker is live on every model schedule: a green run (no
    // model divergence) with diagnostics is a failure in its own right
    // — EXCEPT under the mutation smoke-check cfgs, whose entire point
    // is that the planted bug surfaces here for the tests to assert on.
    let mutant_build = cfg!(loco_mutant)
        || cfg!(loco_mutant_epoch)
        || cfg!(loco_mutant_fence)
        || cfg!(loco_mutant_uaf);
    if failure.is_none() && !mutant_build && !diagnostics.is_empty() {
        failure = Some(format!(
            "race checker: {} diagnostic(s) on a green run; first: {}",
            diagnostics.len(),
            diagnostics[0]
        ));
    }
    ModelRun { failure, trace: sim.trace_hash(), choices: sim.choices(), diagnostics }
}

/// Generate a random schedule: seed half the keyspace, then `rounds`
/// mixed ops over 8 keys from random **alive** nodes, with at most one
/// crash (the single-crash failure model) and at most one join of the
/// designated spare, each at a random position — so the search space
/// covers shrink-only, grow-only, and churn (grow + shrink)
/// interleavings. Every written value is unique, so any stale read is
/// attributable. `n` is the *active* node count (the spare is extra and
/// only issues ops once joined).
pub fn gen_model_ops(seed: u64, n: usize, rounds: usize) -> Vec<ModelOp> {
    let mut rng = Rng::seeded(seed ^ 0x0DE1_0DE1);
    const KEYS: u64 = 8;
    let mut ops = Vec::new();
    let mut next_val = 1u64;
    for key in 0..KEYS / 2 {
        let node = rng.gen_range(n as u64) as NodeId;
        ops.push(ModelOp::Insert { node, key, val: next_val });
        next_val += 1;
    }
    let crash_at = rng.gen_bool(0.5).then(|| rng.gen_range(rounds as u64) as usize);
    let crash_node = rng.gen_range(n as u64) as NodeId;
    let join_at = rng.gen_bool(0.5).then(|| rng.gen_range(rounds as u64) as usize);
    let mut alive: Vec<NodeId> = (0..n as NodeId).collect();
    for i in 0..rounds {
        if crash_at == Some(i) {
            ops.push(ModelOp::Crash { node: crash_node });
            alive.retain(|&x| x != crash_node);
        }
        if join_at == Some(i) {
            ops.push(ModelOp::Join { node: MODEL_SPARE });
            alive.push(MODEL_SPARE);
        }
        let node = alive[rng.gen_range(alive.len() as u64) as usize];
        let key = rng.gen_range(KEYS);
        ops.push(match rng.gen_range(10) {
            0..=1 => {
                next_val += 1;
                ModelOp::Insert { node, key, val: next_val - 1 }
            }
            2..=4 => {
                next_val += 1;
                ModelOp::Update { node, key, val: next_val - 1 }
            }
            5 => ModelOp::Remove { node, key },
            _ => ModelOp::Get { node, key },
        });
    }
    ops
}

/// Delta-debug (ddmin) the op stream: repeatedly drop chunks while the
/// schedule still fails (any divergence counts — the scheduler seed is
/// held fixed, so failing is a deterministic property of the op list),
/// halving the chunk size until single-op removal reaches a fixpoint.
/// Returns the 1-minimal op list and its failure.
pub fn shrink_model_ops(ops: &[ModelOp], seed: u64) -> (Vec<ModelOp>, String) {
    let mut cur: Vec<ModelOp> = ops.to_vec();
    let mut chunk = (cur.len() / 2).max(1);
    loop {
        let mut reduced = false;
        let mut start = 0;
        while start < cur.len() {
            let end = (start + chunk).min(cur.len());
            let cand: Vec<ModelOp> =
                cur[..start].iter().chain(cur[end..].iter()).cloned().collect();
            if !cand.is_empty() && run_model_schedule(&cand, seed, None).failure.is_some() {
                cur = cand; // same start now holds new content; retry it
                reduced = true;
            } else {
                start += chunk;
            }
        }
        if chunk == 1 && !reduced {
            break;
        }
        if !reduced {
            chunk = (chunk / 2).max(1);
        }
    }
    let failure =
        run_model_schedule(&cur, seed, None).failure.expect("shrunk schedule must still fail");
    (cur, failure)
}

/// Canonicalize the scheduler interleaving of a failing schedule:
/// choice 0 (always-first) is the canonical decision, so zero out
/// recorded choice segments while the failure persists. An all-zero
/// outcome means the bug does not depend on the interleaving at all —
/// reported as the empty plan.
pub fn shrink_model_choices(ops: &[ModelOp], seed: u64, recorded: &[u32]) -> Vec<u32> {
    if run_model_schedule(ops, seed, Some(Vec::new())).failure.is_some() {
        return Vec::new(); // plan exhausted ⇒ every choice forced to 0
    }
    let mut cur = recorded.to_vec();
    let mut chunk = (cur.len() / 2).max(1);
    loop {
        let mut reduced = false;
        let mut start = 0;
        while start < cur.len() {
            let end = (start + chunk).min(cur.len());
            if cur[start..end].iter().any(|&c| c != 0) {
                let mut cand = cur.clone();
                cand[start..end].fill(0);
                if run_model_schedule(ops, seed, Some(cand.clone())).failure.is_some() {
                    cur = cand;
                    reduced = true;
                }
            }
            start += chunk;
        }
        if chunk == 1 {
            if !reduced {
                break;
            }
        } else if !reduced {
            chunk = (chunk / 2).max(1);
        }
    }
    while cur.last() == Some(&0) {
        cur.pop(); // trailing zeros ≡ plan exhaustion
    }
    cur
}

/// A fully shrunk failing schedule: replaying
/// `run_model_schedule(&ops, seed, Some(plan))` reproduces `failure`.
pub struct CounterExample {
    pub seed: u64,
    pub ops: Vec<ModelOp>,
    pub failure: String,
    pub plan: Vec<u32>,
}

/// Search up to `schedules` random schedules of `rounds` ops; on the
/// first divergence, shrink the op stream (ddmin) and then the
/// interleaving choices, and return the minimal reproducer.
pub fn model_search(base_seed: u64, schedules: usize, rounds: usize) -> Option<CounterExample> {
    for i in 0..schedules {
        let seed = crate::util::mix64(base_seed ^ (i as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15))
            .max(1);
        let ops = gen_model_ops(seed, MODEL_NODES - 1, rounds);
        if run_model_schedule(&ops, seed, None).failure.is_some() {
            let (ops, _) = shrink_model_ops(&ops, seed);
            let rec = run_model_schedule(&ops, seed, None);
            let plan = shrink_model_choices(&ops, seed, &rec.choices);
            let failure = run_model_schedule(&ops, seed, Some(plan.clone()))
                .failure
                .expect("shrunk reproducer no longer fails");
            return Some(CounterExample { seed, ops, failure, plan });
        }
    }
    None
}

/// Schedule budget for the model tier: `LOCO_MODEL_BUDGET` overrides
/// the caller's default (CI pins it; local runs can crank it up).
pub fn model_budget(default: usize) -> usize {
    std::env::var("LOCO_MODEL_BUDGET").ok().and_then(|s| s.parse().ok()).unwrap_or(default)
}

/// Persist a counterexample under `target/model/` (CI archives the
/// directory as an artifact) and return the path.
pub fn save_counterexample(ce: &CounterExample) -> std::path::PathBuf {
    let dir = std::path::Path::new("target").join("model");
    let _ = std::fs::create_dir_all(&dir);
    let path = dir.join(format!("counterexample-{:016x}.txt", ce.seed));
    let mut text = format!(
        "seed: {:#x}\nfailure: {}\nops ({}):\n",
        ce.seed,
        ce.failure,
        ce.ops.len()
    );
    for op in &ce.ops {
        text.push_str(&format!("  {op:?}\n"));
    }
    text.push_str(&format!("plan ({} choices): {:?}\n", ce.plan.len(), ce.plan));
    let _ = std::fs::write(&path, text);
    path
}

/// Assert the cluster's race checker saw nothing. The chaos and
/// integration tiers call this at quiescence — a no-op for clusters
/// built without checking. Consumes the diagnostics, so repeated phase
/// checks attribute reports to the phase that produced them.
pub fn assert_checker_clean(cluster: &Cluster, context: &str) {
    let diags = cluster.take_diagnostics();
    assert!(
        diags.is_empty(),
        "{context}: race checker reported {} diagnostic(s); first: {}",
        diags.len(),
        diags[0]
    );
}

// ---- scripted membership scenarios ------------------------------------

/// One step of a scripted membership scenario (the e2e membership tier
/// replays these; loads come from seed-picked live nodes and every
/// membership change is followed by a full rebalance sweep before the
/// next step issues).
#[derive(Clone, Debug)]
pub enum MembershipStep {
    /// Insert `count` fresh uniquely-valued keys from live nodes.
    Load { count: usize },
    /// The designated spare joins and pulls its ranges.
    Join { node: NodeId },
    /// `node` leaves the cluster. Leaving is modeled as a crash-stop —
    /// the paper's fault model has no graceful handoff; recovery
    /// promotes the backups either way.
    Leave { node: NodeId },
}

/// Seeded join → rebalance → leave script over an `n`-node cluster
/// whose last node starts as the designated spare: load a base
/// population, bring the spare in, load through the grown table, crash
/// a seed-picked original member, load again through the shrunk table.
/// Convergence after each phase is what [`check_convergence`] asserts.
pub fn join_leave_rebalance(seed: u64, n: usize) -> Vec<MembershipStep> {
    let mut rng = Rng::seeded(seed ^ 0x10CA_1);
    let spare = (n - 1) as NodeId;
    let victim = rng.gen_range(n as u64 - 1) as NodeId; // any original member
    vec![
        MembershipStep::Load { count: 24 + rng.gen_range(16) as usize },
        MembershipStep::Join { node: spare },
        MembershipStep::Load { count: 8 + rng.gen_range(8) as usize },
        MembershipStep::Leave { node: victim },
        MembershipStep::Load { count: 8 + rng.gen_range(8) as usize },
    ]
}

/// Assert the cluster has **converged** after a membership scenario:
/// call at quiescence, after a full [`KvStore::rebalance`] sweep (every
/// live node swept until a sweep moves nothing), with at least
/// `replicas` live nodes. Checks, for every expected key:
///
/// * every live node's index carries the identical entry, and a read
///   from every live node returns the expected value;
/// * the key's home is live and is the ownership-table owner of the
///   key's range — i.e. migration actually converged on the table;
/// * the home's whole static replica chain is live, so the key is held
///   by exactly `replicas` live nodes (the degraded copies a crash
///   leaves behind must have been re-replicated away by the sweep);
///
/// plus, per live node: the index size matches the model exactly (no
/// resurrections, no losses) and [`KvStore::slab_audit`] finds no
/// leaked or double-owned slots (no orphans left by migration).
///
/// Keys whose ticket-lock stripe ([`KvStore::lock_host`]) is hosted on
/// a dead node are exempt from the placement and full-chain checks:
/// lock stripes do not fail over, so such keys are readable but
/// unmovable (`rebalance` skips what it cannot lock). They must still
/// be indexed identically everywhere, read back correctly, and sit on
/// a live home.
pub fn check_convergence(
    cluster: &Cluster,
    mgrs: &[Arc<Manager>],
    kvs: &[Arc<KvStore>],
    expect: &std::collections::BTreeMap<u64, Vec<u64>>,
    context: &str,
) {
    let n = kvs.len();
    let live: Vec<usize> = (0..n).filter(|&i| !cluster.is_down(i as NodeId)).collect();
    let replicas = kvs[0].config().replicas;
    assert!(
        live.len() >= replicas,
        "{context}: convergence needs ≥ replicas ({replicas}) live nodes, have {}",
        live.len()
    );
    for &i in &live {
        assert_eq!(
            kvs[i].index_len(),
            expect.len(),
            "{context}: node {i} index size diverged from the model"
        );
        if let Err(e) = kvs[i].slab_audit() {
            panic!("{context}: node {i} slab audit: {e}");
        }
    }
    let ctxs: Vec<_> = mgrs.iter().map(|m| m.ctx()).collect();
    for (&key, val) in expect {
        let e0 = kvs[live[0]]
            .index_entry(key)
            .unwrap_or_else(|| panic!("{context}: key {key} missing from node {}", live[0]));
        for &i in &live {
            assert_eq!(
                kvs[i].index_entry(key),
                Some(e0),
                "{context}: key {key}: node {i} index disagrees"
            );
            assert_eq!(
                kvs[i].get(&ctxs[i], key).as_ref(),
                Some(val),
                "{context}: key {key} read wrong on node {i}"
            );
        }
        let home = e0.node;
        if cluster.is_down(kvs[live[0]].lock_host(key)) {
            // Corpse-locked: rebalance cannot take the key lock, so the
            // key legitimately parks wherever recovery left it — on a
            // live home, but possibly off-table with a degraded chain.
            assert!(
                !cluster.is_down(home),
                "{context}: corpse-locked key {key} homed on dead node {home}"
            );
            continue;
        }
        assert_eq!(
            home,
            kvs[live[0]].home_of(key),
            "{context}: key {key} homed off the ownership table"
        );
        let dead_in_chain: Vec<NodeId> = (0..replicas)
            .map(|r| ((home as usize + r) % n) as NodeId)
            .filter(|&b| cluster.is_down(b))
            .collect();
        assert!(
            dead_in_chain.is_empty(),
            "{context}: key {key} (home {home}): replica chain members {dead_in_chain:?} \
             are dead — fewer than {replicas} live copies"
        );
    }
}

// ---- linearizability checking (paper Appendix C) ----------------------

/// One recorded operation of a kvstore history.
#[derive(Clone, Debug)]
pub enum Event {
    /// Mutation on `key`: insert/update write `Some(val)`; delete writes
    /// `None`. `resp` is [`CRASHED`] for an op cut short by a crash.
    Mutate { key: u64, val: Option<u64>, inv: u64, resp: u64 },
    /// Read of `key` returning `val` (`None` = EMPTY).
    Read { key: u64, val: Option<u64>, inv: u64, resp: u64 },
}

/// Check one key's history with a sound partial-order argument.
///
/// Recorded intervals include lock-wait time, so mutation intervals may
/// overlap even though their critical sections are serialized. We
/// therefore use only *definite* precedence (a.resp < b.inv ⇒ a
/// linearizes before b) and flag reads that are wrong in EVERY
/// serialization consistent with it:
///
/// * a read of value v is wrong if v's write never happened, or the read
///   completed before the write began, or some other mutation definitely
///   follows v's write and definitely precedes the read (v was
///   certainly overwritten);
/// * an EMPTY read is wrong if some write w definitely precedes it and
///   no delete could linearize after w (every delete definitely
///   precedes w), i.e. the key was certainly present.
///
/// Mutations with `resp == CRASHED` (issuer died mid-call) may or may
/// not have taken effect; their interval never "definitely precedes"
/// anything, which is exactly the required semantics.
pub fn check_key(key: u64, muts: Vec<(Option<u64>, u64, u64)>, reads: &[(Option<u64>, u64, u64)]) {
    for &(val, inv, resp) in reads {
        match val {
            Some(v) => {
                let m = muts
                    .iter()
                    .find(|(mv, _, _)| *mv == Some(v))
                    .unwrap_or_else(|| panic!("key {key}: read of value {v} never written"));
                assert!(
                    resp >= m.1,
                    "key {key}: read {v} @[{inv},{resp}] not linearizable: completed before its write began @{}",
                    m.1
                );
                // Certainly overwritten?
                let overwritten = muts
                    .iter()
                    .any(|&(mv2, inv2, resp2)| mv2 != Some(v) && inv2 > m.2 && resp2 < inv);
                assert!(
                    !overwritten,
                    "key {key}: read {v} @[{inv},{resp}] not linearizable: value certainly overwritten ({muts:?})"
                );
            }
            None => {
                // Certainly present?
                let certainly_present = muts.iter().any(|&(mv, minv, mresp)| {
                    mv.is_some()
                        && mresp < inv // write definitely precedes the read
                        && muts.iter().all(|&(dv, _dinv, dresp)| {
                            dv.is_some() || dresp < minv // every delete definitely precedes the write
                        })
                });
                assert!(
                    !certainly_present,
                    "key {key}: EMPTY read @[{inv},{resp}] not linearizable: key certainly present ({muts:?})"
                );
            }
        }
    }
}

/// Partition a recorded history per key and [`check_key`] each one.
/// `context` is prepended to any failure (tests pass the failing seed).
pub fn check_history(keys: u64, all: &[Event], context: &str) {
    for key in 0..keys {
        let muts: Vec<(Option<u64>, u64, u64)> = all
            .iter()
            .filter_map(|e| match e {
                Event::Mutate { key: k, val, inv, resp } if *k == key => Some((*val, *inv, *resp)),
                _ => None,
            })
            .collect();
        let reads: Vec<(Option<u64>, u64, u64)> = all
            .iter()
            .filter_map(|e| match e {
                Event::Read { key: k, val, inv, resp } if *k == key => Some((*val, *inv, *resp)),
                _ => None,
            })
            .collect();
        let res = std::panic::catch_unwind(|| check_key(key, muts, &reads));
        if let Err(payload) = res {
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "non-string panic".into());
            panic!("{context}: {msg}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chaos_plan_is_deterministic_and_active() {
        let a = chaos_plan(7);
        let b = chaos_plan(7);
        assert_eq!(format!("{a:?}"), format!("{b:?}"), "same seed, same plan");
        assert!(a.any_active());
        let c = chaos_plan(8);
        assert_ne!(format!("{a:?}"), format!("{c:?}"), "different seeds differ");
    }

    #[test]
    fn check_history_prepends_context() {
        // A broken history (stale read) must fail and carry the context.
        let events = vec![
            Event::Mutate { key: 0, val: Some(1), inv: 0, resp: 10 },
            Event::Mutate { key: 0, val: Some(2), inv: 20, resp: 30 },
            Event::Read { key: 0, val: Some(1), inv: 40, resp: 50 },
        ];
        let res = std::panic::catch_unwind(|| check_history(1, &events, "seed 42"));
        let msg = match res {
            Err(p) => p.downcast_ref::<String>().cloned().unwrap_or_default(),
            Ok(()) => panic!("broken history accepted"),
        };
        assert!(msg.contains("seed 42"), "context missing: {msg}");
        assert!(msg.contains("certainly overwritten"), "wrong failure: {msg}");
    }

    #[test]
    fn crashed_mutations_are_never_definite() {
        // An insert whose issuer crashed (resp = CRASHED) may or may not
        // have happened: both a later read of its value and a later
        // EMPTY read must be accepted.
        check_key(0, vec![(Some(9), 10, CRASHED)], &[(Some(9), 50, 60)]);
        check_key(0, vec![(Some(9), 10, CRASHED)], &[(None, 50, 60)]);
    }
}
