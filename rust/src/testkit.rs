//! `testkit` — shared scaffolding for the integration / property /
//! chaos test tiers (and for anyone scripting the simulator).
//!
//! Before this module existed, every test file re-implemented the same
//! three helpers (`managers`, cluster construction, kvstore setup) and
//! the linearizability checker lived inline in one of them. They are
//! centralized here, together with the **seeded chaos schedule DSL**:
//! [`chaos_plan`] derives a complete [`FaultPlan`] (delay / completion
//! reorder / duplication / QP flap mix) from a single seed, so a chaos
//! run's entire behavior — fabric jitter, fault schedule, workload — is
//! reproducible from the one number a failing test prints.
//!
//! The linearizability machinery ([`Event`], [`check_key`],
//! [`check_history`]) implements the paper's Appendix C argument: all
//! mutations of one key hold that key's lock, so their linearization
//! points are totally ordered; each read must be legal at *some* point
//! of its own interval against that order. Only **definite** precedence
//! (`a.resp < b.inv`) is used, which keeps the checker sound for
//! mutation intervals that include lock-wait time — and for mutations
//! cut short by a crash, whose response edge is reported as
//! [`CRASHED`] so they are never "definitely before" anything.

use std::sync::Arc;
use std::time::Duration;

use crate::apps::kvstore::{KvConfig, KvStore};
use crate::core::manager::Manager;
use crate::fabric::{Cluster, FabricConfig, FaultPlan, LatencyModel, NodeId};
use crate::util::rng::Rng;

/// Response timestamp for an operation that never responded (its issuer
/// crash-stopped mid-call). An interval ending here is never definitely
/// before anything, so the checker treats the op as "may or may not
/// have happened" — exactly the truth after a crash.
pub const CRASHED: u64 = u64::MAX;

// ---- cluster builders -------------------------------------------------

/// `n` managers over a fresh cluster (the helper formerly copy-pasted
/// across the test files).
pub fn managers(n: usize, cfg: FabricConfig) -> Vec<Arc<Manager>> {
    cluster_with_managers(n, cfg).1
}

/// A fresh cluster plus one manager per node.
pub fn cluster_with_managers(n: usize, cfg: FabricConfig) -> (Arc<Cluster>, Vec<Arc<Manager>>) {
    let cluster = Cluster::new(n, cfg);
    let mgrs = (0..n as NodeId).map(|i| Manager::new(cluster.clone(), i)).collect();
    (cluster, mgrs)
}

/// A ready kvstore on every node of a fresh cluster: returns the
/// cluster (for crash injection), the managers, and the stores, all
/// `wait_ready`.
pub fn kv_cluster(
    n: usize,
    fabric: FabricConfig,
    cfg: KvConfig,
) -> (Arc<Cluster>, Vec<Arc<Manager>>, Vec<Arc<KvStore>>) {
    let (cluster, mgrs) = cluster_with_managers(n, fabric);
    let kvs: Vec<Arc<KvStore>> = mgrs.iter().map(|m| KvStore::new(m, "kv", cfg.clone())).collect();
    for kv in &kvs {
        kv.wait_ready(Duration::from_secs(30));
    }
    (cluster, mgrs, kvs)
}

// ---- seeded chaos schedules -------------------------------------------

/// Derive a full fault schedule from one seed: moderate probabilities
/// whose exact values are themselves seed-sampled, so a sweep over
/// seeds explores delay-heavy, duplication-heavy, flap-heavy, … mixes.
/// Delay magnitudes scale with `fast_sim` latencies (µs-scale).
pub fn chaos_plan(seed: u64) -> FaultPlan {
    let mut rng = Rng::seeded(seed ^ 0xFA_17);
    FaultPlan::seeded(seed)
        .delays(0.05 + rng.gen_f64() * 0.25, 2_000 + rng.gen_range(30_000))
        .dup_completions(rng.gen_f64() * 0.15)
        .reorders(rng.gen_f64() * 0.15)
        .qp_flaps(rng.gen_f64() * 0.02, 5_000 + rng.gen_range(40_000), 1_000)
}

/// The standard chaos fabric: threaded `fast_sim` with placement lag,
/// chaotic word-by-word placement, and the [`chaos_plan`] for `seed`.
///
/// The seed also picks the **selective-signaling chain length** (PR-5):
/// three quarters of the matrix runs with covered write chains on
/// (lengths 4 / 16 / 64), so duplicated, reordered, and error CQEs are
/// exercised *as covering completions of unsignaled prefixes* — the
/// remaining quarter keeps the signal-everything legacy shape.
pub fn chaos_fabric(seed: u64) -> FabricConfig {
    let mut lat = LatencyModel::fast_sim();
    lat.placement_lag_ns = 3000;
    let mut cfg = FabricConfig::threaded(lat).chaotic().with_faults(chaos_plan(seed));
    cfg.seed = seed.wrapping_mul(0x9E37_79B9_7F4A_7C15).max(1);
    cfg.signal_every = match seed % 4 {
        0 => 1, // legacy: every WQE signaled
        1 => 4,
        2 => 16,
        _ => 64,
    };
    cfg
}

// ---- linearizability checking (paper Appendix C) ----------------------

/// One recorded operation of a kvstore history.
#[derive(Clone, Debug)]
pub enum Event {
    /// Mutation on `key`: insert/update write `Some(val)`; delete writes
    /// `None`. `resp` is [`CRASHED`] for an op cut short by a crash.
    Mutate { key: u64, val: Option<u64>, inv: u64, resp: u64 },
    /// Read of `key` returning `val` (`None` = EMPTY).
    Read { key: u64, val: Option<u64>, inv: u64, resp: u64 },
}

/// Check one key's history with a sound partial-order argument.
///
/// Recorded intervals include lock-wait time, so mutation intervals may
/// overlap even though their critical sections are serialized. We
/// therefore use only *definite* precedence (a.resp < b.inv ⇒ a
/// linearizes before b) and flag reads that are wrong in EVERY
/// serialization consistent with it:
///
/// * a read of value v is wrong if v's write never happened, or the read
///   completed before the write began, or some other mutation definitely
///   follows v's write and definitely precedes the read (v was
///   certainly overwritten);
/// * an EMPTY read is wrong if some write w definitely precedes it and
///   no delete could linearize after w (every delete definitely
///   precedes w), i.e. the key was certainly present.
///
/// Mutations with `resp == CRASHED` (issuer died mid-call) may or may
/// not have taken effect; their interval never "definitely precedes"
/// anything, which is exactly the required semantics.
pub fn check_key(key: u64, muts: Vec<(Option<u64>, u64, u64)>, reads: &[(Option<u64>, u64, u64)]) {
    for &(val, inv, resp) in reads {
        match val {
            Some(v) => {
                let m = muts
                    .iter()
                    .find(|(mv, _, _)| *mv == Some(v))
                    .unwrap_or_else(|| panic!("key {key}: read of value {v} never written"));
                assert!(
                    resp >= m.1,
                    "key {key}: read {v} @[{inv},{resp}] not linearizable: completed before its write began @{}",
                    m.1
                );
                // Certainly overwritten?
                let overwritten = muts
                    .iter()
                    .any(|&(mv2, inv2, resp2)| mv2 != Some(v) && inv2 > m.2 && resp2 < inv);
                assert!(
                    !overwritten,
                    "key {key}: read {v} @[{inv},{resp}] not linearizable: value certainly overwritten ({muts:?})"
                );
            }
            None => {
                // Certainly present?
                let certainly_present = muts.iter().any(|&(mv, minv, mresp)| {
                    mv.is_some()
                        && mresp < inv // write definitely precedes the read
                        && muts.iter().all(|&(dv, _dinv, dresp)| {
                            dv.is_some() || dresp < minv // every delete definitely precedes the write
                        })
                });
                assert!(
                    !certainly_present,
                    "key {key}: EMPTY read @[{inv},{resp}] not linearizable: key certainly present ({muts:?})"
                );
            }
        }
    }
}

/// Partition a recorded history per key and [`check_key`] each one.
/// `context` is prepended to any failure (tests pass the failing seed).
pub fn check_history(keys: u64, all: &[Event], context: &str) {
    for key in 0..keys {
        let muts: Vec<(Option<u64>, u64, u64)> = all
            .iter()
            .filter_map(|e| match e {
                Event::Mutate { key: k, val, inv, resp } if *k == key => Some((*val, *inv, *resp)),
                _ => None,
            })
            .collect();
        let reads: Vec<(Option<u64>, u64, u64)> = all
            .iter()
            .filter_map(|e| match e {
                Event::Read { key: k, val, inv, resp } if *k == key => Some((*val, *inv, *resp)),
                _ => None,
            })
            .collect();
        let res = std::panic::catch_unwind(|| check_key(key, muts, &reads));
        if let Err(payload) = res {
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "non-string panic".into());
            panic!("{context}: {msg}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chaos_plan_is_deterministic_and_active() {
        let a = chaos_plan(7);
        let b = chaos_plan(7);
        assert_eq!(format!("{a:?}"), format!("{b:?}"), "same seed, same plan");
        assert!(a.any_active());
        let c = chaos_plan(8);
        assert_ne!(format!("{a:?}"), format!("{c:?}"), "different seeds differ");
    }

    #[test]
    fn check_history_prepends_context() {
        // A broken history (stale read) must fail and carry the context.
        let events = vec![
            Event::Mutate { key: 0, val: Some(1), inv: 0, resp: 10 },
            Event::Mutate { key: 0, val: Some(2), inv: 20, resp: 30 },
            Event::Read { key: 0, val: Some(1), inv: 40, resp: 50 },
        ];
        let res = std::panic::catch_unwind(|| check_history(1, &events, "seed 42"));
        let msg = match res {
            Err(p) => p.downcast_ref::<String>().cloned().unwrap_or_default(),
            Ok(()) => panic!("broken history accepted"),
        };
        assert!(msg.contains("seed 42"), "context missing: {msg}");
        assert!(msg.contains("certainly overwritten"), "wrong failure: {msg}");
    }

    #[test]
    fn crashed_mutations_are_never_definite() {
        // An insert whose issuer crashed (resp = CRASHED) may or may not
        // have happened: both a later read of its value and a later
        // EMPTY read must be accepted.
        check_key(0, vec![(Some(9), 10, CRASHED)], &[(Some(9), 50, 60)]);
        check_key(0, vec![(Some(9), 10, CRASHED)], &[(None, 50, 60)]);
    }
}
