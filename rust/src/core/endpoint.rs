//! Channel endpoints: the base object every channel type builds on
//! (paper §4.1–§4.2).
//!
//! A channel is **named**; each participating node constructs a local
//! endpoint with the same full name (sub-channels are namespaced under
//! their parent with `/`, component regions with `.`). At construction an
//! endpoint allocates zero or more named local regions and then sends a
//! *join* message to every peer carrying its region metadata and the
//! region names it expects the peer to provide. A peer with a matching
//! endpoint validates the expectation list and replies *connect* with its
//! own region metadata. The endpoint is *ready* once enough peers have
//! connected.

use std::collections::{HashMap, HashSet};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::fabric::{NodeId, Region};

/// How many peers must connect before the endpoint is ready.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Expect {
    /// All other nodes in the cluster participate.
    AllPeers,
    /// Exactly `n` peers (paper: `channel::expect_num(num-1)`).
    Num(usize),
}

type ConnectCallback = Box<dyn Fn(NodeId, &[(String, Region)]) + Send + Sync>;

struct EndpointState {
    /// Peers we have received a join from.
    joined: HashSet<NodeId>,
    /// Peers we have received a connect (region metadata) from.
    connected: HashSet<NodeId>,
    /// Remote regions: (peer, region name) → region.
    remote: HashMap<(NodeId, String), Region>,
    /// Local regions by name.
    local: HashMap<String, Region>,
    /// Names this endpoint expects every participating peer to provide.
    expected_regions: Vec<String>,
    on_connect: Option<ConnectCallback>,
}

/// Shared endpoint object. Channel types hold an `Arc<Endpoint>`; the
/// manager's control thread drives its state from join/connect messages.
pub struct Endpoint {
    name: String,
    me: NodeId,
    expect: Expect,
    num_nodes: usize,
    state: Mutex<EndpointState>,
    ready_cv: Condvar,
}

impl Endpoint {
    pub fn new(name: &str, me: NodeId, num_nodes: usize, expect: Expect) -> Arc<Endpoint> {
        Arc::new(Endpoint {
            name: name.to_string(),
            me,
            expect,
            num_nodes,
            state: Mutex::new(EndpointState {
                joined: HashSet::new(),
                connected: HashSet::new(),
                remote: HashMap::new(),
                local: HashMap::new(),
                expected_regions: Vec::new(),
                on_connect: None,
            }),
            ready_cv: Condvar::new(),
        })
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn me(&self) -> NodeId {
        self.me
    }

    pub fn num_nodes(&self) -> usize {
        self.num_nodes
    }

    fn required(&self) -> usize {
        match self.expect {
            Expect::AllPeers => self.num_nodes - 1,
            Expect::Num(n) => n,
        }
    }

    /// Record a local region under its short (per-channel) name.
    pub fn add_local_region(&self, short_name: &str, region: Region) {
        let mut st = self.state.lock().unwrap();
        let prev = st.local.insert(short_name.to_string(), region);
        assert!(prev.is_none(), "local region name collision: {}.{short_name}", self.name);
    }

    /// Declare the region names each participating peer must provide.
    pub fn expect_regions(&self, names: &[&str]) {
        let mut st = self.state.lock().unwrap();
        st.expected_regions = names.iter().map(|s| s.to_string()).collect();
    }

    /// Register a callback invoked (on the control thread) whenever a
    /// peer's connect metadata arrives. Used for per-participant
    /// sub-structures (paper §5.1.2).
    pub fn on_connect(&self, cb: ConnectCallback) {
        self.state.lock().unwrap().on_connect = Some(cb);
    }

    pub fn local_regions(&self) -> Vec<(String, Region)> {
        let st = self.state.lock().unwrap();
        let mut v: Vec<_> = st.local.iter().map(|(k, r)| (k.clone(), *r)).collect();
        v.sort_by(|a, b| a.0.cmp(&b.0));
        v
    }

    pub fn local_region(&self, short_name: &str) -> Region {
        self.state
            .lock()
            .unwrap()
            .local
            .get(short_name)
            .copied()
            .unwrap_or_else(|| panic!("channel {}: no local region {short_name}", self.name))
    }

    /// Region `short_name` on `peer` (panics if not yet connected —
    /// callers go through `wait_ready` first).
    pub fn remote_region(&self, peer: NodeId, short_name: &str) -> Region {
        self.state
            .lock()
            .unwrap()
            .remote
            .get(&(peer, short_name.to_string()))
            .copied()
            .unwrap_or_else(|| {
                panic!("channel {}: no remote region {short_name} on node {peer}", self.name)
            })
    }

    pub fn try_remote_region(&self, peer: NodeId, short_name: &str) -> Option<Region> {
        self.state.lock().unwrap().remote.get(&(peer, short_name.to_string())).copied()
    }

    /// Peers connected so far (sorted).
    pub fn connected_peers(&self) -> Vec<NodeId> {
        let st = self.state.lock().unwrap();
        let mut v: Vec<_> = st.connected.iter().copied().collect();
        v.sort_unstable();
        v
    }

    /// Control-thread entry: a peer announced itself with its regions.
    /// Returns true if this is the first join from the peer (a connect
    /// reply — and possibly a reciprocal join — should be sent).
    pub(crate) fn handle_join(&self, peer: NodeId, regions: &[(String, Region)]) -> bool {
        let mut st = self.state.lock().unwrap();
        // Validate the peer provides everything we expect of it.
        for want in &st.expected_regions {
            assert!(
                regions.iter().any(|(n, _)| n == want),
                "channel {}: peer {peer} did not provide expected region {want}",
                self.name
            );
        }
        let first = st.joined.insert(peer);
        self.absorb(&mut st, peer, regions);
        drop(st);
        self.ready_cv.notify_all();
        first
    }

    /// Control-thread entry: a connect reply with the peer's regions.
    pub(crate) fn handle_connect(&self, peer: NodeId, regions: &[(String, Region)]) {
        let mut st = self.state.lock().unwrap();
        self.absorb(&mut st, peer, regions);
        drop(st);
        self.ready_cv.notify_all();
    }

    fn absorb(&self, st: &mut EndpointState, peer: NodeId, regions: &[(String, Region)]) {
        let newly = st.connected.insert(peer);
        for (name, r) in regions {
            st.remote.insert((peer, name.clone()), *r);
        }
        if newly {
            if let Some(cb) = st.on_connect.take() {
                // Run without holding the lock against reentrancy on this
                // endpoint? Callbacks only touch *other* objects (create
                // sub-channels), so holding our lock is safe; but release
                // it to be kind.
                cb(peer, regions);
                // Reinstall (callback may be invoked for several peers).
                if st.on_connect.is_none() {
                    st.on_connect = Some(cb);
                }
            }
        }
    }

    /// Block until `required()` peers have connected. Under the
    /// deterministic simulator the condvar never fires from another
    /// thread, so the wait pumps the scheduler (join/connect handling is
    /// a manager service) and uses a progress-based wedge budget instead
    /// of the wall deadline.
    pub fn wait_ready(&self, timeout: Duration) {
        let need = self.required();
        if crate::sim::active() {
            let mut bo = crate::util::Backoff::new();
            let mut budget = crate::util::WaitBudget::wedge(timeout);
            while !self.is_ready() {
                bo.snooze();
                if budget.expired() {
                    let connected = self.state.lock().unwrap().connected.len();
                    panic!(
                        "channel {}: setup timed out ({connected}/{need} peers connected)",
                        self.name
                    );
                }
            }
            return;
        }
        let deadline = Instant::now() + timeout;
        let mut st = self.state.lock().unwrap();
        while st.connected.len() < need {
            let now = Instant::now();
            if now >= deadline {
                panic!(
                    "channel {}: setup timed out ({}/{} peers connected)",
                    self.name,
                    st.connected.len(),
                    need
                );
            }
            let (guard, _) = self.ready_cv.wait_timeout(st, deadline - now).unwrap();
            st = guard;
        }
    }

    pub fn is_ready(&self) -> bool {
        self.state.lock().unwrap().connected.len() >= self.required()
    }
}

/// Compose a sub-channel name: `parent/child` (paper §4.2's `/` scheme).
pub fn sub_name(parent: &str, child: &str) -> String {
    format!("{parent}/{child}")
}

/// Compose a component region name: `chan.region` (paper's `.` scheme).
pub fn region_name(chan: &str, region: &str) -> String {
    format!("{chan}.{region}")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn region(node: NodeId, base: u64) -> Region {
        Region { node, base, len: 8, mr: 0, device: false }
    }

    #[test]
    fn join_connect_ready_flow() {
        let ep = Endpoint::new("bar", 0, 3, Expect::AllPeers);
        ep.add_local_region("data", region(0, 0));
        assert!(!ep.is_ready());
        assert!(ep.handle_join(1, &[("data".into(), region(1, 100))]));
        assert!(!ep.handle_join(1, &[("data".into(), region(1, 100))]), "second join not first");
        ep.handle_connect(2, &[("data".into(), region(2, 200))]);
        assert!(ep.is_ready());
        ep.wait_ready(Duration::from_millis(10));
        assert_eq!(ep.remote_region(1, "data").base, 100);
        assert_eq!(ep.remote_region(2, "data").base, 200);
        assert_eq!(ep.connected_peers(), vec![1, 2]);
    }

    #[test]
    #[should_panic(expected = "did not provide expected region")]
    fn join_missing_expected_region_panics() {
        let ep = Endpoint::new("bar", 0, 2, Expect::AllPeers);
        ep.expect_regions(&["data"]);
        ep.handle_join(1, &[("other".into(), region(1, 0))]);
    }

    #[test]
    #[should_panic(expected = "setup timed out")]
    fn wait_ready_times_out() {
        let ep = Endpoint::new("bar", 0, 2, Expect::AllPeers);
        ep.wait_ready(Duration::from_millis(20));
    }

    #[test]
    fn expect_num_partial_participation() {
        // Paper: peers may not participate in all channels.
        let ep = Endpoint::new("pair", 0, 4, Expect::Num(1));
        ep.handle_connect(3, &[]);
        assert!(ep.is_ready());
    }

    #[test]
    fn on_connect_callback_fires_once_per_peer() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let ep = Endpoint::new("sst", 0, 3, Expect::AllPeers);
        let count = Arc::new(AtomicUsize::new(0));
        let c2 = count.clone();
        ep.on_connect(Box::new(move |_peer, _regions| {
            c2.fetch_add(1, Ordering::SeqCst);
        }));
        ep.handle_join(1, &[]);
        ep.handle_connect(1, &[]); // duplicate peer → no second callback
        ep.handle_join(2, &[]);
        assert_eq!(count.load(Ordering::SeqCst), 2);
    }

    #[test]
    fn names() {
        assert_eq!(sub_name("bar", "sst"), "bar/sst");
        assert_eq!(region_name("bar/sst", "ov0"), "bar/sst.ov0");
    }
}
