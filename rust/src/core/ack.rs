//! `ack_key`: lock-free bitset completion tracking (paper Appendix A.1).
//!
//! Every signaled work request is assigned one bit in a 64-bit word. The
//! bit is set when the op is issued; the polling thread clears it when the
//! corresponding CQE arrives. An [`AckKey`] is a set of `(word, mask)`
//! pairs; the operations it tracks are complete exactly when every masked
//! bit reads zero — no locks, no condvars, no polling-thread↔app-thread
//! synchronization beyond the atomic words themselves.
//!
//! Keys can be unioned, which is how composite operations (e.g. an SST
//! broadcast made of one remote write per peer) expose a single handle.
//!
//! Each tracking word carries a parallel **error word**: a completion
//! with [`CqeStatus::PeerFailed`](crate::fabric::CqeStatus::PeerFailed)
//! sets the op's error bit *before* clearing its pending bit, so a
//! waiter that observes completion can then ask [`AckKey::failed`]
//! whether any covered op died instead of succeeding. This is how a
//! crash-stopped peer propagates up to `Err(Error::PeerFailed)` at the
//! channel layer rather than hanging a spin loop. Error bits are cleared
//! when their bit is next allocated, so recycled words never leak stale
//! failures.
//!
//! Duplicate completions (a fault-injection mode) are idempotent:
//! within one allocation lifetime, clearing a cleared bit and setting a
//! set error bit are no-ops — and across lifetimes every `wr_id` also
//! carries its word's **generation** (bumped when a drained word is
//! recycled), which [`AckRegistry::complete`] checks, so a duplicate
//! that outlives its bit's recycling is dropped instead of completing
//! (or failing) an unrelated new op.
//!
//! # Covered chains (selective signaling)
//!
//! With selective completion signaling (`FabricConfig::signal_every`),
//! the batched write paths allocate bits **only for the signaled WQEs**
//! of a chain: the unsignaled predecessors are *covered* by the next
//! signaled entry — per-QP FIFO completion order means its one CQE
//! proves the whole prefix executed, so clearing its one bit retires
//! the chain. Failure keeps the same contract: a failed unsignaled WQE
//! raises its QP's chain error, the covering CQE is delivered
//! `PeerFailed`, and the error bit set here surfaces through
//! [`AckKey::failed`] exactly as a per-op completion would have.
//! Duplicate or reordered covering CQEs are handled by the same
//! idempotence + generation rules as any other completion.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};

use crate::util::Backoff;

/// One tracking word: pending bits (set at issue, cleared at
/// completion), the parallel error bits, and the recycling generation.
pub struct AckWord {
    pending: AtomicU64,
    err: AtomicU64,
    /// Bumped by the owning allocator each time the (quiescent) word is
    /// recycled; stale completions from a previous life are rejected.
    gen: AtomicU64,
}

impl AckWord {
    fn new() -> AckWord {
        AckWord { pending: AtomicU64::new(0), err: AtomicU64::new(0), gen: AtomicU64::new(0) }
    }
}

/// Routes `wr_id`s back to their tracking words. Shared by all issuing
/// threads of one manager and by the polling thread.
pub struct AckRegistry {
    words: RwLock<Vec<Arc<AckWord>>>,
}

impl AckRegistry {
    pub fn new() -> Self {
        AckRegistry { words: RwLock::new(Vec::new()) }
    }

    /// Register a fresh tracking word; returns its slot index.
    pub fn add_word(&self) -> (u32, Arc<AckWord>) {
        let word = Arc::new(AckWord::new());
        let mut words = self.words.write().unwrap();
        words.push(word.clone());
        ((words.len() - 1) as u32, word)
    }

    /// Pack a (slot, bit, generation) triple into a `wr_id`: bits 0–5
    /// the bit, 6–31 the word slot, 32–63 the word's recycling
    /// generation **mod 2³²** (wrapping — a stale duplicate would have
    /// to survive 2³² recyclings of one word to alias).
    #[inline]
    pub fn wr_id(slot: u32, bit: u8, gen: u64) -> u64 {
        debug_assert!(slot < 1 << 26, "ack slot exceeds the wr_id field");
        ((gen & 0xFFFF_FFFF) << 32) | ((slot as u64) << 6) | bit as u64
    }

    /// Polling-thread side: clear the bit for a completed `wr_id`. A
    /// failed completion (`ok == false`) first sets the error bit, so
    /// any waiter that sees the pending bit clear also sees the error.
    /// Completions whose generation does not match the word's current
    /// life are dropped — a duplicate CQE (fault injection) delivered
    /// after its bit was recycled must not touch the new occupant.
    #[inline]
    pub fn complete(&self, wr_id: u64, ok: bool) {
        let slot = ((wr_id >> 6) & ((1 << 26) - 1)) as usize;
        let bit = wr_id & 63;
        let gen = wr_id >> 32;
        let mask = 1u64 << bit;
        let words = self.words.read().unwrap();
        let w = &words[slot];
        // Compare modulo 2³² — the wr_id field is truncated, the word's
        // counter is not.
        if w.gen.load(Ordering::Acquire) & 0xFFFF_FFFF != gen {
            return; // stale duplicate from a recycled life
        }
        if !ok {
            w.err.fetch_or(mask, Ordering::Release);
        }
        w.pending.fetch_and(!mask, Ordering::Release);
    }

    pub fn word_count(&self) -> usize {
        self.words.read().unwrap().len()
    }

    /// Start a new life for a recycled word: bump its generation under
    /// the registry's **write** lock, which excludes every in-flight
    /// [`AckRegistry::complete`] (each holds the read lock across its
    /// generation check *and* its bit mutation). A stale duplicate CQE
    /// therefore either lands fully in the old life — clearing
    /// already-clear bits, harmless — or observes the new generation
    /// and is dropped; it can never interleave between check and act
    /// and touch the new life's bits.
    fn begin_new_life(&self, word: &AckWord) -> u64 {
        let _guard = self.words.write().unwrap();
        let gen = word.gen.load(Ordering::Relaxed).wrapping_add(1);
        word.gen.store(gen, Ordering::Release);
        gen
    }
}

impl Default for AckRegistry {
    fn default() -> Self {
        Self::new()
    }
}

/// Per-thread bit allocator. Hands out (wr_id, word, mask) triples and
/// recycles fully-drained words whose keys have all been dropped.
pub struct AckAllocator {
    registry: Arc<AckRegistry>,
    slot: u32,
    word: Arc<AckWord>,
    /// The current word's recycling generation (mirrors `word.gen`;
    /// only this allocator ever bumps it).
    gen: u64,
    next_bit: u8,
    /// Full words parked for recycling once quiescent.
    retired: Vec<(u32, Arc<AckWord>)>,
}

impl AckAllocator {
    pub fn new(registry: Arc<AckRegistry>) -> Self {
        let (slot, word) = registry.add_word();
        AckAllocator { registry, slot, word, gen: 0, next_bit: 0, retired: Vec::new() }
    }

    /// Allocate one tracking bit: sets it (clearing any stale error bit
    /// from the word's previous life), returns the wr_id to post and the
    /// (word, mask) pair for the key.
    pub fn alloc(&mut self) -> (u64, Arc<AckWord>, u64) {
        if self.next_bit == 64 {
            self.refill();
        }
        let bit = self.next_bit;
        self.next_bit += 1;
        let mask = 1u64 << bit;
        self.word.err.fetch_and(!mask, Ordering::Relaxed);
        self.word.pending.fetch_or(mask, Ordering::AcqRel);
        (AckRegistry::wr_id(self.slot, bit, self.gen), self.word.clone(), mask)
    }

    /// Allocate `n` tracking bits for a batched post: bits packed into as
    /// few words as possible, **one `fetch_or` per word** instead of one
    /// per op (ack amortization for the doorbell-batched pipeline). The
    /// wr_ids are appended to `wr_ids` in allocation order; the returned
    /// key covers the whole batch.
    pub fn alloc_batch(&mut self, n: usize, wr_ids: &mut Vec<u64>) -> AckKey {
        let mut key = AckKey::ready();
        let mut remaining = n;
        while remaining > 0 {
            if self.next_bit == 64 {
                self.refill();
            }
            let take = remaining.min(64 - self.next_bit as usize) as u8;
            let mut mask = 0u64;
            for i in 0..take {
                let bit = self.next_bit + i;
                mask |= 1u64 << bit;
                wr_ids.push(AckRegistry::wr_id(self.slot, bit, self.gen));
            }
            self.next_bit += take;
            self.word.err.fetch_and(!mask, Ordering::Relaxed);
            self.word.pending.fetch_or(mask, Ordering::AcqRel);
            key.union(AckKey::single(self.word.clone(), mask));
            remaining -= take as usize;
        }
        key
    }

    fn refill(&mut self) {
        let old = (self.slot, self.word.clone());
        self.retired.push(old);
        // Recycle a retired word if all its ops completed and no AckKey
        // still references it (strong count: registry + our retired entry).
        let mut recycled = None;
        for (i, (_slot, w)) in self.retired.iter().enumerate() {
            // Quiescent iff no AckKey still references it: registry +
            // retired list (+ self.word for the entry just pushed).
            let quiescent_count = if Arc::ptr_eq(w, &self.word) { 3 } else { 2 };
            if w.pending.load(Ordering::Acquire) == 0 && Arc::strong_count(w) == quiescent_count {
                recycled = Some(i);
                break;
            }
        }
        if let Some(i) = recycled {
            let (slot, word) = self.retired.swap_remove(i);
            // New life for the word: stale duplicates carrying the old
            // generation are rejected by `complete` from here on (the
            // registry lock makes check+act atomic vs this bump).
            let gen = self.registry.begin_new_life(&word);
            self.slot = slot;
            self.word = word;
            self.gen = gen;
        } else {
            let (slot, word) = self.registry.add_word();
            self.slot = slot;
            self.word = word;
            self.gen = 0;
        }
        self.next_bit = 0;
    }
}

/// Completion handle for one or more asynchronous operations.
#[derive(Clone, Default)]
pub struct AckKey {
    parts: Vec<(Arc<AckWord>, u64)>,
}

impl AckKey {
    /// A key that is already complete (e.g. a local fast-path operation).
    pub fn ready() -> Self {
        AckKey { parts: Vec::new() }
    }

    pub fn single(word: Arc<AckWord>, mask: u64) -> Self {
        AckKey { parts: vec![(word, mask)] }
    }

    /// Merge another key into this one (paper: ack_keys can be unioned).
    pub fn union(&mut self, other: AckKey) {
        for (word, mask) in other.parts {
            if let Some((_, m)) = self.parts.iter_mut().find(|(w, _)| Arc::ptr_eq(w, &word)) {
                *m |= mask;
            } else {
                self.parts.push((word, mask));
            }
        }
    }

    /// Non-blocking completion query.
    #[inline]
    pub fn query(&self) -> bool {
        self.parts.iter().all(|(w, m)| w.pending.load(Ordering::Acquire) & m == 0)
    }

    /// Did any covered op complete **in error** (peer crash-stopped)?
    /// Meaningful once [`AckKey::query`] returns true; error bits are
    /// set before the matching pending bit clears.
    #[inline]
    pub fn failed(&self) -> bool {
        self.parts.iter().any(|(w, m)| w.err.load(Ordering::Acquire) & m != 0)
    }

    /// Spin (with backoff) until complete. The wedge bailout is
    /// clock-aware: 30 s of wall time under threads, a zero-progress
    /// scheduler streak under the deterministic simulator (where
    /// virtual "minutes" may elapse legitimately).
    pub fn wait(&self) {
        let mut bo = Backoff::new();
        let mut budget = crate::util::WaitBudget::wedge(std::time::Duration::from_secs(30));
        while !self.query() {
            bo.snooze();
            if budget.expired() {
                panic!("ack_key wait timed out (30 s): outstanding ops never completed");
            }
        }
    }

    /// Wait, then surface per-op failure: `Err(Error::PeerFailed)` if
    /// any covered op completed in error. A key never hangs on a crash —
    /// the fabric drains dead ops with error completions.
    pub fn wait_result(&self) -> crate::Result<()> {
        self.wait();
        if self.failed() {
            Err(crate::Error::PeerFailed("op completed in error (peer crashed)".into()))
        } else {
            Ok(())
        }
    }

    pub fn tracked_parts(&self) -> usize {
        self.parts.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_set_complete_clear() {
        let reg = Arc::new(AckRegistry::new());
        let mut alloc = AckAllocator::new(reg.clone());
        let (wr, word, mask) = alloc.alloc();
        let key = AckKey::single(word, mask);
        assert!(!key.query(), "bit set at issue");
        reg.complete(wr, true);
        assert!(key.query(), "bit cleared at completion");
        assert!(!key.failed());
        assert!(key.wait_result().is_ok());
    }

    #[test]
    fn error_completion_sets_failed() {
        let reg = Arc::new(AckRegistry::new());
        let mut alloc = AckAllocator::new(reg.clone());
        let (wr1, w1, m1) = alloc.alloc();
        let (wr2, w2, m2) = alloc.alloc();
        let mut key = AckKey::single(w1, m1);
        key.union(AckKey::single(w2, m2));
        reg.complete(wr1, true);
        reg.complete(wr2, false); // peer failed
        assert!(key.query(), "error completions still complete the key");
        assert!(key.failed(), "error bit visible after completion");
        assert!(matches!(key.wait_result(), Err(crate::Error::PeerFailed(_))));
        // Duplicate delivery of the error CQE is idempotent.
        reg.complete(wr2, false);
        assert!(key.query() && key.failed());
    }

    #[test]
    fn reallocated_bit_clears_stale_error() {
        let reg = Arc::new(AckRegistry::new());
        let mut alloc = AckAllocator::new(reg.clone());
        // Burn a full word with one failure, keys dropped immediately.
        for i in 0..64 {
            let (wr, _w, _m) = alloc.alloc();
            reg.complete(wr, i == 7);
        }
        // Rollover recycles the word; the fresh bits must not report the
        // old failures.
        let (wr, w, m) = alloc.alloc();
        let key = AckKey::single(w, m);
        assert!(!key.failed(), "stale error bit leaked into a recycled bit");
        reg.complete(wr, true);
        assert!(key.wait_result().is_ok());
    }

    /// A duplicate CQE that outlives its bit's recycling must not touch
    /// the new occupant: the generation check drops it.
    #[test]
    fn stale_generation_duplicate_is_dropped() {
        let reg = Arc::new(AckRegistry::new());
        let mut alloc = AckAllocator::new(reg.clone());
        // Life 0: burn the whole word; remember one wr_id as the "late
        // duplicate" a faulty fabric might redeliver.
        let mut old_wrs = Vec::new();
        for _ in 0..64 {
            let (wr, _w, _m) = alloc.alloc();
            old_wrs.push(wr);
            reg.complete(wr, true);
        }
        // Rollover recycles the word into generation 1.
        let (wr_new, w, m) = alloc.alloc();
        let key = AckKey::single(w, m);
        assert!(!key.query(), "new op pending");
        // Redeliver every old completion — bit 0 of the old life aliases
        // bit 0 of the new life, but the generation mismatch drops them.
        for wr in &old_wrs {
            reg.complete(*wr, true);
            reg.complete(*wr, false); // even as a late *error* duplicate
        }
        assert!(!key.query(), "stale duplicate completed the new op");
        assert!(!key.failed(), "stale duplicate failed the new op");
        reg.complete(wr_new, true);
        assert!(key.query() && !key.failed());
    }

    #[test]
    fn union_tracks_all() {
        let reg = Arc::new(AckRegistry::new());
        let mut alloc = AckAllocator::new(reg.clone());
        let (wr1, w1, m1) = alloc.alloc();
        let (wr2, w2, m2) = alloc.alloc();
        let mut key = AckKey::single(w1, m1);
        key.union(AckKey::single(w2, m2));
        // Same underlying word → parts merged.
        assert_eq!(key.tracked_parts(), 1);
        reg.complete(wr1, true);
        assert!(!key.query());
        reg.complete(wr2, true);
        assert!(key.query());
    }

    #[test]
    fn alloc_batch_packs_and_completes() {
        let reg = Arc::new(AckRegistry::new());
        let mut alloc = AckAllocator::new(reg.clone());
        // Burn 60 bits so a 10-bit batch must straddle a word boundary.
        for _ in 0..60 {
            let (wr, _w, _m) = alloc.alloc();
            reg.complete(wr, true);
        }
        let mut wr_ids = Vec::new();
        let key = alloc.alloc_batch(10, &mut wr_ids);
        assert_eq!(wr_ids.len(), 10);
        assert!(!key.query(), "bits set at issue");
        assert_eq!(key.tracked_parts(), 2, "batch straddles two words");
        for (i, wr) in wr_ids.iter().enumerate() {
            assert!(!key.query(), "incomplete after {i} acks");
            reg.complete(*wr, true);
        }
        assert!(key.query(), "complete after all acks");
        // Empty batches are already complete.
        let mut none = Vec::new();
        assert!(alloc.alloc_batch(0, &mut none).query());
        assert!(none.is_empty());
    }

    #[test]
    fn alloc_batch_spans_many_words() {
        let reg = Arc::new(AckRegistry::new());
        let mut alloc = AckAllocator::new(reg.clone());
        let mut wr_ids = Vec::new();
        let key = alloc.alloc_batch(200, &mut wr_ids);
        assert_eq!(wr_ids.len(), 200);
        // wr_ids must be unique (distinct (slot, bit) pairs).
        let mut dedup = wr_ids.clone();
        dedup.sort_unstable();
        dedup.dedup();
        assert_eq!(dedup.len(), 200);
        for wr in &wr_ids {
            reg.complete(*wr, true);
        }
        assert!(key.query());
    }

    #[test]
    fn ready_key_is_done() {
        assert!(AckKey::ready().query());
        assert!(!AckKey::ready().failed());
        AckKey::ready().wait();
        assert!(AckKey::ready().wait_result().is_ok());
    }

    #[test]
    fn word_rollover_and_recycle() {
        let reg = Arc::new(AckRegistry::new());
        let mut alloc = AckAllocator::new(reg.clone());
        // Fill 64 bits and complete them all; keys dropped immediately.
        for _ in 0..64 {
            let (wr, _w, _m) = alloc.alloc();
            reg.complete(wr, true);
        }
        let before = reg.word_count();
        // Next alloc rolls over; the drained word should be recycled, not
        // a fresh registry word.
        let (wr, w, m) = alloc.alloc();
        assert_eq!(reg.word_count(), before, "recycled drained word");
        let key = AckKey::single(w, m);
        reg.complete(wr, true);
        assert!(key.query());
    }

    #[test]
    fn no_recycle_while_key_held() {
        let reg = Arc::new(AckRegistry::new());
        let mut alloc = AckAllocator::new(reg.clone());
        let mut keys = Vec::new();
        for _ in 0..64 {
            let (wr, w, m) = alloc.alloc();
            keys.push(AckKey::single(w, m));
            reg.complete(wr, true);
        }
        let before = reg.word_count();
        let (_wr, _w, _m) = alloc.alloc();
        // Keys still alive → word must NOT be recycled.
        assert_eq!(reg.word_count(), before + 1);
        drop(keys);
    }

    #[test]
    fn concurrent_complete_from_poller() {
        let reg = Arc::new(AckRegistry::new());
        let mut alloc = AckAllocator::new(reg.clone());
        let mut wrs = Vec::new();
        let mut key = AckKey::ready();
        for _ in 0..200 {
            let (wr, w, m) = alloc.alloc();
            key.union(AckKey::single(w, m));
            wrs.push(wr);
        }
        let reg2 = reg.clone();
        let h = std::thread::spawn(move || {
            for wr in wrs {
                reg2.complete(wr, true);
            }
        });
        key.wait();
        h.join().unwrap();
        assert!(key.query());
    }
}
