//! `ack_key`: lock-free bitset completion tracking (paper Appendix A.1).
//!
//! Every signaled work request is assigned one bit in a 64-bit word. The
//! bit is set when the op is issued; the polling thread clears it when the
//! corresponding CQE arrives. An [`AckKey`] is a set of `(word, mask)`
//! pairs; the operations it tracks are complete exactly when every masked
//! bit reads zero — no locks, no condvars, no polling-thread↔app-thread
//! synchronization beyond the atomic words themselves.
//!
//! Keys can be unioned, which is how composite operations (e.g. an SST
//! broadcast made of one remote write per peer) expose a single handle.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};

use crate::util::Backoff;

/// Routes `wr_id`s back to their tracking words. Shared by all issuing
/// threads of one manager and by the polling thread.
pub struct AckRegistry {
    words: RwLock<Vec<Arc<AtomicU64>>>,
}

impl AckRegistry {
    pub fn new() -> Self {
        AckRegistry { words: RwLock::new(Vec::new()) }
    }

    /// Register a fresh tracking word; returns its slot index.
    pub fn add_word(&self) -> (u32, Arc<AtomicU64>) {
        let word = Arc::new(AtomicU64::new(0));
        let mut words = self.words.write().unwrap();
        words.push(word.clone());
        ((words.len() - 1) as u32, word)
    }

    /// Pack a (slot, bit) pair into a `wr_id`.
    #[inline]
    pub fn wr_id(slot: u32, bit: u8) -> u64 {
        ((slot as u64) << 6) | bit as u64
    }

    /// Polling-thread side: clear the bit for a completed `wr_id`.
    #[inline]
    pub fn complete(&self, wr_id: u64) {
        let slot = (wr_id >> 6) as usize;
        let bit = wr_id & 63;
        let words = self.words.read().unwrap();
        words[slot].fetch_and(!(1u64 << bit), Ordering::Release);
    }

    pub fn word_count(&self) -> usize {
        self.words.read().unwrap().len()
    }
}

impl Default for AckRegistry {
    fn default() -> Self {
        Self::new()
    }
}

/// Per-thread bit allocator. Hands out (wr_id, word, mask) triples and
/// recycles fully-drained words whose keys have all been dropped.
pub struct AckAllocator {
    registry: Arc<AckRegistry>,
    slot: u32,
    word: Arc<AtomicU64>,
    next_bit: u8,
    /// Full words parked for recycling once quiescent.
    retired: Vec<(u32, Arc<AtomicU64>)>,
}

impl AckAllocator {
    pub fn new(registry: Arc<AckRegistry>) -> Self {
        let (slot, word) = registry.add_word();
        AckAllocator { registry, slot, word, next_bit: 0, retired: Vec::new() }
    }

    /// Allocate one tracking bit: sets it, returns the wr_id to post and
    /// the (word, mask) pair for the key.
    pub fn alloc(&mut self) -> (u64, Arc<AtomicU64>, u64) {
        if self.next_bit == 64 {
            self.refill();
        }
        let bit = self.next_bit;
        self.next_bit += 1;
        let mask = 1u64 << bit;
        self.word.fetch_or(mask, Ordering::AcqRel);
        (AckRegistry::wr_id(self.slot, bit), self.word.clone(), mask)
    }

    /// Allocate `n` tracking bits for a batched post: bits packed into as
    /// few words as possible, **one `fetch_or` per word** instead of one
    /// per op (ack amortization for the doorbell-batched pipeline). The
    /// wr_ids are appended to `wr_ids` in allocation order; the returned
    /// key covers the whole batch.
    pub fn alloc_batch(&mut self, n: usize, wr_ids: &mut Vec<u64>) -> AckKey {
        let mut key = AckKey::ready();
        let mut remaining = n;
        while remaining > 0 {
            if self.next_bit == 64 {
                self.refill();
            }
            let take = remaining.min(64 - self.next_bit as usize) as u8;
            let mut mask = 0u64;
            for i in 0..take {
                let bit = self.next_bit + i;
                mask |= 1u64 << bit;
                wr_ids.push(AckRegistry::wr_id(self.slot, bit));
            }
            self.next_bit += take;
            self.word.fetch_or(mask, Ordering::AcqRel);
            key.union(AckKey::single(self.word.clone(), mask));
            remaining -= take as usize;
        }
        key
    }

    fn refill(&mut self) {
        let old = (self.slot, self.word.clone());
        self.retired.push(old);
        // Recycle a retired word if all its ops completed and no AckKey
        // still references it (strong count: registry + our retired entry).
        let mut recycled = None;
        for (i, (_slot, w)) in self.retired.iter().enumerate() {
            // Quiescent iff no AckKey still references it: registry +
            // retired list (+ self.word for the entry just pushed).
            let quiescent_count = if Arc::ptr_eq(w, &self.word) { 3 } else { 2 };
            if w.load(Ordering::Acquire) == 0 && Arc::strong_count(w) == quiescent_count {
                recycled = Some(i);
                break;
            }
        }
        if let Some(i) = recycled {
            let (slot, word) = self.retired.swap_remove(i);
            self.slot = slot;
            self.word = word;
        } else {
            let (slot, word) = self.registry.add_word();
            self.slot = slot;
            self.word = word;
        }
        self.next_bit = 0;
    }
}

/// Completion handle for one or more asynchronous operations.
#[derive(Clone, Default)]
pub struct AckKey {
    parts: Vec<(Arc<AtomicU64>, u64)>,
}

impl AckKey {
    /// A key that is already complete (e.g. a local fast-path operation).
    pub fn ready() -> Self {
        AckKey { parts: Vec::new() }
    }

    pub fn single(word: Arc<AtomicU64>, mask: u64) -> Self {
        AckKey { parts: vec![(word, mask)] }
    }

    /// Merge another key into this one (paper: ack_keys can be unioned).
    pub fn union(&mut self, other: AckKey) {
        for (word, mask) in other.parts {
            if let Some((_, m)) = self.parts.iter_mut().find(|(w, _)| Arc::ptr_eq(w, &word)) {
                *m |= mask;
            } else {
                self.parts.push((word, mask));
            }
        }
    }

    /// Non-blocking completion query.
    #[inline]
    pub fn query(&self) -> bool {
        self.parts.iter().all(|(w, m)| w.load(Ordering::Acquire) & m == 0)
    }

    /// Spin (with backoff) until complete.
    pub fn wait(&self) {
        let mut bo = Backoff::new();
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(30);
        while !self.query() {
            bo.snooze();
            if std::time::Instant::now() > deadline {
                panic!("ack_key wait timed out (30 s): outstanding ops never completed");
            }
        }
    }

    pub fn tracked_parts(&self) -> usize {
        self.parts.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_set_complete_clear() {
        let reg = Arc::new(AckRegistry::new());
        let mut alloc = AckAllocator::new(reg.clone());
        let (wr, word, mask) = alloc.alloc();
        let key = AckKey::single(word, mask);
        assert!(!key.query(), "bit set at issue");
        reg.complete(wr);
        assert!(key.query(), "bit cleared at completion");
    }

    #[test]
    fn union_tracks_all() {
        let reg = Arc::new(AckRegistry::new());
        let mut alloc = AckAllocator::new(reg.clone());
        let (wr1, w1, m1) = alloc.alloc();
        let (wr2, w2, m2) = alloc.alloc();
        let mut key = AckKey::single(w1, m1);
        key.union(AckKey::single(w2, m2));
        // Same underlying word → parts merged.
        assert_eq!(key.tracked_parts(), 1);
        reg.complete(wr1);
        assert!(!key.query());
        reg.complete(wr2);
        assert!(key.query());
    }

    #[test]
    fn alloc_batch_packs_and_completes() {
        let reg = Arc::new(AckRegistry::new());
        let mut alloc = AckAllocator::new(reg.clone());
        // Burn 60 bits so a 10-bit batch must straddle a word boundary.
        for _ in 0..60 {
            let (wr, _w, _m) = alloc.alloc();
            reg.complete(wr);
        }
        let mut wr_ids = Vec::new();
        let key = alloc.alloc_batch(10, &mut wr_ids);
        assert_eq!(wr_ids.len(), 10);
        assert!(!key.query(), "bits set at issue");
        assert_eq!(key.tracked_parts(), 2, "batch straddles two words");
        for (i, wr) in wr_ids.iter().enumerate() {
            assert!(!key.query(), "incomplete after {i} acks");
            reg.complete(*wr);
        }
        assert!(key.query(), "complete after all acks");
        // Empty batches are already complete.
        let mut none = Vec::new();
        assert!(alloc.alloc_batch(0, &mut none).query());
        assert!(none.is_empty());
    }

    #[test]
    fn alloc_batch_spans_many_words() {
        let reg = Arc::new(AckRegistry::new());
        let mut alloc = AckAllocator::new(reg.clone());
        let mut wr_ids = Vec::new();
        let key = alloc.alloc_batch(200, &mut wr_ids);
        assert_eq!(wr_ids.len(), 200);
        // wr_ids must be unique (distinct (slot, bit) pairs).
        let mut dedup = wr_ids.clone();
        dedup.sort_unstable();
        dedup.dedup();
        assert_eq!(dedup.len(), 200);
        for wr in &wr_ids {
            reg.complete(*wr);
        }
        assert!(key.query());
    }

    #[test]
    fn ready_key_is_done() {
        assert!(AckKey::ready().query());
        AckKey::ready().wait();
    }

    #[test]
    fn word_rollover_and_recycle() {
        let reg = Arc::new(AckRegistry::new());
        let mut alloc = AckAllocator::new(reg.clone());
        // Fill 64 bits and complete them all; keys dropped immediately.
        for _ in 0..64 {
            let (wr, _w, _m) = alloc.alloc();
            reg.complete(wr);
        }
        let before = reg.word_count();
        // Next alloc rolls over; the drained word should be recycled, not
        // a fresh registry word.
        let (wr, w, m) = alloc.alloc();
        assert_eq!(reg.word_count(), before, "recycled drained word");
        let key = AckKey::single(w, m);
        reg.complete(wr);
        assert!(key.query());
    }

    #[test]
    fn no_recycle_while_key_held() {
        let reg = Arc::new(AckRegistry::new());
        let mut alloc = AckAllocator::new(reg.clone());
        let mut keys = Vec::new();
        for _ in 0..64 {
            let (wr, w, m) = alloc.alloc();
            keys.push(AckKey::single(w, m));
            reg.complete(wr);
        }
        let before = reg.word_count();
        let (_wr, _w, _m) = alloc.alloc();
        // Keys still alive → word must NOT be recycled.
        assert_eq!(reg.word_count(), before + 1);
        drop(keys);
    }

    #[test]
    fn concurrent_complete_from_poller() {
        let reg = Arc::new(AckRegistry::new());
        let mut alloc = AckAllocator::new(reg.clone());
        let mut wrs = Vec::new();
        let mut key = AckKey::ready();
        for _ in 0..200 {
            let (wr, w, m) = alloc.alloc();
            key.union(AckKey::single(w, m));
            wrs.push(wr);
        }
        let reg2 = reg.clone();
        let h = std::thread::spawn(move || {
            for wr in wrs {
                reg2.complete(wr);
            }
        });
        key.wait();
        h.join().unwrap();
        assert!(key.query());
    }
}
