//! Per-key heat / contention tracking and the op-routing decision.
//!
//! LOCO's kvstore has two ways to run a mutation (cf. Brock et al.,
//! "RDMA vs. RPC for Implementing Distributed Data Structures"):
//!
//! * **one-sided** — the client acquires the key's ticket lock and
//!   writes the frame (plus replicas) itself. Optimal when the key is
//!   uncontended: every client makes progress in parallel and no
//!   server CPU is involved.
//! * **op-shipping** — the client sends the whole op to the key's home
//!   node in one WRITE and waits for a reply word
//!   ([`crate::channels::request_ring`]). One round trip, server-side
//!   apply, and natural write combining — the winning regime once a
//!   key is hot enough that one-sided clients would convoy on its lock.
//!
//! [`HeatTracker`] picks the path per key. It keeps a fixed table of
//! per-bucket EWMA "heat" values decayed in **operation count** (not
//! wall time, so the decision sequence is identical under the
//! deterministic simulator): each touch first halves the bucket's heat
//! once per [`HALF_LIFE_OPS`] elapsed local ops, then adds one unit
//! (more when the touch observed lock contention). A key touched every
//! Δ ops settles at `1 / (1 - 2^(-Δ/HALF_LIFE_OPS))` units — ~10 for a
//! Zipfian-hot key touched every 10 ops, ~1 for a uniform key touched
//! every few hundred — and a hysteresis band ([`HI`]/[`LO`]) turns that
//! into a sticky per-bucket route bit so borderline keys don't flap.
//!
//! Updates are load/compute/store without CAS loops: a lost race
//! merely under-counts one touch, which the EWMA absorbs. The table is
//! per node and never crosses the network.

use std::sync::atomic::{AtomicU64, Ordering};

/// Routing policy for kvstore mutations (`KvConfig::routing`,
/// CLI `--routing`, env `LOCO_ROUTING`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RouteMode {
    /// Every mutation takes the one-sided lock-and-write path (the
    /// pre-routing behavior; the default).
    OneSided,
    /// Every remote-homed mutation is shipped to its home node.
    Ship,
    /// Per-key decision from the [`HeatTracker`].
    Adaptive,
}

impl RouteMode {
    pub fn label(&self) -> &'static str {
        match self {
            RouteMode::OneSided => "onesided",
            RouteMode::Ship => "ship",
            RouteMode::Adaptive => "adaptive",
        }
    }

    /// Parse a policy name (the `LOCO_ROUTING` / `--routing` values).
    pub fn parse(s: &str) -> Result<RouteMode, String> {
        match s.trim().to_ascii_lowercase().as_str() {
            "onesided" | "one-sided" => Ok(RouteMode::OneSided),
            "ship" => Ok(RouteMode::Ship),
            "adaptive" => Ok(RouteMode::Adaptive),
            other => Err(format!(
                "{other:?} is not a routing policy (expected onesided | ship | adaptive)"
            )),
        }
    }

    /// Policy from `LOCO_ROUTING`, defaulting to `OneSided` when unset.
    /// Invalid values abort with a diagnosis at config construction —
    /// same contract as the `LOCO_SIGNAL_EVERY` validation.
    pub fn from_env() -> RouteMode {
        match std::env::var("LOCO_ROUTING") {
            Err(_) => RouteMode::OneSided,
            Ok(v) => match RouteMode::parse(&v) {
                Ok(m) => m,
                Err(e) => panic!("invalid LOCO_ROUTING: {e}"),
            },
        }
    }
}

/// Which path one mutation should take.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RouteDecision {
    OneSided,
    Ship,
}

/// Heat decays by half every this many local ops.
const HALF_LIFE_OPS: u64 = 64;
/// Heat unit added per touch, in 8-bit fixed point (1.0).
const INC: u64 = 1 << FP_BITS;
/// Extra heat for a touch that observed lock contention: contended
/// keys should cross to shipping sooner than their raw rate implies.
const CONTENDED_BONUS: u64 = INC;
/// Flip a bucket to `Ship` above this heat (≈ touched every ≤ 40 ops).
const HI: u64 = 3 * INC;
/// Flip back to one-sided below this heat (≈ touched every ≥ 180 ops).
const LO: u64 = (5 * INC) / 4;
/// Fixed-point fraction bits for heat values.
const FP_BITS: u32 = 8;
/// Cap so heat (30 bits) never bleeds into the op-stamp field.
const HEAT_MAX: u64 = (1 << 30) - 1;

/// Bucket word layout: `route(1) | heat(31) | last_touch_op(32)`.
const ROUTE_BIT: u64 = 1 << 63;

#[inline]
fn pack(route_ship: bool, heat: u64, op: u64) -> u64 {
    (if route_ship { ROUTE_BIT } else { 0 }) | (heat.min(HEAT_MAX) << 32) | (op & 0xFFFF_FFFF)
}

/// Per-node key-heat table. Sized at construction (power of two);
/// distinct keys may share a bucket, which only makes a shared bucket
/// a little hotter — acceptable for a routing hint.
pub struct HeatTracker {
    buckets: Box<[AtomicU64]>,
    mask: u64,
    /// Local op clock: one tick per sampled mutation.
    ops: AtomicU64,
    /// Hysteresis crossings (either direction), for `Cluster::route_flips`.
    flips: AtomicU64,
}

impl HeatTracker {
    /// Default table size: 1024 buckets (8 KB per node).
    pub fn new() -> Self {
        Self::with_buckets(1024)
    }

    pub fn with_buckets(n: usize) -> Self {
        assert!(n.is_power_of_two(), "heat table size must be a power of two");
        let buckets = (0..n).map(|_| AtomicU64::new(0)).collect::<Vec<_>>().into_boxed_slice();
        HeatTracker { buckets, mask: (n - 1) as u64, ops: AtomicU64::new(0), flips: AtomicU64::new(0) }
    }

    #[inline]
    fn bucket(&self, key: u64) -> &AtomicU64 {
        // splitmix64-style finalizer: adjacent keys land in unrelated
        // buckets (dense prefill keys would otherwise stripe).
        let mut h = key.wrapping_add(0x9E37_79B9_7F4A_7C15);
        h = (h ^ (h >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        h = (h ^ (h >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        &self.buckets[((h ^ (h >> 31)) & self.mask) as usize]
    }

    /// Record one touch of `key` and return the route it should take,
    /// plus whether this touch crossed the hysteresis band (a "flip").
    pub fn sample(&self, key: u64, contended: bool) -> (RouteDecision, bool) {
        let now = self.ops.fetch_add(1, Ordering::Relaxed) + 1;
        let b = self.bucket(key);
        let cur = b.load(Ordering::Relaxed);
        let was_ship = cur & ROUTE_BIT != 0;
        let last = cur & 0xFFFF_FFFF;
        let mut heat = (cur >> 32) & HEAT_MAX;

        // Decay by elapsed local ops (32-bit op stamps wrap ~never
        // within a bucket's half-life horizon; a wrap just over-decays
        // one sample).
        let elapsed = (now & 0xFFFF_FFFF).wrapping_sub(last) & 0xFFFF_FFFF;
        let halves = (elapsed / HALF_LIFE_OPS).min(63);
        heat >>= halves;
        // Fractional residue: linear interpolation of the partial
        // half-life keeps slow-touched buckets from never decaying.
        let residue = elapsed % HALF_LIFE_OPS;
        heat -= (heat / 2) * residue / HALF_LIFE_OPS;
        heat += if contended { INC + CONTENDED_BONUS } else { INC };

        let ship = if was_ship { heat > LO } else { heat >= HI };
        b.store(pack(ship, heat, now), Ordering::Relaxed);
        if ship != was_ship {
            self.flips.fetch_add(1, Ordering::Relaxed);
        }
        (if ship { RouteDecision::Ship } else { RouteDecision::OneSided }, ship != was_ship)
    }

    /// Current route for `key` without recording a touch.
    pub fn decide(&self, key: u64) -> RouteDecision {
        if self.bucket(key).load(Ordering::Relaxed) & ROUTE_BIT != 0 {
            RouteDecision::Ship
        } else {
            RouteDecision::OneSided
        }
    }

    /// Current heat of `key`'s bucket in whole units (tests/debugging).
    pub fn heat(&self, key: u64) -> u64 {
        ((self.bucket(key).load(Ordering::Relaxed) >> 32) & HEAT_MAX) >> FP_BITS
    }

    /// Hysteresis crossings since construction.
    pub fn flips(&self) -> u64 {
        self.flips.load(Ordering::Relaxed)
    }
}

impl Default for HeatTracker {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn route_mode_parses_and_rejects() {
        assert_eq!(RouteMode::parse("onesided"), Ok(RouteMode::OneSided));
        assert_eq!(RouteMode::parse("one-sided"), Ok(RouteMode::OneSided));
        assert_eq!(RouteMode::parse(" SHIP "), Ok(RouteMode::Ship));
        assert_eq!(RouteMode::parse("adaptive"), Ok(RouteMode::Adaptive));
        assert!(RouteMode::parse("rpc").is_err());
        assert!(RouteMode::parse("").is_err());
    }

    #[test]
    fn hot_key_flips_to_ship_and_cools_back() {
        let t = HeatTracker::new();
        // A key touched every op crosses HI quickly...
        let mut flipped_at = None;
        for i in 0..16 {
            let (d, flip) = t.sample(42, false);
            if flip {
                assert_eq!(d, RouteDecision::Ship);
                flipped_at = Some(i);
                break;
            }
        }
        let at = flipped_at.expect("back-to-back touches must flip to ship");
        assert!(at <= 4, "flip should happen within a few touches, took {at}");
        assert_eq!(t.decide(42), RouteDecision::Ship);

        // ...and decays back below LO after a long idle stretch.
        for _ in 0..(HALF_LIFE_OPS * 16) {
            t.sample(7, false); // unrelated traffic advances the op clock
        }
        let (d, flip) = t.sample(42, false);
        assert_eq!(d, RouteDecision::OneSided, "cold key must fall back to one-sided");
        assert!(flip);
        assert!(t.flips() >= 2);
    }

    #[test]
    fn uniform_traffic_stays_one_sided() {
        let t = HeatTracker::new();
        // Round-robin over many keys: per-bucket inter-touch gaps are
        // hundreds of ops, so heat settles near 1 unit — far below HI.
        for round in 0..64u64 {
            for k in 0..512u64 {
                let (d, _) = t.sample(k * 1000 + 3, false);
                if round > 0 {
                    assert_eq!(d, RouteDecision::OneSided, "uniform key {k} must not ship");
                }
            }
        }
        assert_eq!(t.flips(), 0);
    }

    #[test]
    fn contention_accelerates_the_flip() {
        let quiet = HeatTracker::new();
        let noisy = HeatTracker::new();
        // Same touch pattern (one key every HALF_LIFE_OPS, filler in
        // between): uncontended heat settles at 2 units — below HI —
        // while contended touches cross within a couple of samples.
        let mut noisy_shipped = false;
        for i in 0..4096u64 {
            let key = if i % HALF_LIFE_OPS == 0 { 99 } else { 7 };
            let (dq, _) = quiet.sample(key, false);
            let (dn, _) = noisy.sample(key, key == 99);
            if key == 99 {
                assert_eq!(dq, RouteDecision::OneSided, "uncontended rate must not ship");
                noisy_shipped |= dn == RouteDecision::Ship;
            }
        }
        assert!(noisy_shipped, "contended touches must push the key over HI");
    }
}
