//! Sharded, seqlock-validated location index — the first leg of the
//! kvstore's **locality tier** (paper §7's "strong locality effects").
//!
//! The seed implementation kept every node's key → (home, slot, counter)
//! map under one global `RwLock<HashMap>`: every lock-free `get` still
//! serialized on the reader count of that lock, and every tracker
//! broadcast stalled the whole read side. Dewan & Jenkins (PGAS 2020)
//! identify exactly this contended reader lock as the first scalability
//! cliff of distributed data structures, so this index removes it:
//!
//! * The map is split into `2^k` **shards** (key-hash addressed).
//! * Each shard is an open-addressing table of *word-atomic* slots
//!   (`key`, `meta`, `counter` — three `AtomicU64`s), so readers never
//!   take a lock: they probe with plain atomic loads.
//! * Consistency of multi-word entries is guaranteed by a per-shard
//!   **seqlock** version stamp: writers bump it to odd before mutating
//!   and to even after; a reader retries iff the stamp was odd or moved
//!   during its probe. Uncontended reads cost two extra loads.
//! * Writers (tracker thread, mutating ops) serialize on a per-shard
//!   mutex — a broadcast applying on shard A never delays a writer on
//!   shard B, and never delays *any* reader.
//!
//! Deletions leave tombstones (probe chains must not break); a shard
//! compacts itself — under its seqlock, invisible to readers beyond a
//! retry — once tombstones pile up. Capacity is fixed at construction
//! (the kvstore's slot budget bounds live entries), with headroom so the
//! load factor stays low.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::fabric::NodeId;

/// Where a key lives: home node, slot in that node's data array, and the
/// slot's reuse counter (Appendix C's generation tag).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct IndexEntry {
    pub node: NodeId,
    pub slot: u32,
    pub counter: u64,
}

/// Slot states, stored in the top bits of the `meta` word.
const STATE_EMPTY: u64 = 0;
const STATE_FULL: u64 = 1;
const STATE_TOMB: u64 = 2;
const STATE_SHIFT: u32 = 62;
const NODE_SHIFT: u32 = 32;
const NODE_MASK: u64 = (1 << 30) - 1;
const SLOT_MASK: u64 = (1 << 32) - 1;

#[inline]
fn pack_meta(state: u64, e: &IndexEntry) -> u64 {
    debug_assert!((e.node as u64) <= NODE_MASK, "node id exceeds 30 bits");
    (state << STATE_SHIFT) | ((e.node as u64) << NODE_SHIFT) | e.slot as u64
}

#[inline]
fn meta_state(meta: u64) -> u64 {
    meta >> STATE_SHIFT
}

use crate::util::mix64 as mix;

struct Slot {
    key: AtomicU64,
    meta: AtomicU64,
    counter: AtomicU64,
}

struct Shard {
    /// Seqlock stamp: odd while a writer mutates the table.
    seq: AtomicU64,
    /// Serializes writers; readers never touch it.
    writer: Mutex<ShardState>,
    slots: Box<[Slot]>,
    mask: u64,
}

struct ShardState {
    /// FULL slots.
    live: usize,
    /// FULL + TOMB slots (bounds probe-chain length).
    used: usize,
}

impl Shard {
    fn new(capacity: usize) -> Shard {
        Shard {
            seq: AtomicU64::new(0),
            writer: Mutex::new(ShardState { live: 0, used: 0 }),
            slots: (0..capacity)
                .map(|_| Slot {
                    key: AtomicU64::new(0),
                    meta: AtomicU64::new(0),
                    counter: AtomicU64::new(0),
                })
                .collect(),
            mask: capacity as u64 - 1,
        }
    }

    /// One lock-free probe pass. Returns `Err(())` if the table looked
    /// inconsistent (only possible while racing a writer — the caller's
    /// seqlock check rejects the pass anyway).
    fn probe(&self, key: u64, h: u64) -> Result<Option<IndexEntry>, ()> {
        let mut i = h & self.mask;
        for _ in 0..self.slots.len() {
            let s = &self.slots[i as usize];
            let meta = s.meta.load(Ordering::Acquire);
            match meta_state(meta) {
                STATE_EMPTY => return Ok(None),
                STATE_FULL if s.key.load(Ordering::Acquire) == key => {
                    return Ok(Some(IndexEntry {
                        node: ((meta >> NODE_SHIFT) & NODE_MASK) as NodeId,
                        slot: (meta & SLOT_MASK) as u32,
                        counter: s.counter.load(Ordering::Acquire),
                    }));
                }
                _ => {}
            }
            i = (i + 1) & self.mask;
        }
        // Probed the whole table without hitting EMPTY: a concurrent
        // compaction is rearranging under us.
        Err(())
    }

    /// Writer-side probe (shard mutex held): position of `key` if FULL,
    /// else the first insertable slot (reusing tombstones).
    fn probe_for_write(&self, key: u64, h: u64) -> (Option<usize>, Option<usize>) {
        let mut free = None;
        let mut i = h & self.mask;
        for _ in 0..self.slots.len() {
            let s = &self.slots[i as usize];
            match meta_state(s.meta.load(Ordering::Relaxed)) {
                STATE_EMPTY => return (None, free.or(Some(i as usize))),
                STATE_TOMB => free = free.or(Some(i as usize)),
                _ if s.key.load(Ordering::Relaxed) == key => return (Some(i as usize), free),
                _ => {}
            }
            i = (i + 1) & self.mask;
        }
        (None, free)
    }

    /// Drop all tombstones by rehashing live entries in place. Runs under
    /// the shard mutex with the seqlock held odd.
    fn compact(&self, st: &mut ShardState) {
        let live: Vec<(u64, u64, u64)> = self
            .slots
            .iter()
            .filter(|s| meta_state(s.meta.load(Ordering::Relaxed)) == STATE_FULL)
            .map(|s| {
                (
                    s.key.load(Ordering::Relaxed),
                    s.meta.load(Ordering::Relaxed),
                    s.counter.load(Ordering::Relaxed),
                )
            })
            .collect();
        for s in self.slots.iter() {
            s.meta.store(0, Ordering::Relaxed);
        }
        for (key, meta, counter) in live {
            let (_, free) = self.probe_for_write(key, mix(key));
            let s = &self.slots[free.expect("compaction cannot overflow")];
            s.key.store(key, Ordering::Relaxed);
            s.counter.store(counter, Ordering::Relaxed);
            s.meta.store(meta, Ordering::Relaxed);
        }
        st.used = st.live;
    }
}

/// The sharded index. Readers are lock-free (seqlock-validated probes);
/// writers take only their key's shard.
pub struct ShardedIndex {
    shards: Box<[Shard]>,
    shard_bits: u32,
    len: AtomicUsize,
}

impl ShardedIndex {
    /// Build an index able to hold `capacity` live entries. Shard count
    /// scales with capacity (2^3..2^7); per-shard tables carry ≥2×
    /// headroom (≤50 % load) so probe chains stay short even before
    /// compaction.
    pub fn new(capacity: usize) -> ShardedIndex {
        let shard_bits = (capacity / 512).next_power_of_two().trailing_zeros().clamp(3, 7);
        let shards = 1usize << shard_bits;
        let per_shard = (capacity.div_ceil(shards) * 2).next_power_of_two().max(16);
        ShardedIndex {
            shards: (0..shards).map(|_| Shard::new(per_shard)).collect(),
            shard_bits,
            len: AtomicUsize::new(0),
        }
    }

    #[inline]
    fn shard_of(&self, h: u64) -> &Shard {
        // High hash bits pick the shard; low bits walk the probe chain —
        // keeps the two decisions independent.
        &self.shards[(h >> (64 - self.shard_bits)) as usize]
    }

    /// Lock-free lookup.
    pub fn get(&self, key: u64) -> Option<IndexEntry> {
        let h = mix(key);
        let shard = self.shard_of(h);
        let mut bo = crate::util::Backoff::new();
        loop {
            let s1 = shard.seq.load(Ordering::Acquire);
            if s1 & 1 == 0 {
                if let Ok(res) = shard.probe(key, h) {
                    // Keep the probe's loads from sinking below the
                    // validating re-read (the seqlock ordering rule).
                    std::sync::atomic::fence(Ordering::Acquire);
                    if shard.seq.load(Ordering::Acquire) == s1 {
                        return res;
                    }
                }
            }
            bo.snooze(); // writer in flight on this shard: retry
        }
    }

    /// Insert or overwrite. Returns the previous entry, if any.
    pub fn insert(&self, key: u64, e: IndexEntry) -> Option<IndexEntry> {
        let h = mix(key);
        let shard = self.shard_of(h);
        let mut st = shard.writer.lock().unwrap();
        let (hit, free) = shard.probe_for_write(key, h);
        shard.seq.fetch_add(1, Ordering::AcqRel); // -> odd
        let prev = match hit {
            Some(i) => {
                let s = &shard.slots[i];
                let old_meta = s.meta.load(Ordering::Relaxed);
                let prev = IndexEntry {
                    node: ((old_meta >> NODE_SHIFT) & NODE_MASK) as NodeId,
                    slot: (old_meta & SLOT_MASK) as u32,
                    counter: s.counter.load(Ordering::Relaxed),
                };
                s.counter.store(e.counter, Ordering::Release);
                s.meta.store(pack_meta(STATE_FULL, &e), Ordering::Release);
                Some(prev)
            }
            None => {
                // Compact first if tombstones crowd the table (re-probe
                // only then — the first probe's free slot is still valid
                // otherwise).
                let mut free = free;
                if free.is_none() || st.used + 1 > shard.slots.len() * 7 / 8 {
                    shard.compact(&mut st);
                    free = shard.probe_for_write(key, h).1;
                }
                let i = free.unwrap_or_else(|| {
                    panic!(
                        "sharded index shard overflow ({} live in {}-slot shard): \
                         raise the capacity hint",
                        st.live,
                        shard.slots.len()
                    )
                });
                let s = &shard.slots[i];
                let was_tomb = meta_state(s.meta.load(Ordering::Relaxed)) == STATE_TOMB;
                s.key.store(key, Ordering::Release);
                s.counter.store(e.counter, Ordering::Release);
                s.meta.store(pack_meta(STATE_FULL, &e), Ordering::Release);
                st.live += 1;
                if !was_tomb {
                    st.used += 1;
                }
                self.len.fetch_add(1, Ordering::Relaxed);
                None
            }
        };
        shard.seq.fetch_add(1, Ordering::AcqRel); // -> even
        prev
    }

    /// Compare-and-replace: overwrite `key`'s entry with `new` only if
    /// the current entry equals `expect`. Crash recovery's re-home uses
    /// this (locally and via the `OP_REHOME` broadcast) so a recovery
    /// racing a concurrent **relocation** of the same key — the one
    /// mutation that rewrites the index without its home being alive to
    /// serialize against — can never clobber the relocator's fresh
    /// entry: the relocator's unconditional insert wins on every node
    /// regardless of arrival order. Returns whether the swap happened.
    pub fn replace_matching(&self, key: u64, expect: &IndexEntry, new: IndexEntry) -> bool {
        let h = mix(key);
        let shard = self.shard_of(h);
        let _st = shard.writer.lock().unwrap();
        let (hit, _) = shard.probe_for_write(key, h);
        let Some(i) = hit else {
            return false;
        };
        let s = &shard.slots[i];
        let meta = s.meta.load(Ordering::Relaxed);
        let cur = IndexEntry {
            node: ((meta >> NODE_SHIFT) & NODE_MASK) as NodeId,
            slot: (meta & SLOT_MASK) as u32,
            counter: s.counter.load(Ordering::Relaxed),
        };
        if cur != *expect {
            return false;
        }
        shard.seq.fetch_add(1, Ordering::AcqRel);
        s.counter.store(new.counter, Ordering::Release);
        s.meta.store(pack_meta(STATE_FULL, &new), Ordering::Release);
        shard.seq.fetch_add(1, Ordering::AcqRel);
        true
    }

    /// Compare-and-remove: drop `key` only if its current entry equals
    /// `expect`. Crash recovery's broadcast deletes use this so a stale
    /// drop can never clobber a racing fresh re-insert (which carries a
    /// new home/generation). Returns whether the entry was removed.
    pub fn remove_matching(&self, key: u64, expect: &IndexEntry) -> bool {
        let h = mix(key);
        let shard = self.shard_of(h);
        let mut st = shard.writer.lock().unwrap();
        let (hit, _) = shard.probe_for_write(key, h);
        let Some(i) = hit else {
            return false;
        };
        let s = &shard.slots[i];
        let meta = s.meta.load(Ordering::Relaxed);
        let cur = IndexEntry {
            node: ((meta >> NODE_SHIFT) & NODE_MASK) as NodeId,
            slot: (meta & SLOT_MASK) as u32,
            counter: s.counter.load(Ordering::Relaxed),
        };
        if cur != *expect {
            return false;
        }
        shard.seq.fetch_add(1, Ordering::AcqRel);
        s.meta.store(STATE_TOMB << STATE_SHIFT, Ordering::Release);
        st.live -= 1;
        shard.seq.fetch_add(1, Ordering::AcqRel);
        self.len.fetch_sub(1, Ordering::Relaxed);
        true
    }

    /// Remove `key`. Returns the entry that was present, if any.
    pub fn remove(&self, key: u64) -> Option<IndexEntry> {
        let h = mix(key);
        let shard = self.shard_of(h);
        let mut st = shard.writer.lock().unwrap();
        let (hit, _) = shard.probe_for_write(key, h);
        let i = hit?;
        shard.seq.fetch_add(1, Ordering::AcqRel);
        let s = &shard.slots[i];
        let meta = s.meta.load(Ordering::Relaxed);
        let prev = IndexEntry {
            node: ((meta >> NODE_SHIFT) & NODE_MASK) as NodeId,
            slot: (meta & SLOT_MASK) as u32,
            counter: s.counter.load(Ordering::Relaxed),
        };
        s.meta.store(STATE_TOMB << STATE_SHIFT, Ordering::Release);
        st.live -= 1;
        shard.seq.fetch_add(1, Ordering::AcqRel);
        self.len.fetch_sub(1, Ordering::Relaxed);
        Some(prev)
    }

    /// Snapshot every live entry homed on `node` (shard by shard, under
    /// each shard's writer mutex so entries are internally consistent).
    /// This is the recovery path's scan — on a crash, the dead node's
    /// key range is exactly this set, replicated into every index by the
    /// tracker broadcasts that announced it.
    pub fn entries_homed_on(&self, node: NodeId) -> Vec<(u64, IndexEntry)> {
        let mut out = Vec::new();
        for shard in self.shards.iter() {
            let _st = shard.writer.lock().unwrap();
            for s in shard.slots.iter() {
                let meta = s.meta.load(Ordering::Relaxed);
                if meta_state(meta) != STATE_FULL {
                    continue;
                }
                let e = IndexEntry {
                    node: ((meta >> NODE_SHIFT) & NODE_MASK) as NodeId,
                    slot: (meta & SLOT_MASK) as u32,
                    counter: s.counter.load(Ordering::Relaxed),
                };
                if e.node == node {
                    out.push((s.key.load(Ordering::Relaxed), e));
                }
            }
        }
        out
    }

    /// Live entry count.
    pub fn len(&self) -> usize {
        self.len.load(Ordering::Relaxed)
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn e(node: NodeId, slot: u32, counter: u64) -> IndexEntry {
        IndexEntry { node, slot, counter }
    }

    #[test]
    fn insert_get_remove_roundtrip() {
        let idx = ShardedIndex::new(1024);
        assert_eq!(idx.get(7), None);
        assert_eq!(idx.insert(7, e(1, 42, 3)), None);
        assert_eq!(idx.get(7), Some(e(1, 42, 3)));
        assert_eq!(idx.len(), 1);
        // Overwrite keeps len, returns prev.
        assert_eq!(idx.insert(7, e(2, 9, 4)), Some(e(1, 42, 3)));
        assert_eq!(idx.get(7), Some(e(2, 9, 4)));
        assert_eq!(idx.len(), 1);
        assert_eq!(idx.remove(7), Some(e(2, 9, 4)));
        assert_eq!(idx.get(7), None);
        assert_eq!(idx.remove(7), None);
        assert!(idx.is_empty());
    }

    /// Compare-and-remove only drops an exactly matching entry: a stale
    /// delete must not clobber a fresh re-insert's new generation.
    #[test]
    fn remove_matching_guards_generation() {
        let idx = ShardedIndex::new(64);
        idx.insert(5, e(1, 10, 3));
        assert!(!idx.remove_matching(5, &e(1, 10, 2)), "wrong counter must not remove");
        assert!(!idx.remove_matching(5, &e(2, 10, 3)), "wrong node must not remove");
        assert_eq!(idx.get(5), Some(e(1, 10, 3)), "entry survived mismatched drops");
        assert!(idx.remove_matching(5, &e(1, 10, 3)));
        assert_eq!(idx.get(5), None);
        assert!(!idx.remove_matching(5, &e(1, 10, 3)), "absent key");
        assert_eq!(idx.len(), 0);
    }

    /// Compare-and-replace swaps only an exactly matching entry — the
    /// recovery-vs-relocation arbitration rule.
    #[test]
    fn replace_matching_guards_generation() {
        let idx = ShardedIndex::new(64);
        idx.insert(5, e(1, 10, 3));
        assert!(!idx.replace_matching(5, &e(1, 10, 2), e(2, 4, 9)), "wrong counter");
        assert!(!idx.replace_matching(5, &e(0, 10, 3), e(2, 4, 9)), "wrong node");
        assert_eq!(idx.get(5), Some(e(1, 10, 3)));
        assert!(idx.replace_matching(5, &e(1, 10, 3), e(2, 4, 9)));
        assert_eq!(idx.get(5), Some(e(2, 4, 9)));
        assert!(!idx.replace_matching(6, &e(1, 10, 3), e(2, 4, 9)), "absent key");
        assert_eq!(idx.len(), 1, "replace keeps len");
    }

    #[test]
    fn dense_keys_fill_to_capacity() {
        let idx = ShardedIndex::new(4096);
        for k in 0..4096u64 {
            idx.insert(k, e(0, k as u32, k));
        }
        assert_eq!(idx.len(), 4096);
        for k in 0..4096u64 {
            assert_eq!(idx.get(k), Some(e(0, k as u32, k)), "key {k}");
        }
    }

    /// The recovery scan returns exactly the live entries homed on one
    /// node, with internally consistent fields.
    #[test]
    fn entries_homed_on_snapshots_by_node() {
        let idx = ShardedIndex::new(256);
        for k in 0..30u64 {
            idx.insert(k, e((k % 3) as NodeId, k as u32, k * 5));
        }
        idx.remove(3);
        let mut on0 = idx.entries_homed_on(0);
        on0.sort_by_key(|(k, _)| *k);
        let keys: Vec<u64> = on0.iter().map(|(k, _)| *k).collect();
        assert_eq!(keys, vec![0, 6, 9, 12, 15, 18, 21, 24, 27]);
        for (k, entry) in &on0 {
            assert_eq!(*entry, e(0, *k as u32, k * 5), "key {k}");
        }
        assert!(idx.entries_homed_on(7).is_empty());
    }

    /// Tombstone churn (insert/remove cycles far beyond the live count)
    /// must not degrade or overflow: compaction reclaims the chains.
    #[test]
    fn tombstone_churn_compacts() {
        let idx = ShardedIndex::new(512);
        for round in 0..64u64 {
            for k in 0..256u64 {
                idx.insert(round * 1000 + k, e(0, k as u32, round));
            }
            for k in 0..256u64 {
                assert!(idx.remove(round * 1000 + k).is_some());
            }
        }
        assert!(idx.is_empty());
        idx.insert(1, e(0, 0, 1));
        assert_eq!(idx.get(1), Some(e(0, 0, 1)));
    }

    /// Readers never see torn entries while writers churn their keys:
    /// each key's (slot, counter) pair moves in lockstep, so a read
    /// observing slot `s` must observe counter `s * 7`.
    #[test]
    fn concurrent_readers_see_consistent_entries() {
        let idx = Arc::new(ShardedIndex::new(512));
        for k in 0..64u64 {
            idx.insert(k, e(0, 0, 0));
        }
        let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let writers: Vec<_> = (0..2u64)
            .map(|w| {
                let idx = idx.clone();
                let stop = stop.clone();
                std::thread::spawn(move || {
                    let mut v = 1u32;
                    while !stop.load(Ordering::Relaxed) {
                        for k in (w..64u64).step_by(2) {
                            idx.insert(k, e(1, v, v as u64 * 7));
                            if v % 16 == 0 {
                                idx.remove(k);
                                idx.insert(k, e(1, v, v as u64 * 7));
                            }
                        }
                        v = v.wrapping_add(1).max(1);
                    }
                })
            })
            .collect();
        let readers: Vec<_> = (0..4u64)
            .map(|r| {
                let idx = idx.clone();
                let stop = stop.clone();
                std::thread::spawn(move || {
                    let mut rng = crate::util::rng::Rng::seeded(r);
                    let mut seen = 0u64;
                    while !stop.load(Ordering::Relaxed) {
                        let k = rng.gen_range(64);
                        if let Some(got) = idx.get(k) {
                            if got.node == 1 {
                                assert_eq!(
                                    got.counter,
                                    got.slot as u64 * 7,
                                    "torn index entry for key {k}: {got:?}"
                                );
                            }
                            seen += 1;
                        }
                    }
                    seen
                })
            })
            .collect();
        std::thread::sleep(std::time::Duration::from_millis(300));
        stop.store(true, Ordering::SeqCst);
        for w in writers {
            w.join().unwrap();
        }
        let total: u64 = readers.into_iter().map(|r| r.join().unwrap()).sum();
        assert!(total > 0, "readers made no progress");
    }
}
