//! Network-memory pooling (paper Appendix A.2) and the **size-class slab
//! allocator** that carves a channel's data region into variable-size
//! value slots.
//!
//! Registration of an MR is expensive on real hardware, and many small MRs
//! thrash the NIC's translation cache. LOCO therefore aggregates all
//! channel memory into a few huge registered pages and carves named
//! regions out of them. The MPI baseline deliberately does *not* do this
//! (one MR per window), which is half of the Fig. 4 story.
//!
//! The slab layer ([`SlabGeometry`] + [`SlabAllocator`]) is the LOCO
//! answer to variable-size objects: the geometry is a pure function of
//! the channel config, so **every node computes the same slot → offset
//! mapping without communication** — a remote reader needs only the
//! 32-bit slot id from the location index to know which class the frame
//! belongs to and how many words to READ. Allocation state (per-class
//! free lists, leak/double-free accounting) stays node-local, exactly
//! like the kvstore's old single-class free list.

use std::collections::HashMap;
use std::sync::{Arc, Mutex, OnceLock};

use crate::fabric::{NodeFabric, Region};

// ---- size-class slab geometry -----------------------------------------

/// Ceiling on size classes (class ids must fit the frame header's 6-bit
/// class field with slack for flag bits; 32 classes already covers
/// 2^31-word values).
pub const MAX_CLASSES: usize = 32;

/// Slot ids pack `class` in the top bits and the in-class index below,
/// so the index's existing 32-bit slot word carries both.
const CLASS_SHIFT: u32 = 26;
const INDEX_MASK: u32 = (1 << CLASS_SHIFT) - 1;

/// Words of per-slot metadata around the value area:
/// `[len‖class][value …][checksum]…[counter‖valid]`.
pub const FRAME_META_WORDS: usize = 3;

/// Header flag: this frame was written by a **relocation** (an update
/// that outgrew its slot's class). While the frame's valid bit is still
/// unset, a reader that reaches it through the location index must spin
/// for the relocator's valid-set instead of reporting EMPTY — the key
/// exists throughout (its old frame holds the pre-update value until
/// the relocation linearizes). Without the flag, valid-unset means
/// "insert not yet / delete already linearized" and EMPTY is correct.
pub const HDR_RELOC: u64 = 1 << 6;

/// Pack a frame header word: value length (words), the slot's size
/// class, and optionally the [`HDR_RELOC`] marker. The class occupies
/// the low 6 bits so a reader can sanity-check it against the class
/// implied by the slot id before trusting `len`.
#[inline]
pub fn pack_hdr(len: usize, class: usize, reloc: bool) -> u64 {
    debug_assert!(class < MAX_CLASSES);
    ((len as u64) << 8) | if reloc { HDR_RELOC } else { 0 } | class as u64
}

#[inline]
pub fn hdr_len(hdr: u64) -> usize {
    (hdr >> 8) as usize
}

#[inline]
pub fn hdr_class(hdr: u64) -> usize {
    (hdr & 0x3f) as usize
}

#[inline]
pub fn hdr_reloc(hdr: u64) -> bool {
    hdr & HDR_RELOC != 0
}

/// The deterministic slot → class → offset mapping of a slab-carved
/// region. Class `c` holds values of up to `1 << c` words in frames of
/// `(1 << c) + FRAME_META_WORDS` words; every class gets the same number
/// of slots. Both sides of every remote READ share this struct (it is
/// derived from the cluster-wide channel config), which is what lets
/// readers issue per-class frame lengths without any handshake.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SlabGeometry {
    num_classes: usize,
    slots_per_class: usize,
    /// Word offset of each class's slab within the region, precomputed
    /// — `slot_off` sits on every read/write hot path.
    class_bases: [u64; MAX_CLASSES],
}

impl SlabGeometry {
    /// Geometry for values up to `max_value_words` (rounded up to a
    /// power of two), `slots_per_class` slots in every class.
    pub fn new(max_value_words: usize, slots_per_class: usize) -> SlabGeometry {
        assert!(max_value_words >= 1, "zero-width values");
        let max_cap = max_value_words.next_power_of_two();
        let num_classes = max_cap.trailing_zeros() as usize + 1;
        assert!(num_classes <= MAX_CLASSES, "value width {max_value_words} too large");
        assert!(
            (1..=INDEX_MASK as usize + 1).contains(&slots_per_class),
            "slots_per_class {slots_per_class} out of range"
        );
        let mut class_bases = [0u64; MAX_CLASSES];
        let mut base = 0u64;
        for (c, slot) in class_bases.iter_mut().enumerate().take(num_classes) {
            *slot = base;
            base += ((1u64 << c) + FRAME_META_WORDS as u64) * slots_per_class as u64;
        }
        SlabGeometry { num_classes, slots_per_class, class_bases }
    }

    pub fn num_classes(&self) -> usize {
        self.num_classes
    }

    pub fn slots_per_class(&self) -> usize {
        self.slots_per_class
    }

    pub fn total_slots(&self) -> usize {
        self.num_classes * self.slots_per_class
    }

    /// Value capacity of `class`, in words.
    #[inline]
    pub fn cap(&self, class: usize) -> usize {
        debug_assert!(class < self.num_classes);
        1 << class
    }

    /// Largest representable value, in words.
    pub fn max_value_words(&self) -> usize {
        1 << (self.num_classes - 1)
    }

    /// Full frame width of `class` (header + value area + checksum +
    /// counter word).
    #[inline]
    pub fn frame_words(&self, class: usize) -> u64 {
        (self.cap(class) + FRAME_META_WORDS) as u64
    }

    /// The smallest class whose capacity fits a `len`-word value.
    #[inline]
    pub fn class_for_len(&self, len: usize) -> Option<usize> {
        if len == 0 || len > self.max_value_words() {
            return None;
        }
        Some(len.next_power_of_two().trailing_zeros() as usize)
    }

    /// Total words the slab occupies in its region.
    pub fn total_words(&self) -> usize {
        (0..self.num_classes).map(|c| self.frame_words(c) as usize * self.slots_per_class).sum()
    }

    #[inline]
    pub fn pack(&self, class: usize, index: u32) -> u32 {
        debug_assert!(class < self.num_classes && (index as usize) < self.slots_per_class);
        ((class as u32) << CLASS_SHIFT) | index
    }

    #[inline]
    pub fn class_of(&self, slot: u32) -> usize {
        (slot >> CLASS_SHIFT) as usize
    }

    #[inline]
    pub fn index_of(&self, slot: u32) -> u32 {
        slot & INDEX_MASK
    }

    /// Word offset of `class`'s slab within the region.
    #[inline]
    fn class_base(&self, class: usize) -> u64 {
        self.class_bases[class]
    }

    /// Word offset of a slot's frame within the region — computable by
    /// every node from the slot id alone.
    #[inline]
    pub fn slot_off(&self, slot: u32) -> u64 {
        let class = self.class_of(slot);
        debug_assert!(class < self.num_classes);
        self.class_base(class) + self.index_of(slot) as u64 * self.frame_words(class)
    }

    /// Dense ordinal of a slot across all classes (for per-slot counter
    /// arrays).
    #[inline]
    pub fn ordinal(&self, slot: u32) -> usize {
        self.class_of(slot) * self.slots_per_class + self.index_of(slot) as usize
    }
}

/// A slot lifecycle transition, published to the observer installed via
/// [`SlabAllocator::set_observer`]. The race checker treats these as the
/// birth/death events of rule (b)'s use-after-free tracking.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SlabEvent {
    Alloc { slot: u32 },
    Free { slot: u32 },
}

/// Node-local allocation state over a [`SlabGeometry`]: one free list
/// per class plus in-use accounting, so leaks and double frees are
/// detectable (and a post-run audit can prove every slot is accounted
/// for exactly once).
pub struct SlabAllocator {
    geo: SlabGeometry,
    inner: Mutex<SlabInner>,
    /// Lifecycle observer (the race checker's slot birth/death feed).
    /// Fired **while holding `inner`**, so a concurrent re-alloc of the
    /// slot cannot be observed before the free that released it — the
    /// checker never calls back into the allocator, so no deadlock.
    observer: OnceLock<Box<dyn Fn(SlabEvent) + Send + Sync>>,
}

struct SlabInner {
    /// Per-class free stacks of in-class indices.
    free: Vec<Vec<u32>>,
    /// In-use flags by dense ordinal (double-free / leak accounting).
    in_use: Vec<bool>,
    outstanding: usize,
}

impl SlabAllocator {
    pub fn new(geo: SlabGeometry) -> SlabAllocator {
        SlabAllocator {
            geo,
            inner: Mutex::new(SlabInner {
                free: (0..geo.num_classes())
                    .map(|_| (0..geo.slots_per_class() as u32).rev().collect())
                    .collect(),
                in_use: vec![false; geo.total_slots()],
                outstanding: 0,
            }),
            observer: OnceLock::new(),
        }
    }

    pub fn geometry(&self) -> &SlabGeometry {
        &self.geo
    }

    /// Install the lifecycle observer (once; later calls are ignored).
    pub fn set_observer(&self, obs: Box<dyn Fn(SlabEvent) + Send + Sync>) {
        let _ = self.observer.set(obs);
    }

    /// Allocate a slot for a `len`-word value: the smallest fitting
    /// class, falling up to larger classes when it is exhausted (slab
    /// overflow). `None` when nothing fits anywhere (capacity) or `len`
    /// exceeds the largest class (oversized value).
    pub fn alloc(&self, len: usize) -> Option<u32> {
        let first = self.geo.class_for_len(len)?;
        let mut inner = self.inner.lock().unwrap();
        for class in first..self.geo.num_classes() {
            if let Some(index) = inner.free[class].pop() {
                let slot = self.geo.pack(class, index);
                let ord = self.geo.ordinal(slot);
                debug_assert!(!inner.in_use[ord], "allocated slot was marked in use");
                inner.in_use[ord] = true;
                inner.outstanding += 1;
                if let Some(obs) = self.observer.get() {
                    obs(SlabEvent::Alloc { slot });
                }
                return Some(slot);
            }
        }
        None
    }

    /// Return `slot` to its class's free list. Panics on a double free
    /// (the accounting bug this allocator exists to catch).
    pub fn free(&self, slot: u32) {
        let class = self.geo.class_of(slot);
        let index = self.geo.index_of(slot);
        assert!(
            class < self.geo.num_classes() && (index as usize) < self.geo.slots_per_class(),
            "free of out-of-range slot {slot:#x}"
        );
        let ord = self.geo.ordinal(slot);
        let mut inner = self.inner.lock().unwrap();
        assert!(inner.in_use[ord], "double free of slot {slot:#x} (class {class} index {index})");
        inner.in_use[ord] = false;
        inner.outstanding -= 1;
        inner.free[class].push(index);
        if let Some(obs) = self.observer.get() {
            obs(SlabEvent::Free { slot });
        }
    }

    /// Slots currently allocated.
    pub fn outstanding(&self) -> usize {
        self.inner.lock().unwrap().outstanding
    }

    /// Free slots remaining in `class` (not counting larger classes an
    /// allocation could fall up into).
    pub fn free_count(&self, class: usize) -> usize {
        self.inner.lock().unwrap().free[class].len()
    }

    /// Audit against the caller's set of live slots (e.g. every slot the
    /// location index says is homed here): every slot of every class must
    /// be accounted for **exactly once** — on its class's free list XOR
    /// in `live` — with no cross-class aliasing. Returns a description of
    /// the first violation.
    pub fn audit(&self, live: impl IntoIterator<Item = u32>) -> Result<(), String> {
        let inner = self.inner.lock().unwrap();
        let mut seen = vec![false; self.geo.total_slots()];
        for slot in live {
            let class = self.geo.class_of(slot);
            if class >= self.geo.num_classes()
                || self.geo.index_of(slot) as usize >= self.geo.slots_per_class()
            {
                return Err(format!("live slot {slot:#x} out of geometry range"));
            }
            let ord = self.geo.ordinal(slot);
            if seen[ord] {
                return Err(format!("slot {slot:#x} referenced twice by live set"));
            }
            seen[ord] = true;
            if !inner.in_use[ord] {
                return Err(format!("live slot {slot:#x} is not marked allocated"));
            }
        }
        for class in 0..self.geo.num_classes() {
            for &index in &inner.free[class] {
                let ord = self.geo.ordinal(self.geo.pack(class, index));
                if seen[ord] {
                    return Err(format!(
                        "slot class {class} index {index} is both live and on the free list"
                    ));
                }
                if inner.in_use[ord] {
                    return Err(format!(
                        "slot class {class} index {index} on the free list but marked in use"
                    ));
                }
                seen[ord] = true;
            }
        }
        if let Some(ord) = seen.iter().position(|s| !s) {
            return Err(format!(
                "slot ordinal {ord} leaked: neither live nor on a free list \
                 ({} outstanding)",
                inner.outstanding
            ));
        }
        Ok(())
    }
}

/// Default huge-page size in words (2^20 words = 8 MiB in the simulation;
/// stands in for the paper's 1 GB pages).
pub const HUGE_PAGE_WORDS: usize = 1 << 20;

pub struct MemPool {
    node: Arc<NodeFabric>,
    page_words: usize,
    inner: Mutex<PoolInner>,
}

struct PoolInner {
    /// Current host huge page and bump cursor.
    page: Option<Region>,
    cursor: u64,
    /// Current device page and cursor.
    dev_page: Option<Region>,
    dev_cursor: u64,
    /// Named regions (channel-owned), e.g. "bar/sst.cache".
    named: HashMap<String, Region>,
    pages_registered: usize,
}

impl MemPool {
    pub fn new(node: Arc<NodeFabric>, page_words: usize) -> Self {
        MemPool {
            node,
            page_words,
            inner: Mutex::new(PoolInner {
                page: None,
                cursor: 0,
                dev_page: None,
                dev_cursor: 0,
                named: HashMap::new(),
                pages_registered: 0,
            }),
        }
    }

    /// Carve `words` out of the pool (registering a new huge page only
    /// when the current one is exhausted).
    pub fn alloc(&self, words: usize, device: bool) -> Region {
        assert!(words > 0, "zero-length region");
        let mut inner = self.inner.lock().unwrap();
        if device {
            // Device memory is small; register it in page-sized chunks too.
            let need_new = match &inner.dev_page {
                Some(p) => inner.dev_cursor + words as u64 > p.len,
                None => true,
            };
            if need_new {
                let chunk = words.max(1 << 10);
                inner.dev_page = Some(self.node.register_mr(chunk, true));
                inner.dev_cursor = 0;
                inner.pages_registered += 1;
            }
            let page = inner.dev_page.unwrap();
            let r = page.slice(inner.dev_cursor, words as u64);
            inner.dev_cursor += words as u64;
            r
        } else {
            let need_new = match &inner.page {
                Some(p) => inner.cursor + words as u64 > p.len,
                None => true,
            };
            if need_new {
                let chunk = self.page_words.max(words);
                inner.page = Some(self.node.register_mr(chunk, false));
                inner.cursor = 0;
                inner.pages_registered += 1;
            }
            let page = inner.page.unwrap();
            let r = page.slice(inner.cursor, words as u64);
            inner.cursor += words as u64;
            r
        }
    }

    /// Allocate and record under `name` (the channel's `"<chan>.<region>"`
    /// naming scheme). Idempotent lookup via [`MemPool::named`].
    pub fn alloc_named(&self, name: &str, words: usize, device: bool) -> Region {
        let r = self.alloc(words, device);
        let mut inner = self.inner.lock().unwrap();
        let prev = inner.named.insert(name.to_string(), r);
        assert!(prev.is_none(), "region name collision: {name}");
        r
    }

    pub fn named(&self, name: &str) -> Option<Region> {
        self.inner.lock().unwrap().named.get(name).copied()
    }

    /// Number of huge pages (= MRs) registered so far. LOCO's design goal
    /// is that this stays tiny regardless of channel count.
    pub fn pages_registered(&self) -> usize {
        self.inner.lock().unwrap().pages_registered
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::{Cluster, FabricConfig};

    #[test]
    fn many_regions_few_mrs() {
        let c = Cluster::new(1, FabricConfig::inline_ideal());
        let pool = MemPool::new(c.node(0).clone(), 1 << 14);
        for i in 0..100 {
            pool.alloc_named(&format!("chan{i}.data"), 64, false);
        }
        // 100 regions but only ⌈100*64 / 2^14⌉ = 1 huge page registered.
        assert_eq!(pool.pages_registered(), 1);
        assert_eq!(c.node(0).mr_count(), 1);
        assert!(pool.named("chan42.data").is_some());
        assert!(pool.named("nope").is_none());
    }

    #[test]
    fn page_rollover() {
        let c = Cluster::new(1, FabricConfig::inline_ideal());
        let pool = MemPool::new(c.node(0).clone(), 128);
        let a = pool.alloc(100, false);
        let b = pool.alloc(100, false); // doesn't fit in remaining 28
        assert_ne!(a.mr, b.mr);
        assert_eq!(pool.pages_registered(), 2);
    }

    #[test]
    fn device_alloc_is_device_space() {
        let c = Cluster::new(1, FabricConfig::inline_ideal());
        let pool = MemPool::new(c.node(0).clone(), 1 << 14);
        let d = pool.alloc(8, true);
        assert!(d.base >= crate::fabric::DEVICE_BASE);
        assert!(d.device);
    }

    #[test]
    #[should_panic(expected = "collision")]
    fn name_collision_panics() {
        let c = Cluster::new(1, FabricConfig::inline_ideal());
        let pool = MemPool::new(c.node(0).clone(), 1 << 14);
        pool.alloc_named("x", 8, false);
        pool.alloc_named("x", 8, false);
    }

    // ---- slab allocator ------------------------------------------------

    #[test]
    fn geometry_classes_and_offsets() {
        let g = SlabGeometry::new(100, 16); // rounds up to 128 ⇒ 8 classes
        assert_eq!(g.num_classes(), 8);
        assert_eq!(g.max_value_words(), 128);
        assert_eq!(g.class_for_len(1), Some(0));
        assert_eq!(g.class_for_len(2), Some(1));
        assert_eq!(g.class_for_len(3), Some(2));
        assert_eq!(g.class_for_len(128), Some(7));
        assert_eq!(g.class_for_len(129), None);
        assert_eq!(g.class_for_len(0), None);
        // Frames: value area + [hdr][ck][cv].
        assert_eq!(g.frame_words(0), 4);
        assert_eq!(g.frame_words(7), 131);
        // Offsets are dense and non-overlapping across class boundaries.
        let mut expected = 0u64;
        for class in 0..8 {
            for idx in 0..16u32 {
                let slot = g.pack(class, idx);
                assert_eq!(g.class_of(slot), class);
                assert_eq!(g.index_of(slot), idx);
                assert_eq!(g.slot_off(slot), expected, "class {class} idx {idx}");
                expected += g.frame_words(class);
            }
        }
        assert_eq!(expected as usize, g.total_words());
    }

    #[test]
    fn slab_alloc_picks_smallest_fitting_class_and_falls_up() {
        let alloc = SlabAllocator::new(SlabGeometry::new(8, 2)); // classes 1,2,4,8 × 2 slots
        let g = *alloc.geometry();
        let s = alloc.alloc(3).unwrap();
        assert_eq!(g.class_of(s), 2, "3 words should land in the 4-word class");
        let a = alloc.alloc(1).unwrap();
        let b = alloc.alloc(1).unwrap();
        assert_eq!((g.class_of(a), g.class_of(b)), (0, 0));
        // Class 0 exhausted: the next 1-word alloc falls up to class 1.
        let c = alloc.alloc(1).unwrap();
        assert_eq!(g.class_of(c), 1);
        assert_eq!(alloc.outstanding(), 4);
        // Oversized values are rejected outright.
        assert_eq!(alloc.alloc(9), None);
        alloc.free(a);
        assert_eq!(g.class_of(alloc.alloc(1).unwrap()), 0, "freed slot reused first");
    }

    #[test]
    #[should_panic(expected = "double free")]
    fn slab_double_free_panics() {
        let alloc = SlabAllocator::new(SlabGeometry::new(4, 4));
        let s = alloc.alloc(2).unwrap();
        alloc.free(s);
        alloc.free(s);
    }

    /// Satellite: seeded insert/update/remove churn across classes with a
    /// post-run audit — every slot exactly once in a free list or the
    /// live set, no cross-class overlap, no leaks.
    #[test]
    fn slab_seeded_churn_audits_clean() {
        use crate::util::rng::Rng;
        for seed in 0..8u64 {
            let alloc = SlabAllocator::new(SlabGeometry::new(16, 8)); // 5 classes × 8
            let g = *alloc.geometry();
            let mut rng = Rng::seeded(seed);
            let mut live: Vec<u32> = Vec::new();
            for _ in 0..400 {
                match rng.gen_range(3) {
                    // "insert": grab a slot for a random-size value.
                    0 => {
                        let len = 1 + rng.gen_range(16) as usize;
                        if let Some(s) = alloc.alloc(len) {
                            assert!(g.cap(g.class_of(s)) >= len, "seed {seed}: class too small");
                            live.push(s);
                        }
                    }
                    // "update that outgrows": relocate = alloc new, free old.
                    1 if !live.is_empty() => {
                        let i = rng.gen_range(live.len() as u64) as usize;
                        let len = 1 + rng.gen_range(16) as usize;
                        if let Some(s) = alloc.alloc(len) {
                            let old = std::mem::replace(&mut live[i], s);
                            alloc.free(old);
                        }
                    }
                    // "remove".
                    _ if !live.is_empty() => {
                        let i = rng.gen_range(live.len() as u64) as usize;
                        alloc.free(live.swap_remove(i));
                    }
                    _ => {}
                }
                // Slot ids must stay unique at all times.
                let mut sorted: Vec<u32> = live.clone();
                sorted.sort_unstable();
                sorted.dedup();
                assert_eq!(sorted.len(), live.len(), "seed {seed}: duplicate live slot");
            }
            assert_eq!(alloc.outstanding(), live.len(), "seed {seed}");
            alloc.audit(live.iter().copied()).unwrap_or_else(|e| panic!("seed {seed}: {e}"));
            // Audit must also detect a fabricated leak.
            if let Some(&s) = live.first() {
                let err = alloc.audit(live.iter().skip(1).copied()).unwrap_err();
                assert!(err.contains("leaked"), "seed {seed}: wrong audit error: {err} ({s:#x})");
            }
        }
    }
}
