//! Network-memory pooling (paper Appendix A.2).
//!
//! Registration of an MR is expensive on real hardware, and many small MRs
//! thrash the NIC's translation cache. LOCO therefore aggregates all
//! channel memory into a few huge registered pages and carves named
//! regions out of them. The MPI baseline deliberately does *not* do this
//! (one MR per window), which is half of the Fig. 4 story.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use crate::fabric::{NodeFabric, Region};

/// Default huge-page size in words (2^20 words = 8 MiB in the simulation;
/// stands in for the paper's 1 GB pages).
pub const HUGE_PAGE_WORDS: usize = 1 << 20;

pub struct MemPool {
    node: Arc<NodeFabric>,
    page_words: usize,
    inner: Mutex<PoolInner>,
}

struct PoolInner {
    /// Current host huge page and bump cursor.
    page: Option<Region>,
    cursor: u64,
    /// Current device page and cursor.
    dev_page: Option<Region>,
    dev_cursor: u64,
    /// Named regions (channel-owned), e.g. "bar/sst.cache".
    named: HashMap<String, Region>,
    pages_registered: usize,
}

impl MemPool {
    pub fn new(node: Arc<NodeFabric>, page_words: usize) -> Self {
        MemPool {
            node,
            page_words,
            inner: Mutex::new(PoolInner {
                page: None,
                cursor: 0,
                dev_page: None,
                dev_cursor: 0,
                named: HashMap::new(),
                pages_registered: 0,
            }),
        }
    }

    /// Carve `words` out of the pool (registering a new huge page only
    /// when the current one is exhausted).
    pub fn alloc(&self, words: usize, device: bool) -> Region {
        assert!(words > 0, "zero-length region");
        let mut inner = self.inner.lock().unwrap();
        if device {
            // Device memory is small; register it in page-sized chunks too.
            let need_new = match &inner.dev_page {
                Some(p) => inner.dev_cursor + words as u64 > p.len,
                None => true,
            };
            if need_new {
                let chunk = words.max(1 << 10);
                inner.dev_page = Some(self.node.register_mr(chunk, true));
                inner.dev_cursor = 0;
                inner.pages_registered += 1;
            }
            let page = inner.dev_page.unwrap();
            let r = page.slice(inner.dev_cursor, words as u64);
            inner.dev_cursor += words as u64;
            r
        } else {
            let need_new = match &inner.page {
                Some(p) => inner.cursor + words as u64 > p.len,
                None => true,
            };
            if need_new {
                let chunk = self.page_words.max(words);
                inner.page = Some(self.node.register_mr(chunk, false));
                inner.cursor = 0;
                inner.pages_registered += 1;
            }
            let page = inner.page.unwrap();
            let r = page.slice(inner.cursor, words as u64);
            inner.cursor += words as u64;
            r
        }
    }

    /// Allocate and record under `name` (the channel's `"<chan>.<region>"`
    /// naming scheme). Idempotent lookup via [`MemPool::named`].
    pub fn alloc_named(&self, name: &str, words: usize, device: bool) -> Region {
        let r = self.alloc(words, device);
        let mut inner = self.inner.lock().unwrap();
        let prev = inner.named.insert(name.to_string(), r);
        assert!(prev.is_none(), "region name collision: {name}");
        r
    }

    pub fn named(&self, name: &str) -> Option<Region> {
        self.inner.lock().unwrap().named.get(name).copied()
    }

    /// Number of huge pages (= MRs) registered so far. LOCO's design goal
    /// is that this stays tiny regardless of channel count.
    pub fn pages_registered(&self) -> usize {
        self.inner.lock().unwrap().pages_registered
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::{Cluster, FabricConfig};

    #[test]
    fn many_regions_few_mrs() {
        let c = Cluster::new(1, FabricConfig::inline_ideal());
        let pool = MemPool::new(c.node(0).clone(), 1 << 14);
        for i in 0..100 {
            pool.alloc_named(&format!("chan{i}.data"), 64, false);
        }
        // 100 regions but only ⌈100*64 / 2^14⌉ = 1 huge page registered.
        assert_eq!(pool.pages_registered(), 1);
        assert_eq!(c.node(0).mr_count(), 1);
        assert!(pool.named("chan42.data").is_some());
        assert!(pool.named("nope").is_none());
    }

    #[test]
    fn page_rollover() {
        let c = Cluster::new(1, FabricConfig::inline_ideal());
        let pool = MemPool::new(c.node(0).clone(), 128);
        let a = pool.alloc(100, false);
        let b = pool.alloc(100, false); // doesn't fit in remaining 28
        assert_ne!(a.mr, b.mr);
        assert_eq!(pool.pages_registered(), 2);
    }

    #[test]
    fn device_alloc_is_device_space() {
        let c = Cluster::new(1, FabricConfig::inline_ideal());
        let pool = MemPool::new(c.node(0).clone(), 1 << 14);
        let d = pool.alloc(8, true);
        assert!(d.base >= crate::fabric::DEVICE_BASE);
        assert!(d.device);
    }

    #[test]
    #[should_panic(expected = "collision")]
    fn name_collision_panics() {
        let c = Cluster::new(1, FabricConfig::inline_ideal());
        let pool = MemPool::new(c.node(0).clone(), 1 << 14);
        pool.alloc_named("x", 8, false);
        pool.alloc_named("x", 8, false);
    }
}
