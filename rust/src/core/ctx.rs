//! Per-thread issuing context and the fence engine (paper §5.3, App. A).
//!
//! Each application thread obtains a [`ThreadCtx`] from its node's
//! manager. The context owns:
//!
//! * a **private QP per peer** (created lazily) — no cross-thread
//!   synchronization on the submission path;
//! * an **ack-bit allocator** for completion tracking;
//! * a **`mem_ref` pool**: small registered scratch blocks used as the
//!   local source/target of verbs, recycled through per-thread free lists;
//! * **unfenced-write counters** per peer, which the fence engine uses to
//!   choose the cheapest correct fence implementation.
//!
//! Fence semantics (paper §5.3): a fence guarantees that all covered
//! remote WRITEs are *placed* before any subsequent operation. The
//! implementation posts a zero-length READ on every QP that has unfenced
//! writes (the RFC 5040 flushing rule) and waits for the acks; QPs with no
//! unfenced writes cost nothing. Blocking reads/atomics opportunistically
//! reset the counter for their peer, since their completion already proves
//! placement of everything earlier on that QP — this is the paper's
//! "dynamically chooses the best performing implementation".

use std::cell::RefCell;
use std::marker::PhantomData;
use std::rc::Rc;
use std::sync::atomic::{AtomicU32, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::fabric::{Cluster, NodeFabric, Payload, PostList, QpId, Region, Verb, Wqe};

use super::ack::{AckAllocator, AckKey, AckRegistry};
use super::mem_pool::MemPool;

/// Scope of a fence (paper §5.3).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FenceScope {
    /// Prior ops from this thread to this peer are placed first.
    Pair(crate::fabric::NodeId),
    /// Prior ops from this thread (any peer) are placed first.
    Thread,
    /// Prior ops from this *node* (any thread, any peer) are placed first.
    Global,
}

/// The Sync part of a context, visible to the manager for global fences.
pub struct CtxShared {
    /// Count of writes not yet covered by a flushing op, per peer.
    pub(crate) unfenced: Box<[AtomicU64]>,
    /// Lazily created private QPs, per peer.
    pub(crate) qps: Mutex<Vec<Option<QpId>>>,
}

impl CtxShared {
    pub fn new(num_nodes: usize) -> Arc<Self> {
        Arc::new(CtxShared {
            unfenced: (0..num_nodes).map(|_| AtomicU64::new(0)).collect(),
            qps: Mutex::new(vec![None; num_nodes]),
        })
    }

    pub(crate) fn qp(&self, cluster: &Cluster, me: crate::fabric::NodeId, peer: crate::fabric::NodeId) -> QpId {
        let mut qps = self.qps.lock().unwrap();
        if let Some(qp) = qps[peer as usize] {
            return qp;
        }
        let qp = cluster.create_qp(me, peer);
        qps[peer as usize] = Some(qp);
        qp
    }
}

/// Process-unique [`ThreadCtx`] ids: the race checker keys its rule-(c)
/// pending-unfenced-write tracking per issuing context (fences are a
/// per-thread-per-peer contract, so the tracking must be too).
static NEXT_CTX_ID: AtomicU32 = AtomicU32::new(1);

/// Size classes for mem_ref scratch blocks (words).
const MEMREF_SMALL: usize = 64;
const MEMREF_LARGE: usize = 1024;

#[derive(Default)]
struct MemRefFree {
    small: Vec<u64>,
    large: Vec<u64>,
}

/// A temporary chunk of registered network memory (paper App. A.2),
/// used as the local buffer of READ results and atomic return values.
/// Returned to the owning thread's free list on drop.
pub struct MemRef {
    addr: u64,
    len: usize,
    class_small: bool,
    node: Arc<NodeFabric>,
    free: Rc<RefCell<MemRefFree>>,
}

impl MemRef {
    /// Word address of this block in local memory (for verb `local` args).
    pub fn addr(&self) -> u64 {
        self.addr
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    #[inline]
    pub fn load(&self, i: usize) -> u64 {
        debug_assert!(i < self.len);
        self.node.arena().load(self.addr + i as u64)
    }

    #[inline]
    pub fn store(&self, i: usize, v: u64) {
        debug_assert!(i < self.len);
        self.node.arena().store(self.addr + i as u64, v);
    }

    pub fn to_vec(&self) -> Vec<u64> {
        let mut out = vec![0u64; self.len];
        self.node.arena().load_words(self.addr, &mut out);
        out
    }

    pub fn copy_into(&self, out: &mut [u64]) {
        debug_assert!(out.len() <= self.len);
        self.node.arena().load_words(self.addr, out);
    }
}

impl Drop for MemRef {
    fn drop(&mut self) {
        let mut free = self.free.borrow_mut();
        if self.class_small {
            free.small.push(self.addr);
        } else {
            free.large.push(self.addr);
        }
    }
}

/// Read buffers recycled per thread, capped in count AND per-buffer
/// size so a burst of huge reads doesn't pin memory forever.
const READ_POOL_CAP: usize = 64;
/// Largest buffer (in words) worth pooling; bigger ones are freed on
/// drop. 4096 words = 32 KiB, far above every slot/row read in the
/// codebase but small enough that a full pool stays under 2 MiB.
const READ_POOL_MAX_WORDS: usize = 4096;

type ReadPool = Rc<RefCell<Vec<Vec<u64>>>>;

/// A pooled read result (the locality tier's zero-copy read path).
///
/// [`ThreadCtx::read`] / [`ThreadCtx::read_many`] used to allocate a
/// fresh `Vec<u64>` per operation — measurable per-op software overhead
/// on the hot read path (Brock et al. 2019). A `ReadGuard` instead
/// borrows a buffer from the owning thread's free list and returns it on
/// drop; it derefs to `[u64]`, so call sites index, slice and iterate
/// exactly as before. Call [`ReadGuard::to_vec`] (copy) or
/// [`ReadGuard::into_vec`] (steal the allocation, bypassing the pool)
/// when an owned vector must outlive the guard.
pub struct ReadGuard {
    vec: Vec<u64>,
    pool: ReadPool,
}

impl ReadGuard {
    pub fn to_vec(&self) -> Vec<u64> {
        self.vec.clone()
    }

    /// Take the buffer out of the pool's custody.
    pub fn into_vec(mut self) -> Vec<u64> {
        std::mem::take(&mut self.vec)
    }
}

impl std::ops::Deref for ReadGuard {
    type Target = [u64];

    #[inline]
    fn deref(&self) -> &[u64] {
        &self.vec
    }
}

impl Drop for ReadGuard {
    fn drop(&mut self) {
        let mut pool = self.pool.borrow_mut();
        if !self.vec.is_empty()
            && self.vec.capacity() <= READ_POOL_MAX_WORDS
            && pool.len() < READ_POOL_CAP
        {
            pool.push(std::mem::take(&mut self.vec));
        }
    }
}

impl std::fmt::Debug for ReadGuard {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        self.vec.fmt(f)
    }
}

impl PartialEq<[u64]> for ReadGuard {
    fn eq(&self, other: &[u64]) -> bool {
        self.vec == other
    }
}

impl PartialEq<Vec<u64>> for ReadGuard {
    fn eq(&self, other: &Vec<u64>) -> bool {
        &self.vec == other
    }
}

impl PartialEq for ReadGuard {
    fn eq(&self, other: &ReadGuard) -> bool {
        self.vec == other.vec
    }
}

/// Per-thread issuing context. Deliberately `!Sync`: one per thread, as
/// in the paper's backend.
pub struct ThreadCtx {
    cluster: Arc<Cluster>,
    node: Arc<NodeFabric>,
    me: crate::fabric::NodeId,
    pub(crate) shared: Arc<CtxShared>,
    alloc: RefCell<AckAllocator>,
    registry: Arc<AckRegistry>,
    memref_free: Rc<RefCell<MemRefFree>>,
    read_pool: ReadPool,
    pool: Arc<MemPool>,
    cqe_buf: RefCell<Vec<crate::fabric::Cqe>>,
    /// Largest WRITE payload (words) posted inline (0 = never); mirrors
    /// `LatencyModel::max_inline_words`.
    max_inline: usize,
    /// Selective-signaling chain length (`FabricConfig::signal_every`;
    /// ≤ 1 = every WQE signaled).
    signal_every: u32,
    /// Per-peer count of consecutive covered (unsignaled) stream writes
    /// since the last signaled one — the "every Nth in a stream" cadence
    /// of [`ThreadCtx::write_covered`].
    covered_streak: RefCell<Vec<u32>>,
    /// Process-unique id (race-checker rule (c) tracking key).
    ctx_id: u32,
    /// Cached race-checker handle; `None` (the default outside sim)
    /// makes every checker hook below a dead `Option` branch.
    checker: Option<Arc<crate::analysis::Checker>>,
    _not_sync: PhantomData<*const ()>,
}

impl ThreadCtx {
    pub(crate) fn new(
        cluster: Arc<Cluster>,
        me: crate::fabric::NodeId,
        registry: Arc<AckRegistry>,
        shared: Arc<CtxShared>,
        pool: Arc<MemPool>,
    ) -> Self {
        let node = cluster.node(me).clone();
        let max_inline = cluster.config().latency.max_inline_words;
        let signal_every = cluster.config().signal_every;
        let num_nodes = cluster.num_nodes();
        let checker = cluster.checker().cloned();
        ThreadCtx {
            cluster,
            node,
            me,
            shared,
            alloc: RefCell::new(AckAllocator::new(registry.clone())),
            registry,
            memref_free: Rc::new(RefCell::new(MemRefFree::default())),
            read_pool: Rc::new(RefCell::new(Vec::new())),
            pool,
            cqe_buf: RefCell::new(Vec::with_capacity(64)),
            max_inline,
            signal_every,
            covered_streak: RefCell::new(vec![0; num_nodes]),
            ctx_id: NEXT_CTX_ID.fetch_add(1, Ordering::Relaxed),
            checker,
            _not_sync: PhantomData,
        }
    }

    /// Record a remote WRITE not yet covered by a flushing op: bump the
    /// fence engine's per-peer counter and tell the race checker (rule
    /// (c)) which words are pending publication-unsafe.
    #[inline]
    fn note_unfenced_write(
        &self,
        peer: crate::fabric::NodeId,
        addr: u64,
        len: u64,
        site: &'static str,
    ) {
        self.shared.unfenced[peer as usize].fetch_add(1, Ordering::Relaxed);
        if let Some(chk) = &self.checker {
            chk.on_unfenced_write(self.ctx_id, self.me, peer, addr, len, site);
        }
    }

    /// A flushing op (fence read, READ, atomic) to `peer` completed (or
    /// was issued-and-awaited): everything earlier on this thread's QP
    /// is placed, so the counter and the checker's pending set reset.
    #[inline]
    fn clear_unfenced(&self, peer: crate::fabric::NodeId) {
        self.shared.unfenced[peer as usize].store(0, Ordering::Relaxed);
        if let Some(chk) = &self.checker {
            chk.on_flush(self.ctx_id, peer);
        }
    }

    /// Tell the race checker this thread is about to **publish** — make
    /// a location or data announcement other nodes may act on (kvstore
    /// tracker broadcasts, coalesced-invalidation enqueues). If any
    /// covered write into a fence-published frame region is still
    /// unfenced on this context, the checker reports
    /// publication-before-fence (rule (c)). No-op without a checker.
    pub fn note_publication(&self, site: &'static str) {
        if let Some(chk) = &self.checker {
            chk.on_publication(self.ctx_id, self.me, site);
        }
    }

    /// Record a lock-acquire happens-before edge for the race checker:
    /// this node's history joins everything the previous holder did
    /// before its matching release. Keyed by the lock word's
    /// `(host, addr)`. No-op without a checker.
    pub fn note_lock_acquire(&self, lock_node: crate::fabric::NodeId, lock_addr: u64) {
        if let Some(chk) = &self.checker {
            chk.lock_acquire(self.me, lock_node, lock_addr);
        }
    }

    /// Record the matching lock-release edge (see
    /// [`ThreadCtx::note_lock_acquire`]).
    pub fn note_lock_release(&self, lock_node: crate::fabric::NodeId, lock_addr: u64) {
        if let Some(chk) = &self.checker {
            chk.lock_release(self.me, lock_node, lock_addr);
        }
    }

    /// Build a WQE, picking inline automatically: WRITE payloads of at
    /// most `LatencyModel::max_inline_words` are copied into the WQE at
    /// post time, so the NIC skips the scatter-gather payload fetch
    /// (charged `inline_ns` instead of `wqe_fetch_ns`).
    #[inline]
    fn mk_wqe(&self, wr_id: u64, verb: Verb) -> Wqe {
        let inline = match &verb {
            Verb::Write { data, .. } => data.len() <= self.max_inline,
            _ => false,
        };
        let wqe = Wqe::new(wr_id, verb);
        if inline {
            wqe.inlined()
        } else {
            wqe
        }
    }

    /// Drain a batch of completions from the node's shared CQ and clear
    /// their ack bits. Waiting threads call this cooperatively with the
    /// polling thread — on real hardware application threads poll the CQ
    /// the same way; here it also removes one scheduler hop per op
    /// (EXPERIMENTS.md §Perf).
    #[inline]
    pub fn drain_cq(&self) -> usize {
        let mut buf = self.cqe_buf.borrow_mut();
        buf.clear();
        let n = self.node.cq().poll(64, &mut buf);
        for cqe in buf.iter() {
            self.registry.complete(cqe.wr_id, cqe.is_ok());
        }
        if n > 0 {
            // HB edge: the engine's effects before these completions are
            // now ordered before this poller's future accesses.
            if let Some(chk) = &self.checker {
                chk.on_cq_drain(self.me);
            }
        }
        n
    }

    /// Wait for a key, assisting with CQ draining while spinning. The
    /// wedge bailout routes through [`crate::util::WaitBudget`]: 30 s of
    /// wall clock under threads, a zero-progress scheduler streak under
    /// the deterministic simulator — virtual time sailing past "30 s"
    /// must not trip it.
    pub fn wait(&self, key: &AckKey) {
        let mut bo = crate::util::Backoff::new();
        let mut budget = crate::util::WaitBudget::wedge(std::time::Duration::from_secs(30));
        while !key.query() {
            if self.drain_cq() == 0 {
                bo.snooze();
                assert!(
                    !budget.expired(),
                    "ctx wait timed out (30 s): outstanding ops never completed"
                );
            } else {
                bo.reset();
            }
        }
    }

    /// Wait like [`ThreadCtx::wait`], then surface per-op failure:
    /// `Err(Error::PeerFailed)` if any covered op completed with an
    /// error CQE (its peer crash-stopped) instead of taking effect. A
    /// key never hangs on a crash — the fabric drains dead ops with
    /// error completions.
    pub fn wait_checked(&self, key: &AckKey) -> crate::Result<()> {
        self.wait(key);
        if key.failed() {
            Err(crate::Error::PeerFailed("remote op completed in error".into()))
        } else {
            Ok(())
        }
    }

    /// Has `node` crash-stopped? (Fault injection; always false on a
    /// fault-free fabric.)
    #[inline]
    pub fn node_down(&self, node: crate::fabric::NodeId) -> bool {
        self.cluster.is_down(node)
    }

    /// Has *any* node crash-stopped? Cheap (one summary mask load); the
    /// channel layer's bounded waits use it to decide whether an
    /// unusually long spin might be waiting on a corpse.
    #[inline]
    pub fn cluster_has_failures(&self) -> bool {
        self.cluster.down_mask() != 0
    }

    pub fn me(&self) -> crate::fabric::NodeId {
        self.me
    }

    pub fn num_nodes(&self) -> usize {
        self.cluster.num_nodes()
    }

    /// Grab a scratch block of at least `len` words.
    pub fn mem_ref(&self, len: usize) -> MemRef {
        assert!(len <= MEMREF_LARGE, "mem_ref request of {len} words exceeds {MEMREF_LARGE}");
        let small = len <= MEMREF_SMALL;
        let addr = {
            let mut free = self.memref_free.borrow_mut();
            let list = if small { &mut free.small } else { &mut free.large };
            list.pop()
        };
        let addr = addr.unwrap_or_else(|| {
            let words = if small { MEMREF_SMALL } else { MEMREF_LARGE };
            self.pool.alloc(words, false).base
        });
        MemRef {
            addr,
            len,
            class_small: small,
            node: self.node.clone(),
            free: self.memref_free.clone(),
        }
    }

    /// Local CPU access is a plain memory access only for *host* memory;
    /// NIC device memory is not coherent with the CPU (paper App. A.2)
    /// and must be reached through the NIC even from the owning node.
    #[inline]
    fn local_direct(&self, region: &Region) -> bool {
        region.node == self.me && !region.device
    }

    #[inline]
    fn issue(&self, peer: crate::fabric::NodeId, verb: Verb) -> AckKey {
        let qp = self.shared.qp(&self.cluster, self.me, peer);
        let (wr_id, word, mask) = self.alloc.borrow_mut().alloc();
        self.cluster.post(qp, self.mk_wqe(wr_id, verb));
        AckKey::single(word, mask)
    }

    #[inline]
    fn issue_unsignaled(&self, peer: crate::fabric::NodeId, verb: Verb) {
        let qp = self.shared.qp(&self.cluster, self.me, peer);
        self.cluster.post(qp, self.mk_wqe(0, verb).unsignaled());
    }

    /// [`ThreadCtx::issue`] with the target region's MR stamped into the
    /// WQE, moving MR validation from post time to DMA-execution time
    /// (stale-MR detection for in-flight WQEs; see [`crate::analysis`]).
    /// Scalar region verbs use this; grouped posts keep `rkey = None`
    /// and fall back to the target's whole-table `covers` check.
    #[inline]
    fn issue_mr(&self, peer: crate::fabric::NodeId, verb: Verb, mr: u32) -> AckKey {
        let qp = self.shared.qp(&self.cluster, self.me, peer);
        let (wr_id, word, mask) = self.alloc.borrow_mut().alloc();
        self.cluster.post(qp, self.mk_wqe(wr_id, verb).with_rkey(mr));
        AckKey::single(word, mask)
    }

    #[inline]
    fn issue_unsignaled_mr(&self, peer: crate::fabric::NodeId, verb: Verb, mr: u32) {
        let qp = self.shared.qp(&self.cluster, self.me, peer);
        self.cluster.post(qp, self.mk_wqe(0, verb).unsignaled().with_rkey(mr));
    }

    // ---- batched issue (doorbell-batched async pipeline) ------------

    /// Issue an ordered batch of verbs to one peer under a **single
    /// doorbell** (one `PostList`, one ack-word update for the whole
    /// batch). Returns the combined completion key. The scalar `issue`
    /// path is semantically a batch of one.
    pub fn post_list(&self, peer: crate::fabric::NodeId, verbs: Vec<Verb>) -> AckKey {
        if verbs.is_empty() {
            return AckKey::ready();
        }
        let qp = self.shared.qp(&self.cluster, self.me, peer);
        let mut wr_ids = Vec::with_capacity(verbs.len());
        let key = self.alloc.borrow_mut().alloc_batch(verbs.len(), &mut wr_ids);
        let mut list = PostList::with_capacity(verbs.len());
        for (wr_id, verb) in wr_ids.into_iter().zip(verbs) {
            list.push(self.mk_wqe(wr_id, verb));
        }
        self.cluster.post_list(qp, list);
        key
    }

    /// Batched asynchronous reads: one doorbell per **distinct peer**
    /// instead of one per op, with ack allocation amortized across the
    /// whole request set. Requests are `(region, word offset, words)`;
    /// entries targeting local host memory complete immediately. Returns
    /// `(key, bufs)` — `bufs[i]` holds request `i`'s words once `key`
    /// completes.
    pub fn read_many_async(&self, reqs: &[(Region, u64, usize)]) -> (AckKey, Vec<MemRef>) {
        let mut bufs = Vec::with_capacity(reqs.len());
        let mut remote: Vec<(crate::fabric::NodeId, Verb)> = Vec::new();
        for (region, off, len) in reqs {
            let addr = region.at(*off);
            let buf = self.mem_ref(*len);
            if self.local_direct(region) {
                for i in 0..*len as u64 {
                    let w = self.node.arena().load(addr + i);
                    self.node.arena().store(buf.addr + i, w);
                }
            } else {
                remote.push((
                    region.node,
                    Verb::Read { remote: addr, local: buf.addr, len: *len as u32 },
                ));
            }
            bufs.push(buf);
        }
        (self.post_grouped(remote), bufs)
    }

    /// Grab a pooled read buffer of exactly `len` words (zeroed length,
    /// recycled allocation).
    fn pooled_vec(&self, len: usize) -> Vec<u64> {
        let mut v = self.read_pool.borrow_mut().pop().unwrap_or_default();
        v.clear();
        v.resize(len, 0);
        v
    }

    /// Copy a completed mem_ref into a pooled [`ReadGuard`].
    fn guard_from(&self, buf: &MemRef) -> ReadGuard {
        let mut v = self.pooled_vec(buf.len());
        buf.copy_into(&mut v);
        ReadGuard { vec: v, pool: self.read_pool.clone() }
    }

    /// Blocking batched read: issue via [`ThreadCtx::read_many_async`],
    /// wait once for the whole batch, and hand the results out as pooled
    /// [`ReadGuard`]s (no per-entry allocation on the steady state). Like
    /// [`ThreadCtx::read`], the completed READs prove placement of every
    /// earlier write on the involved QPs, so those peers' unfenced
    /// counters reset (the fence engine's fast path, amortized).
    pub fn read_many(&self, reqs: &[(Region, u64, usize)]) -> Vec<ReadGuard> {
        let (key, bufs) = self.read_many_async(reqs);
        self.wait(&key);
        let any_failed = key.failed();
        // If the *issuing* node crash-stopped, every remote read failed
        // regardless of its target's health.
        let me_down = any_failed && self.cluster.is_down(self.me);
        for (i, (region, _, len)) in reqs.iter().enumerate() {
            if any_failed && (me_down || self.cluster.is_down(region.node)) {
                // Failed READ: the buffer was never written. Zero it so
                // stale pool contents can't masquerade as a fresh (even
                // checksum-valid) frame; callers' validation protocols
                // then retry and take their dead-peer path.
                for w in 0..*len {
                    bufs[i].store(w, 0);
                }
                continue;
            }
            if region.node != self.me {
                self.clear_unfenced(region.node);
            }
        }
        bufs.iter().map(|b| self.guard_from(b)).collect()
    }

    /// Batched asynchronous writes: `(region, word offset, words)`
    /// entries, grouped into one doorbell per distinct peer, ack
    /// allocation amortized batch-wide. Local host targets are plain
    /// stores. Completion (the returned key) does NOT imply placement —
    /// fence for that, once, for the whole batch.
    pub fn write_many(&self, writes: &[(Region, u64, &[u64])]) -> AckKey {
        let mut remote: Vec<(crate::fabric::NodeId, Verb)> = Vec::new();
        for (region, off, words) in writes {
            let addr = region.at(*off);
            if self.local_direct(region) {
                self.node.arena().store_words(addr, words, false);
            } else {
                self.note_unfenced_write(region.node, addr, words.len() as u64, "ctx::write_many");
                remote.push((
                    region.node,
                    Verb::Write { remote: addr, data: Payload::from_words(words) },
                ));
            }
        }
        self.post_grouped(remote)
    }

    /// Shared tail of the `*_many` paths: group into one [`PostList`]
    /// per distinct peer — a doorbell cannot span QPs — apply
    /// **selective signaling** to all-WRITE chains, allocate ack bits
    /// only for the signaled entries (one `fetch_or` per ack word for
    /// the whole mixed-peer batch), and post each list under its single
    /// doorbell, preserving per-peer submission order.
    ///
    /// Selective signaling (the hot-write-path economy): in a per-peer
    /// chain consisting solely of WRITEs, only every
    /// [`FabricConfig::signal_every`](crate::fabric::FabricConfig)-th
    /// WQE and the chain's last WQE are signaled; per-QP FIFO completion
    /// order means the covering CQE retires the whole unsignaled prefix,
    /// and a failed unsignaled WQE propagates through the covering
    /// completion via the QP's chain error. Chains carrying READs or
    /// atomics keep per-op signaling (their completions carry results).
    fn post_grouped(&self, remote: Vec<(crate::fabric::NodeId, Verb)>) -> AckKey {
        if remote.is_empty() {
            return AckKey::ready();
        }
        let mut lists: Vec<(crate::fabric::NodeId, Vec<Verb>)> = Vec::new();
        for (peer, verb) in remote {
            let i = match lists.iter().position(|(p, _)| *p == peer) {
                Some(i) => i,
                None => {
                    lists.push((peer, Vec::new()));
                    lists.len() - 1
                }
            };
            lists[i].1.push(verb);
        }
        // Which entries of each chain get a CQE (and hence an ack bit)?
        let n = self.signal_every.max(1) as usize;
        let mut signaled: Vec<Vec<bool>> = Vec::with_capacity(lists.len());
        let mut total_signaled = 0usize;
        for (_, verbs) in &lists {
            let all_writes = verbs.iter().all(|v| matches!(v, Verb::Write { .. }));
            let flags: Vec<bool> = (0..verbs.len())
                .map(|i| !all_writes || n <= 1 || (i + 1) % n == 0 || i + 1 == verbs.len())
                .collect();
            total_signaled += flags.iter().filter(|&&s| s).count();
            signaled.push(flags);
        }
        let mut wr_ids = Vec::with_capacity(total_signaled);
        let key = self.alloc.borrow_mut().alloc_batch(total_signaled, &mut wr_ids);
        let mut next_wr = wr_ids.into_iter();
        for ((peer, verbs), flags) in lists.into_iter().zip(signaled) {
            let mut list = PostList::with_capacity(verbs.len());
            for (verb, sig) in verbs.into_iter().zip(flags) {
                let wqe = if sig {
                    self.mk_wqe(next_wr.next().expect("signaled wr_id budget"), verb)
                } else {
                    self.mk_wqe(0, verb).unsignaled()
                };
                list.push(wqe);
            }
            let qp = self.shared.qp(&self.cluster, self.me, peer);
            self.cluster.post_list(qp, list);
        }
        key
    }

    // ---- writes ----------------------------------------------------

    /// Asynchronous write of `words` at `off` into `target`. Local targets
    /// complete immediately (plain stores); remote targets return a key
    /// tracking the WRITE's completion (which does NOT imply placement —
    /// fence for that).
    pub fn write(&self, target: Region, off: u64, words: &[u64]) -> AckKey {
        let addr = target.at(off);
        if self.local_direct(&target) {
            self.node.arena().store_words(addr, words, false);
            return AckKey::ready();
        }
        self.note_unfenced_write(target.node, addr, words.len() as u64, "ctx::write");
        self.issue_mr(
            target.node,
            Verb::Write { remote: addr, data: Payload::from_words(words) },
            target.mr,
        )
    }

    /// Fire-and-forget write: no completion is generated; a later fence
    /// (or flushing op) on this peer covers it.
    pub fn write_unsignaled(&self, target: Region, off: u64, words: &[u64]) {
        let addr = target.at(off);
        if self.local_direct(&target) {
            self.node.arena().store_words(addr, words, false);
            return;
        }
        self.note_unfenced_write(target.node, addr, words.len() as u64, "ctx::write_unsignaled");
        self.issue_unsignaled_mr(
            target.node,
            Verb::Write { remote: addr, data: Payload::from_words(words) },
            target.mr,
        );
    }

    /// Covered stream write — the "every Nth in a stream" form of
    /// selective signaling. Posts the WRITE unsignaled, except every
    /// [`FabricConfig::signal_every`](crate::fabric::FabricConfig)-th
    /// consecutive covered write to the same peer, which is signaled so
    /// a long stream still generates periodic CQEs (bounding the NIC's
    /// uncompleted backlog, as real send queues require). The caller
    /// must already rely on a later flushing op (fence / read) for
    /// placement — exactly the kvstore's fenced-update contract — and a
    /// failed covered write propagates through that covering op's
    /// completion via the QP chain error. With `signal_every <= 1` this
    /// degrades to a plain signaled [`ThreadCtx::write`] (the ablation
    /// baseline).
    pub fn write_covered(&self, target: Region, off: u64, words: &[u64]) {
        let addr = target.at(off);
        if self.local_direct(&target) {
            self.node.arena().store_words(addr, words, false);
            return;
        }
        self.note_unfenced_write(target.node, addr, words.len() as u64, "ctx::write_covered");
        let peer = target.node;
        let verb = Verb::Write { remote: addr, data: Payload::from_words(words) };
        if self.signal_every <= 1 {
            let _ = self.issue_mr(peer, verb, target.mr);
            return;
        }
        let signal = {
            let mut streaks = self.covered_streak.borrow_mut();
            let streak = &mut streaks[peer as usize];
            *streak += 1;
            if *streak >= self.signal_every {
                *streak = 0;
                true
            } else {
                false
            }
        };
        if signal {
            let _ = self.issue_mr(peer, verb, target.mr); // key dropped; pollers drain the CQE
        } else {
            self.issue_unsignaled_mr(peer, verb, target.mr);
        }
    }

    /// Convenience: single-word write.
    pub fn write1(&self, target: Region, off: u64, word: u64) -> AckKey {
        self.write(target, off, std::slice::from_ref(&word))
    }

    // ---- reads -----------------------------------------------------

    /// Asynchronous read of `len` words at `off` from `src` into a fresh
    /// mem_ref. Returns `(key, buf)`; `buf` is valid once `key` completes.
    pub fn read_async(&self, src: Region, off: u64, len: usize) -> (AckKey, MemRef) {
        let addr = src.at(off);
        let buf = self.mem_ref(len);
        if self.local_direct(&src) {
            for i in 0..len as u64 {
                let w = self.node.arena().load(addr + i);
                self.node.arena().store(buf.addr + i, w);
            }
            return (AckKey::ready(), buf);
        }
        let key = self.issue_mr(
            src.node,
            Verb::Read { remote: addr, local: buf.addr, len: len as u32 },
            src.mr,
        );
        (key, buf)
    }

    /// Blocking read into a pooled [`ReadGuard`] (derefs to `[u64]`; no
    /// allocation on the steady state). On return, everything previously
    /// written to `src.node` on this thread's QP is also placed (flushing
    /// rule), so the unfenced counter resets — the fence engine's fast
    /// path.
    pub fn read(&self, src: Region, off: u64, len: usize) -> ReadGuard {
        let (key, buf) = self.read_async(src, off, len);
        self.wait(&key);
        if key.failed() {
            // Crash-stopped peer: the buffer was never written (see
            // read_many for why it must be zeroed, not returned as-is).
            for w in 0..len {
                buf.store(w, 0);
            }
            return self.guard_from(&buf);
        }
        if src.node != self.me {
            self.clear_unfenced(src.node);
        }
        self.guard_from(&buf)
    }

    /// Like [`ThreadCtx::read`], but surfaces a crash-stopped source as
    /// `Err(Error::PeerFailed)` instead of returning a zeroed buffer.
    pub fn try_read(&self, src: Region, off: u64, len: usize) -> crate::Result<ReadGuard> {
        let (key, buf) = self.read_async(src, off, len);
        self.wait(&key);
        if key.failed() {
            return Err(crate::Error::PeerFailed(format!(
                "read from crashed node {}",
                src.node
            )));
        }
        if src.node != self.me {
            self.clear_unfenced(src.node);
        }
        Ok(self.guard_from(&buf))
    }

    /// Blocking single-word read.
    pub fn read1(&self, src: Region, off: u64) -> u64 {
        let addr = src.at(off);
        if self.local_direct(&src) {
            return self.node.arena().load(addr);
        }
        self.read(src, off, 1)[0]
    }

    /// Local-only load (asserts the region is local). The "read
    /// locally the values of others' registers" path of the SST.
    #[inline]
    pub fn local_load(&self, region: Region, off: u64) -> u64 {
        debug_assert!(region.node == self.me && !region.device, "local_load: host-local only");
        self.node.arena().load(region.at(off))
    }

    #[inline]
    pub fn local_store(&self, region: Region, off: u64, v: u64) {
        debug_assert!(region.node == self.me && !region.device, "local_store: host-local only");
        self.node.arena().store(region.at(off), v);
    }

    // ---- atomics ---------------------------------------------------

    /// Blocking remote (or local) fetch-and-add; returns the old value.
    pub fn fetch_add(&self, target: Region, off: u64, add: u64) -> u64 {
        let addr = target.at(off);
        if self.local_direct(&target) {
            return self.node.arena().fetch_add(addr, add);
        }
        let buf = self.mem_ref(1);
        let key = self.issue_mr(
            target.node,
            Verb::FetchAdd { remote: addr, add, local: buf.addr },
            target.mr,
        );
        self.wait(&key);
        self.clear_unfenced(target.node);
        buf.load(0)
    }

    /// Blocking remote (or local) compare-and-swap; returns the old value.
    pub fn compare_swap(&self, target: Region, off: u64, expect: u64, swap: u64) -> u64 {
        let addr = target.at(off);
        if self.local_direct(&target) {
            return self.node.arena().compare_swap(addr, expect, swap);
        }
        let buf = self.mem_ref(1);
        let key = self.issue_mr(
            target.node,
            Verb::CompareSwap { remote: addr, expect, swap, local: buf.addr },
            target.mr,
        );
        self.wait(&key);
        self.clear_unfenced(target.node);
        buf.load(0)
    }

    /// Like [`ThreadCtx::fetch_add`], but a crash-stopped target is
    /// surfaced as `Err(Error::PeerFailed)` instead of a garbage old
    /// value. The channel layer's bounded-wait paths (ticket lock,
    /// shared queue) are built on this.
    pub fn try_fetch_add(&self, target: Region, off: u64, add: u64) -> crate::Result<u64> {
        let addr = target.at(off);
        if self.local_direct(&target) {
            return Ok(self.node.arena().fetch_add(addr, add));
        }
        let buf = self.mem_ref(1);
        let key = self.issue_mr(
            target.node,
            Verb::FetchAdd { remote: addr, add, local: buf.addr },
            target.mr,
        );
        self.wait(&key);
        if key.failed() {
            return Err(crate::Error::PeerFailed(format!(
                "fetch_add on crashed node {}",
                target.node
            )));
        }
        self.clear_unfenced(target.node);
        Ok(buf.load(0))
    }

    /// Like [`ThreadCtx::compare_swap`], with crash-stop surfaced as
    /// `Err(Error::PeerFailed)`.
    pub fn try_compare_swap(
        &self,
        target: Region,
        off: u64,
        expect: u64,
        swap: u64,
    ) -> crate::Result<u64> {
        let addr = target.at(off);
        if self.local_direct(&target) {
            return Ok(self.node.arena().compare_swap(addr, expect, swap));
        }
        let buf = self.mem_ref(1);
        let key = self.issue_mr(
            target.node,
            Verb::CompareSwap { remote: addr, expect, swap, local: buf.addr },
            target.mr,
        );
        self.wait(&key);
        if key.failed() {
            return Err(crate::Error::PeerFailed(format!(
                "compare_swap on crashed node {}",
                target.node
            )));
        }
        self.clear_unfenced(target.node);
        Ok(buf.load(0))
    }

    // ---- fences ----------------------------------------------------

    /// Issue (but do not wait for) the flushing reads a fence needs for
    /// this context; returns the combined key and zeroes the counters.
    pub(crate) fn fence_issue(&self, peer_filter: Option<crate::fabric::NodeId>) -> AckKey {
        let mut key = AckKey::ready();
        for peer in 0..self.num_nodes() {
            if let Some(p) = peer_filter {
                if p as usize != peer {
                    continue;
                }
            }
            if self.shared.unfenced[peer].load(Ordering::Relaxed) == 0 {
                continue;
            }
            self.clear_unfenced(peer as crate::fabric::NodeId);
            key.union(self.issue(peer as crate::fabric::NodeId, Verb::ZeroLenRead));
        }
        key
    }

    /// Pair- or thread-scope fence (see [`FenceScope`]). Global fences go
    /// through the manager, which covers all threads of this node.
    pub fn fence(&self, scope: FenceScope) {
        match scope {
            FenceScope::Pair(peer) => {
                let key = self.fence_issue(Some(peer));
                self.wait(&key);
            }
            FenceScope::Thread => {
                let key = self.fence_issue(None);
                self.wait(&key);
            }
            FenceScope::Global => {
                panic!("global fences cover other threads: call Manager::global_fence(ctx)")
            }
        }
    }

    /// Like [`ThreadCtx::fence`], but surfaces a crash-stopped peer as
    /// `Err(Error::PeerFailed)`: the flushing read to a dead node
    /// completes in error, meaning the covered writes were **not**
    /// placed there and never will be. Writes to surviving peers are
    /// still flushed by the same call.
    pub fn try_fence(&self, scope: FenceScope) -> crate::Result<()> {
        match scope {
            FenceScope::Pair(peer) => {
                let key = self.fence_issue(Some(peer));
                self.wait_checked(&key)
            }
            FenceScope::Thread => {
                let key = self.fence_issue(None);
                self.wait_checked(&key)
            }
            FenceScope::Global => {
                panic!("global fences cover other threads: call Manager::global_fence(ctx)")
            }
        }
    }

    // ---- NIC-forced variants (no local fast path) -------------------
    //
    // Model RMA stacks that route every operation through the HCA even
    // when the target is the local rank (e.g. MPI/UCX RC loopback).
    // Used by the OpenMPI baseline so its lock words behave like real
    // passive-target RMA rather than free local atomics.

    pub fn read1_nic(&self, src: Region, off: u64) -> u64 {
        let buf = self.mem_ref(1);
        let key = self.issue_mr(
            src.node,
            Verb::Read { remote: src.at(off), local: buf.addr(), len: 1 },
            src.mr,
        );
        self.wait(&key);
        if src.node != self.me {
            self.clear_unfenced(src.node);
        }
        buf.load(0)
    }

    pub fn write1_nic(&self, target: Region, off: u64, word: u64) -> AckKey {
        if target.node != self.me {
            self.note_unfenced_write(target.node, target.at(off), 1, "ctx::write1_nic");
        }
        self.issue_mr(
            target.node,
            Verb::Write { remote: target.at(off), data: Payload::one(word) },
            target.mr,
        )
    }

    pub fn fetch_add_nic(&self, target: Region, off: u64, add: u64) -> u64 {
        let buf = self.mem_ref(1);
        let key = self.issue_mr(
            target.node,
            Verb::FetchAdd { remote: target.at(off), add, local: buf.addr() },
            target.mr,
        );
        self.wait(&key);
        buf.load(0)
    }

    pub fn compare_swap_nic(&self, target: Region, off: u64, expect: u64, swap: u64) -> u64 {
        let buf = self.mem_ref(1);
        let key = self.issue_mr(
            target.node,
            Verb::CompareSwap { remote: target.at(off), expect, swap, local: buf.addr() },
            target.mr,
        );
        self.wait(&key);
        buf.load(0)
    }

    /// Count of peers with unfenced writes (for tests / introspection).
    pub fn unfenced_peers(&self) -> usize {
        (0..self.num_nodes())
            .filter(|&p| self.shared.unfenced[p].load(Ordering::Relaxed) > 0)
            .count()
    }

    /// Issue a zero-length read on another context's QP (manager-side
    /// helper for global fences). Uses our ack allocator for tracking.
    pub(crate) fn flush_other(&self, other: &CtxShared, peer: crate::fabric::NodeId) -> AckKey {
        let qp = {
            let qps = other.qps.lock().unwrap();
            match qps[peer as usize] {
                Some(qp) => qp,
                None => return AckKey::ready(), // no QP → no writes to flush
            }
        };
        let (wr_id, word, mask) = self.alloc.borrow_mut().alloc();
        self.cluster.post(qp, Wqe::new(wr_id, Verb::ZeroLenRead));
        AckKey::single(word, mask)
    }
}

#[cfg(test)]
mod tests {
    use std::sync::Arc;

    use crate::core::manager::Manager;
    use crate::fabric::{Cluster, FabricConfig, LatencyModel};

    fn setup(n: usize, cfg: FabricConfig) -> (Arc<Cluster>, Vec<Arc<Manager>>) {
        let cluster = Cluster::new(n, cfg);
        let mgrs =
            (0..n as crate::fabric::NodeId).map(|i| Manager::new(cluster.clone(), i)).collect();
        (cluster, mgrs)
    }

    /// write_many + read_many round-trip across two remote peers and the
    /// local node, on both delivery modes.
    #[test]
    fn batched_write_read_roundtrip() {
        for cfg in [
            FabricConfig::inline_ideal(),
            FabricConfig::threaded(LatencyModel::fast_sim()),
        ] {
            let (cluster, mgrs) = setup(3, cfg);
            let r0 = cluster.node(0).register_mr(8, false); // local to ctx
            let r1 = cluster.node(1).register_mr(8, false);
            let r2 = cluster.node(2).register_mr(8, false);
            let ctx = mgrs[0].ctx();

            let v1 = [10u64, 11];
            let v2 = [20u64, 21, 22];
            let v0 = [30u64];
            let key = ctx.write_many(&[(r1, 2, &v1[..]), (r2, 0, &v2[..]), (r0, 0, &v0[..])]);
            ctx.wait(&key);
            // Completions don't imply placement — fence, then verify via
            // batched reads (which also re-validate per-entry routing).
            ctx.fence(super::FenceScope::Thread);
            let out = ctx.read_many(&[(r1, 2, 2), (r2, 0, 3), (r0, 0, 1)]);
            assert_eq!(out, vec![vec![10, 11], vec![20, 21, 22], vec![30]]);
            assert_eq!(ctx.unfenced_peers(), 0, "read_many resets unfenced peers");
        }
    }

    /// A large batch (several ack words) to one peer completes through a
    /// single post_list call.
    #[test]
    fn post_list_large_batch_completes() {
        let (cluster, mgrs) = setup(2, FabricConfig::threaded(LatencyModel::fast_sim()));
        let dst = cluster.node(1).register_mr(256, false);
        let ctx = mgrs[0].ctx();
        let reqs: Vec<_> = (0..200u64).map(|i| (dst, i, 1usize)).collect();
        // Prefill via batched writes, then fence, then batched read-back.
        let vals: Vec<[u64; 1]> = (0..200u64).map(|i| [i * 3]).collect();
        let writes: Vec<_> =
            (0..200usize).map(|i| (dst, i as u64, &vals[i][..])).collect();
        ctx.write_many(&writes).wait();
        ctx.fence(super::FenceScope::Pair(1));
        let out = ctx.read_many(&reqs);
        for (i, row) in out.iter().enumerate() {
            assert_eq!(row, &vec![i as u64 * 3], "word {i}");
        }
    }

    /// Selective signaling on the batched write path: a 32-write chain
    /// to one peer generates exactly two CQEs (the every-16th cover and
    /// the tail), every payload ≤ the inline cap goes out inline, and
    /// the covering completion still retires the whole chain (all data
    /// placed after a fence).
    #[test]
    fn write_many_signals_only_chain_covers() {
        let fabric = FabricConfig::inline_ideal().with_signal_every(16);
        let (cluster, mgrs) = setup(2, fabric);
        let dst = cluster.node(1).register_mr(64, false);
        let ctx = mgrs[0].ctx();
        let vals: Vec<[u64; 1]> = (0..32u64).map(|i| [i * 3 + 1]).collect();
        let writes: Vec<_> = (0..32usize).map(|i| (dst, i as u64, &vals[i][..])).collect();
        let cqes0 = cluster.cqes_posted();
        let inl0 = cluster.wqes_inlined();
        let key = ctx.write_many(&writes);
        ctx.wait(&key);
        assert!(!key.failed());
        assert_eq!(
            cluster.cqes_posted() - cqes0,
            2,
            "32-write chain at signal_every=16 must generate exactly 2 CQEs"
        );
        assert_eq!(cluster.wqes_inlined() - inl0, 32, "single-word writes go inline");
        ctx.fence(super::FenceScope::Pair(1));
        let out = ctx.read_many(&(0..32u64).map(|i| (dst, i, 1usize)).collect::<Vec<_>>());
        for (i, row) in out.iter().enumerate() {
            assert_eq!(row, &vec![i as u64 * 3 + 1], "covered write {i} placed");
        }
    }

    /// `signal_every = 1` (the ablation baseline) restores the PR-4
    /// shape: one CQE per write.
    #[test]
    fn signal_every_one_signals_all() {
        let fabric = FabricConfig::inline_ideal().with_signal_every(1);
        let (cluster, mgrs) = setup(2, fabric);
        let dst = cluster.node(1).register_mr(16, false);
        let ctx = mgrs[0].ctx();
        let vals: Vec<[u64; 1]> = (0..8u64).map(|i| [i]).collect();
        let writes: Vec<_> = (0..8usize).map(|i| (dst, i as u64, &vals[i][..])).collect();
        let cqes0 = cluster.cqes_posted();
        ctx.write_many(&writes).wait();
        assert_eq!(cluster.cqes_posted() - cqes0, 8);
    }

    /// The PR-5 spin-audit regression: a waiter on a **covered** write
    /// chain to a peer that crash-stops mid-flight unblocks within the
    /// bound with `PeerFailed` — the chain's covering CQE carries the
    /// failure (no ack bit is ever orphaned by an unsignaled WQE).
    #[test]
    fn crashed_peer_covered_chain_unblocks_within_bound() {
        let mut lat = crate::fabric::LatencyModel::fast_sim();
        lat.write_ns = 20_000_000; // 20 ms: the whole chain is in flight
        let (cluster, mgrs) = setup(2, FabricConfig::threaded(lat));
        let dst = cluster.node(1).register_mr(64, false);
        let ctx = mgrs[0].ctx();
        let vals: Vec<[u64; 1]> = (0..32u64).map(|i| [i]).collect();
        let writes: Vec<_> = (0..32usize).map(|i| (dst, i as u64, &vals[i][..])).collect();
        let key = ctx.write_many(&writes);
        cluster.crash(1);
        let t0 = std::time::Instant::now();
        assert!(
            matches!(ctx.wait_checked(&key), Err(crate::Error::PeerFailed(_))),
            "covered chain to a corpse must surface PeerFailed"
        );
        assert!(
            t0.elapsed() < std::time::Duration::from_secs(10),
            "crashed-peer ack wait exceeded the bound: {:?}",
            t0.elapsed()
        );
    }

    /// Covered stream writes (`write_covered`) generate no CQEs until
    /// the periodic cover, and a fence still proves placement.
    #[test]
    fn covered_stream_writes_and_fence() {
        let fabric = FabricConfig::inline_ideal().with_signal_every(16);
        let (cluster, mgrs) = setup(2, fabric);
        let dst = cluster.node(1).register_mr(64, false);
        let ctx = mgrs[0].ctx();
        let cqes0 = cluster.cqes_posted();
        for i in 0..15u64 {
            ctx.write_covered(dst, i, &[i + 100]);
        }
        assert_eq!(cluster.cqes_posted() - cqes0, 0, "covered stream under the cadence");
        ctx.write_covered(dst, 15, &[115]); // 16th: the periodic cover
        assert_eq!(cluster.cqes_posted() - cqes0, 1);
        ctx.fence(super::FenceScope::Pair(1));
        for i in 0..16u64 {
            assert_eq!(ctx.read1(dst, i), i + 100);
        }
    }

    /// Empty batches short-circuit without touching the fabric.
    #[test]
    fn empty_batches_are_ready() {
        let (_cluster, mgrs) = setup(2, FabricConfig::inline_ideal());
        let ctx = mgrs[0].ctx();
        assert!(ctx.post_list(1, Vec::new()).query());
        assert!(ctx.write_many(&[]).query());
        let (key, bufs) = ctx.read_many_async(&[]);
        assert!(key.query());
        assert!(bufs.is_empty());
    }
}
