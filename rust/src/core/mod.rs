//! LOCO core: the manager, channel endpoints, completion tracking,
//! fences, and network-memory pooling.
//!
//! This layer turns the raw fabric into the paper's programming model:
//!
//! * [`manager`] — one per node; owns peer connections, the shared
//!   completion queue + polling thread, the control-message thread that
//!   runs the join/connect channel handshake, and the network-memory pool.
//! * [`endpoint`] — the channel base object: hierarchical names,
//!   local/remote region tables, readiness, connect callbacks.
//! * [`ack`] — lock-free bitset completion tracking (`ack_key`).
//! * [`ctx`] — per-thread issuing context: private QPs per peer,
//!   `mem_ref` scratch blocks, pooled read buffers, verb issue APIs, and
//!   the fence engine.
//! * [`heat`] — per-key EWMA heat / lock-contention tracker feeding the
//!   kvstore's one-sided-vs-op-shipping route decision.
//! * [`mem_pool`] — huge-page aggregation of registered memory.
//! * [`index`] — sharded, seqlock-validated location index (lock-free
//!   reads; the locality tier's index leg).

pub mod ack;
pub mod ctx;
pub mod endpoint;
pub mod heat;
pub mod index;
pub mod manager;
pub mod mem_pool;

pub use ack::AckKey;
pub use ctx::{FenceScope, MemRef, ReadGuard, ThreadCtx};
pub use endpoint::Endpoint;
pub use heat::{HeatTracker, RouteDecision, RouteMode};
pub use index::{IndexEntry, ShardedIndex};
pub use manager::{Manager, Membership};
