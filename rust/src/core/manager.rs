//! The per-node manager (paper §4.2, App. A).
//!
//! One manager exists per node (per process on real hardware). It owns:
//!
//! * the node's **memory pool** (huge-page MR aggregation),
//! * the **polling thread** that drains the node's single shared CQ and
//!   clears ack bits (App. A.1),
//! * the **control thread** that receives join/connect messages and
//!   drives channel endpoint setup (§4.2),
//! * the registry of **thread contexts** (for global fences) and
//!   **channel endpoints** (for message dispatch).
//!
//! Control messages travel over the fabric's SEND/RECV path, mirroring
//! the paper's use of two-sided verbs for setup only.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use crate::fabric::{Cluster, NodeId, QpId, Region, Verb, Wqe};

/// Lifecycle state of a node slot as observed by one node's
/// [`Membership`] view.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum NodeState {
    /// Full member: owns key ranges and serves its replication chain.
    Alive,
    /// Mid-join: already counted as an owner (so range migration targets
    /// it and readers chase the new epoch) but its join is not yet
    /// announced complete.
    Joining,
    /// Crash-stopped. Leaving is modeled as a crash.
    Dead,
}

/// Number of key ranges in the ownership table: a power of two larger
/// than any supported cluster (≤ 64 nodes) so ranges spread evenly, yet
/// small enough that the table recomputes in microseconds.
pub const RANGES: usize = 64;

/// Cluster membership as observed by this node: per-node lifecycle
/// states ([`NodeState`], plus a designated-spare mask) and a
/// monotonically increasing **epoch** that bumps on every transition.
/// Layers above key recovery and routing off the epoch: the kvstore
/// derives key homes from the epoch-versioned ownership table
/// ([`Membership::owner`]), stamps every tracker broadcast with the
/// sender's epoch so stale-owner messages are rejected
/// ([`Membership::op_is_stale`]), re-homes a dead node's keys once per
/// epoch, and drops read-cache fills from superseded epochs.
///
/// Unlike the crash-only mask it replaces, membership is
/// **bidirectional**: [`Membership::note_joining`] clears a previously
/// dead slot (slot reuse), so the cluster can grow back after failures.
/// Every transition records the epoch at which the node last changed
/// state ([`Membership::state_epoch`]); an op stamped with a sender
/// epoch older than that is stale (e.g. a pre-crash broadcast delivered
/// after the slot re-joined). Epochs on different nodes count the same
/// transition events and so agree up to in-flight skew; the guard is a
/// fast-path filter, not the safety argument — the recovery path's
/// compare-and-swap re-homing tolerates transient cross-view
/// disagreement.
///
/// Detection: the simulated fabric exposes a perfect failure detector
/// ([`Cluster::down_mask`] — a node is down iff it crash-stopped), which
/// the manager's polling thread mirrors here every few milliseconds —
/// latching only *newly* down bits, so a slot whose dead bit a re-join
/// cleared is not wedged dead again by the fabric's stale history. On
/// real RDMA a perfect detector does not exist and agreement needs
/// explicit protocol support ("The Impact of RDMA on Agreement"); the
/// simulation separates that concern so the *recovery* protocol can be
/// tested deterministically.
pub struct Membership {
    n: usize,
    epoch: AtomicU64,
    dead: AtomicU64,
    joining: AtomicU64,
    spares: AtomicU64,
    /// Epoch at which each node last changed state (0 = never has).
    state_epochs: Vec<AtomicU64>,
    /// Serializes transitions so (masks, epoch, state_epochs) move
    /// together. Reads stay lock-free.
    transition: Mutex<()>,
    /// Ownership-table cache: (epoch, replicas, table).
    owners: Mutex<(u64, usize, Arc<Vec<NodeId>>)>,
}

impl Membership {
    fn new(n: usize) -> Membership {
        Membership {
            n,
            epoch: AtomicU64::new(0),
            dead: AtomicU64::new(0),
            joining: AtomicU64::new(0),
            spares: AtomicU64::new(0),
            state_epochs: (0..n).map(|_| AtomicU64::new(0)).collect(),
            transition: Mutex::new(()),
            owners: Mutex::new((0, 0, Arc::new(Vec::new()))),
        }
    }

    /// Monotonic epoch: bumps once per observed membership transition.
    pub fn epoch(&self) -> u64 {
        self.epoch.load(Ordering::SeqCst)
    }

    /// Bitmask of nodes this node has observed as crash-stopped.
    pub fn dead_mask(&self) -> u64 {
        self.dead.load(Ordering::SeqCst)
    }

    /// Bitmask of nodes currently mid-join.
    pub fn joining_mask(&self) -> u64 {
        self.joining.load(Ordering::SeqCst)
    }

    /// Bitmask of designated spares: fabric-live nodes that own no
    /// ranges until they join.
    pub fn spare_mask(&self) -> u64 {
        self.spares.load(Ordering::SeqCst)
    }

    pub fn is_dead(&self, node: NodeId) -> bool {
        self.dead_mask() >> node & 1 == 1
    }

    pub fn is_spare(&self, node: NodeId) -> bool {
        self.spare_mask() >> node & 1 == 1
    }

    /// Lifecycle state of `node` as observed by this node.
    pub fn state(&self, node: NodeId) -> NodeState {
        if self.is_dead(node) {
            NodeState::Dead
        } else if self.joining_mask() >> node & 1 == 1 {
            NodeState::Joining
        } else {
            NodeState::Alive
        }
    }

    /// The epoch at which `node` last changed state (0 = it never has).
    pub fn state_epoch(&self, node: NodeId) -> u64 {
        self.state_epochs[node as usize].load(Ordering::SeqCst)
    }

    /// Is a tracker op stamped `msg_epoch` by `from` stale? True when
    /// the sender is dead, or when the stamp predates the sender's last
    /// observed state transition — a pre-crash broadcast delivered after
    /// the slot re-joined must not resurrect purged locations.
    pub fn op_is_stale(&self, msg_epoch: u64, from: NodeId) -> bool {
        self.is_dead(from) || msg_epoch < self.state_epoch(from)
    }

    fn bump_state(&self, node: NodeId) {
        let e = self.epoch.fetch_add(1, Ordering::SeqCst) + 1;
        self.state_epochs[node as usize].store(e, Ordering::SeqCst);
    }

    /// Record `node` as dead; returns true if it is newly dead (and the
    /// epoch advanced). Idempotent and thread-safe.
    pub(crate) fn note_dead(&self, node: NodeId) -> bool {
        let _g = self.transition.lock().unwrap();
        let bit = 1u64 << node;
        if self.dead.load(Ordering::SeqCst) & bit != 0 {
            return false;
        }
        self.dead.fetch_or(bit, Ordering::SeqCst);
        self.joining.fetch_and(!bit, Ordering::SeqCst);
        self.bump_state(node);
        true
    }

    /// Begin a join of `node`: clears a previously dead (slot reuse) or
    /// spare slot and marks it mid-join. Returns true on a real
    /// transition; a node that is already a full member is left alone.
    pub(crate) fn note_joining(&self, node: NodeId) -> bool {
        let _g = self.transition.lock().unwrap();
        let bit = 1u64 << node;
        if self.joining.load(Ordering::SeqCst) & bit != 0 {
            return false;
        }
        let parked =
            (self.dead.load(Ordering::SeqCst) | self.spares.load(Ordering::SeqCst)) & bit != 0;
        if !parked {
            return false;
        }
        self.dead.fetch_and(!bit, Ordering::SeqCst);
        self.spares.fetch_and(!bit, Ordering::SeqCst);
        self.joining.fetch_or(bit, Ordering::SeqCst);
        self.bump_state(node);
        true
    }

    /// Complete a join: the mid-join node becomes a full member.
    pub(crate) fn note_alive(&self, node: NodeId) -> bool {
        let _g = self.transition.lock().unwrap();
        let bit = 1u64 << node;
        if self.joining.load(Ordering::SeqCst) & bit == 0 {
            return false;
        }
        self.joining.fetch_and(!bit, Ordering::SeqCst);
        self.bump_state(node);
        true
    }

    /// Designate `mask` as spares. Builders call this identically on
    /// every node before any traffic; it is bring-up configuration, not
    /// part of the runtime protocol.
    pub fn set_spares(&self, mask: u64) {
        let _g = self.transition.lock().unwrap();
        let prev = self.spares.swap(mask, Ordering::SeqCst);
        if prev != mask {
            let e = self.epoch.fetch_add(1, Ordering::SeqCst) + 1;
            let mut changed = prev ^ mask;
            while changed != 0 {
                let node = changed.trailing_zeros() as usize;
                self.state_epochs[node].store(e, Ordering::SeqCst);
                changed &= changed - 1;
            }
        }
    }

    /// Current members: not dead and not spare. Mid-join nodes count —
    /// they are valid owners and migration targets.
    pub fn members(&self) -> Vec<NodeId> {
        let parked = self.dead_mask() | self.spare_mask();
        (0..self.n as NodeId).filter(|&i| parked >> i & 1 == 0).collect()
    }

    /// Key-range of `key`: the unit of ownership. A pure hash, so every
    /// node maps a key to the same range forever.
    pub fn range_of(key: u64) -> usize {
        (crate::util::mix64(key) % RANGES as u64) as usize
    }

    /// The epoch-versioned ownership table: the home node of each of the
    /// [`RANGES`] key ranges, recomputed whenever the epoch moves and
    /// cached. Pure in (masks, replicas), so converged views agree on
    /// every owner.
    pub fn owners(&self, replicas: usize) -> Arc<Vec<NodeId>> {
        let epoch = self.epoch();
        let mut cache = self.owners.lock().unwrap();
        if cache.0 != epoch || cache.1 != replicas || cache.2.is_empty() {
            *cache = (epoch, replicas, Arc::new(self.compute_owners(replicas)));
        }
        cache.2.clone()
    }

    /// Home node of `range` under the current epoch.
    pub fn owner(&self, range: usize, replicas: usize) -> NodeId {
        self.owners(replicas)[range]
    }

    /// Recompute the table: spread ranges round-robin over the members,
    /// preferring homes whose whole static backup chain
    /// (`home+1 .. home+replicas-1`, mod n) is live, so new keys keep
    /// all `replicas` copies reachable. Falls back to all members when
    /// no chain is fully live (degraded but still serving).
    fn compute_owners(&self, replicas: usize) -> Vec<NodeId> {
        let n = self.n;
        let dead = self.dead_mask();
        let members = self.members();
        assert!(!members.is_empty(), "ownership table needs at least one live member");
        let chain_live =
            |h: NodeId| (1..replicas).all(|j| dead >> ((h as usize + j) % n) & 1 == 0);
        let pool: Vec<NodeId> = members.iter().copied().filter(|&h| chain_live(h)).collect();
        let pool = if pool.is_empty() { members } else { pool };
        (0..RANGES).map(|r| pool[r % pool.len()]).collect()
    }
}

use super::ack::AckRegistry;
use super::ctx::{CtxShared, ThreadCtx};
use super::endpoint::Endpoint;
use super::mem_pool::{MemPool, HUGE_PAGE_WORDS};

/// State shared with the service threads. Kept in its own `Arc` so the
/// threads never hold `Arc<Manager>` — a Manager→thread→Manager cycle
/// would keep `Drop` (and thus shutdown) from ever running.
struct Shared {
    cluster: Arc<Cluster>,
    me: NodeId,
    ack: Arc<AckRegistry>,
    membership: Arc<Membership>,
    channels: Mutex<HashMap<String, Arc<Endpoint>>>,
    ctrl_qps: Mutex<Vec<Option<QpId>>>,
    shutdown: AtomicBool,
}

pub struct Manager {
    shared: Arc<Shared>,
    pool: Arc<MemPool>,
    ctxs: Mutex<Vec<Arc<CtxShared>>>,
    threads: Mutex<Vec<JoinHandle<()>>>,
}

impl Manager {
    /// Construct the manager for node `me` and start its service threads
    /// (or, on a [`DeliveryMode::Sim`](crate::fabric::DeliveryMode)
    /// cluster, register the equivalent cooperative services with the
    /// installed [`SimExecutor`](crate::sim::SimExecutor)).
    pub fn new(cluster: Arc<Cluster>, me: NodeId) -> Arc<Manager> {
        let node = cluster.node(me).clone();
        // Cap the pool's huge page to the node's arena so many-node sim
        // clusters can shrink per-node memory without the first pool
        // page alone blowing the arena.
        let page_words = HUGE_PAGE_WORDS.min((cluster.config().node_mem_words / 2).max(1));
        let pool = Arc::new(MemPool::new(node, page_words));
        debug_assert!(cluster.num_nodes() <= 64, "membership mask holds at most 64 nodes");
        let shared = Arc::new(Shared {
            cluster: cluster.clone(),
            me,
            ack: Arc::new(AckRegistry::new()),
            membership: Arc::new(Membership::new(cluster.num_nodes())),
            channels: Mutex::new(HashMap::new()),
            ctrl_qps: Mutex::new(vec![None; cluster.num_nodes()]),
            shutdown: AtomicBool::new(false),
        });
        let mgr = Arc::new(Manager {
            shared: shared.clone(),
            pool,
            ctxs: Mutex::new(Vec::new()),
            threads: Mutex::new(Vec::new()),
        });

        if cluster.config().delivery == crate::fabric::DeliveryMode::Sim {
            // One cooperative service per thread the manager would have
            // spawned: a CQ-poll + membership slice and a ctrl-message
            // slice. Each does one non-blocking batch per scheduler pump
            // and reports honestly whether it did anything.
            let sh = shared.clone();
            crate::sim::register_service(format!("mgr-poll-{me}"), Box::new(move || {
                if sh.shutdown.load(Ordering::Relaxed) {
                    return false;
                }
                let mut did = sh.sync_membership();
                let cq = sh.cluster.node(sh.me).cq();
                let mut buf = Vec::with_capacity(256);
                let n = cq.poll(256, &mut buf);
                for cqe in buf.iter() {
                    sh.ack.complete(cqe.wr_id, cqe.is_ok());
                }
                did |= n > 0;
                did
            }));
            let sh = shared;
            let my_node = cluster.node(me).clone();
            crate::sim::register_service(format!("mgr-ctrl-{me}"), Box::new(move || {
                if sh.shutdown.load(Ordering::Relaxed) {
                    return false;
                }
                let mut did = false;
                while let Some(msg) = my_node.try_recv() {
                    let text = String::from_utf8_lossy(&msg.bytes).into_owned();
                    sh.handle_ctrl(msg.from, &text);
                    did = true;
                }
                did
            }));
            return mgr;
        }
        // Polling thread: drain the shared CQ, clear ack bits (App. A.1).
        {
            let sh = shared.clone();
            let h = std::thread::Builder::new()
                .name(format!("loco-poll-{me}"))
                .spawn(move || sh.polling_loop())
                .expect("spawn polling thread");
            mgr.threads.lock().unwrap().push(h);
        }
        // Control thread: join/connect protocol (§4.2).
        {
            let sh = shared;
            let h = std::thread::Builder::new()
                .name(format!("loco-ctrl-{me}"))
                .spawn(move || sh.ctrl_loop())
                .expect("spawn ctrl thread");
            mgr.threads.lock().unwrap().push(h);
        }
        mgr
    }

    pub fn me(&self) -> NodeId {
        self.shared.me
    }

    pub fn num_nodes(&self) -> usize {
        self.shared.cluster.num_nodes()
    }

    pub fn cluster(&self) -> &Arc<Cluster> {
        &self.shared.cluster
    }

    pub fn pool(&self) -> &Arc<MemPool> {
        &self.pool
    }

    /// This node's membership view (epoch + dead mask), kept current by
    /// the polling thread. Channels that must skip dead peers (the
    /// tracker ring's acks, the kvstore's recovery) hold a clone.
    pub fn membership(&self) -> &Arc<Membership> {
        &self.shared.membership
    }

    /// Has this node observed `node` as crash-stopped?
    pub fn is_dead(&self, node: NodeId) -> bool {
        self.shared.membership.is_dead(node)
    }

    /// Create a per-thread issuing context. Each application thread calls
    /// this once and keeps the context for its lifetime.
    pub fn ctx(&self) -> ThreadCtx {
        let shared = CtxShared::new(self.num_nodes());
        self.ctxs.lock().unwrap().push(shared.clone());
        ThreadCtx::new(
            self.shared.cluster.clone(),
            self.shared.me,
            self.shared.ack.clone(),
            shared,
            self.pool.clone(),
        )
    }

    // ---- channel setup (§4.2) ---------------------------------------

    /// Register a freshly constructed endpoint and announce it to peers.
    pub fn register_channel(&self, ep: Arc<Endpoint>) {
        let name = ep.name().to_string();
        let regions = ep.local_regions();
        {
            let mut chans = self.shared.channels.lock().unwrap();
            assert!(
                chans.insert(name.clone(), ep).is_none(),
                "channel endpoint {name} already registered on node {}",
                self.shared.me
            );
        }
        let msg = encode_msg('J', &name, &regions);
        for peer in 0..self.num_nodes() as NodeId {
            if peer != self.shared.me {
                self.shared.ctrl_send(peer, &msg);
            }
        }
    }

    pub fn channel(&self, name: &str) -> Option<Arc<Endpoint>> {
        self.shared.channels.lock().unwrap().get(name).cloned()
    }

    /// Block until every registered endpoint is ready (the paper's
    /// `cm.wait_for_ready()`).
    pub fn wait_all_ready(&self, timeout: Duration) {
        let eps: Vec<Arc<Endpoint>> =
            self.shared.channels.lock().unwrap().values().cloned().collect();
        for ep in eps {
            ep.wait_ready(timeout);
        }
    }

    // ---- fences (§5.3) ------------------------------------------------

    /// Global fence: all unfenced writes from *any* thread of this node
    /// are placed before this call returns. Zero-length reads are issued
    /// on every (thread, peer) QP with outstanding writes, in parallel,
    /// then awaited together.
    pub fn global_fence(&self, ctx: &ThreadCtx) {
        // Own writes first (uses our QPs directly).
        let mut key = ctx.fence_issue(None);
        let ctxs = self.ctxs.lock().unwrap().clone();
        for other in &ctxs {
            if Arc::ptr_eq(other, &ctx.shared) {
                continue;
            }
            for peer in 0..self.num_nodes() {
                if other.unfenced[peer].load(Ordering::Relaxed) == 0 {
                    continue;
                }
                other.unfenced[peer].store(0, Ordering::Relaxed);
                key.union(ctx.flush_other(other, peer as NodeId));
            }
        }
        ctx.wait(&key);
    }

    /// Stop service threads. Called automatically on drop.
    pub fn shutdown(&self) {
        if self.shared.shutdown.swap(true, Ordering::SeqCst) {
            return;
        }
        for h in self.threads.lock().unwrap().drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for Manager {
    fn drop(&mut self) {
        self.shutdown();
    }
}

impl Shared {
    // ---- service threads ---------------------------------------------

    fn polling_loop(&self) {
        // Application threads drain the CQ cooperatively while they wait
        // (ThreadCtx::drain_cq); this thread is the backstop for
        // completions nobody is waiting on. Blocking pop keeps it off
        // the run queue (EXPERIMENTS.md §Perf). It doubles as the
        // failure detector: every tick it mirrors the fabric's down mask
        // into this node's Membership.
        let cq = self.cluster.node(self.me).cq();
        let mut buf = Vec::with_capacity(256);
        loop {
            self.sync_membership();
            match cq.poll_timeout(Duration::from_millis(2)) {
                Some(cqe) => {
                    self.ack.complete(cqe.wr_id, cqe.is_ok());
                    buf.clear();
                    let n = cq.poll(256, &mut buf);
                    for cqe in buf.iter().take(n) {
                        self.ack.complete(cqe.wr_id, cqe.is_ok());
                    }
                }
                None => {
                    if self.shutdown.load(Ordering::Relaxed) {
                        break;
                    }
                }
            }
        }
    }

    /// Mirror *newly* down fabric nodes into this node's membership
    /// (bumping the epoch once per new death). Only the freshly-down
    /// delta is latched: a slot whose dead bit a re-join cleared (after
    /// [`Cluster::revive`]) must not be re-marked dead from the fabric's
    /// stale history, and a revived-but-not-yet-joined node stays dead
    /// until its join is broadcast. Returns whether the view changed
    /// (the sim service's did-work signal).
    fn sync_membership(&self) -> bool {
        let mut fresh = self.cluster.down_mask() & !self.membership.dead_mask();
        let mut did = false;
        while fresh != 0 {
            let node = fresh.trailing_zeros() as NodeId;
            did |= self.membership.note_dead(node);
            fresh &= fresh - 1;
        }
        did
    }

    fn ctrl_loop(&self) {
        let node = self.cluster.node(self.me).clone();
        loop {
            match node.recv_timeout(Duration::from_millis(2)) {
                Some(msg) => {
                    let text = String::from_utf8_lossy(&msg.bytes).into_owned();
                    self.handle_ctrl(msg.from, &text);
                }
                None => {
                    if self.shutdown.load(Ordering::Relaxed) {
                        break;
                    }
                }
            }
        }
    }

    fn handle_ctrl(&self, from: NodeId, text: &str) {
        let Some((kind, chan, regions)) = decode_msg(text) else {
            eprintln!("loco[{}]: malformed ctrl message from {from}: {text}", self.me);
            return;
        };
        let ep = self.channels.lock().unwrap().get(&chan).cloned();
        match kind {
            'J' => {
                let Some(ep) = ep else {
                    // No matching endpoint (yet): the paper drops the
                    // message; symmetry + reciprocal joins converge.
                    return;
                };
                let first = ep.handle_join(from, &regions);
                // Reply connect with our region metadata (idempotent).
                let reply = encode_msg('C', &chan, &ep.local_regions());
                self.ctrl_send(from, &reply);
                if first {
                    // Cover the case where our original join raced ahead
                    // of the peer's endpoint construction and was dropped.
                    let rejoin = encode_msg('J', &chan, &ep.local_regions());
                    self.ctrl_send(from, &rejoin);
                }
            }
            'C' => {
                if let Some(ep) = ep {
                    ep.handle_connect(from, &regions);
                }
            }
            _ => eprintln!("loco[{}]: unknown ctrl kind {kind}", self.me),
        }
    }

    fn ctrl_send(&self, peer: NodeId, msg: &str) {
        let qp = {
            let mut qps = self.ctrl_qps.lock().unwrap();
            match qps[peer as usize] {
                Some(qp) => qp,
                None => {
                    let qp = self.cluster.create_qp(self.me, peer);
                    qps[peer as usize] = Some(qp);
                    qp
                }
            }
        };
        self.cluster.post(
            qp,
            Wqe::new(0, Verb::Send { bytes: msg.as_bytes().to_vec().into_boxed_slice() })
                .unsignaled(),
        );
    }

}

// ---- control message wire format -------------------------------------
//
//   <kind>|<channel-name>|<name>,<node>,<base>,<len>,<mr>,<device>;...
//
// Hand-rolled (no serde in the offline build); names are restricted to
// not contain '|', ',' or ';' which the channel naming scheme respects.

fn encode_msg(kind: char, chan: &str, regions: &[(String, Region)]) -> String {
    let mut s = format!("{kind}|{chan}|");
    for (i, (name, r)) in regions.iter().enumerate() {
        if i > 0 {
            s.push(';');
        }
        s.push_str(&format!(
            "{name},{},{},{},{},{}",
            r.node,
            r.base,
            r.len,
            r.mr,
            if r.device { 1 } else { 0 }
        ));
    }
    s
}

#[allow(clippy::type_complexity)]
fn decode_msg(text: &str) -> Option<(char, String, Vec<(String, Region)>)> {
    let mut parts = text.splitn(3, '|');
    let kind = parts.next()?.chars().next()?;
    let chan = parts.next()?.to_string();
    let regions_text = parts.next()?;
    let mut regions = Vec::new();
    if !regions_text.is_empty() {
        for item in regions_text.split(';') {
            let f: Vec<&str> = item.split(',').collect();
            if f.len() != 6 {
                return None;
            }
            regions.push((
                f[0].to_string(),
                Region {
                    node: f[1].parse().ok()?,
                    base: f[2].parse().ok()?,
                    len: f[3].parse().ok()?,
                    mr: f[4].parse().ok()?,
                    device: f[5] == "1",
                },
            ));
        }
    }
    Some((kind, chan, regions))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::endpoint::Expect;
    use crate::fabric::FabricConfig;

    #[test]
    fn msg_roundtrip() {
        let regions = vec![
            ("own".to_string(), Region { node: 2, base: 512, len: 8, mr: 1, device: false }),
            ("cache".to_string(), Region { node: 2, base: 1024, len: 32, mr: 1, device: true }),
        ];
        let msg = encode_msg('J', "bar/sst", &regions);
        let (kind, chan, parsed) = decode_msg(&msg).unwrap();
        assert_eq!(kind, 'J');
        assert_eq!(chan, "bar/sst");
        assert_eq!(parsed, regions);
        // Empty region list.
        let (k2, c2, r2) = decode_msg(&encode_msg('C', "x", &[])).unwrap();
        assert_eq!((k2, c2.as_str(), r2.len()), ('C', "x", 0));
    }

    /// Two managers connect a channel endpoint pair end-to-end over the
    /// inline fabric, including region metadata exchange.
    #[test]
    fn join_connect_end_to_end() {
        let cluster = Cluster::new(2, FabricConfig::inline_ideal());
        let m0 = Manager::new(cluster.clone(), 0);
        let m1 = Manager::new(cluster.clone(), 1);

        let mk = |m: &Arc<Manager>, base_val: u64| {
            let ep = Endpoint::new("test", m.me(), 2, Expect::AllPeers);
            let r = m.pool().alloc_named("test.data", 16, false);
            m.ctx().local_store(r, 0, base_val);
            ep.add_local_region("data", r);
            ep.expect_regions(&["data"]);
            m.register_channel(ep.clone());
            ep
        };
        let e0 = mk(&m0, 100);
        let e1 = mk(&m1, 200);
        e0.wait_ready(Duration::from_secs(5));
        e1.wait_ready(Duration::from_secs(5));

        // Each side can now read the other's region through the metadata.
        let ctx0 = m0.ctx();
        let r1 = e0.remote_region(1, "data");
        assert_eq!(ctx0.read1(r1, 0), 200);
        let ctx1 = m1.ctx();
        let r0 = e1.remote_region(0, "data");
        assert_eq!(ctx1.read1(r0, 0), 100);
    }

    /// Construction order doesn't matter: a join that arrives before the
    /// local endpoint exists is dropped, and the reciprocal-join rule
    /// still converges.
    #[test]
    fn late_construction_converges() {
        let cluster = Cluster::new(2, FabricConfig::inline_ideal());
        let m0 = Manager::new(cluster.clone(), 0);
        let m1 = Manager::new(cluster.clone(), 1);

        let e0 = Endpoint::new("late", 0, 2, Expect::AllPeers);
        m0.register_channel(e0.clone());
        // Give the join time to arrive at node 1 and be dropped.
        std::thread::sleep(Duration::from_millis(50));
        let e1 = Endpoint::new("late", 1, 2, Expect::AllPeers);
        m1.register_channel(e1.clone());

        e0.wait_ready(Duration::from_secs(5));
        e1.wait_ready(Duration::from_secs(5));
    }

    /// Fences: unfenced counters and the zero-length-read flush.
    #[test]
    fn fence_counters_and_flush() {
        use crate::core::ctx::FenceScope;
        let cluster = Cluster::new(3, FabricConfig::inline_ideal());
        let m0 = Manager::new(cluster.clone(), 0);
        let _m1 = Manager::new(cluster.clone(), 1);
        let _m2 = Manager::new(cluster.clone(), 2);
        let r1 = cluster.node(1).register_mr(16, false);
        let r2 = cluster.node(2).register_mr(16, false);

        let ctx = m0.ctx();
        ctx.write1(r1, 0, 5).wait();
        ctx.write1(r2, 0, 6).wait();
        assert_eq!(ctx.unfenced_peers(), 2);
        ctx.fence(FenceScope::Pair(1));
        assert_eq!(ctx.unfenced_peers(), 1);
        ctx.fence(FenceScope::Thread);
        assert_eq!(ctx.unfenced_peers(), 0);

        // Blocking read resets the counter for its peer (fast path).
        ctx.write1(r1, 0, 7).wait();
        assert_eq!(ctx.unfenced_peers(), 1);
        assert_eq!(ctx.read1(r1, 0), 7);
        assert_eq!(ctx.unfenced_peers(), 0);
    }

    /// The polling thread mirrors the fabric's crash mask into
    /// Membership, bumping the epoch exactly once per death; ops against
    /// the dead peer return PeerFailed instead of hanging.
    #[test]
    fn membership_detects_crash_and_ops_fail_fast() {
        let cluster = Cluster::new(3, FabricConfig::inline_ideal());
        let m0 = Manager::new(cluster.clone(), 0);
        let _m1 = Manager::new(cluster.clone(), 1);
        let _m2 = Manager::new(cluster.clone(), 2);
        let r2 = cluster.node(2).register_mr(8, false);
        let ctx = m0.ctx();
        assert_eq!(ctx.read1(r2, 0), 0);
        assert_eq!(m0.membership().epoch(), 0);
        assert!(!m0.is_dead(2));

        cluster.crash(2);
        let deadline = std::time::Instant::now() + Duration::from_secs(5);
        while !m0.is_dead(2) {
            assert!(std::time::Instant::now() < deadline, "membership never updated");
            std::thread::yield_now();
        }
        assert_eq!(m0.membership().epoch(), 1);
        assert_eq!(m0.membership().dead_mask(), 0b100);
        assert!(!m0.is_dead(1));

        // Fallible ops surface the dead peer; nothing hangs.
        assert!(matches!(
            ctx.try_read(r2, 0, 1),
            Err(crate::Error::PeerFailed(_))
        ));
        assert!(matches!(
            ctx.try_fetch_add(r2, 0, 1),
            Err(crate::Error::PeerFailed(_))
        ));
        // A fence covering unfenced writes to the dead peer reports it.
        ctx.write1(r2, 0, 9);
        assert!(ctx.try_fence(crate::core::ctx::FenceScope::Pair(2)).is_err());
        // The zeroed-buffer contract of the infallible read.
        assert_eq!(ctx.read1(r2, 0), 0);
    }

    /// Global fence covers writes issued by *other* threads of the node.
    #[test]
    fn global_fence_covers_all_threads() {
        use crate::fabric::LatencyModel;
        let mut lat = LatencyModel::ideal();
        lat.placement_lag_ns = 10_000_000_000; // writes never place alone
        let cluster = Cluster::new(2, FabricConfig::threaded(lat));
        let m0 = Manager::new(cluster.clone(), 0);
        let _m1 = Manager::new(cluster.clone(), 1);
        let dst = cluster.node(1).register_mr(16, false);

        // Worker thread writes, never fences.
        let m0b = m0.clone();
        let h = std::thread::spawn(move || {
            let ctx = m0b.ctx();
            ctx.write1(dst, 3, 99).wait();
        });
        h.join().unwrap();
        // Not placed yet (lag is 10 s).
        assert_eq!(cluster.node(1).arena().load(dst.at(3)), 0);

        let main_ctx = m0.ctx();
        m0.global_fence(&main_ctx);
        assert_eq!(cluster.node(1).arena().load(dst.at(3)), 99);
    }

    /// Regression: the old dead-mask mirror could only grow, so reusing
    /// a slot that previously crashed wedged it dead forever. With
    /// epoch-carried states, a crash → revive → join sequence clears
    /// the dead bit, the polling sync (newly-down-only) does not
    /// re-latch it, and ops stamped before the transition are stale.
    #[test]
    fn membership_transitions_are_epoch_carried() {
        let cluster = Cluster::new(3, FabricConfig::inline_ideal());
        let m0 = Manager::new(cluster.clone(), 0);
        let _m1 = Manager::new(cluster.clone(), 1);
        let _m2 = Manager::new(cluster.clone(), 2);
        let ms = m0.membership();

        cluster.crash(2);
        let deadline = std::time::Instant::now() + Duration::from_secs(5);
        while !m0.is_dead(2) {
            assert!(std::time::Instant::now() < deadline, "membership never updated");
            std::thread::yield_now();
        }
        assert_eq!(ms.state(2), NodeState::Dead);
        let death_epoch = ms.state_epoch(2);
        assert!(death_epoch >= 1);
        // A broadcast the corpse stamped before dying is stale now.
        assert!(ms.op_is_stale(death_epoch - 1, 2));

        // Slot reuse: revive the fabric slot, then begin the join.
        cluster.revive(2);
        assert!(ms.note_joining(2));
        assert_eq!(ms.state(2), NodeState::Joining);
        assert!(!ms.is_dead(2));
        assert!(ms.state_epoch(2) > death_epoch);
        // The newly-down-only sync must not re-latch the cleared bit
        // from the fabric's (now clean) history.
        std::thread::sleep(Duration::from_millis(50));
        assert!(!ms.is_dead(2), "stale fabric history re-latched a rejoined slot");

        // Pre-crash stamps stay stale; post-join stamps are fresh.
        assert!(ms.op_is_stale(death_epoch - 1, 2));
        assert!(!ms.op_is_stale(ms.state_epoch(2), 2));
        assert!(ms.note_alive(2));
        assert_eq!(ms.state(2), NodeState::Alive);
        assert!(!ms.note_alive(2), "note_alive must be a joining->alive edge");
    }

    /// The ownership table spreads ranges over members, skips spares
    /// until they join, and prefers homes whose whole static backup
    /// chain is live.
    #[test]
    fn ownership_table_spreads_and_prefers_live_chains() {
        let ms = Membership::new(4);
        // Healthy: round-robin over all four nodes.
        let owners = ms.owners(2);
        for r in 0..RANGES {
            assert_eq!(owners[r], (r % 4) as NodeId);
        }
        // Node 3 is a designated spare: it owns nothing yet.
        ms.set_spares(0b1000);
        let owners = ms.owners(2);
        assert!(owners.iter().all(|&o| o < 3));
        // Node 1 dies. Members are {0, 2}; with replicas = 2 only node
        // 2's chain (successor 3, a live spare hosting backups) is
        // fully live — node 0's successor is the corpse — so every
        // range prefers node 2.
        assert!(ms.note_dead(1));
        let owners = ms.owners(2);
        assert!(owners.iter().all(|&o| o == 2));
        // The spare joins: it immediately counts as an owner (migration
        // targets it), and its chain (node 0) is live too.
        assert!(ms.note_joining(3));
        let owners = ms.owners(2);
        assert!(owners.iter().all(|&o| o == 2 || o == 3));
        assert!(owners.iter().any(|&o| o == 3));
    }
}
