//! # LOCO: Library of Channel Objects
//!
//! A from-scratch reproduction of *"LOCO: Rethinking Objects for Network
//! Memory"* (Hodgkins, Madler, Izraelevitz; 2025): composable concurrent
//! **channel objects** whose state is distributed across the nodes of a
//! weak memory network.
//!
//! The stack has three layers:
//!
//! * **L3 (this crate)** — the LOCO library: a simulated RDMA fabric
//!   ([`fabric`]), the channel/manager core ([`core`]), the channel
//!   catalogue ([`channels`]), applications ([`apps`]: linearizable
//!   kvstore, DC/DC power controller), comparator baselines
//!   ([`baselines`]), workload generators ([`workload`]) and the
//!   benchmark harness ([`bench`]).
//! * **L2/L1 (build-time Python)** — JAX model + Pallas kernels for the
//!   power-controller physics and the kvstore bulk-checksum path,
//!   AOT-lowered to HLO text in `artifacts/` and executed from Rust via
//!   the PJRT client in [`runtime`]. Python never runs at request time.

pub mod apps;
pub mod baselines;
pub mod bench;
pub mod channels;
pub mod core;
pub mod fabric;
pub mod metrics;
pub mod runtime;
pub mod util;
pub mod workload;

pub use crate::core::manager::Manager;
pub use crate::fabric::{Cluster, FabricConfig, LatencyModel, NodeId};

/// Crate-wide error type.
#[derive(Debug, thiserror::Error)]
pub enum Error {
    #[error("channel setup failed: {0}")]
    Setup(String),
    #[error("operation timed out: {0}")]
    Timeout(String),
    #[error("capacity exhausted: {0}")]
    Capacity(String),
    #[error("runtime error: {0}")]
    Runtime(String),
}

pub type Result<T> = std::result::Result<T, Error>;
