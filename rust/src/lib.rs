//! # LOCO: Library of Channel Objects
//!
//! A from-scratch reproduction of *"LOCO: Rethinking Objects for Network
//! Memory"* (Hodgkins, Madler, Izraelevitz; 2025): composable concurrent
//! **channel objects** whose state is distributed across the nodes of a
//! weak memory network.
//!
//! The stack has three layers:
//!
//! * **L3 (this crate)** — the LOCO library: a simulated RDMA fabric
//!   ([`fabric`]), the channel/manager core ([`core`](crate::core)), the
//!   channel catalogue ([`channels`]), applications ([`apps`]:
//!   linearizable kvstore, DC/DC power controller), comparator baselines
//!   ([`baselines`]), workload generators ([`workload`]) and the
//!   benchmark harness ([`bench`]).
//! * **L2/L1 (build-time Python)** — JAX model + Pallas kernels for the
//!   power-controller physics and the kvstore bulk-checksum path,
//!   AOT-lowered to HLO text in `artifacts/` and executed from Rust via
//!   the PJRT client in [`runtime`]. Python never runs at request time;
//!   this offline build stubs the PJRT client and every compute path
//!   falls back to a bit-identical native mirror.
//!
//! Operations issue **asynchronously**: every remote verb (or batch of
//! verbs — see [`fabric::PostList`] and the `*_many` APIs on
//! [`core::ctx::ThreadCtx`](crate::core::ctx::ThreadCtx)) returns an
//! [`core::ack::AckKey`](crate::core::ack::AckKey) that completes when
//! the NIC delivers the matching completions, so callers overlap many
//! operations per doorbell exactly as the paper's backend does on real
//! ConnectX hardware.
//!
//! The kvstore read path additionally carries a **locality tier**
//! (paper §1/§7's "strong locality effects"): a sharded seqlock
//! location index ([`core::index`](crate::core::index)), an optional
//! hot-key value cache with broadcast invalidation
//! ([`channels::read_cache`]), and pooled zero-copy read buffers
//! ([`core::ctx::ReadGuard`](crate::core::ctx::ReadGuard)).

#![deny(rustdoc::broken_intra_doc_links)]

pub mod analysis;
pub mod apps;
pub mod baselines;
pub mod bench;
pub mod channels;
pub mod core;
pub mod fabric;
pub mod metrics;
pub mod runtime;
pub mod sim;
pub mod testkit;
pub mod util;
pub mod workload;

pub use crate::core::manager::Manager;
pub use crate::fabric::{Cluster, FabricConfig, LatencyModel, NodeId};

/// Crate-wide error type. `Display`/`Error` are hand-implemented (the
/// offline build carries no proc-macro dependencies such as `thiserror`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Error {
    /// Channel setup failed.
    Setup(String),
    /// Operation timed out.
    Timeout(String),
    /// Capacity exhausted.
    Capacity(String),
    /// Runtime error.
    Runtime(String),
    /// A peer node crash-stopped while the operation depended on it: the
    /// op completed with an error CQE instead of taking effect (see
    /// [`fabric::CqeStatus`]). Callers can retry after the membership
    /// epoch advances (re-home) or surface the failure.
    PeerFailed(String),
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Error::Setup(m) => write!(f, "channel setup failed: {m}"),
            Error::Timeout(m) => write!(f, "operation timed out: {m}"),
            Error::Capacity(m) => write!(f, "capacity exhausted: {m}"),
            Error::Runtime(m) => write!(f, "runtime error: {m}"),
            Error::PeerFailed(m) => write!(f, "peer failed: {m}"),
        }
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;
