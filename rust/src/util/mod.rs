//! Small std-only utilities: queues, RNG, spin helpers.
//!
//! The build environment is offline with only the `xla` crate's dependency
//! tree vendored, so the usual suspects (crossbeam, rand, parking_lot) are
//! hand-rolled here at the small scale this project needs.

pub mod queue;
pub mod rng;

/// FNV-1a over 64-bit words: the value checksum used by `owned_var` and
/// the kvstore for >word-size atomicity (paper §5.1.1). The Pallas kernel
/// `python/compile/kernels/checksum.py` computes the identical function
/// for the bulk prefill/verify path; `python/tests` pin both to the same
/// test vectors.
#[inline]
pub fn fnv64(words: &[u64]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &w in words {
        h ^= w;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// SplitMix64 finalizer: a cheap full-avalanche bit mixer. The sharded
/// index and the read cache both hash keys through it so dense key
/// ranges (benches prefill `0..n`) spread evenly across shards and
/// probe chains.
#[inline]
pub fn mix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9e3779b97f4a7c15);
    x = (x ^ (x >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94d049bb133111eb);
    x ^ (x >> 31)
}

/// Spin-then-yield backoff for polling loops.
#[derive(Default)]
pub struct Backoff {
    spins: u32,
}

impl Backoff {
    pub fn new() -> Self {
        Backoff { spins: 0 }
    }

    /// Wait a beat. Under the deterministic simulator this is the
    /// universal choke point: instead of burning cycles it pumps the sim
    /// scheduler one step (delivering completions, running services,
    /// advancing virtual time), which is what makes every blocking wait
    /// in the stack sim-compatible without per-call-site surgery.
    #[inline]
    pub fn snooze(&mut self) {
        if crate::sim::maybe_pump() {
            return;
        }
        if self.spins < 64 {
            for _ in 0..(1 << (self.spins / 8).min(5)) {
                std::hint::spin_loop();
            }
            self.spins += 1;
        } else {
            std::thread::yield_now();
        }
    }

    pub fn reset(&mut self) {
        self.spins = 0;
    }
}

/// A wait deadline that works under both wall-clock and virtual time.
///
/// The stack's blocking waits carry "this can only mean a wedge" bailouts
/// (30 s of wall clock). Under the simulator those deadlines are
/// meaningless — virtual time can blow through "30 s" in microseconds of
/// host time, and a wall-clock read is nondeterministic. `WaitBudget`
/// keeps the wall-clock behavior byte-identical in threaded/inline modes
/// and swaps in deterministic equivalents under sim:
///
/// * [`WaitBudget::wedge`]: trips only after many consecutive checks with
///   **zero scheduler progress** (nothing ran, no clock advance) — i.e. a
///   genuine deadlock, never a long-but-live virtual wait.
/// * [`WaitBudget::grace`]: a fixed number of scheduler pumps — a
///   deterministic stand-in for short wall grace windows (e.g. the
///   ticket lock's dead-holder grace).
pub enum WaitBudget {
    Wall { deadline: std::time::Instant },
    SimProgress { last: u64, stale: u32, limit: u32 },
    SimIters { left: u32 },
}

impl WaitBudget {
    /// How many consecutive zero-progress pumps count as a wedge under
    /// sim. Each check follows a full scheduler pump, so any live run
    /// resets the streak long before this.
    const WEDGE_STALE_LIMIT: u32 = 64;

    /// A wedge-detection budget: `wall` of real time in threaded mode, a
    /// zero-progress streak under sim.
    pub fn wedge(wall: std::time::Duration) -> Self {
        match crate::sim::progress() {
            Some(p) => WaitBudget::SimProgress { last: p, stale: 0, limit: Self::WEDGE_STALE_LIMIT },
            None => WaitBudget::Wall { deadline: std::time::Instant::now() + wall },
        }
    }

    /// A bounded grace window: `wall` of real time in threaded mode,
    /// `sim_iters` scheduler pumps under sim.
    pub fn grace(wall: std::time::Duration, sim_iters: u32) -> Self {
        match crate::sim::progress() {
            Some(_) => WaitBudget::SimIters { left: sim_iters },
            None => WaitBudget::Wall { deadline: std::time::Instant::now() + wall },
        }
    }

    /// Check (and consume) the budget. Call once per wait-loop iteration,
    /// after the iteration's `Backoff::snooze`.
    pub fn expired(&mut self) -> bool {
        match self {
            WaitBudget::Wall { deadline } => std::time::Instant::now() >= *deadline,
            WaitBudget::SimProgress { last, stale, limit } => {
                let p = crate::sim::progress().unwrap_or(0);
                if p != *last {
                    *last = p;
                    *stale = 0;
                    false
                } else {
                    *stale += 1;
                    *stale >= *limit
                }
            }
            WaitBudget::SimIters { left } => {
                if *left == 0 {
                    true
                } else {
                    *left -= 1;
                    false
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backoff_progresses() {
        let mut b = Backoff::new();
        for _ in 0..200 {
            b.snooze();
        }
        b.reset();
        assert_eq!(b.spins, 0);
    }
}
