//! Small std-only utilities: queues, RNG, spin helpers.
//!
//! The build environment is offline with only the `xla` crate's dependency
//! tree vendored, so the usual suspects (crossbeam, rand, parking_lot) are
//! hand-rolled here at the small scale this project needs.

pub mod queue;
pub mod rng;

/// FNV-1a over 64-bit words: the value checksum used by `owned_var` and
/// the kvstore for >word-size atomicity (paper §5.1.1). The Pallas kernel
/// `python/compile/kernels/checksum.py` computes the identical function
/// for the bulk prefill/verify path; `python/tests` pin both to the same
/// test vectors.
#[inline]
pub fn fnv64(words: &[u64]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &w in words {
        h ^= w;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// SplitMix64 finalizer: a cheap full-avalanche bit mixer. The sharded
/// index and the read cache both hash keys through it so dense key
/// ranges (benches prefill `0..n`) spread evenly across shards and
/// probe chains.
#[inline]
pub fn mix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9e3779b97f4a7c15);
    x = (x ^ (x >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94d049bb133111eb);
    x ^ (x >> 31)
}

/// Spin-then-yield backoff for polling loops.
#[derive(Default)]
pub struct Backoff {
    spins: u32,
}

impl Backoff {
    pub fn new() -> Self {
        Backoff { spins: 0 }
    }

    #[inline]
    pub fn snooze(&mut self) {
        if self.spins < 64 {
            for _ in 0..(1 << (self.spins / 8).min(5)) {
                std::hint::spin_loop();
            }
            self.spins += 1;
        } else {
            std::thread::yield_now();
        }
    }

    pub fn reset(&mut self) {
        self.spins = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backoff_progresses() {
        let mut b = Backoff::new();
        for _ in 0..200 {
            b.snooze();
        }
        b.reset();
        assert_eq!(b.spins, 0);
    }
}
