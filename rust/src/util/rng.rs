//! Deterministic, seedable PRNG (xoshiro256**) — std-only stand-in for
//! `rand`. Used for latency jitter, placement-lag sampling, workload
//! generation, and property tests. Quality is ample for simulation.

/// splitmix64: seeds xoshiro and is a decent mixer on its own.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// xoshiro256** by Blackman & Vigna.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn seeded(seed: u64) -> Self {
        let mut sm = seed;
        let mut s = [0u64; 4];
        for w in s.iter_mut() {
            *w = splitmix64(&mut sm);
        }
        // Avoid the all-zero state.
        if s == [0, 0, 0, 0] {
            s[0] = 0x9E3779B97F4A7C15;
        }
        Rng { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, n)` (Lemire's method). `n` must be > 0.
    #[inline]
    pub fn gen_range(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform in `[lo, hi]` inclusive.
    #[inline]
    pub fn gen_range_incl(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(lo <= hi);
        lo + self.gen_range(hi - lo + 1)
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn gen_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli with probability `p`.
    #[inline]
    pub fn gen_bool(&mut self, p: f64) -> bool {
        self.gen_f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::seeded(7);
        let mut b = Rng::seeded(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::seeded(8);
        assert_ne!(Rng::seeded(7).next_u64(), c.next_u64());
    }

    #[test]
    fn range_bounds() {
        let mut r = Rng::seeded(1);
        for _ in 0..10_000 {
            let v = r.gen_range(10);
            assert!(v < 10);
            let v = r.gen_range_incl(5, 7);
            assert!((5..=7).contains(&v));
            let f = r.gen_f64();
            assert!((0.0..1.0).contains(&f));
        }
    }

    #[test]
    fn range_covers_all_values() {
        let mut r = Rng::seeded(2);
        let mut seen = [false; 8];
        for _ in 0..1000 {
            seen[r.gen_range(8) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn uniformity_chi_square_loose() {
        // 16 buckets, 64k draws: each bucket ~4096. Loose 10% tolerance.
        let mut r = Rng::seeded(3);
        let mut counts = [0u32; 16];
        for _ in 0..65536 {
            counts[r.gen_range(16) as usize] += 1;
        }
        for c in counts {
            assert!((3686..=4506).contains(&c), "bucket count {c} out of tolerance");
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::seeded(4);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>(), "astronomically unlikely identity");
    }
}
