//! MPMC unbounded FIFO queue on std primitives (Mutex<VecDeque> + Condvar).
//!
//! Used for QP submission queues, completion queues, and receive queues.
//! At the fabric's operating point (µs-scale verb latencies) the mutex is
//! never the bottleneck; see EXPERIMENTS.md §Perf for measurements.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::Duration;

pub struct Queue<T> {
    inner: Mutex<VecDeque<T>>,
    cv: Condvar,
}

impl<T> Queue<T> {
    pub fn new() -> Self {
        Queue { inner: Mutex::new(VecDeque::new()), cv: Condvar::new() }
    }

    pub fn push(&self, item: T) {
        let mut q = self.inner.lock().unwrap();
        q.push_back(item);
        self.cv.notify_one();
    }

    /// Push a batch of items under one lock acquisition and one wakeup.
    /// The fabric's doorbell-batched submission path uses this so an
    /// N-verb post list costs one mutex round instead of N.
    pub fn push_batch(&self, items: impl IntoIterator<Item = T>) {
        let mut q = self.inner.lock().unwrap();
        q.extend(items);
        self.cv.notify_all();
    }

    pub fn try_pop(&self) -> Option<T> {
        self.inner.lock().unwrap().pop_front()
    }

    /// Pop, blocking up to `timeout`.
    pub fn pop_timeout(&self, timeout: Duration) -> Option<T> {
        let mut q = self.inner.lock().unwrap();
        if let Some(v) = q.pop_front() {
            return Some(v);
        }
        let deadline = std::time::Instant::now() + timeout;
        loop {
            let now = std::time::Instant::now();
            if now >= deadline {
                return q.pop_front();
            }
            let (guard, res) = self.cv.wait_timeout(q, deadline - now).unwrap();
            q = guard;
            if let Some(v) = q.pop_front() {
                return Some(v);
            }
            if res.timed_out() {
                return None;
            }
        }
    }

    /// Drain up to `max` items into `out`; returns the count.
    pub fn drain_into(&self, max: usize, out: &mut Vec<T>) -> usize {
        let mut q = self.inner.lock().unwrap();
        let n = max.min(q.len());
        out.extend(q.drain(..n));
        n
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.inner.lock().unwrap().is_empty()
    }
}

impl<T> Default for Queue<T> {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn fifo_order() {
        let q = Queue::new();
        for i in 0..10 {
            q.push(i);
        }
        for i in 0..10 {
            assert_eq!(q.try_pop(), Some(i));
        }
        assert_eq!(q.try_pop(), None);
    }

    #[test]
    fn push_batch_preserves_order() {
        let q = Queue::new();
        q.push(0u64);
        q.push_batch(1..=5u64);
        q.push_batch(std::iter::empty());
        for i in 0..=5 {
            assert_eq!(q.try_pop(), Some(i));
        }
        assert_eq!(q.try_pop(), None);
    }

    #[test]
    fn drain() {
        let q = Queue::new();
        for i in 0..10 {
            q.push(i);
        }
        let mut out = Vec::new();
        assert_eq!(q.drain_into(4, &mut out), 4);
        assert_eq!(out, vec![0, 1, 2, 3]);
        assert_eq!(q.len(), 6);
    }

    #[test]
    fn pop_timeout_wakes_on_push() {
        let q = Arc::new(Queue::new());
        let q2 = q.clone();
        let h = std::thread::spawn(move || q2.pop_timeout(Duration::from_secs(5)));
        std::thread::sleep(Duration::from_millis(20));
        q.push(42u32);
        assert_eq!(h.join().unwrap(), Some(42));
    }

    #[test]
    fn pop_timeout_expires() {
        let q: Queue<u32> = Queue::new();
        let t0 = std::time::Instant::now();
        assert_eq!(q.pop_timeout(Duration::from_millis(30)), None);
        assert!(t0.elapsed() >= Duration::from_millis(25));
    }

    #[test]
    fn mpmc_stress() {
        let q = Arc::new(Queue::new());
        let producers: Vec<_> = (0..4)
            .map(|p| {
                let q = q.clone();
                std::thread::spawn(move || {
                    for i in 0..1000u64 {
                        q.push(p * 1000 + i);
                    }
                })
            })
            .collect();
        let consumers: Vec<_> = (0..4)
            .map(|_| {
                let q = q.clone();
                std::thread::spawn(move || {
                    let mut got = Vec::new();
                    while got.len() < 1000 {
                        if let Some(v) = q.pop_timeout(Duration::from_secs(5)) {
                            got.push(v);
                        }
                    }
                    got
                })
            })
            .collect();
        for p in producers {
            p.join().unwrap();
        }
        let mut all: Vec<u64> = consumers.into_iter().flat_map(|c| c.join().unwrap()).collect();
        all.sort_unstable();
        assert_eq!(all.len(), 4000);
        all.dedup();
        assert_eq!(all.len(), 4000, "every pushed item popped exactly once");
    }
}
