//! Vector clocks over the checker's dense actor space.
//!
//! Actors are dense indices assigned by the [`Checker`](super::Checker):
//! `app(node) = node` and `engine(node) = n + node` for an `n`-node
//! cluster, so a clock is a flat `Vec<u64>` of length `2n` — cheap to
//! snapshot per posted WQE and to join at every happens-before edge.

/// A fixed-width vector clock: one monotone counter per actor.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct VClock {
    c: Vec<u64>,
}

impl VClock {
    /// The zero clock over `actors` components.
    pub fn new(actors: usize) -> VClock {
        VClock { c: vec![0; actors] }
    }

    /// This clock's entry for `actor`.
    #[inline]
    pub fn get(&self, actor: u32) -> u64 {
        self.c[actor as usize]
    }

    /// Advance `actor`'s own component (a new event in its program
    /// order) and return the new epoch.
    #[inline]
    pub fn tick(&mut self, actor: u32) -> u64 {
        let e = &mut self.c[actor as usize];
        *e += 1;
        *e
    }

    /// Pointwise maximum: after `a.join(&b)`, everything ordered before
    /// `b`'s snapshot is also ordered before `a`'s future events.
    pub fn join(&mut self, other: &VClock) {
        debug_assert_eq!(self.c.len(), other.c.len());
        for (s, o) in self.c.iter_mut().zip(other.c.iter()) {
            if *o > *s {
                *s = *o;
            }
        }
    }

    /// `self ≥ other` pointwise: every event in `other`'s past is in
    /// `self`'s past (i.e. `other` happens-before-or-equals `self`).
    pub fn dominates(&self, other: &VClock) -> bool {
        debug_assert_eq!(self.c.len(), other.c.len());
        self.c.iter().zip(other.c.iter()).all(|(s, o)| s >= o)
    }

    /// Number of actor components.
    pub fn len(&self) -> usize {
        self.c.len()
    }

    pub fn is_empty(&self) -> bool {
        self.c.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tick_advances_own_component_only() {
        let mut v = VClock::new(4);
        assert_eq!(v.tick(2), 1);
        assert_eq!(v.tick(2), 2);
        assert_eq!(v.get(2), 2);
        assert_eq!(v.get(0), 0);
    }

    #[test]
    fn join_is_pointwise_max() {
        let mut a = VClock::new(3);
        let mut b = VClock::new(3);
        a.tick(0);
        a.tick(0);
        b.tick(1);
        b.tick(2);
        a.join(&b);
        assert_eq!((a.get(0), a.get(1), a.get(2)), (2, 1, 1));
        // Joining is idempotent and never decreases components.
        let snap = a.clone();
        a.join(&b);
        assert_eq!(a, snap);
    }

    #[test]
    fn dominates_orders_snapshots() {
        let mut a = VClock::new(2);
        let b = VClock::new(2);
        assert!(a.dominates(&b), "zero clock dominates zero clock");
        a.tick(0);
        assert!(a.dominates(&b));
        assert!(!b.dominates(&a));
        // Concurrent clocks: neither dominates.
        let mut c = VClock::new(2);
        c.tick(1);
        assert!(!a.dominates(&c));
        assert!(!c.dominates(&a));
        // After a join, the union dominates both inputs.
        let mut u = a.clone();
        u.join(&c);
        assert!(u.dominates(&a) && u.dominates(&c));
    }
}
