//! Happens-before race and consistency checking of simulated network
//! memory (see `docs/ARCHITECTURE.md § Race & consistency checking`).
//!
//! LOCO's channels stay correct on an incoherent memory network only
//! because every publication is threaded through counters, checksums,
//! valid bits, and §5.3/§7.2 fences. Nothing in the stack *checks* that
//! discipline except end-to-end history checking — which reports a wrong
//! value long after the unfenced WRITE that caused it. This module is
//! the missing root-cause analysis: a [`Checker`] hangs off the fabric's
//! two access choke points (every [`Arena`](crate::fabric::Arena) word
//! access, and the NIC engine's DMA execution of WQEs) and maintains
//! per-actor **vector clocks** advanced by the events that really order
//! accesses in this stack:
//!
//! | edge | from → to |
//! |------|-----------|
//! | WQE post → NIC execution | `on_post` snapshot joined at `on_execute` |
//! | CQE delivery → poller | `on_execute` (signaled) → `on_cq_drain` |
//! | ack-word observation | writer's clock stored per [`RegionKind::AckCell`] word, joined by the reader |
//! | fence / flushing-read completion | `on_flush` (also clears rule-(c) pending) |
//! | tracker apply-then-ack | the ack write/observation edges above, composed |
//! | lock acquire/release | `lock_release` publishes, `lock_acquire` joins |
//!
//! Three diagnostic rules:
//!
//! * **(a) unprotected races** — conflicting accesses to a word of a
//!   [`RegionKind::Checked`] region with no happens-before edge. The
//!   per-address **protocol register** ([`Checker::declare_region`])
//!   lets channels declare torn-tolerant frame layouts
//!   (`counter‖valid` + checksum validation) as [`RegionKind::Frames`]
//!   or [`RegionKind::ValidatedMailbox`], which rule (a) deliberately
//!   skips — the reader-validation idiom is the whole point of LOCO,
//!   and flagging it would drown the signal. Undeclared memory is
//!   likewise skipped (under-approximation: never a false positive).
//! * **(b) use-after-free** — any write (local store or lagged DMA
//!   placement) landing in a slab slot after its free retired it
//!   ([`Checker::on_slab_free`] / [`Checker::on_slab_alloc`] wire the
//!   [`SlabAllocator`](crate::core::mem_pool::SlabAllocator) free-list
//!   transitions in as death/birth events), plus the structural form:
//!   a slot freed while its `counter‖valid` word still has the valid
//!   bit set.
//! * **(c) publication-before-fence** — a publication (tracker
//!   broadcast, coalesced-invalidation enqueue) issued while a fenced
//!   frame write is still unflushed on some peer ([`Checker::on_unfenced_write`]
//!   pending set, cleared by [`Checker::on_flush`]).
//!
//! Two CI mutants prove the teeth: `--cfg loco_mutant_fence` drops the
//! fence on the kvstore's in-place update chain (caught by rule (c));
//! `--cfg loco_mutant_uaf` frees a relocated-away slot before its valid
//! bit is cleared (caught by rule (b), both forms). Green runs of the
//! model and chaos tiers assert **zero** diagnostics.
//!
//! Cost when disabled: every hook is gated on a `OnceLock` handle that
//! was never set — one atomic load and a dead branch, pinned by
//! `bench::micro::check_hook_overhead` exactly like the PR-3 fault
//! hooks.

pub mod vclock;

use std::cell::Cell;
use std::collections::{BTreeMap, HashMap};
use std::fmt;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::fabric::NodeId;
pub use vclock::VClock;

/// Hard cap on retained diagnostics: a badly broken run must not OOM
/// the checker. `Checker::dropped_diagnostics` counts the overflow.
const MAX_DIAGS: usize = 1024;

/// Checker activation, resolved per delivery mode (`Auto`) or forced.
/// Configured via `FabricConfig::check_races` / env `LOCO_CHECK`
/// (unset → `Auto`, `0`/`off` → `Off`, `structural` → `Structural`,
/// `1`/`full` → `Full`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CheckMode {
    /// Default: `Full` under `DeliveryMode::Sim`, `Off` otherwise.
    Auto,
    Off,
    /// Structural rules only — (b) use-after-free and (c)
    /// publication-before-fence, plus stale-MR execution checks. No
    /// vector clocks, so it is cheap enough for the threaded chaos tier.
    Structural,
    /// Everything: structural rules + happens-before rule (a) on
    /// declared `Checked` regions. Meant for the single-threaded sim.
    Full,
}

/// What a resolved, non-`Off` mode runs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CheckLevel {
    Structural,
    Full,
}

impl CheckMode {
    /// Resolve against the delivery mode (`sim` = `DeliveryMode::Sim`).
    pub fn resolve(self, sim: bool) -> Option<CheckLevel> {
        match self {
            CheckMode::Off => None,
            CheckMode::Structural => Some(CheckLevel::Structural),
            CheckMode::Full => Some(CheckLevel::Full),
            CheckMode::Auto => sim.then_some(CheckLevel::Full),
        }
    }
}

/// Parse a `LOCO_CHECK` override. Mirrors `parse_signal_every`: an
/// explicit garbage value is an error (surfaced as a panic at config
/// construction), never silently ignored.
pub fn parse_check_mode(raw: Option<&str>) -> Result<CheckMode, String> {
    match raw.map(str::trim) {
        None | Some("") => Ok(CheckMode::Auto),
        Some("auto") => Ok(CheckMode::Auto),
        Some("0") | Some("off") => Ok(CheckMode::Off),
        Some("structural") => Ok(CheckMode::Structural),
        Some("1") | Some("full") => Ok(CheckMode::Full),
        Some(other) => Err(format!(
            "LOCO_CHECK must be auto|0|off|structural|1|full, got {other:?}"
        )),
    }
}

/// The protocol register: what discipline protects a declared region,
/// i.e. which rules apply to accesses landing in it.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RegionKind {
    /// Plain shared words with no validation protocol: rule (a) applies
    /// in full. Only tests declare these today — every production
    /// channel region is validated by construction.
    Checked,
    /// Torn-tolerant value frames (`[hdr][value…][checksum]…[counter‖valid]`):
    /// readers validate, so rule (a) is exempt; rules (b) and — when the
    /// region's writers fence before publishing — (c) apply.
    Frames {
        /// Writers fence frame writes before publication
        /// (`KvConfig::fence_updates`); off disables rules (b)/(c) for
        /// this region so the unfenced ablation doesn't false-positive.
        fenced_publication: bool,
    },
    /// Single-word ack/cursor cells: observing the value carries the
    /// writer's history (the ack-word happens-before edge). Exempt from
    /// rule (a).
    AckCell,
    /// Seq-validated mailbox rows (owned_var rows, request-ring slots):
    /// reads validate via sequence/checksum and joining the writer's
    /// clock models the validated-handoff edge. Exempt from rule (a).
    ValidatedMailbox,
}

/// How an arena access touches memory.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AccessKind {
    Read,
    Write,
    /// Atomic RMW: conflicts with plain accesses, never with other
    /// atomics (word atomics are race-free against each other).
    Atomic,
}

/// Which logical actor is touching memory right now. Engine attribution
/// is thread-local (the NIC engine sets a guard around `step`); an
/// unguarded access is the arena owner's application actor. Engines
/// carry their stripe lane: with `engines_per_node = E` a node's QPs
/// are striped across `E` engine actors `engine(n, 0..E)`, each an
/// independent timeline (HB edges stay per-QP, so per-QP FIFO keeps
/// ordering exactly as in the serial model).
#[derive(Clone, Copy, Debug)]
enum Who {
    App(NodeId),
    Engine(NodeId, u32),
}

#[derive(Clone, Copy, Debug)]
struct ActorCtx {
    who: Who,
    /// DMA provenance: (posting node, wr_id) of the WQE being executed.
    wqe: Option<(NodeId, u64)>,
}

thread_local! {
    static ACTOR: Cell<Option<ActorCtx>> = const { Cell::new(None) };
}

/// RAII scope marking the current thread as a specific actor for the
/// duration (restores the previous attribution on drop, so nested
/// guards — engine step → per-WQE DMA — compose).
pub struct ActorGuard {
    prev: Option<ActorCtx>,
}

impl ActorGuard {
    fn install(ctx: ActorCtx) -> ActorGuard {
        let prev = ACTOR.with(|a| a.replace(Some(ctx)));
        ActorGuard { prev }
    }

    /// The NIC engine of `node` is running (threaded engine loop or a
    /// sim `EngineCore::step`). Lane 0 — the serial single-engine
    /// configuration; striped engines use [`ActorGuard::engine_lane`].
    pub fn engine(node: NodeId) -> ActorGuard {
        Self::engine_lane(node, 0)
    }

    /// Engine `lane` of `node` is running (one stripe of the node's
    /// QPs when `engines_per_node > 1`).
    pub fn engine_lane(node: NodeId, lane: u32) -> ActorGuard {
        Self::install(ActorCtx { who: Who::Engine(node, lane), wqe: None })
    }

    /// The NIC engine of `engine` is executing (or placing) the WQE
    /// `wr_id` posted by `src` — arena accesses in scope carry that
    /// provenance into diagnostics. Inherits the stripe lane from an
    /// enclosing engine guard (DMA scopes nest inside the engine's
    /// step scope), lane 0 when there is none.
    pub fn dma(engine: NodeId, src: NodeId, wr_id: u64) -> ActorGuard {
        let lane = match ACTOR.with(|a| a.get()) {
            Some(ActorCtx { who: Who::Engine(e, lane), .. }) if e == engine => lane,
            _ => 0,
        };
        Self::install(ActorCtx { who: Who::Engine(engine, lane), wqe: Some((src, wr_id)) })
    }

    /// Inline-mode execution: the posting application thread itself is
    /// performing the remote effect (synchronous, program-ordered).
    pub fn app(node: NodeId, wr_id: u64) -> ActorGuard {
        Self::install(ActorCtx { who: Who::App(node), wqe: Some((node, wr_id)) })
    }
}

impl Drop for ActorGuard {
    fn drop(&mut self) {
        let prev = self.prev.take();
        ACTOR.with(|a| a.set(prev));
    }
}

/// The handle an [`Arena`](crate::fabric::Arena) stores: the checker
/// plus the arena's owning node (the default attribution for unguarded
/// accesses).
#[derive(Clone)]
pub struct CheckerHandle {
    pub node: NodeId,
    pub checker: Arc<Checker>,
}

/// Diagnostic taxonomy. `RaceOnCheckedWord` is rule (a); `UseAfterFree`
/// and `FreeWhileValid` are rule (b)'s dynamic and structural forms;
/// `PublicationBeforeFence` is rule (c); `StaleMr` is the
/// DMA-execution-time MR bounds check.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DiagKind {
    RaceOnCheckedWord,
    UseAfterFree,
    FreeWhileValid,
    PublicationBeforeFence,
    StaleMr,
}

/// One side of a diagnosed access pair.
#[derive(Clone, Debug)]
pub struct AccessSite {
    /// `app(n)` / `engine(n)` actor label.
    pub actor: String,
    /// Static code-site label (`"kvstore::write_value"` …).
    pub site: &'static str,
    /// WQE provenance, when the access was a DMA: (posting node, wr_id).
    pub wqe: Option<(NodeId, u64)>,
}

/// A structured checker finding: both access sites (where known), WQE
/// provenance, and the sim trace hash + seed for deterministic replay.
#[derive(Clone, Debug)]
pub struct Diagnostic {
    pub kind: DiagKind,
    /// Node whose memory the address belongs to.
    pub node: NodeId,
    pub addr: u64,
    pub len: u64,
    /// The access that triggered the report.
    pub a: AccessSite,
    /// The conflicting prior event (racing access, the free, the
    /// unfenced write), when the rule has one.
    pub b: Option<AccessSite>,
    pub detail: String,
    /// Monotone per-checker report number.
    pub seq: u64,
    /// Sim event-trace hash at report time (None outside sim) — replay
    /// the same seed and break at this hash.
    pub trace_hash: Option<u64>,
    pub seed: u64,
}

impl fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "[{:?} #{}] node {} words [{}, +{}): {} at {}",
            self.kind, self.seq, self.node, self.addr, self.len, self.a.actor, self.a.site
        )?;
        if let Some((n, wr)) = self.a.wqe {
            write!(f, " (wqe {wr:#x} from node {n})")?;
        }
        if let Some(b) = &self.b {
            write!(f, " vs {} at {}", b.actor, b.site)?;
            if let Some((n, wr)) = b.wqe {
                write!(f, " (wqe {wr:#x} from node {n})")?;
            }
        }
        write!(f, " — {}", self.detail)?;
        if let Some(h) = self.trace_hash {
            write!(f, " [seed {} trace {h:#x}]", self.seed)?;
        } else {
            write!(f, " [seed {}]", self.seed)?;
        }
        Ok(())
    }
}

#[derive(Clone, Copy, Debug)]
struct DeclaredRegion {
    node: NodeId,
    base: u64,
    len: u64,
    kind: RegionKind,
}

/// A prior access to a `Checked` word.
#[derive(Clone, Debug)]
struct Access {
    actor: u32,
    epoch: u64,
    kind: AccessKind,
    site: &'static str,
    wqe: Option<(NodeId, u64)>,
}

#[derive(Default)]
struct WordState {
    last_write: Option<Access>,
    reads: Vec<Access>,
}

/// A freed slab range awaiting re-allocation: writes landing here are
/// use-after-free.
#[derive(Clone, Debug)]
struct DeadRange {
    len: u64,
    slot: u32,
    site: &'static str,
}

#[derive(Clone, Debug)]
struct PendingWrite {
    peer: NodeId,
    addr: u64,
    len: u64,
    site: &'static str,
}

struct State {
    /// Per-actor clocks (`Full` only; empty under `Structural`).
    clocks: Vec<VClock>,
    /// Per-node CQ clock: joined from every signaled execution, drained
    /// into the poller at `on_cq_drain`.
    cq_clocks: Vec<VClock>,
    /// Post-time snapshots, indexed by `Wqe::hb - 1`.
    wqe_tokens: Vec<VClock>,
    /// Per-lock release clocks, keyed by (lock node, lock base addr).
    lock_clocks: HashMap<(NodeId, u64), VClock>,
    /// Last-writer clocks for AckCell / ValidatedMailbox words.
    ack_clocks: HashMap<(NodeId, u64), VClock>,
    regions: Vec<DeclaredRegion>,
    /// Rule-(a) per-word state, `Checked` regions only.
    words: HashMap<(NodeId, u64), WordState>,
    /// Rule-(b) dead ranges, per node, keyed by range base.
    dead: Vec<BTreeMap<u64, DeadRange>>,
    /// Rule-(c) pending unfenced frame writes, per issuing ThreadCtx.
    pending: HashMap<u32, Vec<PendingWrite>>,
    diags: Vec<Diagnostic>,
    dropped: u64,
    seq: u64,
}

/// The checker proper. One per [`Cluster`](crate::fabric::Cluster),
/// shared by every node's arena; all state sits behind one mutex
/// (uncontended in sim; the threaded chaos tier runs `Structural`,
/// whose arena-access fast path never takes it — see `on_access`).
pub struct Checker {
    n: usize,
    /// Engine stripes per node (`FabricConfig::engines_per_node`): the
    /// actor set is `n` app actors followed by `n * epn` engine actors.
    epn: usize,
    level: CheckLevel,
    seed: u64,
    /// Lock-free count of live dead-ranges: the `Structural` write fast
    /// path skips the mutex entirely while this is zero.
    dead_count: AtomicU64,
    state: Mutex<State>,
}

impl Checker {
    /// Single-engine-per-node checker (the serial seed actor model).
    pub fn new(n: usize, level: CheckLevel, seed: u64) -> Checker {
        Self::new_striped(n, 1, level, seed)
    }

    /// Checker for a cluster running `epn` striped NIC engines per
    /// node: the engine actor set widens from `engine(n)` to
    /// `engine(n, e)`, one vector-clock timeline per stripe. At
    /// `epn = 1` this is exactly [`Checker::new`].
    pub fn new_striped(n: usize, epn: usize, level: CheckLevel, seed: u64) -> Checker {
        assert!(epn >= 1, "a node needs at least one engine actor");
        let actors = n + n * epn;
        let full = level == CheckLevel::Full;
        Checker {
            n,
            epn,
            level,
            seed,
            dead_count: AtomicU64::new(0),
            state: Mutex::new(State {
                clocks: if full { vec![VClock::new(actors); actors] } else { Vec::new() },
                cq_clocks: if full { vec![VClock::new(actors); n] } else { Vec::new() },
                wqe_tokens: Vec::new(),
                lock_clocks: HashMap::new(),
                ack_clocks: HashMap::new(),
                regions: Vec::new(),
                words: HashMap::new(),
                dead: (0..n).map(|_| BTreeMap::new()).collect(),
                pending: HashMap::new(),
                diags: Vec::new(),
                dropped: 0,
                seq: 0,
            }),
        }
    }

    pub fn level(&self) -> CheckLevel {
        self.level
    }

    fn app(&self, node: NodeId) -> u32 {
        debug_assert!((node as usize) < self.n);
        node
    }

    fn engine_lane(&self, node: NodeId, lane: u32) -> u32 {
        debug_assert!((node as usize) < self.n);
        debug_assert!((lane as usize) < self.epn);
        (self.n + node as usize * self.epn + lane as usize) as u32
    }

    /// The engine actor the calling thread is attributed to for `node`:
    /// the enclosing engine guard's lane, or lane 0 unguarded (callers
    /// outside an engine scope, e.g. inline-mode drains).
    fn current_engine(&self, node: NodeId) -> u32 {
        match ACTOR.with(|a| a.get()) {
            Some(ActorCtx { who: Who::Engine(e, lane), .. }) if e == node => {
                self.engine_lane(node, lane)
            }
            _ => self.engine_lane(node, 0),
        }
    }

    fn actor_name(&self, actor: u32) -> String {
        if (actor as usize) < self.n {
            format!("app({actor})")
        } else {
            let idx = actor as usize - self.n;
            let (node, lane) = (idx / self.epn, idx % self.epn);
            if self.epn == 1 {
                format!("engine({node})")
            } else {
                format!("engine({node}, {lane})")
            }
        }
    }

    /// Resolve the current thread's attribution, defaulting to the
    /// accessed arena's owning application actor.
    fn current_actor(&self, owner: NodeId) -> (u32, Option<(NodeId, u64)>) {
        match ACTOR.with(|a| a.get()) {
            Some(ActorCtx { who: Who::Engine(e, lane), wqe }) => (self.engine_lane(e, lane), wqe),
            Some(ActorCtx { who: Who::App(a), wqe }) => (self.app(a), wqe),
            None => (self.app(owner), None),
        }
    }

    /// Declare `[base, base+len)` on `node` as protocol-registered
    /// memory of the given kind. First matching declaration wins on
    /// lookup; channels declare at region-allocation time.
    pub fn declare_region(&self, node: NodeId, base: u64, len: u64, kind: RegionKind) {
        let mut st = self.state.lock().unwrap();
        st.regions.push(DeclaredRegion { node, base, len, kind });
    }

    // ----- diagnostics plumbing ------------------------------------

    fn push_diag(
        &self,
        st: &mut State,
        kind: DiagKind,
        node: NodeId,
        addr: u64,
        len: u64,
        a: AccessSite,
        b: Option<AccessSite>,
        detail: String,
    ) {
        st.seq += 1;
        if st.diags.len() >= MAX_DIAGS {
            st.dropped += 1;
            return;
        }
        let seq = st.seq;
        st.diags.push(Diagnostic {
            kind,
            node,
            addr,
            len,
            a,
            b,
            detail,
            seq,
            trace_hash: crate::sim::current_trace_hash(),
            seed: self.seed,
        });
    }

    /// All diagnostics reported so far.
    pub fn diagnostics(&self) -> Vec<Diagnostic> {
        self.state.lock().unwrap().diags.clone()
    }

    /// Drain diagnostics (tests assert on — and thereby acknowledge —
    /// what they took).
    pub fn take_diagnostics(&self) -> Vec<Diagnostic> {
        std::mem::take(&mut self.state.lock().unwrap().diags)
    }

    /// Diagnostics discarded past the [`MAX_DIAGS`] cap.
    pub fn dropped_diagnostics(&self) -> u64 {
        self.state.lock().unwrap().dropped
    }

    // ----- the arena access hook -----------------------------------

    /// Every `Arena::{load,store,fetch_add,compare_swap,*_words}` call
    /// lands here (when a checker is installed). `owner` is the arena's
    /// node; the acting actor comes from the thread-local guard.
    pub fn on_access(&self, owner: NodeId, addr: u64, len: u64, kind: AccessKind, site: &'static str) {
        if len == 0 {
            return;
        }
        if self.level == CheckLevel::Structural {
            // Fast path for the threaded chaos tier: reads are never
            // flagged structurally, and writes only matter while a
            // freed-but-unreused range exists somewhere.
            if kind == AccessKind::Read || self.dead_count.load(Ordering::Acquire) == 0 {
                return;
            }
        }
        let mut st = self.state.lock().unwrap();
        let (actor, wqe) = self.current_actor(owner);

        // Rule (b), dynamic form: writes into a dead slab range.
        if kind != AccessKind::Read && !st.dead[owner as usize].is_empty() {
            let hit = st.dead[owner as usize]
                .range(..=addr + len.saturating_sub(1))
                .next_back()
                .filter(|(base, dr)| addr < *base + dr.len)
                .map(|(base, dr)| (*base, dr.clone()));
            if let Some((base, dr)) = hit {
                let a = AccessSite { actor: self.actor_name(actor), site, wqe };
                let b = AccessSite { actor: String::from("slab"), site: dr.site, wqe: None };
                self.push_diag(
                    &mut st,
                    DiagKind::UseAfterFree,
                    owner,
                    addr,
                    len,
                    a,
                    Some(b),
                    format!(
                        "write into freed slab slot {} (dead range [{base}, +{}))",
                        dr.slot, dr.len
                    ),
                );
            }
        }

        if self.level != CheckLevel::Full {
            return;
        }

        // Program-order tick for this event.
        st.clocks[actor as usize].tick(actor);

        // Protocol register: what discipline covers this address?
        let rk = st
            .regions
            .iter()
            .find(|r| r.node == owner && addr >= r.base && addr + len <= r.base + r.len)
            .map(|r| r.kind);
        match rk {
            Some(RegionKind::AckCell) | Some(RegionKind::ValidatedMailbox) => {
                // Validated handoff: observing the word carries the
                // writer's history into the reader.
                match kind {
                    AccessKind::Read => {
                        if let Some(wc) = st.ack_clocks.get(&(owner, addr)) {
                            let wc = wc.clone();
                            st.clocks[actor as usize].join(&wc);
                        }
                    }
                    _ => {
                        let snap = st.clocks[actor as usize].clone();
                        st.ack_clocks.insert((owner, addr), snap);
                    }
                }
            }
            Some(RegionKind::Checked) => {
                self.check_words(&mut st, owner, addr, len, kind, actor, site, wqe);
            }
            // Frames regions are validated by readers; undeclared
            // memory is conservatively exempt from rule (a).
            Some(RegionKind::Frames { .. }) | None => {}
        }
    }

    /// FastTrack-style per-word race check over a `Checked` range.
    #[allow(clippy::too_many_arguments)]
    fn check_words(
        &self,
        st: &mut State,
        owner: NodeId,
        addr: u64,
        len: u64,
        kind: AccessKind,
        actor: u32,
        site: &'static str,
        wqe: Option<(NodeId, u64)>,
    ) {
        let epoch = st.clocks[actor as usize].get(actor);
        let my = st.clocks[actor as usize].clone();
        for w in addr..addr + len {
            // Collect the racing prior access first (borrow split).
            let racy: Option<Access> = {
                let ws = st.words.entry((owner, w)).or_default();
                let conflicts = |p: &Access| {
                    p.actor != actor
                        && !(p.kind == AccessKind::Atomic && kind == AccessKind::Atomic)
                        && my.get(p.actor) < p.epoch
                };
                let found = match kind {
                    AccessKind::Read => ws.last_write.as_ref().filter(|p| conflicts(p)).cloned(),
                    _ => ws
                        .last_write
                        .as_ref()
                        .filter(|p| conflicts(p))
                        .cloned()
                        .or_else(|| ws.reads.iter().find(|p| conflicts(p)).cloned()),
                };
                // Update word state.
                let me = Access { actor, epoch, kind, site, wqe };
                match kind {
                    AccessKind::Read => {
                        ws.reads.retain(|r| r.actor != actor);
                        ws.reads.push(me);
                    }
                    _ => {
                        ws.last_write = Some(me);
                        ws.reads.clear();
                    }
                }
                found
            };
            if let Some(p) = racy {
                let a = AccessSite { actor: self.actor_name(actor), site, wqe };
                let b = AccessSite { actor: self.actor_name(p.actor), site: p.site, wqe: p.wqe };
                self.push_diag(
                    st,
                    DiagKind::RaceOnCheckedWord,
                    owner,
                    w,
                    1,
                    a,
                    Some(b),
                    format!("{kind:?} races prior {:?} with no happens-before edge", p.kind),
                );
            }
        }
    }

    // ----- WQE lifecycle edges -------------------------------------

    /// Post-time snapshot of the poster's clock; the returned token is
    /// stamped into `Wqe::hb` and joined at execution. 0 = no token.
    pub fn on_post(&self, from: NodeId) -> u32 {
        if self.level != CheckLevel::Full {
            return 0;
        }
        let mut st = self.state.lock().unwrap();
        let a = self.app(from) as usize;
        st.clocks[a].tick(from);
        let snap = st.clocks[a].clone();
        st.wqe_tokens.push(snap);
        st.wqe_tokens.len() as u32
    }

    /// The NIC engine of `node` executes a WQE: join the post-time
    /// snapshot into the engine's clock and, for signaled WQEs, merge
    /// the engine's clock into the poster's CQ clock (the CQE-delivery
    /// edge, completed by [`Checker::on_cq_drain`]).
    pub fn on_execute(&self, node: NodeId, hb: u32, signaled: bool) {
        if self.level != CheckLevel::Full {
            return;
        }
        let ea = self.current_engine(node);
        let mut st = self.state.lock().unwrap();
        let e = ea as usize;
        st.clocks[e].tick(ea);
        if hb != 0 {
            let tok = st.wqe_tokens[hb as usize - 1].clone();
            st.clocks[e].join(&tok);
        }
        if signaled {
            let snap = st.clocks[e].clone();
            st.cq_clocks[node as usize].join(&snap);
        }
    }

    /// The application poller on `node` drained ≥1 CQE: everything the
    /// engine did before posting those completions is now ordered
    /// before the poller's future events. (Joins the whole CQ clock —
    /// an over-approximation that only *adds* edges, so it can hide
    /// races but never invent one.)
    pub fn on_cq_drain(&self, node: NodeId) {
        if self.level != CheckLevel::Full {
            return;
        }
        let mut st = self.state.lock().unwrap();
        let a = self.app(node) as usize;
        st.clocks[a].tick(node);
        let cqc = st.cq_clocks[node as usize].clone();
        st.clocks[a].join(&cqc);
    }

    // ----- rule (c): publication-before-fence ----------------------

    /// An unfenced remote frame write was issued by ThreadCtx `ctx_id`
    /// on `from` toward `peer`. Recorded only when it lands in a
    /// declared `Frames { fenced_publication: true }` region.
    pub fn on_unfenced_write(
        &self,
        ctx_id: u32,
        _from: NodeId,
        peer: NodeId,
        addr: u64,
        len: u64,
        site: &'static str,
    ) {
        let mut st = self.state.lock().unwrap();
        let covered = st.regions.iter().any(|r| {
            r.node == peer
                && matches!(r.kind, RegionKind::Frames { fenced_publication: true })
                && addr < r.base + r.len
                && addr + len > r.base
        });
        if covered {
            st.pending.entry(ctx_id).or_default().push(PendingWrite { peer, addr, len, site });
        }
    }

    /// ThreadCtx `ctx_id` completed a fence (or any flushing read)
    /// toward `peer`: its frame writes there are placed. Called on both
    /// Ok and Err fence outcomes — a failed fence still retires the
    /// writes (error CQE) and the mutation path surfaces the failure.
    pub fn on_flush(&self, ctx_id: u32, peer: NodeId) {
        let mut st = self.state.lock().unwrap();
        if let Some(v) = st.pending.get_mut(&ctx_id) {
            v.retain(|p| p.peer != peer);
            if v.is_empty() {
                st.pending.remove(&ctx_id);
            }
        }
    }

    /// ThreadCtx `ctx_id` on `node` is publishing (tracker broadcast,
    /// coalesced-invalidation enqueue): any still-unfenced frame write
    /// is a publication-before-fence. Reports once and clears, so one
    /// broken mutation yields one localized diagnostic.
    pub fn on_publication(&self, ctx_id: u32, node: NodeId, site: &'static str) {
        let mut st = self.state.lock().unwrap();
        let Some(pend) = st.pending.remove(&ctx_id) else { return };
        if pend.is_empty() {
            return;
        }
        let first = pend[0].clone();
        let a = AccessSite { actor: self.actor_name(self.app(node)), site, wqe: None };
        let b = AccessSite {
            actor: self.actor_name(self.app(node)),
            site: first.site,
            wqe: None,
        };
        self.push_diag(
            &mut st,
            DiagKind::PublicationBeforeFence,
            first.peer,
            first.addr,
            first.len,
            a,
            Some(b),
            format!(
                "publication with {} unfenced frame write(s) outstanding (first: node {} [{}, +{}))",
                pend.len(),
                first.peer,
                first.addr,
                first.len
            ),
        );
    }

    // ----- lock edges ----------------------------------------------

    /// `node`'s app actor acquired the lock whose word lives at
    /// (`lock_node`, `lock_addr`): join the last releaser's clock.
    pub fn lock_acquire(&self, node: NodeId, lock_node: NodeId, lock_addr: u64) {
        if self.level != CheckLevel::Full {
            return;
        }
        let mut st = self.state.lock().unwrap();
        let a = self.app(node) as usize;
        st.clocks[a].tick(node);
        if let Some(lc) = st.lock_clocks.get(&(lock_node, lock_addr)) {
            let lc = lc.clone();
            st.clocks[a].join(&lc);
        }
    }

    /// `node`'s app actor released the lock: publish its clock for the
    /// next acquirer.
    pub fn lock_release(&self, node: NodeId, lock_node: NodeId, lock_addr: u64) {
        if self.level != CheckLevel::Full {
            return;
        }
        let mut st = self.state.lock().unwrap();
        let a = self.app(node) as usize;
        st.clocks[a].tick(node);
        let snap = st.clocks[a].clone();
        st.lock_clocks.insert((lock_node, lock_addr), snap);
    }

    // ----- rule (b): slab birth/death events -----------------------

    /// A slab slot was freed: `[base, base+len)` on `node` is dead until
    /// re-allocated. `cv` is the slot's `counter‖valid` word read at
    /// free time (None when the caller can't read it): the valid bit
    /// still set at free time is the structural use-after-free — a
    /// reader holding the old location would still validate.
    pub fn on_slab_free(
        &self,
        node: NodeId,
        slot: u32,
        base: u64,
        len: u64,
        cv: Option<u64>,
        site: &'static str,
    ) {
        let mut st = self.state.lock().unwrap();
        if let Some(cv) = cv {
            if cv & 1 == 1 {
                let a = AccessSite {
                    actor: self.actor_name(self.app(node)),
                    site,
                    wqe: None,
                };
                self.push_diag(
                    &mut st,
                    DiagKind::FreeWhileValid,
                    node,
                    base,
                    len,
                    a,
                    None,
                    format!("slab slot {slot} freed with valid bit still set (cv={cv:#x})"),
                );
            }
        }
        st.dead[node as usize].insert(base, DeadRange { len, slot, site });
        self.dead_count.fetch_add(1, Ordering::Release);
    }

    /// A slab slot was (re-)allocated: its range is live again.
    pub fn on_slab_alloc(&self, node: NodeId, base: u64, len: u64) {
        if self.dead_count.load(Ordering::Acquire) == 0 {
            return;
        }
        let mut st = self.state.lock().unwrap();
        let keys: Vec<u64> = st.dead[node as usize]
            .range(..base + len)
            .filter(|(b, dr)| *b + dr.len > base)
            .map(|(b, _)| *b)
            .collect();
        for k in keys {
            st.dead[node as usize].remove(&k);
            self.dead_count.fetch_sub(1, Ordering::Release);
        }
    }

    // ----- stale-MR execution check --------------------------------

    /// DMA execution found the WQE's rkey no longer covering its target
    /// (the MR was invalidated/re-registered mid-flight). The engine
    /// skips the effect and delivers the completion; this records why.
    #[allow(clippy::too_many_arguments)]
    pub fn on_stale_mr(
        &self,
        node: NodeId,
        addr: u64,
        len: u64,
        src: NodeId,
        wr_id: u64,
        mr: u32,
        site: &'static str,
    ) {
        let actor = self.current_engine(src);
        let mut st = self.state.lock().unwrap();
        let a = AccessSite {
            actor: self.actor_name(actor),
            site,
            wqe: Some((src, wr_id)),
        };
        self.push_diag(
            &mut st,
            DiagKind::StaleMr,
            node,
            addr,
            len,
            a,
            None,
            format!("WQE executed against invalidated/re-registered MR {mr}; effect skipped"),
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn full(n: usize) -> Checker {
        Checker::new(n, CheckLevel::Full, 7)
    }

    #[test]
    fn parse_check_mode_accepts_the_documented_values() {
        assert_eq!(parse_check_mode(None), Ok(CheckMode::Auto));
        assert_eq!(parse_check_mode(Some("")), Ok(CheckMode::Auto));
        assert_eq!(parse_check_mode(Some("auto")), Ok(CheckMode::Auto));
        assert_eq!(parse_check_mode(Some("0")), Ok(CheckMode::Off));
        assert_eq!(parse_check_mode(Some("off")), Ok(CheckMode::Off));
        assert_eq!(parse_check_mode(Some("structural")), Ok(CheckMode::Structural));
        assert_eq!(parse_check_mode(Some("1")), Ok(CheckMode::Full));
        assert_eq!(parse_check_mode(Some("full")), Ok(CheckMode::Full));
        assert!(parse_check_mode(Some("yes")).is_err());
    }

    #[test]
    fn auto_resolves_full_only_under_sim() {
        assert_eq!(CheckMode::Auto.resolve(true), Some(CheckLevel::Full));
        assert_eq!(CheckMode::Auto.resolve(false), None);
        assert_eq!(CheckMode::Off.resolve(true), None);
        assert_eq!(CheckMode::Structural.resolve(false), Some(CheckLevel::Structural));
        assert_eq!(CheckMode::Full.resolve(false), Some(CheckLevel::Full));
    }

    #[test]
    fn unordered_writes_to_checked_region_race() {
        let c = full(2);
        c.declare_region(1, 100, 8, RegionKind::Checked);
        // app(0) and app(1) write the same word with no edge between.
        {
            let _g = ActorGuard::app(0, 1);
            c.on_access(1, 100, 1, AccessKind::Write, "a");
        }
        {
            let _g = ActorGuard::app(1, 2);
            c.on_access(1, 100, 1, AccessKind::Write, "b");
        }
        let d = c.take_diagnostics();
        assert_eq!(d.len(), 1, "exactly one race: {d:?}");
        assert_eq!(d[0].kind, DiagKind::RaceOnCheckedWord);
        assert_eq!((d[0].node, d[0].addr), (1, 100));
        assert_eq!(d[0].seed, 7);
    }

    #[test]
    fn torn_frame_regions_are_exempt_from_rule_a() {
        // The protocol-register idiom: the identical access pattern that
        // races on a Checked region is silent on a Frames region —
        // readers there validate via counter/checksum by construction.
        let c = full(2);
        c.declare_region(1, 100, 8, RegionKind::Frames { fenced_publication: true });
        {
            let _g = ActorGuard::app(0, 1);
            c.on_access(1, 100, 4, AccessKind::Write, "writer");
        }
        {
            let _g = ActorGuard::app(1, 2);
            c.on_access(1, 100, 4, AccessKind::Read, "torn reader");
            c.on_access(1, 102, 2, AccessKind::Write, "second writer");
        }
        assert!(c.take_diagnostics().is_empty(), "validated frames must not be flagged");
        // Undeclared memory is exempt too (under-approximation).
        {
            let _g = ActorGuard::app(0, 3);
            c.on_access(0, 500, 1, AccessKind::Write, "x");
        }
        {
            let _g = ActorGuard::app(1, 4);
            c.on_access(0, 500, 1, AccessKind::Write, "y");
        }
        assert!(c.take_diagnostics().is_empty());
    }

    #[test]
    fn ack_word_observation_creates_the_edge() {
        let c = full(2);
        c.declare_region(0, 10, 1, RegionKind::AckCell);
        c.declare_region(1, 100, 1, RegionKind::Checked);
        // app(1) writes the checked word, then writes the ack cell.
        {
            let _g = ActorGuard::app(1, 1);
            c.on_access(1, 100, 1, AccessKind::Write, "payload");
            c.on_access(0, 10, 1, AccessKind::Write, "ack set");
        }
        // app(0) observes the ack cell, then reads the checked word:
        // ordered through the ack-word edge, no race.
        {
            let _g = ActorGuard::app(0, 2);
            c.on_access(0, 10, 1, AccessKind::Read, "ack poll");
            c.on_access(1, 100, 1, AccessKind::Read, "payload read");
        }
        assert!(c.take_diagnostics().is_empty(), "ack observation orders the read");
    }

    #[test]
    fn lock_edges_order_critical_sections() {
        let c = full(2);
        c.declare_region(0, 50, 1, RegionKind::Checked);
        c.lock_acquire(0, 0, 900);
        {
            let _g = ActorGuard::app(0, 1);
            c.on_access(0, 50, 1, AccessKind::Write, "cs write");
        }
        c.lock_release(0, 0, 900);
        c.lock_acquire(1, 0, 900);
        {
            let _g = ActorGuard::app(1, 2);
            c.on_access(0, 50, 1, AccessKind::Write, "cs write 2");
        }
        c.lock_release(1, 0, 900);
        assert!(c.take_diagnostics().is_empty(), "lock hand-off orders the writes");
    }

    #[test]
    fn post_execute_drain_orders_dma_against_poller() {
        let c = full(2);
        c.declare_region(1, 100, 1, RegionKind::Checked);
        // app(0) posts; engine(0) executes the DMA write; app(0) drains
        // the CQE and then reads the word back: all ordered.
        let hb = c.on_post(0);
        assert!(hb != 0);
        {
            let _g = ActorGuard::dma(0, 0, 42);
            c.on_execute(0, hb, true);
            c.on_access(1, 100, 1, AccessKind::Write, "dma write");
        }
        c.on_cq_drain(0);
        {
            let _g = ActorGuard::app(0, 2);
            c.on_access(1, 100, 1, AccessKind::Read, "post-cqe read");
        }
        assert!(c.take_diagnostics().is_empty(), "post→execute→cqe→drain is one chain");
        // Without the drain, a second actor's read would race.
        {
            let _g = ActorGuard::dma(1, 1, 43);
            c.on_execute(1, 0, false);
            c.on_access(1, 100, 1, AccessKind::Write, "unordered dma");
        }
        {
            let _g = ActorGuard::app(0, 3);
            c.on_access(1, 100, 1, AccessKind::Read, "racy read");
        }
        let d = c.take_diagnostics();
        assert_eq!(d.len(), 1);
        assert_eq!(d[0].kind, DiagKind::RaceOnCheckedWord);
        assert_eq!(d[0].b.as_ref().unwrap().wqe, Some((1, 43)), "provenance carried");
    }

    #[test]
    fn striped_engine_lanes_are_independent_actors() {
        // Two stripes of the same node are distinct timelines: their
        // unordered writes to a Checked word race, and the diagnostic
        // names them engine(n, e).
        let c = Checker::new_striped(2, 2, CheckLevel::Full, 7);
        c.declare_region(1, 100, 8, RegionKind::Checked);
        {
            let _g = ActorGuard::engine_lane(0, 0);
            c.on_access(1, 100, 1, AccessKind::Write, "lane0 dma");
        }
        {
            let _g = ActorGuard::engine_lane(0, 1);
            c.on_access(1, 100, 1, AccessKind::Write, "lane1 dma");
        }
        let d = c.take_diagnostics();
        assert_eq!(d.len(), 1, "{d:?}");
        assert_eq!(d[0].kind, DiagKind::RaceOnCheckedWord);
        assert_eq!(d[0].a.actor, "engine(0, 1)");
        assert_eq!(d[0].b.as_ref().unwrap().actor, "engine(0, 0)");
        // A dma guard nested in an engine scope inherits the lane, so
        // per-lane program order holds within a stripe: same lane, no
        // race against its own prior write.
        {
            let _eng = ActorGuard::engine_lane(0, 1);
            let _dma = ActorGuard::dma(0, 0, 42);
            c.on_access(1, 101, 1, AccessKind::Write, "stripe write");
        }
        {
            let _eng = ActorGuard::engine_lane(0, 1);
            let _dma = ActorGuard::dma(0, 0, 43);
            c.on_access(1, 101, 1, AccessKind::Write, "stripe write 2");
        }
        assert!(c.take_diagnostics().is_empty(), "one lane is one program order");
    }

    #[test]
    fn striped_checker_degenerates_to_serial_at_one_engine() {
        // new() is new_striped(.., 1, ..): names and indexing unchanged.
        let c = full(2);
        {
            let _g = ActorGuard::engine_lane(1, 0);
            c.declare_region(1, 10, 1, RegionKind::Checked);
            c.on_access(1, 10, 1, AccessKind::Write, "w");
        }
        {
            let _g = ActorGuard::app(0, 1);
            c.on_access(1, 10, 1, AccessKind::Write, "w2");
        }
        let d = c.take_diagnostics();
        assert_eq!(d.len(), 1);
        assert_eq!(d[0].b.as_ref().unwrap().actor, "engine(1)");
    }

    #[test]
    fn dead_range_write_is_use_after_free() {
        let c = Checker::new(2, CheckLevel::Structural, 3);
        c.on_slab_free(1, 5, 200, 10, Some(2), "retire");
        {
            let _g = ActorGuard::dma(0, 0, 9);
            c.on_access(1, 204, 2, AccessKind::Write, "late placement");
        }
        // Reads of dead ranges are legal (stale readers re-validate).
        c.on_access(1, 204, 2, AccessKind::Read, "stale read");
        // After re-allocation the range is live again.
        c.on_slab_alloc(1, 200, 10);
        c.on_access(1, 204, 2, AccessKind::Write, "fresh write");
        let d = c.take_diagnostics();
        assert_eq!(d.len(), 1, "{d:?}");
        assert_eq!(d[0].kind, DiagKind::UseAfterFree);
        assert_eq!(d[0].a.wqe, Some((0, 9)));
    }

    #[test]
    fn free_with_valid_bit_set_is_structural_uaf() {
        let c = Checker::new(1, CheckLevel::Structural, 3);
        c.on_slab_free(0, 7, 300, 8, Some(0b101), "bad retire");
        let d = c.take_diagnostics();
        assert_eq!(d.len(), 1);
        assert_eq!(d[0].kind, DiagKind::FreeWhileValid);
    }

    #[test]
    fn publication_before_fence_fires_only_on_unfenced_pending() {
        let c = Checker::new(2, CheckLevel::Structural, 3);
        c.declare_region(1, 100, 64, RegionKind::Frames { fenced_publication: true });
        // Fenced flow: write → flush → publish. Clean.
        c.on_unfenced_write(11, 0, 1, 100, 4, "frame write");
        c.on_flush(11, 1);
        c.on_publication(11, 0, "broadcast");
        assert!(c.take_diagnostics().is_empty());
        // Unfenced flow: write → publish. Diagnostic, reported once.
        c.on_unfenced_write(11, 0, 1, 108, 4, "frame write");
        c.on_publication(11, 0, "broadcast");
        c.on_publication(11, 0, "broadcast again");
        let d = c.take_diagnostics();
        assert_eq!(d.len(), 1, "{d:?}");
        assert_eq!(d[0].kind, DiagKind::PublicationBeforeFence);
        assert_eq!((d[0].node, d[0].addr), (1, 108));
        // Writes outside fenced-publication frames never arm the rule.
        c.on_unfenced_write(12, 0, 1, 9000, 4, "scratch write");
        c.on_publication(12, 0, "broadcast");
        assert!(c.take_diagnostics().is_empty());
    }

    #[test]
    fn atomics_do_not_race_each_other() {
        let c = full(2);
        c.declare_region(0, 20, 1, RegionKind::Checked);
        {
            let _g = ActorGuard::app(0, 1);
            c.on_access(0, 20, 1, AccessKind::Atomic, "faa");
        }
        {
            let _g = ActorGuard::app(1, 2);
            c.on_access(0, 20, 1, AccessKind::Atomic, "cas");
        }
        assert!(c.take_diagnostics().is_empty(), "word atomics are race-free");
        {
            let _g = ActorGuard::app(0, 3);
            c.on_access(0, 20, 1, AccessKind::Write, "plain store");
        }
        assert_eq!(c.take_diagnostics().len(), 1, "plain vs atomic still conflicts");
    }

    #[test]
    fn diagnostics_render_and_cap() {
        let c = Checker::new(1, CheckLevel::Structural, 5);
        c.on_slab_free(0, 1, 10, 4, Some(3), "r");
        let d = c.diagnostics();
        let s = d[0].to_string();
        assert!(s.contains("FreeWhileValid"), "{s}");
        assert!(s.contains("seed 5"), "{s}");
        assert_eq!(c.dropped_diagnostics(), 0);
    }
}
