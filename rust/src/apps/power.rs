//! The distributed DC/DC converter system (paper Appendix B).
//!
//! One *controller* node regulates the duty cycles of N *converter*
//! nodes over LOCO owned_vars (Fig. 6): `d[i]` owned by the controller,
//! `v[i]` owned by converter *i*. Converters run a fixed 10 µs plant
//! step; the controller recomputes all duty cycles every loop period.
//! The system parameters are chosen (see `python/compile/model.py`, which
//! is the source of truth shared with the L1/L2 artifacts) so that the
//! output is stable for controller periods ≤ 40 µs and degrades beyond —
//! the Fig. 7 experiment.
//!
//! **Compute path**: the plant physics and the PI controller are the L2
//! JAX model (calling the L1 Pallas converter kernel), AOT-compiled to
//! `artifacts/converter1.hlo.txt` / `artifacts/controller<N>.hlo.txt` and
//! executed through [`crate::runtime`]. A bit-identical native Rust
//! mirror exists for tests and environments without artifacts; the pytest
//! suite pins the Python refs to the same constants.
//!
//! **Timing**: wall-clock periods are the simulated periods scaled by
//! `time_scale` (default 20×) so the PJRT dispatch (~tens of µs) and the
//! simulated fabric latency stay ≪ period, preserving the paper's
//! latency-to-period ratio regime. Plant *dynamics* always integrate with
//! the simulated `DT_PLANT`, so the stability boundary is exact.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::channels::owned_var::OwnedVar;

use crate::core::endpoint::sub_name;
use crate::core::manager::Manager;
use crate::fabric::NodeId;
use crate::runtime::{Executable, Input};

/// Paper-scale converter count (Appendix B.2: 1 controller + 20).
pub const NUM_CONVERTERS: usize = 20;

// ---- plant & controller constants (single source of truth with
// python/compile/model.py; pinned by python/tests/test_power_model.py) --
pub const VIN: f64 = 48.0;
pub const IND_L: f64 = 200e-6;
pub const CAP_C: f64 = 470e-6;
pub const LOAD_R: f64 = 2.0;
pub const VREF: f64 = 24.0;
/// Plant integration step: 10 µs of simulated time (App. B.2).
pub const DT_PLANT: f64 = 10e-6;
pub const KP: f64 = 0.015;
pub const KI: f64 = 32.0;
/// Duty-cycle feedforward (VREF / VIN).
pub const D0: f64 = 0.5;
/// Anti-windup clamp on the integral *contribution*.
pub const WINDUP: f64 = 0.5;

/// One semi-implicit Euler plant step (native mirror of the Pallas
/// kernel `python/compile/kernels/converter.py`).
#[inline]
pub fn converter_step_native(i_l: f64, v_c: f64, d: f64) -> (f64, f64) {
    let i2 = i_l + DT_PLANT * (d * VIN - v_c) / IND_L;
    let v2 = v_c + DT_PLANT * (i2 - v_c / LOAD_R) / CAP_C;
    (i2, v2)
}

/// One PI controller update for a single converter (native mirror of
/// the L2 `controller_step`). Returns (d, integ').
#[inline]
pub fn controller_step_native(v_meas: f64, integ: f64, dt_ctrl: f64) -> (f64, f64) {
    let e = VREF - v_meas;
    let mut integ2 = integ + e * dt_ctrl;
    let lim = WINDUP / KI;
    integ2 = integ2.clamp(-lim, lim);
    let d = (D0 + KP * e + KI * integ2).clamp(0.0, 1.0);
    (d, integ2)
}

/// How the physics/control math is evaluated.
pub enum Compute {
    /// AOT artifacts through PJRT (the real three-layer path).
    Hlo { converter: Arc<Executable>, controller: Arc<Executable> },
    /// Native mirror (tests / artifact-less runs).
    Native,
}

/// How the distributed loop is paced.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Pacing {
    /// Real-time: loops spin until their wall deadline (simulated period
    /// × `time_scale`). Faithful to the paper's latency-sensitivity
    /// story, but requires enough cores that every node keeps its
    /// deadline; on an oversubscribed host the effective
    /// period/plant-step ratio distorts.
    Wall,
    /// Logical time: converters advance exactly `period / 10 µs` plant
    /// steps per controller tick, coordinated *through the channel
    /// itself* (tick and step-acknowledgement owned_vars ride the same
    /// fabric as the data). Deterministic; the stability boundary
    /// reproduces exactly on any host. Default.
    Lockstep,
}

#[derive(Clone, Debug)]
pub struct PowerConfig {
    pub converters: usize,
    /// Simulated controller loop period (the Fig. 7 x-axis).
    pub controller_period: Duration,
    /// Simulated converter loop period (fixed 10 µs in the paper).
    pub converter_period: Duration,
    /// Wall-clock = simulated × time_scale (Wall pacing only).
    pub time_scale: u32,
    /// Total simulated run time.
    pub sim_time: Duration,
    pub pacing: Pacing,
}

impl Default for PowerConfig {
    fn default() -> Self {
        PowerConfig {
            converters: NUM_CONVERTERS,
            controller_period: Duration::from_micros(40),
            converter_period: Duration::from_micros(10),
            time_scale: 20,
            sim_time: Duration::from_millis(40),
            pacing: Pacing::Lockstep,
        }
    }
}

/// The `power_controller` channel: two arrays of owned_vars (Fig. 6).
/// Node 0 is the controller; node `1 + i` simulates converter `i`.
pub struct PowerChannel {
    /// Duty cycles, owned by the controller.
    d: Vec<OwnedVar>,
    /// Output voltages, owned by each converter.
    v: Vec<OwnedVar>,
    /// Run/stop flag, owned by the controller.
    stop: OwnedVar,
    /// Controller tick counter (lockstep pacing).
    tick: OwnedVar,
    /// Per-converter tick acknowledgement (lockstep pacing).
    ack: Vec<OwnedVar>,
}

impl PowerChannel {
    pub fn new(mgr: &Arc<Manager>, name: &str, converters: usize) -> Self {
        assert_eq!(mgr.num_nodes(), converters + 1, "cluster = 1 controller + N converters");
        let d = (0..converters)
            .map(|i| OwnedVar::new(mgr, &sub_name(name, &format!("d{i}")), 0, 1, false))
            .collect();
        let v = (0..converters)
            .map(|i| {
                OwnedVar::new(mgr, &sub_name(name, &format!("v{i}")), (i + 1) as NodeId, 1, false)
            })
            .collect();
        let stop = OwnedVar::new(mgr, &sub_name(name, "stop"), 0, 1, false);
        let tick = OwnedVar::new(mgr, &sub_name(name, "tick"), 0, 1, false);
        let ack = (0..converters)
            .map(|i| {
                OwnedVar::new(mgr, &sub_name(name, &format!("ack{i}")), (i + 1) as NodeId, 1, false)
            })
            .collect();
        PowerChannel { d, v, stop, tick, ack }
    }

    pub fn wait_ready(&self, timeout: Duration) {
        for ov in self.d.iter().chain(&self.v).chain(&self.ack) {
            ov.wait_ready(timeout);
        }
        self.stop.wait_ready(timeout);
        self.tick.wait_ready(timeout);
    }
}

/// A (simulated-time, total output voltage) trace sample.
#[derive(Clone, Copy, Debug)]
pub struct Sample {
    pub t_sim: f64,
    pub v_total: f64,
}

pub struct PowerSystem;

impl PowerSystem {
    /// Run the controller node's loop. Returns the output-voltage trace.
    pub fn run_controller(
        mgr: &Arc<Manager>,
        chan: &PowerChannel,
        cfg: &PowerConfig,
        compute: &Compute,
    ) -> Vec<Sample> {
        let ctx = mgr.ctx();
        let n = cfg.converters;
        let period_wall = cfg.controller_period * cfg.time_scale;
        let dt_ctrl = cfg.controller_period.as_secs_f64();
        let ticks = (cfg.sim_time.as_secs_f64() / dt_ctrl) as u64;

        let mut integ = vec![0.0f64; n];
        let mut duty = vec![D0; n];
        let mut trace = Vec::with_capacity(ticks as usize);
        // Publish initial duties.
        for (i, dv) in chan.d.iter().enumerate() {
            dv.publish(&ctx, &[duty[i].to_bits()]);
        }

        let start = Instant::now();
        let mut bo = crate::util::Backoff::new();
        for tick in 0..ticks {
            if cfg.pacing == Pacing::Lockstep && tick > 0 {
                // Wait for every converter to acknowledge the previous
                // tick; their v push precedes the ack on the same QP, so
                // the ack implies the voltage is placed.
                for a in &chan.ack {
                    bo.reset();
                    while a.read_cached1(&ctx) < tick {
                        bo.snooze();
                    }
                }
            }
            // Read converters' latest voltages from the local caches.
            let v_meas: Vec<f64> =
                chan.v.iter().map(|ov| f64::from_bits(ov.read_cached1(&ctx))).collect();
            let v_total: f64 = v_meas.iter().sum();
            trace.push(Sample { t_sim: tick as f64 * dt_ctrl, v_total });

            // PI update for all converters (L2 model / native mirror).
            match compute {
                Compute::Hlo { controller, .. } => {
                    let dt = [dt_ctrl];
                    let out = controller
                        .run(&[
                            Input::F64(&v_meas, &[n as i64]),
                            Input::F64(&integ, &[n as i64]),
                            Input::F64(&dt, &[1]),
                        ])
                        .expect("controller artifact");
                    duty.copy_from_slice(out[0].as_f64());
                    integ.copy_from_slice(out[1].as_f64());
                }
                Compute::Native => {
                    for i in 0..n {
                        let (d, ig) = controller_step_native(v_meas[i], integ[i], dt_ctrl);
                        duty[i] = d;
                        integ[i] = ig;
                    }
                }
            }
            // Push new duties to the converters.
            for (i, dv) in chan.d.iter().enumerate() {
                dv.store_local(&ctx, &[duty[i].to_bits()]);
                dv.push_to(&ctx, (i + 1) as NodeId);
            }
            match cfg.pacing {
                Pacing::Wall => {
                    let next = start + period_wall * (tick as u32 + 1);
                    while Instant::now() < next {
                        std::hint::spin_loop();
                    }
                }
                Pacing::Lockstep => {
                    // Announce the tick; duty pushes precede it per-QP.
                    chan.tick.publish(&ctx, &[tick + 1]);
                }
            }
        }
        chan.stop.publish(&ctx, &[1]).wait();
        trace
    }

    /// Run one converter node's loop (node `1 + idx`). Returns the number
    /// of plant steps executed.
    pub fn run_converter(
        mgr: &Arc<Manager>,
        chan: &PowerChannel,
        cfg: &PowerConfig,
        compute: &Compute,
        idx: usize,
    ) -> u64 {
        if cfg.pacing == Pacing::Lockstep {
            return Self::run_converter_lockstep(mgr, chan, cfg, compute, idx);
        }
        let ctx = mgr.ctx();
        let period_wall = cfg.converter_period * cfg.time_scale;
        let mut i_l = 0.0f64;
        let mut v_c = 0.0f64;
        let mut steps = 0u64;
        let stopped = AtomicBool::new(false);
        let start = Instant::now();
        while !stopped.load(Ordering::Relaxed) {
            if chan.stop.read_cached1(&ctx) == 1 {
                stopped.store(true, Ordering::Relaxed);
                break;
            }
            let d = f64::from_bits(chan.d[idx].read_cached1(&ctx));
            match compute {
                Compute::Hlo { converter, .. } => {
                    let state = [i_l, v_c];
                    let out = converter
                        .run(&[Input::F64(&state, &[2, 1]), Input::F64(&[d], &[1])])
                        .expect("converter artifact");
                    let s2 = out[0].as_f64();
                    i_l = s2[0];
                    v_c = s2[1];
                }
                Compute::Native => {
                    let (i2, v2) = converter_step_native(i_l, v_c, d);
                    i_l = i2;
                    v_c = v2;
                }
            }
            // Push our voltage to the controller.
            chan.v[idx].store_local(&ctx, &[v_c.to_bits()]);
            chan.v[idx].push_to(&ctx, 0);
            steps += 1;
            let next = start + period_wall * (steps as u32);
            while Instant::now() < next {
                std::hint::spin_loop();
                if chan.stop.read_cached1(&ctx) == 1 {
                    break;
                }
            }
        }
        steps
    }

    fn run_converter_lockstep(
        mgr: &Arc<Manager>,
        chan: &PowerChannel,
        cfg: &PowerConfig,
        compute: &Compute,
        idx: usize,
    ) -> u64 {
        let ctx = mgr.ctx();
        let steps_per_tick = (cfg.controller_period.as_secs_f64()
            / cfg.converter_period.as_secs_f64())
        .round() as u64;
        let mut i_l = 0.0f64;
        let mut v_c = 0.0f64;
        let mut steps = 0u64;
        let mut done_tick = 0u64;
        let mut bo = crate::util::Backoff::new();
        loop {
            let t = chan.tick.read_cached1(&ctx);
            if t <= done_tick {
                if chan.stop.read_cached1(&ctx) == 1 {
                    break;
                }
                bo.snooze();
                continue;
            }
            bo.reset();
            // The duty push precedes the tick push on the controller's QP,
            // so the cached duty is the one for this tick.
            let d = f64::from_bits(chan.d[idx].read_cached1(&ctx));
            for _ in 0..steps_per_tick {
                match compute {
                    Compute::Hlo { converter, .. } => {
                        let state = [i_l, v_c];
                        let out = converter
                            .run(&[Input::F64(&state, &[2, 1]), Input::F64(&[d], &[1])])
                            .expect("converter artifact");
                        let s2 = out[0].as_f64();
                        i_l = s2[0];
                        v_c = s2[1];
                    }
                    Compute::Native => {
                        let (i2, v2) = converter_step_native(i_l, v_c, d);
                        i_l = i2;
                        v_c = v2;
                    }
                }
                steps += 1;
            }
            done_tick = t;
            // Voltage first, ack second: same QP → controller sees the
            // ack only after the voltage is placed.
            chan.v[idx].store_local(&ctx, &[v_c.to_bits()]);
            chan.v[idx].push_to(&ctx, 0);
            chan.ack[idx].store_local(&ctx, &[done_tick]);
            chan.ack[idx].push_to(&ctx, 0);
        }
        steps
    }

    /// Stability metric over the trace tail: peak-to-peak ripple of the
    /// total output voltage (paper Fig. 7 eyeballs the same thing).
    pub fn tail_ripple(trace: &[Sample]) -> f64 {
        let tail = &trace[trace.len() * 3 / 4..];
        let max = tail.iter().map(|s| s.v_total).fold(f64::MIN, f64::max);
        let min = tail.iter().map(|s| s.v_total).fold(f64::MAX, f64::min);
        max - min
    }

    pub fn tail_mean(trace: &[Sample]) -> f64 {
        let tail = &trace[trace.len() * 3 / 4..];
        tail.iter().map(|s| s.v_total).sum::<f64>() / tail.len() as f64
    }
}

/// Pure-compute closed-loop reference (no network): the same dynamics the
/// Python model simulates, used by tests and the Fig. 7 "analytic" series.
pub fn closed_loop_reference(period: Duration, sim_time: Duration) -> (f64, f64) {
    let k = (period.as_secs_f64() / DT_PLANT).round() as usize;
    let steps = (sim_time.as_secs_f64() / DT_PLANT) as usize;
    let dt_ctrl = k as f64 * DT_PLANT;
    let (mut i_l, mut v_c, mut integ, mut d) = (0.0, 0.0, 0.0, 0.0);
    let mut out = Vec::with_capacity(steps);
    for s in 0..steps {
        if s % k == 0 {
            // Sample-and-hold on the current voltage (the converters'
            // push at the end of the previous tick), as in App. B.
            let (dn, ig) = controller_step_native(v_c, integ, dt_ctrl);
            d = dn;
            integ = ig;
        }
        let (i2, v2) = converter_step_native(i_l, v_c, d);
        i_l = i2;
        v_c = v2;
        out.push(v_c);
    }
    let tail = &out[steps * 3 / 4..];
    let max = tail.iter().copied().fold(f64::MIN, f64::max);
    let min = tail.iter().copied().fold(f64::MAX, f64::min);
    let mean = tail.iter().sum::<f64>() / tail.len() as f64;
    (max - min, mean)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::{Cluster, FabricConfig, LatencyModel};

    /// The tuned constants give the paper's stability boundary: stable
    /// at ≤40 µs, unstable beyond (pure-compute reference).
    #[test]
    fn reference_stability_boundary() {
        let sim = Duration::from_millis(300);
        let (r20, m20) = closed_loop_reference(Duration::from_micros(20), sim);
        let (r40, m40) = closed_loop_reference(Duration::from_micros(40), sim);
        let (r60, _) = closed_loop_reference(Duration::from_micros(60), sim);
        let (r80, _) = closed_loop_reference(Duration::from_micros(80), sim);
        assert!(r20 < 0.5, "20µs ripple {r20}");
        assert!(r40 < 0.5, "40µs ripple {r40}");
        assert!((m20 - VREF).abs() < 0.5, "20µs mean {m20}");
        assert!((m40 - VREF).abs() < 0.5, "40µs mean {m40}");
        assert!(r60 > 10.0, "60µs should oscillate, ripple {r60}");
        assert!(r80 > 10.0, "80µs should oscillate, ripple {r80}");
    }

    #[test]
    fn native_step_matches_reference_formulas() {
        let (i, v) = converter_step_native(0.0, 0.0, 0.5);
        assert!((i - DT_PLANT * 0.5 * VIN / IND_L).abs() < 1e-12);
        assert!((v - DT_PLANT * i / CAP_C).abs() < 1e-12);
        let (d, ig) = controller_step_native(VREF, 0.0, 40e-6);
        assert_eq!(ig, 0.0);
        assert_eq!(d, D0);
    }

    /// End-to-end distributed run (native compute, small cluster): the
    /// channel wiring holds the loop together and converges at a stable
    /// period.
    #[test]
    fn distributed_converges_small() {
        let converters = 3;
        let cluster =
            Cluster::new(converters + 1, FabricConfig::threaded(LatencyModel::fast_sim()));
        let mgrs: Vec<Arc<Manager>> = (0..converters as NodeId + 1)
            .map(|i| Manager::new(cluster.clone(), i))
            .collect();
        let cfg = PowerConfig {
            converters,
            controller_period: Duration::from_micros(40),
            converter_period: Duration::from_micros(10),
            time_scale: 2,
            sim_time: Duration::from_millis(250),
            pacing: Pacing::Lockstep,
        };
        let mut handles = Vec::new();
        for idx in 0..converters {
            let m = mgrs[idx + 1].clone();
            let cfg = cfg.clone();
            handles.push(std::thread::spawn(move || {
                let chan = PowerChannel::new(&m, "pwr", cfg.converters);
                chan.wait_ready(Duration::from_secs(30));
                PowerSystem::run_converter(&m, &chan, &cfg, &Compute::Native, idx)
            }));
        }
        let chan = PowerChannel::new(&mgrs[0], "pwr", cfg.converters);
        chan.wait_ready(Duration::from_secs(30));
        let trace = PowerSystem::run_controller(&mgrs[0], &chan, &cfg, &Compute::Native);
        for h in handles {
            assert!(h.join().unwrap() > 0, "converter never stepped");
        }
        let mean = PowerSystem::tail_mean(&trace);
        let ripple = PowerSystem::tail_ripple(&trace);
        let target = VREF * converters as f64;
        assert!(
            (mean - target).abs() < target * 0.05 && ripple < 1.0,
            "distributed loop failed to converge: mean {mean} (target {target}), ripple {ripple}"
        );
    }
}
