//! LOCO applications: the §6 linearizable key-value store and the
//! Appendix-B distributed DC/DC power-controller simulation.

pub mod kvstore;
pub mod power;

pub use kvstore::{KvConfig, KvStore};
pub use power::{PowerConfig, PowerSystem};
