//! The LOCO key-value store (paper §6) — provably linearizable
//! (Appendix C; the history-checking test lives in
//! `rust/tests/linearizability.rs`).
//!
//! Design, exactly as in the paper:
//!
//! * Every node allocates a remotely-accessible **data region** holding
//!   value slots `[value …][checksum][counter‖valid]`.
//! * Every node keeps a **local index** (hash map under a reader-writer
//!   lock) mapping key → (home node, slot, counter).
//! * Mutations are protected by an array of **ticket locks**, indexed by
//!   `key % NUM_LOCKS`, striped across nodes.
//! * Inserts write the value *locally* with the valid bit unset,
//!   broadcast the location on the inserter's **tracker ringbuffer**,
//!   wait for all nodes to apply + acknowledge, then set the valid bit
//!   (the insert's linearization point).
//! * Deletes unset the valid bit (linearization point), broadcast, and
//!   free the slot once acknowledged.
//! * Updates write `[value][checksum]` in place under the lock, then
//!   **fence** before release (the §7.2 "15 % overhead" fence — the
//!   `fence_updates` knob ablates it).
//! * Lookups take **no locks**: index lookup, one remote read, then the
//!   checksum/counter/valid validation protocol of Appendix C.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};
use std::time::Duration;

use crate::channels::ringbuffer::{RingReceiver, RingSender};
use crate::channels::ticket_lock::TicketLock;
use crate::core::ack::AckKey;
use crate::core::ctx::{FenceScope, MemRef, ThreadCtx};
use crate::core::endpoint::{region_name, sub_name, Endpoint, Expect};
use crate::core::manager::Manager;
use crate::fabric::{NodeId, Region};
use crate::util::{fnv64, Backoff};
use crate::workload::cityhash::city_hash64_u64;
use crate::{Error, Result};

/// Tracker message opcodes.
const OP_INSERT: u64 = 1;
const OP_DELETE: u64 = 2;
const OP_BATCH: u64 = 3;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct IndexEntry {
    pub node: NodeId,
    pub slot: u32,
    pub counter: u64,
}

#[derive(Clone, Debug)]
pub struct KvConfig {
    /// Value slots per node.
    pub slots_per_node: usize,
    /// Value width in words.
    pub value_words: usize,
    /// Ticket locks striped across nodes (`key % num_locks`).
    pub num_locks: usize,
    /// Tracker ring capacity in words.
    pub tracker_words: u64,
    /// Fence updates before lock release (§7.2; ablation knob).
    pub fence_updates: bool,
    /// Use the local-handover lock fast path.
    pub lock_handover: bool,
}

impl Default for KvConfig {
    fn default() -> Self {
        KvConfig {
            slots_per_node: 4096,
            value_words: 1,
            num_locks: 256,
            tracker_words: 1 << 14,
            fence_updates: true,
            lock_handover: true,
        }
    }
}

/// State shared between application threads and the tracker thread.
struct KvShared {
    index: RwLock<HashMap<u64, IndexEntry>>,
    free: Mutex<Vec<u32>>,
    /// Authoritative per-slot counters for *local* slots.
    slot_counter: Vec<AtomicU64>,
    tracker_ready: AtomicBool,
    shutdown: AtomicBool,
}

pub struct KvStore {
    cfg: KvConfig,
    me: NodeId,
    num_nodes: usize,
    ep: Arc<Endpoint>,
    data: Region,
    locks: Vec<TicketLock>,
    tracker_tx: Mutex<RingSender>,
    shared: Arc<KvShared>,
    tracker_thread: Mutex<Option<std::thread::JoinHandle<()>>>,
}

impl KvStore {
    /// Construct the kvstore endpoint on this node. All nodes must call
    /// with identical `name` and `cfg`.
    pub fn new(mgr: &Arc<Manager>, name: &str, cfg: KvConfig) -> Arc<KvStore> {
        let me = mgr.me();
        let n = mgr.num_nodes();
        let slot_words = cfg.value_words + 2;

        let ep = Endpoint::new(name, me, n, Expect::AllPeers);
        let data = mgr.pool().alloc_named(
            &region_name(name, "data"),
            cfg.slots_per_node * slot_words,
            false,
        );
        ep.add_local_region("data", data);
        ep.expect_regions(&["data"]);
        mgr.register_channel(ep.clone());

        // Lock array, striped across nodes.
        let locks: Vec<TicketLock> = (0..cfg.num_locks)
            .map(|i| {
                TicketLock::with_options(
                    mgr,
                    &sub_name(name, &format!("lock{i}")),
                    (i % n) as NodeId,
                    FenceScope::Thread,
                    true,
                    cfg.lock_handover,
                )
            })
            .collect();

        // Our tracker (we broadcast; peers receive).
        let tracker_tx = RingSender::new(mgr, &sub_name(name, &format!("trk{me}")), cfg.tracker_words);

        let shared = Arc::new(KvShared {
            index: RwLock::new(HashMap::new()),
            free: Mutex::new((0..cfg.slots_per_node as u32).rev().collect()),
            slot_counter: (0..cfg.slots_per_node).map(|_| AtomicU64::new(0)).collect(),
            tracker_ready: AtomicBool::new(false),
            shutdown: AtomicBool::new(false),
        });

        let kv = Arc::new(KvStore {
            cfg,
            me,
            num_nodes: n,
            ep,
            data,
            locks,
            tracker_tx: Mutex::new(tracker_tx),
            shared: shared.clone(),
            tracker_thread: Mutex::new(None),
        });

        // Dedicated tracker thread (§6): receives peers' tracker rings,
        // applies index updates, then acknowledges. It references only
        // KvShared (never Arc<KvStore>) so Drop/shutdown can run.
        let mgr2 = mgr.clone();
        let name2 = name.to_string();
        let shared2 = shared;
        let words = kv.cfg.tracker_words;
        let handle = std::thread::Builder::new()
            .name(format!("kv-tracker-{me}"))
            .spawn(move || tracker_loop(mgr2, name2, words, me, n, shared2))
            .expect("spawn tracker");
        *kv.tracker_thread.lock().unwrap() = Some(handle);
        kv
    }

    pub fn wait_ready(&self, timeout: Duration) {
        self.ep.wait_ready(timeout);
        for l in &self.locks {
            l.wait_ready(timeout);
        }
        self.tracker_tx.lock().unwrap().wait_ready(timeout);
        let deadline = std::time::Instant::now() + timeout;
        while !self.shared.tracker_ready.load(Ordering::Acquire) {
            assert!(std::time::Instant::now() < deadline, "tracker thread not ready");
            std::thread::yield_now();
        }
    }

    pub fn config(&self) -> &KvConfig {
        &self.cfg
    }

    /// Home node a prefill partitioner should use for `key` (CityHash64
    /// placement, §7.2). Online inserts always go to the *inserting*
    /// node's data array, as in the paper.
    pub fn home_of(&self, key: u64) -> NodeId {
        (city_hash64_u64(key) % self.num_nodes as u64) as NodeId
    }

    fn slot_words(&self) -> usize {
        self.cfg.value_words + 2
    }

    fn slot_off(&self, slot: u32) -> u64 {
        slot as u64 * self.slot_words() as u64
    }

    fn data_region_of(&self, node: NodeId) -> Region {
        if node == self.me {
            self.data
        } else {
            self.ep.remote_region(node, "data")
        }
    }

    fn lock_of(&self, key: u64) -> &TicketLock {
        &self.locks[(key % self.cfg.num_locks as u64) as usize]
    }

    // ---- operations -------------------------------------------------

    /// Insert (or update-in-place if present). Returns Ok(true) if a new
    /// key was inserted.
    pub fn insert(&self, ctx: &ThreadCtx, key: u64, value: &[u64]) -> Result<bool> {
        assert_eq!(value.len(), self.cfg.value_words);
        let lock = self.lock_of(key);
        lock.lock(ctx);
        let existing = self.shared.index.read().unwrap().get(&key).copied();
        if let Some(e) = existing {
            self.write_value(ctx, &e, value);
            lock.unlock(ctx);
            return Ok(false);
        }

        let Some(slot) = self.shared.free.lock().unwrap().pop() else {
            lock.unlock(ctx);
            return Err(Error::Capacity(format!("node {} out of kv slots", self.me)));
        };
        let counter = self.shared.slot_counter[slot as usize].fetch_add(1, Ordering::Relaxed) + 1;
        // Local write: value, checksum, counter with valid UNSET.
        let off = self.slot_off(slot);
        for (i, w) in value.iter().enumerate() {
            ctx.local_store(self.data, off + i as u64, *w);
        }
        ctx.local_store(self.data, off + value.len() as u64, fnv64(value));
        ctx.local_store(self.data, off + value.len() as u64 + 1, counter << 1);

        // Our own index first, then broadcast to peers and await acks.
        self.shared.index.write().unwrap().insert(key, IndexEntry { node: self.me, slot, counter });
        {
            let tx = self.tracker_tx.lock().unwrap();
            tx.send(ctx, &[OP_INSERT, key, self.me as u64, slot as u64, counter]);
            let pos = tx.position();
            tx.wait_all_acked(ctx, pos);
        }
        // All indices now hold the location: set valid (linearization pt).
        ctx.local_store(self.data, off + value.len() as u64 + 1, (counter << 1) | 1);
        lock.unlock(ctx);
        Ok(true)
    }

    /// Update an existing key in place. Returns false if absent.
    pub fn update(&self, ctx: &ThreadCtx, key: u64, value: &[u64]) -> bool {
        assert_eq!(value.len(), self.cfg.value_words);
        let lock = self.lock_of(key);
        lock.lock(ctx);
        let Some(e) = self.shared.index.read().unwrap().get(&key).copied() else {
            lock.unlock(ctx);
            return false;
        };
        self.write_value(ctx, &e, value);
        lock.unlock(ctx);
        true
    }

    /// The locked write path shared by update and insert-over-existing:
    /// write `[value][checksum]`, then fence so the write is placed
    /// before the lock release (§7.2).
    fn write_value(&self, ctx: &ThreadCtx, e: &IndexEntry, value: &[u64]) {
        let region = self.data_region_of(e.node);
        let off = self.slot_off(e.slot);
        let mut buf = Vec::with_capacity(value.len() + 1);
        buf.extend_from_slice(value);
        buf.push(fnv64(value));
        ctx.write(region, off, &buf); // completion tracked by the fence
        if self.cfg.fence_updates && e.node != self.me {
            ctx.fence(FenceScope::Pair(e.node));
        }
    }

    /// Lock-free lookup (Appendix C's read protocol).
    pub fn get(&self, ctx: &ThreadCtx, key: u64) -> Option<Vec<u64>> {
        let mut bo = Backoff::new();
        loop {
            let e = self.shared.index.read().unwrap().get(&key).copied()?;
            let region = self.data_region_of(e.node);
            let words = ctx.read(region, self.slot_off(e.slot), self.slot_words());
            let (value, rest) = words.split_at(self.cfg.value_words);
            let (ck, cv) = (rest[0], rest[1]);
            if fnv64(value) != ck {
                bo.snooze(); // torn update in flight: retry in its entirety
                continue;
            }
            if cv >> 1 != e.counter {
                return None; // stale index: linearizes after the delete
            }
            if cv & 1 == 0 {
                return None; // insert not yet / delete already linearized
            }
            return Some(value.to_vec());
        }
    }

    /// Delete. Returns false if absent.
    pub fn remove(&self, ctx: &ThreadCtx, key: u64) -> bool {
        let lock = self.lock_of(key);
        lock.lock(ctx);
        let Some(e) = self.shared.index.read().unwrap().get(&key).copied() else {
            lock.unlock(ctx);
            return false;
        };
        // Unset the valid bit (the delete's linearization point).
        let region = self.data_region_of(e.node);
        let cv_off = self.slot_off(e.slot) + self.cfg.value_words as u64 + 1;
        ctx.write1(region, cv_off, e.counter << 1);
        if e.node != self.me {
            ctx.fence(FenceScope::Pair(e.node));
        }
        // Broadcast; peers drop their index entries (the home peer also
        // frees the slot); then drop ours.
        {
            let tx = self.tracker_tx.lock().unwrap();
            tx.send(ctx, &[OP_DELETE, key, e.node as u64, e.slot as u64, e.counter]);
            let pos = tx.position();
            tx.wait_all_acked(ctx, pos);
        }
        self.shared.index.write().unwrap().remove(&key);
        if e.node == self.me {
            self.shared.free.lock().unwrap().push(e.slot);
        }
        lock.unlock(ctx);
        true
    }

    // ---- batched operations (doorbell-batched pipeline) ---------------

    /// Batched lock-free lookup: the whole key set is issued through the
    /// doorbell-batched pipeline — slot reads grouped into **one post
    /// list per home node** (instead of one doorbell per key), ack
    /// tracking amortized batch-wide, and a single wait for the batch.
    /// Each result validates exactly like [`KvStore::get`]
    /// (checksum/counter/valid, Appendix C); a key whose read raced an
    /// in-flight update falls back to the scalar retry path.
    ///
    /// `out[i]` corresponds to `keys[i]`. Duplicate keys are permitted.
    pub fn multi_get(&self, ctx: &ThreadCtx, keys: &[u64]) -> Vec<Option<Vec<u64>>> {
        // Snapshot the index once for the whole batch.
        let entries: Vec<Option<IndexEntry>> = {
            let index = self.shared.index.read().unwrap();
            keys.iter().map(|k| index.get(k).copied()).collect()
        };
        let mut reqs = Vec::with_capacity(keys.len());
        let mut req_of = vec![usize::MAX; keys.len()];
        for (i, e) in entries.iter().enumerate() {
            if let Some(e) = e {
                req_of[i] = reqs.len();
                reqs.push((self.data_region_of(e.node), self.slot_off(e.slot), self.slot_words()));
            }
        }
        // read_many waits once for the whole batch and resets the
        // involved peers' unfenced counters (completed READs prove
        // placement on those QPs), exactly like the scalar get path.
        let raws = ctx.read_many(&reqs);
        keys.iter()
            .enumerate()
            .map(|(i, &k)| {
                let e = entries[i]?;
                let words = &raws[req_of[i]];
                let (value, rest) = words.split_at(self.cfg.value_words);
                let (ck, cv) = (rest[0], rest[1]);
                if fnv64(value) != ck {
                    return self.get(ctx, k); // torn update in flight: retry
                }
                if cv >> 1 != e.counter {
                    return None; // stale index: linearizes after the delete
                }
                if cv & 1 == 0 {
                    return None; // insert not yet / delete already linearized
                }
                Some(value.to_vec())
            })
            .collect()
    }

    /// Batched in-place update of existing keys: acquires the
    /// (deduplicated) key locks in ascending index order — so concurrent
    /// `multi_put`s cannot deadlock — issues every value write through
    /// the batched pipeline (one doorbell per home node), runs **one**
    /// fence covering the whole batch before the first release (§7.2's
    /// per-update fence, amortized), then unlocks. Keys not present are
    /// skipped, exactly like [`KvStore::update`]. Returns how many keys
    /// were updated.
    pub fn multi_put(&self, ctx: &ThreadCtx, items: &[(u64, Vec<u64>)]) -> usize {
        for (_, value) in items {
            assert_eq!(value.len(), self.cfg.value_words);
        }
        let mut lock_ids: Vec<usize> =
            items.iter().map(|(k, _)| (*k % self.cfg.num_locks as u64) as usize).collect();
        lock_ids.sort_unstable();
        lock_ids.dedup();
        for &l in &lock_ids {
            self.locks[l].lock(ctx);
        }

        let entries: Vec<Option<IndexEntry>> = {
            let index = self.shared.index.read().unwrap();
            items.iter().map(|(k, _)| index.get(k).copied()).collect()
        };
        // Build [value][checksum] frames, then one batched write issue.
        let mut bufs: Vec<Vec<u64>> = Vec::new();
        let mut targets: Vec<(Region, u64)> = Vec::new();
        for (e, (_k, value)) in entries.iter().zip(items) {
            if let Some(e) = e {
                let mut buf = Vec::with_capacity(value.len() + 1);
                buf.extend_from_slice(value);
                buf.push(fnv64(value));
                bufs.push(buf);
                targets.push((self.data_region_of(e.node), self.slot_off(e.slot)));
            }
        }
        let updated = targets.len();
        let writes: Vec<(Region, u64, &[u64])> = targets
            .iter()
            .zip(&bufs)
            .map(|(&(region, off), buf)| (region, off, buf.as_slice()))
            .collect();
        let _key = ctx.write_many(&writes); // completion tracked by the fence
        if self.cfg.fence_updates && !writes.is_empty() {
            ctx.fence(FenceScope::Thread); // one fence for the whole batch
        }
        for &l in lock_ids.iter().rev() {
            self.locks[l].unlock(ctx);
        }
        updated
    }

    // ---- windowed (asynchronous) reads --------------------------------

    /// Issue a lookup without waiting: returns the in-flight read. Used
    /// by the window-size experiments (§7.2): up to `window` of these may
    /// be outstanding per thread.
    pub fn get_issue(&self, ctx: &ThreadCtx, key: u64) -> Option<PendingGet> {
        let e = self.shared.index.read().unwrap().get(&key).copied()?;
        let region = self.data_region_of(e.node);
        let (ack, buf) = ctx.read_async(region, self.slot_off(e.slot), self.slot_words());
        Some(PendingGet { key, entry: e, ack, buf })
    }

    /// Complete an issued lookup (waits if necessary; falls back to the
    /// blocking path on torn reads).
    pub fn get_complete(&self, ctx: &ThreadCtx, pg: PendingGet) -> Option<Vec<u64>> {
        pg.ack.wait();
        let words = pg.buf.to_vec();
        let (value, rest) = words.split_at(self.cfg.value_words);
        let (ck, cv) = (rest[0], rest[1]);
        if fnv64(value) != ck {
            return self.get(ctx, pg.key); // torn: retry in its entirety
        }
        if cv >> 1 != pg.entry.counter || cv & 1 == 0 {
            return None;
        }
        Some(value.to_vec())
    }

    // ---- bulk prefill --------------------------------------------------

    /// Bulk-load `keys` into *this* node's data array, broadcasting index
    /// updates in batches. `checksums`, if given, must be the per-key
    /// checksum of each value (e.g. produced by the AOT Pallas checksum
    /// kernel via [`crate::runtime`]); otherwise they are computed here.
    pub fn prefill_local(
        &self,
        ctx: &ThreadCtx,
        keys: &[u64],
        mut value_of: impl FnMut(u64) -> Vec<u64>,
        checksums: Option<&[u64]>,
    ) -> Result<()> {
        const BATCH: usize = 128;
        for (chunk_idx, chunk) in keys.chunks(BATCH).enumerate() {
            let mut msg = Vec::with_capacity(3 + chunk.len() * 3);
            msg.push(OP_BATCH);
            msg.push(self.me as u64);
            msg.push(chunk.len() as u64);
            {
                let mut index = self.shared.index.write().unwrap();
                let mut free = self.shared.free.lock().unwrap();
                for (i, &key) in chunk.iter().enumerate() {
                    let Some(slot) = free.pop() else {
                        return Err(Error::Capacity(format!("node {} out of kv slots", self.me)));
                    };
                    let counter =
                        self.shared.slot_counter[slot as usize].fetch_add(1, Ordering::Relaxed) + 1;
                    let value = value_of(key);
                    assert_eq!(value.len(), self.cfg.value_words);
                    let ck = match checksums {
                        Some(cks) => cks[chunk_idx * BATCH + i],
                        None => fnv64(&value),
                    };
                    let off = self.slot_off(slot);
                    for (j, w) in value.iter().enumerate() {
                        ctx.local_store(self.data, off + j as u64, *w);
                    }
                    ctx.local_store(self.data, off + value.len() as u64, ck);
                    ctx.local_store(self.data, off + value.len() as u64 + 1, (counter << 1) | 1);
                    index.insert(key, IndexEntry { node: self.me, slot, counter });
                    msg.extend_from_slice(&[key, slot as u64, counter]);
                }
            }
            let tx = self.tracker_tx.lock().unwrap();
            tx.send(ctx, &msg);
            let pos = tx.position();
            tx.wait_all_acked(ctx, pos);
        }
        Ok(())
    }

    /// Local index size (for tests).
    pub fn index_len(&self) -> usize {
        self.shared.index.read().unwrap().len()
    }

    pub fn index_entry(&self, key: u64) -> Option<IndexEntry> {
        self.shared.index.read().unwrap().get(&key).copied()
    }

    pub fn shutdown(&self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        if let Some(h) = self.tracker_thread.lock().unwrap().take() {
            let _ = h.join();
        }
    }
}

impl Drop for KvStore {
    fn drop(&mut self) {
        self.shutdown();
    }
}

// ---- tracker thread (free-standing: must not keep KvStore alive) ------

fn tracker_loop(
    mgr: Arc<Manager>,
    name: String,
    tracker_words: u64,
    me: NodeId,
    num_nodes: usize,
    shared: Arc<KvShared>,
) {
    let ctx = mgr.ctx();
    // Receive every peer's tracker ring.
    let mut rxs: Vec<(NodeId, RingReceiver)> = (0..num_nodes as NodeId)
        .filter(|&p| p != me)
        .map(|p| {
            let mut rx = RingReceiver::new(&mgr, &sub_name(&name, &format!("trk{p}")), tracker_words);
            rx.set_manual_ack();
            (p, rx)
        })
        .collect();
    for (_, rx) in &rxs {
        rx.wait_ready(Duration::from_secs(30));
    }
    shared.tracker_ready.store(true, Ordering::Release);

    let mut bo = Backoff::new();
    loop {
        let mut did = false;
        for (from, rx) in &mut rxs {
            while let Some(msg) = rx.try_recv(&ctx) {
                apply_tracker(&shared, me, *from, &msg);
                rx.ack_now(&ctx); // apply THEN acknowledge (§6)
                did = true;
            }
        }
        if !did {
            if shared.shutdown.load(Ordering::Relaxed) {
                break;
            }
            bo.snooze();
        } else {
            bo.reset();
        }
    }
}

fn apply_tracker(shared: &KvShared, me: NodeId, from: NodeId, msg: &[u64]) {
    match msg[0] {
        OP_INSERT => {
            let (key, node, slot, counter) = (msg[1], msg[2] as NodeId, msg[3] as u32, msg[4]);
            debug_assert_eq!(node, from);
            shared.index.write().unwrap().insert(key, IndexEntry { node, slot, counter });
        }
        OP_DELETE => {
            let (key, node, slot, _counter) = (msg[1], msg[2] as NodeId, msg[3] as u32, msg[4]);
            shared.index.write().unwrap().remove(&key);
            if node == me {
                // We are the slot's home but not the deleter: reclaim.
                shared.free.lock().unwrap().push(slot);
            }
        }
        OP_BATCH => {
            let node = msg[1] as NodeId;
            let count = msg[2] as usize;
            let mut index = shared.index.write().unwrap();
            for i in 0..count {
                let base = 3 + i * 3;
                index.insert(
                    msg[base],
                    IndexEntry { node, slot: msg[base + 1] as u32, counter: msg[base + 2] },
                );
            }
        }
        other => panic!("unknown tracker opcode {other}"),
    }
}

/// An in-flight windowed lookup.
pub struct PendingGet {
    key: u64,
    entry: IndexEntry,
    ack: AckKey,
    buf: MemRef,
}

impl PendingGet {
    pub fn is_complete(&self) -> bool {
        self.ack.query()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::{Cluster, FabricConfig, LatencyModel};

    fn small_cfg() -> KvConfig {
        KvConfig { slots_per_node: 64, tracker_words: 1 << 10, ..Default::default() }
    }

    fn setup(n: usize, cfg: FabricConfig) -> (Vec<Arc<Manager>>, Vec<Arc<KvStore>>) {
        let cluster = Cluster::new(n, cfg);
        let mgrs: Vec<Arc<Manager>> =
            (0..n as NodeId).map(|i| Manager::new(cluster.clone(), i)).collect();
        let kvs: Vec<Arc<KvStore>> =
            mgrs.iter().map(|m| KvStore::new(m, "kv", small_cfg())).collect();
        for kv in &kvs {
            kv.wait_ready(Duration::from_secs(30));
        }
        (mgrs, kvs)
    }

    #[test]
    fn insert_get_update_delete_cross_node() {
        let (mgrs, kvs) = setup(3, FabricConfig::inline_ideal());
        let ctxs: Vec<_> = mgrs.iter().map(|m| m.ctx()).collect();

        assert!(kvs[0].insert(&ctxs[0], 7, &[100]).unwrap());
        // Visible from every node (index broadcast + remote read).
        for i in 0..3 {
            assert_eq!(kvs[i].get(&ctxs[i], 7), Some(vec![100]), "node {i}");
        }
        // Update from a non-home node.
        assert!(kvs[2].update(&ctxs[2], 7, &[200]));
        for i in 0..3 {
            assert_eq!(kvs[i].get(&ctxs[i], 7), Some(vec![200]));
        }
        // Delete from a third node.
        assert!(kvs[1].remove(&ctxs[1], 7));
        for i in 0..3 {
            assert_eq!(kvs[i].get(&ctxs[i], 7), None);
        }
        // Slot reclaimed at home (node 0).
        assert_eq!(kvs[0].shared.free.lock().unwrap().len(), 64);
    }

    #[test]
    fn missing_key_and_double_ops() {
        let (mgrs, kvs) = setup(2, FabricConfig::inline_ideal());
        let ctx = mgrs[0].ctx();
        assert_eq!(kvs[0].get(&ctx, 42), None);
        assert!(!kvs[0].update(&ctx, 42, &[1]));
        assert!(!kvs[0].remove(&ctx, 42));
        assert!(kvs[0].insert(&ctx, 42, &[1]).unwrap());
        assert!(!kvs[0].insert(&ctx, 42, &[2]).unwrap(), "second insert is update");
        assert_eq!(kvs[0].get(&ctx, 42), Some(vec![2]));
    }

    #[test]
    fn capacity_exhaustion() {
        let (mgrs, kvs) = setup(2, FabricConfig::inline_ideal());
        let ctx = mgrs[0].ctx();
        for k in 0..64 {
            kvs[0].insert(&ctx, k, &[k]).unwrap();
        }
        assert!(matches!(kvs[0].insert(&ctx, 999, &[0]), Err(Error::Capacity(_))));
    }

    #[test]
    fn prefill_batch_visible_everywhere() {
        let (mgrs, kvs) = setup(3, FabricConfig::inline_ideal());
        let ctxs: Vec<_> = mgrs.iter().map(|m| m.ctx()).collect();
        // Each node loads its hash-partitioned shard.
        let all: Vec<u64> = (0..150).collect();
        for (i, kv) in kvs.iter().enumerate() {
            let mine: Vec<u64> =
                all.iter().copied().filter(|&k| kv.home_of(k) == i as NodeId).collect();
            kv.prefill_local(&ctxs[i], &mine, |k| vec![k * 10], None).unwrap();
        }
        for kv in &kvs {
            assert_eq!(kv.index_len(), 150);
        }
        for &k in &all {
            assert_eq!(kvs[(k % 3) as usize].get(&ctxs[(k % 3) as usize], k), Some(vec![k * 10]));
        }
    }

    /// multi_get matches scalar gets across hit/miss/deleted keys and
    /// tolerates duplicates, on both delivery modes.
    #[test]
    fn multi_get_matches_scalar() {
        for cfg in
            [FabricConfig::inline_ideal(), FabricConfig::threaded(LatencyModel::fast_sim())]
        {
            let cluster = Cluster::new(3, cfg);
            let mgrs: Vec<Arc<Manager>> =
                (0..3).map(|i| Manager::new(cluster.clone(), i)).collect();
            let kvs: Vec<Arc<KvStore>> =
                mgrs.iter().map(|m| KvStore::new(m, "kv", small_cfg())).collect();
            for kv in &kvs {
                kv.wait_ready(Duration::from_secs(30));
            }
            let ctxs: Vec<_> = mgrs.iter().map(|m| m.ctx()).collect();
            // Spread homes across nodes: each node inserts its residue class.
            for k in 0..30u64 {
                kvs[(k % 3) as usize].insert(&ctxs[(k % 3) as usize], k, &[k + 500]).unwrap();
            }
            kvs[0].remove(&ctxs[0], 9);
            // Batch with hits on all three homes, a miss, a deleted key,
            // and a duplicate.
            let keys = [0u64, 1, 2, 17, 999, 9, 2];
            let out = kvs[1].multi_get(&ctxs[1], &keys);
            for (i, &k) in keys.iter().enumerate() {
                assert_eq!(out[i], kvs[1].get(&ctxs[1], k), "key {k}");
            }
            assert_eq!(out[4], None);
            assert_eq!(out[5], None);
            assert_eq!(out[6], Some(vec![502]));
        }
    }

    /// multi_put updates present keys, skips absent ones, and the batch
    /// fence makes every write durable before the locks release.
    #[test]
    fn multi_put_batched_updates() {
        let (mgrs, kvs) = setup(3, FabricConfig::threaded(LatencyModel::fast_sim()));
        let ctxs: Vec<_> = mgrs.iter().map(|m| m.ctx()).collect();
        for k in 0..24u64 {
            kvs[(k % 3) as usize].insert(&ctxs[(k % 3) as usize], k, &[0]).unwrap();
        }
        // Node 1 batch-updates keys homed on all three nodes (+1 absent).
        let items: Vec<(u64, Vec<u64>)> =
            (0..24u64).map(|k| (k, vec![k * 7])).chain([(777u64, vec![1])]).collect();
        assert_eq!(kvs[1].multi_put(&ctxs[1], &items), 24);
        for k in 0..24u64 {
            for (i, kv) in kvs.iter().enumerate() {
                assert_eq!(kv.get(&ctxs[i], k), Some(vec![k * 7]), "node {i} key {k}");
            }
        }
        assert_eq!(kvs[1].get(&ctxs[1], 777), None, "absent key skipped");
        // Empty batches are no-ops.
        assert_eq!(kvs[1].multi_put(&ctxs[1], &[]), 0);
        assert!(kvs[1].multi_get(&ctxs[1], &[]).is_empty());
    }

    /// Concurrent multi_puts from every node (overlapping key sets, so
    /// overlapping lock sets) must not deadlock and must leave each key
    /// holding one of the contending values.
    #[test]
    fn concurrent_multi_put_no_deadlock() {
        let (mgrs, kvs) = setup(3, FabricConfig::threaded(LatencyModel::fast_sim()));
        let ctxs: Vec<_> = mgrs.iter().map(|m| m.ctx()).collect();
        for k in 0..16u64 {
            kvs[0].insert(&ctxs[0], k, &[0]).unwrap();
        }
        let handles: Vec<_> = mgrs
            .iter()
            .zip(&kvs)
            .enumerate()
            .map(|(i, (m, kv))| {
                let m = m.clone();
                let kv = kv.clone();
                std::thread::spawn(move || {
                    let ctx = m.ctx();
                    for round in 0..20u64 {
                        let items: Vec<(u64, Vec<u64>)> = (0..16u64)
                            .map(|k| (k, vec![1 + (i as u64) * 1000 + round]))
                            .collect();
                        assert_eq!(kv.multi_put(&ctx, &items), 16);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        for k in 0..16u64 {
            let v = kvs[0].get(&ctxs[0], k).expect("key survived");
            assert!(v[0] >= 1, "key {k} holds a contending value, got {v:?}");
        }
    }

    #[test]
    fn windowed_gets() {
        let (mgrs, kvs) = setup(2, FabricConfig::threaded(LatencyModel::fast_sim()));
        let ctxs: Vec<_> = mgrs.iter().map(|m| m.ctx()).collect();
        for k in 0..32 {
            kvs[0].insert(&ctxs[0], k, &[k + 1000]).unwrap();
        }
        // Window of 8 outstanding reads from node 1.
        let mut pending = Vec::new();
        let mut results = Vec::new();
        for k in 0..32u64 {
            pending.push((k, kvs[1].get_issue(&ctxs[1], k).unwrap()));
            if pending.len() == 8 {
                for (k, pg) in pending.drain(..) {
                    results.push((k, kvs[1].get_complete(&ctxs[1], pg)));
                }
            }
        }
        for (k, pg) in pending.drain(..) {
            results.push((k, kvs[1].get_complete(&ctxs[1], pg)));
        }
        for (k, v) in results {
            assert_eq!(v, Some(vec![k + 1000]));
        }
    }

    /// Concurrent mixed workload across nodes on the racy fabric: every
    /// read sees either a fully written value or nothing — never garbage.
    #[test]
    fn concurrent_mixed_no_torn_values() {
        let n = 3;
        let cluster = Cluster::new(n, FabricConfig::threaded(LatencyModel::fast_sim()).chaotic());
        let mgrs: Vec<Arc<Manager>> =
            (0..n as NodeId).map(|i| Manager::new(cluster.clone(), i)).collect();
        let cfg = KvConfig {
            slots_per_node: 256,
            value_words: 4,
            tracker_words: 1 << 12,
            ..Default::default()
        };
        let kvs: Vec<Arc<KvStore>> =
            mgrs.iter().map(|m| KvStore::new(m, "kv", cfg.clone())).collect();
        for kv in &kvs {
            kv.wait_ready(Duration::from_secs(30));
        }
        // Values encode their key 4× so torn mixes are detectable.
        let handles: Vec<_> = mgrs
            .iter()
            .zip(&kvs)
            .enumerate()
            .map(|(i, (m, kv))| {
                let m = m.clone();
                let kv = kv.clone();
                std::thread::spawn(move || {
                    let ctx = m.ctx();
                    let mut rng = crate::util::rng::Rng::seeded(i as u64);
                    for round in 0..150u64 {
                        let key = rng.gen_range(32);
                        match rng.gen_range(10) {
                            0..=2 => {
                                let tag = round * 10 + i as u64;
                                let _ = kv.insert(&ctx, key, &[tag; 4]);
                            }
                            3..=4 => {
                                let _ = kv.remove(&ctx, key);
                            }
                            5 => {
                                let tag = round * 10 + i as u64;
                                let _ = kv.update(&ctx, key, &[tag; 4]);
                            }
                            _ => {
                                if let Some(v) = kv.get(&ctx, key) {
                                    assert!(
                                        v.iter().all(|&x| x == v[0]),
                                        "torn value from get: {v:?}"
                                    );
                                }
                            }
                        }
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    }
}
