//! The LOCO key-value store (paper §6) — provably linearizable
//! (Appendix C; the history-checking test lives in
//! `rust/tests/linearizability.rs`).
//!
//! Design, exactly as in the paper:
//!
//! * Every node allocates a remotely-accessible **data region** holding
//!   value slots `[value …][checksum][counter‖valid]`.
//! * Every node keeps a **local index** mapping key → (home node, slot,
//!   counter) — a sharded, seqlock-validated table
//!   ([`crate::core::index::ShardedIndex`]) whose readers are lock-free,
//!   so `get` never contends with tracker broadcasts.
//! * Mutations are protected by an array of **ticket locks**, indexed by
//!   `key % NUM_LOCKS`, striped across nodes.
//! * Inserts write the value *locally* with the valid bit unset,
//!   broadcast the location on the inserter's **tracker ringbuffer**,
//!   wait for all nodes to apply + acknowledge, then set the valid bit
//!   (the insert's linearization point).
//! * Deletes unset the valid bit (linearization point), broadcast, and
//!   free the slot once acknowledged.
//! * Updates write `[value][checksum]` in place under the lock, then
//!   **fence** before release (the §7.2 "15 % overhead" fence — the
//!   `fence_updates` knob ablates it).
//! * Lookups take **no locks**: index lookup, one remote read, then the
//!   checksum/counter/valid validation protocol of Appendix C.
//!
//! # The locality tier
//!
//! On top of the paper's protocol, the read path carries a **locality
//! tier** (see `docs/ARCHITECTURE.md § Locality tier`): an optional
//! bounded hot-key value cache ([`crate::channels::read_cache`]) serves
//! repeat `get`s of *remote-homed* keys from local memory. A hit is
//! legal only while the cached slot generation matches the current
//! index counter; in-place updates (which do not bump the counter)
//! broadcast invalidations over the tracker ring and wait for all acks
//! before returning, and fills are epoch-validated so an in-flight read
//! can never re-poison the cache after its key was invalidated. With
//! the cache enabled, updates and deletes therefore linearize at
//! broadcast-ack completion; `fence_updates` is required (an unfenced
//! update could be cached stale indefinitely).
//!
//! # Failure model & recovery
//!
//! Under fault injection (`FabricConfig::faults`) the store survives a
//! **single crash-stop** per cluster (see `docs/ARCHITECTURE.md`,
//! § Failure model & recovery): with [`KvConfig::replicate`] on, every
//! slot frame is mirrored to a backup node, and on a detected crash the
//! backup re-homes the dead node's key range from its replica (fresh
//! generations, normal `OP_INSERT` broadcasts, an `OP_EPOCH` marker to
//! purge leftovers). Reads and locked mutations that catch the dead
//! home park in `wait_entry_change` and resume against the new
//! location; keys whose *lock* is hosted on the corpse are read-only
//! (mutations return `Err(Error::PeerFailed)`). Without replication a
//! crash behaves as a delete of every key the dead node homed.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, Weak};
use std::time::Duration;

use crate::channels::read_cache::{CacheStats, FillToken, ReadCache};
use crate::channels::ringbuffer::{RingReceiver, RingSender};
use crate::channels::ticket_lock::TicketLock;
use crate::core::ack::AckKey;
use crate::core::ctx::{FenceScope, MemRef, ThreadCtx};
use crate::core::endpoint::{region_name, sub_name, Endpoint, Expect};
use crate::core::index::ShardedIndex;
use crate::core::manager::Manager;
use crate::fabric::{NodeId, Region};
use crate::util::{fnv64, Backoff};
use crate::workload::cityhash::city_hash64_u64;
use crate::{Error, Result};

pub use crate::core::index::IndexEntry;

/// Tracker message opcodes.
const OP_INSERT: u64 = 1;
const OP_DELETE: u64 = 2;
const OP_BATCH: u64 = 3;
/// Cache invalidation for in-place updates: `[OP_INVAL, n, key...]`.
const OP_INVAL: u64 = 4;
/// End-of-recovery marker from a dead node's backup: `[OP_EPOCH,
/// dead_node]`. Everything the backup could recover has been
/// re-broadcast (same ring, so FIFO-before this marker); receivers drop
/// any index entry still homed on the dead node — those keys' inserts
/// never completed (or were never known to the backup) and their data
/// died with the node.
const OP_EPOCH: u64 = 5;

/// Torn-read retries between index-entry re-fetches: a reader spinning
/// on a checksum mismatch re-validates its location after this many
/// rounds, so a concurrent slot reuse (its key deleted, the slot now
/// backing an update-heavy neighbour) cannot livelock it.
const TORN_REFETCH: u32 = 8;

#[derive(Clone, Debug)]
pub struct KvConfig {
    /// Value slots per node.
    pub slots_per_node: usize,
    /// Value width in words.
    pub value_words: usize,
    /// Ticket locks striped across nodes (`key % num_locks`).
    pub num_locks: usize,
    /// Tracker ring capacity in words.
    pub tracker_words: u64,
    /// Fence updates before lock release (§7.2; ablation knob).
    pub fence_updates: bool,
    /// Use the local-handover lock fast path.
    pub lock_handover: bool,
    /// Hot-key read-cache capacity in entries; 0 disables the locality
    /// tier's value cache. Requires `fence_updates`.
    ///
    /// Like every other field, this is part of the cluster-wide config
    /// contract ("all nodes must call with identical `cfg`") — and here
    /// a divergence is *silent*: a node configured with 0 never
    /// broadcasts `OP_INVAL` on its updates, so peers that do cache
    /// would serve the pre-update value indefinitely (in-place updates
    /// don't bump the generation counter). There is no cross-node
    /// config handshake; keep configs identical.
    pub read_cache_entries: usize,
    /// Replicate every slot frame to a **backup node** (`(home+1) mod
    /// n`) so a crash-stopped home's key range can be re-homed from the
    /// surviving replica instead of lost (see `docs/ARCHITECTURE.md`,
    /// § Failure model & recovery). Roughly doubles mutation write
    /// cost; requires `fence_updates` (the backup frame must be placed
    /// before a mutation returns) and at least two nodes. Without it a
    /// crash drops the dead node's keys from every index. Default off.
    pub replicate: bool,
}

impl Default for KvConfig {
    fn default() -> Self {
        KvConfig {
            slots_per_node: 4096,
            value_words: 1,
            num_locks: 256,
            tracker_words: 1 << 14,
            fence_updates: true,
            lock_handover: true,
            read_cache_entries: 0,
            replicate: false,
        }
    }
}

impl KvConfig {
    /// Enable the read cache sized for a Zipfian θ=0.99 workload over
    /// `keyspace` keys (see [`ReadCache::zipfian_capacity`]).
    pub fn with_zipfian_cache(mut self, keyspace: u64) -> Self {
        self.read_cache_entries = ReadCache::zipfian_capacity(keyspace);
        self
    }
}

/// State shared between application threads and the tracker thread.
struct KvShared {
    /// Sharded seqlock index: lock-free readers, per-shard writers.
    index: ShardedIndex,
    /// The locality tier's hot-key value cache (None = disabled).
    cache: Option<ReadCache>,
    free: Mutex<Vec<u32>>,
    /// Authoritative per-slot counters for *local* slots.
    slot_counter: Vec<AtomicU64>,
    tracker_ready: AtomicBool,
    shutdown: AtomicBool,
}

impl KvShared {
    fn invalidate(&self, key: u64) {
        if let Some(cache) = &self.cache {
            cache.invalidate(key);
        }
    }

    /// Drop every index entry homed on `dead` (invalidating each key's
    /// cached value): the shared purge step of crash recovery — used
    /// without replication (each node independently), by the backup's
    /// leftover sweep, and by the `OP_EPOCH` tracker handler.
    fn purge_homed_on(&self, dead: NodeId) {
        for (key, e) in self.index.entries_homed_on(dead) {
            self.invalidate(key);
            // Compare-and-remove: never clobber an entry that was
            // re-homed (or freshly re-inserted) between snapshot and
            // drop.
            self.index.remove_matching(key, &e);
        }
    }
}

pub struct KvStore {
    cfg: KvConfig,
    me: NodeId,
    num_nodes: usize,
    ep: Arc<Endpoint>,
    data: Region,
    /// The backup array this node HOSTS — replica frames for the slots
    /// of its predecessor `(me + n - 1) mod n` (replicate only).
    backup_hosted: Option<Region>,
    locks: Vec<TicketLock>,
    tracker_tx: Mutex<RingSender>,
    shared: Arc<KvShared>,
    tracker_thread: Mutex<Option<std::thread::JoinHandle<()>>>,
}

impl KvStore {
    /// Construct the kvstore endpoint on this node. All nodes must call
    /// with identical `name` and `cfg`.
    pub fn new(mgr: &Arc<Manager>, name: &str, cfg: KvConfig) -> Arc<KvStore> {
        let me = mgr.me();
        let n = mgr.num_nodes();
        let slot_words = cfg.value_words + 2;
        assert!(
            cfg.read_cache_entries == 0 || cfg.fence_updates,
            "the read cache requires fence_updates: an unfenced update could \
             be cached stale indefinitely"
        );

        assert!(!cfg.replicate || n > 1, "replicate requires at least two nodes");
        assert!(
            !cfg.replicate || cfg.fence_updates,
            "replicate requires fence_updates: backup frames must be placed \
             before a mutation returns, or recovery could resurrect stale values"
        );

        let ep = Endpoint::new(name, me, n, Expect::AllPeers);
        let data = mgr.pool().alloc_named(
            &region_name(name, "data"),
            cfg.slots_per_node * slot_words,
            false,
        );
        ep.add_local_region("data", data);
        // With replication on, every node also hosts the backup array
        // for its predecessor's slots (same geometry as `data`).
        let backup_hosted = cfg.replicate.then(|| {
            let r = mgr.pool().alloc_named(
                &region_name(name, "backup"),
                cfg.slots_per_node * slot_words,
                false,
            );
            ep.add_local_region("backup", r);
            r
        });
        if cfg.replicate {
            ep.expect_regions(&["data", "backup"]);
        } else {
            ep.expect_regions(&["data"]);
        }
        mgr.register_channel(ep.clone());

        // Lock array, striped across nodes.
        let locks: Vec<TicketLock> = (0..cfg.num_locks)
            .map(|i| {
                TicketLock::with_options(
                    mgr,
                    &sub_name(name, &format!("lock{i}")),
                    (i % n) as NodeId,
                    FenceScope::Thread,
                    true,
                    cfg.lock_handover,
                )
            })
            .collect();

        // Our tracker (we broadcast; peers receive).
        let tracker_tx = RingSender::new(mgr, &sub_name(name, &format!("trk{me}")), cfg.tracker_words);

        let shared = Arc::new(KvShared {
            index: ShardedIndex::new(cfg.slots_per_node * n),
            cache: (cfg.read_cache_entries > 0).then(|| ReadCache::new(cfg.read_cache_entries)),
            free: Mutex::new((0..cfg.slots_per_node as u32).rev().collect()),
            slot_counter: (0..cfg.slots_per_node).map(|_| AtomicU64::new(0)).collect(),
            tracker_ready: AtomicBool::new(false),
            shutdown: AtomicBool::new(false),
        });

        let kv = Arc::new(KvStore {
            cfg,
            me,
            num_nodes: n,
            ep,
            data,
            backup_hosted,
            locks,
            tracker_tx: Mutex::new(tracker_tx),
            shared: shared.clone(),
            tracker_thread: Mutex::new(None),
        });

        // Dedicated tracker thread (§6): receives peers' tracker rings,
        // applies index updates, then acknowledges. It holds only
        // KvShared and a Weak<KvStore> (upgraded transiently for crash
        // recovery) so Drop/shutdown can run.
        let mgr2 = mgr.clone();
        let name2 = name.to_string();
        let shared2 = shared;
        let weak = Arc::downgrade(&kv);
        let words = kv.cfg.tracker_words;
        let handle = std::thread::Builder::new()
            .name(format!("kv-tracker-{me}"))
            .spawn(move || tracker_loop(mgr2, name2, words, me, n, shared2, weak))
            .expect("spawn tracker");
        *kv.tracker_thread.lock().unwrap() = Some(handle);
        kv
    }

    pub fn wait_ready(&self, timeout: Duration) {
        self.ep.wait_ready(timeout);
        for l in &self.locks {
            l.wait_ready(timeout);
        }
        self.tracker_tx.lock().unwrap().wait_ready(timeout);
        let deadline = std::time::Instant::now() + timeout;
        while !self.shared.tracker_ready.load(Ordering::Acquire) {
            assert!(std::time::Instant::now() < deadline, "tracker thread not ready");
            std::thread::yield_now();
        }
    }

    pub fn config(&self) -> &KvConfig {
        &self.cfg
    }

    /// Home node a prefill partitioner should use for `key` (CityHash64
    /// placement, §7.2). Online inserts always go to the *inserting*
    /// node's data array, as in the paper.
    pub fn home_of(&self, key: u64) -> NodeId {
        (city_hash64_u64(key) % self.num_nodes as u64) as NodeId
    }

    fn slot_words(&self) -> usize {
        self.cfg.value_words + 2
    }

    fn slot_off(&self, slot: u32) -> u64 {
        slot as u64 * self.slot_words() as u64
    }

    fn data_region_of(&self, node: NodeId) -> Region {
        if node == self.me {
            self.data
        } else {
            self.ep.remote_region(node, "data")
        }
    }

    fn lock_of(&self, key: u64) -> &TicketLock {
        &self.locks[(key % self.cfg.num_locks as u64) as usize]
    }

    /// The node holding the backup replica of `node`'s slot array.
    fn backup_of(&self, node: NodeId) -> NodeId {
        ((node as usize + 1) % self.num_nodes) as NodeId
    }

    /// Backup region for slots homed on `node` (replicate only).
    fn backup_region_of(&self, node: NodeId) -> Region {
        let b = self.backup_of(node);
        if b == self.me {
            self.backup_hosted.expect("replicate enabled")
        } else {
            self.ep.remote_region(b, "backup")
        }
    }

    /// Write a full frame `[value][ck][cv]` into the backup replica of
    /// OUR slot `slot` and fence it placed. A dead backup node is
    /// tolerated (single-crash model: our backup only matters if *we*
    /// die next, and two simultaneous crashes are out of scope).
    fn write_backup_frame(&self, ctx: &ThreadCtx, slot: u32, value: &[u64], ck: u64, cv: u64) {
        let region = self.backup_region_of(self.me);
        let off = self.slot_off(slot);
        let mut frame = Vec::with_capacity(value.len() + 2);
        frame.extend_from_slice(value);
        frame.push(ck);
        frame.push(cv);
        ctx.write(region, off, &frame);
        let _ = ctx.try_fence(FenceScope::Pair(self.backup_of(self.me)));
    }

    /// Block until the index entry for `key` moves away from `old` —
    /// the signature of a crash re-home (new home node) or a recovery
    /// drop (`None`). Callers park here when they catch `old.node`
    /// crash-stopped; the membership machinery guarantees the entry
    /// changes within the recovery pass. `Err` only if *this* node is
    /// the corpse (nobody re-homes for the dead).
    fn wait_entry_change(
        &self,
        ctx: &ThreadCtx,
        key: u64,
        old: &IndexEntry,
    ) -> crate::Result<Option<IndexEntry>> {
        let mut bo = Backoff::new();
        let deadline = std::time::Instant::now() + Duration::from_secs(30);
        loop {
            let cur = self.shared.index.get(key);
            if cur != Some(*old) {
                return Ok(cur);
            }
            if ctx.node_down(self.me) {
                return Err(crate::Error::PeerFailed(
                    "local node crash-stopped mid-operation".into(),
                ));
            }
            assert!(
                std::time::Instant::now() < deadline,
                "key {key}: home node {} crashed and no re-home/purge arrived \
                 within 30 s (replicate={})",
                old.node,
                self.cfg.replicate
            );
            bo.snooze();
        }
    }

    /// The cache serves only *remote-homed* slots: local reads are
    /// already a couple of loads, and skipping them keeps the whole
    /// capacity for keys that actually cost a network round trip.
    #[inline]
    fn cache_for(&self, e: &IndexEntry) -> Option<&ReadCache> {
        self.shared.cache.as_ref().filter(|_| e.node != self.me)
    }

    // ---- operations -------------------------------------------------

    /// Insert (or update-in-place if present). Returns Ok(true) if a new
    /// key was inserted. `Err(Error::PeerFailed)` when the key's lock is
    /// hosted on a crash-stopped node (the mutation did not happen; see
    /// the failure model in `docs/ARCHITECTURE.md`).
    pub fn insert(&self, ctx: &ThreadCtx, key: u64, value: &[u64]) -> Result<bool> {
        assert_eq!(value.len(), self.cfg.value_words);
        let lock = self.lock_of(key);
        lock.try_lock(ctx)?;
        let res = self.insert_locked(ctx, key, value);
        lock.unlock(ctx);
        res
    }

    fn insert_locked(&self, ctx: &ThreadCtx, key: u64, value: &[u64]) -> Result<bool> {
        loop {
            if let Some(e) = self.shared.index.get(key) {
                if self.locked_update(ctx, key, e, value)? {
                    return Ok(false);
                }
                // The key vanished while its dead home was recovered:
                // re-resolve — this is now a fresh insert.
                continue;
            }
            let Some(slot) = self.shared.free.lock().unwrap().pop() else {
                return Err(Error::Capacity(format!("node {} out of kv slots", self.me)));
            };
            let counter =
                self.shared.slot_counter[slot as usize].fetch_add(1, Ordering::Relaxed) + 1;
            // Local write: value, checksum, counter with valid UNSET.
            let off = self.slot_off(slot);
            let ck = fnv64(value);
            for (i, w) in value.iter().enumerate() {
                ctx.local_store(self.data, off + i as u64, *w);
            }
            ctx.local_store(self.data, off + value.len() as u64, ck);
            ctx.local_store(self.data, off + value.len() as u64 + 1, counter << 1);
            // Backup replica before the broadcast, already valid: if we
            // crash before returning, recovery resurrecting a
            // never-linearized insert is harmless (no reader could have
            // relied on EMPTY — the insert never responded), while the
            // reverse order could lose an insert that *did* respond.
            if self.cfg.replicate {
                self.write_backup_frame(ctx, slot, value, ck, (counter << 1) | 1);
            }

            // Our own index first, then broadcast to peers and await acks.
            self.shared.index.insert(key, IndexEntry { node: self.me, slot, counter });
            {
                let tx = self.tracker_tx.lock().unwrap();
                tx.send(ctx, &[OP_INSERT, key, self.me as u64, slot as u64, counter]);
                let pos = tx.position();
                tx.wait_all_acked(ctx, pos);
            }
            // All indices now hold the location: set valid (linearization pt).
            ctx.local_store(self.data, off + value.len() as u64 + 1, (counter << 1) | 1);
            return Ok(true);
        }
    }

    /// Update an existing key in place. Returns false if absent. Panics
    /// on an unrecoverable peer failure — use [`KvStore::try_update`]
    /// when running with fault injection.
    pub fn update(&self, ctx: &ThreadCtx, key: u64, value: &[u64]) -> bool {
        self.try_update(ctx, key, value).expect("kv update: unrecoverable peer failure")
    }

    /// Crash-stop-aware update: `Ok(false)` if the key is absent (or was
    /// dropped by crash recovery), `Err(Error::PeerFailed)` if the key's
    /// lock is hosted on a dead node (the mutation did not happen). A
    /// home node dying *mid-update* is handled internally: the op waits
    /// for the membership epoch's re-home and retries against the new
    /// location, so an `Ok(true)` always means the value is durable on
    /// the current home.
    pub fn try_update(&self, ctx: &ThreadCtx, key: u64, value: &[u64]) -> Result<bool> {
        assert_eq!(value.len(), self.cfg.value_words);
        let lock = self.lock_of(key);
        lock.try_lock(ctx)?;
        let res = match self.shared.index.get(key) {
            None => Ok(false),
            Some(e) => self.locked_update(ctx, key, e, value),
        };
        lock.unlock(ctx);
        res
    }

    /// The locked mutate-in-place path shared by update and
    /// insert-over-existing, with the crash-recovery retry loop: a home
    /// that crash-stops before the write is placed gets re-resolved via
    /// [`KvStore::wait_entry_change`] and the write retried against the
    /// new location. Returns whether the value was applied (false: the
    /// key vanished — deleted by recovery or a racing delete).
    fn locked_update(
        &self,
        ctx: &ThreadCtx,
        key: u64,
        mut e: IndexEntry,
        value: &[u64],
    ) -> Result<bool> {
        loop {
            if ctx.node_down(e.node) {
                match self.wait_entry_change(ctx, key, &e)? {
                    Some(ne) => {
                        e = ne;
                        continue;
                    }
                    None => return Ok(false),
                }
            }
            match self.write_value(ctx, &e, value) {
                Ok(()) => break,
                Err(err) => {
                    if ctx.node_down(self.me) {
                        // WE died mid-write: nobody re-homes for us, so
                        // retrying would spin forever. Surface it.
                        return Err(err);
                    }
                    // Home died mid-write: loop re-checks, re-resolves.
                }
            }
        }
        self.invalidate_updated(ctx, &[key]);
        Ok(true)
    }

    /// The locked write path shared by update and insert-over-existing:
    /// write `[value][checksum]` (mirrored to the backup replica when
    /// replication is on), then fence so the write is placed before the
    /// lock release (§7.2). `Err` iff the home node crash-stopped before
    /// placement was proven — the caller re-resolves and retries; a dead
    /// *backup* is tolerated (single-crash model).
    fn write_value(&self, ctx: &ThreadCtx, e: &IndexEntry, value: &[u64]) -> Result<()> {
        let region = self.data_region_of(e.node);
        let off = self.slot_off(e.slot);
        let mut buf = Vec::with_capacity(value.len() + 1);
        buf.extend_from_slice(value);
        buf.push(fnv64(value));
        ctx.write(region, off, &buf); // completion tracked by the fence
        if self.cfg.replicate {
            // Mirror [value][ck]; the cv word is untouched (in-place
            // updates do not change the generation).
            ctx.write(self.backup_region_of(e.node), off, &buf);
        }
        if self.cfg.fence_updates {
            let scope = if self.cfg.replicate {
                FenceScope::Thread // covers home and backup peers alike
            } else {
                FenceScope::Pair(e.node)
            };
            if ctx.try_fence(scope).is_err() {
                if ctx.node_down(self.me) {
                    // WE crash-stopped: the write was never transmitted;
                    // reporting success would violate the durability
                    // contract of Ok.
                    return Err(Error::PeerFailed("local node crashed mid-update".into()));
                }
                if ctx.node_down(e.node) {
                    return Err(Error::PeerFailed(format!(
                        "home node {} crashed mid-update",
                        e.node
                    )));
                }
                // Only a dead *backup* remains: tolerated (single-crash
                // model) — the home's flush still completed.
            }
        }
        Ok(())
    }

    /// Post-update cache invalidation (locality tier). In-place updates
    /// don't bump the slot counter, so with the cache enabled they must
    /// purge every node's cached copy before returning: our own cache
    /// directly, peers via an `OP_INVAL` tracker broadcast that is
    /// applied *before* it is acknowledged. Callers hold the key lock(s)
    /// and have already placed (fenced) the value write.
    fn invalidate_updated(&self, ctx: &ThreadCtx, keys: &[u64]) {
        let Some(cache) = &self.shared.cache else { return };
        if keys.is_empty() {
            return;
        }
        cache.invalidate_many(keys.iter().copied());
        // Chunked like prefill's OP_BATCH frames: one huge multi_put must
        // not overflow the tracker ring's message capacity.
        const CHUNK: usize = 128;
        let tx = self.tracker_tx.lock().unwrap();
        for chunk in keys.chunks(CHUNK) {
            let mut msg = Vec::with_capacity(2 + chunk.len());
            msg.push(OP_INVAL);
            msg.push(chunk.len() as u64);
            msg.extend_from_slice(chunk);
            tx.send(ctx, &msg);
            let pos = tx.position();
            tx.wait_all_acked(ctx, pos);
        }
    }

    /// Lock-free lookup (Appendix C's read protocol), served from the
    /// hot-key cache when the locality tier holds a current-generation
    /// copy.
    pub fn get(&self, ctx: &ThreadCtx, key: u64) -> Option<Vec<u64>> {
        let e = self.shared.index.get(key)?;
        if let Some(cache) = self.cache_for(&e) {
            if let Some(v) = cache.lookup(key, e.counter) {
                return Some(v);
            }
        }
        self.get_remote(ctx, key, e)
    }

    /// The remote leg of `get`: read the slot, validate
    /// (checksum/counter/valid, Appendix C), fill the cache on success.
    /// The torn-read spin is bounded by [`TORN_REFETCH`]-round index
    /// re-fetches.
    fn get_remote(&self, ctx: &ThreadCtx, key: u64, mut e: IndexEntry) -> Option<Vec<u64>> {
        let mut bo = Backoff::new();
        let mut torn_rounds = 0u32;
        loop {
            if ctx.node_down(e.node) {
                // Home crash-stopped: park until recovery re-homes the
                // key (serve the new location) or drops it (EMPTY).
                match self.wait_entry_change(ctx, key, &e) {
                    Ok(Some(ne)) => {
                        e = ne;
                        continue;
                    }
                    Ok(None) => return None,
                    Err(_) => return None, // we are the corpse ourselves
                }
            }
            // Fill-token before the READ: a concurrent invalidation
            // between here and the fill rejects the fill.
            let token = self.cache_for(&e).map(|c| c.begin_fill(key));
            let region = self.data_region_of(e.node);
            let words = match ctx.try_read(region, self.slot_off(e.slot), self.slot_words()) {
                Ok(w) => w,
                Err(_) => {
                    // A read error with a live home means *we* are the
                    // crashed node (our posts all fail): bail rather
                    // than spin — a corpse's results no longer matter.
                    if ctx.node_down(self.me) {
                        return None;
                    }
                    continue; // home's crash raced the read: handled above
                }
            };
            let (value, rest) = words.split_at(self.cfg.value_words);
            let (ck, cv) = (rest[0], rest[1]);
            if fnv64(value) == ck {
                if cv >> 1 != e.counter {
                    return None; // stale index: linearizes after the delete
                }
                if cv & 1 == 0 {
                    return None; // insert not yet / delete already linearized
                }
                if let (Some(cache), Some(token)) = (self.cache_for(&e), token) {
                    cache.fill(token, key, e.counter, value);
                }
                return Some(value.to_vec());
            }
            // Torn update in flight: retry in its entirety. Re-fetch the
            // entry periodically — if our slot was reused for another
            // (update-heavy) key, spinning on the old location would
            // never terminate.
            torn_rounds += 1;
            if torn_rounds % TORN_REFETCH == 0 {
                e = self.shared.index.get(key)?;
            }
            bo.snooze();
        }
    }

    /// Delete. Returns false if absent. Panics on an unrecoverable peer
    /// failure — use [`KvStore::try_remove`] under fault injection.
    pub fn remove(&self, ctx: &ThreadCtx, key: u64) -> bool {
        self.try_remove(ctx, key).expect("kv remove: unrecoverable peer failure")
    }

    /// Crash-stop-aware delete: `Err(Error::PeerFailed)` iff the key's
    /// lock is hosted on a dead node (nothing happened). A home dying
    /// mid-delete is re-resolved and retried, like
    /// [`KvStore::try_update`].
    pub fn try_remove(&self, ctx: &ThreadCtx, key: u64) -> Result<bool> {
        let lock = self.lock_of(key);
        lock.try_lock(ctx)?;
        let res = self.remove_locked(ctx, key);
        lock.unlock(ctx);
        res
    }

    fn remove_locked(&self, ctx: &ThreadCtx, key: u64) -> Result<bool> {
        let Some(mut e) = self.shared.index.get(key) else {
            return Ok(false);
        };
        loop {
            if ctx.node_down(e.node) {
                match self.wait_entry_change(ctx, key, &e)? {
                    Some(ne) => {
                        e = ne;
                        continue;
                    }
                    // Recovery already dropped it: the crash deleted the
                    // key before we could.
                    None => return Ok(false),
                }
            }
            // Unset the valid bit (the delete's linearization point) —
            // and its backup mirror FIRST, so a crash of the home right
            // here cannot re-home a key whose delete is about to be
            // broadcast (recovery validates against the backup frame).
            let region = self.data_region_of(e.node);
            let cv_off = self.slot_off(e.slot) + self.cfg.value_words as u64 + 1;
            if self.cfg.replicate {
                ctx.write1(self.backup_region_of(e.node), cv_off, e.counter << 1);
            }
            ctx.write1(region, cv_off, e.counter << 1);
            let scope = if self.cfg.replicate {
                FenceScope::Thread
            } else {
                FenceScope::Pair(e.node)
            };
            if ctx.try_fence(scope).is_err() {
                if ctx.node_down(self.me) {
                    return Err(Error::PeerFailed("local node crashed mid-delete".into()));
                }
                if ctx.node_down(e.node) {
                    continue; // home died mid-delete: re-resolve the location
                }
                // Dead backup only: tolerated, the home's unset placed.
            }
            break;
        }
        // Broadcast; peers invalidate their cache + drop their index
        // entries (the home peer also frees the slot); then drop ours.
        {
            let tx = self.tracker_tx.lock().unwrap();
            tx.send(ctx, &[OP_DELETE, key, e.node as u64, e.slot as u64, e.counter]);
            let pos = tx.position();
            tx.wait_all_acked(ctx, pos);
        }
        self.shared.invalidate(key);
        self.shared.index.remove(key);
        if e.node == self.me {
            self.shared.free.lock().unwrap().push(e.slot);
        }
        Ok(true)
    }

    // ---- batched operations (doorbell-batched pipeline) ---------------

    /// Batched lock-free lookup: cache hits are peeled off locally, the
    /// remaining key set is issued through the doorbell-batched pipeline
    /// — slot reads grouped into **one post list per home node** (instead
    /// of one doorbell per key), ack tracking amortized batch-wide, and a
    /// single wait for the batch. Each result validates exactly like
    /// [`KvStore::get`] (checksum/counter/valid, Appendix C); keys whose
    /// reads raced an in-flight update are collected and retried together
    /// as one `read_many` batch (not one scalar round trip each).
    ///
    /// `out[i]` corresponds to `keys[i]`. Duplicate keys are permitted.
    pub fn multi_get(&self, ctx: &ThreadCtx, keys: &[u64]) -> Vec<Option<Vec<u64>>> {
        let mut out: Vec<Option<Vec<u64>>> = Vec::with_capacity(keys.len());
        let mut entries: Vec<Option<IndexEntry>> = Vec::with_capacity(keys.len());
        // Indices still needing a remote read.
        let mut pending: Vec<usize> = Vec::new();
        for (i, &k) in keys.iter().enumerate() {
            let e = self.shared.index.get(k);
            let hit =
                e.and_then(|e| self.cache_for(&e).and_then(|c| c.lookup(k, e.counter)));
            if hit.is_none() && e.is_some() {
                pending.push(i);
            }
            out.push(hit);
            entries.push(e);
        }

        let mut bo = Backoff::new();
        let mut torn_rounds = 0u32;
        while !pending.is_empty() {
            // Fill-tokens before the batched READs are issued.
            let tokens: Vec<Option<FillToken>> = pending
                .iter()
                .map(|&i| {
                    let e = entries[i].unwrap();
                    self.cache_for(&e).map(|c| c.begin_fill(keys[i]))
                })
                .collect();
            let reqs: Vec<(Region, u64, usize)> = pending
                .iter()
                .map(|&i| {
                    let e = entries[i].unwrap();
                    (self.data_region_of(e.node), self.slot_off(e.slot), self.slot_words())
                })
                .collect();
            // read_many waits once for the whole batch and resets the
            // involved peers' unfenced counters (completed READs prove
            // placement on those QPs), exactly like the scalar get path.
            let raws = ctx.read_many(&reqs);
            let mut torn: Vec<usize> = Vec::new();
            for (j, &i) in pending.iter().enumerate() {
                let e = entries[i].unwrap();
                let words = &raws[j];
                let (value, rest) = words.split_at(self.cfg.value_words);
                let (ck, cv) = (rest[0], rest[1]);
                if fnv64(value) != ck {
                    torn.push(i); // retried as one batch next round
                    continue;
                }
                if cv >> 1 == e.counter && cv & 1 == 1 {
                    if let (Some(cache), Some(token)) = (self.cache_for(&e), tokens[j]) {
                        cache.fill(token, keys[i], e.counter, value);
                    }
                    out[i] = Some(value.to_vec());
                }
                // else: stale index / not linearized — stays None.
            }
            if torn.is_empty() {
                break;
            }
            // Same bounded spin as the scalar path, for the whole batch.
            torn_rounds += 1;
            if torn_rounds % TORN_REFETCH == 0 {
                torn.retain(|&i| match self.shared.index.get(keys[i]) {
                    Some(e) => {
                        entries[i] = Some(e);
                        true
                    }
                    None => false, // key vanished: result stays None
                });
            }
            bo.snooze();
            pending = torn;
        }
        out
    }

    /// Batched in-place update of existing keys: acquires the
    /// (deduplicated) key locks in ascending index order — so concurrent
    /// `multi_put`s cannot deadlock — issues every value write through
    /// the batched pipeline (one doorbell per home node), runs **one**
    /// fence covering the whole batch before the first release (§7.2's
    /// per-update fence, amortized), then broadcasts **one** cache
    /// invalidation for the touched keys and unlocks. Keys not present
    /// are skipped, exactly like [`KvStore::update`]. Returns how many
    /// keys were updated.
    ///
    /// **Not crash-hardened**: unlike the scalar mutations, this batch
    /// path takes the infallible locks and does not re-resolve homes
    /// that die mid-batch — under fault injection with crash-stop, use
    /// the scalar [`KvStore::try_update`] per key instead (the chaos
    /// tier does). Frames are still mirrored to their backups when
    /// replication is on, so a *later* crash recovers multi_put values
    /// correctly.
    pub fn multi_put(&self, ctx: &ThreadCtx, items: &[(u64, Vec<u64>)]) -> usize {
        for (_, value) in items {
            assert_eq!(value.len(), self.cfg.value_words);
        }
        let mut lock_ids: Vec<usize> =
            items.iter().map(|(k, _)| (*k % self.cfg.num_locks as u64) as usize).collect();
        lock_ids.sort_unstable();
        lock_ids.dedup();
        for &l in &lock_ids {
            self.locks[l].lock(ctx);
        }

        let entries: Vec<Option<IndexEntry>> =
            items.iter().map(|(k, _)| self.shared.index.get(*k)).collect();
        // Build [value][checksum] frames, then one batched write issue
        // (each frame mirrored to its backup replica when replication is
        // on — same batch, same fence).
        let mut bufs: Vec<Vec<u64>> = Vec::new();
        let mut targets: Vec<(Region, u64, usize)> = Vec::new();
        let mut touched: Vec<u64> = Vec::new();
        let mut updated = 0usize;
        for (e, (k, value)) in entries.iter().zip(items) {
            if let Some(e) = e {
                let mut buf = Vec::with_capacity(value.len() + 1);
                buf.extend_from_slice(value);
                buf.push(fnv64(value));
                let idx = bufs.len();
                bufs.push(buf);
                let off = self.slot_off(e.slot);
                targets.push((self.data_region_of(e.node), off, idx));
                if self.cfg.replicate {
                    targets.push((self.backup_region_of(e.node), off, idx));
                }
                touched.push(*k);
                updated += 1;
            }
        }
        let writes: Vec<(Region, u64, &[u64])> = targets
            .iter()
            .map(|&(region, off, i)| (region, off, bufs[i].as_slice()))
            .collect();
        let _key = ctx.write_many(&writes); // completion tracked by the fence
        if self.cfg.fence_updates && !writes.is_empty() {
            ctx.fence(FenceScope::Thread); // one fence for the whole batch
        }
        touched.sort_unstable();
        touched.dedup(); // duplicate keys in one batch need one invalidation
        self.invalidate_updated(ctx, &touched);
        for &l in lock_ids.iter().rev() {
            self.locks[l].unlock(ctx);
        }
        updated
    }

    // ---- windowed (asynchronous) reads --------------------------------

    /// Issue a lookup without waiting: returns the in-flight read (or an
    /// already-resolved cache hit). Used by the window-size experiments
    /// (§7.2): up to `window` of these may be outstanding per thread.
    pub fn get_issue(&self, ctx: &ThreadCtx, key: u64) -> Option<PendingGet> {
        let e = self.shared.index.get(key)?;
        if let Some(cache) = self.cache_for(&e) {
            if let Some(v) = cache.lookup(key, e.counter) {
                return Some(PendingGet { key, entry: e, state: PendingState::Cached(v) });
            }
        }
        let token = self.cache_for(&e).map(|c| c.begin_fill(key));
        let region = self.data_region_of(e.node);
        let (ack, buf) = ctx.read_async(region, self.slot_off(e.slot), self.slot_words());
        Some(PendingGet { key, entry: e, state: PendingState::InFlight { ack, buf, token } })
    }

    /// Complete an issued lookup (waits if necessary; falls back to the
    /// blocking path on torn reads).
    pub fn get_complete(&self, ctx: &ThreadCtx, pg: PendingGet) -> Option<Vec<u64>> {
        let (ack, buf, token) = match pg.state {
            PendingState::Cached(v) => return Some(v),
            PendingState::InFlight { ack, buf, token } => (ack, buf, token),
        };
        ack.wait();
        if ack.failed() {
            // The home crash-stopped under the windowed read: the buffer
            // was never written. Restart through the blocking path,
            // which waits out the re-home.
            return self.get(ctx, pg.key);
        }
        let words = buf.to_vec();
        let (value, rest) = words.split_at(self.cfg.value_words);
        let (ck, cv) = (rest[0], rest[1]);
        if fnv64(value) != ck {
            return self.get(ctx, pg.key); // torn: retry in its entirety
        }
        if cv >> 1 != pg.entry.counter || cv & 1 == 0 {
            return None;
        }
        if let (Some(cache), Some(token)) = (self.cache_for(&pg.entry), token) {
            cache.fill(token, pg.key, pg.entry.counter, value);
        }
        Some(value.to_vec())
    }

    // ---- bulk prefill --------------------------------------------------

    /// Bulk-load `keys` into *this* node's data array, broadcasting index
    /// updates in batches. `checksums`, if given, must be the per-key
    /// checksum of each value (e.g. produced by the AOT Pallas checksum
    /// kernel via [`crate::runtime`]); otherwise they are computed here.
    pub fn prefill_local(
        &self,
        ctx: &ThreadCtx,
        keys: &[u64],
        mut value_of: impl FnMut(u64) -> Vec<u64>,
        checksums: Option<&[u64]>,
    ) -> Result<()> {
        const BATCH: usize = 128;
        for (chunk_idx, chunk) in keys.chunks(BATCH).enumerate() {
            let mut msg = Vec::with_capacity(3 + chunk.len() * 3);
            msg.push(OP_BATCH);
            msg.push(self.me as u64);
            msg.push(chunk.len() as u64);
            {
                let mut free = self.shared.free.lock().unwrap();
                for (i, &key) in chunk.iter().enumerate() {
                    let Some(slot) = free.pop() else {
                        return Err(Error::Capacity(format!("node {} out of kv slots", self.me)));
                    };
                    let counter =
                        self.shared.slot_counter[slot as usize].fetch_add(1, Ordering::Relaxed) + 1;
                    let value = value_of(key);
                    assert_eq!(value.len(), self.cfg.value_words);
                    let ck = match checksums {
                        Some(cks) => cks[chunk_idx * BATCH + i],
                        None => fnv64(&value),
                    };
                    let off = self.slot_off(slot);
                    for (j, w) in value.iter().enumerate() {
                        ctx.local_store(self.data, off + j as u64, *w);
                    }
                    ctx.local_store(self.data, off + value.len() as u64, ck);
                    ctx.local_store(self.data, off + value.len() as u64 + 1, (counter << 1) | 1);
                    if self.cfg.replicate {
                        self.write_backup_frame(ctx, slot, &value, ck, (counter << 1) | 1);
                    }
                    self.shared.index.insert(key, IndexEntry { node: self.me, slot, counter });
                    msg.extend_from_slice(&[key, slot as u64, counter]);
                }
            }
            let tx = self.tracker_tx.lock().unwrap();
            tx.send(ctx, &msg);
            let pos = tx.position();
            tx.wait_all_acked(ctx, pos);
        }
        Ok(())
    }

    /// Local index size (for tests).
    pub fn index_len(&self) -> usize {
        self.shared.index.len()
    }

    pub fn index_entry(&self, key: u64) -> Option<IndexEntry> {
        self.shared.index.get(key)
    }

    /// Read-cache counters (all-zero when the cache is disabled).
    pub fn cache_stats(&self) -> CacheStats {
        self.shared.cache.as_ref().map(|c| c.stats()).unwrap_or_default()
    }

    pub fn shutdown(&self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        if let Some(h) = self.tracker_thread.lock().unwrap().take() {
            if h.thread().id() == std::thread::current().id() {
                // We ARE the tracker thread: the last external Arc was
                // dropped while recovery held a transient Weak-upgrade,
                // so Drop is running on the tracker itself. Joining
                // ourselves would deadlock forever — detach instead;
                // the loop observes the shutdown flag and exits.
                return;
            }
            let _ = h.join();
        }
    }

    // ---- crash recovery (membership epoch) ----------------------------

    /// Crash recovery, called from the tracker thread once per newly
    /// dead node. Per-node ordering: drop the hot-key cache (entries
    /// cached under the dead epoch must not serve into the new one),
    /// then either **re-home** the dead node's key range from our
    /// backup replica (if we are its backup and replication is on) or —
    /// without replication — **purge** its entries everywhere (the data
    /// died with the node). Non-backup nodes with replication on keep
    /// their stale entries and learn the new homes from the backup's
    /// re-home broadcasts; reads and locked mutations on those keys
    /// park in [`KvStore::wait_entry_change`] until exactly that signal.
    pub(crate) fn on_peer_dead(&self, ctx: &ThreadCtx, dead: NodeId) {
        if dead == self.me {
            return; // we are the corpse; our view no longer matters
        }
        if let Some(cache) = &self.shared.cache {
            cache.clear();
        }
        if !self.cfg.replicate {
            self.shared.purge_homed_on(dead);
            return;
        }
        if self.backup_of(dead) == self.me {
            self.rehome_from_backup(ctx, dead);
        }
    }

    /// Re-home the crash-stopped `dead` node's key range: our index (a
    /// replica of the locations, built from the tracker broadcasts that
    /// announced them) names every key homed there; our hosted backup
    /// array holds the surviving replica of the frames. Each key whose
    /// backup frame validates is re-inserted under a fresh local
    /// generation and announced with a normal `OP_INSERT`; frames that
    /// do not validate (the insert never completed, or a delete's
    /// backup-unset landed first) are dropped with an `OP_DELETE`. One
    /// ack-wait covers the whole batch — when this returns, every
    /// surviving index agrees on the new homes.
    fn rehome_from_backup(&self, ctx: &ThreadCtx, dead: NodeId) {
        let backup = self.backup_hosted.expect("replicate enabled on the backup node");
        let entries = self.shared.index.entries_homed_on(dead);
        let mut rehomed = 0u64;
        let mut dropped = 0u64;
        for (key, e) in entries {
            match self.read_backup_frame(ctx, backup, &e) {
                Some(value) => {
                    if self.reinsert_recovered(ctx, key, &value) {
                        rehomed += 1;
                    } else {
                        self.announce_drop(ctx, key, &e);
                        dropped += 1;
                    }
                }
                None => {
                    self.announce_drop(ctx, key, &e);
                    dropped += 1;
                }
            }
        }
        {
            // End-of-recovery marker: FIFO-after every re-home broadcast
            // above, so a receiver that has applied it has the complete
            // recovered range and may drop any leftover dead-homed
            // entries. One ack-wait covers the whole batch.
            let tx = self.tracker_tx.lock().unwrap();
            tx.send(ctx, &[OP_EPOCH, dead as u64]);
            let pos = tx.position();
            tx.wait_all_acked(ctx, pos);
        }
        // Our own leftover check (peers get it from OP_EPOCH).
        self.shared.purge_homed_on(dead);
        if rehomed + dropped > 0 {
            eprintln!(
                "loco-kv[{}]: re-homed node {dead}'s range: {rehomed} recovered, {dropped} dropped",
                self.me
            );
        }
    }

    /// Read and validate our backup replica of `e` (a slot frame homed
    /// on the dead node). Plain local loads with a bounded
    /// checksum-retry: an update's mirror write that raced the crash may
    /// still be mid-placement, but placements are transient — a frame
    /// that validates with the wrong generation (or the valid bit clear)
    /// is a *stable* negative, because deletes fence their backup unset
    /// before broadcasting.
    fn read_backup_frame(&self, ctx: &ThreadCtx, backup: Region, e: &IndexEntry) -> Option<Vec<u64>> {
        let off = self.slot_off(e.slot);
        let words = self.slot_words();
        let mut bo = Backoff::new();
        for _ in 0..4096 {
            let mut frame = vec![0u64; words];
            for (i, f) in frame.iter_mut().enumerate() {
                *f = ctx.local_load(backup, off + i as u64);
            }
            let (value, rest) = frame.split_at(self.cfg.value_words);
            let (ck, cv) = (rest[0], rest[1]);
            if fnv64(value) == ck {
                if cv >> 1 == e.counter && cv & 1 == 1 {
                    return Some(value.to_vec());
                }
                return None; // consistent frame, wrong generation / invalid
            }
            bo.snooze(); // torn mirror placement in flight: retry
        }
        None
    }

    /// Promote a recovered frame into a fresh local slot + generation,
    /// mirror it to OUR backup, update our index, and broadcast the new
    /// location. No key lock is taken: mutators of this key are parked
    /// in `wait_entry_change` (their home is down) and proceed against
    /// the new location once the broadcast lands. Returns false if this
    /// node is out of slots (the key is then dropped instead).
    fn reinsert_recovered(&self, ctx: &ThreadCtx, key: u64, value: &[u64]) -> bool {
        let Some(slot) = self.shared.free.lock().unwrap().pop() else {
            return false;
        };
        let counter = self.shared.slot_counter[slot as usize].fetch_add(1, Ordering::Relaxed) + 1;
        let off = self.slot_off(slot);
        let ck = fnv64(value);
        for (i, w) in value.iter().enumerate() {
            ctx.local_store(self.data, off + i as u64, *w);
        }
        ctx.local_store(self.data, off + value.len() as u64, ck);
        ctx.local_store(self.data, off + value.len() as u64 + 1, (counter << 1) | 1);
        self.write_backup_frame(ctx, slot, value, ck, (counter << 1) | 1);
        self.shared.index.insert(key, IndexEntry { node: self.me, slot, counter });
        let tx = self.tracker_tx.lock().unwrap();
        tx.send(ctx, &[OP_INSERT, key, self.me as u64, slot as u64, counter]);
        true
    }

    /// Recovery-side drop of a key whose frame did not survive: remove
    /// it locally (compare-and-remove — a racing fresh re-insert wins)
    /// and broadcast the delete, which peers likewise apply only against
    /// the exact dead entry. Nobody frees a slot — the home is dead.
    fn announce_drop(&self, ctx: &ThreadCtx, key: u64, e: &IndexEntry) {
        self.shared.invalidate(key);
        self.shared.index.remove_matching(key, e);
        let tx = self.tracker_tx.lock().unwrap();
        tx.send(ctx, &[OP_DELETE, key, e.node as u64, e.slot as u64, e.counter]);
    }
}

impl Drop for KvStore {
    fn drop(&mut self) {
        self.shutdown();
    }
}

// ---- tracker thread (free-standing: must not keep KvStore alive) ------

fn tracker_loop(
    mgr: Arc<Manager>,
    name: String,
    tracker_words: u64,
    me: NodeId,
    num_nodes: usize,
    shared: Arc<KvShared>,
    kv: Weak<KvStore>,
) {
    let ctx = mgr.ctx();
    // Receive every peer's tracker ring.
    let mut rxs: Vec<(NodeId, RingReceiver)> = (0..num_nodes as NodeId)
        .filter(|&p| p != me)
        .map(|p| {
            let mut rx = RingReceiver::new(&mgr, &sub_name(&name, &format!("trk{p}")), tracker_words);
            rx.set_manual_ack();
            (p, rx)
        })
        .collect();
    for (_, rx) in &rxs {
        rx.wait_ready(Duration::from_secs(30));
    }
    shared.tracker_ready.store(true, Ordering::Release);

    let mut known_dead: u64 = 0;
    let mut bo = Backoff::new();
    loop {
        let mut did = false;
        // Drain FIRST, then react to deaths: a dead node's final
        // broadcasts that already reached our ring are applied with the
        // pre-death mask, so the recovery scan below sees them; anything
        // arriving later is rejected by apply_tracker's dead-home guard.
        for (from, rx) in &mut rxs {
            while let Some(msg) = rx.try_recv(&ctx) {
                apply_tracker(&shared, me, *from, &msg, known_dead);
                rx.ack_now(&ctx); // apply THEN acknowledge (§6)
                did = true;
            }
        }
        // Crash recovery: the manager's polling thread mirrors the
        // fabric's down mask into Membership; we react here, once per
        // newly dead node, on the thread that owns index application.
        let dead_mask = mgr.membership().dead_mask();
        if dead_mask != known_dead {
            for node in 0..num_nodes as NodeId {
                if dead_mask >> node & 1 == 1 && known_dead >> node & 1 == 0 {
                    if let Some(kv) = kv.upgrade() {
                        kv.on_peer_dead(&ctx, node);
                    }
                }
            }
            known_dead = dead_mask;
            did = true;
        }
        if !did {
            if shared.shutdown.load(Ordering::Relaxed) {
                break;
            }
            bo.snooze();
        } else {
            bo.reset();
        }
    }
}

fn apply_tracker(shared: &KvShared, me: NodeId, from: NodeId, msg: &[u64], dead_mask: u64) {
    // A location broadcast whose home we already know to be dead must
    // not land: it would point the index at a corpse *after* recovery
    // re-homed (or purged) that range, wedging readers forever. It can
    // only be a crashed node's final broadcast racing its own death —
    // the insert it announces never completed.
    let home_is_dead = |node: NodeId| dead_mask >> node & 1 == 1;
    match msg[0] {
        OP_INSERT => {
            let (key, node, slot, counter) = (msg[1], msg[2] as NodeId, msg[3] as u32, msg[4]);
            debug_assert_eq!(node, from);
            if home_is_dead(node) {
                return;
            }
            // The new generation can't be served from a stale cached
            // copy (counter mismatch), but purging keeps dead entries
            // from squatting on cache capacity.
            shared.invalidate(key);
            shared.index.insert(key, IndexEntry { node, slot, counter });
        }
        OP_DELETE => {
            let (key, node, slot, counter) = (msg[1], msg[2] as NodeId, msg[3] as u32, msg[4]);
            shared.invalidate(key);
            // Compare-and-remove: a recovery drop racing a fresh
            // re-insert of the same key (new home, new generation) must
            // lose — only the exact announced entry is deleted. Normal
            // deletes always match (the deleter holds the key's lock).
            let removed = shared.index.remove_matching(key, &IndexEntry { node, slot, counter });
            if removed && node == me {
                // We are the slot's home but not the deleter: reclaim.
                shared.free.lock().unwrap().push(slot);
            }
        }
        OP_BATCH => {
            let node = msg[1] as NodeId;
            let count = msg[2] as usize;
            if home_is_dead(node) {
                return;
            }
            for i in 0..count {
                let base = 3 + i * 3;
                let key = msg[base];
                shared.invalidate(key);
                shared.index.insert(
                    key,
                    IndexEntry { node, slot: msg[base + 1] as u32, counter: msg[base + 2] },
                );
            }
        }
        OP_INVAL => {
            // In-place update: drop cached copies (and poison in-flight
            // fills via the shard epochs) before this message is acked —
            // the updater returns only after every node has done so.
            let count = msg[1] as usize;
            if let Some(cache) = &shared.cache {
                cache.invalidate_many(msg[2..2 + count].iter().copied());
            }
        }
        OP_EPOCH => {
            // The dead node's backup finished re-homing (all recovered
            // locations precede this on the same FIFO ring): any entry
            // still homed on the corpse belongs to an insert that never
            // completed — drop it.
            shared.purge_homed_on(msg[1] as NodeId);
        }
        other => panic!("unknown tracker opcode {other}"),
    }
}

/// An in-flight windowed lookup.
pub struct PendingGet {
    key: u64,
    entry: IndexEntry,
    state: PendingState,
}

enum PendingState {
    /// Resolved from the hot-key cache at issue time.
    Cached(Vec<u64>),
    /// Remote READ in flight.
    InFlight { ack: AckKey, buf: MemRef, token: Option<FillToken> },
}

impl PendingGet {
    pub fn is_complete(&self) -> bool {
        match &self.state {
            PendingState::Cached(_) => true,
            PendingState::InFlight { ack, .. } => ack.query(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::{Cluster, FabricConfig, LatencyModel};

    fn small_cfg() -> KvConfig {
        KvConfig { slots_per_node: 64, tracker_words: 1 << 10, ..Default::default() }
    }

    fn cached_cfg() -> KvConfig {
        KvConfig { read_cache_entries: 64, ..small_cfg() }
    }

    fn setup_cfg(
        n: usize,
        fabric: FabricConfig,
        cfg: KvConfig,
    ) -> (Vec<Arc<Manager>>, Vec<Arc<KvStore>>) {
        let cluster = Cluster::new(n, fabric);
        let mgrs: Vec<Arc<Manager>> =
            (0..n as NodeId).map(|i| Manager::new(cluster.clone(), i)).collect();
        let kvs: Vec<Arc<KvStore>> =
            mgrs.iter().map(|m| KvStore::new(m, "kv", cfg.clone())).collect();
        for kv in &kvs {
            kv.wait_ready(Duration::from_secs(30));
        }
        (mgrs, kvs)
    }

    fn setup(n: usize, cfg: FabricConfig) -> (Vec<Arc<Manager>>, Vec<Arc<KvStore>>) {
        setup_cfg(n, cfg, small_cfg())
    }

    #[test]
    fn insert_get_update_delete_cross_node() {
        let (mgrs, kvs) = setup(3, FabricConfig::inline_ideal());
        let ctxs: Vec<_> = mgrs.iter().map(|m| m.ctx()).collect();

        assert!(kvs[0].insert(&ctxs[0], 7, &[100]).unwrap());
        // Visible from every node (index broadcast + remote read).
        for i in 0..3 {
            assert_eq!(kvs[i].get(&ctxs[i], 7), Some(vec![100]), "node {i}");
        }
        // Update from a non-home node.
        assert!(kvs[2].update(&ctxs[2], 7, &[200]));
        for i in 0..3 {
            assert_eq!(kvs[i].get(&ctxs[i], 7), Some(vec![200]));
        }
        // Delete from a third node.
        assert!(kvs[1].remove(&ctxs[1], 7));
        for i in 0..3 {
            assert_eq!(kvs[i].get(&ctxs[i], 7), None);
        }
        // Slot reclaimed at home (node 0).
        assert_eq!(kvs[0].shared.free.lock().unwrap().len(), 64);
    }

    #[test]
    fn missing_key_and_double_ops() {
        let (mgrs, kvs) = setup(2, FabricConfig::inline_ideal());
        let ctx = mgrs[0].ctx();
        assert_eq!(kvs[0].get(&ctx, 42), None);
        assert!(!kvs[0].update(&ctx, 42, &[1]));
        assert!(!kvs[0].remove(&ctx, 42));
        assert!(kvs[0].insert(&ctx, 42, &[1]).unwrap());
        assert!(!kvs[0].insert(&ctx, 42, &[2]).unwrap(), "second insert is update");
        assert_eq!(kvs[0].get(&ctx, 42), Some(vec![2]));
    }

    #[test]
    fn capacity_exhaustion() {
        let (mgrs, kvs) = setup(2, FabricConfig::inline_ideal());
        let ctx = mgrs[0].ctx();
        for k in 0..64 {
            kvs[0].insert(&ctx, k, &[k]).unwrap();
        }
        assert!(matches!(kvs[0].insert(&ctx, 999, &[0]), Err(Error::Capacity(_))));
    }

    #[test]
    fn prefill_batch_visible_everywhere() {
        let (mgrs, kvs) = setup(3, FabricConfig::inline_ideal());
        let ctxs: Vec<_> = mgrs.iter().map(|m| m.ctx()).collect();
        // Each node loads its hash-partitioned shard.
        let all: Vec<u64> = (0..150).collect();
        for (i, kv) in kvs.iter().enumerate() {
            let mine: Vec<u64> =
                all.iter().copied().filter(|&k| kv.home_of(k) == i as NodeId).collect();
            kv.prefill_local(&ctxs[i], &mine, |k| vec![k * 10], None).unwrap();
        }
        for kv in &kvs {
            assert_eq!(kv.index_len(), 150);
        }
        for &k in &all {
            assert_eq!(kvs[(k % 3) as usize].get(&ctxs[(k % 3) as usize], k), Some(vec![k * 10]));
        }
    }

    /// multi_get matches scalar gets across hit/miss/deleted keys and
    /// tolerates duplicates, on both delivery modes and with the read
    /// cache on and off.
    #[test]
    fn multi_get_matches_scalar() {
        for cache_entries in [0usize, 64] {
            for fabric in
                [FabricConfig::inline_ideal(), FabricConfig::threaded(LatencyModel::fast_sim())]
            {
                let cfg = KvConfig { read_cache_entries: cache_entries, ..small_cfg() };
                let (mgrs, kvs) = setup_cfg(3, fabric, cfg);
                let ctxs: Vec<_> = mgrs.iter().map(|m| m.ctx()).collect();
                // Spread homes across nodes: each node inserts its residue class.
                for k in 0..30u64 {
                    kvs[(k % 3) as usize].insert(&ctxs[(k % 3) as usize], k, &[k + 500]).unwrap();
                }
                kvs[0].remove(&ctxs[0], 9);
                // Batch with hits on all three homes, a miss, a deleted key,
                // and a duplicate.
                let keys = [0u64, 1, 2, 17, 999, 9, 2];
                let out = kvs[1].multi_get(&ctxs[1], &keys);
                for (i, &k) in keys.iter().enumerate() {
                    assert_eq!(out[i], kvs[1].get(&ctxs[1], k), "key {k}");
                }
                assert_eq!(out[4], None);
                assert_eq!(out[5], None);
                assert_eq!(out[6], Some(vec![502]));
                // Second batch: with the cache on, remote-homed keys now hit.
                let out = kvs[1].multi_get(&ctxs[1], &keys);
                assert_eq!(out[6], Some(vec![502]));
                if cache_entries > 0 {
                    assert!(kvs[1].cache_stats().hits > 0, "no cache hits recorded");
                }
            }
        }
    }

    /// multi_put updates present keys, skips absent ones, and the batch
    /// fence makes every write durable before the locks release.
    #[test]
    fn multi_put_batched_updates() {
        let (mgrs, kvs) = setup(3, FabricConfig::threaded(LatencyModel::fast_sim()));
        let ctxs: Vec<_> = mgrs.iter().map(|m| m.ctx()).collect();
        for k in 0..24u64 {
            kvs[(k % 3) as usize].insert(&ctxs[(k % 3) as usize], k, &[0]).unwrap();
        }
        // Node 1 batch-updates keys homed on all three nodes (+1 absent).
        let items: Vec<(u64, Vec<u64>)> =
            (0..24u64).map(|k| (k, vec![k * 7])).chain([(777u64, vec![1])]).collect();
        assert_eq!(kvs[1].multi_put(&ctxs[1], &items), 24);
        for k in 0..24u64 {
            for (i, kv) in kvs.iter().enumerate() {
                assert_eq!(kv.get(&ctxs[i], k), Some(vec![k * 7]), "node {i} key {k}");
            }
        }
        assert_eq!(kvs[1].get(&ctxs[1], 777), None, "absent key skipped");
        // Empty batches are no-ops.
        assert_eq!(kvs[1].multi_put(&ctxs[1], &[]), 0);
        assert!(kvs[1].multi_get(&ctxs[1], &[]).is_empty());
    }

    /// Concurrent multi_puts from every node (overlapping key sets, so
    /// overlapping lock sets) must not deadlock and must leave each key
    /// holding one of the contending values. Cache enabled: the batch
    /// invalidation broadcast runs under the held locks.
    #[test]
    fn concurrent_multi_put_no_deadlock() {
        let (mgrs, kvs) =
            setup_cfg(3, FabricConfig::threaded(LatencyModel::fast_sim()), cached_cfg());
        let ctxs: Vec<_> = mgrs.iter().map(|m| m.ctx()).collect();
        for k in 0..16u64 {
            kvs[0].insert(&ctxs[0], k, &[0]).unwrap();
        }
        let handles: Vec<_> = mgrs
            .iter()
            .zip(&kvs)
            .enumerate()
            .map(|(i, (m, kv))| {
                let m = m.clone();
                let kv = kv.clone();
                std::thread::spawn(move || {
                    let ctx = m.ctx();
                    for round in 0..20u64 {
                        let items: Vec<(u64, Vec<u64>)> = (0..16u64)
                            .map(|k| (k, vec![1 + (i as u64) * 1000 + round]))
                            .collect();
                        assert_eq!(kv.multi_put(&ctx, &items), 16);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        for k in 0..16u64 {
            let v = kvs[0].get(&ctxs[0], k).expect("key survived");
            assert!(v[0] >= 1, "key {k} holds a contending value, got {v:?}");
        }
    }

    #[test]
    fn windowed_gets() {
        let (mgrs, kvs) = setup(2, FabricConfig::threaded(LatencyModel::fast_sim()));
        let ctxs: Vec<_> = mgrs.iter().map(|m| m.ctx()).collect();
        for k in 0..32 {
            kvs[0].insert(&ctxs[0], k, &[k + 1000]).unwrap();
        }
        // Window of 8 outstanding reads from node 1.
        let mut pending = Vec::new();
        let mut results = Vec::new();
        for k in 0..32u64 {
            pending.push((k, kvs[1].get_issue(&ctxs[1], k).unwrap()));
            if pending.len() == 8 {
                for (k, pg) in pending.drain(..) {
                    results.push((k, kvs[1].get_complete(&ctxs[1], pg)));
                }
            }
        }
        for (k, pg) in pending.drain(..) {
            results.push((k, kvs[1].get_complete(&ctxs[1], pg)));
        }
        for (k, v) in results {
            assert_eq!(v, Some(vec![k + 1000]));
        }
    }

    /// The locality tier end to end: repeat gets hit the cache, updates
    /// and deletes invalidate every node before returning, windowed gets
    /// resolve cached keys at issue time.
    #[test]
    fn cached_get_hits_and_stays_fresh() {
        let (mgrs, kvs) =
            setup_cfg(3, FabricConfig::threaded(LatencyModel::fast_sim()), cached_cfg());
        let ctxs: Vec<_> = mgrs.iter().map(|m| m.ctx()).collect();

        assert!(kvs[0].insert(&ctxs[0], 5, &[700]).unwrap());
        // First get from node 2 fills, second hits.
        assert_eq!(kvs[2].get(&ctxs[2], 5), Some(vec![700]));
        assert_eq!(kvs[2].get(&ctxs[2], 5), Some(vec![700]));
        let s = kvs[2].cache_stats();
        assert!(s.fills >= 1, "{s:?}");
        assert!(s.hits >= 1, "{s:?}");

        // Update from node 1: node 2's cached copy must be gone by the
        // time update() returns.
        assert!(kvs[1].update(&ctxs[1], 5, &[701]));
        assert_eq!(kvs[2].get(&ctxs[2], 5), Some(vec![701]), "stale cached value served");

        // Windowed path: issue resolves from cache once re-filled.
        assert_eq!(kvs[2].get(&ctxs[2], 5), Some(vec![701]));
        let pg = kvs[2].get_issue(&ctxs[2], 5).unwrap();
        assert!(pg.is_complete(), "cached issue should resolve instantly");
        assert_eq!(kvs[2].get_complete(&ctxs[2], pg), Some(vec![701]));

        // Delete: after remove() returns no node may serve the value.
        assert!(kvs[0].remove(&ctxs[0], 5));
        for i in 0..3 {
            assert_eq!(kvs[i].get(&ctxs[i], 5), None, "node {i}");
        }
        // Re-insert gets a fresh generation; old cached copies can't hit.
        assert!(kvs[1].insert(&ctxs[1], 5, &[702]).unwrap());
        for i in 0..3 {
            assert_eq!(kvs[i].get(&ctxs[i], 5), Some(vec![702]), "node {i}");
        }
    }

    /// Crash-stop + re-home end to end: keys homed on the dead node come
    /// back from the backup replica (same values, new home on the backup
    /// node), deleted keys stay gone, mutations whose lock lives on the
    /// corpse fail fast, and everything else keeps serving.
    #[test]
    fn crash_rehomes_dead_nodes_keys_from_backup() {
        let cfg = KvConfig {
            slots_per_node: 64,
            tracker_words: 1 << 10,
            read_cache_entries: 16,
            replicate: true,
            ..Default::default()
        };
        let (mgrs, kvs) = setup_cfg(3, FabricConfig::threaded(LatencyModel::fast_sim()), cfg);
        let ctxs: Vec<_> = mgrs.iter().map(|m| m.ctx()).collect();

        // Node 1 homes keys 100..110; cross-node update + delete + a
        // cache fill before the crash.
        for k in 100..110u64 {
            assert!(kvs[1].insert(&ctxs[1], k, &[k * 3]).unwrap());
        }
        assert!(kvs[0].update(&ctxs[0], 105, &[999]));
        assert!(kvs[2].remove(&ctxs[2], 107));
        assert_eq!(kvs[2].get(&ctxs[2], 104), Some(vec![312])); // fills node 2's cache

        mgrs[0].cluster().crash(1);

        // Recovery: node 2 == backup_of(1) re-homes the range; wait for
        // the index to reflect it everywhere.
        let deadline = std::time::Instant::now() + Duration::from_secs(20);
        loop {
            let moved = [&kvs[0], &kvs[2]].iter().all(|kv| {
                (100..110u64)
                    .filter(|k| *k != 107)
                    .all(|k| kv.index_entry(k).map(|e| e.node == 2).unwrap_or(false))
            });
            if moved {
                break;
            }
            assert!(std::time::Instant::now() < deadline, "re-home never completed");
            std::thread::yield_now();
        }

        // Values survived the crash (including the pre-crash update);
        // the deleted key did not resurrect.
        for (i, kv) in [(0usize, &kvs[0]), (2usize, &kvs[2])] {
            for k in 100..110u64 {
                let expect = match k {
                    105 => Some(vec![999]),
                    107 => None,
                    _ => Some(vec![k * 3]),
                };
                assert_eq!(kv.get(&ctxs[i], k), expect, "node {i} key {k}");
            }
        }

        // Locks striped on the dead node (key % 256 % 3 == 1) are
        // unusable: mutations fail fast instead of hanging.
        assert!(matches!(
            kvs[0].try_update(&ctxs[0], 100, &[1]),
            Err(Error::PeerFailed(_))
        ));
        assert_eq!(kvs[0].get(&ctxs[0], 100), Some(vec![300]), "failed update left value");

        // Keys whose lock is alive stay fully mutable, and new inserts
        // (broadcast acks skip the corpse) still complete.
        assert_eq!(kvs[0].try_update(&ctxs[0], 101, &[777]), Ok(true));
        assert_eq!(kvs[2].get(&ctxs[2], 101), Some(vec![777]));
        assert!(kvs[0].insert(&ctxs[0], 200, &[42]).unwrap());
        assert_eq!(kvs[2].get(&ctxs[2], 200), Some(vec![42]));
    }

    /// Without replication a crash is a delete of the dead node's range:
    /// every surviving index purges it and reads return EMPTY.
    #[test]
    fn crash_without_replication_purges_dead_range() {
        let (mgrs, kvs) = setup(3, FabricConfig::threaded(LatencyModel::fast_sim()));
        let ctxs: Vec<_> = mgrs.iter().map(|m| m.ctx()).collect();
        for k in 30..36u64 {
            assert!(kvs[1].insert(&ctxs[1], k, &[k]).unwrap());
        }
        assert_eq!(kvs[0].get(&ctxs[0], 30), Some(vec![30]));
        mgrs[0].cluster().crash(1);
        let deadline = std::time::Instant::now() + Duration::from_secs(20);
        while kvs[0].index_entry(30).is_some() || kvs[2].index_entry(35).is_some() {
            assert!(std::time::Instant::now() < deadline, "purge never happened");
            std::thread::yield_now();
        }
        for k in 30..36u64 {
            assert_eq!(kvs[0].get(&ctxs[0], k), None, "key {k} not purged");
            assert_eq!(kvs[2].get(&ctxs[2], k), None, "key {k} not purged");
        }
    }

    /// Satellite regression: an adversarial writer hammering updates and
    /// recycling slots (delete + reinsert) must not livelock concurrent
    /// readers — the bounded torn-read spin re-fetches the index entry
    /// and every get terminates with an untorn value.
    #[test]
    fn adversarial_writer_cannot_livelock_get() {
        let fabric = FabricConfig::threaded(LatencyModel::fast_sim()).chaotic();
        let cfg = KvConfig {
            slots_per_node: 32,
            value_words: 4,
            tracker_words: 1 << 12,
            read_cache_entries: 16,
            ..Default::default()
        };
        let (mgrs, kvs) = setup_cfg(2, fabric, cfg);
        let ctx0 = mgrs[0].ctx();
        kvs[0].insert(&ctx0, 1, &[1; 4]).unwrap();

        let writer = {
            let m = mgrs[0].clone();
            let kv = kvs[0].clone();
            std::thread::spawn(move || {
                let ctx = m.ctx();
                for round in 2..250u64 {
                    if round % 10 == 0 {
                        // Slot churn: the reader's cached entry goes stale.
                        kv.remove(&ctx, 1);
                        kv.insert(&ctx, 1, &[round; 4]).unwrap();
                    } else {
                        kv.update(&ctx, 1, &[round; 4]);
                    }
                }
            })
        };
        let reader = {
            let m = mgrs[1].clone();
            let kv = kvs[1].clone();
            std::thread::spawn(move || {
                let ctx = m.ctx();
                let mut observed = 0u64;
                for _ in 0..500 {
                    if let Some(v) = kv.get(&ctx, 1) {
                        assert!(v.iter().all(|&x| x == v[0]), "torn value: {v:?}");
                        observed += 1;
                    }
                }
                observed
            })
        };
        writer.join().unwrap();
        let observed = reader.join().unwrap();
        assert!(observed > 0, "reader starved outright");
        // And a final quiescent read agrees with the last write.
        let ctx1 = mgrs[1].ctx();
        let v = kvs[1].get(&ctx1, 1).expect("key present");
        assert!(v.iter().all(|&x| x == v[0]), "torn value after quiesce: {v:?}");
    }

    /// Concurrent mixed workload across nodes on the racy fabric: every
    /// read — scalar or batched — sees either a fully written value or
    /// nothing, never garbage. The batched reads exercise multi_get's
    /// torn-key rebatching under real races.
    #[test]
    fn concurrent_mixed_no_torn_values() {
        let n = 3;
        let cluster = Cluster::new(n, FabricConfig::threaded(LatencyModel::fast_sim()).chaotic());
        let mgrs: Vec<Arc<Manager>> =
            (0..n as NodeId).map(|i| Manager::new(cluster.clone(), i)).collect();
        let cfg = KvConfig {
            slots_per_node: 256,
            value_words: 4,
            tracker_words: 1 << 12,
            read_cache_entries: 64,
            ..Default::default()
        };
        let kvs: Vec<Arc<KvStore>> =
            mgrs.iter().map(|m| KvStore::new(m, "kv", cfg.clone())).collect();
        for kv in &kvs {
            kv.wait_ready(Duration::from_secs(30));
        }
        // Values encode their key 4× so torn mixes are detectable.
        let handles: Vec<_> = mgrs
            .iter()
            .zip(&kvs)
            .enumerate()
            .map(|(i, (m, kv))| {
                let m = m.clone();
                let kv = kv.clone();
                std::thread::spawn(move || {
                    let ctx = m.ctx();
                    let mut rng = crate::util::rng::Rng::seeded(i as u64);
                    for round in 0..150u64 {
                        let key = rng.gen_range(32);
                        match rng.gen_range(10) {
                            0..=2 => {
                                let tag = round * 10 + i as u64;
                                let _ = kv.insert(&ctx, key, &[tag; 4]);
                            }
                            3..=4 => {
                                let _ = kv.remove(&ctx, key);
                            }
                            5 => {
                                let tag = round * 10 + i as u64;
                                let _ = kv.update(&ctx, key, &[tag; 4]);
                            }
                            6 => {
                                let keys = [key, (key + 7) % 32, key];
                                for v in kv.multi_get(&ctx, &keys).into_iter().flatten() {
                                    assert!(
                                        v.iter().all(|&x| x == v[0]),
                                        "torn value from multi_get: {v:?}"
                                    );
                                }
                            }
                            _ => {
                                if let Some(v) = kv.get(&ctx, key) {
                                    assert!(
                                        v.iter().all(|&x| x == v[0]),
                                        "torn value from get: {v:?}"
                                    );
                                }
                            }
                        }
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    }
}
