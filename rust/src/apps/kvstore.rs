//! The LOCO key-value store (paper §6) — provably linearizable
//! (Appendix C; the history-checking test lives in
//! `rust/tests/linearizability.rs`).
//!
//! Design, exactly as in the paper:
//!
//! * Every node allocates a remotely-accessible **data region**, carved
//!   by the size-class **slab allocator**
//!   ([`crate::core::mem_pool::SlabAllocator`]) into per-class value
//!   slots framed `[len‖class][value …][checksum]…[counter‖valid]`
//!   (checksum right after the value, counter word at the fixed frame
//!   end). Values are variable-size: an insert picks the smallest
//!   class that fits, an update that outgrows its class **relocates**
//!   (see below), and readers learn the frame length to READ from the
//!   class packed into the 32-bit slot id — no handshake needed.
//! * Every node keeps a **local index** mapping key → (home node, slot,
//!   counter) — a sharded, seqlock-validated table
//!   ([`crate::core::index::ShardedIndex`]) whose readers are lock-free,
//!   so `get` never contends with tracker broadcasts.
//! * Mutations are protected by an array of **ticket locks**, indexed by
//!   `key % NUM_LOCKS`, striped across nodes.
//! * Inserts write the value *locally* with the valid bit unset,
//!   broadcast the location on the inserter's **tracker ringbuffer**,
//!   wait for all nodes to apply + acknowledge, then set the valid bit
//!   (the insert's linearization point).
//! * Deletes unset the valid bit (linearization point), broadcast, and
//!   free the slot once acknowledged.
//! * Updates that still fit their slot's class write
//!   `[len‖class][value][checksum]` in place under the lock, then
//!   **fence** before release (the §7.2 "15 % overhead" fence — the
//!   `fence_updates` knob ablates it); updates that outgrew the class
//!   relocate (below).
//! * Lookups take **no locks**: index lookup, one remote read of the
//!   slot's class-sized frame, then the checksum/counter/valid
//!   validation protocol of Appendix C (the checksum covers the value's
//!   *actual* length and sits right after it — a header torn against
//!   its value shifts the checksum position, so the mix is rejected).
//!
//! # Relocation (updates that outgrow their class)
//!
//! An update whose new value exceeds its slot's class capacity cannot
//! write in place. It instead **relocates** under the key lock: a fresh
//! local slot (fresh generation) is written with the new value and the
//! [`crate::core::mem_pool::HDR_RELOC`] marker, valid bit UNSET; the
//! new location is broadcast (`OP_INSERT`) and acknowledged by every
//! node; only then is the valid bit set — the update's linearization
//! point — and the old slot retired (valid bit unset and fenced, then
//! `OP_FREE`). The old frame keeps serving the pre-update value to
//! readers whose index snapshot predates the broadcast (their
//! invocations predate the linearization point, so the old value is
//! legal) right up to the retire; readers that reach the new frame
//! before valid-set see the RELOC marker and spin for the valid bit
//! instead of reporting EMPTY — exactly the "park until the location
//! settles" behavior of readers racing crash recovery.
//!
//! Crash atomicity: the relocation `OP_INSERT` carries the **origin**
//! entry, which every tracker records until the retire (`OP_FREE`)
//! proves completion. If the relocator crash-stops in between, each
//! node converges without coordination — recovery's re-home (which
//! applies compare-and-swap, `OP_REHOME` /
//! [`crate::core::index::ShardedIndex::replace_matching`], so a LIVE
//! relocation always wins the index) resurrects the relocated frame
//! from the relocator's backup when the broadcast reached the backup,
//! and otherwise the epoch purge **reverts** the key to its recorded
//! origin — the pre-relocation frame at its alive old home, which the
//! protocol deliberately never invalidates — instead of dropping a key
//! that still exists.
//!
//! # The locality tier
//!
//! On top of the paper's protocol, the read path carries a **locality
//! tier** (see `docs/ARCHITECTURE.md § Locality tier`): an optional
//! bounded hot-key value cache ([`crate::channels::read_cache`]) serves
//! repeat `get`s of *remote-homed* keys from local memory. A hit is
//! legal only while the cached slot generation matches the current
//! index counter; in-place updates (which do not bump the counter)
//! broadcast invalidations over the tracker ring and wait for all acks
//! before returning, and fills are epoch-validated so an in-flight read
//! can never re-poison the cache after its key was invalidated. With
//! the cache enabled, updates and deletes therefore linearize at
//! broadcast-ack completion; `fence_updates` is required (an unfenced
//! update could be cached stale indefinitely).
//!
//! # The hot write path
//!
//! Fenced mutations apply the standard-RDMA verb economies (see
//! `docs/ARCHITECTURE.md § Write path`): frame writes are **covered**
//! (unsignaled; the §7.2 fence is the chain's one CQE, and a dead
//! home's failure propagates through it via the QP chain error),
//! small-class frames go out **inline** (no NIC payload-fetch round),
//! concurrent updates **coalesce** their `OP_INVAL` broadcasts into one
//! multicast with a union ack wait ([`KvConfig::coalesce_invals`]), and
//! duplicate keys inside one `multi_put` collapse to the last value
//! under the held lock.
//!
//! # Failure model & recovery
//!
//! Under fault injection (`FabricConfig::faults`) the store survives up
//! to `replicas − 1` crash-stops per key range (see
//! `docs/ARCHITECTURE.md`, § Elastic membership & replication): with
//! [`KvConfig::replicas`] ≥ 2, every slot frame is mirrored to the
//! `replicas − 1` **static successor** nodes of its home in one covered
//! write chain, and on a detected crash the *first live* backup in the
//! dead node's chain re-homes its key range from the hosted replica
//! (fresh generations, compare-and-swap `OP_REHOME` broadcasts, an
//! `OP_EPOCH` marker to purge leftovers) — re-replicating each
//! recovered frame to its own successors, which restores the
//! replication factor (anti-entropy repair). Reads whose home is dead
//! **fail over** to the first live replica's backup frame instead of
//! parking (graceful degradation; see `failover_read` for the
//! linearizability argument); locked mutations that catch the dead home
//! park in `wait_entry_change` and resume against the new location;
//! keys whose *lock* is hosted on the corpse are read-only (mutations
//! return `Err(Error::PeerFailed)`). Without replication a crash
//! behaves as a delete of every key the dead node homed.
//!
//! # Elastic membership
//!
//! Membership is **bidirectional** (see
//! [`Membership`](crate::core::manager::Membership)): every tracker
//! broadcast carries the sender's membership **epoch** (appended as the
//! message's last word) so stale-owner broadcasts — e.g. a pre-crash
//! message delivered after its slot re-joined — are rejected, not just
//! ones from currently dead homes. A spare (or revived) node enters
//! with [`KvStore::join`], pulls the key ranges the epoch-versioned
//! ownership table now assigns it with [`KvStore::rebalance`] — the
//! relocation primitive lifted into a range-migration driver, so reads
//! and writes keep landing mid-reshard and a joiner crash reverts via
//! the origin-tracking story — and completes with
//! [`KvStore::activate`].

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, Weak};
use std::time::Duration;

use crate::channels::read_cache::{CacheStats, EpochGate, FillToken, ReadCache};
use crate::channels::request_ring::RequestRing;
use crate::channels::ringbuffer::{RingReceiver, RingSender};
use crate::channels::ticket_lock::TicketLock;
use crate::core::ack::AckKey;
use crate::core::ctx::{FenceScope, MemRef, ThreadCtx};
use crate::core::endpoint::{region_name, sub_name, Endpoint, Expect};
use crate::core::heat::{HeatTracker, RouteDecision, RouteMode};
use crate::core::index::ShardedIndex;
use crate::core::manager::{Manager, Membership};
use crate::core::mem_pool::{
    hdr_class, hdr_len, hdr_reloc, pack_hdr, SlabAllocator, SlabEvent, SlabGeometry,
};
use crate::fabric::{Cluster, NodeId, Region};
use crate::util::{fnv64, Backoff};
use crate::{Error, Result};

pub use crate::core::index::IndexEntry;

/// Tracker message opcodes.
const OP_INSERT: u64 = 1;
const OP_DELETE: u64 = 2;
const OP_BATCH: u64 = 3;
/// Cache invalidation for in-place updates: `[OP_INVAL, n, key...]`.
const OP_INVAL: u64 = 4;
/// End-of-recovery marker from a dead node's backup: `[OP_EPOCH,
/// dead_node]`. Everything the backup could recover has been
/// re-broadcast (same ring, so FIFO-before this marker); receivers drop
/// any index entry still homed on the dead node — those keys' inserts
/// never completed (or were never known to the backup) and their data
/// died with the node.
const OP_EPOCH: u64 = 5;
/// Retire a relocated-away slot: `[OP_FREE, node, slot, key]`. Only the
/// slot's home applies the free (returns the slot to its slab free
/// list); FIFO-after the relocation's `OP_INSERT` on the same ring, so
/// the home learns the new location before it can reuse the old slot.
/// Every receiver also prunes `key`'s relocation-origin record — an
/// OP_FREE proves the relocation completed (it is sent only after the
/// valid-set), so the origin will never be needed for a crash revert.
const OP_FREE: u64 = 6;
/// Recovery re-home: `[OP_REHOME, key, node, slot, counter, old_node,
/// old_slot, old_counter]`, optionally extended with the dead entry's
/// relocation **origin** `[…, origin_node, origin_slot,
/// origin_counter]` (11 words). Applied compare-and-swap — against the
/// exact dead entry, or the origin (a receiver that never saw the
/// crashed relocation's broadcast still holds it), or an absent key (a
/// receiver that never saw the crashed *insert's* broadcast) — so a
/// LIVE relocation's unconditional `OP_INSERT` wins on every node
/// whatever the arrival order, while crashed partial broadcasts still
/// converge everywhere.
const OP_REHOME: u64 = 7;

/// Membership: the sender begins **joining** — a designated spare
/// activating, or a previously crashed slot being reused after
/// [`crate::fabric::Cluster::revive`]: `[OP_JOIN, node]`. Receivers
/// move the slot to the Joining state (clearing its dead/spare bits)
/// and bump their membership epoch; the ownership table recomputes on
/// next use and [`KvStore::rebalance`] migrates the ranges.
const OP_JOIN: u64 = 8;

/// Membership: the sender finished joining (its migration converged):
/// `[OP_ALIVE, node]`.
const OP_ALIVE: u64 = 9;

/// Request-ring op code: shipped in-place update, `(key, epoch, value)`
/// (see § Op routing in `docs/ARCHITECTURE.md`).
const SHIP_UPDATE: u8 = 1;
/// Shipped-op reply statuses: the server applied the update under the
/// key lock (replication + invalidation broadcast included, so the
/// reply is the client's linearization witness)…
const SHIP_APPLIED: u8 = 1;
/// …the key is absent in the server's index (a legal "absent" answer —
/// the index read is the serialization point, exactly like `get`'s)…
const SHIP_MISSING: u8 = 2;
/// …the server is not (or no longer) the key's home — the client
/// re-resolves its index and retries or falls back one-sided…
const SHIP_WRONG_HOME: u8 = 3;
/// …or a transient server-side failure (lock host dead, home
/// mid-recovery): the client falls back to the one-sided path, which
/// owns the re-home dance.
const SHIP_RETRY: u8 = 4;
/// WRONG_HOME/RETRY attempts before a shipped update falls back to the
/// one-sided path (which is always correct, just slower when hot).
const SHIP_ATTEMPTS: usize = 3;

/// What a ship attempt established (see [`KvStore::ship_update`]).
enum ShipOutcome {
    /// Definite server answer: applied (`true`) or key absent (`false`).
    Done(bool),
    /// The op never reached a server that could apply it (local home,
    /// oversized value, home already marked down, WRONG_HOME/RETRY
    /// budget exhausted): take the one-sided path — nothing happened.
    NotShipped,
    /// The server crash-stopped between the request enqueue and its
    /// reply: the update may or may not have been applied (and
    /// replicated) before the crash.
    Ambiguous,
}

/// Outcome of [`KvStore::try_update_outcome`]: `applied` is what
/// [`KvStore::try_update`] returns; `ambiguous` is set when the op
/// completed through the post-crash fallback after a shipped attempt
/// whose fate is unknown — the value is durably in place on return,
/// but the op may have had TWO application points (the dead server's
/// pre-crash apply and the fallback's re-apply), possibly with other
/// writes between them. History recorders must give such an op
/// CRASHED-style uncertainty instead of a definite interval (see
/// `testkit::CRASHED`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct UpdateOutcome {
    /// The value is in place (`false`: the key was absent).
    pub applied: bool,
    /// Completed via the ambiguous-ship fallback; the linearization
    /// point cannot be pinned to one instant.
    pub ambiguous: bool,
}

/// `OP_INSERT` message lengths: the 5-word plain form, and the 8-word
/// relocation form carrying the origin entry (`[…, old_node, old_slot,
/// old_counter]`) that receivers record for crash reverts.
const OP_INSERT_PLAIN_LEN: usize = 5;

/// Torn-read retries between index-entry re-fetches: a reader spinning
/// on a checksum mismatch re-validates its location after this many
/// rounds, so a concurrent slot reuse (its key deleted, the slot now
/// backing an update-heavy neighbour) cannot livelock it.
const TORN_REFETCH: u32 = 8;

/// Keys per `OP_INVAL` tracker message (chunked like prefill's
/// `OP_BATCH` frames: one huge coalesced snapshot must not overflow the
/// tracker ring's message capacity).
const INVAL_CHUNK: usize = 128;

/// Frame one `OP_INVAL` chunk: `[OP_INVAL, n, key…]` — the single
/// encoding shared by the coalesced and per-update broadcast paths.
fn encode_inval(chunk: &[u64]) -> Vec<u64> {
    let mut msg = Vec::with_capacity(2 + chunk.len());
    msg.push(OP_INVAL);
    msg.push(chunk.len() as u64);
    msg.extend_from_slice(chunk);
    msg
}

/// Tracker shard of `key`: the key's ownership **range** (already a
/// stable pure hash, see [`Membership::range_of`]) folded onto the
/// configured shard count. A key maps to the same shard forever, so
/// every broadcast about it rides one FIFO ring and per-key apply order
/// survives sharding.
fn shard_of(key: u64, shards: usize) -> usize {
    Membership::range_of(key) % shards
}

/// Ring name of `node`'s shard-`shard` tracker ring. Shard 0 keeps the
/// pre-sharding name, so `tracker_shards = 1` is byte-for-byte
/// compatible with existing channel names (and sim schedules).
fn tracker_ring_name(name: &str, node: NodeId, shard: usize) -> String {
    if shard == 0 {
        sub_name(name, &format!("trk{node}"))
    } else {
        sub_name(name, &format!("trk{node}s{shard}"))
    }
}

/// Group `keys` by tracker shard, preserving within-shard order.
/// Returns only non-empty groups, in ascending shard order (so the
/// send sequence is a pure function of the key set — determinism).
fn group_by_shard(keys: &[u64], shards: usize) -> Vec<(usize, Vec<u64>)> {
    if shards == 1 {
        return vec![(0, keys.to_vec())];
    }
    let mut groups: Vec<Vec<u64>> = vec![Vec::new(); shards];
    for &k in keys {
        groups[shard_of(k, shards)].push(k);
    }
    groups.into_iter().enumerate().filter(|(_, g)| !g.is_empty()).collect()
}

#[derive(Clone, Debug)]
pub struct KvConfig {
    /// Value slots per node **per size class** (the slab geometry gives
    /// every class the same slot count; with `value_words == 1` there is
    /// exactly one class and this is the node's total slot budget, as
    /// before).
    pub slots_per_node: usize,
    /// **Maximum** value width in words (rounded up to a power of two).
    /// Values of any length `1..=value_words` are accepted by every op;
    /// the slab allocator places each in the smallest class that fits.
    pub value_words: usize,
    /// Ticket locks striped across nodes (`key % num_locks`).
    pub num_locks: usize,
    /// Tracker ring capacity in words.
    pub tracker_words: u64,
    /// Key-range-sharded tracker rings per node (default 1 = the
    /// pre-sharding single ring, byte-for-byte compatible; env
    /// `LOCO_TRACKER_SHARDS` overrides the default). A key's broadcasts
    /// always ride shard `range_of(key) % tracker_shards` of its
    /// sender's rings, so per-key apply order is untouched while
    /// hot-insert and coalesced-invalidation apply parallelize across
    /// `tracker_shards` receiver threads per node. Membership and
    /// end-of-recovery ops (`OP_JOIN`/`OP_ALIVE`/`OP_EPOCH`) broadcast
    /// on **every** shard so they order after each shard's keyed
    /// traffic. Part of the cluster-wide config contract (ring
    /// endpoints must pair up).
    pub tracker_shards: usize,
    /// Fence updates before lock release (§7.2; ablation knob).
    pub fence_updates: bool,
    /// Use the local-handover lock fast path.
    pub lock_handover: bool,
    /// Hot-key read-cache **byte budget**; 0 disables the locality
    /// tier's value cache. Requires `fence_updates`. A byte budget (not
    /// an entry count) so large values cannot blow the cache: a cached
    /// entry costs its value words plus a fixed overhead (see
    /// [`ReadCache`]), and fills evict until the budget holds.
    ///
    /// Like every other field, this is part of the cluster-wide config
    /// contract ("all nodes must call with identical `cfg`") — and here
    /// a divergence is *silent*: a node configured with 0 never
    /// broadcasts `OP_INVAL` on its updates, so peers that do cache
    /// would serve the pre-update value indefinitely (in-place updates
    /// don't bump the generation counter). There is no cross-node
    /// config handshake; keep configs identical.
    pub read_cache_bytes: usize,
    /// **Total** copies of every slot frame, the authoritative one
    /// included: `1` = no replication (a crash drops the dead node's
    /// keys from every index), `k ≥ 2` mirrors each frame to the home's
    /// `k − 1` **static successors** (`(home+1+r) mod n`) so a key
    /// range survives the loss of any `k − 1` of its replicas — reads
    /// fail over to the first live replica while recovery re-homes and
    /// re-replicates (see `docs/ARCHITECTURE.md`, § Elastic membership
    /// & replication). Multiplies mutation write cost by ~`k`; `k ≥ 2`
    /// requires `fence_updates` (backup frames must be placed before a
    /// mutation returns) and `k ≤ n`. Default 1.
    pub replicas: usize,
    /// Coalesce `OP_INVAL` broadcasts (locality tier): concurrent
    /// in-place updates on this node merge their invalidation keys into
    /// one tracker message with a **union ack wait** — one
    /// doorbell-batched multicast retires every waiter — instead of one
    /// broadcast round per update. Consistency is unchanged: every
    /// updater still returns only after all peers applied an
    /// invalidation that was *sent after its fence*, so mutations keep
    /// linearizing at ack completion (see ARCHITECTURE § Write path).
    /// Off = the pre-coalescing one-round-per-update behavior (the
    /// ablation baseline). No effect with the cache disabled.
    pub coalesce_invals: bool,
    /// Mutation routing policy (see `docs/ARCHITECTURE.md § Op
    /// routing`): [`RouteMode::OneSided`] always takes the lock-and-
    /// write path, [`RouteMode::Ship`] sends every remote-homed update
    /// to its home's request ring, [`RouteMode::Adaptive`] picks per
    /// key from the [`HeatTracker`] (hot/contended keys ship, the rest
    /// stay one-sided). Default from `LOCO_ROUTING` (unset =
    /// `OneSided`). Part of the cluster-wide config contract: with
    /// `OneSided` no ring endpoint is created at all, so nodes must
    /// agree on *whether* routing is on (the ring's join handshake
    /// would otherwise wedge `wait_ready`); the Ship/Adaptive choice
    /// itself may differ per node.
    pub routing: RouteMode,
    /// Override for the fabric's race-checking mode when the test
    /// harness builds the cluster from this config (see
    /// [`crate::analysis::CheckMode`] and
    /// [`crate::fabric::FabricConfig::check_races`]). `None` (the
    /// default) keeps the fabric's own setting — full checking under
    /// `Sim`, off otherwise. Purely a construction-time knob: a
    /// `KvStore` attached to an existing cluster uses whatever checker
    /// that cluster was built with.
    pub check_races: Option<crate::analysis::CheckMode>,
}

impl Default for KvConfig {
    fn default() -> Self {
        KvConfig {
            slots_per_node: 4096,
            value_words: 1,
            num_locks: 256,
            tracker_words: 1 << 14,
            tracker_shards: default_tracker_shards(),
            fence_updates: true,
            lock_handover: true,
            read_cache_bytes: 0,
            replicas: 1,
            coalesce_invals: true,
            routing: RouteMode::from_env(),
            check_races: None,
        }
    }
}

/// `LOCO_TRACKER_SHARDS` (unset = 1): default shard count for
/// [`KvConfig::tracker_shards`].
fn default_tracker_shards() -> usize {
    match parse_tracker_shards(std::env::var("LOCO_TRACKER_SHARDS").ok().as_deref()) {
        Ok(n) => n,
        Err(e) => panic!("invalid LOCO_TRACKER_SHARDS: {e}"),
    }
}

fn parse_tracker_shards(raw: Option<&str>) -> std::result::Result<usize, String> {
    match raw {
        None => Ok(1),
        Some(v) => match v.trim().parse::<usize>() {
            Ok(0) => Err(format!(
                "{v:?} — a node needs at least one tracker ring; use 1 for the \
                 unsharded (default) configuration"
            )),
            Ok(n) => Ok(n),
            Err(_) => Err(format!("{v:?} is not a positive integer (expected 1, 2, 4, ...)")),
        },
    }
}

impl KvConfig {
    /// Whether slot frames carry at least one backup copy.
    pub fn replicated(&self) -> bool {
        self.replicas > 1
    }

    /// Enable the read cache sized for a Zipfian θ=0.99 workload over
    /// `keyspace` keys (see [`ReadCache::zipfian_capacity`]), budgeted
    /// in bytes for this config's maximum value width.
    pub fn with_zipfian_cache(mut self, keyspace: u64) -> Self {
        self.read_cache_bytes =
            ReadCache::zipfian_capacity(keyspace) * ReadCache::entry_bytes(self.value_words);
        self
    }
}

/// State shared between application threads and the tracker thread.
struct KvShared {
    /// Sharded seqlock index: lock-free readers, per-shard writers.
    index: ShardedIndex,
    /// The locality tier's hot-key value cache (None = disabled).
    cache: Option<ReadCache>,
    /// Size-class slab allocator over this node's data region: per-class
    /// free lists plus leak/double-free accounting (auditable via
    /// [`KvStore::slab_audit`]).
    alloc: SlabAllocator,
    /// Authoritative per-slot generation counters for *local* slots,
    /// indexed by the slab's dense slot ordinal.
    slot_counter: Vec<AtomicU64>,
    /// In-flight relocation origins, keyed by key: recorded when a
    /// relocation's `OP_INSERT` applies, pruned when its `OP_FREE`
    /// proves completion (or any later op supersedes it). If the
    /// relocator crash-stops in between, the replicated recovery path
    /// **reverts** the key to this origin — the pre-relocation frame at
    /// its (alive) old home still holds the pre-update value, and the
    /// relocation never linearized — instead of dropping a key that
    /// exists (see `purge_homed_on` for why the revert is
    /// replicate-only). Touched only by the tracker thread (apply +
    /// recovery).
    reloc_origins: Mutex<HashMap<u64, IndexEntry>>,
    /// The manager's membership view: epoch source for tracker-message
    /// stamping, staleness guard for location broadcasts, and the
    /// epoch-versioned ownership table behind [`KvStore::home_of`] and
    /// [`KvStore::rebalance`].
    membership: Arc<Membership>,
    /// Shard count this store was built with (mirrors
    /// [`KvConfig::tracker_shards`] for the free-standing apply path).
    tracker_shards: usize,
    /// `OP_EPOCH` markers seen per dead node, across shard rings: the
    /// leftover purge must wait for **every** shard's marker — the
    /// recovered-location broadcasts ride per-key shards, and only the
    /// same shard's marker is FIFO-after them (see `apply_tracker`).
    epoch_marks: Mutex<HashMap<NodeId, usize>>,
    /// Count of shard receiver groups that finished their ring
    /// handshakes; the store is ready at `tracker_shards`.
    tracker_ready: AtomicUsize,
    shutdown: AtomicBool,
}

impl KvShared {
    fn invalidate(&self, key: u64) {
        if let Some(cache) = &self.cache {
            cache.invalidate(key);
        }
    }

    /// Record one shard ring's `OP_EPOCH` marker for `dead`; true when
    /// this was the last outstanding shard — only then may the leftover
    /// purge run (with one shard this is every marker, the pre-sharding
    /// behavior). The counter resets on trigger so a revived slot's
    /// next crash counts afresh.
    fn note_epoch_mark(&self, dead: NodeId) -> bool {
        let mut marks = self.epoch_marks.lock().unwrap();
        let c = marks.entry(dead).or_insert(0);
        *c += 1;
        if *c == self.tracker_shards {
            marks.remove(&dead);
            true
        } else {
            false
        }
    }

    /// Resolve every index entry still homed on `dead` (invalidating
    /// each key's cached value): the shared purge step of crash
    /// recovery — used without replication (each node independently),
    /// by the backup's leftover sweep, and by the `OP_EPOCH` tracker
    /// handler.
    ///
    /// With `revert` (the replicated paths), an entry with a recorded
    /// relocation origin **reverts** to it instead of being dropped:
    /// the relocation never completed — its `OP_FREE` never arrived —
    /// so the pre-relocation frame at the alive old home still serves
    /// the pre-update value. This is safe precisely because, with
    /// replication, any *linearized* relocation was fully acked and is
    /// re-homed by the backup's `OP_REHOME` before this purge runs (so
    /// the revert can only fire for relocations whose old slot was
    /// never freed). Without replication that guarantee is gone — a
    /// relocator dying mid-`OP_FREE` could leave the origin slot freed
    /// and reused, and a reverted entry would point locked writes at
    /// another key's frame — so the unreplicated purge always drops
    /// (`revert: false`; crash = loss of the dead node's range, as
    /// documented).
    fn purge_homed_on(&self, dead: NodeId, revert: bool) {
        let mut origins = self.reloc_origins.lock().unwrap();
        for (key, e) in self.index.entries_homed_on(dead) {
            self.invalidate(key);
            match origins.remove(&key) {
                Some(origin) if revert && origin.node != dead => {
                    // Compare-and-swap revert: never clobber an entry
                    // that was re-homed (or freshly re-inserted)
                    // between snapshot and revert.
                    self.index.replace_matching(key, &e, origin);
                }
                _ => {
                    self.index.remove_matching(key, &e);
                }
            }
        }
    }
}

/// Group-commit state for coalesced `OP_INVAL` broadcasts: concurrent
/// updaters enqueue their keys; one thread at a time snapshots the whole
/// pending set and broadcasts it as a single tracker message, and every
/// thread whose keys rode that snapshot is released by the one union ack
/// wait. `next_batch` counts snapshots started, `done_batch` snapshots
/// fully acked; a thread that enqueued while snapshot *k* was in flight
/// is covered by snapshot *k+1* (its keys were not in *k*'s cut).
struct InvalCoalescer {
    st: Mutex<InvalState>,
    cv: Condvar,
}

#[derive(Default)]
struct InvalState {
    pending: Vec<u64>,
    next_batch: u64,
    done_batch: u64,
    in_flight: bool,
}

impl InvalCoalescer {
    fn new() -> InvalCoalescer {
        InvalCoalescer { st: Mutex::new(InvalState::default()), cv: Condvar::new() }
    }
}

pub struct KvStore {
    cfg: KvConfig,
    me: NodeId,
    num_nodes: usize,
    ep: Arc<Endpoint>,
    data: Region,
    /// The backup arrays this node HOSTS, indexed by **rank**: region
    /// `backup{r}` holds replica frames for the slots of the node that
    /// has us as its rank-`r` successor, i.e. home `(me − 1 − r) mod n`
    /// (empty when `replicas == 1`).
    backup_hosted: Vec<Region>,
    /// Membership epoch the read cache was last filled under: on any
    /// transition the whole locality tier drops, so entries filled
    /// under a superseded ownership table cannot serve into the new one
    /// (see [`EpochGate`]).
    cache_gate: EpochGate,
    locks: Vec<TicketLock>,
    /// Per-shard tracker rings (we broadcast; peers receive). Index =
    /// shard; key ops ride `shard_of(key, len)`, membership/epoch ops
    /// ride all of them.
    tracker_tx: Vec<Mutex<RingSender>>,
    /// Coalesced-`OP_INVAL` group commit, one per tracker shard (a
    /// snapshot's union ack wait covers exactly one shard ring's
    /// receivers; see [`InvalCoalescer`]).
    inval: Vec<InvalCoalescer>,
    /// Fabric handle for the routing observability counters
    /// (`Cluster::ops_shipped` / `Cluster::route_flips`).
    cluster: Arc<Cluster>,
    /// Op-shipping request ring (`None` iff `routing == OneSided`:
    /// nothing ships and no serve loop runs — the pre-routing store).
    ship: Option<Arc<RequestRing>>,
    /// Per-key heat/contention tracker driving Adaptive decisions.
    heat: HeatTracker,
    shared: Arc<KvShared>,
    tracker_threads: Mutex<Vec<std::thread::JoinHandle<()>>>,
    ship_thread: Mutex<Option<std::thread::JoinHandle<()>>>,
}

impl KvStore {
    /// Construct the kvstore endpoint on this node. All nodes must call
    /// with identical `name` and `cfg`.
    pub fn new(mgr: &Arc<Manager>, name: &str, cfg: KvConfig) -> Arc<KvStore> {
        let me = mgr.me();
        let n = mgr.num_nodes();
        let geo = SlabGeometry::new(cfg.value_words, cfg.slots_per_node);
        assert!(
            cfg.read_cache_bytes == 0 || cfg.fence_updates,
            "the read cache requires fence_updates: an unfenced update could \
             be cached stale indefinitely"
        );

        assert!(cfg.replicas >= 1, "replicas counts the authoritative copy; 0 stores nothing");
        assert!(
            cfg.replicas <= n,
            "replicas ({}) cannot exceed the cluster size ({n})",
            cfg.replicas
        );
        assert!(
            !cfg.replicated() || cfg.fence_updates,
            "replicas >= 2 requires fence_updates: backup frames must be placed \
             before a mutation returns, or recovery could resurrect stale values"
        );
        assert!(cfg.tracker_shards >= 1, "a node needs at least one tracker ring");

        let ep = Endpoint::new(name, me, n, Expect::AllPeers);
        let data = mgr.pool().alloc_named(&region_name(name, "data"), geo.total_words(), false);
        ep.add_local_region("data", data);
        // With replication on, every node also hosts one backup array
        // per rank (same slab geometry as `data`): `backup{r}` mirrors
        // the slots of the home that has us as rank-`r` successor,
        // `(me − 1 − r) mod n`.
        let backup_hosted: Vec<Region> = (0..cfg.replicas - 1)
            .map(|r| {
                let reg_name = format!("backup{r}");
                let reg = mgr.pool().alloc_named(
                    &region_name(name, &reg_name),
                    geo.total_words(),
                    false,
                );
                ep.add_local_region(&reg_name, reg);
                reg
            })
            .collect();
        let mut expect: Vec<String> = vec!["data".to_string()];
        expect.extend((0..cfg.replicas - 1).map(|r| format!("backup{r}")));
        let expect_refs: Vec<&str> = expect.iter().map(|s| s.as_str()).collect();
        ep.expect_regions(&expect_refs);
        mgr.register_channel(ep.clone());

        // Lock array, striped across nodes.
        let locks: Vec<TicketLock> = (0..cfg.num_locks)
            .map(|i| {
                TicketLock::with_options(
                    mgr,
                    &sub_name(name, &format!("lock{i}")),
                    (i % n) as NodeId,
                    FenceScope::Thread,
                    true,
                    cfg.lock_handover,
                )
            })
            .collect();

        // Our tracker rings (we broadcast; peers receive), one per
        // shard: keys route by `shard_of`, so apply parallelizes across
        // shards without giving up per-key order.
        let tracker_tx: Vec<Mutex<RingSender>> = (0..cfg.tracker_shards)
            .map(|s| {
                Mutex::new(RingSender::new(mgr, &tracker_ring_name(name, me, s), cfg.tracker_words))
            })
            .collect();

        // Op-shipping ring (§ Op routing): one served request ring per
        // node, created only when routing is on — with `OneSided` the
        // store is byte-for-byte the pre-routing one. The inline value
        // budget is capped at the fabric's inline-WRITE budget so a
        // shipped frame stays one inline WRITE; wider values simply
        // take the one-sided path.
        let ship = (cfg.routing != RouteMode::OneSided).then(|| {
            let inline = mgr.cluster().config().latency.max_inline_words;
            // 4 frame meta words (header, key, epoch, checksum) ride
            // along with the value in the one WRITE.
            let max_val = cfg.value_words.min(inline.saturating_sub(4)).max(1);
            Arc::new(RequestRing::new(mgr, &sub_name(name, "ship"), max_val))
        });

        let shared = Arc::new(KvShared {
            index: ShardedIndex::new(geo.total_slots() * n),
            cache: (cfg.read_cache_bytes > 0).then(|| ReadCache::new(cfg.read_cache_bytes)),
            alloc: SlabAllocator::new(geo),
            slot_counter: (0..geo.total_slots()).map(|_| AtomicU64::new(0)).collect(),
            reloc_origins: Mutex::new(HashMap::new()),
            membership: mgr.membership().clone(),
            tracker_shards: cfg.tracker_shards,
            epoch_marks: Mutex::new(HashMap::new()),
            tracker_ready: AtomicUsize::new(0),
            shutdown: AtomicBool::new(false),
        });

        // Race checker wiring (see `crate::analysis`): declare this
        // node's frame arrays as generation/checksum-validated regions
        // — torn or stale reads there are protocol-legal, so rule (a)
        // stays quiet — and feed the slab's birth/death transitions in
        // as the use-after-free ground truth (rule (b)). The free-side
        // event also reads the frame's `counter‖valid` word (still
        // under the allocator lock, so no re-allocation can interleave)
        // to catch slots retired with the valid bit up: every retire
        // protocol in this module unsets-and-fences cv *before* the
        // free, so a set bit at free time is a protocol violation.
        if let Some(chk) = mgr.cluster().checker() {
            let kind =
                crate::analysis::RegionKind::Frames { fenced_publication: cfg.fence_updates };
            chk.declare_region(me, data.base, data.len, kind);
            for reg in &backup_hosted {
                chk.declare_region(me, reg.base, reg.len, kind);
            }
            let chk = chk.clone();
            let node = mgr.cluster().node(me).clone();
            let base = data.base;
            shared.alloc.set_observer(Box::new(move |ev| match ev {
                SlabEvent::Alloc { slot } => {
                    let fw = geo.frame_words(geo.class_of(slot));
                    chk.on_slab_alloc(me, base + geo.slot_off(slot), fw);
                }
                SlabEvent::Free { slot } => {
                    let fw = geo.frame_words(geo.class_of(slot));
                    let fb = base + geo.slot_off(slot);
                    let cv = node.arena().load(fb + fw - 1);
                    chk.on_slab_free(me, slot, fb, fw, Some(cv), "kvstore::slab_free");
                }
            }));
        }

        let cfg_shards = cfg.tracker_shards;
        let kv = Arc::new(KvStore {
            cfg,
            me,
            num_nodes: n,
            ep,
            data,
            backup_hosted,
            cache_gate: EpochGate::new(),
            locks,
            tracker_tx,
            inval: (0..cfg_shards).map(|_| InvalCoalescer::new()).collect(),
            cluster: mgr.cluster().clone(),
            ship,
            heat: HeatTracker::new(),
            shared: shared.clone(),
            tracker_threads: Mutex::new(Vec::new()),
            ship_thread: Mutex::new(None),
        });

        // Dedicated tracker (§6): receives peers' tracker rings — one
        // receiver group per shard — applies index updates, then
        // acknowledges. Each group holds only KvShared and a
        // Weak<KvStore> (upgraded transiently for crash recovery) so
        // Drop/shutdown can run. Under the deterministic simulator each
        // shard's tracker is a scheduler *service* (stepped
        // non-blockingly by the single-threaded executor) instead of a
        // thread. Shard 0 owns the crash-recovery reaction (one driver
        // per node, as before sharding); the other shards only drain
        // and keep their apply-side dead screen fresh.
        let words = kv.cfg.tracker_words;
        if mgr.cluster().config().delivery == crate::fabric::DeliveryMode::Sim {
            for shard in 0..cfg_shards {
                let ctx = mgr.ctx();
                let mgr2 = mgr.clone();
                let shared2 = shared.clone();
                let weak = Arc::downgrade(&kv);
                let mut rxs: Vec<(NodeId, RingReceiver)> = (0..n as NodeId)
                    .filter(|&p| p != me)
                    .map(|p| {
                        let mut rx =
                            RingReceiver::new(mgr, &tracker_ring_name(name, p, shard), words);
                        rx.set_manual_ack();
                        (p, rx)
                    })
                    .collect();
                let mut known_dead: u64 = 0;
                let mut announced = false;
                let svc = if shard == 0 {
                    format!("kv-tracker-{me}")
                } else {
                    format!("kv-tracker-{me}s{shard}")
                };
                crate::sim::register_service(
                    svc,
                    Box::new(move || {
                        if shared2.shutdown.load(Ordering::Relaxed) {
                            return false;
                        }
                        if !announced {
                            // Setup phase: probe readiness without blocking —
                            // the manager's ctrl service completes the
                            // join/connect exchange between our steps.
                            if rxs.iter().all(|(_, rx)| rx.is_ready()) {
                                announced = true;
                                shared2.tracker_ready.fetch_add(1, Ordering::Release);
                                return true;
                            }
                            return false;
                        }
                        let mut did = false;
                        for (from, rx) in &mut rxs {
                            while let Some(msg) = rx.try_recv(&ctx) {
                                apply_tracker(&shared2, me, *from, &msg, known_dead);
                                rx.ack_now(&ctx); // apply THEN acknowledge (§6)
                                did = true;
                            }
                        }
                        let dead_mask = mgr2.membership().dead_mask();
                        if dead_mask != known_dead {
                            if shard == 0 {
                                for node in 0..n as NodeId {
                                    if dead_mask >> node & 1 == 1 && known_dead >> node & 1 == 0 {
                                        if let Some(kv) = weak.upgrade() {
                                            kv.on_peer_dead(&ctx, node);
                                        }
                                    }
                                }
                            }
                            known_dead = dead_mask;
                            did = true;
                        }
                        did
                    }),
                );
            }
            // The ship server is its own service: drains our request
            // ring and applies shipped updates under the key locks.
            if kv.ship.is_some() {
                let ctx = mgr.ctx();
                let weak = Arc::downgrade(&kv);
                crate::sim::register_service(
                    format!("kv-ship-{me}"),
                    Box::new(move || {
                        let Some(kv) = weak.upgrade() else { return false };
                        if kv.shared.shutdown.load(Ordering::Relaxed) {
                            return false;
                        }
                        kv.serve_shipped(&ctx)
                    }),
                );
            }
            return kv;
        }
        for shard in 0..cfg_shards {
            let mgr2 = mgr.clone();
            let name2 = name.to_string();
            let shared2 = shared.clone();
            let weak = Arc::downgrade(&kv);
            let tname = if shard == 0 {
                format!("kv-tracker-{me}")
            } else {
                format!("kv-tracker-{me}s{shard}")
            };
            let handle = std::thread::Builder::new()
                .name(tname)
                .spawn(move || tracker_loop(mgr2, name2, words, me, n, shard, shared2, weak))
                .expect("spawn tracker");
            kv.tracker_threads.lock().unwrap().push(handle);
        }
        if kv.ship.is_some() {
            let weak = Arc::downgrade(&kv);
            let mgr3 = mgr.clone();
            let handle = std::thread::Builder::new()
                .name(format!("kv-ship-{me}"))
                .spawn(move || {
                    let ctx = mgr3.ctx();
                    let mut bo = Backoff::new();
                    loop {
                        // Transient upgrade only: holding the Arc across
                        // the snooze would keep Drop from ever running.
                        let Some(kv) = weak.upgrade() else { break };
                        if kv.shared.shutdown.load(Ordering::Relaxed) {
                            break;
                        }
                        if kv.serve_shipped(&ctx) {
                            bo.reset();
                        } else {
                            drop(kv);
                            bo.snooze();
                        }
                    }
                })
                .expect("spawn ship server");
            *kv.ship_thread.lock().unwrap() = Some(handle);
        }
        kv
    }

    pub fn wait_ready(&self, timeout: Duration) {
        self.ep.wait_ready(timeout);
        for l in &self.locks {
            l.wait_ready(timeout);
        }
        if let Some(ring) = &self.ship {
            ring.wait_ready(timeout);
        }
        for tx in &self.tracker_tx {
            tx.lock().unwrap().wait_ready(timeout);
        }
        let mut bo = Backoff::new();
        let mut budget = crate::util::WaitBudget::wedge(timeout);
        while self.shared.tracker_ready.load(Ordering::Acquire) < self.tracker_tx.len() {
            assert!(!budget.expired(), "tracker not ready");
            bo.snooze();
        }
    }

    pub fn config(&self) -> &KvConfig {
        &self.cfg
    }

    /// Home node a prefill partitioner (and the rebalance driver)
    /// should use for `key`: the current owner of the key's range in
    /// the epoch-versioned ownership table — under a healthy full
    /// membership this degenerates to round-robin over nodes, but it
    /// tracks deaths, spares, and joins (see [`Membership::owners`]).
    /// Online inserts still go to the *inserting* node's data array, as
    /// in the paper; [`KvStore::rebalance`] is what pulls keys toward
    /// their owners.
    pub fn home_of(&self, key: u64) -> NodeId {
        self.shared.membership.owner(Membership::range_of(key), self.cfg.replicas)
    }

    #[inline]
    fn geo(&self) -> &SlabGeometry {
        self.shared.alloc.geometry()
    }

    /// Frame width (words) of `slot`'s class — what a reader READs.
    #[inline]
    fn frame_words_of(&self, slot: u32) -> usize {
        self.geo().frame_words(self.geo().class_of(slot)) as usize
    }

    #[inline]
    fn slot_off(&self, slot: u32) -> u64 {
        self.geo().slot_off(slot)
    }

    /// Offset of the `counter‖valid` word (fixed frame end).
    #[inline]
    fn cv_off(&self, slot: u32) -> u64 {
        self.slot_off(slot) + self.frame_words_of(slot) as u64 - 1
    }

    /// Build the writable frame prefix `[len‖class][value…][checksum]`
    /// for `slot` (the cv word at the frame end is managed separately).
    /// The checksum covers the **actual** value length — a header torn
    /// against its value shifts the checksum position, so the validation
    /// still rejects the mix.
    fn build_frame(&self, slot: u32, value: &[u64], reloc: bool) -> Vec<u64> {
        let class = self.geo().class_of(slot);
        debug_assert!(value.len() <= self.geo().cap(class));
        let mut frame = Vec::with_capacity(value.len() + 2);
        frame.push(pack_hdr(value.len(), class, reloc));
        frame.extend_from_slice(value);
        frame.push(fnv64(value));
        frame
    }

    /// Validate a read frame against the reader's index entry
    /// (Appendix C, extended with the variable-size header and the
    /// relocation marker).
    fn parse_frame(&self, e: &IndexEntry, words: &[u64]) -> FrameRead {
        let geo = self.geo();
        let class = geo.class_of(e.slot);
        let fw = geo.frame_words(class) as usize;
        debug_assert_eq!(words.len(), fw);
        let hdr = words[0];
        let len = hdr_len(hdr);
        if hdr_class(hdr) != class || len == 0 || len > geo.cap(class) {
            return FrameRead::Torn; // header from a write in flight
        }
        if fnv64(&words[1..1 + len]) != words[1 + len] {
            return FrameRead::Torn;
        }
        let cv = words[fw - 1];
        if cv >> 1 != e.counter {
            return FrameRead::Stale; // slot reused under a newer generation
        }
        if cv & 1 == 0 {
            // A relocation's frame before its valid-set is *about* to
            // linearize — the key exists throughout, so spin rather than
            // report EMPTY. Anything else unset means "insert not yet /
            // delete already linearized".
            return if hdr_reloc(hdr) { FrameRead::Pending } else { FrameRead::Stale };
        }
        FrameRead::Value(words[1..1 + len].to_vec())
    }

    fn data_region_of(&self, node: NodeId) -> Region {
        if node == self.me {
            self.data
        } else {
            self.ep.remote_region(node, "data")
        }
    }

    fn lock_of(&self, key: u64) -> &TicketLock {
        &self.locks[(key % self.cfg.num_locks as u64) as usize]
    }

    /// Host node of the ticket-lock stripe guarding `key`. Stripes are
    /// placed at construction and do **not** fail over: while the host
    /// is down, mutations of `key` fail fast and [`KvStore::rebalance`]
    /// skips it. The key stays readable and crash re-homes still cover
    /// it (recovery takes no key locks), but it cannot migrate — so
    /// convergence checkers exempt corpse-locked keys from placement
    /// invariants ([`crate::testkit::check_convergence`]).
    pub fn lock_host(&self, key: u64) -> NodeId {
        ((key % self.cfg.num_locks as u64) as usize % self.num_nodes) as NodeId
    }

    /// Backup replica count (`replicas − 1`).
    #[inline]
    fn backup_count(&self) -> usize {
        self.cfg.replicas - 1
    }

    /// The node holding the rank-`rank` backup replica of `node`'s slot
    /// array: its `rank+1`-th static successor.
    fn backup_of(&self, node: NodeId, rank: usize) -> NodeId {
        ((node as usize + 1 + rank) % self.num_nodes) as NodeId
    }

    /// Rank-`rank` backup region for slots homed on `node` (replicated
    /// only).
    fn backup_region_of(&self, node: NodeId, rank: usize) -> Region {
        let b = self.backup_of(node, rank);
        if b == self.me {
            self.backup_hosted[rank]
        } else {
            self.ep.remote_region(b, &format!("backup{rank}"))
        }
    }

    /// Write a full class-sized frame `[hdr][value…][ck]…[cv]` into
    /// EVERY backup replica of OUR slot `slot` and fence the chain
    /// placed — one covered write per rank, one signaled fence for all
    /// of them (§7.2 selective signaling). Dead backup nodes are
    /// tolerated: the surviving copies are what the fault model needs
    /// (`replicas` copies survive any `replicas − 1` crash-stops).
    fn write_backup_frame(&self, ctx: &ThreadCtx, slot: u32, frame: &[u64], cv: u64) {
        let fw = self.frame_words_of(slot);
        let mut full = vec![0u64; fw];
        full[..frame.len()].copy_from_slice(frame);
        full[fw - 1] = cv;
        for rank in 0..self.backup_count() {
            // Covered: the fence right below is the chain's one CQE.
            ctx.write_covered(self.backup_region_of(self.me, rank), self.slot_off(slot), &full);
        }
        let _ = ctx.try_fence(FenceScope::Thread);
    }

    /// Block until the index entry for `key` moves away from `old` —
    /// the signature of a crash re-home (new home node) or a recovery
    /// drop (`None`). Callers park here when they catch `old.node`
    /// crash-stopped; the membership machinery guarantees the entry
    /// changes within the recovery pass. `Err` only if *this* node is
    /// the corpse (nobody re-homes for the dead).
    fn wait_entry_change(
        &self,
        ctx: &ThreadCtx,
        key: u64,
        old: &IndexEntry,
    ) -> crate::Result<Option<IndexEntry>> {
        let mut bo = Backoff::new();
        let mut budget = crate::util::WaitBudget::wedge(Duration::from_secs(30));
        loop {
            let cur = self.shared.index.get(key);
            if cur != Some(*old) {
                return Ok(cur);
            }
            if ctx.node_down(self.me) {
                return Err(crate::Error::PeerFailed(
                    "local node crash-stopped mid-operation".into(),
                ));
            }
            assert!(
                !budget.expired(),
                "key {key}: home node {} crashed and no re-home/purge arrived \
                 within 30 s (replicas={})",
                old.node,
                self.cfg.replicas
            );
            bo.snooze();
        }
    }

    /// Send a tracker message stamped with this node's membership epoch
    /// — appended as the **last** word, so receivers strip it before
    /// parsing and every per-opcode layout stays unchanged. The stamp is
    /// what lets receivers reject stale-owner broadcasts (a pre-crash
    /// message delivered after its sender's slot re-joined), not just
    /// ones from currently dead homes; see `apply_tracker`.
    fn send_tracker(&self, ctx: &ThreadCtx, tx: &RingSender, msg: &[u64]) {
        // Publication point for the race checker's rule (c): a tracker
        // broadcast announces state other nodes will act on, so every
        // covered frame write this thread issued must be fenced by now.
        // (Must run before the ring write below: the ring's own
        // flushing ops would clear the pending set and mask the bug.)
        ctx.note_publication("kvstore::send_tracker");
        let mut stamped = Vec::with_capacity(msg.len() + 1);
        stamped.extend_from_slice(msg);
        stamped.push(self.shared.membership.epoch());
        tx.send(ctx, &stamped);
    }

    /// The tracker ring `key`'s broadcasts ride: every op about one key
    /// goes through the same shard, so per-key apply order survives
    /// sharding.
    #[inline]
    fn tracker_shard(&self, key: u64) -> &Mutex<RingSender> {
        &self.tracker_tx[shard_of(key, self.tracker_tx.len())]
    }

    /// Broadcast a key-routed op on the key's shard ring and wait until
    /// every live peer acknowledged it.
    fn send_tracker_keyed(&self, ctx: &ThreadCtx, key: u64, msg: &[u64]) {
        let tx = self.tracker_shard(key).lock().unwrap();
        self.send_tracker(ctx, &tx, msg);
        let pos = tx.position();
        tx.wait_all_acked(ctx, pos);
    }

    /// Broadcast a membership/epoch op on **every** shard ring, waiting
    /// out each ring's acks: these ops must order after the keyed
    /// traffic of all shards (per-ring FIFO is the only order the
    /// tracker protocol has), so they ride all of them. Receivers apply
    /// them idempotently — see `apply_tracker`'s `OP_JOIN`/`OP_ALIVE`
    /// handling and `KvShared::note_epoch_mark`.
    fn send_tracker_all_shards(&self, ctx: &ThreadCtx, msg: &[u64]) {
        for txm in &self.tracker_tx {
            let tx = txm.lock().unwrap();
            self.send_tracker(ctx, &tx, msg);
            let pos = tx.position();
            tx.wait_all_acked(ctx, pos);
        }
    }

    /// The cache serves only *remote-homed* slots: local reads are
    /// already a couple of loads, and skipping them keeps the whole
    /// capacity for keys that actually cost a network round trip.
    #[inline]
    fn cache_for(&self, e: &IndexEntry) -> Option<&ReadCache> {
        self.shared.cache.as_ref().filter(|_| e.node != self.me)
    }

    /// Epoch-key the locality tier against elastic membership: on any
    /// membership transition (death, join, join-complete) the whole
    /// cache drops, so entries filled under a superseded ownership
    /// table cannot serve into the new one. Exactly one thread performs
    /// the clear per transition (see [`EpochGate`]); read paths call
    /// this before consulting the cache.
    #[inline]
    fn check_cache_epoch(&self) {
        if let Some(cache) = &self.shared.cache {
            if self.cache_gate.advance(self.shared.membership.epoch()) {
                cache.clear();
            }
        }
    }

    // ---- operations -------------------------------------------------

    /// Assert `value` is a legal width for this config (any length up to
    /// the configured maximum — the slab picks the class).
    #[inline]
    fn check_value_len(&self, value: &[u64]) {
        assert!(
            !value.is_empty() && value.len() <= self.cfg.value_words,
            "value length {} outside 1..={} words",
            value.len(),
            self.cfg.value_words
        );
    }

    /// Insert (or update-in-place if present). Returns Ok(true) if a new
    /// key was inserted. `Err(Error::PeerFailed)` when the key's lock is
    /// hosted on a crash-stopped node (the mutation did not happen; see
    /// the failure model in `docs/ARCHITECTURE.md`).
    pub fn insert(&self, ctx: &ThreadCtx, key: u64, value: &[u64]) -> Result<bool> {
        self.check_value_len(value);
        let lock = self.lock_of(key);
        lock.try_lock(ctx)?;
        let res = self.insert_locked(ctx, key, value);
        lock.unlock(ctx);
        res
    }

    fn insert_locked(&self, ctx: &ThreadCtx, key: u64, value: &[u64]) -> Result<bool> {
        loop {
            if let Some(e) = self.shared.index.get(key) {
                if self.locked_update(ctx, key, e, value)? {
                    return Ok(false);
                }
                // The key vanished while its dead home was recovered:
                // re-resolve — this is now a fresh insert.
                continue;
            }
            let Some(slot) = self.shared.alloc.alloc(value.len()) else {
                return Err(Error::Capacity(format!(
                    "node {} out of kv slots for a {}-word value",
                    self.me,
                    value.len()
                )));
            };
            let counter = self.bump_counter(slot);
            // Local write: header, value, checksum, counter with valid
            // UNSET.
            let frame = self.build_frame(slot, value, false);
            self.store_frame_local(ctx, slot, &frame, counter << 1);
            // Backup replica before the broadcast, already valid: if we
            // crash before returning, recovery resurrecting a
            // never-linearized insert is harmless (no reader could have
            // relied on EMPTY — the insert never responded), while the
            // reverse order could lose an insert that *did* respond.
            if self.cfg.replicated() {
                self.write_backup_frame(ctx, slot, &frame, (counter << 1) | 1);
            }

            // Our own index first, then broadcast to peers and await acks.
            self.shared.index.insert(key, IndexEntry { node: self.me, slot, counter });
            self.send_tracker_keyed(ctx, key, &[OP_INSERT, key, self.me as u64, slot as u64, counter]);
            // All indices now hold the location: set valid (linearization pt).
            ctx.local_store(self.data, self.cv_off(slot), (counter << 1) | 1);
            return Ok(true);
        }
    }

    /// Bump and return the fresh generation for a local `slot`.
    #[inline]
    fn bump_counter(&self, slot: u32) -> u64 {
        self.shared.slot_counter[self.geo().ordinal(slot)].fetch_add(1, Ordering::Relaxed) + 1
    }

    /// Store a frame prefix plus its cv word into OUR data region with
    /// plain local stores.
    fn store_frame_local(&self, ctx: &ThreadCtx, slot: u32, frame: &[u64], cv: u64) {
        let off = self.slot_off(slot);
        for (i, w) in frame.iter().enumerate() {
            ctx.local_store(self.data, off + i as u64, *w);
        }
        ctx.local_store(self.data, self.cv_off(slot), cv);
    }

    /// Update an existing key (in place, or relocating if the value
    /// outgrew its slot's class). Returns false if absent. Panics on an
    /// unrecoverable peer failure or relocation capacity exhaustion —
    /// use [`KvStore::try_update`] when either is expected.
    pub fn update(&self, ctx: &ThreadCtx, key: u64, value: &[u64]) -> bool {
        self.try_update(ctx, key, value).expect("kv update: peer failure or slab capacity")
    }

    /// Crash-stop-aware update: `Ok(false)` if the key is absent (or was
    /// dropped by crash recovery), `Err(Error::PeerFailed)` if the key's
    /// lock is hosted on a dead node (the mutation did not happen). A
    /// home node dying *mid-update* is handled internally: the op waits
    /// for the membership epoch's re-home and retries against the new
    /// location, so an `Ok(true)` always means the value is durable on
    /// the current home.
    pub fn try_update(&self, ctx: &ThreadCtx, key: u64, value: &[u64]) -> Result<bool> {
        self.try_update_outcome(ctx, key, value).map(|o| o.applied)
    }

    /// [`KvStore::try_update`] with the full [`UpdateOutcome`]: history
    /// recorders need the `ambiguous` flag (an op completed through the
    /// post-crash ship fallback has no single provable linearization
    /// point and must be recorded with CRASHED-style uncertainty).
    pub fn try_update_outcome(
        &self,
        ctx: &ThreadCtx,
        key: u64,
        value: &[u64],
    ) -> Result<UpdateOutcome> {
        self.check_value_len(value);
        // Route BEFORE taking the lock: the shipping client never holds
        // a ticket lock (the server takes it), so ship-vs-one-sided can
        // never deadlock against the lock order.
        if self.route_mutation(key) == RouteDecision::Ship {
            match self.ship_update(ctx, key, value) {
                ShipOutcome::Done(applied) => {
                    return Ok(UpdateOutcome { applied, ambiguous: false })
                }
                ShipOutcome::Ambiguous => return self.ambiguous_ship_fallback(ctx, key, value),
                ShipOutcome::NotShipped => {} // fall through one-sided
            }
        }
        let lock = self.lock_of(key);
        lock.try_lock(ctx)?;
        let res = match self.shared.index.get(key) {
            None => Ok(false),
            Some(e) => self.locked_update(ctx, key, e, value),
        };
        lock.unlock(ctx);
        res.map(|applied| UpdateOutcome { applied, ambiguous: false })
    }

    // ---- op routing (one-sided vs op-shipping) ------------------------

    /// Pick the path for one mutation of `key` (see
    /// `docs/ARCHITECTURE.md § Op routing`). `Adaptive` samples the
    /// per-key heat tracker, folding in whether the key's ticket lock
    /// saw contention since its last sample.
    fn route_mutation(&self, key: u64) -> RouteDecision {
        if self.ship.is_none() {
            return RouteDecision::OneSided;
        }
        match self.cfg.routing {
            RouteMode::OneSided => RouteDecision::OneSided,
            RouteMode::Ship => RouteDecision::Ship,
            RouteMode::Adaptive => {
                let contended = self.lock_of(key).take_contended();
                let (d, flipped) = self.heat.sample(key, contended);
                if flipped {
                    self.cluster.note_route_flip(self.me);
                }
                d
            }
        }
    }

    /// Ship an in-place update to the key's home node. `Done` is a
    /// definite server answer; `NotShipped` means nothing was applied
    /// and the caller should take the one-sided path (local home,
    /// oversized value, dead/mid-move home, or a server that answered
    /// WRONG_HOME/RETRY every attempt). `Ambiguous` means the server
    /// died between our enqueue and its reply — it may have applied
    /// (and replicated) the value first, so a blind one-sided re-apply
    /// is NOT invisible to readers: another write can land at the
    /// promoted re-home in between, and re-applying would resurrect
    /// our value over it. [`KvStore::ambiguous_ship_fallback`] owns
    /// that case.
    fn ship_update(&self, ctx: &ThreadCtx, key: u64, value: &[u64]) -> ShipOutcome {
        let Some(ring) = self.ship.as_ref() else {
            return ShipOutcome::NotShipped;
        };
        if value.len() > ring.max_value_words() {
            return ShipOutcome::NotShipped; // outgrew the inline budget
        }
        for _ in 0..SHIP_ATTEMPTS {
            let Some(e) = self.shared.index.get(key) else {
                // Absent at the index-read instant: the same legal
                // "absent" linearization `get` uses — and unlike the
                // one-sided path, no lock host needs to be alive.
                return ShipOutcome::Done(false);
            };
            if e.node == self.me || ctx.node_down(e.node) {
                // Local apply is strictly cheaper one-sided; a dead
                // home needs the one-sided path's re-home parking.
                return ShipOutcome::NotShipped;
            }
            self.cluster.note_op_shipped(self.me);
            let epoch = self.shared.membership.epoch();
            match ring.call(ctx, e.node, SHIP_UPDATE, key, epoch, value) {
                Ok(rep) if rep.status == SHIP_APPLIED => return ShipOutcome::Done(true),
                Ok(rep) if rep.status == SHIP_MISSING => return ShipOutcome::Done(false),
                Ok(_) => continue, // WRONG_HOME / RETRY: re-resolve
                // The server died mid-call: the request may already
                // have been drained and applied before the crash.
                Err(_) => return ShipOutcome::Ambiguous,
            }
        }
        ShipOutcome::NotShipped
    }

    /// Complete an update whose shipped attempt ended ambiguously (the
    /// server crash-stopped between enqueue and reply). Under the key
    /// lock, first probe the current frame: if the shipped value is
    /// already in place, the server's apply (or an identical write)
    /// landed and survived recovery — re-writing it would only create a
    /// second application point, so skip the write and report a
    /// definite success (the probe instant, inside our interval, is a
    /// valid linearization point). Otherwise re-apply one-sided; the
    /// value is then durably in place, but if the server HAD applied
    /// pre-crash and another write slipped in at the promoted re-home,
    /// this op has two application points with a foreign write between
    /// them — report `ambiguous` so history recorders give the op
    /// CRASHED-style uncertainty rather than a definite interval.
    fn ambiguous_ship_fallback(
        &self,
        ctx: &ThreadCtx,
        key: u64,
        value: &[u64],
    ) -> Result<UpdateOutcome> {
        self.cluster.note_ship_fallback(self.me);
        let lock = self.lock_of(key);
        lock.try_lock(ctx)?;
        let res = self.ambiguous_ship_fallback_locked(ctx, key, value);
        lock.unlock(ctx);
        res
    }

    fn ambiguous_ship_fallback_locked(
        &self,
        ctx: &ThreadCtx,
        key: u64,
        value: &[u64],
    ) -> Result<UpdateOutcome> {
        let Some(mut e) = self.shared.index.get(key) else {
            // Dropped by crash recovery (or a racing delete): the same
            // definite "absent" answer the one-sided path gives.
            return Ok(UpdateOutcome { applied: false, ambiguous: false });
        };
        // The shipped target just died, so the entry usually still
        // names the corpse: park for the re-home exactly like the
        // one-sided path before trusting any frame.
        while ctx.node_down(e.node) {
            match self.wait_entry_change(ctx, key, &e)? {
                Some(ne) => e = ne,
                None => return Ok(UpdateOutcome { applied: false, ambiguous: false }),
            }
        }
        if self.probe_value_locked(ctx, &e).as_deref() == Some(value) {
            self.cluster.note_ship_fallback_confirmed(self.me);
            return Ok(UpdateOutcome { applied: true, ambiguous: false });
        }
        let applied = self.locked_update(ctx, key, e, value)?;
        // Only a performed re-apply is uncertain; "key vanished" stays
        // a definite answer.
        Ok(UpdateOutcome { applied, ambiguous: applied })
    }

    /// One best-effort validated read of `e`'s frame (no cache fill, no
    /// retry loop) — the ambiguous-fallback probe. Any unreadable or
    /// non-validating frame is `None` ("could not confirm"), which the
    /// caller treats conservatively.
    fn probe_value_locked(&self, ctx: &ThreadCtx, e: &IndexEntry) -> Option<Vec<u64>> {
        let region = self.data_region_of(e.node);
        let words = ctx.try_read(region, self.slot_off(e.slot), self.frame_words_of(e.slot)).ok()?;
        match self.parse_frame(e, &words) {
            FrameRead::Value(v) => Some(v),
            _ => None,
        }
    }

    /// Serve one sweep of our request ring (the ship server's loop
    /// body; a simulator service in sim mode, a thread otherwise).
    /// Returns whether any work was done.
    ///
    /// Same-key requests in one sweep are **write-combined**: all of
    /// them are pending concurrently, so applying only the last value
    /// and acking every rider linearizes them back-to-back at that one
    /// apply — the batch analogue of `multi_put`'s collapse, minus the
    /// frame writes the riders no longer cost.
    fn serve_shipped(&self, ctx: &ThreadCtx) -> bool {
        let Some(ring) = &self.ship else { return false };
        if !ring.is_ready() || self.shared.tracker_ready.load(Ordering::Acquire) < self.tracker_tx.len()
        {
            return false;
        }
        if ctx.node_down(self.me) {
            return false; // a corpse serves nothing (crash-stop)
        }
        let reqs = ring.drain(ctx);
        if reqs.is_empty() {
            return false;
        }
        // Per-request screen BEFORE write-combining: only requests that
        // would individually pass `apply_shipped`'s op-code and
        // membership-epoch checks may ride a same-key neighbour's apply
        // — a rider shipped under a stale epoch (or, if an op code is
        // ever added, a different op) must get its own
        // WRONG_HOME/RETRY, not be acked with the last request's
        // outcome. (The epoch can still advance between this screen and
        // the apply; `apply_shipped` re-checks and the combined answer
        // only gets more conservative.)
        let epoch = self.shared.membership.epoch();
        let screened = |req: &crate::channels::OpReq| req.op == SHIP_UPDATE && req.aux == epoch;
        // Last screened occurrence per key wins; earlier screened
        // riders share its fate.
        let mut last_of: HashMap<u64, usize> = HashMap::with_capacity(reqs.len());
        for (i, req) in reqs.iter().enumerate() {
            if screened(req) {
                last_of.insert(req.key, i);
            }
        }
        // Apply in drain order (not map order): the sweep must be a
        // deterministic function of ring state under the simulator.
        let mut outcome: HashMap<u64, (u8, u64)> = HashMap::with_capacity(last_of.len());
        for (i, req) in reqs.iter().enumerate() {
            if last_of.get(&req.key) == Some(&i) {
                outcome.insert(req.key, self.apply_shipped(ctx, req));
            }
        }
        for req in &reqs {
            let (status, retval) = if req.op != SHIP_UPDATE {
                (SHIP_RETRY, 0)
            } else if req.aux != epoch {
                // Routed under another membership epoch: re-resolve
                // rather than guess whose view is ahead.
                (SHIP_WRONG_HOME, 0)
            } else {
                outcome[&req.key]
            };
            ring.reply(ctx, req, status, retval);
        }
        true
    }

    /// Apply one shipped update under the key lock. The index is
    /// re-resolved **under the lock** — a key mid-migration (rebalance,
    /// relocation, crash re-home) moves only under this same lock, so
    /// `e.node == me` checked here is authoritative; the client's
    /// shipped epoch is an additional staleness screen.
    fn apply_shipped(&self, ctx: &ThreadCtx, req: &crate::channels::OpReq) -> (u8, u64) {
        if req.op != SHIP_UPDATE {
            return (SHIP_RETRY, 0);
        }
        if req.aux != self.shared.membership.epoch() {
            // The client routed under another membership epoch; make it
            // re-resolve rather than guess whose view is ahead.
            return (SHIP_WRONG_HOME, 0);
        }
        match self.shared.index.get(req.key) {
            None => return (SHIP_MISSING, 0),
            Some(e) if e.node != self.me => return (SHIP_WRONG_HOME, 0),
            Some(_) => {}
        }
        let lock = self.lock_of(req.key);
        if lock.try_lock(ctx).is_err() {
            return (SHIP_RETRY, 0); // lock host dead: client falls back
        }
        let res = match self.shared.index.get(req.key) {
            None => Ok((SHIP_MISSING, 0)),
            Some(e) if e.node != self.me => Ok((SHIP_WRONG_HOME, 0)),
            Some(e) => {
                self.locked_update(ctx, req.key, e, &req.val).map(|applied| {
                    if applied {
                        (SHIP_APPLIED, 0)
                    } else {
                        (SHIP_MISSING, 0)
                    }
                })
            }
        };
        lock.unlock(ctx);
        res.unwrap_or((SHIP_RETRY, 0))
    }

    /// The locked mutate path shared by update and insert-over-existing,
    /// with the crash-recovery retry loop: a home that crash-stops
    /// before the write is placed gets re-resolved via
    /// [`KvStore::wait_entry_change`] and the write retried against the
    /// new location. Values that still fit their slot's class are
    /// written in place; values that outgrew it **relocate** (see the
    /// module docs). Returns whether the value was applied (false: the
    /// key vanished — deleted by recovery or a racing delete).
    fn locked_update(
        &self,
        ctx: &ThreadCtx,
        key: u64,
        mut e: IndexEntry,
        value: &[u64],
    ) -> Result<bool> {
        loop {
            if ctx.node_down(e.node) {
                match self.wait_entry_change(ctx, key, &e)? {
                    Some(ne) => {
                        e = ne;
                        continue;
                    }
                    None => return Ok(false),
                }
            }
            if value.len() > self.geo().cap(self.geo().class_of(e.slot)) {
                // Outgrew the slot's class: fresh slot, fresh
                // generation, location broadcast. Linearizes at the new
                // frame's valid-set; no OP_INVAL needed (the OP_INSERT
                // apply invalidates caches, and the generation moved).
                self.relocate_locked(ctx, key, e, value)?;
                return Ok(true);
            }
            match self.write_value(ctx, &e, value) {
                Ok(()) => break,
                Err(err) => {
                    if ctx.node_down(self.me) {
                        // WE died mid-write: nobody re-homes for us, so
                        // retrying would spin forever. Surface it.
                        return Err(err);
                    }
                    // Home died mid-write: loop re-checks, re-resolves.
                }
            }
        }
        self.invalidate_updated(ctx, &[key]);
        Ok(true)
    }

    /// Relocate `key` from `old` into a fresh **local** slot (the
    /// relocation analogue of an insert — online placement follows the
    /// mutating node, as in the paper). Caller holds the key lock.
    ///
    /// Ordering (the relocation consistency story, see module docs):
    /// new frame written valid-UNSET with the `HDR_RELOC` marker →
    /// backup replica (valid set, like an insert's) → own index +
    /// `OP_INSERT` broadcast, **all acks** → valid-set (linearization
    /// point) → old frame's valid bit unset + fenced → old slot retired
    /// (`OP_FREE`, ack-waited so a quiesced store audits clean). The
    /// old frame keeps its old value and valid bit until *after* the
    /// linearization: readers whose snapshot predates the broadcast
    /// serve it legally (their invocation predates the linearization
    /// point); readers at the new frame pre-valid-set spin on the RELOC
    /// marker; readers that catch the old frame retired re-resolve
    /// through the index, which already names the new location. The
    /// old home's crash racing this is arbitrated by `OP_REHOME`'s
    /// compare-and-swap (the relocation wins).
    fn relocate_locked(
        &self,
        ctx: &ThreadCtx,
        key: u64,
        old: IndexEntry,
        value: &[u64],
    ) -> Result<()> {
        let Some(slot) = self.shared.alloc.alloc(value.len()) else {
            return Err(Error::Capacity(format!(
                "node {} out of kv slots relocating a {}-word value",
                self.me,
                value.len()
            )));
        };
        let counter = self.bump_counter(slot);
        let frame = self.build_frame(slot, value, true);
        self.store_frame_local(ctx, slot, &frame, counter << 1);
        if self.cfg.replicated() {
            // Valid in the backup: if we crash before setting the live
            // bit, recovery resurrects the relocated value — the update
            // never responded, so either outcome is linearizable, and
            // the old entry no longer names a frame recovery would pick.
            self.write_backup_frame(ctx, slot, &frame, (counter << 1) | 1);
        }
        self.shared.invalidate(key);
        self.shared.index.insert(key, IndexEntry { node: self.me, slot, counter });
        // The 8-word relocation form: receivers record the origin so a
        // crash of THIS node mid-protocol reverts the key to its old
        // location instead of dropping it.
        self.send_tracker_keyed(
            ctx,
            key,
            &[
                OP_INSERT,
                key,
                self.me as u64,
                slot as u64,
                counter,
                old.node as u64,
                old.slot as u64,
                old.counter,
            ],
        );
        // Every index now names the new location: linearize.
        ctx.local_store(self.data, self.cv_off(slot), (counter << 1) | 1);
        // Retire the old slot. FIRST unset its valid bit and prove the
        // unset placed: the old frame deliberately kept serving the
        // pre-update value until the linearization above, but a
        // freed-and-reused slot must never be reachable through a stale
        // entry with a still-valid cv — a reuse's insert writes its
        // frame bytes before its own cv word, and a reader holding the
        // pre-relocation entry could otherwise validate the NEW key's
        // checksummed bytes against the OLD generation. With the unset
        // placed, stale readers take the Stale/Pending path and
        // re-resolve to the new location (every index already names
        // it). Then free (locally, or via OP_FREE — which also prunes
        // the origin records everywhere, doubling as the "relocation
        // completed" marker). A dead old home keeps its slots.
        let old_cv = old.counter << 1;
        if old.node == self.me {
            if cfg!(loco_mutant_uaf) {
                // `--cfg loco_mutant_uaf` (mutation smoke-check):
                // retire the slot while its cv still carries the valid
                // bit, then unset it on a range the free list already
                // owns. The checker must catch both halves — the
                // valid-at-free structural violation and the dynamic
                // write into the dead range.
                self.shared.alloc.free(old.slot);
                ctx.local_store(self.data, self.cv_off(old.slot), old_cv);
            } else {
                ctx.local_store(self.data, self.cv_off(old.slot), old_cv);
                self.shared.alloc.free(old.slot);
            }
        } else if !ctx.node_down(old.node) {
            // Covered unset (the fence is the chain's signaled op).
            ctx.write_covered(self.data_region_of(old.node), self.cv_off(old.slot), &[old_cv]);
            // Fence failure means the old home (or we) just died: its
            // slots die with it either way.
            let _ = ctx.try_fence(FenceScope::Pair(old.node));
        }
        // Same shard as the relocation's OP_INSERT above (routed by the
        // same key), so the old home learns the new location FIFO-before
        // the free can let it reuse the slot.
        self.send_tracker_keyed(ctx, key, &[OP_FREE, old.node as u64, old.slot as u64, key]);
        Ok(())
    }

    /// The locked in-place write path shared by update and
    /// insert-over-existing: write `[hdr][value][checksum]` (the header
    /// carries the new actual length; the class cannot change in place)
    /// mirrored to the backup replica when replication is on, then fence
    /// so the write is placed before the lock release (§7.2). `Err` iff
    /// the home node crash-stopped before placement was proven — the
    /// caller re-resolves and retries; dead *backups* are tolerated
    /// (the surviving copies satisfy the `replicas − 1` fault budget).
    ///
    /// With `fence_updates` the frame writes are **covered** (selective
    /// signaling): no CQE per frame — the fence's flushing read is the
    /// chain's covering signaled op, and a dead home fails that
    /// completion via the QP chain error, exactly like the old per-write
    /// CQE did. Small-class frames also go out **inline** (picked
    /// automatically by the context), skipping the NIC's payload-fetch
    /// round.
    fn write_value(&self, ctx: &ThreadCtx, e: &IndexEntry, value: &[u64]) -> Result<()> {
        let region = self.data_region_of(e.node);
        let off = self.slot_off(e.slot);
        let buf = self.build_frame(e.slot, value, false);
        if self.cfg.fence_updates {
            ctx.write_covered(region, off, &buf); // the fence covers the chain
            for rank in 0..self.backup_count() {
                // Mirror [hdr][value][ck] to every rank; the cv word is
                // untouched (in-place updates do not change the
                // generation).
                ctx.write_covered(self.backup_region_of(e.node, rank), off, &buf);
            }
        } else {
            ctx.write(region, off, &buf); // unfenced ablation: completion dropped
            for rank in 0..self.backup_count() {
                ctx.write(self.backup_region_of(e.node, rank), off, &buf);
            }
        }
        // `--cfg loco_mutant_fence` (mutation smoke-check): drop the
        // covering fence, leaving the frame writes above unplaced when
        // the caller publishes the update (cache invalidation / lock
        // release). The checker must catch this as
        // publication-before-fence, localized to THIS chain — the
        // backup writes of inserts/relocations are fenced inside
        // `write_backup_frame` and must stay quiet.
        if self.cfg.fence_updates && !cfg!(loco_mutant_fence) {
            let scope = if self.cfg.replicated() {
                FenceScope::Thread // covers home and backup peers alike
            } else {
                FenceScope::Pair(e.node)
            };
            if ctx.try_fence(scope).is_err() {
                if ctx.node_down(self.me) {
                    // WE crash-stopped: the write was never transmitted;
                    // reporting success would violate the durability
                    // contract of Ok.
                    return Err(Error::PeerFailed("local node crashed mid-update".into()));
                }
                if ctx.node_down(e.node) {
                    return Err(Error::PeerFailed(format!(
                        "home node {} crashed mid-update",
                        e.node
                    )));
                }
                // Only dead *backups* remain: tolerated — the home's
                // flush still completed and the surviving copies cover
                // the fault budget.
            }
        }
        Ok(())
    }

    /// Post-update cache invalidation (locality tier). In-place updates
    /// don't bump the slot counter, so with the cache enabled they must
    /// purge every node's cached copy before returning: our own cache
    /// directly, peers via an `OP_INVAL` tracker broadcast that is
    /// applied *before* it is acknowledged. Callers hold the key lock(s)
    /// and have already placed (fenced) the value write.
    ///
    /// With [`KvConfig::coalesce_invals`] (the default), concurrent
    /// updates on this node **merge** their broadcasts: each updater
    /// enqueues its keys and the next snapshot — taken by whichever
    /// thread gets there first — ships every pending key as one
    /// doorbell-batched, singly-signaled multicast; the snapshot's one
    /// union ack wait releases all riders. Safe because a key is only
    /// enqueued *after* its value write was fenced placed, so every
    /// broadcast invalidation is applied after the value it covers.
    fn invalidate_updated(&self, ctx: &ThreadCtx, keys: &[u64]) {
        let Some(cache) = &self.shared.cache else { return };
        if keys.is_empty() {
            return;
        }
        cache.invalidate_many(keys.iter().copied());
        if cfg!(loco_mutant) {
            // Intentional bug for mutation-smoke runs (`--cfg
            // loco_mutant`): skip the peer broadcast, leaving remote
            // caches serving the stale pre-update value. The model
            // harness must find and shrink this.
            return;
        }
        let shards = self.tracker_tx.len();
        if !self.cfg.coalesce_invals {
            // Pre-coalescing baseline: one broadcast round (send + full
            // ack wait) per chunk, per caller — chunks grouped per
            // shard so each key rides its own ring.
            for (shard, keys) in group_by_shard(keys, shards) {
                let tx = self.tracker_tx[shard].lock().unwrap();
                for chunk in keys.chunks(INVAL_CHUNK) {
                    self.send_tracker(ctx, &tx, &encode_inval(chunk));
                    let pos = tx.position();
                    tx.wait_all_acked(ctx, pos);
                }
            }
            return;
        }
        // Publication point (rule (c)): enqueueing keys into the
        // coalescer is this updater's announcement — the broadcast
        // itself may be shipped by a *different* thread, so the check
        // must anchor here, on the updater's own pending-fence state.
        ctx.note_publication("kvstore::invalidate_updated");
        for (shard, keys) in group_by_shard(keys, shards) {
            self.coalesce_shard(ctx, shard, &keys);
        }
    }

    /// One shard's coalesced-invalidation group commit (see
    /// [`InvalCoalescer`]): enqueue this updater's keys — all already
    /// routed to `shard` — and return once a snapshot that carries them
    /// is fully acked, broadcasting it ourselves if we get there first.
    fn coalesce_shard(&self, ctx: &ThreadCtx, shard: usize, keys: &[u64]) {
        let co = &self.inval[shard];
        let mut st = co.st.lock().unwrap();
        st.pending.extend_from_slice(keys);
        // The first snapshot taken after this enqueue carries our keys:
        // the one about to start (`next_batch`) — possibly by us.
        let my_batch = st.next_batch;
        loop {
            if st.done_batch > my_batch {
                return; // our snapshot is fully acked on every peer
            }
            if !st.in_flight {
                // Become the broadcaster for snapshot `next_batch`
                // (which still holds our keys).
                let mut batch = std::mem::take(&mut st.pending);
                let id = st.next_batch;
                st.next_batch += 1;
                st.in_flight = true;
                drop(st);
                batch.sort_unstable();
                batch.dedup(); // concurrent updates of one key need one entry
                self.send_inval_snapshot(ctx, shard, &batch);
                st = co.st.lock().unwrap();
                st.done_batch = id + 1;
                st.in_flight = false;
                co.cv.notify_all();
            } else if crate::sim::active() {
                // Single-threaded simulation: no other thread will ever
                // signal the condvar — release the mutex and pump the
                // scheduler instead.
                drop(st);
                Backoff::new().snooze();
                st = co.st.lock().unwrap();
            } else {
                st = co.cv.wait(st).unwrap();
            }
        }
    }

    /// Ship one coalesced invalidation snapshot on `shard`'s ring:
    /// every chunk is sent back to back (the ring writes ride the
    /// batched pipeline), then **one** ack wait at the final position
    /// covers the union — not one round per chunk.
    fn send_inval_snapshot(&self, ctx: &ThreadCtx, shard: usize, keys: &[u64]) {
        let tx = self.tracker_tx[shard].lock().unwrap();
        for chunk in keys.chunks(INVAL_CHUNK) {
            self.send_tracker(ctx, &tx, &encode_inval(chunk));
        }
        let pos = tx.position();
        tx.wait_all_acked(ctx, pos);
    }

    /// Lock-free lookup (Appendix C's read protocol), served from the
    /// hot-key cache when the locality tier holds a current-generation
    /// copy.
    pub fn get(&self, ctx: &ThreadCtx, key: u64) -> Option<Vec<u64>> {
        self.check_cache_epoch();
        let e = self.shared.index.get(key)?;
        if let Some(cache) = self.cache_for(&e) {
            if let Some(v) = cache.lookup(key, e.counter) {
                return Some(v);
            }
        }
        self.get_remote(ctx, key, e)
    }

    /// The remote leg of `get`: read the slot, validate
    /// (checksum/counter/valid, Appendix C), fill the cache on success.
    /// The torn-read spin is bounded by [`TORN_REFETCH`]-round index
    /// re-fetches.
    fn get_remote(&self, ctx: &ThreadCtx, key: u64, mut e: IndexEntry) -> Option<Vec<u64>> {
        let mut bo = Backoff::new();
        let mut torn_rounds = 0u32;
        loop {
            if ctx.node_down(e.node) {
                // Home crash-stopped. With replication, fail over to the
                // first live replica's backup frame (graceful
                // degradation — no parking while recovery runs); when no
                // replica can answer safely, park until recovery
                // re-homes the key (serve the new location) or drops it
                // (EMPTY).
                if let Some(value) = self.failover_read(ctx, &e) {
                    return Some(value);
                }
                match self.wait_entry_change(ctx, key, &e) {
                    Ok(Some(ne)) => {
                        e = ne;
                        continue;
                    }
                    Ok(None) => return None,
                    Err(_) => return None, // we are the corpse ourselves
                }
            }
            // Fill-token before the READ: a concurrent invalidation
            // between here and the fill rejects the fill.
            let token = self.cache_for(&e).map(|c| c.begin_fill(key));
            let region = self.data_region_of(e.node);
            let words = match ctx.try_read(region, self.slot_off(e.slot), self.frame_words_of(e.slot))
            {
                Ok(w) => w,
                Err(_) => {
                    // A read error with a live home means *we* are the
                    // crashed node (our posts all fail): bail rather
                    // than spin — a corpse's results no longer matter.
                    if ctx.node_down(self.me) {
                        return None;
                    }
                    continue; // home's crash raced the read: handled above
                }
            };
            match self.parse_frame(&e, &words) {
                FrameRead::Value(value) => {
                    if let (Some(cache), Some(token)) = (self.cache_for(&e), token) {
                        cache.fill(token, key, e.counter, &value);
                    }
                    return Some(value);
                }
                FrameRead::Stale => {
                    // Wrong generation or valid unset: the slot moved on
                    // without us. Re-resolve — a relocation or re-insert
                    // left a *new* location to serve; an unchanged entry
                    // means the delete (or a pending insert's EMPTY
                    // window) linearized: EMPTY is correct.
                    match self.shared.index.get(key) {
                        Some(ne) if ne != e => {
                            e = ne;
                            continue;
                        }
                        _ => return None,
                    }
                }
                // Torn write in flight, or a relocation racing toward
                // its valid-set: retry. Re-fetch the entry periodically
                // — if our slot was reused for another (update-heavy)
                // key, spinning on the old location would never
                // terminate, and a delete landing under a RELOC-marked
                // frame only resolves through the index.
                FrameRead::Torn | FrameRead::Pending => {
                    torn_rounds += 1;
                    if torn_rounds % TORN_REFETCH == 0 {
                        e = self.shared.index.get(key)?;
                    }
                    bo.snooze();
                }
            }
        }
    }

    /// Failover read (replicas ≥ 2): the key's home is dead, so serve
    /// the first live replica's hosted backup frame instead of parking
    /// until re-home completes.
    ///
    /// Linearizability argument. Backup frames are fence-placed before
    /// any mutation acknowledges, so a frame that **validates**
    /// (checksum + generation + valid bit) holds the latest
    /// acknowledged value — *provided no re-home has superseded it*.
    /// That proviso is made checkable by recovery itself: the promoted
    /// backup retires its hosted frame (unsets its cv word, a local
    /// store) **before** broadcasting the key's new location, so a
    /// frame that still validates was read strictly before the re-home
    /// published — before any writer could have reached the new
    /// location — and its value is still the freshest acknowledged one.
    /// Conversely a frame that does NOT validate is ambiguous (retired
    /// by recovery? unset by an in-flight delete? never written by a
    /// never-acked insert?), so we return `None` and the caller parks
    /// on the index change, which resolves every one of those cases.
    /// Replicas are probed in rank order and the probe STOPS at the
    /// first live rank whatever it finds — skipping past a retired
    /// rank-0 frame to a deeper replica could resurrect a value the
    /// re-home already superseded. No cache fill: the entry's
    /// generation names the dead home, and recovery is about to move
    /// it.
    fn failover_read(&self, ctx: &ThreadCtx, e: &IndexEntry) -> Option<Vec<u64>> {
        if !self.cfg.replicated() {
            return None;
        }
        for rank in 0..self.backup_count() {
            let b = self.backup_of(e.node, rank);
            if ctx.node_down(b) {
                continue; // dead replica: the next rank holds a copy too
            }
            let region = self.backup_region_of(e.node, rank);
            let mut bo = Backoff::new();
            let mut read_failed = false;
            for _ in 0..4096 {
                match ctx.try_read(region, self.slot_off(e.slot), self.frame_words_of(e.slot)) {
                    Err(_) => {
                        read_failed = true; // replica died under us
                        break;
                    }
                    Ok(words) => match self.parse_frame(e, &words) {
                        FrameRead::Value(value) => return Some(value),
                        // Retired/unset/pending: ambiguous — park (doc).
                        FrameRead::Stale | FrameRead::Pending => return None,
                        // Mirror placement in flight: bounded spin, then
                        // give up to the parking path.
                        FrameRead::Torn => bo.snooze(),
                    },
                }
            }
            if read_failed {
                continue; // the next rank holds a copy too
            }
            return None; // persistent torn: let the parking path decide
        }
        None
    }

    /// Delete. Returns false if absent. Panics on an unrecoverable peer
    /// failure — use [`KvStore::try_remove`] under fault injection.
    pub fn remove(&self, ctx: &ThreadCtx, key: u64) -> bool {
        self.try_remove(ctx, key).expect("kv remove: unrecoverable peer failure")
    }

    /// Crash-stop-aware delete: `Err(Error::PeerFailed)` iff the key's
    /// lock is hosted on a dead node (nothing happened). A home dying
    /// mid-delete is re-resolved and retried, like
    /// [`KvStore::try_update`].
    pub fn try_remove(&self, ctx: &ThreadCtx, key: u64) -> Result<bool> {
        let lock = self.lock_of(key);
        lock.try_lock(ctx)?;
        let res = self.remove_locked(ctx, key);
        lock.unlock(ctx);
        res
    }

    fn remove_locked(&self, ctx: &ThreadCtx, key: u64) -> Result<bool> {
        let Some(mut e) = self.shared.index.get(key) else {
            return Ok(false);
        };
        loop {
            if ctx.node_down(e.node) {
                match self.wait_entry_change(ctx, key, &e)? {
                    Some(ne) => {
                        e = ne;
                        continue;
                    }
                    // Recovery already dropped it: the crash deleted the
                    // key before we could.
                    None => return Ok(false),
                }
            }
            // Unset the valid bit (the delete's linearization point) —
            // and its backup mirrors FIRST, so a crash of the home right
            // here cannot re-home a key whose delete is about to be
            // broadcast (recovery validates against the backup frame),
            // and a failover reader cannot validate a copy of a key
            // whose delete already acknowledged.
            let region = self.data_region_of(e.node);
            let cv_off = self.cv_off(e.slot);
            // Covered single-word unsets: the fence right below is the
            // covering signaled op of every chain.
            for rank in 0..self.backup_count() {
                ctx.write_covered(self.backup_region_of(e.node, rank), cv_off, &[e.counter << 1]);
            }
            ctx.write_covered(region, cv_off, &[e.counter << 1]);
            let scope = if self.cfg.replicated() {
                FenceScope::Thread
            } else {
                FenceScope::Pair(e.node)
            };
            if ctx.try_fence(scope).is_err() {
                if ctx.node_down(self.me) {
                    return Err(Error::PeerFailed("local node crashed mid-delete".into()));
                }
                if ctx.node_down(e.node) {
                    continue; // home died mid-delete: re-resolve the location
                }
                // Dead backup only: tolerated, the home's unset placed.
            }
            break;
        }
        // Broadcast; peers invalidate their cache + drop their index
        // entries (the home peer also frees the slot); then drop ours.
        self.send_tracker_keyed(ctx, key, &[OP_DELETE, key, e.node as u64, e.slot as u64, e.counter]);
        self.shared.invalidate(key);
        self.shared.index.remove(key);
        if e.node == self.me {
            self.shared.alloc.free(e.slot);
        }
        Ok(true)
    }

    // ---- batched operations (doorbell-batched pipeline) ---------------

    /// Batched lock-free lookup: cache hits are peeled off locally, the
    /// remaining key set is issued through the doorbell-batched pipeline
    /// — slot reads grouped into **one post list per home node** (instead
    /// of one doorbell per key), ack tracking amortized batch-wide, and a
    /// single wait for the batch. Each result validates exactly like
    /// [`KvStore::get`] (checksum/counter/valid, Appendix C); keys whose
    /// reads raced an in-flight update are collected and retried together
    /// as one `read_many` batch (not one scalar round trip each).
    ///
    /// `out[i]` corresponds to `keys[i]`. Duplicate keys are permitted.
    pub fn multi_get(&self, ctx: &ThreadCtx, keys: &[u64]) -> Vec<Option<Vec<u64>>> {
        self.check_cache_epoch();
        let mut out: Vec<Option<Vec<u64>>> = Vec::with_capacity(keys.len());
        let mut entries: Vec<Option<IndexEntry>> = Vec::with_capacity(keys.len());
        // Indices still needing a remote read.
        let mut pending: Vec<usize> = Vec::new();
        for (i, &k) in keys.iter().enumerate() {
            let e = self.shared.index.get(k);
            let hit =
                e.and_then(|e| self.cache_for(&e).and_then(|c| c.lookup(k, e.counter)));
            if hit.is_none() && e.is_some() {
                pending.push(i);
            }
            out.push(hit);
            entries.push(e);
        }

        let mut bo = Backoff::new();
        let mut torn_rounds = 0u32;
        while !pending.is_empty() {
            // Fill-tokens before the batched READs are issued.
            let tokens: Vec<Option<FillToken>> = pending
                .iter()
                .map(|&i| {
                    let e = entries[i].unwrap();
                    self.cache_for(&e).map(|c| c.begin_fill(keys[i]))
                })
                .collect();
            // Per-class frame lengths, one post list per home node: the
            // class packed into each slot id tells the reader how many
            // words to READ without any handshake.
            let reqs: Vec<(Region, u64, usize)> = pending
                .iter()
                .map(|&i| {
                    let e = entries[i].unwrap();
                    (self.data_region_of(e.node), self.slot_off(e.slot), self.frame_words_of(e.slot))
                })
                .collect();
            // read_many waits once for the whole batch and resets the
            // involved peers' unfenced counters (completed READs prove
            // placement on those QPs), exactly like the scalar get path.
            let raws = ctx.read_many(&reqs);
            let mut torn: Vec<usize> = Vec::new();
            for (j, &i) in pending.iter().enumerate() {
                let e = entries[i].unwrap();
                match self.parse_frame(&e, &raws[j]) {
                    FrameRead::Value(value) => {
                        if let (Some(cache), Some(token)) = (self.cache_for(&e), tokens[j]) {
                            cache.fill(token, keys[i], e.counter, &value);
                        }
                        out[i] = Some(value);
                    }
                    // Torn write / relocation racing its valid-set:
                    // retried as one batch next round.
                    FrameRead::Torn | FrameRead::Pending => torn.push(i),
                    FrameRead::Stale => {
                        // Slot moved on: re-resolve now. A new location
                        // (relocation / re-insert) rejoins the batch;
                        // an unchanged or vanished entry is EMPTY.
                        match self.shared.index.get(keys[i]) {
                            Some(ne) if ne != e => {
                                entries[i] = Some(ne);
                                torn.push(i);
                            }
                            _ => {} // stays None
                        }
                    }
                }
            }
            if torn.is_empty() {
                break;
            }
            // Same bounded spin as the scalar path, for the whole batch.
            torn_rounds += 1;
            if torn_rounds % TORN_REFETCH == 0 {
                torn.retain(|&i| match self.shared.index.get(keys[i]) {
                    Some(e) => {
                        entries[i] = Some(e);
                        true
                    }
                    None => false, // key vanished: result stays None
                });
            }
            bo.snooze();
            pending = torn;
        }
        out
    }

    /// Batched in-place update of existing keys: acquires the
    /// (deduplicated) key locks in ascending index order — so concurrent
    /// `multi_put`s cannot deadlock — issues every value write through
    /// the batched pipeline (one doorbell per home node, **selective
    /// signaling**: only the tail of each per-home write chain carries a
    /// CQE, and small-class frames go out inline), collapses
    /// back-to-back updates of the same key to the last value (write
    /// combining under the held locks), runs **one** fence covering the
    /// whole batch before the first release (§7.2's per-update fence,
    /// amortized), then broadcasts **one** (coalesced) cache
    /// invalidation for the touched keys and unlocks. Keys not present
    /// are skipped, exactly like [`KvStore::update`]. Returns how many
    /// keys were updated.
    ///
    /// **Not crash-hardened**: unlike the scalar mutations, this batch
    /// path takes the infallible locks and does not re-resolve homes
    /// that die mid-batch — under fault injection with crash-stop, use
    /// the scalar [`KvStore::try_update`] per key instead (the chaos
    /// tier does). Frames are still mirrored to their backups when
    /// replication is on, so a *later* crash recovers multi_put values
    /// correctly.
    pub fn multi_put(&self, ctx: &ThreadCtx, items: &[(u64, Vec<u64>)]) -> usize {
        for (_, value) in items {
            self.check_value_len(value);
        }
        // Routing: batches always take the one-sided batched pipeline
        // (amortized doorbells/fences ARE their advantage), but their
        // touches still heat the keys so the scalar path's adaptive
        // decisions account for batch traffic too.
        if self.cfg.routing == RouteMode::Adaptive && self.ship.is_some() {
            for (k, _) in items {
                let contended = self.lock_of(*k).take_contended();
                let (_, flipped) = self.heat.sample(*k, contended);
                if flipped {
                    self.cluster.note_route_flip(self.me);
                }
            }
        }
        let mut lock_ids: Vec<usize> =
            items.iter().map(|(k, _)| (*k % self.cfg.num_locks as u64) as usize).collect();
        lock_ids.sort_unstable();
        lock_ids.dedup();
        for &l in &lock_ids {
            self.locks[l].lock(ctx);
        }

        let entries: Vec<Option<IndexEntry>> =
            items.iter().map(|(k, _)| self.shared.index.get(*k)).collect();
        // Build `[hdr][value][checksum]` frames for every value that
        // still fits its slot's class, then one batched write issue
        // (each frame mirrored to its backup replica when replication is
        // on — same batch, same fence). Values that outgrew their class
        // take the scalar relocation path below, under the same held
        // locks.
        //
        // Write combining: back-to-back updates of the SAME key inside
        // one batch — all under the held key lock — collapse to the last
        // value; earlier occurrences still count as applied (their
        // intermediate state was never observable under the lock), but
        // cost no frame write.
        let mut bufs: Vec<Vec<u64>> = Vec::new();
        let mut targets: Vec<(Region, u64, usize)> = Vec::new();
        let mut relocations: Vec<usize> = Vec::new();
        let mut touched: Vec<u64> = Vec::new();
        let mut updated = 0usize;
        // One reverse pass marks each key's last occurrence (the first
        // time it is seen walking backwards) — O(n), not a rescan of
        // the batch tail per item.
        let mut is_last = vec![false; items.len()];
        {
            let mut seen = std::collections::HashSet::with_capacity(items.len());
            for (i, (k, _)) in items.iter().enumerate().rev() {
                is_last[i] = seen.insert(*k);
            }
        }
        for (i, (e, (k, value))) in entries.iter().zip(items).enumerate() {
            if let Some(e) = e {
                updated += 1;
                if !is_last[i] {
                    continue; // collapsed: a later item supersedes this one
                }
                if value.len() > self.geo().cap(self.geo().class_of(e.slot)) {
                    relocations.push(i);
                    continue;
                }
                let buf = self.build_frame(e.slot, value, false);
                let idx = bufs.len();
                bufs.push(buf);
                let off = self.slot_off(e.slot);
                targets.push((self.data_region_of(e.node), off, idx));
                for rank in 0..self.backup_count() {
                    targets.push((self.backup_region_of(e.node, rank), off, idx));
                }
                touched.push(*k);
            }
        }
        let writes: Vec<(Region, u64, &[u64])> = targets
            .iter()
            .map(|&(region, off, i)| (region, off, bufs[i].as_slice()))
            .collect();
        let _key = ctx.write_many(&writes); // completion tracked by the fence
        if self.cfg.fence_updates && !writes.is_empty() {
            ctx.fence(FenceScope::Thread); // one fence for the whole batch
        }
        // Outgrown values relocate one by one (rare path; still under
        // the batch's locks, so the per-key mutation order holds). Their
        // OP_INSERT broadcasts invalidate caches — no OP_INVAL needed.
        // Only last occurrences reach this list (write combining above);
        // re-resolve each entry first anyway — a concurrent recovery may
        // have moved it, in which case the value may now fit in place.
        for &i in &relocations {
            let (k, value) = &items[i];
            let Some(e) = self.shared.index.get(*k) else { continue };
            if value.len() <= self.geo().cap(self.geo().class_of(e.slot)) {
                self.write_value(ctx, &e, value).expect("multi_put in-place rewrite failed");
                touched.push(*k);
            } else {
                self.relocate_locked(ctx, *k, e, value)
                    .expect("multi_put relocation failed (capacity/peer)");
            }
        }
        touched.sort_unstable();
        touched.dedup(); // duplicate keys in one batch need one invalidation
        self.invalidate_updated(ctx, &touched);
        for &l in lock_ids.iter().rev() {
            self.locks[l].unlock(ctx);
        }
        updated
    }

    // ---- windowed (asynchronous) reads --------------------------------

    /// Issue a lookup without waiting: returns the in-flight read (or an
    /// already-resolved cache hit). Used by the window-size experiments
    /// (§7.2): up to `window` of these may be outstanding per thread.
    pub fn get_issue(&self, ctx: &ThreadCtx, key: u64) -> Option<PendingGet> {
        self.check_cache_epoch();
        let e = self.shared.index.get(key)?;
        if let Some(cache) = self.cache_for(&e) {
            if let Some(v) = cache.lookup(key, e.counter) {
                return Some(PendingGet { key, entry: e, state: PendingState::Cached(v) });
            }
        }
        let token = self.cache_for(&e).map(|c| c.begin_fill(key));
        let region = self.data_region_of(e.node);
        let (ack, buf) = ctx.read_async(region, self.slot_off(e.slot), self.frame_words_of(e.slot));
        Some(PendingGet { key, entry: e, state: PendingState::InFlight { ack, buf, token } })
    }

    /// Complete an issued lookup (waits if necessary; falls back to the
    /// blocking path on torn reads).
    pub fn get_complete(&self, ctx: &ThreadCtx, pg: PendingGet) -> Option<Vec<u64>> {
        let (ack, buf, token) = match pg.state {
            PendingState::Cached(v) => return Some(v),
            PendingState::InFlight { ack, buf, token } => (ack, buf, token),
        };
        ack.wait();
        if ack.failed() {
            // The home crash-stopped under the windowed read: the buffer
            // was never written. Restart through the blocking path,
            // which waits out the re-home.
            return self.get(ctx, pg.key);
        }
        let words = buf.to_vec();
        match self.parse_frame(&pg.entry, &words) {
            FrameRead::Value(value) => {
                if let (Some(cache), Some(token)) = (self.cache_for(&pg.entry), token) {
                    cache.fill(token, pg.key, pg.entry.counter, &value);
                }
                Some(value)
            }
            // Torn, mid-relocation, or stale: restart through the
            // blocking path, which re-resolves the location (and returns
            // EMPTY only once that is the linearizable answer).
            FrameRead::Torn | FrameRead::Pending | FrameRead::Stale => self.get(ctx, pg.key),
        }
    }

    // ---- bulk prefill --------------------------------------------------

    /// Bulk-load `keys` into *this* node's data array, broadcasting index
    /// updates in batches. `checksums`, if given, must be the per-key
    /// checksum of each value (e.g. produced by the AOT Pallas checksum
    /// kernel via [`crate::runtime`]); otherwise they are computed here.
    pub fn prefill_local(
        &self,
        ctx: &ThreadCtx,
        keys: &[u64],
        mut value_of: impl FnMut(u64) -> Vec<u64>,
        checksums: Option<&[u64]>,
    ) -> Result<()> {
        const BATCH: usize = 128;
        let shards = self.tracker_tx.len();
        for (chunk_idx, chunk) in keys.chunks(BATCH).enumerate() {
            // One OP_BATCH frame per shard ring: a key's bulk insert
            // must ride the same ring as its later ops (per-key order).
            let mut msgs: Vec<Vec<u64>> =
                (0..shards).map(|_| vec![OP_BATCH, self.me as u64, 0]).collect();
            for (i, &key) in chunk.iter().enumerate() {
                let value = value_of(key);
                self.check_value_len(&value);
                let Some(slot) = self.shared.alloc.alloc(value.len()) else {
                    return Err(Error::Capacity(format!(
                        "node {} out of kv slots for a {}-word value",
                        self.me,
                        value.len()
                    )));
                };
                let counter = self.bump_counter(slot);
                let mut frame = Vec::with_capacity(value.len() + 2);
                frame.push(pack_hdr(value.len(), self.geo().class_of(slot), false));
                frame.extend_from_slice(&value);
                frame.push(match checksums {
                    Some(cks) => cks[chunk_idx * BATCH + i],
                    None => fnv64(&value),
                });
                self.store_frame_local(ctx, slot, &frame, (counter << 1) | 1);
                if self.cfg.replicated() {
                    self.write_backup_frame(ctx, slot, &frame, (counter << 1) | 1);
                }
                self.shared.index.insert(key, IndexEntry { node: self.me, slot, counter });
                let m = &mut msgs[shard_of(key, shards)];
                m[2] += 1;
                m.extend_from_slice(&[key, slot as u64, counter]);
            }
            for (shard, msg) in msgs.into_iter().enumerate() {
                if msg[2] == 0 {
                    continue;
                }
                let tx = self.tracker_tx[shard].lock().unwrap();
                self.send_tracker(ctx, &tx, &msg);
                let pos = tx.position();
                tx.wait_all_acked(ctx, pos);
            }
        }
        Ok(())
    }

    /// Local index size (for tests).
    pub fn index_len(&self) -> usize {
        self.shared.index.len()
    }

    pub fn index_entry(&self, key: u64) -> Option<IndexEntry> {
        self.shared.index.get(key)
    }

    /// Read-cache counters (all-zero when the cache is disabled).
    pub fn cache_stats(&self) -> CacheStats {
        self.shared.cache.as_ref().map(|c| c.stats()).unwrap_or_default()
    }

    /// Slots of this node's slab currently allocated (for tests).
    pub fn slots_outstanding(&self) -> usize {
        self.shared.alloc.outstanding()
    }

    /// Slab accounting audit (satellite of the allocator work): every
    /// slot of every class must be accounted for exactly once — on its
    /// class's free list XOR referenced by the location index — with no
    /// cross-class aliasing. Only meaningful on a **quiesced** store
    /// (no ops or tracker messages in flight) with no crashed peers
    /// (a relocation cut short by a crash intentionally leaks its old
    /// slot rather than risk a double free).
    pub fn slab_audit(&self) -> std::result::Result<(), String> {
        self.shared
            .alloc
            .audit(self.shared.index.entries_homed_on(self.me).into_iter().map(|(_, e)| e.slot))
    }

    pub fn shutdown(&self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        if let Some(h) = self.ship_thread.lock().unwrap().take() {
            if h.thread().id() != std::thread::current().id() {
                let _ = h.join();
            }
        }
        for h in self.tracker_threads.lock().unwrap().drain(..) {
            if h.thread().id() == std::thread::current().id() {
                // We ARE a tracker thread: the last external Arc was
                // dropped while recovery held a transient Weak-upgrade,
                // so Drop is running on the tracker itself. Joining
                // ourselves would deadlock forever — detach instead;
                // the loop observes the shutdown flag and exits.
                continue;
            }
            let _ = h.join();
        }
    }

    // ---- elastic membership: join + live resharding --------------------

    /// Enter the cluster as a **joining** member: broadcast `OP_JOIN`
    /// so every view moves this node's slot to the Joining state
    /// (clearing a spare or stale dead bit) and bumps its membership
    /// epoch. From here the epoch-versioned ownership table assigns
    /// this node target ranges; call [`KvStore::rebalance`] to pull the
    /// keys in and [`KvStore::activate`] once converged. If this slot
    /// was previously crash-stopped, [`Cluster::revive`] must run
    /// first (on every node's view, it is global) so the fabric down
    /// bit cannot re-latch the dead state.
    ///
    /// [`Cluster::revive`]: crate::fabric::Cluster::revive
    pub fn join(&self, ctx: &ThreadCtx) {
        if let Some(ring) = &self.ship {
            // Drop anything shipped to us before this (re)join: those
            // clients have long since erred out on our death/absence,
            // and a late apply of their frames would un-linearize the
            // fallback path they already completed down.
            ring.quiesce(ctx);
        }
        self.shared.membership.note_joining(self.me);
        self.send_tracker_all_shards(ctx, &[OP_JOIN, self.me as u64]);
    }

    /// Complete this node's join (migration converged): broadcast
    /// `OP_ALIVE`, moving the slot from Joining to full membership.
    pub fn activate(&self, ctx: &ThreadCtx) {
        self.shared.membership.note_alive(self.me);
        self.send_tracker_all_shards(ctx, &[OP_ALIVE, self.me as u64]);
    }

    /// Live resharding driver: pull every key whose range the current
    /// ownership table assigns to this node but whose frame lives on
    /// another (live) node, using the per-key relocation primitive —
    /// valid-unset staging, origin tracking, CAS re-home — so reads and
    /// writes keep landing throughout (readers of a mid-flight key spin
    /// on the RELOC marker or chase the index exactly as for crash
    /// re-homes), and a crash of this node mid-migration reverts each
    /// in-flight key to its recorded origin. Call repeatedly until it
    /// returns 0 (a concurrent mutation can momentarily hold a key's
    /// lock); each call is one full pass. Returns the number of keys
    /// migrated.
    pub fn rebalance(&self, ctx: &ThreadCtx) -> usize {
        let owners = self.shared.membership.owners(self.cfg.replicas);
        let mut moved = 0usize;
        for p in 0..self.num_nodes as NodeId {
            if p == self.me || self.shared.membership.is_dead(p) {
                continue;
            }
            let mut entries = self.shared.index.entries_homed_on(p);
            // Deterministic migration order (sim trace = f(state)).
            entries.sort_unstable_by_key(|(k, _)| *k);
            for (key, _) in entries {
                if owners[Membership::range_of(key)] != self.me {
                    continue;
                }
                let lock = self.lock_of(key);
                if lock.try_lock(ctx).is_err() {
                    continue; // lock host died: skip, recovery handles it
                }
                // Re-resolve under the lock; the entry may have moved.
                if let Some(e) = self.shared.index.get(key) {
                    if e.node != self.me && !self.shared.membership.is_dead(e.node) {
                        let fw = self.frame_words_of(e.slot);
                        let read =
                            ctx.try_read(self.data_region_of(e.node), self.slot_off(e.slot), fw);
                        if let Ok(words) = read {
                            // Under the key lock the frame is stable;
                            // anything but a clean value (a crash race)
                            // is skipped — recovery owns those keys.
                            if let FrameRead::Value(value) = self.parse_frame(&e, &words) {
                                if self.relocate_locked(ctx, key, e, &value).is_ok() {
                                    moved += 1;
                                }
                            }
                        }
                    }
                }
                lock.unlock(ctx);
            }
        }
        moved
    }

    // ---- crash recovery (membership epoch) ----------------------------

    /// Crash recovery, called from the tracker thread once per newly
    /// dead node. Per-node ordering: drop the hot-key cache (entries
    /// cached under the dead epoch must not serve into the new one),
    /// then either **re-home** key ranges from our hosted backup arrays
    /// (if we are the promoted replica and replication is on) or —
    /// without replication — **purge** the dead node's entries
    /// everywhere (the data died with the node). Non-promoted nodes
    /// with replication on keep their stale entries and learn the new
    /// homes from the promoted replica's re-home broadcasts; reads fail
    /// over to a live replica meanwhile ([`KvStore::failover_read`]),
    /// and locked mutations park in [`KvStore::wait_entry_change`]
    /// until exactly that signal.
    ///
    /// Promotion rule: the **first live** backup in a dead node's
    /// static successor chain re-homes; deeper replicas stand by.
    /// Double faults make promotion fall through the chain, so the scan
    /// below covers *every* dead node that still has homed entries, not
    /// only the newly dead one — a home whose promoted backup died
    /// mid-re-home falls to us on the backup's death, with the
    /// remaining (not yet re-homed) entries recovered from our
    /// deeper-rank array.
    pub(crate) fn on_peer_dead(&self, ctx: &ThreadCtx, dead: NodeId) {
        if dead == self.me {
            return; // we are the corpse; our view no longer matters
        }
        if let Some(cache) = &self.shared.cache {
            cache.clear();
        }
        if !self.cfg.replicated() {
            self.shared.purge_homed_on(dead, false);
            return;
        }
        for d in 0..self.num_nodes as NodeId {
            if d == self.me || !self.shared.membership.is_dead(d) {
                continue;
            }
            if let Some(rank) = self.promotion_rank(d) {
                if !self.shared.index.entries_homed_on(d).is_empty() {
                    self.rehome_from_backup(ctx, d, rank);
                }
            }
        }
    }

    /// If this node is the first **live** replica in `dead`'s static
    /// successor chain, its rank (which hosted backup array holds the
    /// surviving copies); `None` when an earlier replica is alive (it
    /// re-homes, we stand by) or we are not in the chain at all.
    fn promotion_rank(&self, dead: NodeId) -> Option<usize> {
        for rank in 0..self.backup_count() {
            let b = self.backup_of(dead, rank);
            if b == self.me {
                return Some(rank);
            }
            if !self.shared.membership.is_dead(b) {
                return None;
            }
        }
        None
    }

    /// Re-home the crash-stopped `dead` node's key range: our index (a
    /// replica of the locations, built from the tracker broadcasts that
    /// announced them) names every key homed there; our rank-`rank`
    /// hosted backup array holds a surviving replica of the frames.
    /// Each key whose backup frame validates is re-inserted under a
    /// fresh local generation — re-replicated to OUR successors, which
    /// restores the replication factor (anti-entropy repair) — and
    /// announced with an `OP_REHOME`; frames that do not validate (the
    /// insert never completed, or a delete's backup-unset landed first)
    /// are dropped with an `OP_DELETE`. Each validated hosted frame is
    /// **retired** (cv unset) before its new location is broadcast —
    /// the handshake failover readers rely on (see
    /// [`KvStore::failover_read`]). One ack-wait covers the whole batch
    /// — when this returns, every surviving index agrees on the new
    /// homes.
    fn rehome_from_backup(&self, ctx: &ThreadCtx, dead: NodeId, rank: usize) {
        let backup = self.backup_hosted[rank];
        let mut entries = self.shared.index.entries_homed_on(dead);
        // Shard-scan order depends on insertion history; sort so the
        // re-home broadcast sequence (and thus the sim event trace) is a
        // pure function of the logical state.
        entries.sort_unstable_by_key(|(k, _)| *k);
        let mut rehomed = 0u64;
        let mut dropped = 0u64;
        for (key, e) in entries {
            match self.read_backup_frame(ctx, backup, &e) {
                Some(value) => {
                    // Retire our hosted frame FIRST: a failover reader
                    // that still validates it must be reading strictly
                    // before the re-home (or drop) publishes a path to
                    // newer writes.
                    ctx.local_store(backup, self.cv_off(e.slot), e.counter << 1);
                    if self.reinsert_recovered(ctx, key, &e, &value) {
                        rehomed += 1;
                    } else {
                        self.announce_drop(ctx, key, &e);
                        dropped += 1;
                    }
                }
                None => {
                    self.announce_drop(ctx, key, &e);
                    dropped += 1;
                }
            }
        }
        // End-of-recovery marker on EVERY shard ring: the re-home
        // broadcasts above rode their keys' shards, and per-ring FIFO
        // only orders the same shard's marker after them — so a
        // receiver purges leftovers only once all shards' markers
        // applied (see `KvShared::note_epoch_mark`). One ack-wait per
        // ring covers that ring's whole batch.
        self.send_tracker_all_shards(ctx, &[OP_EPOCH, dead as u64]);
        // Our own leftover check (peers get it from OP_EPOCH).
        self.shared.purge_homed_on(dead, true);
        if rehomed + dropped > 0 {
            eprintln!(
                "loco-kv[{}]: re-homed node {dead}'s range: {rehomed} recovered, {dropped} dropped",
                self.me
            );
        }
    }

    /// Read and validate our backup replica of `e` (a slot frame homed
    /// on the dead node). Plain local loads with a bounded
    /// checksum-retry: an update's mirror write that raced the crash may
    /// still be mid-placement, but placements are transient — a frame
    /// that validates with the wrong generation (or the valid bit clear)
    /// is a *stable* negative, because deletes fence their backup unset
    /// before broadcasting.
    fn read_backup_frame(&self, ctx: &ThreadCtx, backup: Region, e: &IndexEntry) -> Option<Vec<u64>> {
        let off = self.slot_off(e.slot);
        let words = self.frame_words_of(e.slot);
        let mut bo = Backoff::new();
        for _ in 0..4096 {
            let mut frame = vec![0u64; words];
            for (i, f) in frame.iter_mut().enumerate() {
                *f = ctx.local_load(backup, off + i as u64);
            }
            match self.parse_frame(e, &frame) {
                FrameRead::Value(v) => return Some(v),
                // Consistent frame, wrong generation / invalid: stable
                // negative (deletes fence their backup unset first).
                FrameRead::Stale | FrameRead::Pending => return None,
                FrameRead::Torn => bo.snooze(), // mirror placement in flight
            }
        }
        None
    }

    /// Promote a recovered frame into a fresh local slot + generation
    /// (smallest class that fits the recovered length), mirror it to
    /// OUR successor replicas (restoring the replication factor), swap
    /// our index entry, and broadcast the new location. No
    /// key lock is taken: mutators of this key are parked in
    /// `wait_entry_change` (their home is down) and proceed against the
    /// new location once the broadcast lands — EXCEPT a concurrent
    /// **relocation**, which rewrites the index while the old home is
    /// already dead. Both the local swap and the `OP_REHOME` broadcast
    /// are therefore compare-and-swap against the exact dead entry, so
    /// the relocator's unconditional insert wins on every node whatever
    /// the arrival order. Returns false if this node is out of slots
    /// (the key is then dropped instead).
    fn reinsert_recovered(&self, ctx: &ThreadCtx, key: u64, old: &IndexEntry, value: &[u64]) -> bool {
        let Some(slot) = self.shared.alloc.alloc(value.len()) else {
            return false;
        };
        let counter = self.bump_counter(slot);
        let frame = self.build_frame(slot, value, false);
        self.store_frame_local(ctx, slot, &frame, (counter << 1) | 1);
        self.write_backup_frame(ctx, slot, &frame, (counter << 1) | 1);
        let new = IndexEntry { node: self.me, slot, counter };
        if !self.shared.index.replace_matching(key, old, new) {
            // A relocation beat us to the key: it owns the new location.
            // Unset before freeing — no frame ever returns to a free
            // list with its valid bit up (this generation was never
            // published, but the invariant is cheap and uniform).
            ctx.local_store(self.data, self.cv_off(slot), counter << 1);
            self.shared.alloc.free(slot);
            return true;
        }
        // If the dead entry was itself a half-done relocation, ship its
        // origin along: receivers that never saw the crashed broadcast
        // still hold the origin entry and must converge too.
        let origin = self.shared.reloc_origins.lock().unwrap().remove(&key);
        let mut msg = vec![
            OP_REHOME,
            key,
            self.me as u64,
            slot as u64,
            counter,
            old.node as u64,
            old.slot as u64,
            old.counter,
        ];
        if let Some(o) = origin {
            msg.extend_from_slice(&[o.node as u64, o.slot as u64, o.counter]);
        }
        // No ack wait here: the OP_EPOCH markers' per-ring ack waits
        // cover the whole recovery batch. Keyed shard, so the marker on
        // this ring stays FIFO-after us.
        let tx = self.tracker_shard(key).lock().unwrap();
        self.send_tracker(ctx, &tx, &msg);
        true
    }

    /// Recovery-side drop of a key whose frame did not survive: remove
    /// it locally (compare-and-remove — a racing fresh re-insert wins)
    /// and broadcast the delete, which peers likewise apply only against
    /// the exact dead entry. Nobody frees a slot — the home is dead.
    fn announce_drop(&self, ctx: &ThreadCtx, key: u64, e: &IndexEntry) {
        self.shared.invalidate(key);
        self.shared.reloc_origins.lock().unwrap().remove(&key);
        self.shared.index.remove_matching(key, e);
        let tx = self.tracker_shard(key).lock().unwrap();
        self.send_tracker(ctx, &tx, &[OP_DELETE, key, e.node as u64, e.slot as u64, e.counter]);
    }
}

impl Drop for KvStore {
    fn drop(&mut self) {
        self.shutdown();
    }
}

// ---- tracker thread (free-standing: must not keep KvStore alive) ------

fn tracker_loop(
    mgr: Arc<Manager>,
    name: String,
    tracker_words: u64,
    me: NodeId,
    num_nodes: usize,
    shard: usize,
    shared: Arc<KvShared>,
    kv: Weak<KvStore>,
) {
    let ctx = mgr.ctx();
    // Receive every peer's shard-`shard` tracker ring.
    let mut rxs: Vec<(NodeId, RingReceiver)> = (0..num_nodes as NodeId)
        .filter(|&p| p != me)
        .map(|p| {
            let mut rx =
                RingReceiver::new(&mgr, &tracker_ring_name(&name, p, shard), tracker_words);
            rx.set_manual_ack();
            (p, rx)
        })
        .collect();
    for (_, rx) in &rxs {
        rx.wait_ready(Duration::from_secs(30));
    }
    shared.tracker_ready.fetch_add(1, Ordering::Release);

    let mut known_dead: u64 = 0;
    let mut bo = Backoff::new();
    loop {
        let mut did = false;
        // Drain FIRST, then react to deaths: a dead node's final
        // broadcasts that already reached our ring are applied with the
        // pre-death mask, so the recovery scan below sees them; anything
        // arriving later is rejected by apply_tracker's dead-home guard.
        for (from, rx) in &mut rxs {
            while let Some(msg) = rx.try_recv(&ctx) {
                apply_tracker(&shared, me, *from, &msg, known_dead);
                rx.ack_now(&ctx); // apply THEN acknowledge (§6)
                did = true;
            }
        }
        // Crash recovery: the manager's polling thread mirrors the
        // fabric's down mask into Membership; shard 0's thread reacts,
        // once per newly dead node (one recovery driver per node, as
        // before sharding); the other shard threads only refresh their
        // apply-side dead screen.
        let dead_mask = mgr.membership().dead_mask();
        if dead_mask != known_dead {
            if shard == 0 {
                for node in 0..num_nodes as NodeId {
                    if dead_mask >> node & 1 == 1 && known_dead >> node & 1 == 0 {
                        if let Some(kv) = kv.upgrade() {
                            kv.on_peer_dead(&ctx, node);
                        }
                    }
                }
            }
            known_dead = dead_mask;
            did = true;
        }
        if !did {
            if shared.shutdown.load(Ordering::Relaxed) {
                break;
            }
            bo.snooze();
        } else {
            bo.reset();
        }
    }
}

fn apply_tracker(shared: &KvShared, me: NodeId, from: NodeId, msg: &[u64], dead_mask: u64) {
    // Every tracker message's LAST word is the sender's membership epoch
    // at send time (appended by `send_tracker`, so the per-opcode
    // layouts below are unchanged). Strip it before parsing.
    let Some((&msg_epoch, msg)) = msg.split_last() else { return };
    // A location broadcast must not land when its sender is stale:
    // (a) the home we already know to be dead — it would point the
    // index at a corpse *after* recovery re-homed (or purged) that
    // range, wedging readers forever; it can only be a crashed node's
    // final broadcast racing its own death, and the insert it announces
    // never completed. (b) a message stamped before the sender's last
    // membership transition we observed — a pre-crash broadcast
    // delivered after the sender's slot re-joined must not clobber the
    // rejoined node's fresh locations (every location op's home IS its
    // sender, so one sender-staleness check covers them all).
    // `--cfg loco_mutant_epoch` (mutation smoke-check) drops the guard
    // entirely; the model/chaos tiers must catch the divergence.
    let stale = !cfg!(loco_mutant_epoch)
        && (dead_mask >> from & 1 == 1 || msg_epoch < shared.membership.state_epoch(from));
    match msg[0] {
        OP_INSERT => {
            let (key, node, slot, counter) = (msg[1], msg[2] as NodeId, msg[3] as u32, msg[4]);
            debug_assert_eq!(node, from);
            if stale {
                return;
            }
            // The new generation can't be served from a stale cached
            // copy (counter mismatch), but purging keeps dead entries
            // from squatting on cache capacity.
            shared.invalidate(key);
            {
                let mut origins = shared.reloc_origins.lock().unwrap();
                if msg.len() > OP_INSERT_PLAIN_LEN {
                    // Relocation form: remember where the key came from
                    // until the OP_FREE proves the protocol completed.
                    origins.insert(
                        key,
                        IndexEntry {
                            node: msg[5] as NodeId,
                            slot: msg[6] as u32,
                            counter: msg[7],
                        },
                    );
                } else {
                    origins.remove(&key);
                }
            }
            shared.index.insert(key, IndexEntry { node, slot, counter });
        }
        OP_DELETE => {
            let (key, node, slot, counter) = (msg[1], msg[2] as NodeId, msg[3] as u32, msg[4]);
            shared.invalidate(key);
            shared.reloc_origins.lock().unwrap().remove(&key);
            // Compare-and-remove: a recovery drop racing a fresh
            // re-insert of the same key (new home, new generation) must
            // lose — only the exact announced entry is deleted. Normal
            // deletes always match (the deleter holds the key's lock).
            let removed = shared.index.remove_matching(key, &IndexEntry { node, slot, counter });
            if removed && node == me {
                // We are the slot's home but not the deleter: reclaim.
                shared.alloc.free(slot);
            }
        }
        OP_BATCH => {
            let node = msg[1] as NodeId;
            let count = msg[2] as usize;
            debug_assert_eq!(node, from);
            if stale {
                return;
            }
            for i in 0..count {
                let base = 3 + i * 3;
                let key = msg[base];
                shared.invalidate(key);
                shared.index.insert(
                    key,
                    IndexEntry { node, slot: msg[base + 1] as u32, counter: msg[base + 2] },
                );
            }
        }
        OP_INVAL => {
            // In-place update: drop cached copies (and poison in-flight
            // fills via the shard epochs) before this message is acked —
            // the updater returns only after every node has done so.
            let count = msg[1] as usize;
            if let Some(cache) = &shared.cache {
                cache.invalidate_many(msg[2..2 + count].iter().copied());
            }
        }
        OP_EPOCH => {
            // The dead node's backup finished re-homing. The recovered
            // locations rode their keys' shard rings, and the backup
            // sent one marker per ring FIFO-after them — so only when
            // the LAST shard's marker applies is every recovered
            // location guaranteed applied here, and any entry still
            // homed on the corpse belongs to an insert that never
            // completed — drop it — or to a relocation whose broadcast
            // never fully acked — revert it to its recorded origin.
            // OP_EPOCH is only ever sent by a backup, i.e. with
            // replication on, where the revert is safe (see
            // `purge_homed_on`).
            let dead = msg[1] as NodeId;
            if shared.note_epoch_mark(dead) {
                shared.purge_homed_on(dead, true);
            }
        }
        OP_FREE => {
            // A relocation completed (the retire is sent only after the
            // valid-set): drop the key's origin record everywhere, and
            // — on the old home only — return the slot to the slab
            // (FIFO-after the relocation's OP_INSERT on the same ring,
            // so our index already names the new location and a reuse
            // can't be mistaken for the old generation).
            let (node, slot, key) = (msg[1] as NodeId, msg[2] as u32, msg[3]);
            shared.reloc_origins.lock().unwrap().remove(&key);
            if node == me {
                shared.alloc.free(slot);
            }
        }
        OP_REHOME => {
            // Recovery re-home: adopt the recovered location iff our
            // current entry is still the exact dead one — so a live
            // relocation's unconditional OP_INSERT wins on every node
            // regardless of arrival order — or the dead entry's
            // relocation ORIGIN (we never applied the crashed
            // relocation's broadcast and still hold the pre-relocation
            // entry), or the key is absent here (we never applied the
            // crashed insert's broadcast; a *completed* delete can't
            // look like this, because deletes invalidate the backup
            // frame before broadcasting and an invalid frame is never
            // re-homed).
            let (key, node, slot, counter) = (msg[1], msg[2] as NodeId, msg[3] as u32, msg[4]);
            debug_assert_eq!(node, from);
            if stale {
                return;
            }
            let old = IndexEntry {
                node: msg[5] as NodeId,
                slot: msg[6] as u32,
                counter: msg[7],
            };
            shared.invalidate(key);
            shared.reloc_origins.lock().unwrap().remove(&key);
            let new_e = IndexEntry { node, slot, counter };
            let mut applied = shared.index.replace_matching(key, &old, new_e);
            if !applied && msg.len() > 8 {
                let origin = IndexEntry {
                    node: msg[8] as NodeId,
                    slot: msg[9] as u32,
                    counter: msg[10],
                };
                applied = shared.index.replace_matching(key, &origin, new_e);
            }
            if !applied && shared.index.get(key).is_none() {
                shared.index.insert(key, new_e);
            }
        }
        OP_JOIN => {
            // Membership transitions are their own epoch source: never
            // guarded by `stale` (the joiner's stamp predates the epoch
            // its own join bumps).
            debug_assert_eq!(msg[1] as NodeId, from);
            shared.membership.note_joining(msg[1] as NodeId);
        }
        OP_ALIVE => {
            debug_assert_eq!(msg[1] as NodeId, from);
            shared.membership.note_alive(msg[1] as NodeId);
        }
        other => panic!("unknown tracker opcode {other}"),
    }
}

/// Outcome of validating a read frame against the reader's index entry.
enum FrameRead {
    /// Checksum-valid, generation matches, valid bit set: the value.
    Value(Vec<u64>),
    /// Internally inconsistent (a write in flight): retry the READ.
    Torn,
    /// Consistent frame of a relocation whose valid bit is not yet set:
    /// the relocator is about to linearize — spin, don't report EMPTY.
    Pending,
    /// Consistent frame but wrong generation or valid bit unset: the
    /// reader's entry is stale (delete / relocation / slot reuse) —
    /// re-resolve the location before concluding EMPTY.
    Stale,
}

/// An in-flight windowed lookup.
pub struct PendingGet {
    key: u64,
    entry: IndexEntry,
    state: PendingState,
}

enum PendingState {
    /// Resolved from the hot-key cache at issue time.
    Cached(Vec<u64>),
    /// Remote READ in flight.
    InFlight { ack: AckKey, buf: MemRef, token: Option<FillToken> },
}

impl PendingGet {
    pub fn is_complete(&self) -> bool {
        match &self.state {
            PendingState::Cached(_) => true,
            PendingState::InFlight { ack, .. } => ack.query(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::{Cluster, FabricConfig, LatencyModel};

    fn small_cfg() -> KvConfig {
        KvConfig { slots_per_node: 64, tracker_words: 1 << 10, ..Default::default() }
    }

    fn cached_cfg() -> KvConfig {
        KvConfig { read_cache_bytes: 4096, ..small_cfg() }
    }

    fn setup_cfg(
        n: usize,
        fabric: FabricConfig,
        cfg: KvConfig,
    ) -> (Vec<Arc<Manager>>, Vec<Arc<KvStore>>) {
        let cluster = Cluster::new(n, fabric);
        let mgrs: Vec<Arc<Manager>> =
            (0..n as NodeId).map(|i| Manager::new(cluster.clone(), i)).collect();
        let kvs: Vec<Arc<KvStore>> =
            mgrs.iter().map(|m| KvStore::new(m, "kv", cfg.clone())).collect();
        for kv in &kvs {
            kv.wait_ready(Duration::from_secs(30));
        }
        (mgrs, kvs)
    }

    fn setup(n: usize, cfg: FabricConfig) -> (Vec<Arc<Manager>>, Vec<Arc<KvStore>>) {
        setup_cfg(n, cfg, small_cfg())
    }

    #[test]
    fn insert_get_update_delete_cross_node() {
        let (mgrs, kvs) = setup(3, FabricConfig::inline_ideal());
        let ctxs: Vec<_> = mgrs.iter().map(|m| m.ctx()).collect();

        assert!(kvs[0].insert(&ctxs[0], 7, &[100]).unwrap());
        // Visible from every node (index broadcast + remote read).
        for i in 0..3 {
            assert_eq!(kvs[i].get(&ctxs[i], 7), Some(vec![100]), "node {i}");
        }
        // Update from a non-home node.
        assert!(kvs[2].update(&ctxs[2], 7, &[200]));
        for i in 0..3 {
            assert_eq!(kvs[i].get(&ctxs[i], 7), Some(vec![200]));
        }
        // Delete from a third node.
        assert!(kvs[1].remove(&ctxs[1], 7));
        for i in 0..3 {
            assert_eq!(kvs[i].get(&ctxs[i], 7), None);
        }
        // Slot reclaimed at home (node 0).
        assert_eq!(kvs[0].slots_outstanding(), 0);
        kvs[0].slab_audit().unwrap();
    }

    #[test]
    fn missing_key_and_double_ops() {
        let (mgrs, kvs) = setup(2, FabricConfig::inline_ideal());
        let ctx = mgrs[0].ctx();
        assert_eq!(kvs[0].get(&ctx, 42), None);
        assert!(!kvs[0].update(&ctx, 42, &[1]));
        assert!(!kvs[0].remove(&ctx, 42));
        assert!(kvs[0].insert(&ctx, 42, &[1]).unwrap());
        assert!(!kvs[0].insert(&ctx, 42, &[2]).unwrap(), "second insert is update");
        assert_eq!(kvs[0].get(&ctx, 42), Some(vec![2]));
    }

    /// Variable-size values end to end: lengths across every class of
    /// an 8-word geometry round-trip through insert / scalar get /
    /// multi_get / windowed get from every node, with the exact length
    /// preserved (frames are trimmed to the header's `len`).
    #[test]
    fn variable_size_values_roundtrip() {
        let cfg = KvConfig { value_words: 8, ..small_cfg() };
        let (mgrs, kvs) = setup_cfg(3, FabricConfig::threaded(LatencyModel::fast_sim()), cfg);
        let ctxs: Vec<_> = mgrs.iter().map(|m| m.ctx()).collect();
        let value_of = |k: u64| vec![k + 100; 1 + (k % 8) as usize];
        for k in 0..24u64 {
            assert!(kvs[(k % 3) as usize].insert(&ctxs[(k % 3) as usize], k, &value_of(k)).unwrap());
        }
        for (i, kv) in kvs.iter().enumerate() {
            for k in 0..24u64 {
                assert_eq!(kv.get(&ctxs[i], k), Some(value_of(k)), "node {i} key {k}");
            }
            let keys: Vec<u64> = (0..24).collect();
            let out = kv.multi_get(&ctxs[i], &keys);
            for (j, got) in out.into_iter().enumerate() {
                assert_eq!(got, Some(value_of(j as u64)), "node {i} multi_get key {j}");
            }
            let pgs: Vec<_> = keys.iter().map(|&k| kv.get_issue(&ctxs[i], k).unwrap()).collect();
            for (k, pg) in keys.iter().zip(pgs) {
                assert_eq!(kv.get_complete(&ctxs[i], pg), Some(value_of(*k)));
            }
        }
        for kv in &kvs {
            kv.slab_audit().unwrap();
        }
    }

    /// The relocation protocol: an update that outgrows its slot's
    /// class moves the key to a fresh slot (new home = the updater, new
    /// generation), every node serves the new value afterwards, and the
    /// old slot returns to its home's free list (audit-clean on both).
    #[test]
    fn update_past_class_boundary_relocates() {
        let cfg = KvConfig { value_words: 16, ..small_cfg() };
        let (mgrs, kvs) = setup_cfg(3, FabricConfig::threaded(LatencyModel::fast_sim()), cfg);
        let ctxs: Vec<_> = mgrs.iter().map(|m| m.ctx()).collect();

        assert!(kvs[0].insert(&ctxs[0], 7, &[1, 1]).unwrap()); // class 1 on node 0
        let before = kvs[0].index_entry(7).unwrap();
        assert_eq!(before.node, 0);

        // Node 2 grows the value past the 2-word class: relocation.
        assert!(kvs[2].update(&ctxs[2], 7, &[9; 11]));
        let after = kvs[2].index_entry(7).unwrap();
        assert_eq!(after.node, 2, "relocated to the updating node");
        assert_ne!((after.slot, after.counter), (before.slot, before.counter));
        for i in 0..3 {
            assert_eq!(kvs[i].get(&ctxs[i], 7), Some(vec![9; 11]), "node {i}");
            assert_eq!(kvs[i].index_entry(7), Some(after), "node {i} index diverged");
        }
        // Old slot reclaimed at the old home; shrink-update stays put
        // (a smaller value always fits in place).
        assert_eq!(kvs[0].slots_outstanding(), 0);
        assert!(kvs[1].update(&ctxs[1], 7, &[3]));
        assert_eq!(kvs[2].index_entry(7), Some(after), "shrink must not relocate");
        for i in 0..3 {
            assert_eq!(kvs[i].get(&ctxs[i], 7), Some(vec![3]), "node {i}");
        }
        // Delete after relocation reclaims the new slot too.
        assert!(kvs[1].remove(&ctxs[1], 7));
        for kv in &kvs {
            assert_eq!(kv.slots_outstanding(), 0);
            kv.slab_audit().unwrap();
        }
    }

    /// Relocation with the locality tier + replication on: cached copies
    /// of the pre-relocation value die with the generation change, and
    /// the relocated frame is replicated (survives a crash of the NEW
    /// home).
    #[test]
    fn relocation_invalidates_cache_and_replicates() {
        let cfg = KvConfig {
            value_words: 8,
            read_cache_bytes: 4096,
            replicas: 2,
            ..small_cfg()
        };
        let (mgrs, kvs) = setup_cfg(3, FabricConfig::threaded(LatencyModel::fast_sim()), cfg);
        let ctxs: Vec<_> = mgrs.iter().map(|m| m.ctx()).collect();

        assert!(kvs[1].insert(&ctxs[1], 5, &[70]).unwrap());
        assert_eq!(kvs[2].get(&ctxs[2], 5), Some(vec![70])); // fills node 2's cache
        assert_eq!(kvs[2].get(&ctxs[2], 5), Some(vec![70]));

        // Node 0 relocates the key (1 word → 5 words).
        assert!(kvs[0].update(&ctxs[0], 5, &[71; 5]));
        assert_eq!(kvs[2].get(&ctxs[2], 5), Some(vec![71; 5]), "stale cached value served");
        assert_eq!(kvs[2].index_entry(5).unwrap().node, 0);

        // Crash the new home: the backup (node 1) re-homes the
        // relocated frame — the post-relocation value survives.
        mgrs[0].cluster().crash(0);
        let deadline = std::time::Instant::now() + Duration::from_secs(20);
        while kvs[2].index_entry(5).map(|e| e.node) != Some(1) {
            assert!(std::time::Instant::now() < deadline, "re-home never completed");
            std::thread::yield_now();
        }
        assert_eq!(kvs[2].get(&ctxs[2], 5), Some(vec![71; 5]), "relocated value lost in crash");
    }

    /// Class exhaustion falls up to larger classes before reporting
    /// Capacity, and frees refill the exact class.
    #[test]
    fn class_exhaustion_falls_up_then_errors() {
        // 4 classes (1,2,4,8) × 4 slots each.
        let cfg = KvConfig { slots_per_node: 4, value_words: 8, ..small_cfg() };
        let (mgrs, kvs) = setup_cfg(2, FabricConfig::inline_ideal(), cfg);
        let ctx = mgrs[0].ctx();
        // 16 single-word inserts: 4 land in class 0, the rest fall up.
        for k in 0..16u64 {
            kvs[0].insert(&ctx, k, &[k]).unwrap();
        }
        assert!(matches!(kvs[0].insert(&ctx, 99, &[0]), Err(Error::Capacity(_))));
        // Everything still reads back exactly.
        for k in 0..16u64 {
            assert_eq!(kvs[0].get(&ctx, k), Some(vec![k]));
        }
        kvs[0].slab_audit().unwrap();
        assert!(kvs[0].remove(&ctx, 3));
        assert!(kvs[0].insert(&ctx, 99, &[1]).unwrap(), "freed capacity reusable");
    }

    #[test]
    fn capacity_exhaustion() {
        let (mgrs, kvs) = setup(2, FabricConfig::inline_ideal());
        let ctx = mgrs[0].ctx();
        for k in 0..64 {
            kvs[0].insert(&ctx, k, &[k]).unwrap();
        }
        assert!(matches!(kvs[0].insert(&ctx, 999, &[0]), Err(Error::Capacity(_))));
    }

    #[test]
    fn prefill_batch_visible_everywhere() {
        let (mgrs, kvs) = setup(3, FabricConfig::inline_ideal());
        let ctxs: Vec<_> = mgrs.iter().map(|m| m.ctx()).collect();
        // Each node loads its hash-partitioned shard.
        let all: Vec<u64> = (0..150).collect();
        for (i, kv) in kvs.iter().enumerate() {
            let mine: Vec<u64> =
                all.iter().copied().filter(|&k| kv.home_of(k) == i as NodeId).collect();
            kv.prefill_local(&ctxs[i], &mine, |k| vec![k * 10], None).unwrap();
        }
        for kv in &kvs {
            assert_eq!(kv.index_len(), 150);
        }
        for &k in &all {
            assert_eq!(kvs[(k % 3) as usize].get(&ctxs[(k % 3) as usize], k), Some(vec![k * 10]));
        }
    }

    /// multi_get matches scalar gets across hit/miss/deleted keys and
    /// tolerates duplicates, on both delivery modes and with the read
    /// cache on and off.
    #[test]
    fn multi_get_matches_scalar() {
        for cache_bytes in [0usize, 4096] {
            for fabric in
                [FabricConfig::inline_ideal(), FabricConfig::threaded(LatencyModel::fast_sim())]
            {
                let cfg = KvConfig { read_cache_bytes: cache_bytes, ..small_cfg() };
                let (mgrs, kvs) = setup_cfg(3, fabric, cfg);
                let ctxs: Vec<_> = mgrs.iter().map(|m| m.ctx()).collect();
                // Spread homes across nodes: each node inserts its residue class.
                for k in 0..30u64 {
                    kvs[(k % 3) as usize].insert(&ctxs[(k % 3) as usize], k, &[k + 500]).unwrap();
                }
                kvs[0].remove(&ctxs[0], 9);
                // Batch with hits on all three homes, a miss, a deleted key,
                // and a duplicate.
                let keys = [0u64, 1, 2, 17, 999, 9, 2];
                let out = kvs[1].multi_get(&ctxs[1], &keys);
                for (i, &k) in keys.iter().enumerate() {
                    assert_eq!(out[i], kvs[1].get(&ctxs[1], k), "key {k}");
                }
                assert_eq!(out[4], None);
                assert_eq!(out[5], None);
                assert_eq!(out[6], Some(vec![502]));
                // Second batch: with the cache on, remote-homed keys now hit.
                let out = kvs[1].multi_get(&ctxs[1], &keys);
                assert_eq!(out[6], Some(vec![502]));
                if cache_bytes > 0 {
                    assert!(kvs[1].cache_stats().hits > 0, "no cache hits recorded");
                }
            }
        }
    }

    /// Write combining (PR-5): back-to-back updates of the same key in
    /// one `multi_put` collapse to the last value — every present item
    /// still counts as applied, only one frame is written, and a
    /// collapsed earlier occurrence can neither clobber a later one nor
    /// force a dead relocation.
    #[test]
    fn multi_put_collapses_duplicate_keys() {
        let cfg = KvConfig { value_words: 8, ..small_cfg() };
        let (mgrs, kvs) = setup_cfg(2, FabricConfig::inline_ideal(), cfg);
        let ctxs: Vec<_> = mgrs.iter().map(|m| m.ctx()).collect();
        kvs[0].insert(&ctxs[0], 7, &[1]).unwrap();
        kvs[0].insert(&ctxs[0], 8, &[2]).unwrap();
        // Three updates of key 7 (last wins) interleaved with key 8.
        let items: Vec<(u64, Vec<u64>)> = vec![
            (7, vec![10]),
            (8, vec![20]),
            (7, vec![11]),
            (7, vec![12]),
        ];
        assert_eq!(kvs[1].multi_put(&ctxs[1], &items), 4, "every present item counts");
        assert_eq!(kvs[1].get(&ctxs[1], 7), Some(vec![12]), "last value wins");
        assert_eq!(kvs[1].get(&ctxs[1], 8), Some(vec![20]));
        // An earlier small update collapses into a later RELOCATING one:
        // only the 8-word value lands, via the relocation path.
        let items: Vec<(u64, Vec<u64>)> = vec![(7, vec![30]), (7, vec![31; 8])];
        assert_eq!(kvs[1].multi_put(&ctxs[1], &items), 2);
        for (i, kv) in kvs.iter().enumerate() {
            assert_eq!(kv.get(&ctxs[i], 7), Some(vec![31; 8]), "node {i}");
        }
        // And an earlier RELOCATING update collapses into a later
        // in-place-sized one (the new 8-word slot fits 1 word in place).
        let items: Vec<(u64, Vec<u64>)> = vec![(7, vec![40; 8]), (7, vec![41])];
        assert_eq!(kvs[1].multi_put(&ctxs[1], &items), 2);
        assert_eq!(kvs[1].get(&ctxs[1], 7), Some(vec![41]));
        kvs[0].slab_audit().unwrap();
        kvs[1].slab_audit().unwrap();
    }

    /// Coalesced invalidations (PR-5): with the cache on, an in-place
    /// update's return still guarantees every peer's cached copy is
    /// gone — scalar back-to-back (each snapshot carries one key) and
    /// under same-node concurrency (snapshots merge several updaters;
    /// the union ack wait releases them all). The reader would serve a
    /// stale cached value forever if an invalidation were lost.
    #[test]
    fn coalesced_invals_keep_peers_fresh() {
        let (mgrs, kvs) = setup_cfg(2, FabricConfig::inline_ideal(), cached_cfg());
        let ctx0 = mgrs[0].ctx();
        let ctx1 = mgrs[1].ctx();
        assert!(kvs[0].config().coalesce_invals, "coalescing is the default");
        kvs[0].insert(&ctx0, 1, &[100]).unwrap();
        // Fill node 1's cache, then update in place repeatedly: every
        // update's return must already be visible through the cache.
        for round in 0..20u64 {
            assert_eq!(kvs[1].get(&ctx1, 1), Some(vec![100 + round]));
            assert_eq!(kvs[1].get(&ctx1, 1), Some(vec![100 + round])); // cached hit
            assert!(kvs[0].update(&ctx0, 1, &[100 + round + 1]));
        }
        // Concurrent same-node updaters on distinct keys: their OP_INVAL
        // broadcasts ride shared snapshots.
        for k in 10..14u64 {
            kvs[0].insert(&ctx0, k, &[0]).unwrap();
            let _ = kvs[1].get(&ctx1, k); // warm the peer cache
        }
        let updaters: Vec<_> = (10..14u64)
            .map(|k| {
                let m = mgrs[0].clone();
                let kv = kvs[0].clone();
                std::thread::spawn(move || {
                    let ctx = m.ctx();
                    for v in 1..=50u64 {
                        assert!(kv.update(&ctx, k, &[k * 1000 + v]));
                    }
                })
            })
            .collect();
        for h in updaters {
            h.join().unwrap();
        }
        for k in 10..14u64 {
            assert_eq!(kvs[1].get(&ctx1, k), Some(vec![k * 1000 + 50]), "key {k}");
        }
    }

    #[test]
    fn tracker_shards_env_is_validated() {
        assert_eq!(parse_tracker_shards(None), Ok(1));
        assert_eq!(parse_tracker_shards(Some("2")), Ok(2));
        assert_eq!(parse_tracker_shards(Some(" 4 ")), Ok(4));
        assert!(parse_tracker_shards(Some("0")).unwrap_err().contains("at least one"));
        assert!(parse_tracker_shards(Some("two")).is_err());
        assert!(parse_tracker_shards(Some("-2")).is_err());
        assert!(parse_tracker_shards(Some("")).is_err());
    }

    /// Shard routing is a stable pure function (a key's ops must ride
    /// one ring forever) and `group_by_shard` partitions losslessly in
    /// ascending shard order.
    #[test]
    fn shard_routing_is_stable_and_total() {
        for k in 0..1024u64 {
            assert_eq!(shard_of(k, 1), 0);
            let s = shard_of(k, 4);
            assert!(s < 4);
            assert_eq!(s, shard_of(k, 4), "a key's shard never changes");
        }
        let keys: Vec<u64> = (0..64).collect();
        let groups = group_by_shard(&keys, 4);
        assert_eq!(groups.iter().map(|(_, g)| g.len()).sum::<usize>(), 64);
        let shards: Vec<usize> = groups.iter().map(|(s, _)| *s).collect();
        let mut sorted = shards.clone();
        sorted.sort_unstable();
        assert_eq!(shards, sorted, "groups come out in shard order");
        assert!(shards.len() > 1, "64 ranges spread across >1 of 4 shards");
    }

    /// Sharded tracker rings (PR-10): every op about one key rides the
    /// same shard ring, so rapid same-key transitions — an insert from
    /// one peer, then a delete + re-insert from another — apply in
    /// broadcast order on every node. A routing bug that let a key's
    /// delete and re-insert ride different rings could reorder them
    /// into "insert, then delete" and lose the key.
    #[test]
    fn sharded_tracker_preserves_per_key_order() {
        let cfg = KvConfig { tracker_shards: 3, ..small_cfg() };
        let (mgrs, kvs) = setup_cfg(3, FabricConfig::threaded(LatencyModel::fast_sim()), cfg);
        let ctxs: Vec<_> = mgrs.iter().map(|m| m.ctx()).collect();
        for k in 0..32u64 {
            assert!(kvs[0].insert(&ctxs[0], k, &[k + 1]).unwrap());
            assert!(kvs[1].remove(&ctxs[1], k));
            assert!(kvs[1].insert(&ctxs[1], k, &[k + 1000]).unwrap());
        }
        for k in 0..32u64 {
            let e = kvs[1].index_entry(k).expect("key survived the delete + re-insert");
            assert_eq!(e.node, 1, "key {k} homed on its re-inserter");
            for (i, kv) in kvs.iter().enumerate() {
                assert_eq!(kv.get(&ctxs[i], k), Some(vec![k + 1000]), "node {i} key {k}");
                assert_eq!(kv.index_entry(k), Some(e), "node {i} key {k} index diverged");
            }
        }
        for kv in &kvs {
            kv.slab_audit().unwrap();
        }
    }

    /// Bulk prefill with sharding on: each `OP_BATCH` chunk splits into
    /// per-shard frames (a key's batch insert must ride the same ring
    /// as its later ops), everything reads back from every node, and
    /// the rings stay usable for follow-on keyed traffic.
    #[test]
    fn sharded_prefill_converges() {
        let cfg = KvConfig { tracker_shards: 4, ..small_cfg() };
        let (mgrs, kvs) = setup_cfg(3, FabricConfig::threaded(LatencyModel::fast_sim()), cfg);
        let ctxs: Vec<_> = mgrs.iter().map(|m| m.ctx()).collect();
        let keys: Vec<u64> = (0..48).collect();
        kvs[0].prefill_local(&ctxs[0], &keys, |k| vec![k * 3], None).unwrap();
        for (i, kv) in kvs.iter().enumerate() {
            for &k in &keys {
                assert_eq!(kv.get(&ctxs[i], k), Some(vec![k * 3]), "node {i} key {k}");
            }
        }
        assert!(kvs[2].remove(&ctxs[2], 7));
        for (i, kv) in kvs.iter().enumerate() {
            assert_eq!(kv.get(&ctxs[i], 7), None, "node {i} still serves the deleted key");
        }
    }

    /// Crash-stop with sharded trackers: every shard's union-ack wait
    /// (the coalesced-invalidation snapshot's release condition) drains
    /// the dead peer's receivers — `PeerFailed` drops them from the ack
    /// minimum — instead of wedging, and the live peer still observes
    /// every invalidation. Keys are chosen so their lock hosts stay
    /// alive; the dead node participates only as a tracker receiver.
    #[test]
    fn sharded_union_ack_survives_crash() {
        let cfg = KvConfig { tracker_shards: 2, ..cached_cfg() };
        let (mgrs, kvs) = setup_cfg(3, FabricConfig::threaded(LatencyModel::fast_sim()), cfg);
        let ctxs: Vec<_> = mgrs.iter().map(|m| m.ctx()).collect();
        let keys: Vec<u64> = (0..12).filter(|k| k % 3 != 2).collect(); // lock hosts 0/1 only
        for &k in &keys {
            kvs[0].insert(&ctxs[0], k, &[k]).unwrap();
            let _ = kvs[1].get(&ctxs[1], k); // warm the live peer's cache
            let _ = kvs[2].get(&ctxs[2], k); // and the one about to die
        }
        mgrs[0].cluster().crash(2);
        for &k in &keys {
            assert!(kvs[0].update(&ctxs[0], k, &[k + 500]), "update wedged on the dead peer");
            assert_eq!(kvs[1].get(&ctxs[1], k), Some(vec![k + 500]), "key {k} stale on live peer");
        }
    }

    /// multi_put updates present keys, skips absent ones, and the batch
    /// fence makes every write durable before the locks release.
    #[test]
    fn multi_put_batched_updates() {
        let (mgrs, kvs) = setup(3, FabricConfig::threaded(LatencyModel::fast_sim()));
        let ctxs: Vec<_> = mgrs.iter().map(|m| m.ctx()).collect();
        for k in 0..24u64 {
            kvs[(k % 3) as usize].insert(&ctxs[(k % 3) as usize], k, &[0]).unwrap();
        }
        // Node 1 batch-updates keys homed on all three nodes (+1 absent).
        let items: Vec<(u64, Vec<u64>)> =
            (0..24u64).map(|k| (k, vec![k * 7])).chain([(777u64, vec![1])]).collect();
        assert_eq!(kvs[1].multi_put(&ctxs[1], &items), 24);
        for k in 0..24u64 {
            for (i, kv) in kvs.iter().enumerate() {
                assert_eq!(kv.get(&ctxs[i], k), Some(vec![k * 7]), "node {i} key {k}");
            }
        }
        assert_eq!(kvs[1].get(&ctxs[1], 777), None, "absent key skipped");
        // Empty batches are no-ops.
        assert_eq!(kvs[1].multi_put(&ctxs[1], &[]), 0);
        assert!(kvs[1].multi_get(&ctxs[1], &[]).is_empty());
    }

    /// Concurrent multi_puts from every node (overlapping key sets, so
    /// overlapping lock sets) must not deadlock and must leave each key
    /// holding one of the contending values. Cache enabled: the batch
    /// invalidation broadcast runs under the held locks.
    #[test]
    fn concurrent_multi_put_no_deadlock() {
        let (mgrs, kvs) =
            setup_cfg(3, FabricConfig::threaded(LatencyModel::fast_sim()), cached_cfg());
        let ctxs: Vec<_> = mgrs.iter().map(|m| m.ctx()).collect();
        for k in 0..16u64 {
            kvs[0].insert(&ctxs[0], k, &[0]).unwrap();
        }
        let handles: Vec<_> = mgrs
            .iter()
            .zip(&kvs)
            .enumerate()
            .map(|(i, (m, kv))| {
                let m = m.clone();
                let kv = kv.clone();
                std::thread::spawn(move || {
                    let ctx = m.ctx();
                    for round in 0..20u64 {
                        let items: Vec<(u64, Vec<u64>)> = (0..16u64)
                            .map(|k| (k, vec![1 + (i as u64) * 1000 + round]))
                            .collect();
                        assert_eq!(kv.multi_put(&ctx, &items), 16);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        for k in 0..16u64 {
            let v = kvs[0].get(&ctxs[0], k).expect("key survived");
            assert!(v[0] >= 1, "key {k} holds a contending value, got {v:?}");
        }
    }

    #[test]
    fn windowed_gets() {
        let (mgrs, kvs) = setup(2, FabricConfig::threaded(LatencyModel::fast_sim()));
        let ctxs: Vec<_> = mgrs.iter().map(|m| m.ctx()).collect();
        for k in 0..32 {
            kvs[0].insert(&ctxs[0], k, &[k + 1000]).unwrap();
        }
        // Window of 8 outstanding reads from node 1.
        let mut pending = Vec::new();
        let mut results = Vec::new();
        for k in 0..32u64 {
            pending.push((k, kvs[1].get_issue(&ctxs[1], k).unwrap()));
            if pending.len() == 8 {
                for (k, pg) in pending.drain(..) {
                    results.push((k, kvs[1].get_complete(&ctxs[1], pg)));
                }
            }
        }
        for (k, pg) in pending.drain(..) {
            results.push((k, kvs[1].get_complete(&ctxs[1], pg)));
        }
        for (k, v) in results {
            assert_eq!(v, Some(vec![k + 1000]));
        }
    }

    /// The locality tier end to end: repeat gets hit the cache, updates
    /// and deletes invalidate every node before returning, windowed gets
    /// resolve cached keys at issue time.
    #[test]
    fn cached_get_hits_and_stays_fresh() {
        let (mgrs, kvs) =
            setup_cfg(3, FabricConfig::threaded(LatencyModel::fast_sim()), cached_cfg());
        let ctxs: Vec<_> = mgrs.iter().map(|m| m.ctx()).collect();

        assert!(kvs[0].insert(&ctxs[0], 5, &[700]).unwrap());
        // First get from node 2 fills, second hits.
        assert_eq!(kvs[2].get(&ctxs[2], 5), Some(vec![700]));
        assert_eq!(kvs[2].get(&ctxs[2], 5), Some(vec![700]));
        let s = kvs[2].cache_stats();
        assert!(s.fills >= 1, "{s:?}");
        assert!(s.hits >= 1, "{s:?}");

        // Update from node 1: node 2's cached copy must be gone by the
        // time update() returns.
        assert!(kvs[1].update(&ctxs[1], 5, &[701]));
        assert_eq!(kvs[2].get(&ctxs[2], 5), Some(vec![701]), "stale cached value served");

        // Windowed path: issue resolves from cache once re-filled.
        assert_eq!(kvs[2].get(&ctxs[2], 5), Some(vec![701]));
        let pg = kvs[2].get_issue(&ctxs[2], 5).unwrap();
        assert!(pg.is_complete(), "cached issue should resolve instantly");
        assert_eq!(kvs[2].get_complete(&ctxs[2], pg), Some(vec![701]));

        // Delete: after remove() returns no node may serve the value.
        assert!(kvs[0].remove(&ctxs[0], 5));
        for i in 0..3 {
            assert_eq!(kvs[i].get(&ctxs[i], 5), None, "node {i}");
        }
        // Re-insert gets a fresh generation; old cached copies can't hit.
        assert!(kvs[1].insert(&ctxs[1], 5, &[702]).unwrap());
        for i in 0..3 {
            assert_eq!(kvs[i].get(&ctxs[i], 5), Some(vec![702]), "node {i}");
        }
    }

    /// Crash-stop + re-home end to end: keys homed on the dead node come
    /// back from the backup replica (same values, new home on the backup
    /// node), deleted keys stay gone, mutations whose lock lives on the
    /// corpse fail fast, and everything else keeps serving.
    #[test]
    fn crash_rehomes_dead_nodes_keys_from_backup() {
        let cfg = KvConfig {
            slots_per_node: 64,
            tracker_words: 1 << 10,
            read_cache_bytes: 2048,
            replicas: 2,
            ..Default::default()
        };
        let (mgrs, kvs) = setup_cfg(3, FabricConfig::threaded(LatencyModel::fast_sim()), cfg);
        let ctxs: Vec<_> = mgrs.iter().map(|m| m.ctx()).collect();

        // Node 1 homes keys 100..110; cross-node update + delete + a
        // cache fill before the crash.
        for k in 100..110u64 {
            assert!(kvs[1].insert(&ctxs[1], k, &[k * 3]).unwrap());
        }
        assert!(kvs[0].update(&ctxs[0], 105, &[999]));
        assert!(kvs[2].remove(&ctxs[2], 107));
        assert_eq!(kvs[2].get(&ctxs[2], 104), Some(vec![312])); // fills node 2's cache

        mgrs[0].cluster().crash(1);

        // Recovery: node 2 == backup_of(1) re-homes the range; wait for
        // the index to reflect it everywhere.
        let deadline = std::time::Instant::now() + Duration::from_secs(20);
        loop {
            let moved = [&kvs[0], &kvs[2]].iter().all(|kv| {
                (100..110u64)
                    .filter(|k| *k != 107)
                    .all(|k| kv.index_entry(k).map(|e| e.node == 2).unwrap_or(false))
            });
            if moved {
                break;
            }
            assert!(std::time::Instant::now() < deadline, "re-home never completed");
            std::thread::yield_now();
        }

        // Values survived the crash (including the pre-crash update);
        // the deleted key did not resurrect.
        for (i, kv) in [(0usize, &kvs[0]), (2usize, &kvs[2])] {
            for k in 100..110u64 {
                let expect = match k {
                    105 => Some(vec![999]),
                    107 => None,
                    _ => Some(vec![k * 3]),
                };
                assert_eq!(kv.get(&ctxs[i], k), expect, "node {i} key {k}");
            }
        }

        // Locks striped on the dead node (key % 256 % 3 == 1) are
        // unusable: mutations fail fast instead of hanging.
        assert!(matches!(
            kvs[0].try_update(&ctxs[0], 100, &[1]),
            Err(Error::PeerFailed(_))
        ));
        assert_eq!(kvs[0].get(&ctxs[0], 100), Some(vec![300]), "failed update left value");

        // Keys whose lock is alive stay fully mutable, and new inserts
        // (broadcast acks skip the corpse) still complete.
        assert_eq!(kvs[0].try_update(&ctxs[0], 101, &[777]), Ok(true));
        assert_eq!(kvs[2].get(&ctxs[2], 101), Some(vec![777]));
        assert!(kvs[0].insert(&ctxs[0], 200, &[42]).unwrap());
        assert_eq!(kvs[2].get(&ctxs[2], 200), Some(vec![42]));
    }

    /// Without replication a crash is a delete of the dead node's range:
    /// every surviving index purges it and reads return EMPTY.
    #[test]
    fn crash_without_replication_purges_dead_range() {
        let (mgrs, kvs) = setup(3, FabricConfig::threaded(LatencyModel::fast_sim()));
        let ctxs: Vec<_> = mgrs.iter().map(|m| m.ctx()).collect();
        for k in 30..36u64 {
            assert!(kvs[1].insert(&ctxs[1], k, &[k]).unwrap());
        }
        assert_eq!(kvs[0].get(&ctxs[0], 30), Some(vec![30]));
        mgrs[0].cluster().crash(1);
        let deadline = std::time::Instant::now() + Duration::from_secs(20);
        while kvs[0].index_entry(30).is_some() || kvs[2].index_entry(35).is_some() {
            assert!(std::time::Instant::now() < deadline, "purge never happened");
            std::thread::yield_now();
        }
        for k in 30..36u64 {
            assert_eq!(kvs[0].get(&ctxs[0], k), None, "key {k} not purged");
            assert_eq!(kvs[2].get(&ctxs[2], k), None, "key {k} not purged");
        }
    }

    /// The epoch half of the staleness guard, deterministically: a
    /// location broadcast stamped before the sender's last observed
    /// membership transition (a pre-crash duplicate delivered after the
    /// sender's slot re-joined) must not clobber the index. This is also
    /// the tripwire for the `--cfg loco_mutant_epoch` mutation
    /// smoke-check: that build deletes the guard, this test fails, and
    /// CI asserts that it does.
    #[test]
    fn stale_epoch_broadcast_is_rejected() {
        let (mgrs, kvs) = setup(2, FabricConfig::inline_ideal());
        let ctx0 = mgrs[0].ctx();
        assert!(kvs[0].insert(&ctx0, 9, &[55]).unwrap());
        let before = kvs[1].index_entry(9).unwrap();
        assert_eq!(before.node, 0);

        // Node 1 observes node 0 crash-stop and its slot begin a
        // re-join: state_epoch(0) moves past every stamp the old
        // incarnation could have produced.
        let m1 = &kvs[1].shared.membership;
        m1.note_dead(0);
        m1.note_joining(0);

        // The old incarnation's delayed OP_INSERT (stamp 1 < state_epoch
        // 2) re-announcing key 9 under a new generation. `send_tracker`
        // appends the stamp as the last word; the zero dead-mask
        // isolates the epoch half of the guard.
        let msg = [OP_INSERT, 9, 0, before.slot as u64, before.counter + 9, 1];
        apply_tracker(&kvs[1].shared, 1, 0, &msg, 0);
        assert_eq!(
            kvs[1].index_entry(9),
            Some(before),
            "stale-epoch broadcast clobbered the index"
        );
    }

    /// Satellite regression: an adversarial writer hammering updates and
    /// recycling slots (delete + reinsert) must not livelock concurrent
    /// readers — the bounded torn-read spin re-fetches the index entry
    /// and every get terminates with an untorn value.
    #[test]
    fn adversarial_writer_cannot_livelock_get() {
        let fabric = FabricConfig::threaded(LatencyModel::fast_sim()).chaotic();
        let cfg = KvConfig {
            slots_per_node: 32,
            value_words: 4,
            tracker_words: 1 << 12,
            read_cache_bytes: 2048,
            ..Default::default()
        };
        let (mgrs, kvs) = setup_cfg(2, fabric, cfg);
        let ctx0 = mgrs[0].ctx();
        kvs[0].insert(&ctx0, 1, &[1; 4]).unwrap();

        let writer = {
            let m = mgrs[0].clone();
            let kv = kvs[0].clone();
            std::thread::spawn(move || {
                let ctx = m.ctx();
                for round in 2..250u64 {
                    if round % 10 == 0 {
                        // Slot churn: the reader's cached entry goes stale.
                        kv.remove(&ctx, 1);
                        kv.insert(&ctx, 1, &[round; 4]).unwrap();
                    } else {
                        kv.update(&ctx, 1, &[round; 4]);
                    }
                }
            })
        };
        let reader = {
            let m = mgrs[1].clone();
            let kv = kvs[1].clone();
            std::thread::spawn(move || {
                let ctx = m.ctx();
                let mut observed = 0u64;
                for _ in 0..500 {
                    if let Some(v) = kv.get(&ctx, 1) {
                        assert!(v.iter().all(|&x| x == v[0]), "torn value: {v:?}");
                        observed += 1;
                    }
                }
                observed
            })
        };
        writer.join().unwrap();
        let observed = reader.join().unwrap();
        assert!(observed > 0, "reader starved outright");
        // And a final quiescent read agrees with the last write.
        let ctx1 = mgrs[1].ctx();
        let v = kvs[1].get(&ctx1, 1).expect("key present");
        assert!(v.iter().all(|&x| x == v[0]), "torn value after quiesce: {v:?}");
    }

    /// Concurrent mixed workload across nodes on the racy fabric: every
    /// read — scalar or batched — sees either a fully written value or
    /// nothing, never garbage. The batched reads exercise multi_get's
    /// torn-key rebatching under real races.
    #[test]
    fn concurrent_mixed_no_torn_values() {
        let n = 3;
        let cluster = Cluster::new(n, FabricConfig::threaded(LatencyModel::fast_sim()).chaotic());
        let mgrs: Vec<Arc<Manager>> =
            (0..n as NodeId).map(|i| Manager::new(cluster.clone(), i)).collect();
        let cfg = KvConfig {
            slots_per_node: 256,
            value_words: 4,
            tracker_words: 1 << 12,
            read_cache_bytes: 4096,
            ..Default::default()
        };
        let kvs: Vec<Arc<KvStore>> =
            mgrs.iter().map(|m| KvStore::new(m, "kv", cfg.clone())).collect();
        for kv in &kvs {
            kv.wait_ready(Duration::from_secs(30));
        }
        // Values encode their key 4× so torn mixes are detectable.
        let handles: Vec<_> = mgrs
            .iter()
            .zip(&kvs)
            .enumerate()
            .map(|(i, (m, kv))| {
                let m = m.clone();
                let kv = kv.clone();
                std::thread::spawn(move || {
                    let ctx = m.ctx();
                    let mut rng = crate::util::rng::Rng::seeded(i as u64);
                    for round in 0..150u64 {
                        let key = rng.gen_range(32);
                        match rng.gen_range(10) {
                            0..=2 => {
                                let tag = round * 10 + i as u64;
                                let _ = kv.insert(&ctx, key, &[tag; 4]);
                            }
                            3..=4 => {
                                let _ = kv.remove(&ctx, key);
                            }
                            5 => {
                                let tag = round * 10 + i as u64;
                                let _ = kv.update(&ctx, key, &[tag; 4]);
                            }
                            6 => {
                                let keys = [key, (key + 7) % 32, key];
                                for v in kv.multi_get(&ctx, &keys).into_iter().flatten() {
                                    assert!(
                                        v.iter().all(|&x| x == v[0]),
                                        "torn value from multi_get: {v:?}"
                                    );
                                }
                            }
                            _ => {
                                if let Some(v) = kv.get(&ctx, key) {
                                    assert!(
                                        v.iter().all(|&x| x == v[0]),
                                        "torn value from get: {v:?}"
                                    );
                                }
                            }
                        }
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    }
}
