//! PJRT runtime: loads the AOT-compiled JAX/Pallas artifacts
//! (`artifacts/*.hlo.txt`) and executes them from Rust.
//!
//! This is the only place the compute layers (L1 Pallas kernels, L2 JAX
//! model) touch the serving path — as *compiled XLA executables*, never
//! as Python. The interchange format is HLO **text** (see
//! `python/compile/aot.py`): jax ≥ 0.5 emits HloModuleProtos with 64-bit
//! instruction ids that the crate's xla_extension 0.5.1 rejects, while
//! the text parser reassigns ids and round-trips cleanly.

use std::path::Path;
use std::sync::Mutex;

use crate::{Error, Result};

/// A PJRT CPU client plus the executables loaded into it.
pub struct Runtime {
    client: xla::PjRtClient,
}

fn xerr(e: xla::Error) -> Error {
    Error::Runtime(e.to_string())
}

impl Runtime {
    /// Create a CPU PJRT client.
    pub fn cpu() -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().map_err(xerr)?;
        Ok(Runtime { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load and compile an HLO-text artifact.
    pub fn load(&self, path: impl AsRef<Path>) -> Result<Executable> {
        let path = path.as_ref();
        let proto = xla::HloModuleProto::from_text_file(path).map_err(|e| {
            Error::Runtime(format!(
                "loading {} failed ({e}); run `make artifacts` first",
                path.display()
            ))
        })?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).map_err(xerr)?;
        Ok(Executable { exe: Mutex::new(exe), name: path.display().to_string() })
    }
}

/// One compiled artifact. Executions are serialized by a mutex: the PJRT
/// CPU client is not re-entrant per-executable, and LOCO's hot paths call
/// from a single driver thread anyway.
pub struct Executable {
    exe: Mutex<xla::PjRtLoadedExecutable>,
    name: String,
}

/// A typed input buffer for [`Executable::run`].
pub enum Input<'a> {
    F32(&'a [f32], &'a [i64]),
    F64(&'a [f64], &'a [i64]),
    U64(&'a [u64], &'a [i64]),
}

/// A typed output buffer.
#[derive(Debug, Clone, PartialEq)]
pub enum Output {
    F32(Vec<f32>),
    F64(Vec<f64>),
    U64(Vec<u64>),
}

impl Output {
    pub fn as_f32(&self) -> &[f32] {
        match self {
            Output::F32(v) => v,
            other => panic!("expected f32 output, got {other:?}"),
        }
    }

    pub fn as_f64(&self) -> &[f64] {
        match self {
            Output::F64(v) => v,
            other => panic!("expected f64 output, got {other:?}"),
        }
    }

    pub fn as_u64(&self) -> &[u64] {
        match self {
            Output::U64(v) => v,
            other => panic!("expected u64 output, got {other:?}"),
        }
    }
}

impl Executable {
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Execute with the given inputs. The artifact was lowered with
    /// `return_tuple=True`, so the result is always a tuple; each element
    /// is converted per its element type.
    pub fn run(&self, inputs: &[Input<'_>]) -> Result<Vec<Output>> {
        let mut literals = Vec::with_capacity(inputs.len());
        for inp in inputs {
            let lit = match inp {
                Input::F32(data, dims) => {
                    xla::Literal::vec1(data).reshape(dims).map_err(xerr)?
                }
                Input::F64(data, dims) => {
                    xla::Literal::vec1(data).reshape(dims).map_err(xerr)?
                }
                Input::U64(data, dims) => {
                    xla::Literal::vec1(data).reshape(dims).map_err(xerr)?
                }
            };
            literals.push(lit);
        }
        let exe = self.exe.lock().unwrap();
        let result = exe.execute::<xla::Literal>(&literals).map_err(xerr)?[0][0]
            .to_literal_sync()
            .map_err(xerr)?;
        drop(exe);
        let parts = result.to_tuple().map_err(xerr)?;
        let mut outs = Vec::with_capacity(parts.len());
        for p in parts {
            let ty = p.ty().map_err(xerr)?;
            let out = match ty {
                xla::ElementType::F32 => Output::F32(p.to_vec::<f32>().map_err(xerr)?),
                xla::ElementType::F64 => Output::F64(p.to_vec::<f64>().map_err(xerr)?),
                xla::ElementType::U64 => Output::U64(p.to_vec::<u64>().map_err(xerr)?),
                other => {
                    return Err(Error::Runtime(format!(
                        "{}: unsupported output element type {other:?}",
                        self.name
                    )))
                }
            };
            outs.push(out);
        }
        Ok(outs)
    }
}

/// Default artifact directory (overridable with `LOCO_ARTIFACTS`).
pub fn artifacts_dir() -> std::path::PathBuf {
    std::env::var_os("LOCO_ARTIFACTS")
        .map(Into::into)
        .unwrap_or_else(|| std::path::PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    /// These tests require `make artifacts` to have produced the HLO
    /// files; they are skipped (not failed) if artifacts are missing so
    /// `cargo test` works on a fresh checkout.
    fn artifact(name: &str) -> Option<Executable> {
        let path = artifacts_dir().join(name);
        if !path.exists() {
            eprintln!("skipping: {} missing (run `make artifacts`)", path.display());
            return None;
        }
        let rt = Runtime::cpu().expect("pjrt cpu client");
        Some(rt.load(path).expect("load artifact"))
    }

    #[test]
    fn checksum_artifact_matches_rust_fnv64() {
        let Some(exe) = artifact("checksum4.hlo.txt") else { return };
        // 1024 rows × 4 words; first 8 rows are the shared golden vectors
        // that python/tests/test_kernels.py pins too.
        let mut rows: Vec<u64> = (0..4096).map(|i| (i as u64).wrapping_mul(0x9E3779B97F4A7C15)).collect();
        rows.truncate(4096);
        let out = exe.run(&[Input::U64(&rows, &[1024, 4])]).unwrap();
        let got = out[0].as_u64();
        for r in 0..1024 {
            let expect = crate::util::fnv64(&rows[r * 4..r * 4 + 4]);
            assert_eq!(got[r], expect, "row {r}");
        }
    }

    #[test]
    fn converter_artifact_matches_native_mirror() {
        let Some(exe) = artifact("converter1.hlo.txt") else { return };
        let (i0, v0, d) = (1.5f64, 10.0f64, 0.7f64);
        let out = exe
            .run(&[Input::F64(&[i0, v0], &[2, 1]), Input::F64(&[d], &[1])])
            .unwrap();
        let s2 = out[0].as_f64();
        let v = out[1].as_f64();
        let (ei, ev) = crate::apps::power::converter_step_native(i0, v0, d);
        assert!((s2[0] - ei).abs() < 1e-12, "i: {} vs {}", s2[0], ei);
        assert!((s2[1] - ev).abs() < 1e-12, "v: {} vs {}", s2[1], ev);
        assert!((v[0] - ev).abs() < 1e-12);
    }

    #[test]
    fn controller_artifact_matches_native_mirror() {
        let Some(exe) = artifact("controller4.hlo.txt") else { return };
        let v_meas = [20.0f64, 24.0, 30.0, 0.0];
        let integ = [0.0f64; 4];
        let dt = [40e-6f64];
        let out = exe
            .run(&[
                Input::F64(&v_meas, &[4]),
                Input::F64(&integ, &[4]),
                Input::F64(&dt, &[1]),
            ])
            .unwrap();
        let duty = out[0].as_f64();
        let integ2 = out[1].as_f64();
        for i in 0..4 {
            let (ed, eg) = crate::apps::power::controller_step_native(v_meas[i], integ[i], dt[0]);
            assert!((duty[i] - ed).abs() < 1e-12, "duty[{i}]: {} vs {}", duty[i], ed);
            assert!((integ2[i] - eg).abs() < 1e-12);
        }
    }
}
