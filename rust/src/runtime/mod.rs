//! PJRT runtime facade: loads the AOT-compiled JAX/Pallas artifacts
//! (`artifacts/*.hlo.txt`) and executes them from Rust.
//!
//! In the full three-layer build this is the only place the compute
//! layers (L1 Pallas kernels, L2 JAX model) touch the serving path — as
//! *compiled XLA executables* through a PJRT CPU client, never as
//! Python. The interchange format is HLO **text** (see
//! `python/compile/aot.py`).
//!
//! **This offline build has no PJRT client** (the `xla` bindings cannot
//! be vendored here), so [`Runtime::cpu`] returns
//! [`Error::Runtime`](crate::Error::Runtime) and every caller falls back
//! to its bit-identical native mirror:
//!
//! * the power controller uses
//!   [`converter_step_native`](crate::apps::power::converter_step_native)
//!   / [`controller_step_native`](crate::apps::power::controller_step_native),
//!   pinned to the Python model's constants by `python/tests`;
//! * the kvstore prefill path computes checksums with
//!   [`fnv64`](crate::util::fnv64), the same function the Pallas
//!   checksum kernel implements (`python/compile/kernels/checksum.py`).
//!
//! The API surface (types and signatures) is kept identical to the real
//! client so swapping the PJRT implementation back in is a local change.

use std::path::Path;

use crate::{Error, Result};

fn unavailable() -> Error {
    Error::Runtime(
        "PJRT runtime unavailable in this offline build; \
         compute paths use the native mirrors"
            .to_string(),
    )
}

/// A PJRT CPU client plus the executables loaded into it. In this build
/// construction always fails gracefully (see the module docs).
pub struct Runtime {
    _private: (),
}

impl Runtime {
    /// Create a CPU PJRT client. Always returns
    /// [`Error::Runtime`](crate::Error::Runtime) in the offline build;
    /// callers are expected to fall back to their native mirrors.
    pub fn cpu() -> Result<Runtime> {
        Err(unavailable())
    }

    pub fn platform(&self) -> String {
        "unavailable".to_string()
    }

    /// Load and compile an HLO-text artifact.
    pub fn load(&self, path: impl AsRef<Path>) -> Result<Executable> {
        let _ = path;
        Err(unavailable())
    }
}

/// One compiled artifact (never constructible in the offline build; the
/// type exists so the `Compute::Hlo` path in
/// [`apps::power`](crate::apps::power) keeps compiling unchanged).
pub struct Executable {
    _private: (),
    name: String,
}

/// A typed input buffer for [`Executable::run`]: data plus dims.
pub enum Input<'a> {
    F32(&'a [f32], &'a [i64]),
    F64(&'a [f64], &'a [i64]),
    U64(&'a [u64], &'a [i64]),
}

/// A typed output buffer.
#[derive(Debug, Clone, PartialEq)]
pub enum Output {
    F32(Vec<f32>),
    F64(Vec<f64>),
    U64(Vec<u64>),
}

impl Output {
    pub fn as_f32(&self) -> &[f32] {
        match self {
            Output::F32(v) => v,
            other => panic!("expected f32 output, got {other:?}"),
        }
    }

    pub fn as_f64(&self) -> &[f64] {
        match self {
            Output::F64(v) => v,
            other => panic!("expected f64 output, got {other:?}"),
        }
    }

    pub fn as_u64(&self) -> &[u64] {
        match self {
            Output::U64(v) => v,
            other => panic!("expected u64 output, got {other:?}"),
        }
    }
}

impl Executable {
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Execute with the given inputs. Unreachable in the offline build
    /// (no [`Executable`] can be constructed), kept for API parity.
    pub fn run(&self, inputs: &[Input<'_>]) -> Result<Vec<Output>> {
        let _ = inputs;
        Err(unavailable())
    }
}

/// Default artifact directory (overridable with `LOCO_ARTIFACTS`).
pub fn artifacts_dir() -> std::path::PathBuf {
    std::env::var_os("LOCO_ARTIFACTS")
        .map(Into::into)
        .unwrap_or_else(|| std::path::PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The stub must fail *gracefully*: an Err every caller can route to
    /// its native mirror, never a panic.
    #[test]
    fn stub_errors_cleanly() {
        let err = Runtime::cpu().err().expect("stub cpu() must fail");
        assert!(matches!(err, Error::Runtime(_)));
        assert!(err.to_string().contains("PJRT runtime unavailable"));
    }

    #[test]
    fn artifacts_dir_default() {
        // Only exercise the default branch when the env var is unset, so
        // the test is robust to ambient configuration.
        if std::env::var_os("LOCO_ARTIFACTS").is_none() {
            assert_eq!(artifacts_dir(), std::path::PathBuf::from("artifacts"));
        }
    }
}
