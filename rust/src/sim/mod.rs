//! Deterministic discrete-event simulation of a whole cluster on one
//! OS thread.
//!
//! In `DeliveryMode::Sim` a cluster spawns no NIC-engine threads and its
//! [`Clock`] is **virtual**: time only moves when the scheduler here
//! advances it. A [`SimExecutor`] owns one steppable
//! [`EngineCore`](crate::fabric::nic::EngineCore) per node (the exact
//! state machine the threaded engine threads run) plus a list of
//! cooperative **services** — the manager's poll/ctrl work and the
//! kvstore's tracker loop register themselves here instead of spawning
//! threads.
//!
//! Every nondeterministic decision (which runnable engine steps next,
//! which service runs, when the clock advances) is drawn from one seeded
//! RNG stream and recorded, so:
//!
//! * **same seed ⇒ bit-identical run** — asserted via the event-trace
//!   hash ([`SimExecutor::trace_hash`]), which folds every executed verb
//!   arrival and every scheduler decision;
//! * **a failing schedule replays exactly** — and can be *shrunk*: the
//!   recorded choice list ([`SimExecutor::choices`]) can be fed back as
//!   a forced plan ([`SimExecutor::force_plan`]) with segments
//!   simplified, which is how the model harness in
//!   [`testkit`](crate::testkit) minimizes interleavings.
//!
//! The blocking waits all over the stack (ack waits, ring-buffer waits,
//! lock spins) reach the scheduler through one choke point:
//! [`Backoff::snooze`](crate::util::Backoff::snooze) calls
//! [`maybe_pump`], which runs one scheduler step when a `SimExecutor`
//! is installed on the current thread and is a no-op otherwise. So the
//! same application code runs unmodified under threads or under sim.
//!
//! What is preserved vs. the threaded mode: per-QP FIFO execution and
//! monotone arrival stamping, completion-before-placement lag, flushing
//! reads, torn placement, QP flaps with retransmit, selective-signaling
//! chain errors, crash-stop drains — all of it runs through the very
//! same `EngineCore` code. What changes: application/service code is
//! interleaved at `snooze` boundaries (cooperative points) instead of
//! preemptively, and wall-clock grace windows become deterministic
//! pump-count windows (see [`WaitBudget`](crate::util::WaitBudget)).

use std::cell::{Cell, RefCell};
use std::rc::Rc;
use std::sync::Arc;

use crate::fabric::nic::EngineCore;
use crate::fabric::{Clock, Cluster, DeliveryMode};
use crate::util::mix64;
use crate::util::rng::Rng;

/// A cooperative service: one non-blocking slice of work per call
/// (e.g. "poll the manager CQ once", "run one tracker iteration").
/// Returns whether it did anything — the scheduler uses that to decide
/// quiescence, so a service must not report idle polls as work.
#[derive(Clone)]
struct Service {
    name: String,
    /// Re-entrancy guard: a service's slice may block internally (e.g. a
    /// tracker waiting out an ack) and pump the scheduler from inside;
    /// the nested pump must not re-enter the same service.
    active: Rc<Cell<bool>>,
    f: Rc<RefCell<Box<dyn FnMut() -> bool>>>,
}

/// The scheduler state, shared between the [`SimExecutor`] handle and
/// the thread-local slot that [`maybe_pump`] reads.
struct SimCore {
    clock: Clock,
    engines: RefCell<Vec<EngineCore>>,
    services: RefCell<Vec<Service>>,
    sched_rng: RefCell<Rng>,
    /// Every scheduler decision, in order (index into the runnable set).
    choices: RefCell<Vec<u32>>,
    /// Forced replay plan: when set, decisions come from here (clamped
    /// to the runnable count; exhausted → 0) instead of the RNG.
    plan: RefCell<Option<Vec<u32>>>,
    plan_cursor: Cell<usize>,
    /// Monotone count of scheduler steps that did work. `WaitBudget`
    /// samples this to tell a long virtual wait from a true deadlock.
    progress: Cell<u64>,
    /// Event-trace hash over scheduler decisions and clock advances;
    /// [`SimExecutor::trace_hash`] folds the per-engine arrival traces
    /// in on top.
    trace: Cell<u64>,
}

impl SimCore {
    /// Draw (and record) one scheduler decision among `n` alternatives.
    fn choose(&self, n: u32) -> u32 {
        debug_assert!(n > 0);
        let raw = match &*self.plan.borrow() {
            Some(p) => {
                let cur = self.plan_cursor.get();
                self.plan_cursor.set(cur + 1);
                p.get(cur).copied().unwrap_or(0)
            }
            None => self.sched_rng.borrow_mut().gen_range(n as u64) as u32,
        };
        let pick = raw.min(n - 1);
        self.choices.borrow_mut().push(pick);
        pick
    }

    fn bump(&self, tag: u64, a: u64, b: u64) {
        self.progress.set(self.progress.get() + 1);
        self.trace
            .set(mix64(self.trace.get() ^ (tag << 60) ^ a.rotate_left(17) ^ b));
    }

    /// One scheduler step. Returns whether anything in the simulated
    /// world moved; `false` means the cluster is fully quiescent and
    /// nothing will ever move again without external input.
    fn pump_once(&self) -> bool {
        // Phase 1: engines with work runnable *now* — pick one.
        let now = self.clock.now_ns();
        let runnable: Vec<usize> = {
            let mut engines = self.engines.borrow_mut();
            for e in engines.iter_mut() {
                e.pickup_qps();
            }
            engines
                .iter()
                .enumerate()
                .filter(|(_, e)| e.has_immediate_work(now))
                .map(|(i, _)| i)
                .collect()
        };
        if !runnable.is_empty() {
            let pick = self.choose(runnable.len() as u32) as usize;
            let idx = runnable[pick];
            {
                let mut engines = self.engines.borrow_mut();
                engines[idx].step(&self.clock);
            }
            self.bump(1, idx as u64, now);
            return true;
        }

        // Phase 2: run each idle service one slice, in fixed order,
        // until one reports work. (Services are cloned out of the vec so
        // a slice that pumps the scheduler internally — or registers a
        // new service — never sees an outstanding borrow.)
        let services: Vec<Service> = self.services.borrow().clone();
        for (i, s) in services.iter().enumerate() {
            if s.active.get() {
                continue;
            }
            s.active.set(true);
            let did = (s.f.borrow_mut())();
            s.active.set(false);
            if did {
                self.bump(2, i as u64, now);
                return true;
            }
        }

        // Phase 3: nothing runnable → flush held-back (reorder-fault)
        // completions; a held CQE must not outlive its burst.
        let flushed = {
            let mut engines = self.engines.borrow_mut();
            let mut any = false;
            for e in engines.iter_mut() {
                any |= e.flush_hold();
            }
            any
        };
        if flushed {
            self.bump(3, 0, now);
            return true;
        }

        // Phase 4: advance virtual time to the earliest future event.
        let next = self.engines.borrow().iter().filter_map(|e| e.next_due()).min();
        if let Some(t) = next {
            if t > now {
                self.clock.advance_to(t);
                self.bump(4, t, now);
                return true;
            }
        }
        false
    }
}

thread_local! {
    static CURRENT: RefCell<Option<Rc<SimCore>>> = const { RefCell::new(None) };
}

/// If a [`SimExecutor`] is installed on this thread, run one scheduler
/// step and return `true`; otherwise do nothing and return `false`.
/// This is the hook [`Backoff::snooze`](crate::util::Backoff::snooze)
/// calls, making every polling wait in the stack a cooperative yield
/// point under sim.
#[inline]
pub fn maybe_pump() -> bool {
    let core = CURRENT.with(|c| c.borrow().clone());
    match core {
        Some(core) => {
            core.pump_once();
            true
        }
        None => false,
    }
}

/// The installed scheduler's progress counter, or `None` when this
/// thread is not running under a [`SimExecutor`].
/// [`WaitBudget`](crate::util::WaitBudget) uses this to make wedge
/// deadlines deterministic: the counter stalling across many pumps means
/// a true deadlock, not a long virtual wait.
pub fn progress() -> Option<u64> {
    CURRENT.with(|c| c.borrow().as_ref().map(|core| core.progress.get()))
}

/// Is a [`SimExecutor`] installed on this thread?
pub fn active() -> bool {
    CURRENT.with(|c| c.borrow().is_some())
}

/// The installed scheduler's *core* trace hash (decisions + clock
/// advances only), or `None` outside sim. Checker diagnostics stamp
/// this for replay. Unlike [`SimExecutor::trace_hash`] it does NOT fold
/// the per-engine traces in: diagnostics often fire from inside
/// `EngineCore::step`, while the engines `RefCell` is mutably borrowed.
pub fn current_trace_hash() -> Option<u64> {
    CURRENT.with(|c| c.borrow().as_ref().map(|core| core.trace.get()))
}

/// Register a cooperative service with the installed scheduler. Called
/// by components that would spawn a thread in threaded mode (manager
/// poll/ctrl loops, kvstore tracker). Panics if no [`SimExecutor`] is
/// installed — construct one before building managers or stores on a
/// sim cluster.
pub(crate) fn register_service(name: impl Into<String>, f: Box<dyn FnMut() -> bool>) {
    let core = CURRENT.with(|c| c.borrow().clone());
    let core = core.expect(
        "DeliveryMode::Sim requires a SimExecutor on this thread before \
         building managers/stores (services have nowhere to run)",
    );
    core.services.borrow_mut().push(Service {
        name: name.into(),
        active: Rc::new(Cell::new(false)),
        f: Rc::new(RefCell::new(f)),
    });
}

/// Names of the registered services (diagnostics/tests).
pub fn service_names() -> Vec<String> {
    CURRENT.with(|c| {
        c.borrow()
            .as_ref()
            .map(|core| core.services.borrow().iter().map(|s| s.name.clone()).collect())
            .unwrap_or_default()
    })
}

/// The single-threaded deterministic scheduler for a
/// [`DeliveryMode::Sim`] cluster. Owns the per-node engine cores and
/// installs itself in thread-local storage so the whole stack's waits
/// pump it; dropped, it uninstalls.
pub struct SimExecutor {
    core: Rc<SimCore>,
}

impl SimExecutor {
    /// Adopt `cluster` (which must be `DeliveryMode::Sim`) and install
    /// the scheduler on the current thread. Panics if another
    /// `SimExecutor` is already installed here.
    pub fn install(cluster: &Arc<Cluster>) -> SimExecutor {
        assert_eq!(
            cluster.config().delivery,
            DeliveryMode::Sim,
            "SimExecutor requires a cluster built with FabricConfig::sim"
        );
        let seed = cluster.config().seed;
        let core = Rc::new(SimCore {
            clock: cluster.clock().clone(),
            engines: RefCell::new(cluster.engine_cores()),
            services: RefCell::new(Vec::new()),
            sched_rng: RefCell::new(Rng::seeded(seed ^ 0x51D0_C0DE_0515_C0DE)),
            choices: RefCell::new(Vec::new()),
            plan: RefCell::new(None),
            plan_cursor: Cell::new(0),
            progress: Cell::new(0),
            trace: Cell::new(mix64(seed)),
        });
        CURRENT.with(|c| {
            let mut slot = c.borrow_mut();
            assert!(slot.is_none(), "a SimExecutor is already installed on this thread");
            *slot = Some(core.clone());
        });
        SimExecutor { core }
    }

    /// One scheduler step; returns whether anything moved.
    pub fn pump(&self) -> bool {
        self.core.pump_once()
    }

    /// Pump until the simulated world is fully quiescent: no engine has
    /// work now or in the future, no service has anything to do, no
    /// held completions. Panics (rather than hanging the test) if the
    /// world fails to settle within a generous step bound.
    pub fn settle(&self) {
        let mut steps: u64 = 0;
        while self.core.pump_once() {
            steps += 1;
            assert!(steps < 50_000_000, "sim failed to settle (livelocked service?)");
        }
    }

    /// Current virtual time.
    pub fn now_ns(&self) -> u64 {
        self.core.clock.now_ns()
    }

    /// Scheduler progress counter (monotone count of steps that did
    /// work).
    pub fn progress(&self) -> u64 {
        self.core.progress.get()
    }

    /// The event-trace hash: scheduler decisions + clock advances +
    /// every engine's executed-arrival trace. Two runs of the same
    /// seeded schedule must agree on this bit-for-bit.
    pub fn trace_hash(&self) -> u64 {
        let mut h = self.core.trace.get();
        for e in self.core.engines.borrow().iter() {
            h = mix64(h ^ e.trace());
        }
        h
    }

    /// The recorded scheduler decisions so far (one entry per choice
    /// point, each an index into that point's runnable set).
    pub fn choices(&self) -> Vec<u32> {
        self.core.choices.borrow().clone()
    }

    /// Force future decisions to follow `plan` (each entry clamped to
    /// the runnable count at its choice point; entries past the end of
    /// the plan fall back to 0). Used by the shrinker to replay and
    /// simplify interleavings.
    pub fn force_plan(&self, plan: Vec<u32>) {
        *self.core.plan.borrow_mut() = Some(plan);
        self.core.plan_cursor.set(0);
    }
}

impl Drop for SimExecutor {
    fn drop(&mut self) {
        CURRENT.with(|c| {
            c.borrow_mut().take();
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::verbs::{Payload, Verb, Wqe};
    use crate::fabric::{FabricConfig, LatencyModel};

    #[test]
    fn sim_cluster_roundtrip_over_virtual_time() {
        let c = Cluster::new(2, FabricConfig::sim(LatencyModel::fast_sim(), 7));
        let sim = SimExecutor::install(&c);
        let dst = c.node(1).register_mr(16, false);
        let qp = c.create_qp(0, 1);
        let wr = Wqe::new(1, Verb::Write { remote: dst.at(0), data: Payload::from_words(&[4, 5]) });
        c.post(qp, wr);
        assert!(c.node(0).cq().is_empty(), "nothing moves until the sim is pumped");
        sim.settle();
        assert!(sim.now_ns() > 0, "virtual time advanced");
        let mut out = Vec::new();
        assert_eq!(c.node(0).cq().poll(8, &mut out), 1);
        assert_eq!(out[0].wr_id, 1);
        assert_eq!(c.node(1).arena().load(dst.at(1)), 5);
    }

    #[test]
    fn snooze_pumps_installed_sim() {
        let c = Cluster::new(2, FabricConfig::sim(LatencyModel::fast_sim(), 9));
        let _sim = SimExecutor::install(&c);
        let dst = c.node(1).register_mr(4, false);
        let qp = c.create_qp(0, 1);
        c.post(qp, Wqe::new(3, Verb::Write { remote: dst.at(0), data: Payload::one(9) }));
        // poll_one_blocking spins via Backoff::snooze → maybe_pump.
        assert_eq!(c.node(0).cq().poll_one_blocking().wr_id, 3);
    }

    #[test]
    #[should_panic(expected = "already installed")]
    fn double_install_panics() {
        let c = Cluster::new(1, FabricConfig::sim(LatencyModel::fast_sim(), 1));
        let _a = SimExecutor::install(&c);
        let _b = SimExecutor::install(&c);
    }
}
